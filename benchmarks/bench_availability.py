"""ours — goodput/availability under failures, repair & live expansion.

Three scenario families exercising `repro.fault` end to end:

* **sweep** (steady state, no queueing noise) — a fixed full-port-budget
  placement mix in the paper's §6.2 heavy-workload regime (3-pod DP rings
  at full degree, a K5 MoE all-to-all, 2-pod dense pairs) runs while
  transceiver failure/repair renewal processes (MTBF derived from a target
  *concurrent failed-port fraction* at fixed MTTR) mask slots.  At every
  event the control plane re-solves — Cross Wiring via the degraded MDMCF
  (exact core + violation-minimizing slot assignment + salvage), Uniform
  via masked greedy matching — and per-job slowdowns come from the flow
  model.  Goodput = delivered compute integrated between events over
  capacity.  Uniform starts below 1 (odd rings / K5 are unrealizable) and
  shrinks further with failures; Cross Wiring reroutes around them.
* **policies** — a scripted pod failure + repair mid-trace in the full
  event-driven scheduler under each recovery policy: rewire-around loses
  the whole run (no checkpoints), checkpoint-restart rolls back to the
  last checkpoint and pays the restore cost, shrink-collective drops the
  pod and keeps going.
* **burst** — the same steady-state loop under a *correlated* top-of-pod
  OCS burst (``repro.fault.chaos``): ``k//4`` consecutive switches of
  one spine group dark together for 20% of the horizon — the correlation
  shape independent MTBF draws never produce (the full closed-loop
  treatment is ``bench_chaos.py``; this row keeps the steady-state
  goodput comparison honest under it).
* **expansion** — a live P−ΔP → P grow-out (ExpandEvent) under
  rewire-around on an overloaded small cluster: no running job restarts,
  queued jobs drain onto the new pods, JCT drops vs staying small.

Checks (in the payload and printed): Cross Wiring sustains strictly
higher goodput than Uniform at ≥1 nonzero failure rate; the expansion
causes zero restarts.
"""
from __future__ import annotations

import numpy as np

from repro.core.reconfig import ltrr, uniform_greedy
from repro.core.topology import ClusterSpec
from repro.dist import demand as dist_demand
from repro.fault import (
    ExpandEvent,
    FailureEvent,
    FaultModel,
    PortMask,
    RepairEvent,
    apply_event,
    masked_aggregate_demand,
    mdmcf_degraded,
    top_of_pod_burst,
)
from repro.obs import attribute_jobs
from repro.obs.attrib import JOB_CAUSES
from repro.sim import SimConfig, Simulator, generate_trace, summarize
from repro.sim import flowsim

from .common import save

LINK_MTTR_S = 4 * 3600.0
SIM_GROUPS = 2


def _mtbf_for_fraction(frac: float, mttr: float = LINK_MTTR_S) -> float:
    """MTBF so the steady-state concurrently-failed fraction is ``frac``."""
    return mttr * (1.0 - frac) / frac


# ---------------------------------------------------------------------------
# Part A — steady-state goodput sweep
# ---------------------------------------------------------------------------

def _steady_layout(P: int):
    """Full-budget placement mix tiling ``P`` pods in blocks of 8: a 3-pod
    DP ring (odd cycle at full degree — Uniform's Fig. 1 blind spot) and a
    K5 MoE all-to-all spill; leftover pods pair up as 2-pod dense jobs."""
    jobs = []
    p = 0
    while P - p >= 8:
        jobs.append((list(range(p, p + 3)), "llama2-13b", 1, 1))
        jobs.append((list(range(p + 3, p + 8)), "mixtral-8x7b", 8, 1))
        p += 8
    while P - p >= 2:
        jobs.append(([p, p + 1], "llama2-7b", 1, 1))
        p += 2
    return jobs


def _steady_state(P, k):
    """The fixed placement mix as (spec, jobs, total_gpus)."""
    spec = ClusterSpec(num_pods=P, k_spine=k, k_leaf=k)
    jobs = []
    for jid, (pods, model, ep, pp) in enumerate(_steady_layout(P)):
        links = k if len(pods) == 2 else k // 2
        edges, alpha = dist_demand.job_flow(model, pods, links, ep=ep, pp=pp)
        jobs.append((jid, edges, alpha, len(pods) * spec.gpus_per_pod))
    return spec, jobs, sum(j[3] for j in jobs)


def _resolve(spec, jobs, arch, mask, old):
    C = masked_aggregate_demand(
        spec.num_pods, SIM_GROUPS, [j[1] for j in jobs], mask
    )
    m = None if mask.is_trivial() else mask
    if arch == "cross_wiring":
        res = mdmcf_degraded(spec, C, old=old, mask=m)
    else:
        res = uniform_greedy(spec, C, mask=m)
    flows = [
        flowsim.JobFlows(jid, edges, alpha) for jid, edges, alpha, _ in jobs
    ]
    phi = flowsim.waterfill_fractions(spec, flows, res.config, arch)
    rate = sum(
        gpus / flowsim.job_slowdown(alpha, phi.get(jid, 1.0))
        for jid, _, alpha, gpus in jobs
    )
    return res.config, rate, ltrr(res.config, C)


def _goodput_run(spec, jobs, total_gpus, arch, events, horizon):
    """Integrate delivered compute between fault events (re-solving the
    control plane at each) over ``horizon``."""
    mask = PortMask.healthy(spec, SIM_GROUPS)
    cfg, rate, lt = _resolve(spec, jobs, arch, mask, None)
    lts, t_prev, work = [lt], 0.0, 0.0
    for ev in events:
        work += rate * (ev.time - t_prev)
        t_prev = ev.time
        apply_event(mask, ev)
        cfg, rate, lt = _resolve(spec, jobs, arch, mask, cfg)
        lts.append(lt)
    work += rate * (horizon - t_prev)
    return {
        "arch": arch,
        "events": len(events),
        "goodput": work / (horizon * total_gpus),
        "ltrr_avg": float(np.mean(lts)),
        "ltrr_min": float(np.min(lts)),
    }


def _steady_goodput(P, k, fractions, horizon, seed=0):
    spec, jobs, total_gpus = _steady_state(P, k)
    rows = []
    for frac in fractions:
        events = []
        if frac > 0:
            fm = FaultModel(
                P, k, SIM_GROUPS,
                link_mtbf_s=_mtbf_for_fraction(frac),
                link_mttr_s=LINK_MTTR_S,
                seed=seed + 17,
            )
            events = [e for e in fm.sample(horizon) if e.time < horizon]
        for arch in ("cross_wiring", "uniform"):
            row = _goodput_run(spec, jobs, total_gpus, arch, events, horizon)
            row["failed_frac"] = frac
            rows.append(row)
    return rows


def _burst_goodput(P, k, horizon):
    """Correlated top-of-pod burst through the same steady-state loop:
    ``k//4`` consecutive OCSes of one spine group drop *together* (one
    power domain) for 20% of the horizon.  Independent-failure MTBF math
    never produces this shape; Cross Wiring's degraded MDMCF reroutes
    around the darkened group while Uniform eats the correlated loss."""
    spec, jobs, total_gpus = _steady_state(P, k)
    events = top_of_pod_burst(
        0.3 * horizon, group=0, first_ocs=0, size=max(2, k // 4),
        repair_s=0.2 * horizon, k_spine=k,
    )
    return [
        _goodput_run(spec, jobs, total_gpus, arch, events, horizon)
        for arch in ("cross_wiring", "uniform")
    ]


# ---------------------------------------------------------------------------
# Part B — recovery policies (full scheduler, scripted pod failure)
# ---------------------------------------------------------------------------

def _policies(P, k, n_jobs, seed=0):
    jobs = generate_trace(
        n_jobs, num_gpus=P * k * k, workload_level=0.9, seed=seed,
        max_job_gpus=P * k * k // 4,
    )
    t_fail = jobs[len(jobs) // 3].arrival
    events = [
        FailureEvent(t_fail, "pod", pod=1),
        RepairEvent(t_fail + 2 * 3600.0, "pod", pod=1),
    ]
    rows = []
    # engine axis: the fluid engine prices OCS retune windows (100 ms) and
    # drives the 'cheapest' policy with fluid-measured degradation
    for engine in ("analytic", "fluid"):
        policies = ("rewire_around", "ckpt_restart", "shrink_collective",
                    "cheapest")
        for policy in policies:
            sim = Simulator(
                SimConfig(
                    architecture="cross_wiring", strategy="mdmcf",
                    num_pods=P, k_spine=k, k_leaf=k, recovery_policy=policy,
                    engine=engine,
                    reconfig_delay_s=0.1 if engine == "fluid" else 0.0,
                ),
                jobs,
                fault_events=events,
            )
            recs = sim.run()
            fs = sim.fault_summary()
            s = summarize(recs)
            # blame decomposition over finished jobs: where the JCT
            # inflation each policy pays actually went
            blames = attribute_jobs(sim)
            row = {
                "policy": policy,
                "engine": engine,
                "restarts": int(fs["restarts"]),
                "shrinks": int(fs["shrinks"]),
                "lost_gpu_s": fs["lost_gpu_s"],
                "availability": fs["availability"],
                "avg_jct": s["avg_jct"],
                "blame_jobs": len(blames),
                "blame_max_residual": max(
                    (abs(b.residual) for b in blames.values()), default=0.0
                ),
            }
            for c in JOB_CAUSES:
                row[f"blame_{c}_s"] = sum(
                    b.causes.get(c, 0.0) for b in blames.values()
                )
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Part C — live expansion
# ---------------------------------------------------------------------------

def _expansion(P, k, n_jobs, delta_pods, seed=0):
    """Live grow-out: start with P-ΔP active pods under heavy overload,
    expand to P mid-trace.  ``workload_level`` compensates for the
    truncated job mix (``max_job_gpus`` drops the large jobs that carry
    most of eq. 17's GPU-seconds), so the small cluster actually queues."""
    small_gpus = (P - delta_pods) * k * k
    jobs = generate_trace(
        n_jobs, num_gpus=small_gpus, workload_level=4.0,
        seed=seed, max_job_gpus=small_gpus // 4,
    )
    t_exp = jobs[len(jobs) // 3].arrival
    grow = [ExpandEvent(t_exp, tuple(range(P - delta_pods, P)))]
    out = {}
    for name, events in [("static_small", []), ("expanded", grow)]:
        sim = Simulator(
            SimConfig(
                architecture="cross_wiring", strategy="mdmcf",
                num_pods=P, k_spine=k, k_leaf=k,
                recovery_policy="rewire_around", active_pods=P - delta_pods,
            ),
            jobs,
            fault_events=events,
        )
        recs = sim.run()
        fs = sim.fault_summary()
        s = summarize(recs)
        out[name] = {
            "restarts": int(fs["restarts"]),
            "expands": int(fs["expands"]),
            "completed": s["completed"],
            "avg_jct": s["avg_jct"],
            "avg_jwt": s["avg_jwt"],
            "max_jwt": s["max_jwt"],
        }
    out["t_expand_s"] = t_exp
    return out


def run(quick: bool = True) -> dict:
    P, k = (18, 8) if quick else (36, 8)
    fractions = [0.0, 0.01, 0.03] if quick else [0.0, 0.005, 0.01, 0.02, 0.04]
    horizon = 24 * 3600.0 if quick else 72 * 3600.0
    sweep = _steady_goodput(P, k, fractions, horizon)
    burst = _burst_goodput(P, k, horizon)
    policies = _policies(16 if quick else 32, k, 40 if quick else 150)
    expansion = _expansion(16 if quick else 32, k, 70 if quick else 250, delta_pods=4)

    by_frac = {}
    for r in sweep:
        by_frac.setdefault(r["failed_frac"], {})[r["arch"]] = r["goodput"]
    cw_wins = [
        f for f, g in by_frac.items()
        if f > 0 and g["cross_wiring"] > g["uniform"]
    ]
    by_arch = {r["arch"]: r["goodput"] for r in burst}
    checks = {
        "cw_beats_uniform_at_nonzero_failure_rate": bool(cw_wins),
        "cw_win_fractions": cw_wins,
        "cw_beats_uniform_on_correlated_burst": (
            by_arch["cross_wiring"] > by_arch["uniform"]
        ),
        "policy_blame_conserved": all(
            r["blame_max_residual"] <= 1e-6 for r in policies
        ),
        "expansion_no_restarts": expansion["expanded"]["restarts"] == 0,
        "expansion_helps_jct": (
            expansion["expanded"]["avg_jct"]
            < expansion["static_small"]["avg_jct"]
        ),
    }
    payload = {
        "params": {
            "sweep_pods": P, "k": k, "fractions": fractions,
            "horizon_s": horizon, "link_mttr_s": LINK_MTTR_S,
        },
        "rows": sweep,
        "burst": burst,
        "policies": policies,
        "expansion": expansion,
        "checks": checks,
    }
    save("availability", payload)
    return payload


def main():
    p = run(quick=True)
    for r in p["rows"]:
        print(
            f"availability,sweep,{r['arch']},frac={r['failed_frac']},"
            f"goodput={r['goodput']:.4f},ltrr_avg={r['ltrr_avg']:.4f},"
            f"events={r['events']}"
        )
    for r in p["burst"]:
        print(
            f"availability,burst,{r['arch']},goodput={r['goodput']:.4f},"
            f"ltrr_min={r['ltrr_min']:.4f},events={r['events']}"
        )
    for r in p["policies"]:
        top = sorted(
            ((c, r[f"blame_{c}_s"]) for c in JOB_CAUSES),
            key=lambda kv: -kv[1],
        )[:3]
        blame = ",".join(f"{c}={v:.0f}s" for c, v in top if v > 0)
        print(
            f"availability,policy,{r['policy']}@{r['engine']},"
            f"restarts={r['restarts']},"
            f"shrinks={r['shrinks']},lost_gpu_s={r['lost_gpu_s']:.0f},"
            f"avg_jct={r['avg_jct']:.0f}"
            + (f",blame[{blame}]" if blame else "")
        )
    e = p["expansion"]
    print(
        f"availability,expansion,restarts={e['expanded']['restarts']},"
        f"jct_small={e['static_small']['avg_jct']:.0f},"
        f"jct_expanded={e['expanded']['avg_jct']:.0f},"
        f"jwt_small={e['static_small']['avg_jwt']:.0f},"
        f"jwt_expanded={e['expanded']['avg_jwt']:.0f}"
    )
    print(f"availability,checks,{p['checks']}")
    assert p["checks"]["cw_beats_uniform_at_nonzero_failure_rate"]
    assert p["checks"]["cw_beats_uniform_on_correlated_burst"]
    assert p["checks"]["expansion_no_restarts"]


if __name__ == "__main__":
    main()
