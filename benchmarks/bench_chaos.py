"""ours — closed-loop self-healing under correlated & gray failures.

The chaos suite: every scenario from
:func:`repro.fault.chaos.standard_scenarios` (a correlated top-of-pod
OCS burst, gray flapping links, and the compound burst+flap+derate
acceptance scenario) runs through the full event-driven scheduler twice
— **passive** (detect-only: the health monitor watches, nobody acts) and
**remediate** (a :class:`~repro.fault.RemediationEngine` wired as
``on_health`` cordons flappers with exponential-backoff readmission,
drains serving load off sick pods, pre-emptively checkpoints, and
escalates a thrashing incremental solver) — on both fabrics (Cross
Wiring/MDMCF and Uniform/greedy).

Per cell it reports time-based SLO **availability** (share of the run
with fleet φ above the SLO floor — :func:`repro.sim.serving.
slo_availability`), request **goodput** and p50/p99 TTFT, **training
goodput** (ideal GPU·s over occupied GPU·s of finished training jobs),
dark-window and solver-fallback counts, the engine's action ledger, and
the full per-cause blame decomposition from ``repro.obs.attrib`` — every
remediation-spent second lands in causes ``remediation``/``cordon`` and
conservation stays exact (max residual in the payload; the
``check_regression.py --chaos`` gate enforces ≤ 1e-6).

Workload and chaos parameters are tuned so the passive plane visibly
suffers: 1.1× offered training load plus two serving fleets on a 12-pod
cluster, 30 s reconfiguration delay, and four flappers on a 600 s period
— each flap forces a cold solve whose dark windows stall live circuits.
The headline check: remediation strictly improves availability *and*
serving goodput over passive for Cross Wiring on the compound scenario.
"""
from __future__ import annotations

import math

from repro.fault import RemediationEngine, scenario_events, standard_scenarios
from repro.obs import CAUSES, attribute_jobs, attribute_requests
from repro.sim import SimConfig, Simulator, generate_trace

from .common import save

P, K = 12, 8
GPUS = P * K * K
HORIZON_S = 8 * 3600.0


def _jobs():
    return generate_trace(
        12, num_gpus=GPUS, workload_level=1.1, seed=3,
        max_job_gpus=GPUS // 4, serving_jobs=2, serving_gpus=256,
    )


def _run_one(sc, arch: str, strategy: str, mode: str) -> dict:
    eng = RemediationEngine(cordon_base_s=600.0) if mode == "remediate" else None
    sim = Simulator(
        SimConfig(
            architecture=arch, strategy=strategy,
            num_pods=P, k_spine=K, k_leaf=K,
            engine="fluid", reconfig_delay_s=30.0,
            recovery_policy="ckpt_restart", serving_slo=2.0,
            on_health=eng,
        ),
        _jobs(),
        fault_events=scenario_events(sc, K),
    )
    # bounded at the scenario horizon: passive and remediated runs are
    # compared over the identical wall-clock window (a free post-horizon
    # drain would let pending backoff checks stretch the denominator)
    recs = sim.run(until=HORIZON_S)
    ss = sim.serving_summary()
    train = [r for r in recs if r.job.kind != "serve" and math.isfinite(r.finish)]
    ideal = sum(r.job.service_time * r.job.num_gpus for r in train)
    occupied = sum(r.jrt * r.job.num_gpus for r in train)

    req = attribute_requests(sim)
    blames = attribute_jobs(sim)
    job_residual = max((abs(b.residual) for b in blames.values()), default=0.0)
    row = {
        "scenario": sc.name,
        "arch": arch,
        "strategy": strategy,
        "mode": mode,
        "availability": ss["availability"],
        "goodput": ss["goodput"],
        "p50_s": ss["p50_s"],
        "p99_s": ss["p99_s"],
        "requests": ss["requests"],
        "train_goodput": ideal / occupied if occupied else math.nan,
        "train_finished": len(train),
        "dark_events": int(sim.downtime_events),
        "dark_s": float(sim.downtime_s),
        "solver_fallbacks": int(sim.solver_fallbacks),
        "blame_max_residual": max(req["max_residual"], job_residual),
        "blame_conserved": bool(req["conserved"]) and job_residual <= 1e-6,
    }
    for c in CAUSES:
        row[f"blame_{c}_s"] = req["totals"].get(c, 0.0)
    if eng is not None:
        for k, v in eng.summary().items():
            row[f"act_{k}"] = int(v)
    return row


def run(quick: bool = True) -> dict:
    scenarios = standard_scenarios(P, K, HORIZON_S)
    cells = []
    for sc in scenarios:
        for mode in ("passive", "remediate"):
            cells.append((sc, "cross_wiring", "mdmcf", mode))
    # Uniform has no incremental plane to thrash and no degraded MDMCF to
    # escalate to, but cordon/drain/ckpt still apply — in quick (CI) mode
    # one scenario pins that the sweep axis works end to end; --full runs
    # the whole grid.
    uniform_scs = scenarios[-1:] if quick else scenarios
    for sc in uniform_scs:
        for mode in ("passive", "remediate"):
            cells.append((sc, "uniform", "greedy", mode))
    rows = [_run_one(*cell) for cell in cells]

    def cell(sc_name, arch, mode):
        return next(
            r for r in rows
            if (r["scenario"], r["arch"], r["mode"]) == (sc_name, arch, mode)
        )

    improves = {}
    for sc in scenarios:
        p = cell(sc.name, "cross_wiring", "passive")
        r = cell(sc.name, "cross_wiring", "remediate")
        improves[sc.name] = {
            "availability": r["availability"] - p["availability"],
            "goodput": r["goodput"] - p["goodput"],
        }
    acc = improves["burst_flap"]
    checks = {
        # remediation never hurts availability, on any scenario
        "remediate_availability_ge_passive": all(
            d["availability"] >= -1e-9 for d in improves.values()
        ),
        # ... and strictly wins on the compound acceptance scenario
        "acceptance_strict_improvement": (
            acc["availability"] > 0 and acc["goodput"] > 0
        ),
        "blame_conserved": all(r["blame_conserved"] for r in rows),
        "improvements": improves,
    }
    payload = {
        "params": {
            "pods": P, "k": K, "gpus": GPUS, "horizon_s": HORIZON_S,
            "workload_level": 1.1, "serving_slo": 2.0,
            "reconfig_delay_s": 30.0, "cordon_base_s": 600.0,
            "scenarios": [sc.name for sc in scenarios],
        },
        "rows": rows,
        "checks": checks,
    }
    save("chaos", payload)
    return payload


def main():
    p = run(quick=True)
    for r in p["rows"]:
        acts = ",".join(
            f"{k[4:]}={r[k]}" for k in sorted(r) if k.startswith("act_") and r[k]
        )
        top = sorted(
            ((c, r[f"blame_{c}_s"]) for c in CAUSES), key=lambda kv: -kv[1]
        )[:3]
        blame = ",".join(f"{c}={v:.0f}s" for c, v in top if v > 0)
        print(
            f"chaos,{r['scenario']},{r['arch']},{r['mode']},"
            f"avail={r['availability']:.4f},goodput={r['goodput']:.4f},"
            f"p99={r['p99_s']:.3f},train={r['train_goodput']:.4f},"
            f"dark_s={r['dark_s']:.0f},fallbacks={r['solver_fallbacks']}"
            + (f",acts[{acts}]" if acts else "")
            + (f",blame[{blame}]" if blame else "")
        )
    print(f"chaos,checks,{p['checks']}")
    assert p["checks"]["remediate_availability_ge_passive"]
    assert p["checks"]["acceptance_strict_improvement"]
    assert p["checks"]["blame_conserved"]


if __name__ == "__main__":
    main()
