"""ours: collective completion time per fabric — Cross Wiring vs Uniform
vs Clos vs Best, driven by the ``repro.dist`` planner.

For a set of job archetypes (dense DP ring, MoE-EP all-to-all spillover,
PP stage chain) sharing a cluster, lower each job's collective schedule to
pod×pod demand, reconfigure the OCS under each architecture, water-fill
the realized capacities, and report per-job realized bandwidth fraction φ
and cross-pod collective completion time (alpha–beta model stretched by
1/φ).  The headline check: Cross Wiring's realized bandwidth fraction is
≥ Uniform's on every scenario (Theorem 4.1 — the all-to-all demand of the
MoE job is exactly what a symmetric-matching fabric cannot realize).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.reconfig import mdmcf_reconfigure, uniform_greedy
from repro.core.topology import ClusterSpec, OCSConfig
from repro.dist import (
    AlphaBeta,
    collectives_to_edges,
    comm_fraction_for,
    edges_to_matrix,
    plan_collectives,
    ring_order,
    schedule_time,
    uncoverable_fraction,
)
from repro.dist.demand import clip_feasible
from repro.sim import flowsim

from .common import save

# (name, model, pods occupied, ep, pp, dp_cross)
# moe_ep is the saturated spillover archetype: experts span 5 pods (DP
# replicas stay in-pod), so the OCS carries a K5 all-to-all at full port
# share — realizable under Cross Wiring (Thm 4.1), provably not under
# Uniform (a symmetric matching covers ≤ 2 of K5's edges per OCS).
SCENARIOS: List[Tuple[str, str, Tuple[int, ...], int, int, bool]] = [
    ("dense_dp", "llama2-13b", (0, 1, 11), 1, 1, True),
    ("moe_ep", "mixtral-8x7b", (2, 3, 4, 5, 6), 8, 1, False),
    ("pp_chain", "llama2-70b", (7, 8, 9, 10), 1, 4, True),
]
LINKS = 8  # half of k_spine per ring hop: jobs fully own their pods' ports


def _jobs_on_cluster():
    """All scenarios run concurrently on disjoint pod sets."""
    jobs = []
    for name, model, pods, ep, pp, dp_cross in SCENARIOS:
        colls = plan_collectives(
            model, len(pods), ep=ep, pp=pp, dp_cross=dp_cross
        )
        jobs.append({
            "name": name, "model": model, "pods": pods, "ep": ep, "pp": pp,
            "colls": colls,
        })
    return jobs


def _phi_for(arch: str, spec, jobs, config) -> Dict[int, float]:
    flows = [
        flowsim.JobFlows(i, j["edges"], 0.0) for i, j in enumerate(jobs)
    ]
    return flowsim.waterfill_fractions(spec, flows, config, arch)


def run(quick: bool = True) -> dict:
    spec = ClusterSpec(num_pods=12, k_spine=16, k_leaf=16)
    sim_groups = 2
    ab = AlphaBeta()
    jobs = _jobs_on_cluster()

    rows = []
    for arch in ("best", "cross_wiring", "uniform", "clos"):
        # per-arch ring ordering: warm configs let the pass matter; start
        # from the aggregate demand of sorted orders (cold), then re-order
        config = None
        for _ in range(2 if arch in ("cross_wiring", "uniform") else 1):
            for j in jobs:
                order = ring_order(sorted(j["pods"]), config, links=LINKS)
                j["order"] = order
                j["edges"] = collectives_to_edges(j["colls"], order, LINKS)
            C = sum(
                edges_to_matrix(j["edges"], spec.num_pods, sim_groups)
                for j in jobs
            )
            C = clip_feasible(C, spec.k_spine)
            if arch == "cross_wiring":
                config = mdmcf_reconfigure(spec, C).config
            elif arch == "uniform":
                config = uniform_greedy(spec, C).config
            else:
                config = None
                break

        phi = _phi_for(arch, spec, jobs, config)
        for i, j in enumerate(jobs):
            p = phi.get(i, 1.0)
            t_cross = schedule_time(
                [c for c in j["colls"] if c.scope == "cross_pod"],
                ab, links=LINKS, phi_cross=p,
            )
            alpha = comm_fraction_for(
                j["model"], len(j["pods"]), ep=j["ep"], pp=j["pp"],
                links=LINKS,
            )
            rows.append({
                "arch": arch,
                "scenario": j["name"],
                "phi": p,
                "cross_collective_s": t_cross,
                "comm_fraction": alpha,
                "step_slowdown": flowsim.job_slowdown(alpha, p),
                "uncoverable": (
                    uncoverable_fraction(j["edges"], config)
                    if config is not None else 0.0
                ),
            })

    by = {(r["arch"], r["scenario"]): r for r in rows}
    checks = {
        "cross_wiring_ge_uniform_phi": all(
            by[("cross_wiring", sc[0])]["phi"]
            >= by[("uniform", sc[0])]["phi"] - 1e-9
            for sc in SCENARIOS
        ),
        "best_is_upper_bound": all(
            r["phi"] <= 1.0 + 1e-9 for r in rows
        ),
    }
    payload = {"rows": rows, "checks": checks}
    save("collectives", payload)
    return payload


def main() -> None:
    payload = run()
    for r in payload["rows"]:
        print(
            f"collectives,{r['arch']},{r['scenario']},phi={r['phi']:.3f},"
            f"t_cross={r['cross_collective_s']*1e3:.1f}ms,"
            f"alpha={r['comm_fraction']:.3f},"
            f"slowdown={r['step_slowdown']:.3f}"
        )
    print(f"checks: {payload['checks']}")
    if not all(payload["checks"].values()):
        raise SystemExit("collective benchmark invariant violated")


if __name__ == "__main__":
    main()
