"""Ours — end-to-end simulator events/sec with the incremental control plane.

Runs the multi-tenant scheduler on the bench_jct trace (Sense-style Poisson
arrivals, eq. 17 workload calibration) twice per scale: cold-solving the
full ITV-MDMCF decomposition on every scheduler event, and carrying a
:class:`~repro.core.incremental.ColoringState` between events
(``SimConfig.incremental``).  The control plane solves **all** OCS groups
(``sim_groups = K_leaf``) so the per-event reconfiguration cost is the one
a real deployment pays.  The metric is heap events processed per second of
wall clock; exactness is asserted on the raw emitted circuits after each
run (cache-free — see ``_check_exactness``), not just on LTRR samples.

The committed baseline (benchmarks/baselines/control_plane.json) gates CI:
>3× events/sec regression on the incremental rows fails the build.
"""
from __future__ import annotations

import time

import numpy as np

from repro.sim import SimConfig, Simulator, generate_trace, summarize

from .common import save

SCALES_QUICK = [(128, 8)]  # the bench_jct scale with a control-plane-bound
# cold path; 64 pods is kept in full mode for context (its cold solver is
# small enough that shared simulator overhead caps the ratio near 3x)
SCALES_FULL = [(64, 8), (128, 8), (128, 16)]


def _run_once(P: int, k: int, jobs, incremental: bool):
    cfg = SimConfig(
        architecture="cross_wiring",
        strategy="mdmcf",
        num_pods=P,
        k_spine=k,
        k_leaf=k,
        sim_groups=k,  # solve every OCS group: real control-plane load
        incremental=incremental,
    )
    sim = Simulator(cfg, jobs)
    t0 = time.perf_counter()
    recs = sim.run()
    wall = time.perf_counter() - t0
    _check_exactness(sim)
    return sim, recs, wall


def _check_exactness(sim) -> None:
    """Exactness from the raw emitted circuits — deliberately bypassing the
    derived-view caches the exact solvers preseed, so a delta-path bug that
    dropped or misplaced a circuit cannot hide behind LTRR == 1."""
    cfg = sim.old_config
    cfg.validate()  # sub-permutation on raw x
    x = np.asarray(cfg.x, dtype=np.int64)
    realized = x.sum(axis=1)
    assert (realized == np.transpose(realized, (0, 2, 1))).all(), "asymmetric"
    even, odd = x[:, 0::2], x[:, 1::2]
    assert (odd == np.transpose(even, (0, 1, 3, 2))).all(), "L2 pairing broken"
    st = sim._coloring_state
    if st is not None:
        assert not st._poisoned
        assert (realized == st.C).all(), "raw x does not realize the demand"
        assert (cfg.x == st._x).all(), "emitted mirror out of sync"


def run(quick: bool = True) -> dict:
    scales = SCALES_QUICK if quick else SCALES_FULL
    n_jobs = 150 if quick else 400
    reps = 3
    rows = []
    for P, k in scales:
        num_gpus = P * k * k
        jobs = generate_trace(
            n_jobs, num_gpus=num_gpus, workload_level=0.801, seed=0,
            max_job_gpus=min(2048, num_gpus // 4),
        )
        eps = {}
        extra = {}
        for inc in (False, True):
            best = 0.0
            for _ in range(reps):
                sim, recs, wall = _run_once(P, k, jobs, inc)
                assert min(sim.ltrr_samples) >= 0.9999
                best = max(best, sim.events / wall)
            eps[inc] = best
            if inc:
                extra = {
                    "events": sim.events,
                    "reconfigs": sim.reconfig_calls,
                    "delta_hits": sim.delta_calls,
                    "avg_jct": summarize(recs)["avg_jct"],
                }
        rows.append(
            {
                "pods": P,
                "k_spine": k,
                "nodes": num_gpus,
                "cold_events_per_sec": eps[False],
                "incremental_events_per_sec": eps[True],
                "speedup": eps[True] / max(1e-12, eps[False]),
                **extra,
            }
        )
    payload = {
        "rows": rows,
        "trace": {"n_jobs": n_jobs, "workload_level": 0.801, "seed": 0},
        "metric": "heap events processed per wall-clock second (best of reps)",
    }
    save("control_plane", payload)
    return payload


def main():
    p = run(quick=False)
    for r in p["rows"]:
        print(
            f"control_plane,{r['nodes']},cold={r['cold_events_per_sec']:.0f}eps,"
            f"incremental={r['incremental_events_per_sec']:.0f}eps,"
            f"speedup={r['speedup']:.2f}x,delta_hits={r['delta_hits']}/{r['reconfigs']}"
        )


if __name__ == "__main__":
    main()
