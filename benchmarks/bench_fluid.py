"""ours — fluid engine: events/sec, fidelity gap, time-priced downtime.

Three parts exercising ``repro.sim.fluid`` end to end:

* **events** — raw engine throughput: a churning multi-flow trace on a
  P=128 cluster (periodic dark-window capacity events included) through
  the standalone :class:`FluidSim`.  Target: ≥ 1k processed events/sec
  (the vectorized water-filling makes a 10k-event trace a seconds-scale
  run).
* **fidelity** — the same scheduler trace under ``engine='analytic'`` vs
  ``engine='fluid'`` across reconfiguration delays.  At delay 0 the two
  engines agree to ~1e-4 relative JCT (the residue is the analytic
  engine's fixed OCS_SWITCH_S progress-pause stand-in); growing delays
  open real dark windows only the fluid engine prices.
* **downtime** — the reconfiguration-delay sweep (0 / 10 / 100 ms) on a
  multi-pod-job trace, Cross Wiring incremental (`mdmcf_delta`) vs
  warm-cold vs truly-cold (`mcf`): time-priced downtime
  Σ delay·|Δx| must be *strictly* smaller for incremental deltas than
  for cold re-solves at every nonzero delay.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro import obs
from repro.core.logical import Job
from repro.core.reconfig import mdmcf_cold
from repro.core.topology import ClusterSpec
from repro.dist import demand as dist_demand
from repro.sim import SimConfig, Simulator, generate_trace, summarize
from repro.sim import flowsim, fluid

from .common import ART_DIR, save


# ---------------------------------------------------------------------------
# Part A — standalone engine throughput
# ---------------------------------------------------------------------------

def _events_per_sec(P=128, k=8, n_flows=2000, seed=0, tracer=None):
    spec = ClusterSpec(num_pods=P, k_spine=k, k_leaf=k)
    rng = np.random.default_rng(seed)
    # a realized config carrying a full-degree ring over all pods — plenty
    # of shared capacity for random sub-rings to contend on
    ring = flowsim.ring_edges(list(range(P)), k // 2)
    C = dist_demand.edges_to_matrix(ring, P, 2)
    config = mdmcf_cold(spec, C).config

    flows, t = [], 0.0
    for fid in range(n_flows):
        t += float(rng.exponential(10.0))
        n = int(rng.integers(2, 7))
        start = int(rng.integers(0, P - n))
        pods = list(range(start, start + n))  # windows overlap across flows
        edges = flowsim.ring_edges(pods, int(rng.integers(1, 3)))
        flows.append(
            fluid.Flow(
                fid, edges, float(rng.uniform(0.1, 0.6)),
                float(rng.lognormal(5.0, 0.5)), arrival=t,
            )
        )
    horizon = t
    cap_events = [
        fluid.CapacityEvent(
            time=tc,
            dark_pairs=frozenset(
                {(int(i), int(i) + 1) for i in rng.integers(0, P - 1, size=8)}
            ),
            downtime_s=0.1,
            rewired=32,
        )
        for tc in np.arange(60.0, horizon, 120.0)
    ]
    sim = fluid.FluidSim(
        spec, "cross_wiring", config, flows=flows, capacity_events=cap_events,
        tracer=tracer,
    )
    t0 = time.perf_counter()
    recs = sim.run()
    wall = time.perf_counter() - t0
    done = sum(1 for r in recs if np.isfinite(r.finish))
    return {
        "num_pods": P,
        "flows": n_flows,
        "completed": done,
        "events": sim.events,
        "wall_s": wall,
        "events_per_sec": sim.events / max(wall, 1e-9),
        "downtime_circuit_s": sim.downtime_circuit_s,
    }


# ---------------------------------------------------------------------------
# Part B — fidelity gap: analytic vs fluid through the scheduler
# ---------------------------------------------------------------------------

def _fidelity(P=16, k=8, n_jobs=60, delays=(0.0, 0.01, 0.1), seed=1):
    jobs = generate_trace(
        n_jobs, num_gpus=P * k * k, workload_level=0.85, seed=seed,
        max_job_gpus=P * k * k // 4,
    )

    def _run(engine, delay):
        sim = Simulator(
            SimConfig(
                architecture="cross_wiring", strategy="mdmcf",
                num_pods=P, k_spine=k, k_leaf=k,
                engine=engine, reconfig_delay_s=delay,
            ),
            jobs,
        )
        return sim.run(), sim

    base, _ = _run("analytic", 0.0)
    rows = []
    for delay in delays:
        recs, sim = _run("fluid", delay)
        gaps = np.array(
            [abs(r.jct - b.jct) / max(b.jct, 1e-9) for r, b in zip(recs, base)]
        )
        rows.append(
            {
                "kind": "fidelity",
                "engine": "fluid",
                "delay_s": delay,
                "avg_jct": summarize(recs)["avg_jct"],
                "avg_jct_analytic": summarize(base)["avg_jct"],
                "rel_gap_mean": float(gaps.mean()),
                "rel_gap_max": float(gaps.max()),
                "downtime_events": sim.downtime_events,
                "downtime_circuit_s": sim.downtime_circuit_s,
            }
        )
    return rows


# ---------------------------------------------------------------------------
# Part C — time-priced downtime: incremental vs cold reconfigurations
# ---------------------------------------------------------------------------

def _multi_pod_trace(n, gpus_per_pod, seed=0, mean_gap_s=70.0):
    """All-multi-pod job mix (2–6 pods each): dense concurrent cross-pod
    demand, the regime where solver rewiring behavior actually separates
    (single-pod jobs put nothing on the OCS layer)."""
    rng = np.random.default_rng(seed)
    models = ["llama2-13b", "mixtral-8x7b", "llama2-70b", "pangu-alpha-6b"]
    plans = {"mixtral-8x7b": (8, 1), "llama2-70b": (1, 4)}
    jobs, t = [], 0.0
    for jid in range(n):
        t += float(rng.exponential(mean_gap_s))
        pods = int(rng.integers(2, 7))
        model = models[int(rng.integers(len(models)))]
        ep, pp = plans.get(model, (2, 1))
        jobs.append(
            Job(
                job_id=jid, num_gpus=pods * gpus_per_pod, arrival=t,
                service_time=float(rng.lognormal(7.2, 0.4)), model=model,
                tp=8, ep=ep, pp=pp,
            )
        )
    return jobs


def _downtime_sweep(P=16, k=8, n_jobs=60, delays=(0.0, 0.01, 0.1), seed=2):
    jobs = _multi_pod_trace(n_jobs, k * k, seed=seed)
    modes = [
        ("incremental", "mdmcf", True),
        ("warm_cold", "mdmcf", False),
        ("cold", "mcf", True),
    ]
    rows = []
    for delay in delays:
        for mode, strat, inc in modes:
            sim = Simulator(
                SimConfig(
                    architecture="cross_wiring", strategy=strat,
                    num_pods=P, k_spine=k, k_leaf=k,
                    engine="fluid", reconfig_delay_s=delay, incremental=inc,
                ),
                jobs,
            )
            recs = sim.run()
            rows.append(
                {
                    "kind": "downtime",
                    "mode": mode,
                    "delay_s": delay,
                    "downtime_circuit_s": sim.downtime_circuit_s,
                    "downtime_events": sim.downtime_events,
                    "delta_calls": sim.delta_calls,
                    "avg_jct": summarize(recs)["avg_jct"],
                }
            )
    return rows


def run(quick: bool = True) -> dict:
    n_flows = 1200 if quick else 5000
    _events_per_sec(n_flows=min(n_flows, 600))  # warmup (JIT-free, but cache-warm)
    ev = _events_per_sec(n_flows=n_flows)
    # same trace with the flight recorder attached: the no-op-when-disabled
    # discipline means tracing must cost < 5% events/sec (CI gate via
    # check_regression.py --tracing-overhead)
    tracer = obs.Tracer()
    ev_traced = _events_per_sec(n_flows=n_flows, tracer=tracer)
    trace_path = os.path.join(ART_DIR, "fluid_trace.json")
    os.makedirs(ART_DIR, exist_ok=True)
    tracer.export_json(trace_path)
    with open(trace_path) as fh:
        trace_problems = obs.validate_trace(json.load(fh))
    fidelity = _fidelity(n_jobs=50 if quick else 150)
    sweep = _downtime_sweep(n_jobs=50 if quick else 150)

    by_delay = {}
    for r in sweep:
        by_delay.setdefault(r["delay_s"], {})[r["mode"]] = r["downtime_circuit_s"]
    incr_strictly_cheaper = all(
        m["incremental"] < m["cold"]
        for d, m in by_delay.items()
        if d > 0
    )
    overhead = ev_traced["events_per_sec"] / max(ev["events_per_sec"], 1e-9)
    checks = {
        "events_per_sec_ge_1k": ev["events_per_sec"] >= 1000.0,
        "fidelity_gap_at_zero_delay_small": fidelity[0]["rel_gap_mean"] < 1e-3,
        "incremental_strictly_cheaper_than_cold": incr_strictly_cheaper,
        "tracing_overhead_ok": overhead >= 0.95,
        "trace_valid": not trace_problems,
        "downtime_by_delay": {
            str(d): m for d, m in sorted(by_delay.items())
        },
    }
    payload = {
        "throughput": ev,
        "throughput_traced": ev_traced,
        "tracing": {
            "throughput_ratio": overhead,
            "trace_events": len(tracer.events()),
            "trace_categories": sorted(tracer.categories()),
            "trace_path": trace_path,
            "trace_problems": trace_problems,
        },
        "rows": fidelity + sweep,
        "checks": checks,
    }
    save("fluid", payload)
    return payload


def main():
    p = run(quick=True)
    t = p["throughput"]
    print(
        f"fluid,events,P={t['num_pods']},flows={t['flows']},"
        f"events={t['events']},eps={t['events_per_sec']:.0f}/s,"
        f"wall={t['wall_s']:.2f}s"
    )
    tr = p["tracing"]
    print(
        f"fluid,tracing,ratio={tr['throughput_ratio']:.3f},"
        f"events={tr['trace_events']},cats={','.join(tr['trace_categories'])}"
    )
    for r in p["rows"]:
        if r["kind"] == "fidelity":
            print(
                f"fluid,fidelity,delay={r['delay_s']},"
                f"gap_mean={r['rel_gap_mean']:.2e},"
                f"gap_max={r['rel_gap_max']:.2e},"
                f"downtime_circ_s={r['downtime_circuit_s']:.2f}"
            )
        else:
            print(
                f"fluid,downtime,{r['mode']},delay={r['delay_s']},"
                f"circ_s={r['downtime_circuit_s']:.2f},"
                f"delta_calls={r['delta_calls']},avg_jct={r['avg_jct']:.0f}"
            )
    print(f"fluid,checks,{p['checks']}")
    assert p["checks"]["events_per_sec_ge_1k"]
    assert p["checks"]["incremental_strictly_cheaper_than_cold"]


if __name__ == "__main__":
    main()
