"""Paper Fig. 8a–d — multi-tenant end-to-end JRT / JWT / JCT.

Event-driven simulation of the generated trace under each
(architecture × strategy) pair, at several cluster scales and workload
levels.  ``Best`` (infinite crossbar) is the lower bound; slowdowns are
reported relative to it, as in the paper.
"""
from __future__ import annotations

import math

import numpy as np

from repro.sim import SimConfig, Simulator, generate_trace, summarize

from .common import save

PAIRS = [
    ("best", "none"),
    ("cross_wiring", "mdmcf"),
    ("cross_wiring", "mcf"),
    ("cross_wiring", "itv_ilp"),
    ("uniform", "greedy"),
    ("uniform", "uniform_ilp"),
    ("clos", "none"),
]

# engine axis: fluid rows re-run the OCS pairs with the event-driven fluid
# engine and a 100 ms reconfiguration dark window (sim/fluid.py) — what the
# analytic snapshot model approximates with its fixed switching pause
FLUID_PAIRS = [("cross_wiring", "mdmcf"), ("uniform", "greedy")]
FLUID_DELAY_S = 0.1


def _one_scale(num_pods: int, k: int, n_jobs: int, wl: float, seed: int = 0):
    num_gpus = num_pods * k * k
    jobs = generate_trace(
        n_jobs, num_gpus=num_gpus, workload_level=wl, seed=seed,
        max_job_gpus=min(2048, num_gpus // 4),
    )
    out = {}
    best = None
    runs = [(arch, strat, "analytic") for arch, strat in PAIRS]
    runs += [(arch, strat, "fluid") for arch, strat in FLUID_PAIRS]
    for arch, strat, engine in runs:
        sim = Simulator(
            SimConfig(
                architecture=arch, strategy=strat,
                num_pods=num_pods, k_spine=k, k_leaf=k,
                engine=engine,
                reconfig_delay_s=FLUID_DELAY_S if engine == "fluid" else 0.0,
            ),
            jobs,
        )
        recs = sim.run()
        s = summarize(recs)
        if best is None:
            best = recs
        s["jrt_slow_vs_best_avg"] = float(
            np.mean([r.jrt / b.jrt - 1.0 for r, b in zip(recs, best)])
        )
        s["jrt_slow_vs_best_max"] = float(
            np.max([r.jrt / b.jrt - 1.0 for r, b in zip(recs, best)])
        )
        s["jwt_slow_vs_best_avg"] = float(
            np.mean([r.jwt - b.jwt for r, b in zip(recs, best)])
        )
        s["pct_affected"] = float(
            np.mean([r.min_phi < 0.999 for r in recs]) * 100
        )
        key = f"{arch}/{strat}"
        if engine != "analytic":
            key += f"@{engine}"
            s["downtime_circuit_s"] = sim.downtime_circuit_s
        out[key] = s
    return out


def run(quick: bool = True) -> dict:
    # 64-GPU pods (k=8): pod granularity of the paper's testbed scaled up
    scales = [(64, 8), (128, 8)] if quick else [(64, 8), (128, 8), (256, 8), (512, 8)]
    n_jobs = 150 if quick else 1000
    wl_sweep = [0.801] if quick else [0.7, 0.801, 0.9]
    results = {}
    for P, k in scales:
        results[f"{P * k * k}gpu@0.801"] = _one_scale(P, k, n_jobs, 0.801)
    if not quick:
        for wl in wl_sweep:
            if wl == 0.801:
                continue
            results[f"{128 * 64}gpu@{wl}"] = _one_scale(128, 8, n_jobs, wl)
    payload = {"results": results, "paper_claim": {
        "uniform_greedy_avg_jrt_pct": 2.1,
        "uniform_greedy_worst_jrt_pct": 91.9,
        "pct_affected": 2.6,
        "clos_avg_jrt_pct": 1.3,
        "jct_gain_vs_ilp_32k_pct": 12.6,
    }}
    save("jct", payload)
    return payload


def main():
    p = run(quick=False)
    for scale, by in p["results"].items():
        for name, s in by.items():
            print(
                f"jct,{scale},{name},avg_jrt={s['avg_jrt']:.1f},"
                f"avg_jwt={s['avg_jwt']:.1f},avg_jct={s['avg_jct']:.1f},"
                f"slow_avg={s['jrt_slow_vs_best_avg']:.4f},"
                f"slow_max={s['jrt_slow_vs_best_max']:.3f},"
                f"affected%={s['pct_affected']:.1f}"
            )


if __name__ == "__main__":
    main()
