"""Paper Fig. 2b / Fig. 5 — Logical Topology Realization Rate by scale.

100 random full-fill demands per scale (quick: fewer); Cross Wiring must
stay at LTRR = 1.0 (Thm 4.1) while Uniform degrades with scale.
Scale = pods × 256 GPUs (K_spine = K_leaf = 16), matching the paper's
"each Pod contains 256 ports" setup up to 32k nodes.
"""
from __future__ import annotations

import numpy as np

from repro.core.logical import random_feasible_demand
from repro.core.reconfig import (
    helios_matching,
    mdmcf_reconfigure,
    uniform_best_effort,
    uniform_greedy,
)
from repro.core.topology import ClusterSpec

from .common import save

STRATEGIES = {
    "ITV-MDMCF": mdmcf_reconfigure,
    "Uniform-Greedy": uniform_greedy,
    "Uniform-ILP*": uniform_best_effort,  # Lagrangian-relaxed ILP stand-in
    "Helios": helios_matching,
}


def run(quick: bool = True) -> dict:
    pod_counts = [8, 32, 128] if quick else [8, 16, 32, 64, 128]
    n_topos = 10 if quick else 100
    rows = []
    for P in pod_counts:
        spec = ClusterSpec(num_pods=P, k_spine=16, k_leaf=16)
        rng = np.random.default_rng(0)
        demands = [
            random_feasible_demand(spec, rng, fill=1.0, num_groups=2)
            for _ in range(n_topos)
        ]
        for name, fn in STRATEGIES.items():
            vals = [fn(spec, C).ltrr for C in demands]
            rows.append(
                {
                    "nodes": spec.num_gpus,
                    "strategy": name,
                    "ltrr_avg": float(np.mean(vals)),
                    "ltrr_min": float(np.min(vals)),
                }
            )
    payload = {"rows": rows, "paper_claim": {
        "ITV": 1.0, "Uniform_avg_32k": 0.921, "Uniform_min": 0.703}}
    save("ltrr", payload)
    return payload


def main():
    p = run(quick=False)
    for r in p["rows"]:
        print(
            f"ltrr,{r['nodes']},{r['strategy']},{r['ltrr_avg']:.4f},{r['ltrr_min']:.4f}"
        )


if __name__ == "__main__":
    main()
