"""Paper Fig. 7 — Min-Rewiring Achievement Rate across consecutive
reconfigurations.

MRAR^ST = Σ_l cos(x_l, x_{l-1})^ST / Σ_l cos(x_l, x_{l-1})^REF  (eq. 16).

REF is warm-started MDMCF with Hungarian slot matching — our best rewiring
minimizer (the paper uses exact ILP; no ILP solver ships here, and the
paper itself shows MDMCF within 4% of ILP, so the reference substitution
shifts all MRARs by <4%; documented in EXPERIMENTS.md).
Compared: MDMCF(warm) vs MCF(cold, MinRewiring-[39] style) vs
Uniform-ILP* (Lagrangian-relaxed stand-in) — the paper's three regimes.
"""
from __future__ import annotations

import numpy as np

from repro.core.logical import random_feasible_demand
from repro.core.reconfig import (
    config_cosine,
    mdmcf_cold,
    mdmcf_reconfigure,
    uniform_best_effort,
)
from repro.core.topology import ClusterSpec

from .common import save


def _sequence_cos(spec, demands, step_fn):
    prev = None
    cs = []
    for C in demands:
        res = step_fn(spec, C, prev)
        if prev is not None:
            cs.append(config_cosine(res.config, prev))
        prev = res.config
    return float(np.sum(cs))


def run(quick: bool = True) -> dict:
    pod_counts = [16, 64] if quick else [16, 32, 64, 128]
    n_seq = 8 if quick else 20
    rows = []
    for P in pod_counts:
        spec = ClusterSpec(num_pods=P, k_spine=16, k_leaf=16)
        rng = np.random.default_rng(2)
        # temporally consecutive topologies: each is a perturbation of the
        # last (a fraction of jobs churn), as in the paper's §6.2 setup
        demands = [random_feasible_demand(spec, rng, fill=1.0, num_groups=2)]
        for _ in range(n_seq - 1):
            # multi-tenant churn: ~10% of links turn over per event (one job
            # arrives/leaves), the regime the Min-Rewiring objective targets
            base = demands[-1].copy()
            churn = random_feasible_demand(spec, rng, fill=0.1, num_groups=2)
            mixed = np.maximum(base - churn, 0) + churn
            # re-clip to feasibility
            for h in range(mixed.shape[0]):
                deg = mixed[h].sum(axis=1)
                while (deg > spec.k_spine).any():
                    p = int(np.argmax(deg))
                    q = int(np.argmax(mixed[h, p]))
                    mixed[h, p, q] -= 1
                    mixed[h, q, p] -= 1
                    deg = mixed[h].sum(axis=1)
            demands.append(mixed)

        # REF = MDMCF warm + Hungarian slot matching (ILP substitute)
        ref = _sequence_cos(
            spec, demands, lambda s, C, old: mdmcf_reconfigure(s, C, old=old)
        )
        # MCF = MinRewiring-[39]-style: decomposition reuse, no slot align
        mcf = _sequence_cos(
            spec, demands,
            lambda s, C, old: mdmcf_reconfigure(s, C, old=old, slot_match=False),
        )
        cold = _sequence_cos(spec, demands, lambda s, C, old: mdmcf_cold(s, C))
        uni = _sequence_cos(
            spec, demands, lambda s, C, old: uniform_best_effort(s, C)
        )
        rows.append(
            {
                "nodes": spec.num_gpus,
                "MRAR_MDMCF(warm+slot)": 1.0,
                "MRAR_MCF(decomp-reuse)": mcf / ref if ref else 1.0,
                "MRAR_cold": cold / ref if ref else 1.0,
                "MRAR_Uniform-ILP*": uni / ref if ref else 1.0,
            }
        )
    payload = {"rows": rows, "paper_claim": {
        "MDMCF_vs_MCF_gain_pct": 2.77, "Uniform_vs_ITV_ILP_drop_pct": 16.14}}
    save("mrar", payload)
    return payload


def main():
    for r in run(quick=False)["rows"]:
        print(
            f"mrar,{r['nodes']},warm=1.0,mcf={r['MRAR_MCF(decomp-reuse)']:.4f},"
            f"cold={r['MRAR_cold']:.4f},uniform={r['MRAR_Uniform-ILP*']:.4f}"
        )


if __name__ == "__main__":
    main()
