"""Paper Table 1 — training overhead vs OCS reconfiguration frequency.

A llama2-7B-class job trains (1103 ms/step baseline, the paper's number)
while background tenant churn forces OCS reconfiguration every T seconds.
With the Min-Rewiring objective most of the job's links survive each event
(warm-started MDMCF); each *rewired* link pauses affected traffic for the
optical switching + reconvergence time.  We measure the actually-rewired
link fraction from the control plane and report amortized ms/step.
"""
from __future__ import annotations

import numpy as np

from repro.core.logical import random_feasible_demand, ring_demand
from repro.core.reconfig import mdmcf_cold, mdmcf_reconfigure
from repro.core.topology import ClusterSpec

from .common import save

STEP_MS = 1103.0  # paper's no-reconfiguration step time
# per-event pause if *all* of a job's links rewire: MEMS switching (~10 ms)
# is negligible — the dominant term is BGP reconvergence of rewired links,
# which the paper's §5 Discussion flags as the scalability challenge.
SWITCH_PAUSE_MS = 14000.0
INTERVALS = (30.0, 60.0, 90.0, float("inf"))


def run(quick: bool = True) -> dict:
    spec = ClusterSpec(num_pods=4, k_spine=8, k_leaf=8)
    rng = np.random.default_rng(0)
    # the job: 96-GPU llama2 on pods {0,1,2} (the testbed's static ring)
    job = ring_demand(spec, [0, 1, 2], links=2)
    n_events = 10 if quick else 40

    rows = []
    for warm in (True, False):
        frac_changed = []
        prev = None
        for _ in range(n_events):
            bg = random_feasible_demand(spec, rng, fill=0.4)
            total = np.minimum(job + bg, spec.k_spine)  # clip conservatively
            # keep symmetric + feasible
            total = np.minimum(total, np.transpose(total, (0, 2, 1)))
            res = (
                mdmcf_reconfigure(spec, total, old=prev)
                if warm
                else mdmcf_cold(spec, total)
            )
            if prev is not None:
                # job link survival: circuits serving pods {0,1,2} pairs
                kept = 0
                tot = 0
                for i, j in ((0, 1), (1, 2), (0, 2)):
                    old_units = np.minimum(prev.x[:, :, i, j], res.config.x[:, :, i, j]).sum()
                    new_units = res.config.x[:, :, i, j].sum()
                    kept += old_units
                    tot += new_units
                frac_changed.append(1.0 - kept / max(tot, 1))
            prev = res.config
        fc = float(np.mean(frac_changed))
        for interval in INTERVALS:
            if np.isinf(interval):
                overhead = 0.0
            else:
                steps_between = interval * 1000.0 / STEP_MS
                overhead = SWITCH_PAUSE_MS * fc / steps_between
            rows.append(
                {
                    "objective": "min-rewiring" if warm else "cold",
                    "interval_s": interval,
                    "frac_links_rewired": fc,
                    "avg_ms_per_step": STEP_MS + overhead,
                }
            )
    payload = {"rows": rows, "paper_claim": {
        "30s": 1175.4, "60s": 1112.8, "90s": 1103.2, "none": 1103.0}}
    save("reconfig_interval", payload)
    return payload


def main():
    for r in run(quick=False)["rows"]:
        print(
            f"reconfig_interval,{r['objective']},{r['interval_s']},"
            f"rewired={r['frac_links_rewired']:.3f},ms={r['avg_ms_per_step']:.1f}"
        )


if __name__ == "__main__":
    main()
