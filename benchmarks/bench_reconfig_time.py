"""Paper Fig. 2c / Fig. 6 — OCS reconfiguration computation time by scale.

Measured: our MDMCF (Euler fast path), the MCF-oracle path (networkx
min-cost-flow, the paper's proof construction), Uniform-Greedy, and the
incremental delta path (``ITV-MDMCF(incremental)``): a warm
:class:`~repro.core.incremental.ColoringState` patched with a single-job
demand delta (one DP ring arriving), which is the per-event cost the
multi-tenant scheduler actually pays between cold solves.
Modeled: exact-ILP runtime from the calibrated curve (no ILP solver in this
container; anchored to the paper's 435.07 s at 32k nodes).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.incremental import ColoringState, mdmcf_delta
from repro.core.logical import random_feasible_demand, ring_demand
from repro.core.reconfig import mdmcf_reconfigure, uniform_greedy
from repro.core.topology import ClusterSpec, demand_feasible
from repro.sim.scheduler import ilp_time_model

from .common import save


def _single_job_delta(spec, C, rng, num_groups):
    """C plus one arriving job: a DP ring over 8 random pods."""
    P = spec.num_pods
    for attempt in range(64):
        pods = sorted(rng.choice(P, size=min(8, P), replace=False).tolist())
        links = 1 if attempt >= 8 else int(rng.integers(1, 3))
        R = ring_demand(spec, pods, links, num_groups=num_groups)
        if demand_feasible(C + R, spec):
            return C + R
    raise RuntimeError("no feasible single-job delta found (demand saturated)")


def run(quick: bool = True) -> dict:
    pod_counts = [8, 32, 128] if quick else [8, 16, 32, 64, 128, 256]
    reps = 3 if quick else 10
    rows = []
    for P in pod_counts:
        spec = ClusterSpec(num_pods=P, k_spine=16, k_leaf=16)
        H = spec.num_ocs_groups  # 16 — time the FULL group set here
        rng = np.random.default_rng(1)
        demands = [
            random_feasible_demand(spec, rng, fill=1.0, num_groups=H)
            for _ in range(reps)
        ]
        meas = {}
        for name, fn, kw in (
            ("ITV-MDMCF(euler)", mdmcf_reconfigure, {}),
            ("ITV-MDMCF(mcf-oracle)", mdmcf_reconfigure, {"method": "mcf"}),
            ("Uniform-Greedy", uniform_greedy, {}),
        ):
            if quick and name == "ITV-MDMCF(mcf-oracle)" and P > 32:
                continue  # oracle is O(P^2) nodes in the flow graph
            ts = []
            for C in demands:
                t0 = time.perf_counter()
                fn(spec, C, **kw)
                ts.append(time.perf_counter() - t0)
            meas[name] = float(np.mean(ts))
        # incremental: warm state at fill 0.8, patch in one arriving job.
        # Measured in the scheduler's hot-path configuration (feasibility
        # guaranteed by the caller, sub-permutation by construction —
        # validate/check_feasible off), against the warm-started cold
        # solve the scheduler would otherwise run on the same demand.
        ts_inc, ts_warm_cold = [], []
        base = random_feasible_demand(spec, rng, fill=0.8, num_groups=H)
        res0 = mdmcf_reconfigure(spec, base)
        state = ColoringState.from_config(spec, base, res0.config)
        C_cur = base
        prev = res0.config
        for _ in range(reps):
            # one arriving job, then its departure (keeps headroom stable)
            for C_next in (_single_job_delta(spec, C_cur, rng, H), C_cur):
                t0 = time.perf_counter()
                res = mdmcf_delta(
                    spec, state, C_next, validate=False, check_feasible=False
                )
                ts_inc.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                mdmcf_reconfigure(spec, C_next, old=prev)
                ts_warm_cold.append(time.perf_counter() - t0)
                prev = res.config
        meas["ITV-MDMCF(incremental)"] = float(np.mean(ts_inc))
        meas["ITV-MDMCF(warm-cold)"] = float(np.mean(ts_warm_cold))
        rows.append(
            {
                "nodes": spec.num_gpus,
                **meas,
                "incremental_speedup_vs_cold": float(
                    np.mean(ts_warm_cold) / max(1e-12, np.mean(ts_inc))
                ),
                "ILP(modeled)": ilp_time_model(spec.num_gpus),
            }
        )
    payload = {"rows": rows, "paper_claim": {
        "MDMCF_32k_s": 19.37, "ILP_32k_s": 435.07, "speedup": 22.5}}
    save("reconfig_time", payload)
    return payload


def main():
    p = run(quick=False)
    for r in p["rows"]:
        parts = ",".join(f"{k}={v:.4f}" for k, v in r.items() if k != "nodes")
        print(f"reconfig_time,{r['nodes']},{parts}")


if __name__ == "__main__":
    main()
