"""Paper Fig. 2c / Fig. 6 — OCS reconfiguration computation time by scale.

Measured: our MDMCF (Euler fast path), the MCF-oracle path (networkx
min-cost-flow, the paper's proof construction), and Uniform-Greedy.
Modeled: exact-ILP runtime from the calibrated curve (no ILP solver in this
container; anchored to the paper's 435.07 s at 32k nodes).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.logical import random_feasible_demand
from repro.core.reconfig import mdmcf_reconfigure, uniform_greedy
from repro.core.topology import ClusterSpec
from repro.sim.scheduler import ilp_time_model

from .common import save


def run(quick: bool = True) -> dict:
    pod_counts = [8, 32, 128] if quick else [8, 16, 32, 64, 128]
    reps = 3 if quick else 10
    rows = []
    for P in pod_counts:
        spec = ClusterSpec(num_pods=P, k_spine=16, k_leaf=16)
        H = spec.num_ocs_groups  # 16 — time the FULL group set here
        rng = np.random.default_rng(1)
        demands = [
            random_feasible_demand(spec, rng, fill=1.0, num_groups=H)
            for _ in range(reps)
        ]
        meas = {}
        for name, fn, kw in (
            ("ITV-MDMCF(euler)", mdmcf_reconfigure, {}),
            ("ITV-MDMCF(mcf-oracle)", mdmcf_reconfigure, {"method": "mcf"}),
            ("Uniform-Greedy", uniform_greedy, {}),
        ):
            if quick and name == "ITV-MDMCF(mcf-oracle)" and P > 32:
                continue  # oracle is O(P^2) nodes in the flow graph
            ts = []
            for C in demands:
                t0 = time.perf_counter()
                fn(spec, C, **kw)
                ts.append(time.perf_counter() - t0)
            meas[name] = float(np.mean(ts))
        rows.append(
            {
                "nodes": spec.num_gpus,
                **meas,
                "ILP(modeled)": ilp_time_model(spec.num_gpus),
            }
        )
    payload = {"rows": rows, "paper_claim": {
        "MDMCF_32k_s": 19.37, "ILP_32k_s": 435.07, "speedup": 22.5}}
    save("reconfig_time", payload)
    return payload


def main():
    p = run(quick=False)
    for r in p["rows"]:
        parts = ",".join(f"{k}={v:.4f}" for k, v in r.items() if k != "nodes")
        print(f"reconfig_time,{r['nodes']},{parts}")


if __name__ == "__main__":
    main()
