"""ours — the scenario suite end to end: goldens + calibration drift.

Runs every catalogued multi-day scenario (``repro.scenario.CATALOG``)
and emits one row per scenario with the headline summary metrics (JCT,
goodput, SLO availability, p50/p99 TTFT, dark circuit-seconds, blame
residual, action counts) plus the *calibration table* — the per-arch
step times the suite derives from the committed ``BENCH_step.json``
constants.

Quick (CI) mode runs the reduced-scale ``quick_spec`` twins — same
composition (chaos, expansion, routing, remediation), minutes of
simulated time — and checks run-level byte-determinism per scenario.
Full mode (``--full`` via benchmarks.run) runs the catalogued specs and
additionally asserts each canonical summary matches its committed
golden under ``tests/golden/scenarios/`` byte for byte.

The ``check_regression.py --scenarios`` gate re-derives the invariants
from this block's rows (golden match, determinism, blame conservation)
and pins the recorded calibration constants against the current
``BENCH_step.json`` — a re-bench that moves step times must ship
regenerated scenario goldens with it.
"""
from __future__ import annotations

import hashlib
import math
import os

from repro.scenario import (
    CATALOG,
    calibration_report,
    get_scenario,
    quick_spec,
    run_scenario,
)

from .common import save

_GOLDEN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "tests", "golden", "scenarios",
)


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def _run_one(name: str, quick: bool) -> dict:
    spec = quick_spec(get_scenario(name)) if quick else get_scenario(name)
    summary, sim = run_scenario(spec)
    text = summary.to_json() + "\n"
    rerun, _ = run_scenario(spec)
    t = summary.table
    row = {
        "scenario": name,
        "quick": quick,
        "horizon_s": spec.horizon_s,
        "avg_jct": t["train"]["avg_jct"],
        "train_finished": t["train"]["finished"],
        "goodput": t["goodput"],
        "availability": t["availability"],
        "dark_circuit_s": t["dark"]["circuit_s"],
        "blame_max_residual": t["blame"]["max_residual"],
        "blame_conserved": bool(t["blame"]["conserved"]),
        "deterministic": rerun.to_json() + "\n" == text,
        "summary_sha256": _sha(text),
        "actions_reconfig": t["actions"]["reconfig_calls"],
        "actions_delta": t["actions"]["delta_calls"],
    }
    sv = t.get("serving")
    if sv is not None:
        row.update(
            requests=sv["requests"],
            p50_ttft_s=sv["p50_ttft_s"],
            p99_ttft_s=sv["p99_ttft_s"],
            serving_goodput=sv["goodput"],
            slo_availability=sv["slo_availability"],
        )
    if not quick:
        path = os.path.join(_GOLDEN_DIR, f"{name}.json")
        golden = open(path).read() if os.path.exists(path) else None
        row["golden_match"] = golden == text
    return row


def run(quick: bool = True) -> dict:
    rows = [_run_one(name, quick) for name in CATALOG]
    calib = calibration_report()
    checks = {
        "all_deterministic": all(r["deterministic"] for r in rows),
        "blame_conserved": all(r["blame_conserved"] for r in rows),
        "calibrated_archs": sorted(calib),
    }
    if not quick:
        checks["all_golden_match"] = all(r.get("golden_match") for r in rows)
    payload = {
        "rows": rows,
        "calibration": [
            {"arch": arch, **vals} for arch, vals in sorted(calib.items())
        ],
        "checks": checks,
    }
    save("scenarios", payload)
    return payload


def main() -> None:
    quick = os.environ.get("BENCH_FULL", "") != "1"
    payload = run(quick=quick)
    print("scenario,avg_jct,goodput,p99_ttft_s,dark_circuit_s,residual")
    for r in payload["rows"]:
        print(
            f"{r['scenario']},{r['avg_jct']:.1f},{r['goodput']:.3f},"
            f"{r.get('p99_ttft_s', math.nan):.3f},"
            f"{r['dark_circuit_s']:.2f},{r['blame_max_residual']:.2e}"
        )
    for arch in payload["calibration"]:
        print(
            f"calib,{arch['arch']},step_ms={arch['measured_step_ms']:.3f},"
            f"compute_s={arch['compute_s']:.3f}"
        )
    for k, v in payload["checks"].items():
        print(f"check,{k},{v}")
        if isinstance(v, bool):
            assert v, k


if __name__ == "__main__":
    main()
