"""ours: inference serving over the optical fabric — p99 KV-transfer
latency (TTFT proxy) and SLO goodput, Cross Wiring vs Uniform vs Helios.

Mixed train+serve traces (``generate_trace(serving_jobs=...)``) run under
the fluid engine with real reconfiguration dark windows: every train-job
arrival/finish and every diurnal autoscale event re-solves the control
plane, and the circuits that move go dark for ``RECONFIG_DELAY_S``.  The
serving fleets' prefill→decode KV streams are latency-critical, so the
quantity that separates the fabrics is the *tail*: Cross Wiring realizes
the bipartite KV demand exactly (φ = 1, Thm 4.1) and its incremental
deltas (`mdmcf_delta`) move few circuits, while Uniform/Helios both
under-realize the demand and cold-solve every event, darkening more of
the serving fleet's pairs.

Every row also carries the blame decomposition (``repro.obs.attrib``):
the fleet's total slowdown split into named causes (``blame_<cause>_s``)
and the p99-tail split (``p99_<cause>_s`` — the mean breakdown of the
slowest 1 % of requests), so the headline p99 delta arrives *explained*:
Cross Wiring wins because its dark-window share is smaller, not merely
because the number is smaller.

Invariant gates (CI): Cross Wiring's pooled p99 KV-transfer latency is
≤ Uniform's on every load level; blame conservation holds on every
fleet; Cross Wiring's dark-window blame share never exceeds Uniform's
(``check_regression.py --attribution``).

The router axis (``repro.serve.router``) re-runs the high-load level
with per-request prefill→decode routing under every policy in
``ROUTER_POLICIES`` and a tighter interactive SLO (``ROUTER_SLO`` ×
ideal, vs the pooled ``serving_slo`` of 4×): at that operating point
the naive policies pay the full KV transfer on every request and land
on degraded pods in proportion to pod count, while ``topology_aware``
both reuses the session prefix cache (hits skip the stream entirely)
and steers misses toward pods with φ headroom.  Gates
(``check_regression.py --routing``): ``topology_aware`` strictly beats
``random`` and ``round_robin`` on fleet-mean p99 and SLO goodput on
both fabrics, stays ≤ ``round_robin`` per fleet, and the CW-vs-Uniform
p99/goodput ordering survives on every policy row.
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.fault import FaultModel, merge_events
from repro.obs import attribute_requests
from repro.obs.attrib import CAUSES, DARK_CAUSES
from repro.sim import (
    ROUTER_POLICIES,
    SimConfig,
    Simulator,
    autoscale_events,
    generate_trace,
)

from .common import save

# (architecture, strategy) triples under comparison; helios runs on the
# uniform fabric (repeated max-weight matchings, no L2 cross wiring)
PAIRS = [
    ("cross_wiring", "mdmcf"),
    ("uniform", "greedy"),
    ("uniform", "helios"),
]

RECONFIG_DELAY_S = 0.1  # OCS retune dark window
DIURNAL = 0.3
PERIOD_S = 1200.0  # compressed "day" so autoscale fires inside the horizon
LOAD_LEVELS = (0.5, 1.0, 2.0)  # low / mid / high serving load
LINK_FAIL_FRACTION = 0.005  # steady-state concurrently-failed port share
LINK_MTTR_S = 600.0
ROUTER_LOAD = 2.0  # router axis runs at the high load only
ROUTER_SLO = 2.0  # interactive TTFT SLO for the routed axis (× ideal);
# the pooled default of 4× never bites here (max-min waterfill floors
# φ at 1/pairs), so policy differences would be invisible in goodput
ROUTER_PAIRS = [("cross_wiring", "mdmcf"), ("uniform", "greedy")]


def _pooled_dark_share(rows, arch: str, strat: str,
                       load: float = None) -> float:
    """Dark-window blame pooled over a (arch, strategy)'s serving
    fleets — every load level unless ``load`` pins one — as a share of
    their total ideal service time (the same request stream on every
    fabric, so the denominators are identical and the ordering equals
    the absolute dark-seconds ordering).  The gate compares the
    all-loads pool: a single low-load level carries a few seconds of
    dark blame against hours of ideal service, so its per-level
    ordering is sampling noise, not signal."""
    sel = [r for r in rows
           if (r["arch"], r["strategy"]) == (arch, strat)
           and (load is None or r["load"] == load)
           and r.get("policy", "pooled") == "pooled"]
    ideal = math.fsum(r["ideal_total_s"] for r in sel)
    return math.fsum(r["dark_s"] for r in sel) / ideal if ideal > 0 else 0.0


def _fleet_rows(sim, arch: str, strat: str, load: float,
                policy: str, slo: float) -> List[Dict[str, float]]:
    """One row per serving fleet: tail/goodput plus the blame
    decomposition, and (routed runs) the router's accounting."""
    s = sim.serving_summary()
    attr = attribute_requests(sim)
    out: List[Dict[str, float]] = []
    for jid, jr in sorted(s["jobs"].items()):
        ab = attr["jobs"][jid]
        slowdown = ab["slowdown_s"]
        dark_s = math.fsum(ab["blame"][c] for c in DARK_CAUSES)
        row = {
            "arch": arch,
            "strategy": strat,
            "load": load,
            "policy": policy,
            "slo": slo,
            "fleet": sim.records[jid].job.model,
            "requests": jr["requests"],
            "p50_s": jr["p50_s"],
            "p99_s": jr["p99_s"],
            "goodput": jr["goodput"],
            "ideal_s": jr["ideal_s"],
            "autoscale_applied": s["autoscale_applied"],
            "delta_calls": float(sim.delta_calls),
            "reconfigs": float(sim.reconfig_calls),
            "downtime_circuit_s": sim.downtime_circuit_s,
            # blame decomposition: the p99 delta, explained.
            # dark_share normalizes by the fleet's total *ideal*
            # service time — identical across fabrics at the same
            # load — so the fabrics' dark-window exposure is
            # directly comparable (a share of own slowdown would
            # reward a fabric for being slow everywhere else)
            "slowdown_s": slowdown,
            "dark_s": dark_s,
            "ideal_total_s": jr["requests"] * jr["ideal_s"],
            "dark_share": (
                dark_s / (jr["requests"] * jr["ideal_s"])
                if jr["requests"] else 0.0
            ),
            "blame_max_residual": ab["max_residual"],
        }
        for c in CAUSES:
            row[f"blame_{c}_s"] = ab["blame"][c]
            row[f"p99_{c}_s"] = ab["p99_blame"][c]
        for key, val in jr.get("routing", {}).items():
            if key != "policy":  # already a row column
                row[f"routing_{key}"] = float(val)
        out.append(row)
    return out


def run(quick: bool = True) -> dict:
    num_pods, k = (12, 8) if quick else (16, 16)
    horizon = 2500.0 if quick else 7200.0
    n_train = 24 if quick else 80
    num_gpus = num_pods * k * k
    serving_gpus = 4 * k * k  # fleets span ~4 pods: cross-pod KV streams

    # a thin stream of transceiver failures (the dominant class in real
    # optical plants): degraded-mode TE quality shows up directly as
    # serving tail latency
    faults = FaultModel(
        num_pods=num_pods, k_spine=k, num_groups=2,
        link_mtbf_s=LINK_MTTR_S * (1 - LINK_FAIL_FRACTION) / LINK_FAIL_FRACTION,
        link_mttr_s=LINK_MTTR_S, seed=7,
    ).sample(horizon)

    rows: List[Dict[str, float]] = []
    for load in LOAD_LEVELS:
        jobs = generate_trace(
            n_train, num_gpus=num_gpus, workload_level=0.801, seed=0,
            max_job_gpus=num_gpus // 4, serving_jobs=2,
            serving_gpus=serving_gpus, serving_diurnal=DIURNAL,
            serving_load=load,
        )
        evs = list(faults)
        for j in jobs:
            if j.kind == "serve":
                evs += autoscale_events(j, horizon, period_s=PERIOD_S)
        evs = merge_events(evs)
        for arch, strat in PAIRS:
            cfg = SimConfig(
                architecture=arch, strategy=strat, num_pods=num_pods,
                k_spine=k, k_leaf=k, engine="fluid",
                reconfig_delay_s=RECONFIG_DELAY_S, serving_period_s=PERIOD_S,
            )
            sim = Simulator(cfg, jobs, seed=0, fault_events=evs)
            sim.run(until=horizon)
            rows += _fleet_rows(sim, arch, strat, load, "pooled",
                                cfg.serving_slo)
        # router axis: the same trace at the high load, re-run with
        # per-request prefill→decode routing under every policy and the
        # tighter interactive SLO
        if load == ROUTER_LOAD:
            for arch, strat in ROUTER_PAIRS:
                for pol in ROUTER_POLICIES:
                    cfg = SimConfig(
                        architecture=arch, strategy=strat,
                        num_pods=num_pods, k_spine=k, k_leaf=k,
                        engine="fluid", reconfig_delay_s=RECONFIG_DELAY_S,
                        serving_period_s=PERIOD_S, serving_slo=ROUTER_SLO,
                        router=pol,
                    )
                    sim = Simulator(cfg, jobs, seed=0, fault_events=evs)
                    sim.run(until=horizon)
                    rows += _fleet_rows(sim, arch, strat, load, pol,
                                        ROUTER_SLO)

    by: Dict = {}
    for r in rows:
        key = (r["arch"], r["strategy"], r["load"], r["fleet"], r["policy"])
        by[key] = r
    fleets = sorted({r["fleet"] for r in rows})

    def _mean(arch: str, strat: str, pol: str, metric: str) -> float:
        return math.fsum(
            by[(arch, strat, ROUTER_LOAD, f, pol)][metric] for f in fleets
        ) / len(fleets)

    checks = {
        # the CI gate: Cross Wiring's tail never loses to Uniform's, on
        # any load level, for any serving fleet
        "cw_p99_le_uniform_every_level": all(
            by[("cross_wiring", "mdmcf", lv, f, "pooled")]["p99_s"]
            <= by[("uniform", "greedy", lv, f, "pooled")]["p99_s"]
            * (1 + 1e-9) + 1e-12
            for lv in LOAD_LEVELS for f in fleets
        ),
        "cw_goodput_ge_uniform_every_level": all(
            by[("cross_wiring", "mdmcf", lv, f, "pooled")]["goodput"]
            >= by[("uniform", "greedy", lv, f, "pooled")]["goodput"] - 1e-9
            for lv in LOAD_LEVELS for f in fleets
        ),
        "cw_incremental_served": all(
            by[("cross_wiring", "mdmcf", lv, f, "pooled")]["delta_calls"] > 0
            for lv in LOAD_LEVELS for f in fleets
        ),
        # attribution gates: every fleet's blame sums back to its
        # measured slowdown (pooled AND routed rows), and Cross Wiring's
        # dark-window share (pooled over fleets) never exceeds Uniform's
        "blame_conserved": all(
            r["blame_max_residual"] <= 1e-6 for r in rows
        ),
        "cw_dark_share_le_uniform_pooled": (
            _pooled_dark_share(rows, "cross_wiring", "mdmcf")
            <= _pooled_dark_share(rows, "uniform", "greedy") + 1e-9
        ),
        # router-axis gates: topology_aware strictly beats both naive
        # policies on fleet-mean p99 and goodput, on both fabrics, and
        # never loses to round_robin on any single fleet
        "topo_beats_naive_p99": all(
            _mean(a, s, "topology_aware", "p99_s")
            < min(_mean(a, s, "random", "p99_s"),
                  _mean(a, s, "round_robin", "p99_s"))
            for a, s in ROUTER_PAIRS
        ),
        "topo_beats_naive_goodput": all(
            _mean(a, s, "topology_aware", "goodput")
            > max(_mean(a, s, "random", "goodput"),
                  _mean(a, s, "round_robin", "goodput"))
            for a, s in ROUTER_PAIRS
        ),
        "topo_p99_le_rr_per_fleet": all(
            by[(a, s, ROUTER_LOAD, f, "topology_aware")]["p99_s"]
            <= by[(a, s, ROUTER_LOAD, f, "round_robin")]["p99_s"]
            * (1 + 1e-9) + 1e-12
            for a, s in ROUTER_PAIRS for f in fleets
        ),
        # the paper's fabric ordering must survive request routing:
        # CW ≤ Uniform on p99 (and ≥ on goodput) under EVERY policy
        "cw_p99_le_uniform_every_policy": all(
            by[("cross_wiring", "mdmcf", ROUTER_LOAD, f, p)]["p99_s"]
            <= by[("uniform", "greedy", ROUTER_LOAD, f, p)]["p99_s"]
            * (1 + 1e-9) + 1e-12
            for p in ROUTER_POLICIES for f in fleets
        ),
        "cw_goodput_ge_uniform_every_policy": all(
            by[("cross_wiring", "mdmcf", ROUTER_LOAD, f, p)]["goodput"]
            >= by[("uniform", "greedy", ROUTER_LOAD, f, p)]["goodput"] - 1e-9
            for p in ROUTER_POLICIES for f in fleets
        ),
    }
    payload = {"rows": rows, "checks": checks}
    save("serving", payload)
    return payload


def main() -> None:
    payload = run()
    for r in payload["rows"]:
        top = sorted(
            ((c, r[f"blame_{c}_s"]) for c in CAUSES),
            key=lambda kv: -kv[1],
        )[:2]
        blame = ",".join(f"{c}={v:.2f}s" for c, v in top if v > 0)
        routing = (
            f",hit_rate={r['routing_hit_rate']:.3f},"
            f"sheds={r['routing_sheds']:.0f}"
            if "routing_hit_rate" in r else ""
        )
        print(
            f"serving,{r['arch']}/{r['strategy']},load={r['load']},"
            f"policy={r['policy']},{r['fleet']},"
            f"p50={r['p50_s']*1e3:.2f}ms,p99={r['p99_s']*1e3:.2f}ms,"
            f"goodput={r['goodput']:.4f},"
            f"dark={r['downtime_circuit_s']:.1f}cs,"
            f"delta={r['delta_calls']:.0f}/{r['reconfigs']:.0f},"
            f"dark_share={r['dark_share']:.3f}"
            + routing
            + (f",blame[{blame}]" if blame else "")
        )
    print(f"checks: {payload['checks']}")
    if not all(payload["checks"].values()):
        raise SystemExit("serving benchmark invariant violated")


if __name__ == "__main__":
    main()
