"""Data-plane sanity perf (ours): CPU wall time of reduced-config train and
decode steps per architecture family — catches pathological regressions in
the model substrate; real performance numbers come from the dry-run
roofline (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models import ARCHS, get_api, make_smoke_batch, smoke_config
from repro.train.optimizer import OptConfig
from repro.train.trainstep import TrainHparams, make_train_state, make_train_step

from .common import save

QUICK_ARCHS = ("olmo-1b", "deepseek-v3-671b", "rwkv6-1.6b", "whisper-small")


def run(quick: bool = True) -> dict:
    archs = QUICK_ARCHS if quick else sorted(ARCHS)
    B, S, iters = 4, 64, 5
    rows = []
    mesh = make_host_mesh()
    for arch in archs:
        cfg = smoke_config(arch)
        api = get_api(cfg)
        rng = np.random.default_rng(0)
        batch = make_smoke_batch(cfg, rng=rng, batch=B, seq=S)
        sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
        step, *_ = make_train_step(
            api, cfg, OptConfig(), mesh, TrainHparams(), sds
        )
        state = make_train_state(api, jax.random.PRNGKey(0))
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        state, m = step(state, jb)  # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = step(state, jb)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / iters
        # decode step
        cache = api.init_cache(B, S + 8)
        _, cache = jax.jit(api.prefill)(state["params"], jb, cache)
        dec = jax.jit(api.decode)
        tok = jnp.zeros((B, 1), jnp.int32)
        out, cache = dec(state["params"], tok, cache)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out, cache = dec(state["params"], tok, cache)
        jax.block_until_ready(out)
        ddt = (time.perf_counter() - t0) / iters
        rows.append(
            {
                "arch": arch,
                "train_ms": dt * 1e3,
                "train_tok_s": B * S / dt,
                "decode_ms": ddt * 1e3,
                "decode_tok_s": B / ddt,
            }
        )
    payload = {"rows": rows}
    save("step", payload)
    return payload


def main():
    for r in run(quick=False)["rows"]:
        print(
            f"step,{r['arch']},train_ms={r['train_ms']:.1f},"
            f"train_tok_s={r['train_tok_s']:.0f},decode_ms={r['decode_ms']:.1f}"
        )


if __name__ == "__main__":
    main()
