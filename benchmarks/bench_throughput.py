"""Paper Fig. 2a / 4a — testbed throughput reproduction (static scenario +
48h trace).

Static scenario (§5): 3 pods, 96 GPUs, TP=8 PP=2 DP=6 (EP=2 for
PanguAlpha/GPT2).  The DP ring over 3 pods is a *triangle at full degree* —
the Fig. 1 counterexample.  Uniform cannot realize it (chromatic index
3Δ/2 > K_spine), so two flows contend on one link; Cross Wiring realizes it
exactly.  Step time = compute + comm/φ, with per-model testbed comm
fractions α calibrated the way the paper calibrates its simulator ζ
("based on the results of our testbed experiments" — here: to the paper's
own reported deltas, since this container has no 128-NPU testbed).

The same αs then drive the 48h-trace run (Fig 4a) as a consistency check:
the resulting average/maximum job-time reduction emerges from the model
rather than being fitted.
"""
from __future__ import annotations

import numpy as np

from repro.core.reconfig import mdmcf_reconfigure, uniform_exact_small
from repro.core.topology import ClusterSpec
from repro.sim import SimConfig, Simulator, generate_trace, summarize

from .common import save

# testbed comm fractions on 100G RoCE (heavier than the 1.6T sim fabric);
# EP=2 models (pangu/gpt2) carry extra all-to-all in the DP domain
TESTBED_ALPHA = {
    "llama-7b": 0.22,
    "llama2-7b": 0.22,
    "llama2-13b": 0.28,
    "pangu-alpha-6b": 0.40,
    "gpt2-13b": 0.36,
}


def static_scenario() -> dict:
    """3-pod triangle at full degree on the 128-NPU testbed geometry."""
    spec = ClusterSpec(num_pods=4, k_spine=4, k_leaf=4, tau=1)  # 16/pod... geometry
    # demand: full-degree triangle over pods {0,1,2}: 2 links per pair/group
    H = spec.num_ocs_groups
    C = np.zeros((H, 4, 4), dtype=np.int64)
    for i in range(3):
        for j in range(3):
            if i != j:
                C[:, i, j] = spec.k_spine // 2
    itv = mdmcf_reconfigure(spec, C)
    uni = uniform_exact_small(spec, C)
    phi_itv = 1.0
    # Uniform: unrealized pair demand reroutes over the 2-hop detour, adding
    # transit load on the realized links — the paper's "2 flows contention".
    realized = uni.config.realized_bidirectional().sum(axis=0)
    demand = C.sum(axis=0)
    pairs = [(0, 1), (1, 2), (0, 2)]
    load = {e: float(min(demand[e], realized[e])) for e in pairs}
    for i, j in pairs:
        deficit = max(0.0, float(demand[i, j] - realized[i, j]))
        if deficit:
            k = ({0, 1, 2} - {i, j}).pop()  # detour pod
            for e in ((min(i, k), max(i, k)), (min(j, k), max(j, k))):
                load[e] += deficit
    fracs = [
        realized[e] / load[e] for e in pairs if demand[e] > 0 and load[e] > 0
    ]
    phi_uni = float(np.clip(min(fracs), 0.05, 1.0))

    rows = []
    for model, alpha in TESTBED_ALPHA.items():
        t_itv = 1.0 + alpha * (1.0 / phi_itv - 1.0)
        t_uni = 1.0 + alpha * (1.0 / phi_uni - 1.0)
        rows.append(
            {
                "model": model,
                "phi_uniform": phi_uni,
                "throughput_gain_pct": (t_uni / t_itv - 1.0) * 100,
            }
        )
    return {"ltrr_uniform_exact": uni.ltrr, "rows": rows}


def trace_48h(quick: bool = True) -> dict:
    """Fig 4a: 50-job 48h trace on the 128-NPU 4-pod testbed."""
    from repro.sim.trace import COMM_FRACTION

    saved = dict(COMM_FRACTION)
    COMM_FRACTION.update(TESTBED_ALPHA)  # testbed fabric calibration
    try:
        jobs = generate_trace(
            50 if quick else 50, num_gpus=128, workload_level=0.72, seed=7,
            max_job_gpus=128,
        )
        out = {}
        for arch, strat in (
            ("best", "none"),  # stands in for the paper's leaf-spine optimum
            ("cross_wiring", "mdmcf"),
            ("uniform", "greedy"),
        ):
            sim = Simulator(
                SimConfig(
                    architecture=arch, strategy=strat,
                    num_pods=4, k_spine=4, k_leaf=8,  # 4 pods × 32 GPUs
                ),
                jobs,
            )
            recs = sim.run()
            out[f"{arch}/{strat}"] = {
                **summarize(recs),
                "jrt_list": [r.jrt for r in recs],
            }
        cw = np.array(out["cross_wiring/mdmcf"]["jrt_list"])
        un = np.array(out["uniform/greedy"]["jrt_list"])
        ls = np.array(out["best/none"]["jrt_list"])
        return {
            "avg_jrt_reduction_vs_uniform_pct": float((1 - cw.mean() / un.mean()) * 100),
            "max_jrt_reduction_vs_uniform_pct": float(np.max(1 - cw / un) * 100),
            "gap_to_leafspine_pct": float((cw.mean() / ls.mean() - 1) * 100),
        }
    finally:
        COMM_FRACTION.clear()
        COMM_FRACTION.update(saved)


def run(quick: bool = True) -> dict:
    payload = {
        "static": static_scenario(),
        "trace_48h": trace_48h(quick),
        "paper_claim": {
            "static_gain_up_to_pct": 39.5,
            "trace_avg_reduction_pct": 3.9,
            "trace_max_reduction_pct": 28.3,
            "gap_to_leafspine_within_pct": 1.0,
        },
    }
    save("throughput", payload)
    return payload


def main():
    p = run(quick=False)
    for r in p["static"]["rows"]:
        print(
            f"throughput,static,{r['model']},phi_uni={r['phi_uniform']:.3f},"
            f"gain={r['throughput_gain_pct']:.1f}%"
        )
    t = p["trace_48h"]
    print(
        f"throughput,48h,avg_red={t['avg_jrt_reduction_vs_uniform_pct']:.1f}%,"
        f"max_red={t['max_jrt_reduction_vs_uniform_pct']:.1f}%,"
        f"leafspine_gap={t['gap_to_leafspine_pct']:.2f}%"
    )


if __name__ == "__main__":
    main()
