"""Perf-smoke gate: fail CI when control-plane throughput regresses >N×.

    python benchmarks/check_regression.py \
        artifacts/bench/control_plane.json \
        benchmarks/baselines/control_plane.json --max-regression 3

Rows are matched by (pods, k_spine).  The *failing* gate is the
machine-independent incremental-vs-cold speedup ratio: it must stay
above baseline/N (floor 1.5×), which catches a lost delta path or an
accidentally re-quadratic hot loop on any runner class.  Absolute
incremental events/sec below baseline/N is reported as a warning only —
CI runners are not the machine the baseline was recorded on, so an
absolute floor would flake on hardware differences alone.

Artifacts are accepted in either format: a legacy raw payload or the
uniform ``repro-bench/1`` block (``BENCH_*.json``) every benchmark now
emits — both carry the ``rows`` list.

A second, self-contained gate for the observability substrate:

    python benchmarks/check_regression.py --tracing-overhead \
        artifacts/bench/BENCH_fluid.json --min-ratio 0.95

reads the fluid benchmark's traced-vs-untraced events/sec ratio and
fails when attaching the tracer costs more than (1 − min-ratio) of
engine throughput — the no-op-when-disabled discipline is a measured
property, not a comment.

A fourth gate for the self-healing loop:

    python benchmarks/check_regression.py --chaos \
        artifacts/bench/BENCH_chaos.json

re-derives the closed-loop invariants from the chaos sweep's rows (not
the payload's ``checks``): for every scenario present on Cross Wiring,
remediated time-based SLO availability must be ≥ passive (the engine
never makes things worse), and every cell's blame decomposition must
conserve within ``--tol`` — remediation actions (cordons, drains,
pre-emptive checkpoints, solver escalations) spend seconds, and each
one has to be attributed, not leaked into the residual.

A third gate for the blame-attribution engine:

    python benchmarks/check_regression.py --attribution \
        artifacts/bench/BENCH_serving.json

re-derives two invariants from the serving sweep's rows (it does not
trust the payload's own ``checks``): every fleet's blame decomposition
conserves — attributed seconds reconstruct the measured slowdown within
``--tol`` (default 1e-6), routed rows included — and Cross Wiring's
dark-window blame share, pooled over the non-routed rows of every load
level, is ≤ Uniform's (per-level shares are printed for inspection but
a single level's ordering is sampling noise: a few dark seconds against
hours of ideal service).  A conservation break means the attribution
replay no longer matches what the scheduler integrated; a dark-share
inversion means the headline p99 win is no longer coming from the
mechanism the paper claims (fewer, cheaper reconfigurations).

A sixth gate for the scenario suite (``repro.scenario``):

    python benchmarks/check_regression.py --scenarios \
        artifacts/bench/BENCH_scenarios.json --step-bench BENCH_step.json

re-derives the suite invariants from the block's rows — every scenario
summary byte-deterministic, blame conservation ≤ ``--tol``, and (full
runs) every canonical summary matching its committed golden under
``tests/golden/scenarios/`` — and pins the recorded per-architecture
calibration constants against the current ``BENCH_step.json`` within
``--cal-tol`` relative (default 0.25): re-benching step times on new
hardware without regenerating the scenario goldens fails the gate.

A fifth gate for the request router (``repro.serve.router``):

    python benchmarks/check_regression.py --routing \
        artifacts/bench/BENCH_serving.json

re-derives the router-axis invariants from the policy rows: on every
routed fabric, ``topology_aware`` p99 must stay ≤ ``round_robin`` per
fleet, beat both naive policies strictly on fleet-mean p99 and SLO
goodput, and the CW-≤-Uniform p99 / CW-≥-Uniform goodput ordering must
hold on every policy row — routing must never invert the paper's
fabric comparison.
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def _load(path: str) -> dict:
    """Accept both a legacy payload and a repro-bench/1 block."""
    with open(path) as f:
        return json.load(f)


def _metrics(doc: dict) -> dict:
    """Flat scalar metrics from either artifact format."""
    if doc.get("schema") == "repro-bench/1":
        return doc["metrics"]

    def flat(v, prefix=""):
        out = {}
        if isinstance(v, dict):
            for k in v:
                out.update(flat(v[k], f"{prefix}{k}."))
        elif isinstance(v, (int, float, str, bool)) or v is None:
            out[prefix[:-1]] = v
        return out

    return flat(doc)


def check_tracing_overhead(path: str, min_ratio: float) -> int:
    m = _metrics(_load(path))
    ratio = m.get("tracing.throughput_ratio")
    if ratio is None:
        print(f"check_regression,tracing: no tracing.throughput_ratio in {path}",
              file=sys.stderr)
        return 1
    traced = m.get("throughput_traced.events_per_sec", float("nan"))
    plain = m.get("throughput.events_per_sec", float("nan"))
    print(
        f"check_regression,tracing,ratio={ratio:.3f}"
        f"(floor {min_ratio:g}),traced={traced:.0f}eps,untraced={plain:.0f}eps"
    )
    if ratio < min_ratio:
        print(
            f"TRACING OVERHEAD: traced/untraced events/sec {ratio:.3f} "
            f"< {min_ratio:g} — tracer hooks are on the hot path",
            file=sys.stderr,
        )
        return 1
    print("check_regression,tracing,ok")
    return 0


def check_attribution(path: str, tol: float) -> int:
    doc = _load(path)
    rows = doc.get("rows", [])
    if not rows:
        print(f"check_regression,attribution: no rows in {path}",
              file=sys.stderr)
        return 1
    failures = []

    worst = max(r.get("blame_max_residual", float("inf")) for r in rows)
    if not worst <= tol:
        failures.append(
            f"blame conservation broken: max residual {worst:.3e} > {tol:g}"
        )
    print(f"check_regression,attribution,max_residual={worst:.3e}(tol {tol:g})")

    def dark_share(arch, strat, load=None):
        # dark blame as a share of total ideal service time: the request
        # stream is identical across fabrics at one load level, so the
        # denominators match and the comparison is apples-to-apples.
        # Router-axis rows are excluded — they re-run one load under
        # policy variations and would double-count its dark seconds.
        sel = [r for r in rows
               if (r["arch"], r["strategy"]) == (arch, strat)
               and (load is None or r["load"] == load)
               and r.get("policy", "pooled") == "pooled"]
        ideal = math.fsum(r["ideal_total_s"] for r in sel)
        return math.fsum(r["dark_s"] for r in sel) / ideal if ideal > 0 else 0.0

    for load in sorted({r["load"] for r in rows}):
        cw = dark_share("cross_wiring", "mdmcf", load)
        un = dark_share("uniform", "greedy", load)
        print(
            f"check_regression,attribution,load={load},"
            f"dark_share_cw={cw:.4f},dark_share_uniform={un:.4f}"
        )
    cw = dark_share("cross_wiring", "mdmcf")
    un = dark_share("uniform", "greedy")
    print(
        f"check_regression,attribution,pooled,"
        f"dark_share_cw={cw:.6f},dark_share_uniform={un:.6f}"
    )
    if cw > un + 1e-9:
        failures.append(
            f"Cross Wiring pooled dark-window share {cw:.6f} "
            f"> Uniform {un:.6f}"
        )
    if failures:
        print("ATTRIBUTION REGRESSION:", *failures, sep="\n  ",
              file=sys.stderr)
        return 1
    print("check_regression,attribution,ok")
    return 0


def check_routing(path: str) -> int:
    doc = _load(path)
    rows = [r for r in doc.get("rows", [])
            if r.get("policy", "pooled") != "pooled"]
    if not rows:
        print(f"check_regression,routing: no policy rows in {path}",
              file=sys.stderr)
        return 1
    failures = []
    by = {}
    for r in rows:
        by[(r["arch"], r["strategy"], r["fleet"], r["policy"])] = r
    pairs = sorted({(r["arch"], r["strategy"]) for r in rows})
    fleets = sorted({r["fleet"] for r in rows})
    policies = sorted({r["policy"] for r in rows})

    def mean(arch, strat, pol, metric):
        return math.fsum(
            by[(arch, strat, f, pol)][metric] for f in fleets
        ) / len(fleets)

    for arch, strat in pairs:
        topo_p99 = mean(arch, strat, "topology_aware", "p99_s")
        topo_gp = mean(arch, strat, "topology_aware", "goodput")
        print(
            f"check_regression,routing,{arch}/{strat},"
            f"topo_p99={topo_p99*1e3:.2f}ms,topo_goodput={topo_gp:.4f}"
        )
        for naive in ("random", "round_robin"):
            n_p99 = mean(arch, strat, naive, "p99_s")
            n_gp = mean(arch, strat, naive, "goodput")
            if not topo_p99 < n_p99:
                failures.append(
                    f"{arch}/{strat}: topology_aware mean p99 "
                    f"{topo_p99*1e3:.2f}ms not < {naive} {n_p99*1e3:.2f}ms"
                )
            if not topo_gp > n_gp:
                failures.append(
                    f"{arch}/{strat}: topology_aware mean goodput "
                    f"{topo_gp:.4f} not > {naive} {n_gp:.4f}"
                )
        for f in fleets:
            tp = by[(arch, strat, f, "topology_aware")]["p99_s"]
            rr = by[(arch, strat, f, "round_robin")]["p99_s"]
            if tp > rr * (1 + 1e-9) + 1e-12:
                failures.append(
                    f"{arch}/{strat}/{f}: topology_aware p99 "
                    f"{tp*1e3:.2f}ms > round_robin {rr*1e3:.2f}ms"
                )
    # routing must not invert the paper's fabric ordering: CW ≤ Uniform
    # on p99 (≥ on goodput) for every policy on every fleet
    cw_pair = ("cross_wiring", "mdmcf")
    un_pair = ("uniform", "greedy")
    if cw_pair in pairs and un_pair in pairs:
        for pol in policies:
            for f in fleets:
                cw = by[(*cw_pair, f, pol)]
                un = by[(*un_pair, f, pol)]
                if cw["p99_s"] > un["p99_s"] * (1 + 1e-9) + 1e-12:
                    failures.append(
                        f"policy={pol}/{f}: CW p99 {cw['p99_s']*1e3:.2f}ms "
                        f"> Uniform {un['p99_s']*1e3:.2f}ms"
                    )
                if cw["goodput"] < un["goodput"] - 1e-9:
                    failures.append(
                        f"policy={pol}/{f}: CW goodput {cw['goodput']:.4f} "
                        f"< Uniform {un['goodput']:.4f}"
                    )
    if failures:
        print("ROUTING REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print("check_regression,routing,ok")
    return 0


def check_chaos(path: str, tol: float) -> int:
    doc = _load(path)
    rows = doc.get("rows", [])
    if not rows:
        print(f"check_regression,chaos: no rows in {path}", file=sys.stderr)
        return 1
    failures = []

    worst = max(r.get("blame_max_residual", float("inf")) for r in rows)
    if not worst <= tol:
        failures.append(
            f"blame conservation broken: max residual {worst:.3e} > {tol:g}"
        )
    print(f"check_regression,chaos,max_residual={worst:.3e}(tol {tol:g})")

    cells = {(r["scenario"], r["arch"], r["mode"]): r for r in rows}
    scenarios = sorted({r["scenario"] for r in rows})
    for sc in scenarios:
        p = cells.get((sc, "cross_wiring", "passive"))
        r = cells.get((sc, "cross_wiring", "remediate"))
        if p is None or r is None:
            failures.append(f"{sc}: missing passive/remediate cross_wiring cell")
            continue
        print(
            f"check_regression,chaos,{sc},"
            f"avail_passive={p['availability']:.4f},"
            f"avail_remediate={r['availability']:.4f},"
            f"goodput_passive={p['goodput']:.4f},"
            f"goodput_remediate={r['goodput']:.4f}"
        )
        if r["availability"] < p["availability"] - 1e-9:
            failures.append(
                f"{sc}: remediated availability {r['availability']:.4f} "
                f"< passive {p['availability']:.4f} — the engine made "
                f"things worse"
            )
    if failures:
        print("CHAOS REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print("check_regression,chaos,ok")
    return 0


def _calibration_rows(doc: dict) -> list:
    """The scenarios block's calibration table, from either format: the
    raw payload carries a ``calibration`` list; the repro-bench/1 block
    flattens it into ``metrics`` as ``calibration.<i>.<field>``."""
    if doc.get("calibration"):
        return doc["calibration"]
    rows = {}
    for k, v in _metrics(doc).items():
        parts = k.split(".")
        if len(parts) == 3 and parts[0] == "calibration":
            rows.setdefault(int(parts[1]), {})[parts[2]] = v
    return [rows[i] for i in sorted(rows)]


def check_scenarios(path: str, step_path: str, tol: float,
                    cal_tol: float) -> int:
    """Scenario-suite gate: golden/determinism/conservation invariants
    from the rows, plus calibration drift — the per-arch step constants
    recorded in the scenarios block must match the current
    ``BENCH_step.json`` within ``cal_tol`` relative.  A re-bench that
    moves step times without regenerated scenario goldens fails here."""
    doc = _load(path)
    rows = doc.get("rows", [])
    if not rows:
        print(f"check_regression,scenarios: no rows in {path}",
              file=sys.stderr)
        return 1
    failures = []

    worst = max(r.get("blame_max_residual", float("inf")) for r in rows)
    if not worst <= tol:
        failures.append(
            f"blame conservation broken: max residual {worst:.3e} > {tol:g}"
        )
    for r in rows:
        sc = r.get("scenario", "?")
        print(
            f"check_regression,scenarios,{sc},"
            f"goodput={r.get('goodput', float('nan')):.4f},"
            f"dark_circuit_s={r.get('dark_circuit_s', float('nan')):.2f},"
            f"deterministic={r.get('deterministic')},"
            f"golden_match={r.get('golden_match', 'n/a')}"
        )
        if not r.get("deterministic", False):
            failures.append(f"{sc}: summary not byte-deterministic")
        if r.get("golden_match") is False:
            failures.append(
                f"{sc}: summary drifted from tests/golden/scenarios/"
                f"{sc}.json — regenerate with "
                "`PYTHONPATH=src python -m tests.golden.regen`"
            )

    calib = {c["arch"]: c for c in _calibration_rows(doc)}
    if not calib:
        failures.append("no calibration table in scenarios block")
    try:
        step_rows = {r["arch"]: r for r in _load(step_path)["rows"]}
    except (OSError, KeyError) as e:
        step_rows = {}
        failures.append(f"cannot read step constants from {step_path}: {e}")
    for arch, c in sorted(calib.items()):
        s = step_rows.get(arch)
        if s is None:
            failures.append(f"{arch}: calibrated but absent from {step_path}")
            continue
        rec, cur = c["measured_step_ms"], s["train_ms"]
        drift = abs(rec - cur) / max(abs(cur), 1e-12)
        print(
            f"check_regression,scenarios,calib,{arch},"
            f"recorded_ms={rec:.3f},step_bench_ms={cur:.3f},"
            f"drift={drift:.3e}(tol {cal_tol:g})"
        )
        if drift > cal_tol:
            failures.append(
                f"{arch}: calibration drift {drift:.3e} > {cal_tol:g} "
                f"(recorded {rec:.3f} ms vs BENCH_step {cur:.3f} ms) — "
                "rerun the scenarios bench and regenerate goldens"
            )
    if failures:
        print("SCENARIO REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print("check_regression,scenarios,ok")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline", nargs="?")
    ap.add_argument("--max-regression", type=float, default=3.0)
    ap.add_argument("--tracing-overhead", action="store_true")
    ap.add_argument("--min-ratio", type=float, default=0.95)
    ap.add_argument("--attribution", action="store_true")
    ap.add_argument("--routing", action="store_true")
    ap.add_argument("--chaos", action="store_true")
    ap.add_argument("--scenarios", action="store_true")
    ap.add_argument(
        "--step-bench", default="BENCH_step.json",
        help="step-constant block the calibration drift is pinned against",
    )
    ap.add_argument("--cal-tol", type=float, default=0.25)
    ap.add_argument("--tol", type=float, default=1e-6)
    args = ap.parse_args()

    if args.tracing_overhead:
        return check_tracing_overhead(args.current, args.min_ratio)
    if args.attribution:
        return check_attribution(args.current, args.tol)
    if args.routing:
        return check_routing(args.current)
    if args.chaos:
        return check_chaos(args.current, args.tol)
    if args.scenarios:
        return check_scenarios(
            args.current, args.step_bench, args.tol, args.cal_tol
        )
    if args.baseline is None:
        ap.error("baseline is required unless --tracing-overhead")

    cur = {(r["pods"], r["k_spine"]): r for r in _load(args.current)["rows"]}
    base = {(r["pods"], r["k_spine"]): r for r in _load(args.baseline)["rows"]}

    failures = []
    for key, b in base.items():
        c = cur.get(key)
        if c is None:
            failures.append(f"{key}: row missing from current run")
            continue
        floor_eps = b["incremental_events_per_sec"] / args.max_regression
        if c["incremental_events_per_sec"] < floor_eps:
            print(
                f"check_regression,warn,{key}: incremental "
                f"{c['incremental_events_per_sec']:.0f} eps < {floor_eps:.0f} "
                f"(baseline/{args.max_regression:g}; hardware-dependent, not fatal)",
                file=sys.stderr,
            )
        floor_speedup = max(1.5, b["speedup"] / args.max_regression)
        if c["speedup"] < floor_speedup:
            failures.append(
                f"{key}: speedup {c['speedup']:.2f}x < {floor_speedup:.2f}x "
                f"(baseline {b['speedup']:.2f}x / {args.max_regression:g})"
            )
        print(
            f"check_regression,{key},eps={c['incremental_events_per_sec']:.0f}"
            f"(warn floor {floor_eps:.0f}),speedup={c['speedup']:.2f}x"
            f"(fail floor {floor_speedup:.2f}x)"
        )
    if failures:
        print("PERF REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print("check_regression,ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
