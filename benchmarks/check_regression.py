"""Perf-smoke gate: fail CI when control-plane throughput regresses >N×.

    python benchmarks/check_regression.py \
        artifacts/bench/control_plane.json \
        benchmarks/baselines/control_plane.json --max-regression 3

Rows are matched by (pods, k_spine).  The *failing* gate is the
machine-independent incremental-vs-cold speedup ratio: it must stay
above baseline/N (floor 1.5×), which catches a lost delta path or an
accidentally re-quadratic hot loop on any runner class.  Absolute
incremental events/sec below baseline/N is reported as a warning only —
CI runners are not the machine the baseline was recorded on, so an
absolute floor would flake on hardware differences alone.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--max-regression", type=float, default=3.0)
    args = ap.parse_args()

    with open(args.current) as f:
        cur = {(r["pods"], r["k_spine"]): r for r in json.load(f)["rows"]}
    with open(args.baseline) as f:
        base = {(r["pods"], r["k_spine"]): r for r in json.load(f)["rows"]}

    failures = []
    for key, b in base.items():
        c = cur.get(key)
        if c is None:
            failures.append(f"{key}: row missing from current run")
            continue
        floor_eps = b["incremental_events_per_sec"] / args.max_regression
        if c["incremental_events_per_sec"] < floor_eps:
            print(
                f"check_regression,warn,{key}: incremental "
                f"{c['incremental_events_per_sec']:.0f} eps < {floor_eps:.0f} "
                f"(baseline/{args.max_regression:g}; hardware-dependent, not fatal)",
                file=sys.stderr,
            )
        floor_speedup = max(1.5, b["speedup"] / args.max_regression)
        if c["speedup"] < floor_speedup:
            failures.append(
                f"{key}: speedup {c['speedup']:.2f}x < {floor_speedup:.2f}x "
                f"(baseline {b['speedup']:.2f}x / {args.max_regression:g})"
            )
        print(
            f"check_regression,{key},eps={c['incremental_events_per_sec']:.0f}"
            f"(warn floor {floor_eps:.0f}),speedup={c['speedup']:.2f}x"
            f"(fail floor {floor_speedup:.2f}x)"
        )
    if failures:
        print("PERF REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print("check_regression,ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
