"""Perf-smoke gate: fail CI when control-plane throughput regresses >N×.

    python benchmarks/check_regression.py \
        artifacts/bench/control_plane.json \
        benchmarks/baselines/control_plane.json --max-regression 3

Rows are matched by (pods, k_spine).  The *failing* gate is the
machine-independent incremental-vs-cold speedup ratio: it must stay
above baseline/N (floor 1.5×), which catches a lost delta path or an
accidentally re-quadratic hot loop on any runner class.  Absolute
incremental events/sec below baseline/N is reported as a warning only —
CI runners are not the machine the baseline was recorded on, so an
absolute floor would flake on hardware differences alone.

Artifacts are accepted in either format: a legacy raw payload or the
uniform ``repro-bench/1`` block (``BENCH_*.json``) every benchmark now
emits — both carry the ``rows`` list.

A second, self-contained gate for the observability substrate:

    python benchmarks/check_regression.py --tracing-overhead \
        artifacts/bench/BENCH_fluid.json --min-ratio 0.95

reads the fluid benchmark's traced-vs-untraced events/sec ratio and
fails when attaching the tracer costs more than (1 − min-ratio) of
engine throughput — the no-op-when-disabled discipline is a measured
property, not a comment.

A fourth gate for the self-healing loop:

    python benchmarks/check_regression.py --chaos \
        artifacts/bench/BENCH_chaos.json

re-derives the closed-loop invariants from the chaos sweep's rows (not
the payload's ``checks``): for every scenario present on Cross Wiring,
remediated time-based SLO availability must be ≥ passive (the engine
never makes things worse), and every cell's blame decomposition must
conserve within ``--tol`` — remediation actions (cordons, drains,
pre-emptive checkpoints, solver escalations) spend seconds, and each
one has to be attributed, not leaked into the residual.

A third gate for the blame-attribution engine:

    python benchmarks/check_regression.py --attribution \
        artifacts/bench/BENCH_serving.json

re-derives two invariants from the serving sweep's rows (it does not
trust the payload's own ``checks``): every fleet's blame decomposition
conserves — attributed seconds reconstruct the measured slowdown within
``--tol`` (default 1e-6) — and Cross Wiring's pooled dark-window blame
share is ≤ Uniform's at every load level.  A conservation break means
the attribution replay no longer matches what the scheduler integrated;
a dark-share inversion means the headline p99 win is no longer coming
from the mechanism the paper claims (fewer, cheaper reconfigurations).
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def _load(path: str) -> dict:
    """Accept both a legacy payload and a repro-bench/1 block."""
    with open(path) as f:
        return json.load(f)


def _metrics(doc: dict) -> dict:
    """Flat scalar metrics from either artifact format."""
    if doc.get("schema") == "repro-bench/1":
        return doc["metrics"]

    def flat(v, prefix=""):
        out = {}
        if isinstance(v, dict):
            for k in v:
                out.update(flat(v[k], f"{prefix}{k}."))
        elif isinstance(v, (int, float, str, bool)) or v is None:
            out[prefix[:-1]] = v
        return out

    return flat(doc)


def check_tracing_overhead(path: str, min_ratio: float) -> int:
    m = _metrics(_load(path))
    ratio = m.get("tracing.throughput_ratio")
    if ratio is None:
        print(f"check_regression,tracing: no tracing.throughput_ratio in {path}",
              file=sys.stderr)
        return 1
    traced = m.get("throughput_traced.events_per_sec", float("nan"))
    plain = m.get("throughput.events_per_sec", float("nan"))
    print(
        f"check_regression,tracing,ratio={ratio:.3f}"
        f"(floor {min_ratio:g}),traced={traced:.0f}eps,untraced={plain:.0f}eps"
    )
    if ratio < min_ratio:
        print(
            f"TRACING OVERHEAD: traced/untraced events/sec {ratio:.3f} "
            f"< {min_ratio:g} — tracer hooks are on the hot path",
            file=sys.stderr,
        )
        return 1
    print("check_regression,tracing,ok")
    return 0


def check_attribution(path: str, tol: float) -> int:
    doc = _load(path)
    rows = doc.get("rows", [])
    if not rows:
        print(f"check_regression,attribution: no rows in {path}",
              file=sys.stderr)
        return 1
    failures = []

    worst = max(r.get("blame_max_residual", float("inf")) for r in rows)
    if not worst <= tol:
        failures.append(
            f"blame conservation broken: max residual {worst:.3e} > {tol:g}"
        )
    print(f"check_regression,attribution,max_residual={worst:.3e}(tol {tol:g})")

    def dark_share(arch, strat, load):
        # dark blame as a share of total ideal service time: the request
        # stream is identical across fabrics at one load level, so the
        # denominators match and the comparison is apples-to-apples
        sel = [r for r in rows
               if (r["arch"], r["strategy"], r["load"]) == (arch, strat, load)]
        ideal = math.fsum(r["ideal_total_s"] for r in sel)
        return math.fsum(r["dark_s"] for r in sel) / ideal if ideal > 0 else 0.0

    for load in sorted({r["load"] for r in rows}):
        cw = dark_share("cross_wiring", "mdmcf", load)
        un = dark_share("uniform", "greedy", load)
        print(
            f"check_regression,attribution,load={load},"
            f"dark_share_cw={cw:.4f},dark_share_uniform={un:.4f}"
        )
        if cw > un + 1e-9:
            failures.append(
                f"load={load}: Cross Wiring dark-window share {cw:.4f} "
                f"> Uniform {un:.4f}"
            )
    if failures:
        print("ATTRIBUTION REGRESSION:", *failures, sep="\n  ",
              file=sys.stderr)
        return 1
    print("check_regression,attribution,ok")
    return 0


def check_chaos(path: str, tol: float) -> int:
    doc = _load(path)
    rows = doc.get("rows", [])
    if not rows:
        print(f"check_regression,chaos: no rows in {path}", file=sys.stderr)
        return 1
    failures = []

    worst = max(r.get("blame_max_residual", float("inf")) for r in rows)
    if not worst <= tol:
        failures.append(
            f"blame conservation broken: max residual {worst:.3e} > {tol:g}"
        )
    print(f"check_regression,chaos,max_residual={worst:.3e}(tol {tol:g})")

    cells = {(r["scenario"], r["arch"], r["mode"]): r for r in rows}
    scenarios = sorted({r["scenario"] for r in rows})
    for sc in scenarios:
        p = cells.get((sc, "cross_wiring", "passive"))
        r = cells.get((sc, "cross_wiring", "remediate"))
        if p is None or r is None:
            failures.append(f"{sc}: missing passive/remediate cross_wiring cell")
            continue
        print(
            f"check_regression,chaos,{sc},"
            f"avail_passive={p['availability']:.4f},"
            f"avail_remediate={r['availability']:.4f},"
            f"goodput_passive={p['goodput']:.4f},"
            f"goodput_remediate={r['goodput']:.4f}"
        )
        if r["availability"] < p["availability"] - 1e-9:
            failures.append(
                f"{sc}: remediated availability {r['availability']:.4f} "
                f"< passive {p['availability']:.4f} — the engine made "
                f"things worse"
            )
    if failures:
        print("CHAOS REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print("check_regression,chaos,ok")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline", nargs="?")
    ap.add_argument("--max-regression", type=float, default=3.0)
    ap.add_argument("--tracing-overhead", action="store_true")
    ap.add_argument("--min-ratio", type=float, default=0.95)
    ap.add_argument("--attribution", action="store_true")
    ap.add_argument("--chaos", action="store_true")
    ap.add_argument("--tol", type=float, default=1e-6)
    args = ap.parse_args()

    if args.tracing_overhead:
        return check_tracing_overhead(args.current, args.min_ratio)
    if args.attribution:
        return check_attribution(args.current, args.tol)
    if args.chaos:
        return check_chaos(args.current, args.tol)
    if args.baseline is None:
        ap.error("baseline is required unless --tracing-overhead")

    cur = {(r["pods"], r["k_spine"]): r for r in _load(args.current)["rows"]}
    base = {(r["pods"], r["k_spine"]): r for r in _load(args.baseline)["rows"]}

    failures = []
    for key, b in base.items():
        c = cur.get(key)
        if c is None:
            failures.append(f"{key}: row missing from current run")
            continue
        floor_eps = b["incremental_events_per_sec"] / args.max_regression
        if c["incremental_events_per_sec"] < floor_eps:
            print(
                f"check_regression,warn,{key}: incremental "
                f"{c['incremental_events_per_sec']:.0f} eps < {floor_eps:.0f} "
                f"(baseline/{args.max_regression:g}; hardware-dependent, not fatal)",
                file=sys.stderr,
            )
        floor_speedup = max(1.5, b["speedup"] / args.max_regression)
        if c["speedup"] < floor_speedup:
            failures.append(
                f"{key}: speedup {c['speedup']:.2f}x < {floor_speedup:.2f}x "
                f"(baseline {b['speedup']:.2f}x / {args.max_regression:g})"
            )
        print(
            f"check_regression,{key},eps={c['incremental_events_per_sec']:.0f}"
            f"(warn floor {floor_eps:.0f}),speedup={c['speedup']:.2f}x"
            f"(fail floor {floor_speedup:.2f}x)"
        )
    if failures:
        print("PERF REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print("check_regression,ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
