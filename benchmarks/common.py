"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "artifacts", "bench")


def save(name: str, payload: Dict[str, Any]) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    # every benchmark also exports the uniform repro-bench/1 block
    # (flattened scalar metrics + checks + rows) next to its legacy
    # artifact, so gates and dashboards need one parser
    from repro.obs.report import write_bench_block

    write_bench_block(name, payload, ART_DIR)
    return path


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0
