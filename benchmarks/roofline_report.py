"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from
artifacts/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16 << 30  # v5e

ARCH_ORDER = [
    "deepseek-v3-671b", "grok-1-314b", "internvl2-1b", "gemma-2b",
    "qwen2.5-14b", "gemma2-9b", "olmo-1b", "jamba-1.5-large-398b",
    "rwkv6-1.6b", "whisper-small",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, opt: bool = False):
    recs = {}
    for path in glob.glob("artifacts/dryrun/*.json"):
        r = json.load(open(path))
        if r.get("mesh") != mesh or r.get("optimized", False) != opt:
            continue
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.1f}"
    return f"{x:.3f}"


def dominant_frac(r):
    """Roofline fraction: useful model compute time / dominant term."""
    if not r.get("ok"):
        return None
    per_chip_model_s = (r["model_flops"] / _chips(r)) / 197e12
    dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return per_chip_model_s / dom if dom else None


def _chips(r):
    n = 1
    for s in r["mesh"].split("x"):
        n *= int(s)
    return n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--optimized", action="store_true")
    args = ap.parse_args()
    recs = load(args.mesh, args.optimized)

    print(f"### Roofline — mesh {args.mesh}"
          + (" (optimized)" if args.optimized else " (baseline)"))
    print()
    print("| arch | shape | compute_s | memory_s | coll_s | bottleneck | "
          "HBM GiB/chip | fits | useful | roofline-frac | policy |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if not r.get("ok"):
                print(f"| {arch} | {shape} | FAIL | | | | | | | | "
                      f"{r.get('error','')[:60]} |")
                continue
            mem = r.get("per_device_bytes", 0)
            fits = "yes" if mem <= HBM_PER_CHIP else f"NO ({mem/2**30:.0f}G)"
            frac = dominant_frac(r)
            pol = r.get("policy", {})
            pol_s = f"fsdp={'Y' if pol.get('fsdp') else 'n'},ga={pol.get('grad_accum',1)}"
            print(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"{r['bottleneck']} | {mem/2**30:.1f} | {fits} | "
                f"{r['useful_ratio']:.2f} | "
                f"{frac:.3f} | {pol_s} |"
            )
    print()


if __name__ == "__main__":
    main()
