"""Benchmark driver — one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # quick (CI) pass
  PYTHONPATH=src python -m benchmarks.run --full     # recorded numbers
  PYTHONPATH=src python -m benchmarks.run --only ltrr jct

Each benchmark prints ``name,…`` CSV lines and writes
``artifacts/bench/<name>.json`` (plus the uniform ``repro-bench/1``
block next to it).  The driver additionally mirrors every block to the
repo root as ``BENCH_<name>.json`` — the committed baseline set CI
gates diff against.
"""
from __future__ import annotations

import argparse
import os
import time

from repro.obs.report import write_bench_block

from . import (
    bench_availability,
    bench_chaos,
    bench_collectives,
    bench_control_plane,
    bench_fluid,
    bench_jct,
    bench_ltrr,
    bench_mrar,
    bench_reconfig_interval,
    bench_reconfig_time,
    bench_scenarios,
    bench_serving,
    bench_step,
    bench_throughput,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BENCHES = {
    "collectives": (
        bench_collectives,
        "ours: planner-driven collective completion",
    ),
    "ltrr": (bench_ltrr, "Fig 2b/5: logical topology realization rate"),
    "reconfig_time": (bench_reconfig_time, "Fig 2c/6: reconfiguration runtime"),
    "mrar": (bench_mrar, "Fig 7: min-rewiring achievement rate"),
    "jct": (bench_jct, "Fig 8a-d: multi-tenant JRT/JWT/JCT"),
    "throughput": (bench_throughput, "Fig 2a/4a: testbed throughput"),
    "reconfig_interval": (bench_reconfig_interval, "Table 1: reconfig frequency"),
    "step": (bench_step, "ours: per-arch step sanity perf"),
    "availability": (
        bench_availability,
        "ours: goodput under failures + live expansion",
    ),
    "chaos": (
        bench_chaos,
        "ours: self-healing vs passive under correlated/gray chaos",
    ),
    "control_plane": (
        bench_control_plane,
        "ours: simulator events/sec, incremental vs cold",
    ),
    "fluid": (
        bench_fluid,
        "ours: fluid engine events/sec, fidelity gap, downtime pricing",
    ),
    "serving": (
        bench_serving,
        "ours: serving p99 KV-transfer latency + goodput per fabric",
    ),
    "scenarios": (
        bench_scenarios,
        "ours: multi-day scenario suite, goldens + calibration drift",
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    names = args.only if args.only else list(BENCHES)
    for name in names:
        mod, desc = BENCHES[name]
        t0 = time.perf_counter()
        print(f"== {name}: {desc} " + "=" * max(1, 46 - len(name) - len(desc)))
        payload = mod.run(quick=not args.full)
        write_bench_block(name, payload, REPO_ROOT)
        _summarize(name, payload)
        print(f"-- {name} done in {time.perf_counter() - t0:.1f}s\n", flush=True)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)


def _summarize_generic(name: str, payload: dict) -> None:
    """Fallback key=value printer for benches without a bespoke formatter
    (otherwise new benches silently print nothing)."""
    rows = payload.get("rows")
    if isinstance(rows, list) and rows:
        for r in rows:
            if isinstance(r, dict):
                print(f"{name}," + ",".join(f"{k}={_fmt(v)}" for k, v in r.items()))
    else:
        scalars = {
            k: v for k, v in payload.items()
            if isinstance(v, (int, float, str, bool))
        }
        if scalars:
            print(f"{name}," + ",".join(f"{k}={_fmt(v)}" for k, v in scalars.items()))
    if isinstance(payload.get("checks"), dict):
        print(f"{name},checks," + ",".join(
            f"{k}={v}" for k, v in payload["checks"].items()
        ))


def _summarize(name: str, payload: dict) -> None:
    if name == "ltrr":
        for r in payload["rows"]:
            print(
                f"ltrr,{r['nodes']},{r['strategy']},avg={r['ltrr_avg']:.4f},"
                f"min={r['ltrr_min']:.4f}"
            )
    elif name == "reconfig_time":
        for r in payload["rows"]:
            keys = [k for k in r if k != "nodes"]
            print(
                f"reconfig_time,{r['nodes']},"
                + ",".join(
                    f"{k}={r[k]:.2f}x" if "speedup" in k else f"{k}={r[k]:.4f}s"
                    for k in keys
                )
            )
    elif name == "control_plane":
        for r in payload["rows"]:
            print(
                f"control_plane,{r['nodes']},"
                f"cold={r['cold_events_per_sec']:.0f}eps,"
                f"incremental={r['incremental_events_per_sec']:.0f}eps,"
                f"speedup={r['speedup']:.2f}x,"
                f"delta_hits={r['delta_hits']}/{r['reconfigs']}"
            )
    elif name == "mrar":
        for r in payload["rows"]:
            print(
                f"mrar,{r['nodes']},warm=1.0,"
                f"mcf={r['MRAR_MCF(decomp-reuse)']:.4f},"
                f"cold={r['MRAR_cold']:.4f},"
                f"uniformILP*={r['MRAR_Uniform-ILP*']:.4f}"
            )
    elif name == "jct":
        for scale, by in payload["results"].items():
            for pair, s in by.items():
                print(
                    f"jct,{scale},{pair},avg_jct={s['avg_jct']:.1f},"
                    f"avg_jwt={s['avg_jwt']:.1f},"
                    f"slow_avg={s['jrt_slow_vs_best_avg']:+.4f},"
                    f"slow_max={s['jrt_slow_vs_best_max']:+.3f},"
                    f"affected={s['pct_affected']:.1f}%"
                )
    elif name == "throughput":
        for r in payload["static"]["rows"]:
            print(
                f"throughput,static,{r['model']},"
                f"gain={r['throughput_gain_pct']:.1f}%"
            )
        t = payload["trace_48h"]
        print(
            f"throughput,48h,avg_red={t['avg_jrt_reduction_vs_uniform_pct']:.1f}%,"
            f"max_red={t['max_jrt_reduction_vs_uniform_pct']:.1f}%,"
            f"leafspine_gap={t['gap_to_leafspine_pct']:+.2f}%"
        )
    elif name == "reconfig_interval":
        for r in payload["rows"]:
            print(
                f"reconfig_interval,{r['objective']},T={r['interval_s']}s,"
                f"ms_per_step={r['avg_ms_per_step']:.1f}"
            )
    elif name == "step":
        for r in payload["rows"]:
            print(
                f"step,{r['arch']},train_ms={r['train_ms']:.1f},"
                f"decode_ms={r['decode_ms']:.1f}"
            )
    elif name == "fluid":
        t = payload["throughput"]
        print(
            f"fluid,events,P={t['num_pods']},events={t['events']},"
            f"eps={t['events_per_sec']:.0f}/s"
        )
        tr = payload["tracing"]
        print(
            f"fluid,tracing,ratio={tr['throughput_ratio']:.3f},"
            f"events={tr['trace_events']},"
            f"cats={','.join(tr['trace_categories'])}"
        )
        for r in payload["rows"]:
            if r["kind"] == "fidelity":
                print(
                    f"fluid,fidelity,delay={r['delay_s']},"
                    f"gap_mean={r['rel_gap_mean']:.2e},"
                    f"downtime_circ_s={r['downtime_circuit_s']:.2f}"
                )
            else:
                print(
                    f"fluid,downtime,{r['mode']},delay={r['delay_s']},"
                    f"circ_s={r['downtime_circuit_s']:.2f},"
                    f"avg_jct={r['avg_jct']:.0f}"
                )
        checks = payload["checks"]
        print(
            "fluid,checks,"
            + ",".join(
                f"{k}={v}" for k, v in checks.items()
                if not isinstance(v, dict)
            )
        )
    elif name == "chaos":
        for r in payload["rows"]:
            print(
                f"chaos,{r['scenario']},{r['arch']},{r['mode']},"
                f"avail={r['availability']:.4f},goodput={r['goodput']:.4f},"
                f"train={r['train_goodput']:.4f},dark_s={r['dark_s']:.0f},"
                f"fallbacks={r['solver_fallbacks']}"
            )
        ck = payload["checks"]
        print(
            "chaos,checks,"
            + ",".join(f"{k}={v}" for k, v in ck.items()
                       if not isinstance(v, dict))
        )
    elif name == "collectives":
        for r in payload["rows"]:
            print(
                f"collectives,{r['arch']},{r['scenario']},"
                f"phi={r['phi']:.3f},"
                f"t_cross={r['cross_collective_s']*1e3:.1f}ms,"
                f"slowdown={r['step_slowdown']:.3f}"
            )
        print(f"collectives,checks,{payload['checks']}")
    else:
        _summarize_generic(name, payload)


if __name__ == "__main__":
    main()
