"""Multi-tenant cluster scheduling with topology engineering (paper §6.3).

Simulates a 4096-GPU cluster serving a 150-job trace under three designs —
Cross Wiring + MDMCF, Uniform + greedy, and the ideal crossbar — and prints
the paper's headline metrics (JRT/JWT/JCT, slowdowns, affected jobs).
A second act replays the same trace through a scripted failure / repair /
expansion scenario (`repro.fault`) under each recovery policy.

Run:  PYTHONPATH=src python examples/multi_tenant_cluster.py
"""
import numpy as np

from repro.fault import ExpandEvent, FailureEvent, RepairEvent
from repro.sim import SimConfig, Simulator, generate_trace, summarize

jobs = generate_trace(150, num_gpus=4096, workload_level=0.9, seed=0)
print(f"trace: {len(jobs)} jobs over {jobs[-1].arrival/3600:.1f} h, "
      f"sizes {min(j.num_gpus for j in jobs)}–{max(j.num_gpus for j in jobs)} GPUs")

results = {}
for arch, strat in [
    ("best", "none"),
    ("cross_wiring", "mdmcf"),
    ("cross_wiring", "itv_ilp"),
    ("uniform", "greedy"),
    ("clos", "none"),
]:
    sim = Simulator(
        SimConfig(architecture=arch, strategy=strat, num_pods=64, k_spine=8, k_leaf=8),
        jobs,
    )
    recs = sim.run()
    s = summarize(recs)
    results[(arch, strat)] = (s, recs)
    affected = 100 * np.mean([r.min_phi < 0.999 for r in recs])
    print(
        f"{arch:13s}/{strat:8s}  avg JRT {s['avg_jrt']:7.1f}s  "
        f"avg JWT {s['avg_jwt']:7.1f}s  avg JCT {s['avg_jct']:7.1f}s  "
        f"affected {affected:4.1f}%"
    )

best = results[("best", "none")][0]["avg_jct"]
cw = results[("cross_wiring", "mdmcf")][0]["avg_jct"]
un = results[("uniform", "greedy")][0]["avg_jct"]
print(f"\nCross Wiring vs Uniform: {100 * (un / cw - 1):.1f}% lower avg JCT")
print(f"Cross Wiring vs ideal:   {100 * (cw / best - 1):.2f}% above the crossbar bound")

# --- act two: the cluster has a bad day (repro.fault) -----------------------
# a transceiver dies, then a whole OCS, then pod 3 goes down for two hours,
# and finally four cold spare pods (60..63 were kept inactive) come online.
t0 = jobs[len(jobs) // 4].arrival
scenario = [
    FailureEvent(t0, "link", h=0, k=2, pod=5),
    FailureEvent(t0 + 1800.0, "ocs", h=1, k=4),
    FailureEvent(t0 + 3600.0, "pod", pod=3),
    RepairEvent(t0 + 3600.0 + 7200.0, "pod", pod=3),
    RepairEvent(t0 + 4 * 3600.0, "ocs", h=1, k=4),
    RepairEvent(t0 + 6 * 3600.0, "link", h=0, k=2, pod=5),
    ExpandEvent(t0 + 8 * 3600.0, pods=(60, 61, 62, 63)),
]
print("\nscripted failure/repair/expansion scenario (Cross Wiring + MDMCF):")
for policy in ("rewire_around", "ckpt_restart", "shrink_collective"):
    sim = Simulator(
        SimConfig(
            architecture="cross_wiring", strategy="mdmcf",
            num_pods=64, k_spine=8, k_leaf=8,
            recovery_policy=policy, active_pods=60,
        ),
        jobs,
        fault_events=scenario,
    )
    recs = sim.run()
    s = summarize(recs)
    fs = sim.fault_summary()
    print(
        f"{policy:17s}  avg JCT {s['avg_jct']:7.1f}s  "
        f"restarts {fs['restarts']:2.0f}  shrinks {fs['shrinks']:2.0f}  "
        f"work lost {fs['lost_gpu_s']:9.0f} GPU·s  "
        f"availability {100 * fs['availability']:.2f}%"
    )
