"""Quickstart: the paper's full workflow in one script.

1. Build a Cross Wiring cluster (deployment stage, §2.1).
2. Submit a training job: place it, derive its logical topology, and run the
   polynomial-time MDMCF reconfiguration (running stage).
3. Show the Fig. 1 counterexample: the same demand is *unrealizable* under
   the Uniform physical topology.
4. Train a reduced model for a few steps on the data plane the control
   plane just provisioned.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ClusterSpec,
    ltrr,
    mdmcf_reconfigure,
    ring_demand,
    uniform_exact_small,
)
from repro.launch.mesh import make_host_mesh
from repro.models import get_api, smoke_config
from repro.train.data import DataConfig, SyntheticData
from repro.train.optimizer import OptConfig
from repro.train.trainstep import TrainHparams, make_train_state, make_train_step

# ---------------------------------------------------------------------------
# 1. deployment stage: a 4-pod cluster, 8 OCS ports per spine
# ---------------------------------------------------------------------------
spec = ClusterSpec(num_pods=4, k_spine=4, k_leaf=4)
print(f"cluster: {spec.num_pods} pods × {spec.gpus_per_pod} GPUs "
      f"({spec.num_ocs_groups} OCS groups × {spec.ocs_per_group} OCSes)")

# ---------------------------------------------------------------------------
# 2. running stage: a job lands on pods {0,1,2}; its DP ring becomes the
#    logical topology; MDMCF realizes it in polynomial time
# ---------------------------------------------------------------------------
demand = ring_demand(spec, [0, 1, 2], links=spec.k_spine // 2)
t0 = time.perf_counter()
res = mdmcf_reconfigure(spec, demand)
print(f"MDMCF: realized {int(demand.sum()) // 2} logical links "
      f"in {(time.perf_counter() - t0) * 1e3:.1f} ms, LTRR={res.ltrr:.3f}")
assert res.ltrr == 1.0  # Thm 4.1

# ---------------------------------------------------------------------------
# 3. the same demand under Uniform wiring (Gemini/Jupiter-Evolving style):
#    a triangle at full degree is UNREALIZABLE (paper Fig. 1)
# ---------------------------------------------------------------------------
uni = uniform_exact_small(spec, demand)
print(f"Uniform (exact optimum): LTRR={uni.ltrr:.3f}  ← bandwidth lost; "
      f"Cross Wiring keeps 1.000")

# ---------------------------------------------------------------------------
# 4. data plane: train a reduced olmo-1b on the provisioned mesh
# ---------------------------------------------------------------------------
cfg = smoke_config("olmo-1b")
api = get_api(cfg)
mesh = make_host_mesh()
data = SyntheticData(DataConfig(vocab_size=cfg.vocab_size, batch=8, seq=32))
b0 = data.batch_at(0)
sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in b0.items()}
step, *_ = make_train_step(
    api, cfg, OptConfig(lr=5e-3, warmup_steps=5), mesh, TrainHparams(), sds
)
state = make_train_state(api, jax.random.PRNGKey(0))
for i in range(20):
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
    state, m = step(state, batch)
    if i % 5 == 0 or i == 19:
        print(f"  step {i:2d}  loss {float(m['loss']):.4f}")
print("quickstart complete ✓")
