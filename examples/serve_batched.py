"""Batched serving example: prefill + greedy KV-cache decode across three
architecture families (dense GQA, attention-free RWKV, encoder-decoder).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.models import get_api, smoke_config
from repro.serve.engine import ServeEngine

for arch in ("gemma-2b", "rwkv6-1.6b", "whisper-small"):
    cfg = smoke_config(arch)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S0, new = 4, 16, 12

    inputs = {"tokens": rng.integers(0, cfg.vocab_size, size=(B, S0)).astype(np.int32)}
    if cfg.family == "audio":
        inputs["frames"] = rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)).astype(
            np.float32
        )
    if cfg.family == "vlm":
        inputs["patches"] = rng.normal(
            size=(B, cfg.vision_tokens, cfg.vision_dim)
        ).astype(np.float32)

    eng = ServeEngine(api, params, batch=B, s_max=S0 + new + 4)
    t0 = time.perf_counter()
    out = eng.generate(inputs, max_new_tokens=new)
    dt = time.perf_counter() - t0
    print(
        f"{arch:14s} generated {out.shape[0]}×{out.shape[1]} tokens "
        f"in {dt:5.2f}s ({B * new / dt:6.1f} tok/s)   first row: {out[0][:8].tolist()}"
    )
