"""Sharded checkpointing with async writes and elastic (re-mesh) restore.

Format: one ``.npz`` per checkpoint step holding every leaf keyed by its
pytree path, plus a JSON manifest (step, shapes, dtypes).  Arrays are saved
in *logical* (unsharded) form, so a checkpoint written on a (2,16,16) mesh
restores onto any other mesh — elastic rescale is just restore-with-new-
shardings.  Writes go to a temp name and rename atomically; an optional
background thread makes them async (fault tolerance: the train loop never
blocks on I/O).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(state: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8, ...)
            # npz cannot serialize extension dtypes; bf16→f32 is lossless
            # and restore casts back to the target leaf dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(
    ckpt_dir: str, step: int, state: Any, *, background: bool = False
) -> Optional[threading.Thread]:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)

    def write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.npz")
        final = os.path.join(ckpt_dir, f"step_{step}.npz")
        np.savez(tmp, **flat)
        os.replace(tmp, final)
        manifest = {
            "step": step,
            "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()},
        }
        mtmp = os.path.join(ckpt_dir, f".tmp_step_{step}.json")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(ckpt_dir, f"step_{step}.json"))

    if background:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for fn in os.listdir(ckpt_dir):
        if fn.startswith("step_") and fn.endswith(".json"):
            try:
                steps.append(int(fn[5:-5]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    state_like: Any,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> Any:
    """Restore into the structure of ``state_like``; if ``shardings`` given,
    device_put each leaf with its target sharding (elastic re-mesh)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"step_{step}.npz"))
    paths = jax.tree_util.tree_flatten_with_path(state_like)[0]
    treedef = jax.tree_util.tree_structure(state_like)
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec")
        )
        if shardings is not None
        else [None] * len(paths)
    )
    leaves = []
    for (path, like), shard in zip(paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {like.shape}")
        arr = arr.astype(like.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None else jax.device_put(arr))
    return treedef.unflatten(leaves)
