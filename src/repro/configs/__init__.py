"""Selectable architecture configs (``--arch <id>``).

One module per assigned architecture, each the canonical definition of the
full-scale :class:`ModelConfig` (exact assignment numbers) plus the
:class:`~repro.configs.common.ParallelismPlan` mapping the arch onto the
paper's cluster (TP/EP in-pod, DP across pods over the OCS core).
"""
from __future__ import annotations

import importlib
from typing import Dict

from .common import ParallelismPlan, job_demand

_MODULES: Dict[str, str] = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "grok-1-314b": "grok_1_314b",
    "internvl2-1b": "internvl2_1b",
    "gemma-2b": "gemma_2b",
    "qwen2.5-14b": "qwen2_5_14b",
    "gemma2-9b": "gemma2_9b",
    "olmo-1b": "olmo_1b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-small": "whisper_small",
}

ARCH_IDS = tuple(_MODULES)


def arch_module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch_id]}", __package__)


def get_config(arch_id: str):
    """The exact full-scale ModelConfig for ``--arch <id>``."""
    return arch_module(arch_id).config()


def get_plan(arch_id: str) -> ParallelismPlan:
    """The arch's cluster parallelism plan (paper §3.1 traffic containment)."""
    return arch_module(arch_id).PLAN


__all__ = [
    "ARCH_IDS",
    "ParallelismPlan",
    "arch_module",
    "get_config",
    "get_plan",
    "job_demand",
]
