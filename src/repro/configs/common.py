"""Shared plumbing for per-architecture config modules.

Each ``configs/<arch>.py`` declares:

* ``ARCH_ID``   — the assignment's architecture id (``--arch`` value).
* ``config()``  — the exact full-scale :class:`~repro.models.config.ModelConfig`
  from the assignment table (public literature).
* ``PLAN``      — a :class:`ParallelismPlan`: how the architecture's traffic
  maps onto the paper's cluster (§3.1): TP/EP confined to the intra-pod
  electrical fabric (mesh axis ``model``), DP/PP across pods over the OCS
  core (mesh axes ``pod``/``data``).  The launcher turns this into the
  logical-topology demand handed to the Cross Wiring control plane.

The full configs are exercised only through the dry-run (ShapeDtypeStruct,
no allocation); CPU smoke tests use ``models.registry.smoke_config``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ParallelismPlan:
    """How one architecture occupies the paper's cluster.

    Attributes
    ----------
    tp:
        tensor-parallel ways — always intra-pod (mesh axis ``model``), the
        paper's §3.1 containment ("each Pod could host ... the TP traffic").
    ep:
        expert-parallel ways — intra-pod; shares the ``model`` axis with TP
        (experts sharded over ``model``; the EP all-to-all stays on the
        electrical fabric).
    dp_cross_pod:
        whether the DP gradient ring crosses pods — this is the traffic the
        OCS core carries and the control plane provisions (ring demand over
        the job's pods).
    seq_shard_long:
        long-context cells (batch=1) shard the sequence/state dim of the
        cache over the DP axes instead of the batch dim.
    ocs_links_per_ring_hop:
        how many parallel spine-level links the launcher requests per
        adjacent pod pair in the job's DP ring (per spine group).
    notes:
        one-line applicability note for DESIGN.md §Arch-applicability.
    """

    tp: int
    ep: int = 1
    dp_cross_pod: bool = True
    seq_shard_long: bool = False
    ocs_links_per_ring_hop: int = 4
    notes: str = ""


def job_demand(plan: ParallelismPlan, spec, pods: Tuple[int, ...]):
    """Logical-topology demand this job asks from the control plane.

    The cross-pod traffic of an LLM job under the paper's containment policy
    is the DP gradient ring over the pods it occupies (PP would add the same
    chain pattern); TP/EP never leave the pod, so they produce no OCS demand.
    """
    from ..core.logical import ring_demand

    if not plan.dp_cross_pod or len(pods) < 2:
        import numpy as np

        return np.zeros(
            (spec.num_ocs_groups, spec.num_pods, spec.num_pods), dtype=np.int64
        )
    return ring_demand(spec, list(pods), plan.ocs_links_per_ring_hop)
