"""deepseek-v3-671b — DeepSeek-V3 [arXiv:2412.19437; hf].

61L, d_model=7168, 128 heads (MLA), MoE 1 shared + 256 routed top-8 with
d_expert=2048, vocab 129280, MTP depth 1.  The assignment's ``d_ff=2048`` is
the *expert* FFN width (HF ``moe_intermediate_size``); the three leading
dense layers use the HF ``intermediate_size`` 18432.

Paper mapping: the heaviest EP all-to-all of the pool — exactly the traffic
the paper sizes pods for (§3.1: "each Pod could host hundreds of GPUs, which
is large enough to accommodate the MoE Parallelism (EP) ... within a Pod").
Most representative cell for §Perf.
"""
from __future__ import annotations

from ..models.config import MLAConfig, ModelConfig, MoEConfig
from .common import ParallelismPlan

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        d_ff=18432,  # dense (first-3) layers; experts use d_expert=2048
        vocab_size=129280,
        head_dim=128,
        attn_kind="mla",
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=256,
            top_k=8,
            d_expert=2048,
            num_shared=1,
            first_dense=3,
            router="sigmoid",
        ),
        tie_embeddings=False,
        mtp_depth=1,
    )


PLAN = ParallelismPlan(
    tp=16,
    ep=16,  # 256 experts / 16 model-axis shards = 16 experts per device
    dp_cross_pod=True,
    ocs_links_per_ring_hop=8,  # largest model → widest DP ring links
    notes=(
        "EP all-to-all confined in-pod on the model axis; DP gradient ring "
        "across pods over the OCS core. The paper's motivating workload."
    ),
)
