"""gemma2-9b — Gemma 2 9B [arXiv:2408.00118; hf].

42L, d_model=3584, 16H (GQA kv=8), head_dim=256, GeGLU d_ff=14336,
vocab 256000.  Alternating local(sliding-4096)/global attention layers,
attention-logit softcap 50, final-logit softcap 30.
"""
from __future__ import annotations

from ..models.config import ModelConfig
from .common import ParallelismPlan

ARCH_ID = "gemma2-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=256000,
        head_dim=256,
        mlp_kind="geglu",
        local_global=True,
        sliding_window=4096,
        attn_softcap=50.0,
        logit_softcap=30.0,
        embed_scale=True,
        tie_embeddings=True,
    )


PLAN = ParallelismPlan(
    tp=16,
    dp_cross_pod=True,
    ocs_links_per_ring_hop=4,
    notes=(
        "Local/global alternation halves attention FLOPs at 32k; long_500k "
        "still skipped — half the layers are full-attention (DESIGN.md §4)."
    ),
)
