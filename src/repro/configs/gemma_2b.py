"""gemma-2b — Gemma 2B [arXiv:2403.08295; hf].

18L, d_model=2048, 8H with MQA (kv=1), head_dim=256, GeGLU d_ff=16384,
vocab 256000.  Gemma scales embeddings by sqrt(d_model) and ties the LM head.
"""
from __future__ import annotations

from ..models.config import ModelConfig
from .common import ParallelismPlan

ARCH_ID = "gemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,  # MQA
        d_ff=16384,
        vocab_size=256000,
        head_dim=256,
        mlp_kind="geglu",
        embed_scale=True,
        tie_embeddings=True,
    )


PLAN = ParallelismPlan(
    tp=8,
    dp_cross_pod=True,
    ocs_links_per_ring_hop=4,
    notes=(
        "MQA (kv=1): the single KV head replicates under TP; q-heads shard. "
        "256k vocab makes the embedding/LM-head the TP hot spot."
    ),
)
