"""grok-1-314b — Grok-1 [hf:xai-org/grok-1; unverified].

64L, d_model=6144, 48H (GQA kv=8), d_ff=32768, vocab 131072,
MoE 8 experts top-2.  Grok-1 softcaps attention logits at 30.
"""
from __future__ import annotations

from ..models.config import ModelConfig, MoEConfig
from .common import ParallelismPlan

ARCH_ID = "grok-1-314b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        head_dim=128,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=32768),
        attn_softcap=30.0,
        tie_embeddings=True,
    )


PLAN = ParallelismPlan(
    tp=16,
    ep=8,  # 8 experts ≤ model-axis width; EP in-pod
    dp_cross_pod=True,
    ocs_links_per_ring_hop=8,
    notes="8-expert top-2 MoE; EP in-pod, wide d_expert makes TP dominant.",
)
