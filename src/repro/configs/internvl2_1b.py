"""internvl2-1b — InternVL2-1B [arXiv:2404.16821; hf].

Backbone: Qwen2-0.5B-style LM — 24L, d_model=896, 14H (GQA kv=2),
d_ff=4864, vocab 151655, QKV bias.  The InternViT vision frontend is a STUB
per the assignment: ``input_specs()`` supplies precomputed patch embeddings
(256 tokens × 1024 dims) projected into the LM.
"""
from __future__ import annotations

from ..models.config import ModelConfig
from .common import ParallelismPlan

ARCH_ID = "internvl2-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        head_dim=64,
        qkv_bias=True,  # Qwen2 backbone
        vision_tokens=256,
        vision_dim=1024,
        tie_embeddings=True,
    )


PLAN = ParallelismPlan(
    tp=2,  # tiny model: 14 heads, d_model=896 → little TP headroom
    dp_cross_pod=True,
    ocs_links_per_ring_hop=2,
    notes=(
        "Small VLM; DP-dominant. 14 q-heads do not divide the model axis — "
        "sharding degrades those dims to replicated (divisibility guard)."
    ),
)
