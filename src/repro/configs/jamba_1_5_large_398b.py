"""jamba-1.5-large-398b — Jamba-1.5 Large [arXiv:2403.19887; hf].

72L, d_model=8192, 64H (GQA kv=8), d_ff=24576, vocab 65536.
Hybrid Mamba+attention at 1:7 interleave (1 attn per 8-layer block),
MoE 16 experts top-2 on every other layer.
"""
from __future__ import annotations

from ..models.config import MambaConfig, ModelConfig, MoEConfig
from .common import ParallelismPlan

ARCH_ID = "jamba-1.5-large-398b"


def _pattern():
    return ("attn",) + ("mamba",) * 7


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        head_dim=128,
        block_pattern=_pattern(),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576, every=2),
        tie_embeddings=True,
    )


PLAN = ParallelismPlan(
    tp=16,
    ep=16,
    dp_cross_pod=True,
    seq_shard_long=True,  # SSM state is O(1)/token → long_500k native
    ocs_links_per_ring_hop=8,
    notes=(
        "Hybrid: Mamba layers have O(1) state → long_500k runs; attention "
        "layers (1:7) keep a 500k KV cache sharded over the data axis."
    ),
)
