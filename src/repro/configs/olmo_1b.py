"""olmo-1b — OLMo 1B [arXiv:2402.00838; hf].

16L, d_model=2048, 16H (MHA, kv=16), d_ff=8192, vocab 50304.
OLMo uses non-parametric LayerNorm (no scale/bias) and SwiGLU.
"""
from __future__ import annotations

from ..models.config import ModelConfig
from .common import ParallelismPlan

ARCH_ID = "olmo-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        norm_kind="nonparametric",
        tie_embeddings=True,
    )


PLAN = ParallelismPlan(
    tp=8,
    dp_cross_pod=True,
    ocs_links_per_ring_hop=2,
    notes="Smallest dense LM; DP-dominant, used as the fast CI cell.",
)
