"""qwen2.5-14b — Qwen2.5-14B [hf:Qwen/Qwen2.5-14B; hf].

48L, d_model=5120, 40H (GQA kv=8), d_ff=13824, vocab 152064, QKV bias,
untied embeddings.
"""
from __future__ import annotations

from ..models.config import ModelConfig
from .common import ParallelismPlan

ARCH_ID = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=152064,
        head_dim=128,
        qkv_bias=True,
        tie_embeddings=False,
    )


PLAN = ParallelismPlan(
    tp=8,
    dp_cross_pod=True,
    ocs_links_per_ring_hop=4,
    notes="Standard dense GQA; TP in-pod, DP ring across pods.",
)
