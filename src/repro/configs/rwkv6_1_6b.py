"""rwkv6-1.6b — RWKV-6 "Finch" 1.6B [arXiv:2404.05892; unverified].

24L, d_model=2048, attention-free (WKV linear recurrence with
data-dependent decay), channel-mix d_ff=7168, vocab 65536, head_dim=64
(32 WKV heads).
"""
from __future__ import annotations

from ..models.config import ModelConfig, RWKVConfig
from .common import ParallelismPlan

ARCH_ID = "rwkv6-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,  # d_model / rwkv.head_dim
        num_kv_heads=32,
        d_ff=7168,
        vocab_size=65536,
        attn_kind="none",
        block_pattern=("rwkv",),
        rwkv=RWKVConfig(head_dim=64),
        norm_kind="layernorm",
        tie_embeddings=False,
    )


PLAN = ParallelismPlan(
    tp=8,
    dp_cross_pod=True,
    seq_shard_long=True,  # O(1) recurrent state → long_500k native
    ocs_links_per_ring_hop=2,
    notes=(
        "Attention-free: the paper's EP/TP-in-pod reasoning has no attention "
        "traffic to confine, but the control plane is agnostic — it only "
        "sees the DP link demand. Technique fully applicable (DESIGN.md §4)."
    ),
)
