"""whisper-small — Whisper small [arXiv:2212.04356; unverified].

Encoder-decoder, 12L each, d_model=768, 12H (MHA), d_ff=3072, vocab 51865.
The conv mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (1500 × d_model).  Decoder positions
are learned; the table is sized for the decode_32k dry-run cell.
"""
from __future__ import annotations

from ..models.config import ModelConfig
from .common import ParallelismPlan

ARCH_ID = "whisper-small"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        head_dim=64,
        mlp_kind="gelu",
        norm_kind="layernorm",
        use_rope=False,
        is_encoder_decoder=True,
        encoder_layers=12,
        encoder_seq=1500,
        max_target_positions=32768,  # sized for the decode_32k dry-run cell
        tie_embeddings=True,
    )


PLAN = ParallelismPlan(
    tp=4,
    dp_cross_pod=True,
    ocs_links_per_ring_hop=1,
    notes=(
        "Enc-dec: decode = decoder self-attn + cross-attn over the cached "
        "encoder output; 12 heads limit TP to 4 (divisibility guard)."
    ),
)
