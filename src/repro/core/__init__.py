"""Cross Wiring control plane: physical topology, decomposition theorems,
OCS reconfiguration, logical-topology demands (the paper's contribution)."""
from .topology import ClusterSpec, CrossWiring, OCSConfig, Uniform, demand_feasible
from .decomposition import (
    edge_color_bipartite,
    halve_matrix,
    integer_matrix_decompose,
    symmetric_split,
    symmetric_split_euler,
    symmetric_split_mcf,
)
from .incremental import (
    ColoringState,
    DeltaInfeasible,
    StaleStateError,
    mdmcf_delta,
)
from .reconfig import (
    ReconfigResult,
    check_ilp_constraints,
    config_cosine,
    helios_matching,
    ltrr,
    mdmcf_cold,
    mdmcf_reconfigure,
    uniform_best_effort,
    uniform_exact_small,
    uniform_greedy,
)
from .logical import Job, Placement, jobs_to_demand, random_feasible_demand, ring_demand

__all__ = [
    "ClusterSpec",
    "CrossWiring",
    "OCSConfig",
    "Uniform",
    "demand_feasible",
    "edge_color_bipartite",
    "halve_matrix",
    "integer_matrix_decompose",
    "symmetric_split",
    "symmetric_split_euler",
    "symmetric_split_mcf",
    "ColoringState",
    "DeltaInfeasible",
    "StaleStateError",
    "mdmcf_delta",
    "ReconfigResult",
    "check_ilp_constraints",
    "config_cosine",
    "helios_matching",
    "ltrr",
    "mdmcf_cold",
    "mdmcf_reconfigure",
    "uniform_best_effort",
    "uniform_exact_small",
    "uniform_greedy",
    "Job",
    "Placement",
    "jobs_to_demand",
    "random_feasible_demand",
    "ring_demand",
]
