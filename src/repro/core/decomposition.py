"""Matrix decomposition theorems underlying Cross Wiring (paper §3.4).

Theorem 3.1 (Symmetric Integer Matrix Decomposition): any symmetric integer
matrix ``C`` decomposes as ``C = A + Aᵀ`` with every row/col sum of ``A``
within ``⌊Σ/2⌋ .. ⌈Σ/2⌉`` of half the corresponding sum of ``C``.

Theorem 3.2 (Integer Matrix Decomposition, from Minimal Rewiring): any
integer matrix ``C`` splits into ``K`` integer matrices whose entries and
row/col sums are all within floor/ceil of ``1/K``-th of the originals.

The paper proves both via min-cost-flow (MCF) feasibility.  We implement the
MCF constructions (networkx) as *oracles* and two classical combinatorial
fast paths that are exact and near-linear:

* Thm 3.1 ≡ *balanced orientation* of the multigraph with adjacency ``C`` —
  Eulerian-circuit orientation with a dummy vertex absorbing odd degrees.
* the sub-permutation case of Thm 3.2 (the one MDMCF needs) ≡ *bipartite
  edge coloring* with ``Δ`` colors (König), via alternating-path recoloring —
  and it accepts a warm start, which is how MDMCF serves the Min-Rewiring
  objective (paper eq. 7).

All code is plain numpy + python — cluster control plane, not data plane.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "symmetric_split",
    "symmetric_split_euler",
    "symmetric_split_mcf",
    "edge_color_bipartite",
    "halve_matrix",
    "integer_matrix_decompose",
    "check_symmetric_split",
    "check_edge_coloring",
]


# --------------------------------------------------------------------------
# Theorem 3.1 — fast path: Eulerian balanced orientation
# --------------------------------------------------------------------------

def _euler_orient(num_vertices: int, edges: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Orient ``edges`` (undirected multigraph) so |out(v) - in(v)| <= 1.

    Classical construction: join all odd-degree vertices to a dummy vertex,
    walk Euler circuits (Hierholzer) orienting along the walk, drop dummy
    edges.  O(E).
    """
    deg = [0] * num_vertices
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    dummy = num_vertices
    all_edges = list(edges)
    for v in range(num_vertices):
        if deg[v] % 2:
            all_edges.append((dummy, v))

    # adjacency: vertex -> list of (edge_id, other_endpoint)
    adj: List[List[Tuple[int, int]]] = [[] for _ in range(num_vertices + 1)]
    for eid, (u, v) in enumerate(all_edges):
        adj[u].append((eid, v))
        adj[v].append((eid, u))
    used = [False] * len(all_edges)
    ptr = [0] * (num_vertices + 1)  # per-vertex scan pointer (amortized O(E))
    oriented: List[Tuple[int, int]] = []

    for start in range(num_vertices + 1):
        if ptr[start] >= len(adj[start]):
            continue
        # Hierholzer, iterative.  Record traversal direction of each edge.
        stack = [start]
        path_edges: List[Tuple[int, int]] = []  # (edge_id, tail_vertex)
        edge_stack: List[Tuple[int, int]] = []
        while stack:
            v = stack[-1]
            advanced = False
            while ptr[v] < len(adj[v]):
                eid, w = adj[v][ptr[v]]
                ptr[v] += 1
                if used[eid]:
                    continue
                used[eid] = True
                stack.append(w)
                edge_stack.append((eid, v))  # traversed v -> w
                advanced = True
                break
            if not advanced:
                stack.pop()
                if edge_stack:
                    path_edges.append(edge_stack.pop())
        for eid, tail in path_edges:
            u, v = all_edges[eid]
            head = v if tail == u else u
            if tail != dummy and head != dummy:
                oriented.append((tail, head))
    return oriented


def symmetric_split_euler(C: np.ndarray) -> np.ndarray:
    """Thm 3.1 via Eulerian orientation.  Returns integer A with C = A + Aᵀ
    and balanced row/col sums.  Diagonal entries of C must be even."""
    C = np.asarray(C)
    if (C != C.T).any():
        raise ValueError("C must be symmetric")
    if (C < 0).any():
        raise ValueError("C must be non-negative")
    d = np.diagonal(C)
    if (d % 2).any():
        raise ValueError("diagonal entries of C must be even (C_ii = 2*A_ii)")
    P = C.shape[0]
    A = np.zeros_like(C)
    np.fill_diagonal(A, d // 2)
    # Pre-assign paired off-diagonal links symmetrically (a 2-cycle i->j->i is
    # already balanced); only the odd remainder needs orientation.
    off = C.copy()
    np.fill_diagonal(off, 0)
    half = off // 2
    A += half  # adds C_ij//2 in both directions
    rem = off - 2 * half  # 0/1 symmetric, zero diagonal
    iu, ju = np.nonzero(np.triu(rem, k=1))
    edges = list(zip(iu.tolist(), ju.tolist()))
    for u, v in _euler_orient(P, edges):
        A[u, v] += 1
    return A


# --------------------------------------------------------------------------
# Theorem 3.1 — oracle: the paper's MCF construction (networkx)
# --------------------------------------------------------------------------

def symmetric_split_mcf(C: np.ndarray) -> np.ndarray:
    """Thm 3.1 via the paper's min-cost-flow proof construction (DecomOPT).

    Used as a reference oracle in tests; the Euler path above is the
    production implementation.
    """
    import networkx as nx

    C = np.asarray(C)
    if (C != C.T).any():
        raise ValueError("C must be symmetric")
    d = np.diagonal(C)
    if (d % 2).any():
        raise ValueError("diagonal entries of C must be even")
    P = C.shape[0]
    A = np.zeros_like(C)
    np.fill_diagonal(A, d // 2)
    off = C.copy()
    np.fill_diagonal(off, 0)

    G = nx.DiGraph()
    demand: Dict[object, int] = {}
    rowsum = off.sum(axis=1)

    def add_demand(node, amt):
        demand[node] = demand.get(node, 0) + int(amt)

    total = 0
    for i in range(P):
        for j in range(i + 1, P):
            cij = int(off[i, j])
            if cij == 0:
                continue
            s = ("s", i, j)
            add_demand(s, -cij)  # supply
            total += cij
            G.add_edge(s, ("r", i), capacity=cij, weight=0)
            G.add_edge(s, ("r", j), capacity=cij, weight=0)
    t = "t"
    add_demand(t, total)
    # r_i -> t with bounds [floor(rowsum/2), ceil(rowsum/2)]
    for i in range(P):
        lo = int(rowsum[i]) // 2
        hi = -(-int(rowsum[i]) // 2)
        # lower-bound transformation: capacity hi-lo, shift demands by lo
        G.add_edge(("r", i), t, capacity=hi - lo, weight=0)
        add_demand(("r", i), lo)
        add_demand(t, -lo)
    for node, dem in demand.items():
        if node not in G:
            G.add_node(node)
        G.nodes[node]["demand"] = dem
    flow = nx.min_cost_flow(G)
    for i in range(P):
        for j in range(i + 1, P):
            if off[i, j] == 0:
                continue
            s = ("s", i, j)
            A[i, j] += flow[s].get(("r", i), 0)
            A[j, i] += flow[s].get(("r", j), 0)
    return A


def symmetric_split(C: np.ndarray, method: str = "euler") -> np.ndarray:
    if method == "euler":
        return symmetric_split_euler(C)
    if method == "mcf":
        return symmetric_split_mcf(C)
    raise ValueError(f"unknown method {method!r}")


def check_symmetric_split(C: np.ndarray, A: np.ndarray) -> None:
    """Assert the Thm 3.1 guarantees."""
    C = np.asarray(C)
    A = np.asarray(A)
    assert (A >= 0).all(), "A must be non-negative"
    assert (A + A.T == C).all(), "C != A + A^T"
    rs_c, cs_c = C.sum(axis=1), C.sum(axis=0)
    rs_a, cs_a = A.sum(axis=1), A.sum(axis=0)
    assert (rs_a >= rs_c // 2).all() and (rs_a <= -(-rs_c // 2)).all(), "row bound"
    assert (cs_a >= cs_c // 2).all() and (cs_a <= -(-cs_c // 2)).all(), "col bound"


# --------------------------------------------------------------------------
# Theorem 3.2 specialization — bipartite edge coloring (König)
# --------------------------------------------------------------------------

def edge_color_bipartite(
    A: np.ndarray,
    num_colors: int,
    warm: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Decompose non-negative integer matrix ``A`` (row & col sums ≤
    ``num_colors``) into ``num_colors`` sub-permutation 0/1 matrices.

    Returns ``colors`` of shape ``(num_colors, P, Q)`` with
    ``colors.sum(0) == A`` and each slice having row/col sums ≤ 1.

    ``warm`` (optional, same shape as the output) seeds the coloring with a
    previous configuration: any unit of demand that the old configuration
    already carried keeps its color when still free — this implements the
    Min-Rewiring objective (paper eq. 7) inside the decomposition.

    Algorithm: classical alternating-path bipartite edge coloring
    (König / Vizing restricted to bipartite), O(E · (P + num_colors)).
    """
    A = np.asarray(A)
    if (A < 0).any():
        raise ValueError("A must be non-negative")
    P, Q = A.shape
    K = num_colors
    if (A.sum(axis=1) > K).any() or (A.sum(axis=0) > K).any():
        raise ValueError("row/col sums must be <= num_colors")

    # rowc[i, c] = matched column (or -1); colc[j, c] = matched row (or -1)
    rowc = np.full((P, K), -1, dtype=np.int64)
    colc = np.full((Q, K), -1, dtype=np.int64)
    remaining = A.astype(np.int64).copy()

    def assign(i: int, j: int, c: int) -> None:
        rowc[i, c] = j
        colc[j, c] = i

    # ---- warm start ------------------------------------------------------
    if warm is not None:
        warm = np.asarray(warm)
        if warm.shape != (K, P, Q):
            raise ValueError("warm must have shape (num_colors, P, Q)")
        cs, is_, js = np.nonzero(warm)
        for c, i, j in zip(cs.tolist(), is_.tolist(), js.tolist()):
            if remaining[i, j] > 0 and rowc[i, c] == -1 and colc[j, c] == -1:
                assign(i, j, c)
                remaining[i, j] -= 1

    # ---- main loop ---------------------------------------------------------
    iu, ju = np.nonzero(remaining)
    for i, j in zip(iu.tolist(), ju.tolist()):
        for _ in range(int(remaining[i, j])):
            # free colors
            a = -1  # free at row i
            b = -1  # free at col j
            common = -1
            for c in range(K):
                fi = rowc[i, c] == -1
                fj = colc[j, c] == -1
                if fi and fj:
                    common = c
                    break
                if fi and a == -1:
                    a = c
                if fj and b == -1:
                    b = c
            if common >= 0:
                assign(i, j, common)
                continue
            assert a >= 0 and b >= 0, "degree bound violated"
            # Invert the (a, b)-alternating path starting at column j (which
            # is missing color a).  The path cannot reach row i (parity
            # argument), so after inversion color a is free at both endpoints.
            # Phase 1: collect alternating path edges starting at col j.
            path: List[Tuple[int, int, int]] = []  # (row, col, color)
            cur_color = a
            cur_node = j
            at_col = True
            while True:
                if at_col:
                    r = colc[cur_node, cur_color]
                    if r == -1:
                        break
                    path.append((r, cur_node, cur_color))
                    cur_node, at_col = r, False
                    cur_color = b if cur_color == a else a
                else:
                    cc = rowc[cur_node, cur_color]
                    if cc == -1:
                        break
                    path.append((cur_node, cc, cur_color))
                    cur_node, at_col = cc, True
                    cur_color = b if cur_color == a else a
            # Phase 2: flip colors along the path.
            for (r, cc, col_) in path:
                rowc[r, col_] = -1
                colc[cc, col_] = -1
            for (r, cc, col_) in path:
                other = b if col_ == a else a
                rowc[r, other] = cc
                colc[cc, other] = r
            assert rowc[i, a] == -1 and colc[j, a] == -1
            assign(i, j, a)

    colors = np.zeros((K, P, Q), dtype=np.int8)
    for c in range(K):
        rows = np.nonzero(rowc[:, c] >= 0)[0]
        colors[c, rows, rowc[rows, c]] = 1
    return colors


def check_edge_coloring(A: np.ndarray, colors: np.ndarray) -> None:
    assert (colors.sum(axis=0) == A).all(), "colors do not sum to A"
    assert (colors.sum(axis=2) <= 1).all(), "row sum > 1 in a color class"
    assert (colors.sum(axis=1) <= 1).all(), "col sum > 1 in a color class"


# --------------------------------------------------------------------------
# Theorem 3.2 — general K-way decomposition
# --------------------------------------------------------------------------

def halve_matrix(C: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Split integer matrix C into C1 + C2 with entries and row/col sums each
    within floor/ceil of half — via Eulerian orientation of the bipartite
    multigraph (rows ∪ cols) of the odd remainder."""
    C = np.asarray(C)
    P, Q = C.shape
    base = C // 2
    rem = C - 2 * base  # 0/1
    iu, ju = np.nonzero(rem)
    edges = [(int(i), int(P + j)) for i, j in zip(iu, ju)]
    C1 = base.copy()
    C2 = base.copy()
    for u, v in _euler_orient(P + Q, edges):
        if u < P:  # row -> col  ⇒ give the odd unit to C1
            C1[u, v - P] += 1
        else:  # col -> row       ⇒ give it to C2
            C2[v, u - P] += 1
    return C1, C2


def integer_matrix_decompose(C: np.ndarray, K: int) -> List[np.ndarray]:
    """Thm 3.2: split C into K matrices with per-entry and row/col-sum
    balance w.r.t. the *original* C (floor/ceil of 1/K shares).

    Power-of-two K uses recursive Euler halving (near-linear); other K peel
    one balanced slice at a time (each peel preserves the bounds — see
    tests/test_decomposition.py for the property check).
    """
    C = np.asarray(C).astype(np.int64)
    if K <= 0:
        raise ValueError("K must be positive")
    if K == 1:
        return [C.copy()]
    if K % 2 == 0:
        C1, C2 = halve_matrix(C)
        return integer_matrix_decompose(C1, K // 2) + integer_matrix_decompose(
            C2, K // 2
        )
    # odd K: peel one slice with entries in [⌊C/K⌋, ⌈C/K⌉] and balanced
    # row/col sums, then recurse with K-1.  The peel is itself computed by
    # repeated halving: slice = C - decompose(C, K)[1:] would be circular, so
    # use a direct proportional split via sorting of fractional parts
    # (a transportation-rounding argument).
    slice_ = _peel_balanced_slice(C, K)
    rest = C - slice_
    return [slice_] + integer_matrix_decompose_bounded(rest, K - 1, C, K)


def integer_matrix_decompose_bounded(
    C: np.ndarray, K: int, orig: np.ndarray, orig_k: int
) -> List[np.ndarray]:
    """Recurse like :func:`integer_matrix_decompose` — bounds relative to the
    *current* remainder stay within the original floor/ceil window (standard
    floor/ceil arithmetic, property-tested)."""
    if K == 1:
        return [C.copy()]
    if K % 2 == 0:
        C1, C2 = halve_matrix(C)
        return integer_matrix_decompose_bounded(
            C1, K // 2, orig, orig_k
        ) + integer_matrix_decompose_bounded(C2, K // 2, orig, orig_k)
    slice_ = _peel_balanced_slice(C, K)
    return [slice_] + integer_matrix_decompose_bounded(C - slice_, K - 1, orig, orig_k)


def _peel_balanced_slice(C: np.ndarray, K: int) -> np.ndarray:
    """Extract S with S_ij ∈ [⌊C_ij/K⌋, ⌈C_ij/K⌉], row/col sums within
    floor/ceil of 1/K of C's — via min-cost-flow feasibility (networkx),
    mirroring the paper's proof of Thm 3.2."""
    import networkx as nx

    C = np.asarray(C)
    P, Q = C.shape
    G = nx.DiGraph()
    demand: Dict[object, int] = {}

    def add_demand(node, amt):
        demand[node] = demand.get(node, 0) + int(amt)

    rs, cs = C.sum(axis=1), C.sum(axis=0)
    s, t = "s", "t"

    def bounded_edge(u, v, lo, hi):
        G.add_edge(u, v, capacity=int(hi - lo), weight=0)
        add_demand(u, lo)
        add_demand(v, -lo)

    for i in range(P):
        bounded_edge(s, ("r", i), int(rs[i]) // K, -(-int(rs[i]) // K))
    for j in range(Q):
        bounded_edge(("c", j), t, int(cs[j]) // K, -(-int(cs[j]) // K))
    for i in range(P):
        for j in range(Q):
            lo, hi = int(C[i, j]) // K, -(-int(C[i, j]) // K)
            if hi == 0:
                continue
            bounded_edge(("r", i), ("c", j), lo, hi)
    # close the circulation t -> s
    total_lo = sum(int(rs[i]) // K for i in range(P))
    total_hi = sum(-(-int(rs[i]) // K) for i in range(P))
    bounded_edge(t, s, total_lo, total_hi)
    for node, dem in demand.items():
        if node not in G:
            G.add_node(node)
        G.nodes[node]["demand"] = dem  # networkx: demand>0 means sink
    flow = nx.min_cost_flow(G)
    S = np.zeros_like(C)
    for i in range(P):
        fr = flow.get(("r", i), {})
        for j in range(Q):
            lo = int(C[i, j]) // K
            S[i, j] = lo + fr.get(("c", j), 0)
    return S
