"""Matrix decomposition theorems underlying Cross Wiring (paper §3.4).

Theorem 3.1 (Symmetric Integer Matrix Decomposition): any symmetric integer
matrix ``C`` decomposes as ``C = A + Aᵀ`` with every row/col sum of ``A``
within ``⌊Σ/2⌋ .. ⌈Σ/2⌉`` of half the corresponding sum of ``C``.

Theorem 3.2 (Integer Matrix Decomposition, from Minimal Rewiring): any
integer matrix ``C`` splits into ``K`` integer matrices whose entries and
row/col sums are all within floor/ceil of ``1/K``-th of the originals.

The paper proves both via min-cost-flow (MCF) feasibility.  We implement the
MCF constructions (networkx) as *oracles* and two classical combinatorial
fast paths that are exact and near-linear:

* Thm 3.1 ≡ *balanced orientation* of the multigraph with adjacency ``C`` —
  Eulerian-circuit orientation with a dummy vertex absorbing odd degrees.
* the sub-permutation case of Thm 3.2 (the one MDMCF needs) ≡ *bipartite
  edge coloring* with ``Δ`` colors (König), via alternating-path recoloring —
  and it accepts a warm start, which is how MDMCF serves the Min-Rewiring
  objective (paper eq. 7).

All code is plain numpy + python — cluster control plane, not data plane.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "symmetric_split",
    "symmetric_split_euler",
    "symmetric_split_mcf",
    "assign_unit",
    "edge_color_bipartite",
    "halve_matrix",
    "integer_matrix_decompose",
    "check_symmetric_split",
    "check_edge_coloring",
]


# --------------------------------------------------------------------------
# Theorem 3.1 — fast path: Eulerian balanced orientation
# --------------------------------------------------------------------------

def _euler_orient(num_vertices: int, edges) -> np.ndarray:
    """Orient ``edges`` (undirected multigraph) so |out(v) - in(v)| <= 1.

    Classical construction: join all odd-degree vertices to a dummy vertex,
    walk Euler circuits (Hierholzer) orienting along the walk, drop dummy
    edges.  O(E).  Returns an ``(N, 2)`` int array of (tail, head) rows.
    The adjacency structure is built as a CSR incidence array with numpy
    (degrees via bincount, per-vertex slices via a stable argsort) so only
    the circuit walk itself remains a Python loop.
    """
    E0 = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    deg = np.bincount(E0.ravel(), minlength=num_vertices + 1)
    dummy = num_vertices
    odd = np.nonzero(deg[:num_vertices] % 2)[0]
    all_edges = np.concatenate(
        [E0, np.stack([np.full(odd.size, dummy, dtype=np.int64), odd], axis=1)]
    )
    M = all_edges.shape[0]
    if M == 0:
        return np.empty((0, 2), dtype=np.int64)

    # CSR incidence: per vertex, (edge_id, other_endpoint) in edge order —
    # stable sort of the interleaved endpoint list reproduces the classical
    # append-order adjacency exactly.
    verts = all_edges.ravel()
    eids = np.repeat(np.arange(M, dtype=np.int64), 2)
    others = all_edges[:, ::-1].ravel()
    order = np.argsort(verts, kind="stable")
    adj_eid = eids[order]
    adj_other = others[order]
    counts = np.bincount(verts, minlength=num_vertices + 1)
    indptr = np.zeros(num_vertices + 2, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])

    used = np.zeros(M, dtype=bool)
    ptr = indptr[:-1].copy()  # per-vertex scan pointer (amortized O(E))
    tails: List[int] = []
    eid_out: List[int] = []

    for start in range(num_vertices + 1):
        if ptr[start] >= indptr[start + 1]:
            continue
        # Hierholzer, iterative.  Record traversal direction of each edge.
        stack = [start]
        path_tails: List[int] = []
        path_eids: List[int] = []
        tail_stack: List[int] = []
        eid_stack: List[int] = []
        while stack:
            v = stack[-1]
            advanced = False
            while ptr[v] < indptr[v + 1]:
                eid = adj_eid[ptr[v]]
                w = adj_other[ptr[v]]
                ptr[v] += 1
                if used[eid]:
                    continue
                used[eid] = True
                stack.append(int(w))
                tail_stack.append(v)  # traversed v -> w
                eid_stack.append(int(eid))
                advanced = True
                break
            if not advanced:
                stack.pop()
                if eid_stack:
                    path_tails.append(tail_stack.pop())
                    path_eids.append(eid_stack.pop())
        tails.extend(path_tails)
        eid_out.extend(path_eids)

    t = np.asarray(tails, dtype=np.int64)
    e = np.asarray(eid_out, dtype=np.int64)
    u, v = all_edges[e, 0], all_edges[e, 1]
    h = np.where(t == u, v, u)
    keep = (t != dummy) & (h != dummy)
    return np.stack([t[keep], h[keep]], axis=1)


def symmetric_split_euler(C: np.ndarray) -> np.ndarray:
    """Thm 3.1 via Eulerian orientation.  Returns integer A with C = A + Aᵀ
    and balanced row/col sums.  Diagonal entries of C must be even."""
    C = np.asarray(C)
    if (C != C.T).any():
        raise ValueError("C must be symmetric")
    if (C < 0).any():
        raise ValueError("C must be non-negative")
    d = np.diagonal(C)
    if (d % 2).any():
        raise ValueError("diagonal entries of C must be even (C_ii = 2*A_ii)")
    P = C.shape[0]
    A = np.zeros_like(C)
    np.fill_diagonal(A, d // 2)
    # Pre-assign paired off-diagonal links symmetrically (a 2-cycle i->j->i is
    # already balanced); only the odd remainder needs orientation.
    off = C.copy()
    np.fill_diagonal(off, 0)
    half = off // 2
    A += half  # adds C_ij//2 in both directions
    rem = off - 2 * half  # 0/1 symmetric, zero diagonal
    iu, ju = np.nonzero(np.triu(rem, k=1))
    oriented = _euler_orient(P, np.stack([iu, ju], axis=1))
    np.add.at(A, (oriented[:, 0], oriented[:, 1]), 1)
    return A


# --------------------------------------------------------------------------
# Theorem 3.1 — oracle: the paper's MCF construction (networkx)
# --------------------------------------------------------------------------

def symmetric_split_mcf(C: np.ndarray) -> np.ndarray:
    """Thm 3.1 via the paper's min-cost-flow proof construction (DecomOPT).

    Used as a reference oracle in tests; the Euler path above is the
    production implementation.
    """
    import networkx as nx

    C = np.asarray(C)
    if (C != C.T).any():
        raise ValueError("C must be symmetric")
    d = np.diagonal(C)
    if (d % 2).any():
        raise ValueError("diagonal entries of C must be even")
    P = C.shape[0]
    A = np.zeros_like(C)
    np.fill_diagonal(A, d // 2)
    off = C.copy()
    np.fill_diagonal(off, 0)

    G = nx.DiGraph()
    demand: Dict[object, int] = {}
    rowsum = off.sum(axis=1)

    def add_demand(node, amt):
        demand[node] = demand.get(node, 0) + int(amt)

    total = 0
    for i in range(P):
        for j in range(i + 1, P):
            cij = int(off[i, j])
            if cij == 0:
                continue
            s = ("s", i, j)
            add_demand(s, -cij)  # supply
            total += cij
            G.add_edge(s, ("r", i), capacity=cij, weight=0)
            G.add_edge(s, ("r", j), capacity=cij, weight=0)
    t = "t"
    add_demand(t, total)
    # r_i -> t with bounds [floor(rowsum/2), ceil(rowsum/2)]
    for i in range(P):
        lo = int(rowsum[i]) // 2
        hi = -(-int(rowsum[i]) // 2)
        # lower-bound transformation: capacity hi-lo, shift demands by lo
        G.add_edge(("r", i), t, capacity=hi - lo, weight=0)
        add_demand(("r", i), lo)
        add_demand(t, -lo)
    for node, dem in demand.items():
        if node not in G:
            G.add_node(node)
        G.nodes[node]["demand"] = dem
    flow = nx.min_cost_flow(G)
    for i in range(P):
        for j in range(i + 1, P):
            if off[i, j] == 0:
                continue
            s = ("s", i, j)
            A[i, j] += flow[s].get(("r", i), 0)
            A[j, i] += flow[s].get(("r", j), 0)
    return A


def symmetric_split(C: np.ndarray, method: str = "euler") -> np.ndarray:
    if method == "euler":
        return symmetric_split_euler(C)
    if method == "mcf":
        return symmetric_split_mcf(C)
    raise ValueError(f"unknown method {method!r}")


def check_symmetric_split(C: np.ndarray, A: np.ndarray) -> None:
    """Assert the Thm 3.1 guarantees."""
    C = np.asarray(C)
    A = np.asarray(A)
    assert (A >= 0).all(), "A must be non-negative"
    assert (A + A.T == C).all(), "C != A + A^T"
    rs_c, cs_c = C.sum(axis=1), C.sum(axis=0)
    rs_a, cs_a = A.sum(axis=1), A.sum(axis=0)
    assert (rs_a >= rs_c // 2).all() and (rs_a <= -(-rs_c // 2)).all(), "row bound"
    assert (cs_a >= cs_c // 2).all() and (cs_a <= -(-cs_c // 2)).all(), "col bound"


# --------------------------------------------------------------------------
# Theorem 3.2 specialization — bipartite edge coloring (König)
# --------------------------------------------------------------------------

def assign_unit(
    rowc: np.ndarray,
    colc: np.ndarray,
    i: int,
    j: int,
    on_set=None,
    on_clear=None,
) -> int:
    """Color one directed unit ``(i, j)`` against a partial proper coloring.

    ``rowc[i, c]``/``colc[j, c]`` hold the matched column/row per color (or
    -1), with the number of colors given by their second axis.  Requires a
    free color at row ``i`` and at column ``j`` — the König precondition
    (fewer colored units at each endpoint than colors), under which a
    common free color exists or an (a, b)-alternating path inversion
    creates one.

    ``on_set(i, j, c)`` / ``on_clear(i, j, c)`` observe every (un)coloring,
    letting callers (e.g. the incremental MDMCF state) mirror the coloring
    into an OCS configuration.  Returns the number of path-flipped units.
    """
    free_i = rowc[i] == -1
    free_j = colc[j] == -1
    both = free_i & free_j
    if both.any():
        c = int(both.argmax())
        rowc[i, c] = j
        colc[j, c] = i
        if on_set is not None:
            on_set(i, j, c)
        return 0
    if not (free_i.any() and free_j.any()):
        raise ValueError("degree bound violated: no free color at an endpoint")
    a = int(free_i.argmax())  # first color free at row i
    b = int(free_j.argmax())  # first color free at col j
    # Invert the (a, b)-alternating path starting at column j (which is
    # missing color a).  The path cannot reach row i (parity argument), so
    # after inversion color a is free at both endpoints.
    path: List[Tuple[int, int, int]] = []  # (row, col, color)
    cur_color = a
    cur_node = j
    at_col = True
    while True:
        if at_col:
            r = int(colc[cur_node, cur_color])
            if r == -1:
                break
            path.append((r, cur_node, cur_color))
            cur_node, at_col = r, False
            cur_color = b if cur_color == a else a
        else:
            cc = int(rowc[cur_node, cur_color])
            if cc == -1:
                break
            path.append((cur_node, cc, cur_color))
            cur_node, at_col = cc, True
            cur_color = b if cur_color == a else a
    for (r, cc, col_) in path:
        rowc[r, col_] = -1
        colc[cc, col_] = -1
        if on_clear is not None:
            on_clear(r, cc, col_)
    for (r, cc, col_) in path:
        other = b if col_ == a else a
        rowc[r, other] = cc
        colc[cc, other] = r
        if on_set is not None:
            on_set(r, cc, other)
    assert rowc[i, a] == -1 and colc[j, a] == -1
    rowc[i, a] = j
    colc[j, a] = i
    if on_set is not None:
        on_set(i, j, a)
    return len(path)


def edge_color_bipartite(
    A: np.ndarray,
    num_colors: int,
    warm: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Decompose non-negative integer matrix ``A`` (row & col sums ≤
    ``num_colors``) into ``num_colors`` sub-permutation 0/1 matrices.

    Returns ``colors`` of shape ``(num_colors, P, Q)`` with
    ``colors.sum(0) == A`` and each slice having row/col sums ≤ 1.

    ``warm`` (optional, same shape as the output) seeds the coloring with a
    previous configuration: any unit of demand that the old configuration
    already carried keeps its color when still free — this implements the
    Min-Rewiring objective (paper eq. 7) inside the decomposition.

    Algorithm: classical alternating-path bipartite edge coloring
    (König / Vizing restricted to bipartite), O(E · (P + num_colors)).
    The bulk of the units carry a color free at both endpoints and is
    assigned in vectorized conflict-free waves; only the leftovers walk
    the scalar alternating-path machinery (:func:`assign_unit`).
    """
    A = np.asarray(A)
    if (A < 0).any():
        raise ValueError("A must be non-negative")
    P, Q = A.shape
    K = num_colors
    if (A.sum(axis=1) > K).any() or (A.sum(axis=0) > K).any():
        raise ValueError("row/col sums must be <= num_colors")

    # rowc[i, c] = matched column (or -1); colc[j, c] = matched row (or -1)
    rowc = np.full((P, K), -1, dtype=np.int64)
    colc = np.full((Q, K), -1, dtype=np.int64)
    remaining = A.astype(np.int64).copy()

    # ---- warm start ------------------------------------------------------
    if warm is not None:
        warm = np.asarray(warm)
        if warm.shape != (K, P, Q):
            raise ValueError("warm must have shape (num_colors, P, Q)")
        cs, is_, js = np.nonzero(warm)
        for c, i, j in zip(cs.tolist(), is_.tolist(), js.tolist()):
            if remaining[i, j] > 0 and rowc[i, c] == -1 and colc[j, c] == -1:
                rowc[i, c] = j
                colc[j, c] = i
                remaining[i, j] -= 1

    # ---- wave phase: batch-assign units with a common free color ---------
    iu, ju = np.nonzero(remaining)
    counts = remaining[iu, ju]
    ui = np.repeat(iu, counts)
    uj = np.repeat(ju, counts)
    while ui.size:
        common = (rowc[ui] == -1) & (colc[uj] == -1)  # (U, K)
        has = common.any(axis=1)
        if not has.any():
            break
        hi, hj = ui[has], uj[has]
        pick = common[has].argmax(axis=1)  # first common free color
        U = hi.size
        idx = np.arange(U)
        # conflict-free subset: keep only the first unit per (row, color)
        # and per (col, color) slot, exactly what sequential order would do
        kic = hi * K + pick
        kjc = hj * K + pick
        first_ic = np.full(P * K, U, dtype=np.int64)
        first_jc = np.full(Q * K, U, dtype=np.int64)
        np.minimum.at(first_ic, kic, idx)
        np.minimum.at(first_jc, kjc, idx)
        win = (first_ic[kic] == idx) & (first_jc[kjc] == idx)
        rowc[hi[win], pick[win]] = hj[win]
        colc[hj[win], pick[win]] = hi[win]
        keep = np.ones(ui.size, dtype=bool)
        keep[np.nonzero(has)[0][win]] = False
        ui, uj = ui[keep], uj[keep]

    # ---- leftovers: alternating-path recoloring --------------------------
    for i, j in zip(ui.tolist(), uj.tolist()):
        assign_unit(rowc, colc, i, j)

    colors = np.zeros((K, P, Q), dtype=np.int8)
    for c in range(K):
        rows = np.nonzero(rowc[:, c] >= 0)[0]
        colors[c, rows, rowc[rows, c]] = 1
    return colors


def check_edge_coloring(A: np.ndarray, colors: np.ndarray) -> None:
    assert (colors.sum(axis=0) == A).all(), "colors do not sum to A"
    assert (colors.sum(axis=2) <= 1).all(), "row sum > 1 in a color class"
    assert (colors.sum(axis=1) <= 1).all(), "col sum > 1 in a color class"


# --------------------------------------------------------------------------
# Theorem 3.2 — general K-way decomposition
# --------------------------------------------------------------------------

def halve_matrix(C: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Split integer matrix C into C1 + C2 with entries and row/col sums each
    within floor/ceil of half — via Eulerian orientation of the bipartite
    multigraph (rows ∪ cols) of the odd remainder."""
    C = np.asarray(C)
    P, Q = C.shape
    base = C // 2
    rem = C - 2 * base  # 0/1
    iu, ju = np.nonzero(rem)
    C1 = base.copy()
    C2 = base.copy()
    oriented = _euler_orient(P + Q, np.stack([iu, P + ju], axis=1))
    u, v = oriented[:, 0], oriented[:, 1]
    fwd = u < P  # row -> col  ⇒ give the odd unit to C1, else to C2
    np.add.at(C1, (u[fwd], v[fwd] - P), 1)
    np.add.at(C2, (v[~fwd], u[~fwd] - P), 1)
    return C1, C2


def integer_matrix_decompose(C: np.ndarray, K: int) -> List[np.ndarray]:
    """Thm 3.2: split C into K matrices with per-entry and row/col-sum
    balance w.r.t. the *original* C (floor/ceil of 1/K shares).

    Power-of-two K uses recursive Euler halving (near-linear); other K peel
    one balanced slice at a time (each peel preserves the bounds — see
    tests/test_decomposition.py for the property check).
    """
    C = np.asarray(C).astype(np.int64)
    if K <= 0:
        raise ValueError("K must be positive")
    if K == 1:
        return [C.copy()]
    if K % 2 == 0:
        C1, C2 = halve_matrix(C)
        return integer_matrix_decompose(C1, K // 2) + integer_matrix_decompose(
            C2, K // 2
        )
    # odd K: peel one slice with entries in [⌊C/K⌋, ⌈C/K⌉] and balanced
    # row/col sums, then recurse with K-1.  The peel is itself computed by
    # repeated halving: slice = C - decompose(C, K)[1:] would be circular, so
    # use a direct proportional split via sorting of fractional parts
    # (a transportation-rounding argument).
    slice_ = _peel_balanced_slice(C, K)
    rest = C - slice_
    return [slice_] + integer_matrix_decompose_bounded(rest, K - 1, C, K)


def integer_matrix_decompose_bounded(
    C: np.ndarray, K: int, orig: np.ndarray, orig_k: int
) -> List[np.ndarray]:
    """Recurse like :func:`integer_matrix_decompose` — bounds relative to the
    *current* remainder stay within the original floor/ceil window (standard
    floor/ceil arithmetic, property-tested)."""
    if K == 1:
        return [C.copy()]
    if K % 2 == 0:
        C1, C2 = halve_matrix(C)
        return integer_matrix_decompose_bounded(
            C1, K // 2, orig, orig_k
        ) + integer_matrix_decompose_bounded(C2, K // 2, orig, orig_k)
    slice_ = _peel_balanced_slice(C, K)
    return [slice_] + integer_matrix_decompose_bounded(C - slice_, K - 1, orig, orig_k)


def _peel_balanced_slice(C: np.ndarray, K: int) -> np.ndarray:
    """Extract S with S_ij ∈ [⌊C_ij/K⌋, ⌈C_ij/K⌉], row/col sums within
    floor/ceil of 1/K of C's — via min-cost-flow feasibility (networkx),
    mirroring the paper's proof of Thm 3.2."""
    import networkx as nx

    C = np.asarray(C)
    P, Q = C.shape
    G = nx.DiGraph()
    demand: Dict[object, int] = {}

    def add_demand(node, amt):
        demand[node] = demand.get(node, 0) + int(amt)

    rs, cs = C.sum(axis=1), C.sum(axis=0)
    s, t = "s", "t"

    def bounded_edge(u, v, lo, hi):
        G.add_edge(u, v, capacity=int(hi - lo), weight=0)
        add_demand(u, lo)
        add_demand(v, -lo)

    for i in range(P):
        bounded_edge(s, ("r", i), int(rs[i]) // K, -(-int(rs[i]) // K))
    for j in range(Q):
        bounded_edge(("c", j), t, int(cs[j]) // K, -(-int(cs[j]) // K))
    for i in range(P):
        for j in range(Q):
            lo, hi = int(C[i, j]) // K, -(-int(C[i, j]) // K)
            if hi == 0:
                continue
            bounded_edge(("r", i), ("c", j), lo, hi)
    # close the circulation t -> s
    total_lo = sum(int(rs[i]) // K for i in range(P))
    total_hi = sum(-(-int(rs[i]) // K) for i in range(P))
    bounded_edge(t, s, total_lo, total_hi)
    for node, dem in demand.items():
        if node not in G:
            G.add_node(node)
        G.nodes[node]["demand"] = dem  # networkx: demand>0 means sink
    flow = nx.min_cost_flow(G)
    S = np.zeros_like(C)
    for i in range(P):
        fr = flow.get(("r", i), {})
        for j in range(Q):
            lo = int(C[i, j]) // K
            S[i, j] = lo + fr.get(("c", j), 0)
    return S
