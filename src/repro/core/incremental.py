"""Incremental topology engineering: O(|demand delta|) exact MDMCF updates.

The cold ITV-MDMCF solve (:func:`~repro.core.reconfig.mdmcf_reconfigure`)
re-runs the full Theorem 4.1 construction — symmetric split + König edge
coloring over *every* demand unit — on each scheduler event, even though a
job arrival/departure/fault typically touches a few pod pairs.  Following
the delta-update playbook of FastReChain / ACOS (see PAPERS.md), this
module keeps the decomposition *alive* between events:

:class:`ColoringState` holds, per OCS group, the balanced orientation ``A``
(``A + Aᵀ = C``) and the proper ``K_spine/2``-edge-coloring of its directed
units (``rowc``/``colc``), with color classes pinned to OCS pairs — plus a
live mirror of the emitted configuration.  :func:`mdmcf_delta` patches that
state under a demand delta:

* released units are simply un-colored (uncoloring preserves properness);
* added units are oriented greedily against the out/in budgets; when both
  budgets at an endpoint are saturated, a short *flip chain* (a directed
  path found by BFS on ``A``) re-orients existing units to free one slot —
  the same residual-flow argument that proves Theorem 3.1 guarantees such
  a chain exists whenever the new demand is feasible;
* every uncolored unit (new or flipped) is re-colored by the alternating
  -path machinery (:func:`~repro.core.decomposition.assign_unit`) — König's
  argument applies verbatim to the residual, so the update is *exact*:
  ``LTRR = 1`` for any feasible demand, same as the cold solve.

Untouched demand keeps its color *and* its OCS slot, so the rewiring cost
of a delta is bounded by the delta-adjacent work — in practice no worse
than (and usually far below) the warm-started cold solve's.

Degraded mode: a state built against a :class:`~repro.fault.masks.PortMask`
colors only the mask's clean OCS pairs and is stamped with the mask's
``fingerprint()``; any later mask change raises :class:`StaleStateError`,
telling the caller (``sim/scheduler.py``) to fall back to a cold solve and
rebuild.  Demands outside the clean-pair budget raise
:class:`DeltaInfeasible` (the cold path then degrades gracefully via
``repro.fault.recover.mdmcf_degraded``).
"""
from __future__ import annotations

import collections
import time
from typing import List, Optional, Tuple

import numpy as np

from .decomposition import assign_unit
from .reconfig import ReconfigResult, linear_sum_assignment
from .topology import ClusterSpec, OCSConfig, demand_feasible
from ..obs.trace import ambient as _trace_ambient

__all__ = [
    "ColoringState",
    "DeltaInfeasible",
    "StaleStateError",
    "mdmcf_delta",
]


class StaleStateError(RuntimeError):
    """The coloring state no longer matches the cluster (mask changed)."""


class DeltaInfeasible(ValueError):
    """The new demand is not feasible under the state's (masked) budget."""


class ColoringState:
    """Persistent per-group coloring of the current MDMCF decomposition.

    Invariants (per group ``h``, with ``k2[h]`` usable OCS pairs):

    * ``A[h] + A[h].T == C[h]`` — exact realization of the demand;
    * ``A[h].sum(1) <= k2[h]`` and ``A[h].sum(0) <= k2[h]`` — orientation
      within the out/in budgets (``outdeg``/``indeg`` track these);
    * ``rowc[h]``/``colc[h]`` are a proper edge coloring of ``A[h]``'s
      units with ``k2[h]`` colors; color ``c`` lives on OCS pair
      ``pairs[h][c]`` (even OCS carries the class, odd its transpose);
    * ``_x`` mirrors the coloring as a full OCS configuration.

    Build one by adopting a cold solve (:meth:`from_config`) or from the
    all-zero demand (:meth:`empty`), then patch it with
    :func:`mdmcf_delta` — exactness (LTRR = 1) is preserved on every
    feasible step:

    >>> import numpy as np
    >>> from repro.core.topology import ClusterSpec
    >>> from repro.core.reconfig import mdmcf_reconfigure
    >>> spec = ClusterSpec(num_pods=4, k_spine=4, k_leaf=4)
    >>> C = np.zeros((spec.num_ocs_groups, 4, 4), dtype=np.int64)
    >>> C[:, 0, 1] = C[:, 1, 0] = 2
    >>> res = mdmcf_reconfigure(spec, C)
    >>> state = ColoringState.from_config(spec, res.demand, res.config)
    >>> C2 = C.copy(); C2[:, 2, 3] = C2[:, 3, 2] = 1
    >>> round(float(mdmcf_delta(spec, state, C2).ltrr), 9)  # exact delta
    1.0
    """

    def __init__(
        self,
        spec: ClusterSpec,
        num_groups: int,
        pairs: List[np.ndarray],
        mask_sig: Optional[bytes] = None,
    ):
        P, K = spec.num_pods, spec.ocs_per_group
        self.spec = spec
        self.num_groups = num_groups
        self.pairs = [np.asarray(p, dtype=np.int64) for p in pairs]
        self.k2 = [int(p.size) for p in self.pairs]
        self.mask_sig = mask_sig
        self.C = np.zeros((num_groups, P, P), dtype=np.int64)
        self.A = np.zeros((num_groups, P, P), dtype=np.int64)
        self.outdeg = np.zeros((num_groups, P), dtype=np.int64)
        self.indeg = np.zeros((num_groups, P), dtype=np.int64)
        self.rowc = [np.full((P, k), -1, dtype=np.int64) for k in self.k2]
        self.colc = [np.full((P, k), -1, dtype=np.int64) for k in self.k2]
        self._x = np.zeros((num_groups, K, P, P), dtype=np.int8)
        self.rewired = 0  # |Δx| entries touched by the last delta
        self._poisoned = False

    # ---- construction ----------------------------------------------------

    @classmethod
    def empty(cls, spec: ClusterSpec, num_groups: int, mask=None) -> "ColoringState":
        """State realizing the all-zero demand."""
        K2 = spec.k_spine // 2
        pairs = [
            mask.clean_pairs(h) if mask is not None else np.arange(K2)
            for h in range(num_groups)
        ]
        sig = mask.fingerprint() if mask is not None else None
        return cls(spec, num_groups, pairs, mask_sig=sig)

    @classmethod
    def from_config(
        cls, spec: ClusterSpec, C: np.ndarray, config: OCSConfig, mask=None
    ) -> "ColoringState":
        """Adopt a solver-emitted configuration that realizes ``C`` exactly.

        The configuration must come from :func:`mdmcf_reconfigure` (healthy
        or clean-pair masked): every circuit on a tracked even OCS, the odd
        OCS carrying its transpose.  Anything else (e.g. the salvage paths
        of ``mdmcf_degraded``) raises ``ValueError`` — such configs have no
        coloring to adopt.
        """
        C = np.asarray(C, dtype=np.int64)
        H = C.shape[0]
        if config.num_groups != H:
            raise ValueError("config/demand group counts differ")
        st = cls.empty(spec, H, mask=mask)
        x = config.x
        for h in range(H):
            total = int(x[h].astype(np.int64).sum())
            tracked = 0
            for t, slot in enumerate(st.pairs[h].tolist()):
                m = x[h, 2 * slot]
                if (x[h, 2 * slot + 1] != m.T).any():
                    raise ValueError("odd OCS is not the even transpose")
                ri, cj = np.nonzero(m)
                st.rowc[h][ri, t] = cj
                st.colc[h][cj, t] = ri
                st.A[h][ri, cj] += 1
                tracked += 2 * ri.size
            if tracked != total:
                raise ValueError("config uses untracked (masked) OCS slots")
            if (st.A[h] + st.A[h].T != C[h]).any():
                raise ValueError("config does not realize C exactly")
        st.C[:] = C
        st.outdeg[:] = st.A.sum(axis=2)
        st.indeg[:] = st.A.sum(axis=1)
        st._x[:] = x
        return st

    # ---- emission --------------------------------------------------------

    def emit_config(self) -> OCSConfig:
        cfg = OCSConfig(self.spec, self.num_groups)
        cfg.x = self._x.copy()
        return cfg

    # ---- per-unit mutators (all keep the class invariants) ---------------

    def _set(self, h: int, i: int, j: int, c: int) -> None:
        slot = int(self.pairs[h][c])
        self._x[h, 2 * slot, i, j] = 1
        self._x[h, 2 * slot + 1, j, i] = 1

    def _clear(self, h: int, i: int, j: int, c: int) -> None:
        slot = int(self.pairs[h][c])
        self._x[h, 2 * slot, i, j] = 0
        self._x[h, 2 * slot + 1, j, i] = 0

    def _color_of(self, h: int, u: int, v: int) -> int:
        cs = np.nonzero(self.rowc[h][u] == v)[0]
        if not cs.size:
            raise DeltaInfeasible("no colored unit to release")
        c = int(cs[0])
        if self.colc[h][v, c] != u:
            raise DeltaInfeasible("rowc/colc desynchronized")
        return c

    def _uncolor(self, h: int, u: int, v: int) -> None:
        c = self._color_of(h, u, v)
        self.rowc[h][u, c] = -1
        self.colc[h][v, c] = -1
        self._clear(h, u, v, c)

    def _color(self, h: int, u: int, v: int) -> None:
        assign_unit(
            self.rowc[h],
            self.colc[h],
            u,
            v,
            on_set=lambda i, j, c: self._set(h, i, j, c),
            on_clear=lambda i, j, c: self._clear(h, i, j, c),
        )

    def _remove_unit(self, h: int, i: int, j: int) -> None:
        """Release one bidirectional demand unit {i, j}."""
        A, out, ind = self.A[h], self.outdeg[h], self.indeg[h]
        u, v = i, j
        if i != j and A[j, i] > 0:
            # prefer un-orienting the more loaded direction (rebalances
            # toward future additions); ties keep (i, j)
            if A[i, j] == 0 or out[j] + ind[i] > out[i] + ind[j]:
                u, v = j, i
        if A[u, v] <= 0:
            raise DeltaInfeasible("state does not carry the released demand")
        self._uncolor(h, u, v)
        A[u, v] -= 1
        out[u] -= 1
        ind[v] -= 1

    def _flip_chain(self, h: int, chain: List[Tuple[int, int]]) -> None:
        """Re-orient each unit ``u→w`` of ``chain`` to ``w→u``; re-color
        the reversed units only after all flips (mid-chain budgets may
        transiently exceed ``k2`` — the end state never does)."""
        A, out, ind = self.A[h], self.outdeg[h], self.indeg[h]
        for u, w in chain:
            self._uncolor(h, u, w)
            A[u, w] -= 1
            out[u] -= 1
            ind[w] -= 1
            A[w, u] += 1
            out[w] += 1
            ind[u] += 1
        for u, w in chain:
            self._color(h, w, u)

    def _bfs_chain(self, h: int, v: int, forward: bool) -> List[Tuple[int, int]]:
        """Directed path from ``v`` (along ``A`` units; against them when
        ``forward`` is False) to the nearest vertex with spare out- (in-)
        budget.  Existence for feasible demand follows from the counting
        argument on the reachable set (Thm 3.1's residual-flow view)."""
        A = self.A[h]
        bud = self.outdeg[h] if forward else self.indeg[h]
        K2 = self.k2[h]
        P = A.shape[0]
        visited = np.zeros(P, dtype=bool)
        visited[v] = True
        parent = np.full(P, -1, dtype=np.int64)
        queue = collections.deque([v])
        target = -1
        while queue and target < 0:
            u = queue.popleft()
            succ = np.nonzero(A[u] if forward else A[:, u])[0]
            for w in succ.tolist():
                if w == u or visited[w]:
                    continue
                visited[w] = True
                parent[w] = u
                if bud[w] < K2:
                    target = w
                    break
                queue.append(w)
        if target < 0:
            raise DeltaInfeasible("no rebalancing chain: demand delta infeasible")
        hops: List[int] = [target]
        while hops[-1] != v:
            hops.append(int(parent[hops[-1]]))
        hops.reverse()  # v ... target
        if forward:
            return [(hops[t], hops[t + 1]) for t in range(len(hops) - 1)]
        return [(hops[t + 1], hops[t]) for t in range(len(hops) - 1)]

    def _add_unit(self, h: int, i: int, j: int) -> None:
        """Orient, rebalance if needed, and color one new unit {i, j}."""
        A, out, ind = self.A[h], self.outdeg[h], self.indeg[h]
        K2 = self.k2[h]
        if i == j:
            u = v = i
        else:
            vio_ij = int(out[i] >= K2) + int(ind[j] >= K2)
            vio_ji = int(out[j] >= K2) + int(ind[i] >= K2)
            if vio_ij != vio_ji:
                u, v = (i, j) if vio_ij < vio_ji else (j, i)
            else:
                u, v = (i, j) if out[i] - ind[i] <= out[j] - ind[j] else (j, i)
            if min(vio_ij, vio_ji) > 1:
                raise DeltaInfeasible("demand delta infeasible")
        if out[u] >= K2:
            self._flip_chain(h, self._bfs_chain(h, u, forward=True))
        if ind[v] >= K2:
            self._flip_chain(h, self._bfs_chain(h, v, forward=False))
        if out[u] >= K2 or ind[v] >= K2:
            raise DeltaInfeasible("rebalancing failed: demand delta infeasible")
        A[u, v] += 1
        out[u] += 1
        ind[v] += 1
        self._color(h, u, v)

    def _apply_group_delta(self, h: int, D: np.ndarray) -> None:
        up = np.triu(D)
        ri, rj = np.nonzero(up < 0)
        for i, j in zip(ri.tolist(), rj.tolist()):
            n = -int(D[i, j]) if i != j else -int(D[i, i]) // 2
            for _ in range(n):
                self._remove_unit(h, i, j)
        ai, aj = np.nonzero(up > 0)
        for i, j in zip(ai.tolist(), aj.tolist()):
            n = int(D[i, j]) if i != j else int(D[i, i]) // 2
            for _ in range(n):
                self._add_unit(h, i, j)

    def _slot_rematch(self, h: int, old_rowc: np.ndarray) -> int:
        """Hungarian-permute color classes over this group's OCS pairs to
        maximize overlap with the pre-delta configuration (paper eq. 7).

        Cheap by structure: the odd OCS always carries the even transpose,
        so the even/odd overlap terms of the cold solve's slot matching are
        equal and the whole objective reduces to per-row match counts
        between the current and previous ``rowc`` — O(P·k2²), no P×P
        einsums.  Returns the number of directed units kept in place.
        """
        k2 = self.k2[h]
        if k2 == 0:
            return 0
        rc = self.rowc[h]
        # ov[t, s] = units class t shares with the class previously on s
        ov = ((rc[:, :, None] == old_rowc[:, None, :]) & (rc[:, :, None] >= 0)).sum(
            axis=0
        )
        order = np.arange(k2)
        if linear_sum_assignment is not None:
            rows, cols = linear_sum_assignment(-ov)
            order[cols] = rows  # slot s gets class order[s]
        kept = int(ov[order, np.arange(k2)].sum())
        if (order != np.arange(k2)).any():
            self.rowc[h] = rc[:, order].copy()
            self.colc[h] = self.colc[h][:, order].copy()
            P = rc.shape[0]
            for s in range(k2):
                slot = int(self.pairs[h][s])
                m = np.zeros((P, P), dtype=np.int8)
                rows_s = np.nonzero(self.rowc[h][:, s] >= 0)[0]
                m[rows_s, self.rowc[h][rows_s, s]] = 1
                self._x[h, 2 * slot] = m
                self._x[h, 2 * slot + 1] = m.T
        return kept


def mdmcf_delta(
    spec: ClusterSpec,
    state: ColoringState,
    C_new: np.ndarray,
    mask=None,
    slot_match: bool = True,
    validate: bool = True,
    check_feasible: bool = True,
) -> ReconfigResult:
    """Patch ``state`` from its current demand to ``C_new``; exact, and
    O(|demand delta|) instead of O(full demand).

    ``slot_match`` re-runs the Min-Rewiring slot assignment (Hungarian, on
    the cheap rowc-overlap reduction) for the changed groups only —
    untouched groups never rewire at all.

    ``validate=False`` / ``check_feasible=False`` skip the O(H·K·P²)
    config re-validation and the (11)(12) pre-check.  The sub-permutation
    property holds by construction (``rowc``/``colc`` cannot double-book a
    port), so the scheduler's healthy hot path — whose aggregate demand is
    budget-shaved and symmetric by construction — disables both; any
    caller that cannot guarantee feasibility must keep ``check_feasible``
    (an infeasible delta would otherwise poison the state loudly via the
    rebalancing-chain assertion).

    Raises :class:`StaleStateError` when ``mask`` no longer matches the
    state (cold re-solve required) and :class:`DeltaInfeasible` when
    ``C_new`` violates the (masked) feasibility conditions (11)(12) — the
    pre-checks leave the state untouched, while a failure detected
    mid-patch (possible with ``check_feasible=False``) poisons the state
    (``state._poisoned``) so it cannot silently serve further deltas;
    callers fall back to a cold solve either way.  Returns a
    :class:`~repro.core.reconfig.ReconfigResult` whose
    config realizes ``C_new`` exactly; ``result.rewired`` counts the
    ``Σ|Δx|`` entries the delta touched.
    """
    t0 = time.perf_counter()
    if state._poisoned:
        raise StaleStateError("coloring state poisoned by an earlier failure")
    sig = mask.fingerprint() if mask is not None else None
    if sig != state.mask_sig:
        raise StaleStateError("mask changed since the state was built")
    C_new = np.asarray(C_new).astype(np.int64, copy=False)
    if C_new.shape != state.C.shape:
        raise DeltaInfeasible("demand shape changed")
    if check_feasible:
        if not demand_feasible(C_new, spec, mask=mask):
            raise DeltaInfeasible("demand violates (11)(12) under the mask")
        if (np.diagonal(C_new, axis1=1, axis2=2) % 2).any():
            raise DeltaInfeasible("diagonal demand entries must be even")
    rewired = 0
    try:
        for h in range(state.num_groups):
            D = C_new[h] - state.C[h]
            if not D.any():
                continue
            old_rowc = state.rowc[h].copy()
            units_old = int(state.A[h].sum())
            state._apply_group_delta(h, D)
            state.C[h] = C_new[h]
            units_new = int(state.A[h].sum())
            if slot_match:
                kept = state._slot_rematch(h, old_rowc)
            else:
                rc = state.rowc[h]
                kept = int(((rc == old_rowc) & (rc >= 0)).sum())
            # Σ|Δx| for this group: every directed unit that left or
            # entered its slot touches one even and one odd x entry
            rewired += 2 * (units_old + units_new - 2 * kept)
    except Exception:
        state._poisoned = True
        raise
    state.rewired = rewired
    cfg = state.emit_config()
    if validate:
        cfg.validate()
    # demand is stored by reference, matching mdmcf_reconfigure's convention
    res = ReconfigResult(cfg, C_new, time.perf_counter() - t0)
    cfg.preseed_pair_capacity(C_new)  # exact by invariant: realized == C_new
    res.rewired = rewired
    tr = _trace_ambient()
    if tr is not None and tr.enabled:
        tr.instant("solve", "delta.patch", rewired=rewired)
    return res
