"""Logical topology demands: generation from jobs and random workloads.

A *logical topology* is a tensor ``C[h, i, j]`` — the number of bidirectional
links required between the h-th spines of pods i and j (paper §4.2).  It must
be symmetric (L2-compatibility, eq. 11) and degree-feasible (eq. 12).

Two sources of demand:

* :func:`random_feasible_demand` — configuration-model random multigraphs,
  used by the LTRR/MRAR/runtime benchmarks (paper §6.2's "100 distinct
  logical topologies ... fully utilize all ports in each Pod").
* :func:`jobs_to_demand` — the multi-tenant path: each training job's
  parallelism plan (TP/EP confined in-pod, DP/PP across pods, §3.1 Remark)
  becomes ring/chain traffic between the pods it occupies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .topology import ClusterSpec

__all__ = [
    "random_feasible_demand",
    "Job",
    "Placement",
    "jobs_to_demand",
    "ring_demand",
    "ring_pairs",
    "shave_to_budget",
]


def ring_pairs(order: Sequence[int]) -> List[Tuple[int, int]]:
    """Adjacent (i, j) hops of a bidirectional ring over ``order``.

    A 2-pod ring collapses onto a single pair (both directions share it).
    The single source of the wrap-around rule — demand lowering, the flow
    model, and ring scoring all build on this."""
    n = len(order)
    if n < 2:
        return []
    if n == 2:
        return [(order[0], order[1])]
    return [(order[t], order[(t + 1) % n]) for t in range(n)]


def shave_to_budget(M: np.ndarray, budget: np.ndarray) -> np.ndarray:
    """In-place: symmetrically remove links (fattest pair of the most
    oversubscribed pod first) until every pod's degree fits its budget
    (eq. 12).  Deterministic; shared by demand clipping everywhere."""
    over = M.sum(axis=1) - budget
    while (over > 0).any():
        p = int(np.argmax(over))
        nz = np.nonzero(M[p])[0]
        if nz.size == 0:
            break
        q = int(nz[np.argmax(M[p, nz])])
        M[p, q] -= 1
        M[q, p] -= 1
        # O(1) degree maintenance (a removed link costs each endpoint one
        # degree; a diagonal link costs its pod two)
        if p == q:
            over[p] -= 2
        else:
            over[p] -= 1
            over[q] -= 1
    return M


def random_feasible_demand(
    spec: ClusterSpec,
    rng: np.random.Generator,
    fill: float = 1.0,
    num_groups: Optional[int] = None,
) -> np.ndarray:
    """Random symmetric demand with row sums ≤ K_spine (== K_spine·fill).

    Uses the configuration model: each pod contributes ``round(K_spine·fill)``
    stubs per spine group; stubs are shuffled and paired.  Self-pairs are
    repaired by swapping with another pair (bounded retries, then dropped),
    keeping the diagonal zero.
    """
    P = spec.num_pods
    H = num_groups if num_groups is not None else spec.num_ocs_groups
    per = int(round(spec.k_spine * fill))
    per = max(0, min(per, spec.k_spine))
    C = np.zeros((H, P, P), dtype=np.int64)
    for h in range(H):
        stubs = np.repeat(np.arange(P), per)
        if stubs.size % 2:
            stubs = stubs[:-1]
        rng.shuffle(stubs)
        pairs = stubs.reshape(-1, 2)
        for t in range(len(pairs)):
            i, j = pairs[t]
            retries = 0
            while i == j and retries < 20:
                s = rng.integers(0, len(pairs))
                u, v = pairs[s]
                # swap j with u
                pairs[t, 1], pairs[s, 0] = u, j
                i, j = pairs[t]
                retries += 1
        for i, j in pairs:
            if i != j:
                C[h, i, j] += 1
                C[h, j, i] += 1
    assert (C.sum(axis=2) <= spec.k_spine).all()
    return C


def ring_demand(
    spec: ClusterSpec, pods: Sequence[int], links: int, num_groups: Optional[int] = None
) -> np.ndarray:
    """Demand of a bidirectional ring over ``pods`` with ``links`` parallel
    links per adjacent pair per spine group (the DP all-reduce pattern)."""
    P = spec.num_pods
    H = num_groups if num_groups is not None else spec.num_ocs_groups
    C = np.zeros((H, P, P), dtype=np.int64)
    for h in range(H):
        for i, j in ring_pairs(list(pods)):
            if i == j:
                continue
            C[h, i, j] += links
            C[h, j, i] += links
    return C


@dataclasses.dataclass
class Job:
    """One multi-tenant cluster job (paper §6.3 workload model).

    Two archetypes share the dataclass, selected by ``kind``:

    * ``"train"`` — a batch training job: runs for ``service_time``
      ideal-fabric seconds, its cross-pod traffic is the DP ring / EP
      all-to-all / PP chain planned by :mod:`repro.dist`.
    * ``"serve"`` — an inference-serving replica fleet
      (:mod:`repro.sim.serving`): ``service_time`` is ``inf`` (it runs to
      the simulation horizon), and its cross-pod traffic is the
      prefill→decode KV-cache stream sized from ``req_rate`` requests/s ×
      ``kv_tokens`` prompt tokens; ``prefill_frac`` splits the fleet's
      GPUs into the two pools and ``diurnal`` sets the daily load swing.
    """

    job_id: int
    num_gpus: int
    arrival: float
    service_time: float  # JRT on the ideal `Best` fabric
    model: str = "llama-7b"
    tp: int = 8
    ep: int = 1
    pp: int = 1  # pipeline stages (cross-pod chain traffic when > 1)
    # ---- serving archetype (repro.sim.serving) ---------------------------
    kind: str = "train"  # train | serve
    req_rate: float = 0.0  # serve: mean offered requests/s
    kv_tokens: int = 0  # serve: prompt tokens whose KV migrates per request
    prefill_frac: float = 0.25  # serve: share of GPUs in the prefill pool
    diurnal: float = 0.0  # serve: relative diurnal load amplitude [0, 1)

    @property
    def dp_pp_ways(self) -> int:
        return max(1, self.num_gpus // self.tp)


@dataclasses.dataclass
class Placement:
    """GPUs allocated to a job: pod -> gpu count.

    ``ring_order`` is the cyclic pod order chosen by the topology-aware
    ring-ordering pass (``dist.demand.ring_order``): the DP ring visits
    pods in this order so its edges land on the best-provisioned pairs of
    the current OCS configuration.  ``None`` → sorted order (cold start).
    """

    job_id: int
    pods: Dict[int, int]
    ring_order: Optional[Tuple[int, ...]] = None

    def pod_list(self) -> List[int]:
        return sorted(self.pods)

    def ring(self) -> List[int]:
        """Pods in DP-ring order (falls back to sorted pod ids)."""
        if self.ring_order is not None:
            return list(self.ring_order)
        return sorted(self.pods)


def jobs_to_demand(
    spec: ClusterSpec,
    placements: Sequence[Placement],
    links_per_job: Optional[int] = None,
) -> np.ndarray:
    """Aggregate logical-topology demand of concurrently running jobs.

    Each job contributes a DP ring across its pods.  Per-pod spine-port
    budget is allocated proportionally to the job's GPU share in that pod;
    demands are clipped to keep the total feasible (eq. 12)."""
    P, H, K = spec.num_pods, spec.num_ocs_groups, spec.k_spine
    C = np.zeros((H, P, P), dtype=np.int64)
    # remaining egress budget per (h, pod)
    budget = np.full((H, P), K, dtype=np.int64)
    for pl in placements:
        pods = pl.ring()
        if len(pods) < 2:
            continue
        # links per adjacent pair: share of pod capacity this job owns
        frac = min(1.0, max(pl.pods[p] for p in pods) / spec.gpus_per_pod)
        want = links_per_job if links_per_job is not None else max(
            1, int(round(K * frac / 2))
        )
        ring = ring_demand(spec, pods, want)
        # clip to remaining budget
        for h in range(H):
            shave_to_budget(ring[h], budget[h])
            budget[h] -= ring[h].sum(axis=1)
        C += ring
    assert (C.sum(axis=2) <= K).all()
    return C
