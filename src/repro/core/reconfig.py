"""OCS reconfiguration algorithms (paper §3.2 ILP model, §4.2, §6.2).

Strategies implemented:

* :func:`mdmcf_reconfigure` — the paper's polynomial-time algorithm for the
  Cross Wiring physical topology ("ITV-MDMCF"): Thm 3.1 symmetric split,
  then Thm 3.2's sub-permutation specialization (bipartite edge coloring)
  with a warm start + Hungarian slot matching for the Min-Rewiring objective
  (eq. 7).  Realizes **every** feasible logical topology exactly (Thm 4.1).

* :func:`mdmcf_cold` — same without warm start / slot matching (the "MCF"
  baseline of Minimal Rewiring [39], which ignores rewiring cost).

* :func:`uniform_greedy` — greedy per-OCS maximal matching under the Uniform
  physical topology (Qian Lv-style heuristic [21]).

* :func:`uniform_best_effort` — greedy multigraph edge coloring with
  ``K_spine`` colors + restarts; our scalable stand-in for the paper's
  Lagrangian-relaxed "Uniform-ILP".

* :func:`uniform_exact_small` — exhaustive optimum for tiny instances; used
  to *certify* the paper's Fig. 1 counterexample (a 3-pod full mesh is
  unrealizable under Uniform).

* :func:`helios_matching` — Helios-style [8,9] repeated max-weight bipartite
  matching on the remaining demand, under Cross Wiring wiring rules.

All strategies emit an :class:`~repro.core.topology.OCSConfig` and are
checked against the ILP constraints (1)–(6) by :func:`check_ilp_constraints`.
"""
from __future__ import annotations

import itertools
import time
from typing import List, Optional, Tuple

import numpy as np

from .decomposition import edge_color_bipartite, symmetric_split
from .topology import ClusterSpec, CrossWiring, OCSConfig, Uniform, demand_feasible

__all__ = [
    "mdmcf_reconfigure",
    "mdmcf_cold",
    "uniform_greedy",
    "uniform_best_effort",
    "uniform_exact_small",
    "helios_matching",
    "check_ilp_constraints",
    "ltrr",
    "config_cosine",
    "ReconfigResult",
]


class ReconfigResult:
    """Output of a reconfiguration strategy."""

    def __init__(self, config: OCSConfig, demand: np.ndarray, seconds: float):
        self.config = config
        self.demand = demand
        self.seconds = seconds

    @property
    def ltrr(self) -> float:
        return ltrr(self.config, self.demand)


def _cos(u: np.ndarray, v: np.ndarray) -> float:
    u = u.astype(np.float64).ravel()
    v = v.astype(np.float64).ravel()
    nu, nv = np.linalg.norm(u), np.linalg.norm(v)
    if nu == 0 or nv == 0:
        return 1.0 if nu == nv else 0.0
    return float(min(1.0, max(-1.0, u @ v / (nu * nv))))


def ltrr(config: OCSConfig, C: np.ndarray) -> float:
    """Logical Topology Realization Rate (paper eq. 15):
    cosine between realized bidirectional link counts and the demand."""
    realized = config.realized_bidirectional()
    return _cos(realized, C)


def config_cosine(a: OCSConfig, b: OCSConfig) -> float:
    """cos(x_l, x_{l-1}) — the MRAR building block (paper eq. 16)."""
    return _cos(a.x, b.x)


# --------------------------------------------------------------------------
# ITV-MDMCF (Cross Wiring)
# --------------------------------------------------------------------------

def mdmcf_reconfigure(
    spec: ClusterSpec,
    C: np.ndarray,
    old: Optional[OCSConfig] = None,
    method: str = "euler",
    slot_match: bool = True,
) -> ReconfigResult:
    """The paper's polynomial-time reconfiguration under Cross Wiring.

    ``C``: demand of shape ``(H, P, P)`` satisfying (11)(12).  Realizes it
    exactly.  ``method`` selects the Thm 3.1 implementation ("euler" fast
    path or "mcf" oracle).  With ``old`` given, the edge coloring is
    warm-started from the previous even-OCS sub-permutations and color
    classes are then Hungarian-matched to OCS slots to minimize rewiring.
    """
    t0 = time.perf_counter()
    C = np.asarray(C)
    if not demand_feasible(C, spec):
        raise ValueError("demand violates (11)(12); not a feasible logical topology")
    H, P, _ = C.shape
    K2 = spec.k_spine // 2
    cfg = OCSConfig(spec, num_groups=H)
    for h in range(H):
        A = symmetric_split(C[h], method=method)
        warm = old.x[h, 0::2] if old is not None else None
        colors = edge_color_bipartite(A, K2, warm=warm)
        order = np.arange(K2)
        if old is not None and slot_match:
            # overlap[t, s] = links kept if color class t lands on slot s
            old_even = old.x[h, 0::2].astype(np.int32)
            old_odd = old.x[h, 1::2].astype(np.int32)
            cint = colors.astype(np.int32)
            overlap = np.einsum("tij,sij->ts", cint, old_even) + np.einsum(
                "tji,sij->ts", cint, old_odd
            )
            from scipy.optimize import linear_sum_assignment

            rows, cols_idx = linear_sum_assignment(-overlap)
            order = np.empty(K2, dtype=np.int64)
            order[cols_idx] = rows  # slot s gets color class order[s]
        for s in range(K2):
            m = colors[order[s]]
            cfg.x[h, 2 * s] = m
            cfg.x[h, 2 * s + 1] = m.T
    cfg.validate()
    return ReconfigResult(cfg, C, time.perf_counter() - t0)


def mdmcf_cold(
    spec: ClusterSpec, C: np.ndarray, old: Optional[OCSConfig] = None, method: str = "euler"
) -> ReconfigResult:
    """MDMCF without rewiring awareness (the MinRewiring-MCF baseline)."""
    return mdmcf_reconfigure(spec, C, old=None, method=method, slot_match=False)


# --------------------------------------------------------------------------
# Uniform baselines
# --------------------------------------------------------------------------

def uniform_greedy(
    spec: ClusterSpec, C: np.ndarray, old: Optional[OCSConfig] = None
) -> ReconfigResult:
    """Greedy per-OCS maximal matching under Uniform wiring [21-style].

    Each OCS hosts a symmetric matching; greedily saturate the heaviest
    remaining demands first.  May leave demand unrealized (LTRR < 1)."""
    t0 = time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    cfg = OCSConfig(spec, num_groups=H)
    for h in range(H):
        rem = C[h].astype(np.int64).copy()
        for k in range(spec.k_spine):
            matched = np.zeros(P, dtype=bool)
            iu, ju = np.nonzero(np.triu(rem, k=1))
            weights = rem[iu, ju]
            for idx in np.argsort(-weights):
                i, j = int(iu[idx]), int(ju[idx])
                if matched[i] or matched[j] or rem[i, j] <= 0:
                    continue
                matched[i] = matched[j] = True
                rem[i, j] -= 1
                rem[j, i] -= 1
                cfg.x[h, k, i, j] = 1
                cfg.x[h, k, j, i] = 1
    cfg.validate()
    return ReconfigResult(cfg, C, time.perf_counter() - t0)


def uniform_best_effort(
    spec: ClusterSpec,
    C: np.ndarray,
    old: Optional[OCSConfig] = None,
    restarts: int = 4,
    seed: int = 0,
) -> ReconfigResult:
    """Greedy multigraph edge coloring with K_spine colors (+ restarts).

    Stand-in for the paper's Lagrangian-relaxed Uniform-ILP at scale: tries
    to cover the demand multigraph by K_spine symmetric matchings; overflow
    demand is dropped.  A proper K_spine-coloring exists iff the demand is
    realizable under Uniform — odd-cycle demands at full degree are not
    (chromatic index > Δ), which is the paper's Fig. 1 suboptimality.
    """
    t0 = time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    rng = np.random.default_rng(seed)
    best: Optional[OCSConfig] = None
    best_score = -1.0
    for r in range(restarts):
        cfg = OCSConfig(spec, num_groups=H)
        for h in range(H):
            edges: List[Tuple[int, int]] = []
            iu, ju = np.nonzero(np.triu(C[h], k=1))
            for i, j in zip(iu.tolist(), ju.tolist()):
                edges.extend([(i, j)] * int(C[h, i, j]))
            order = rng.permutation(len(edges)) if r else np.arange(len(edges))
            # free[v] = boolean over colors
            free = np.ones((P, spec.k_spine), dtype=bool)
            for e in order:
                i, j = edges[int(e)]
                both = np.nonzero(free[i] & free[j])[0]
                if both.size == 0:
                    continue  # dropped (unrealizable under Uniform greedily)
                c = int(both[0])
                free[i, c] = free[j, c] = False
                cfg.x[h, c, i, j] = 1
                cfg.x[h, c, j, i] = 1
        score = ltrr(cfg, C)
        if score > best_score:
            best, best_score = cfg, score
    assert best is not None
    best.validate()
    return ReconfigResult(best, C, time.perf_counter() - t0)


def uniform_exact_small(spec: ClusterSpec, C: np.ndarray) -> ReconfigResult:
    """Exhaustive optimum under Uniform (tiny instances only).

    Maximizes realized links over all per-OCS symmetric matchings.  Used in
    tests to certify unrealizability (e.g. paper Fig. 1's 3-pod full mesh).
    """
    t0 = time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    if P > 6 or spec.k_spine > 6:
        raise ValueError("exact solver is for tiny instances")

    # all matchings on P vertices (as lists of pairs)
    verts = list(range(P))
    matchings: List[Tuple[Tuple[int, int], ...]] = []

    def gen(avail: Tuple[int, ...], cur: Tuple[Tuple[int, int], ...]):
        matchings.append(cur)
        if len(avail) < 2:
            return
        a = avail[0]
        rest = avail[1:]
        for t, b in enumerate(rest):
            gen(rest[:t] + rest[t + 1 :], cur + ((a, b),))
        gen(rest, cur)  # leave `a` unmatched

    gen(tuple(verts), ())
    matchings = list(dict.fromkeys(matchings))

    cfg = OCSConfig(spec, num_groups=H)
    for h in range(H):
        best_assign: Optional[List[Tuple[Tuple[int, int], ...]]] = None
        best_links = -1

        def dfs(k: int, rem: np.ndarray, links: int, chosen):
            nonlocal best_assign, best_links
            ub = links + int(np.triu(rem, 1).sum())
            if ub <= best_links:
                return
            if k == spec.k_spine:
                if links > best_links:
                    best_links, best_assign = links, list(chosen)
                return
            for m in matchings:
                if any(rem[i, j] <= 0 for i, j in m):
                    continue
                rem2 = rem.copy()
                for i, j in m:
                    rem2[i, j] -= 1
                    rem2[j, i] -= 1
                dfs(k + 1, rem2, links + len(m), chosen + [m])

        dfs(0, C[h].astype(np.int64).copy(), 0, [])
        assert best_assign is not None
        for k, m in enumerate(best_assign):
            for i, j in m:
                cfg.x[h, k, i, j] = 1
                cfg.x[h, k, j, i] = 1
    cfg.validate()
    return ReconfigResult(cfg, C, time.perf_counter() - t0)


def helios_matching(
    spec: ClusterSpec, C: np.ndarray, old: Optional[OCSConfig] = None
) -> ReconfigResult:
    """Helios-style repeated max-weight matching, on Cross Wiring.

    For each even/odd OCS pair, extract a max-weight matching of the
    remaining (symmetric) demand via scipy's linear_sum_assignment on the
    demand matrix.  No optimality guarantee — included as the paper's
    'Helios' comparison point.
    """
    from scipy.optimize import linear_sum_assignment

    t0 = time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    cfg = OCSConfig(spec, num_groups=H)
    K2 = spec.k_spine // 2
    for h in range(H):
        rem = C[h].astype(np.int64).copy()
        for t in range(K2):
            w = rem.astype(np.float64)
            # maximize total weight of a directed sub-permutation
            rows, cols = linear_sum_assignment(-w)
            m = np.zeros((P, P), dtype=np.int8)
            for i, j in zip(rows, cols):
                if rem[i, j] > 0:
                    m[i, j] = 1
            # keep symmetric consumption: even OCS carries m, odd carries mᵀ;
            # each unit consumes one bidirectional demand link.
            cfg.x[h, 2 * t] = m
            cfg.x[h, 2 * t + 1] = m.T
            rem -= np.minimum(rem, (m + m.T).astype(np.int64))
    cfg.validate()
    return ReconfigResult(cfg, C, time.perf_counter() - t0)


# --------------------------------------------------------------------------
# ILP constraint checker (paper §3.2, constraints (1)–(6))
# --------------------------------------------------------------------------

def check_ilp_constraints(
    spec: ClusterSpec,
    C: np.ndarray,
    cfg: OCSConfig,
    topology: str = "cross_wiring",
    require_exact: bool = True,
) -> None:
    """Assert the ILP model's constraints hold for ``cfg``.

    (1) Σ_k x_ijkh == C_ijh          (demand satisfaction; ``require_exact``)
    (2)(3) per-spine port budgets    (≤ K_spine egress/ingress)
    (4)(5) per-OCS sub-permutation
    (6) L2-compatibility             (Cross Wiring pairing / Uniform symmetry)
    """
    x = cfg.x.astype(np.int64)
    realized = x.sum(axis=1)  # (H, P, P) directed circuits
    if require_exact:
        assert (realized == C).all(), "constraint (1): demand not met exactly"
    assert (x.sum(axis=(1, 3)) <= spec.k_spine).all(), "constraint (2)"
    assert (x.sum(axis=(1, 2)) <= spec.k_spine).all(), "constraint (3)"
    cfg.validate()  # (4)(5)
    if topology == "cross_wiring":
        assert CrossWiring(spec).l2_feasible(cfg), "constraint (6): pairing"
    else:
        assert Uniform(spec).l2_feasible(cfg), "constraint (6): symmetry"
