"""OCS reconfiguration algorithms (paper §3.2 ILP model, §4.2, §6.2).

Strategies implemented:

* :func:`mdmcf_reconfigure` — the paper's polynomial-time algorithm for the
  Cross Wiring physical topology ("ITV-MDMCF"): Thm 3.1 symmetric split,
  then Thm 3.2's sub-permutation specialization (bipartite edge coloring)
  with a warm start + Hungarian slot matching for the Min-Rewiring objective
  (eq. 7).  Realizes **every** feasible logical topology exactly (Thm 4.1).

* :func:`mdmcf_cold` — same without warm start / slot matching (the "MCF"
  baseline of Minimal Rewiring [39], which ignores rewiring cost).

* :func:`uniform_greedy` — greedy per-OCS maximal matching under the Uniform
  physical topology (Qian Lv-style heuristic [21]).

* :func:`uniform_best_effort` — greedy multigraph edge coloring with
  ``K_spine`` colors + restarts; our scalable stand-in for the paper's
  Lagrangian-relaxed "Uniform-ILP".

* :func:`uniform_exact_small` — exhaustive optimum for tiny instances; used
  to *certify* the paper's Fig. 1 counterexample (a 3-pod full mesh is
  unrealizable under Uniform).

* :func:`helios_matching` — Helios-style [8,9] repeated max-weight bipartite
  matching on the remaining demand, under Cross Wiring wiring rules.

All strategies emit an :class:`~repro.core.topology.OCSConfig` and are
checked against the ILP constraints (1)–(6) by :func:`check_ilp_constraints`.
"""
from __future__ import annotations

import itertools
import time
from typing import List, Optional, Tuple

import numpy as np

try:  # module-level hoist: imported once, not per OCS group / per call
    from scipy.optimize import linear_sum_assignment
except ImportError:  # pragma: no cover - scipy ships in the container
    linear_sum_assignment = None

from .decomposition import edge_color_bipartite, symmetric_split
from .topology import ClusterSpec, CrossWiring, OCSConfig, Uniform, demand_feasible
from ..obs.trace import ambient as _trace_ambient

__all__ = [
    "mdmcf_reconfigure",
    "mdmcf_cold",
    "uniform_greedy",
    "uniform_best_effort",
    "uniform_exact_small",
    "helios_matching",
    "check_ilp_constraints",
    "ltrr",
    "config_cosine",
    "ReconfigResult",
]


class ReconfigResult:
    """Output of a reconfiguration strategy.

    The emitted configuration is frozen: solvers are done mutating it, and
    freezing turns on :class:`~repro.core.topology.OCSConfig`'s derived-view
    memoization (``pair_capacity``/``realized_bidirectional``) for all the
    flow-model / ring-scoring reads between reconfigurations.
    """

    def __init__(self, config: OCSConfig, demand: np.ndarray, seconds: float):
        self.config = config.freeze()
        self.demand = demand
        self.seconds = seconds

    @property
    def ltrr(self) -> float:
        return ltrr(self.config, self.demand)


def _cos(u: np.ndarray, v: np.ndarray) -> float:
    u = u.astype(np.float64).ravel()
    v = v.astype(np.float64).ravel()
    nu, nv = np.linalg.norm(u), np.linalg.norm(v)
    if nu == 0 or nv == 0:
        return 1.0 if nu == nv else 0.0
    return float(min(1.0, max(-1.0, u @ v / (nu * nv))))


def ltrr(config: OCSConfig, C: np.ndarray) -> float:
    """Logical Topology Realization Rate (paper eq. 15):
    cosine between realized bidirectional link counts and the demand."""
    realized = config.realized_bidirectional()
    return _cos(realized, C)


def config_cosine(a: OCSConfig, b: OCSConfig) -> float:
    """cos(x_l, x_{l-1}) — the MRAR building block (paper eq. 16)."""
    return _cos(a.x, b.x)


# --------------------------------------------------------------------------
# ITV-MDMCF (Cross Wiring)
# --------------------------------------------------------------------------

def mdmcf_reconfigure(
    spec: ClusterSpec,
    C: np.ndarray,
    old: Optional[OCSConfig] = None,
    method: str = "euler",
    slot_match: bool = True,
    mask=None,
) -> ReconfigResult:
    """The paper's polynomial-time reconfiguration under Cross Wiring.

    ``C``: demand of shape ``(H, P, P)`` satisfying (11)(12).  Realizes it
    exactly.  ``method`` selects the Thm 3.1 implementation ("euler" fast
    path or "mcf" oracle).  With ``old`` given, the edge coloring is
    warm-started from the previous even-OCS sub-permutations and color
    classes are then Hungarian-matched to OCS slots to minimize rewiring.

    ``mask`` (a :class:`~repro.fault.masks.PortMask`) switches on the
    degraded-mode solve: color classes land only on the mask's *clean* OCS
    pairs, so no failed slot is ever assigned, and any demand within the
    degraded budget (``demand_feasible(C, spec, mask)``) is still realized
    exactly in polynomial time — the healthy algorithm on a smaller slot
    set (argument spelled out in ``repro.fault.recover``).  Use
    ``repro.fault.recover.degrade_demand`` to clip demand first.  The
    mask's blocked views fold *cordoned* slots (administratively excluded
    by the remediation engine, ``repro.fault.remediate``) in with failed
    ones, so a cordon is just a degraded solve the solver cannot tell
    from a failure — and gray (derated) slots stay assignable here;
    ``repro.fault.recover.mdmcf_degraded`` tie-breaks away from them.
    """
    t0 = time.perf_counter()
    C = np.asarray(C)
    if not demand_feasible(C, spec, mask=mask):
        raise ValueError("demand violates (11)(12); not a feasible logical topology")
    H, P, _ = C.shape
    K2 = spec.k_spine // 2
    cfg = OCSConfig(spec, num_groups=H)
    for h in range(H):
        pairs = mask.clean_pairs(h) if mask is not None else np.arange(K2)
        k2_eff = len(pairs)
        A = symmetric_split(C[h], method=method)
        warm = old.x[h, 2 * pairs] if old is not None else None
        colors = edge_color_bipartite(A, k2_eff, warm=warm)
        order = np.arange(k2_eff)
        if old is not None and slot_match and k2_eff:
            if linear_sum_assignment is None:
                raise ImportError("scipy is required for Min-Rewiring slot matching")
            # overlap[t, s] = links kept if color class t lands on slot s
            # (flattened float32 matmuls — much faster than int einsums)
            old_even = old.x[h, 2 * pairs].reshape(k2_eff, -1).astype(np.float32)
            old_odd = (
                np.transpose(old.x[h, 2 * pairs + 1], (0, 2, 1))
                .reshape(k2_eff, -1)
                .astype(np.float32)
            )
            cflat = colors.reshape(k2_eff, -1).astype(np.float32)
            overlap = cflat @ (old_even + old_odd).T
            rows, cols_idx = linear_sum_assignment(-overlap)
            order = np.empty(k2_eff, dtype=np.int64)
            order[cols_idx] = rows  # slot s gets color class order[s]
        for s in range(k2_eff):
            m = colors[order[s]]
            t = int(pairs[s])
            cfg.x[h, 2 * t] = m
            cfg.x[h, 2 * t + 1] = m.T
    cfg.validate(mask)
    res = ReconfigResult(cfg, C, time.perf_counter() - t0)
    cfg.preseed_pair_capacity(C)  # Thm 4.1: realized == C, skip the reduction
    tr = _trace_ambient()
    if tr is not None and tr.enabled:
        tr.instant(
            "solve", "cold_solve",
            warm=old is not None, slot_match=bool(slot_match),
            degraded=mask is not None, groups=int(H),
        )
    return res


def mdmcf_cold(
    spec: ClusterSpec,
    C: np.ndarray,
    old: Optional[OCSConfig] = None,
    method: str = "euler",
    mask=None,
) -> ReconfigResult:
    """MDMCF without rewiring awareness (the MinRewiring-MCF baseline)."""
    return mdmcf_reconfigure(
        spec, C, old=None, method=method, slot_match=False, mask=mask
    )


def _uniform_pod_ok(mask, H: int, K: int, P: int) -> Optional[np.ndarray]:
    """(H, K, P) bool — pod p can join OCS (h, k)'s symmetric matching.

    Under Uniform wiring a bidirectional link {i, j} on OCS k consumes the
    full (egress, ingress) port pair of *both* pods on that OCS, so a pod
    with either direction masked is out of that OCS entirely."""
    if mask is None:
        return None
    ok = ~(mask.egress_blocked() | mask.ingress_blocked())[:H]
    return ok & mask.pod_up()[None, None, :]


# --------------------------------------------------------------------------
# Uniform baselines
# --------------------------------------------------------------------------

def uniform_greedy(
    spec: ClusterSpec,
    C: np.ndarray,
    old: Optional[OCSConfig] = None,
    mask=None,
) -> ReconfigResult:
    """Greedy per-OCS maximal matching under Uniform wiring [21-style].

    Each OCS hosts a symmetric matching; greedily saturate the heaviest
    remaining demands first.  May leave demand unrealized (LTRR < 1).
    ``mask`` excludes pods whose ports on an OCS are failed — Uniform has
    no clean-pair fallback, so every failure directly shrinks matchings.

    The per-OCS matching is a vectorized sweep: edges sorted by remaining
    weight are accepted in rounds — an edge is taken when it is the first
    live appearance of *both* endpoints, which reproduces the sequential
    heaviest-first greedy exactly without a per-edge Python loop."""
    t0 = time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    ok = _uniform_pod_ok(mask, H, spec.k_spine, P)
    cfg = OCSConfig(spec, num_groups=H)
    for h in range(H):
        rem = C[h].astype(np.int64).copy()
        for k in range(spec.k_spine):
            matched = np.zeros(P, dtype=bool)
            if ok is not None:
                matched |= ~ok[h, k]
            iu, ju = np.nonzero(np.triu(rem, k=1))
            order = np.argsort(-rem[iu, ju], kind="stable")
            ei, ej = iu[order], ju[order]
            while ei.size:
                alive = ~matched[ei] & ~matched[ej]
                ei, ej = ei[alive], ej[alive]
                if not ei.size:
                    break
                idx = np.arange(ei.size)
                first = np.full(P, ei.size, dtype=np.int64)
                np.minimum.at(first, ei, idx)
                np.minimum.at(first, ej, idx)
                acc = (first[ei] == idx) & (first[ej] == idx)
                ai, aj = ei[acc], ej[acc]
                matched[ai] = matched[aj] = True
                rem[ai, aj] -= 1
                rem[aj, ai] -= 1
                cfg.x[h, k, ai, aj] = 1
                cfg.x[h, k, aj, ai] = 1
                ei, ej = ei[~acc], ej[~acc]
    cfg.validate(mask)
    return ReconfigResult(cfg, C, time.perf_counter() - t0)


def uniform_best_effort(
    spec: ClusterSpec,
    C: np.ndarray,
    old: Optional[OCSConfig] = None,
    restarts: int = 4,
    seed: int = 0,
    mask=None,
) -> ReconfigResult:
    """Greedy multigraph edge coloring with K_spine colors (+ restarts).

    Stand-in for the paper's Lagrangian-relaxed Uniform-ILP at scale: tries
    to cover the demand multigraph by K_spine symmetric matchings; overflow
    demand is dropped.  A proper K_spine-coloring exists iff the demand is
    realizable under Uniform — odd-cycle demands at full degree are not
    (chromatic index > Δ), which is the paper's Fig. 1 suboptimality.
    """
    t0 = time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    ok = _uniform_pod_ok(mask, H, spec.k_spine, P)
    rng = np.random.default_rng(seed)
    best: Optional[OCSConfig] = None
    best_score = -1.0
    for r in range(restarts):
        cfg = OCSConfig(spec, num_groups=H)
        for h in range(H):
            edges: List[Tuple[int, int]] = []
            iu, ju = np.nonzero(np.triu(C[h], k=1))
            for i, j in zip(iu.tolist(), ju.tolist()):
                edges.extend([(i, j)] * int(C[h, i, j]))
            order = rng.permutation(len(edges)) if r else np.arange(len(edges))
            # free[v] = boolean over colors (a masked slot is never free)
            free = (
                np.ones((P, spec.k_spine), dtype=bool)
                if ok is None
                else ok[h].T.copy()
            )
            for e in order:
                i, j = edges[int(e)]
                both = np.nonzero(free[i] & free[j])[0]
                if both.size == 0:
                    continue  # dropped (unrealizable under Uniform greedily)
                c = int(both[0])
                free[i, c] = free[j, c] = False
                cfg.x[h, c, i, j] = 1
                cfg.x[h, c, j, i] = 1
        score = ltrr(cfg, C)
        if score > best_score:
            best, best_score = cfg, score
    assert best is not None
    best.validate(mask)
    return ReconfigResult(best, C, time.perf_counter() - t0)


def uniform_exact_small(spec: ClusterSpec, C: np.ndarray) -> ReconfigResult:
    """Exhaustive optimum under Uniform (tiny instances only).

    Maximizes realized links over all per-OCS symmetric matchings.  Used in
    tests to certify unrealizability (e.g. paper Fig. 1's 3-pod full mesh).
    """
    t0 = time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    if P > 6 or spec.k_spine > 6:
        raise ValueError("exact solver is for tiny instances")

    # all matchings on P vertices (as lists of pairs)
    verts = list(range(P))
    matchings: List[Tuple[Tuple[int, int], ...]] = []

    def gen(avail: Tuple[int, ...], cur: Tuple[Tuple[int, int], ...]):
        matchings.append(cur)
        if len(avail) < 2:
            return
        a = avail[0]
        rest = avail[1:]
        for t, b in enumerate(rest):
            gen(rest[:t] + rest[t + 1 :], cur + ((a, b),))
        gen(rest, cur)  # leave `a` unmatched

    gen(tuple(verts), ())
    matchings = list(dict.fromkeys(matchings))

    cfg = OCSConfig(spec, num_groups=H)
    for h in range(H):
        best_assign: Optional[List[Tuple[Tuple[int, int], ...]]] = None
        best_links = -1

        def dfs(k: int, rem: np.ndarray, links: int, chosen):
            nonlocal best_assign, best_links
            ub = links + int(np.triu(rem, 1).sum())
            if ub <= best_links:
                return
            if k == spec.k_spine:
                if links > best_links:
                    best_links, best_assign = links, list(chosen)
                return
            for m in matchings:
                if any(rem[i, j] <= 0 for i, j in m):
                    continue
                rem2 = rem.copy()
                for i, j in m:
                    rem2[i, j] -= 1
                    rem2[j, i] -= 1
                dfs(k + 1, rem2, links + len(m), chosen + [m])

        dfs(0, C[h].astype(np.int64).copy(), 0, [])
        assert best_assign is not None
        for k, m in enumerate(best_assign):
            for i, j in m:
                cfg.x[h, k, i, j] = 1
                cfg.x[h, k, j, i] = 1
    cfg.validate()
    return ReconfigResult(cfg, C, time.perf_counter() - t0)


def helios_matching(
    spec: ClusterSpec,
    C: np.ndarray,
    old: Optional[OCSConfig] = None,
    mask=None,
) -> ReconfigResult:
    """Helios-style repeated max-weight matching, on Cross Wiring.

    For each even/odd OCS pair, extract a max-weight matching of the
    remaining (symmetric) demand via scipy's linear_sum_assignment on the
    demand matrix.  No optimality guarantee — included as the paper's
    'Helios' comparison point.  ``mask`` drops assigned circuits whose
    slots are failed (best-effort degradation, no clean-pair relocation).
    """
    if linear_sum_assignment is None:
        raise ImportError("scipy is required for Helios max-weight matching")
    t0 = time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    cfg = OCSConfig(spec, num_groups=H)
    K2 = spec.k_spine // 2
    for h in range(H):
        rem = C[h].astype(np.int64).copy()
        for t in range(K2):
            if mask is not None:
                a_even = mask.allowed(h, 2 * t)
                a_odd = mask.allowed(h, 2 * t + 1)
            w = rem.astype(np.float64)
            # maximize total weight of a directed sub-permutation
            rows, cols = linear_sum_assignment(-w)
            m = np.zeros((P, P), dtype=np.int8)
            for i, j in zip(rows, cols):
                if rem[i, j] > 0 and (
                    mask is None or (a_even[i, j] and a_odd[j, i])
                ):
                    m[i, j] = 1
            # keep symmetric consumption: even OCS carries m, odd carries mᵀ;
            # each unit consumes one bidirectional demand link.
            cfg.x[h, 2 * t] = m
            cfg.x[h, 2 * t + 1] = m.T
            rem -= np.minimum(rem, (m + m.T).astype(np.int64))
    cfg.validate(mask)
    return ReconfigResult(cfg, C, time.perf_counter() - t0)


# --------------------------------------------------------------------------
# ILP constraint checker (paper §3.2, constraints (1)–(6))
# --------------------------------------------------------------------------

def check_ilp_constraints(
    spec: ClusterSpec,
    C: np.ndarray,
    cfg: OCSConfig,
    topology: str = "cross_wiring",
    require_exact: bool = True,
    mask=None,
) -> None:
    """Assert the ILP model's constraints hold for ``cfg``.

    (1) Σ_k x_ijkh == C_ijh          (demand satisfaction; ``require_exact``)
    (2)(3) per-spine port budgets    (≤ K_spine egress/ingress)
    (4)(5) per-OCS sub-permutation
    (6) L2-compatibility             (Cross Wiring pairing / Uniform symmetry)

    ``mask`` additionally asserts degraded-mode feasibility: no circuit on
    a failed slot or through a drained/inactive pod.
    """
    x = cfg.x.astype(np.int64)
    realized = x.sum(axis=1)  # (H, P, P) directed circuits
    if require_exact:
        assert (realized == C).all(), "constraint (1): demand not met exactly"
    assert (x.sum(axis=(1, 3)) <= spec.k_spine).all(), "constraint (2)"
    assert (x.sum(axis=(1, 2)) <= spec.k_spine).all(), "constraint (3)"
    cfg.validate(mask)  # (4)(5) + masked slots
    if topology == "cross_wiring":
        assert CrossWiring(spec).l2_feasible(cfg), "constraint (6): pairing"
    else:
        assert Uniform(spec).l2_feasible(cfg), "constraint (6): symmetry"
