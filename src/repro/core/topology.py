"""Physical topology design for OCS-based LLM clusters (paper §3.1, §4.1).

Two physical topologies are modeled:

* :class:`CrossWiring` — the paper's contribution.  OCSes come in adjacent
  pairs ``(2k, 2k+1)`` inside each OCS group; the ingress wiring of a spine's
  port pair ``(2k, 2k+1)`` is *swapped* relative to the egress wiring, so the
  even sub-topology and the odd sub-topology are mirrored (transposes of each
  other).  Theorem 4.1: every symmetric, degree-feasible logical topology is
  realizable.

* :class:`Uniform` — the uniform bipartite design used by Gemini / Jupiter
  Evolving: both Tx and Rx of spine port ``k`` land on OCS ``k`` of the
  corresponding group.  Under the L2-compatibility constraint each OCS can
  only host a *symmetric matching* of pods, which makes some logical
  topologies unrealizable (paper Fig. 1).

Everything here is plain numpy — this is the cluster *control plane*.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Tuple

import numpy as np

__all__ = [
    "ClusterSpec",
    "PhysicalTopology",
    "CrossWiring",
    "Uniform",
    "OCSConfig",
]


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Key deployment-stage parameters of an OCS-based cluster (paper §2.1).

    Attributes
    ----------
    num_pods:
        ``P`` — number of pods.  Must satisfy ``P <= k_ocs``.
    k_spine:
        number of OCS-facing ports per spine (== OCSes per OCS group).
        Must be even (paper §3.1 assumption).
    k_leaf:
        number of spine-facing ports per leaf (== GPU-facing ports per leaf).
    tau:
        number of links between each (leaf, spine) pair inside a pod.
    k_ocs:
        number of ingress (= egress) ports per OCS; bounds the pod count.
    slowdown_cap:
        flow-model slowdown ceiling for starved cross-pod traffic: a flow
        whose OCS circuits are gone still progresses at ``1/slowdown_cap``
        of full rate over residual electrical paths.  ``None`` configures
        *zero* residual electrical capacity — a fully-dark circuit then
        stalls its flows outright (infinite slowdown) instead of quietly
        bottoming out at the cap.
    """

    num_pods: int
    k_spine: int = 8
    k_leaf: int = 8
    tau: int = 1
    k_ocs: int = 512
    slowdown_cap: Optional[float] = 4.0

    def __post_init__(self) -> None:
        if self.k_spine % 2:
            raise ValueError("K_spine must be even (paper assumes port pairing)")
        if self.slowdown_cap is not None and self.slowdown_cap < 1.0:
            raise ValueError("slowdown_cap must be >= 1 (or None for no floor)")
        if self.k_leaf % self.tau:
            raise ValueError("K_leaf must be divisible by tau")
        if self.num_pods > self.k_ocs:
            raise ValueError(
                f"Cross Wiring interconnects at most K_ocs={self.k_ocs} pods; "
                f"got P={self.num_pods}"
            )

    # ---- derived sizes (paper §3.1) -------------------------------------
    @property
    def leaves_per_pod(self) -> int:
        return self.k_spine // self.tau

    @property
    def spines_per_pod(self) -> int:
        return self.k_leaf // self.tau

    @property
    def gpus_per_pod(self) -> int:
        return self.k_spine * self.k_leaf // self.tau

    @property
    def num_gpus(self) -> int:
        return self.num_pods * self.gpus_per_pod

    @property
    def num_ocs_groups(self) -> int:
        # One OCS group per spine index h.
        return self.spines_per_pod

    @property
    def ocs_per_group(self) -> int:
        return self.k_spine


class OCSConfig:
    """A full OCS-layer configuration.

    ``x[h][k]`` is a ``P×P`` 0/1 matrix: ``x[h][k][i, j] == 1`` iff OCS ``k``
    of group ``h`` forwards the egress of pod ``i``'s spine ``h`` into the
    ingress of pod ``j``'s spine ``h`` (a directed optical circuit i→j).

    Feasibility per OCS: each pod has exactly one egress and one ingress port
    on each OCS it is wired to, so each ``x[h][k]`` must have row sums ≤ 1 and
    column sums ≤ 1 (a sub-permutation, ILP constraints (4)(5)).
    """

    def __init__(self, spec: ClusterSpec, num_groups: int | None = None):
        self.spec = spec
        self.num_groups = num_groups if num_groups is not None else spec.num_ocs_groups
        P, K = spec.num_pods, spec.ocs_per_group
        self.x = np.zeros((self.num_groups, K, P, P), dtype=np.int8)
        self._derived_cache: Dict[str, np.ndarray] = {}

    def copy(self) -> "OCSConfig":
        out = OCSConfig(self.spec, self.num_groups)
        out.x = self.x.copy()  # writable even when self is frozen
        return out

    # ---- derived-view cache ----------------------------------------------
    def freeze(self) -> "OCSConfig":
        """Mark ``x`` immutable and enable memoization of the derived views.

        Solvers freeze the configuration they emit (``ReconfigResult``
        does it), so the O(H·P²) reductions below are computed once per
        reconfiguration instead of on every slowdown re-evaluation in
        between.  Hand-built (unfrozen) configs keep recomputing fresh —
        mutate-after-read stays correct for them.  Rebuilding ``x`` on a
        frozen config requires ``invalidate_cache()`` (which re-opens it).
        """
        self.x.flags.writeable = False
        return self

    def invalidate_cache(self) -> None:
        """Drop memoized derived views and make ``x`` writable again."""
        self._derived_cache.clear()
        self.x = np.array(self.x)  # fresh writable buffer

    def _derived(self, key: str, fn) -> np.ndarray:
        if self.x.flags.writeable:
            return fn()  # mutable config: never cache
        out = self._derived_cache.get(key)
        if out is None:
            out = fn()
            out.flags.writeable = False
            self._derived_cache[key] = out
        return out

    def preseed_pair_capacity(self, C: np.ndarray) -> None:
        """Seed the ``pair_capacity`` cache from the demand an *exact*
        solver just realized (Thm 4.1: ``Σ_k x == C``), skipping the
        O(H·K·P²) reduction on every flow-model / ring-scoring read
        between reconfigurations.  Only meaningful on a frozen config;
        callers are the exact MDMCF paths.

        Deliberately seeds *only* ``pair_capacity`` (the slowdown
        re-evaluation hot path): ``realized``/``realized_bidirectional``
        — and therefore :func:`~repro.core.reconfig.ltrr` — keep reducing
        the raw emitted circuits, so the LTRR benchmarks still measure
        realization rather than echo the asserted invariant.
        """
        if self.x.flags.writeable:
            return
        # integer sum first, tiny float divide after — no float64 copy of C
        seed = np.asarray(C).sum(axis=0) / max(1, self.num_groups)
        seed.flags.writeable = False
        self._derived_cache["pair_capacity"] = seed

    # ---- realized logical topology ---------------------------------------
    def realized(self) -> np.ndarray:
        """Directed link counts ``R[h, i, j] = Σ_k x[h][k][i, j]``."""
        return self._derived("realized", lambda: self.x.sum(axis=1))

    def realized_bidirectional(self) -> np.ndarray:
        """Bidirectional (L2-compatible) link counts per (h, i, j).

        A *logical* L2 link i↔j needs one i→j circuit and one j→i circuit.
        The number of bidirectional links is min(R_ij, R_ji) directionwise;
        with symmetric R this is just R.
        """

        def _compute() -> np.ndarray:
            r = self.realized().astype(np.int64)
            return np.minimum(r, np.transpose(r, (0, 2, 1)))

        return self._derived("realized_bidirectional", _compute)

    def pair_capacity(self) -> np.ndarray:
        """Per-group-average bidirectional link capacity between pod pairs
        — the ``(P, P)`` matrix the flow model and ring scoring share."""

        def _compute() -> np.ndarray:
            r = self.realized_bidirectional().astype(np.float64)
            return r.sum(axis=0) / max(1, self.num_groups)

        return self._derived("pair_capacity", _compute)

    def validate(self, mask=None) -> None:
        """Assert per-OCS sub-permutation feasibility (constraints (4)(5)).

        With a :class:`~repro.fault.masks.PortMask` given, additionally
        assert that no circuit uses a failed slot or a drained/inactive
        pod (degraded-mode feasibility)."""
        if self.x.min() < 0 or self.x.max() > 1:
            raise AssertionError("x must be binary")
        if (self.x.sum(axis=3) > 1).any():
            raise AssertionError("some OCS row sum > 1 (egress port reused)")
        if (self.x.sum(axis=2) > 1).any():
            raise AssertionError("some OCS col sum > 1 (ingress port reused)")
        if mask is not None:
            mask.check_config(self.x)

    def rewiring_distance(self, other: "OCSConfig") -> int:
        """Min-Rewiring objective (eq. 7): Σ |x - u| (= Σ x≠u for 0/1 x)."""
        return int(np.count_nonzero(self.x != other.x))

    def changed_pairs(self, other: "OCSConfig") -> FrozenSet[Tuple[int, int]]:
        """Pod pairs ``(i, j)`` (i ≤ j) whose circuits differ from ``other``
        anywhere in the OCS layer — every pair touched by the retune,
        additions included.  Prefer :meth:`dark_pairs` for pricing the
        switching window: a pair that only *gains* circuits keeps its
        surviving capacity live while the new ports tune."""
        diff = (self.x != other.x).any(axis=(0, 1))
        diff |= diff.T
        ii, jj = np.nonzero(np.triu(diff))
        return frozenset(zip(ii.tolist(), jj.tolist()))

    def dark_pairs(self, other: "OCSConfig") -> FrozenSet[Tuple[int, int]]:
        """Pod pairs that carry zero bandwidth while this configuration is
        being switched in from ``other`` (the fluid engine's dark set).

        The unit that retunes is the *circuit* (an OCS port), not the pod
        pair: a circuit occupying the same slot in both configurations
        never goes down, and keeps its pair alive through the window
        (make-before-break at port granularity).  A pair is dark only
        when the new configuration routes over it and **no** circuit
        survives in place — every circuit it will carry is still tuning.
        Pairs that merely gain extra circuits, or lose some while others
        stay put, keep serving; so the fabric that tracks demand with
        incremental deltas (:mod:`~repro.core.incremental`) is not
        charged a dark window on capacity it was already serving.  Pairs
        the new configuration abandons entirely contribute zero capacity
        either way and are not in the set.
        """
        new_live = (self.x > 0).any(axis=(0, 1))
        new_live |= new_live.T
        survived = ((self.x > 0) & (other.x > 0)).any(axis=(0, 1))
        survived |= survived.T
        dark = new_live & ~survived
        ii, jj = np.nonzero(np.triu(dark))
        return frozenset(zip(ii.tolist(), jj.tolist()))


class PhysicalTopology:
    """Base class: a wiring between the spine layer and the OCS layer."""

    name = "abstract"

    def __init__(self, spec: ClusterSpec):
        self.spec = spec

    # Sub-classes define which directed circuits a single OCS may realize and
    # what the L2-compatibility constraint means for configurations.

    def l2_feasible(self, config: OCSConfig) -> bool:
        raise NotImplementedError


class CrossWiring(PhysicalTopology):
    """The paper's physical topology (§4.1).

    Port/OCS pairing: for even k, spine port pair ``(k, k+1)`` and OCS pair
    ``(k, k+1)`` in the same group are cross-connected:

    * egress of port k   → OCS k      ingress of port k+1 → OCS k
    * egress of port k+1 → OCS k+1    ingress of port k   → OCS k+1

    Consequence: if even OCS ``2t`` realizes the directed circuit set ``M``
    (a sub-permutation on pods) then the paired odd OCS ``2t+1`` attaches to
    the *same* spine port pairs mirrored, so realizing ``Mᵀ`` on it makes all
    circuits bidirectional at the port-pair granularity — L2 holds without
    constraining the *logical* matrix beyond symmetry.
    """

    name = "cross_wiring"

    def l2_feasible(self, config: OCSConfig) -> bool:
        """L2-compatibility (ILP eq. 6): odd OCS 2t+1 carries the transpose of
        even OCS 2t."""
        x = config.x
        even = x[:, 0::2]
        odd = x[:, 1::2]
        return bool((odd == np.transpose(even, (0, 1, 3, 2))).all())


class Uniform(PhysicalTopology):
    """Uniform bipartite wiring (Gemini / Jupiter Evolving; paper §2.3).

    Both Tx and Rx of spine port k land on OCS k, so a bidirectional logical
    link i↔j on OCS k consumes the full (ingress,egress) pair of pods i and j
    on that OCS: each per-OCS configuration must be a *symmetric matching*
    (x[h][k] symmetric with zero diagonal under L2).
    """

    name = "uniform"

    def l2_feasible(self, config: OCSConfig) -> bool:
        x = config.x
        sym = (x == np.transpose(x, (0, 1, 3, 2))).all()
        nodiag = (np.diagonal(x, axis1=2, axis2=3) == 0).all()
        return bool(sym and nodiag)


def demand_feasible(C: np.ndarray, spec: ClusterSpec, mask=None) -> bool:
    """Check logical-topology feasibility conditions (11)(12) of the paper.

    ``C`` has shape ``(H, P, P)`` with ``C[h, i, j]`` = # of bidirectional
    links between the h-th spines of pods i and j.

    With a :class:`~repro.fault.masks.PortMask`, the per-pod degree bound
    tightens from ``K_spine`` to the mask's degraded budget (clean OCS
    pairs only; zero for drained/inactive pods) — the feasibility regime
    the degraded-mode MDMCF realizes exactly (see ``repro.fault.recover``).
    """
    if C.ndim != 3:
        raise ValueError("C must have shape (H, P, P)")
    sym = (C == np.transpose(C, (0, 2, 1))).all()
    deg = C.sum(axis=2)  # (H, P) row sums
    if mask is not None:
        budget = mask.degree_budget()[: C.shape[0]]
    else:
        budget = spec.k_spine
    return bool(sym and (deg <= budget).all() and (C >= 0).all())
