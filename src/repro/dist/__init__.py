"""`repro.dist` — the distributed-communication subsystem.

Two halves bridging the data plane to the OCS control plane:

* :mod:`~repro.dist.sharding` — PartitionSpec rules for parameters, batches,
  caches and ZeRO-1 optimizer state (consumed by ``train.trainstep``).
* :mod:`~repro.dist.collectives` / :mod:`~repro.dist.demand` — the
  collective-communication planner: parallelism plan → explicit collective
  schedule (alpha-beta cost model) → pod×pod demand matrices → ring-ordering
  against the current OCS configuration.
"""
from .collectives import (
    AlphaBeta,
    Collective,
    MODEL_PROFILES,
    ModelProfile,
    collective_time,
    plan_collectives,
    schedule_time,
)
from .demand import (
    collectives_to_edges,
    comm_fraction_for,
    edges_to_matrix,
    job_edges,
    job_flow,
    kv_bytes_per_token,
    kv_flow,
    ring_order,
    serving_edges,
    uncoverable_fraction,
)
# sharding.py imports jax; the planner half (collectives/demand) and the
# simulator that consumes it are numpy-only.  Load sharding names lazily
# (PEP 562) so `repro.sim` / the benchmarks never pay the jax import.
_SHARDING_NAMES = frozenset(
    {
        "batch_specs",
        "cache_specs",
        "mesh_axis_sizes",
        "param_pspec",
        "param_specs",
        "shard_map_dp",
        "to_shardings",
        "zero1_dim",
        "zero1_specs",
    }
)


def __getattr__(name):
    if name in _SHARDING_NAMES:
        from . import sharding

        return getattr(sharding, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "AlphaBeta",
    "Collective",
    "MODEL_PROFILES",
    "ModelProfile",
    "batch_specs",
    "cache_specs",
    "collective_time",
    "collectives_to_edges",
    "comm_fraction_for",
    "edges_to_matrix",
    "job_edges",
    "job_flow",
    "kv_bytes_per_token",
    "kv_flow",
    "mesh_axis_sizes",
    "param_pspec",
    "param_specs",
    "plan_collectives",
    "ring_order",
    "schedule_time",
    "serving_edges",
    "shard_map_dp",
    "to_shardings",
    "uncoverable_fraction",
    "zero1_dim",
    "zero1_specs",
]
