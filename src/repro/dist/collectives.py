"""Collective-communication schedules with an alpha–beta cost model.

Given a job's parallelism plan (TP/EP in-pod, DP/PP cross-pod — the paper's
§3.1 containment policy), emit the explicit per-step collective schedule:

* ring all-reduce of gradients over the DP pods (or reduce-scatter +
  all-gather when ZeRO-1 shards the optimizer state),
* cross-pod all-to-all for MoE expert parallelism that spills out of a pod
  (expert footprint exceeding one pod's HBM),
* point-to-point activation transfers between adjacent PP stages,
* in-pod TP all-reduces (electrical fabric; never reach the OCS core).

Each collective's completion time follows the standard alpha–beta model
(e.g. ring all-reduce of ``b`` bytes over ``w`` ways: ``2(w-1)α +
2b(w-1)/w·β``).  ``demand.py`` lowers the cross-pod part of a schedule to
pod×pod demand matrices for the OCS control plane.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = [
    "AlphaBeta",
    "Collective",
    "MODEL_PROFILES",
    "ModelProfile",
    "collective_time",
    "plan_collectives",
    "schedule_time",
]

IN_POD = "in_pod"
CROSS_POD = "cross_pod"


@dataclasses.dataclass(frozen=True)
class AlphaBeta:
    """Per-fabric latency (s/hop) and inverse bandwidth (s/byte).

    Defaults: 400 Gb/s electrical in-pod links vs a single 100 Gb/s optical
    spine link cross-pod (a job stripes over several — ``links`` below).
    """

    alpha_in_pod: float = 2e-6
    beta_in_pod: float = 1.0 / 50e9  # 400 Gb/s
    alpha_cross_pod: float = 10e-6
    beta_cross_pod: float = 1.0 / 12.5e9  # 100 Gb/s per spine-level link


@dataclasses.dataclass(frozen=True)
class Collective:
    """One collective operation of a training step.

    ``bytes`` is the per-participant payload; ``ways`` the group size;
    ``rounds`` how many times per step it runs (PP microbatches, MoE
    layers); ``scope`` whether it rides the electrical or optical fabric.
    """

    kind: str  # all_reduce | reduce_scatter | all_gather | all_to_all | p2p
    scope: str  # in_pod | cross_pod
    bytes: float
    ways: int
    rounds: int = 1

    def __post_init__(self) -> None:
        if self.kind not in (
            "all_reduce", "reduce_scatter", "all_gather", "all_to_all", "p2p"
        ):
            raise ValueError(f"unknown collective kind {self.kind!r}")
        if self.ways < 1 or self.bytes < 0 or self.rounds < 1:
            raise ValueError("degenerate collective")


def collective_time(
    c: Collective, ab: AlphaBeta, links: int = 1, phi: float = 1.0
) -> float:
    """Completion time of one collective under the alpha–beta model.

    ``links`` stripes the payload over parallel spine-level links;
    ``phi`` ∈ (0, 1] is the realized bandwidth fraction of the worst edge
    (from the flow model) — bandwidth terms stretch by 1/φ, latency terms
    do not (the circuit exists, it is just thinner than requested).
    """
    if c.ways == 1 or c.bytes == 0:
        return 0.0
    if c.scope == IN_POD:
        # electrical fabric: no spine-link striping, always full rate
        alpha, beta = ab.alpha_in_pod, ab.beta_in_pod
    else:
        alpha, beta = ab.alpha_cross_pod, ab.beta_cross_pod
        beta = beta / max(1, links) / max(phi, 1e-9)
    w, b = c.ways, c.bytes
    if c.kind == "all_reduce":
        t = 2 * (w - 1) * alpha + 2 * b * (w - 1) / w * beta
    elif c.kind in ("reduce_scatter", "all_gather"):
        t = (w - 1) * alpha + b * (w - 1) / w * beta
    elif c.kind == "all_to_all":
        # each rank holds b bytes, sends (w-1)/w of it, one hop per peer
        t = (w - 1) * alpha + b * (w - 1) / w * beta
    else:  # p2p: one stage boundary transfer
        t = alpha + b * beta
    return t * c.rounds


def schedule_time(
    colls: List[Collective],
    ab: AlphaBeta,
    links: int = 1,
    phi_cross: float = 1.0,
) -> float:
    """Serial completion time of a schedule (collectives on the critical
    path; in-pod ones always run at full rate)."""
    t = 0.0
    for c in colls:
        phi = phi_cross if c.scope == CROSS_POD else 1.0
        t += collective_time(c, ab, links=links, phi=phi)
    return t


# ---------------------------------------------------------------------------
# model profiles for the multi-tenant trace (§6.3 workload)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Coarse per-model byte/compute profile driving the planner.

    ``grad_bytes``: full gradient size (bf16).  ``moe_tokens_bytes``: per
    all-to-all dispatch payload of one MoE layer (tokens × d_model × bf16 ×
    capacity).  ``pp_act_bytes``: activation tensor crossing one PP stage
    boundary per microbatch.  ``compute_s``: per-step compute time on the
    reference accelerator (calibrates the communication *fraction*).
    ``kv_bytes_per_token``: KV-cache footprint of one token
    (2 · layers · kv_heads · head_dim · dtype bytes) — the payload a
    disaggregated serving deployment migrates from prefill to decode pods
    per prompt token (see :func:`repro.dist.demand.kv_flow`).
    """

    grad_bytes: float
    compute_s: float
    layers: int
    moe: bool = False
    moe_layers: int = 0
    moe_tokens_bytes: float = 0.0
    # experts exceed one pod's HBM: the EP all-to-all must span the job's
    # pods (small-EP models keep it on the electrical fabric per §3.1)
    ep_spill: bool = False
    pp_act_bytes: float = 0.0
    kv_bytes_per_token: float = 0.0


# Trace models: dense LLaMA-family, MoE (pangu/gpt2 with EP=2 in the paper
# testbed; mixtral-class with wide EP), and a PP archetype for 70B-class
# jobs that pipeline across pods.
MODEL_PROFILES: Dict[str, ModelProfile] = {
    # kv_bytes_per_token = 2 · layers · kv_heads · head_dim · 2 B (bf16):
    # MHA for the 7B/13B-class models, GQA (8 kv heads) for mixtral/70B.
    "llama-7b": ModelProfile(
        14e9, 0.55, 32, pp_act_bytes=67e6, kv_bytes_per_token=524288.0
    ),
    "llama2-7b": ModelProfile(
        14e9, 0.55, 32, pp_act_bytes=67e6, kv_bytes_per_token=524288.0
    ),
    "llama2-13b": ModelProfile(
        26e9, 0.95, 40, pp_act_bytes=84e6, kv_bytes_per_token=819200.0
    ),
    "pangu-alpha-6b": ModelProfile(
        12e9, 0.50, 31, moe=True, moe_layers=8, moe_tokens_bytes=34e6,
        kv_bytes_per_token=507904.0,
    ),
    "gpt2-13b": ModelProfile(
        26e9, 0.90, 40, moe=True, moe_layers=10, moe_tokens_bytes=42e6,
        kv_bytes_per_token=819200.0,
    ),
    "mixtral-8x7b": ModelProfile(
        26e9, 0.70, 32, moe=True, moe_layers=32, moe_tokens_bytes=67e6,
        ep_spill=True, kv_bytes_per_token=131072.0,
    ),
    "llama2-70b": ModelProfile(
        140e9, 2.8, 80, pp_act_bytes=134e6, kv_bytes_per_token=327680.0
    ),
}


def plan_collectives(
    model: str,
    n_pods: int,
    tp: int = 8,
    ep: int = 1,
    pp: int = 1,
    zero1: bool = False,
    dp_cross: bool = True,
    profile: Optional[ModelProfile] = None,
) -> List[Collective]:
    """Explicit collective schedule of one training step.

    ``n_pods`` is the number of pods the job's cross-pod groups span.  EP
    spillover: an ``ep > 1`` job whose experts do not fit one pod runs its
    dispatch/combine all-to-all across *all* its pods (dense pairwise
    traffic — the pattern Cross Wiring realizes and Uniform cannot).  PP
    splits the DP ring per stage: gradient bytes divide by ``pp`` and each
    microbatch crosses ``pp - 1`` stage boundaries.  ``dp_cross=False``
    keeps the gradient ring on the electrical fabric (DP replicas fit
    in-pod; only EP/PP traffic reaches the OCS core).
    """
    prof = profile if profile is not None else MODEL_PROFILES.get(model)
    if prof is None:
        prof = ModelProfile(14e9, 0.55, 32)
    out: List[Collective] = []

    # TP: two all-reduces (attention + MLP) per layer, in-pod electrical.
    if tp > 1:
        out.append(
            Collective(
                "all_reduce", IN_POD,
                bytes=prof.pp_act_bytes or 67e6,
                ways=tp, rounds=2 * prof.layers,
            )
        )

    # DP gradient reduction across pods (per PP stage).
    if n_pods > 1 and dp_cross:
        g = prof.grad_bytes / max(1, pp)
        if zero1:
            out.append(Collective("reduce_scatter", CROSS_POD, g, n_pods))
            out.append(Collective("all_gather", CROSS_POD, g, n_pods))
        else:
            out.append(Collective("all_reduce", CROSS_POD, g, n_pods))
    elif not dp_cross:
        out.append(
            Collective("all_reduce", IN_POD, prof.grad_bytes, max(2, tp))
        )

    # MoE EP: dispatch + combine all-to-all per MoE layer.  Stays on the
    # electrical fabric while the experts fit a pod (§3.1 containment);
    # only footprint spillover (profile flag) sends it across the OCS.
    if prof.moe and ep > 1:
        spill = prof.ep_spill and n_pods > 1
        scope = CROSS_POD if spill else IN_POD
        ways = n_pods if spill else ep
        out.append(
            Collective(
                "all_to_all", scope, prof.moe_tokens_bytes,
                ways=max(2, ways), rounds=2 * max(1, prof.moe_layers),
            )
        )

    # PP: activations (fwd) + activation grads (bwd) per microbatch chain.
    if pp > 1 and n_pods > 1:
        micro = 2 * pp  # standard 1F1B fill: ~2·pp microbatches in flight
        out.append(
            Collective(
                "p2p", CROSS_POD, prof.pp_act_bytes or 67e6,
                ways=min(pp, n_pods), rounds=2 * micro * (pp - 1),
            )
        )
    return out
