"""Lower collective schedules to pod×pod OCS demand; ring-order pods.

The bridge between the data plane and the control plane: a job's cross-pod
collectives (from :mod:`~repro.dist.collectives`) become spine-level link
demand between the pods it occupies —

* ring collectives (all-reduce / reduce-scatter / all-gather) → ring edges,
* MoE EP all-to-all → dense pairwise edges (the pattern Theorem 4.1 lets
  Cross Wiring realize and Uniform cannot),
* PP point-to-point → an open chain over the stage pods.

The per-job link budget is split over the job's cross-pod collectives in
proportion to their byte volume, and :func:`ring_order` permutes the pods
so the ring lands on the best-provisioned pairs of the *current* OCS
configuration (minimizing uncoverable demand before any reconfiguration).
"""
from __future__ import annotations

import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.logical import ring_pairs
from .collectives import (
    AlphaBeta,
    CROSS_POD,
    Collective,
    MODEL_PROFILES,
    collective_time,
    plan_collectives,
)

__all__ = [
    "clip_feasible",
    "collectives_to_edges",
    "comm_fraction_for",
    "edges_to_matrix",
    "job_edges",
    "job_flow",
    "kv_bytes_per_token",
    "kv_flow",
    "serving_edges",
    "ring_order",
    "uncoverable_fraction",
]

Edges = Dict[Tuple[int, int], int]

_RING_KINDS = ("all_reduce", "reduce_scatter", "all_gather")


def _add(edges: Edges, i: int, j: int, links: int) -> None:
    if i == j or links <= 0:
        return
    e = (min(i, j), max(i, j))
    edges[e] = edges.get(e, 0) + links


def _volume(c: Collective) -> float:
    """Bandwidth-seconds of a collective at β=1 — the link-split weight."""
    return collective_time(
        c, AlphaBeta(alpha_in_pod=0.0, beta_in_pod=1.0,
                     alpha_cross_pod=0.0, beta_cross_pod=1.0)
    )


def collectives_to_edges(
    colls: Sequence[Collective], pods: Sequence[int], links: int
) -> Edges:
    """Cross-pod collectives → symmetric edge demand over ``pods``.

    ``pods`` is the (ring-ordered) pod sequence; ``links`` the per-hop
    budget the job may claim (its share of each pod's spine ports), split
    across collectives in proportion to byte volume.
    """
    edges: Edges = {}
    n = len(pods)
    if n < 2 or links <= 0:
        return edges
    cross = [c for c in colls if c.scope == CROSS_POD and c.ways > 1]
    if not cross:
        return edges
    vols = np.array([_volume(c) for c in cross], dtype=np.float64)
    total = vols.sum()
    shares = vols / total if total > 0 else np.full(len(cross), 1.0 / len(cross))
    # largest-remainder apportionment: per-hop budgets sum to exactly
    # ``links`` so a multi-collective job never claims more than its share
    quotas = shares * links
    budgets = np.floor(quotas).astype(np.int64)
    order = np.argsort(-(quotas - budgets), kind="stable")
    for idx in order[: links - int(budgets.sum())]:
        budgets[idx] += 1
    for c, budget in zip(cross, budgets):
        budget = int(budget)
        if budget <= 0:
            continue  # below one link of the job's share: not provisioned
        if c.kind in _RING_KINDS:
            for i, j in ring_pairs(list(pods)):
                _add(edges, i, j, budget)
        elif c.kind == "all_to_all":
            # spread the ring degree budget (2·links) over all n-1 peers
            per_pair = max(1, int(round(2 * budget / (n - 1))))
            for a, b in itertools.combinations(pods, 2):
                _add(edges, a, b, per_pair)
        else:  # p2p chain: stage boundaries, no wrap-around
            stages = min(c.ways, n)
            for t in range(stages - 1):
                _add(edges, pods[t], pods[t + 1], budget)
    return edges


def job_edges(
    model: str,
    pods: Sequence[int],
    links: int,
    ep: int = 1,
    pp: int = 1,
    tp: int = 8,
    zero1: bool = False,
) -> Edges:
    """Planner demand of one job: schedule → edges over its ordered pods."""
    colls = plan_collectives(
        model, len(pods), tp=tp, ep=ep, pp=pp, zero1=zero1
    )
    return collectives_to_edges(colls, pods, links)


def job_flow(
    model: str,
    pods: Sequence[int],
    links: int,
    ep: int = 1,
    pp: int = 1,
    tp: int = 8,
    zero1: bool = False,
) -> Tuple[Edges, float]:
    """One job's planner demand as a fluid-flow payload: ``(edges, α)``.

    The bridge the flow engines consume — ``edges`` feed
    :class:`repro.sim.fluid.Flow` / :class:`repro.sim.flowsim.JobFlows`
    and α is the cross-pod communication fraction the slowdown model
    stretches by 1/φ.  Both derive from the same planned schedule, so a
    caller can never pair mismatched demand and fraction.
    """
    edges = job_edges(model, pods, links, ep=ep, pp=pp, tp=tp, zero1=zero1)
    alpha = comm_fraction_for(
        model, len(pods), ep=ep, pp=pp, links=max(1, links), tp=tp
    )
    return edges, alpha


def edges_to_matrix(edges: Edges, num_pods: int, num_groups: int = 1) -> np.ndarray:
    """Edge dict → symmetric ``(H, P, P)`` logical-topology demand."""
    C = np.zeros((num_groups, num_pods, num_pods), dtype=np.int64)
    for (i, j), links in edges.items():
        C[:, i, j] += links
        C[:, j, i] += links
    return C


def clip_feasible(C: np.ndarray, k_spine: int) -> np.ndarray:
    """Copy of ``C`` shaved until it satisfies the degree constraint
    (paper eq. 12), via the shared :func:`core.logical.shave_to_budget`."""
    from ..core.logical import shave_to_budget

    C = C.copy()
    budget = np.full(C.shape[1], k_spine, dtype=np.int64)
    for h in range(C.shape[0]):
        shave_to_budget(C[h], budget)
    return C


# ---------------------------------------------------------------------------
# inference serving: prefill → decode KV-cache migration demand
# ---------------------------------------------------------------------------

# KV caches are stored in the compute dtype; demand.py stays numpy-only, so
# map dtype names by hand (np.dtype("bfloat16") does not exist).
_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


def kv_bytes_per_token(model) -> float:
    """KV-cache bytes one generated-context token occupies — the payload a
    disaggregated serving deployment streams from a prefill pod to a decode
    pod per prompt token.

    ``model`` is either a trace-model name (looked up in
    :data:`~repro.dist.collectives.MODEL_PROFILES`) or a
    :class:`~repro.models.config.ModelConfig`-like object with
    ``num_layers`` / ``num_kv_heads`` / ``head_dim`` / ``compute_dtype``
    attributes.  For GQA/MHA attention the per-layer footprint is the
    textbook ``2 (K and V) · kv_heads · head_dim · dtype`` bytes; MLA
    caches the compressed latent instead (``kv_lora_rank +
    qk_rope_head_dim``), and non-attention layers (mamba/rwkv blocks of a
    hybrid pattern) contribute nothing — their state does not grow with
    context.  The result matches what
    :meth:`repro.serve.engine.ServeEngine.comm_profile` measures off the
    real cache pytree (``tests/test_serving.py``).

    >>> kv_bytes_per_token("mixtral-8x7b")  # 2 · 32 · 8 · 128 · 2 B
    131072.0
    """
    if isinstance(model, str):
        prof = MODEL_PROFILES.get(model)
        return float(prof.kv_bytes_per_token) if prof is not None else 0.0
    cfg = model
    dtype_bytes = _DTYPE_BYTES.get(str(cfg.compute_dtype), 2)
    pattern = getattr(cfg, "block_pattern", None)
    if pattern:
        attn_layers = sum(
            1 for i in range(cfg.num_layers)
            if pattern[i % len(pattern)] == "attn"
        )
    else:
        attn_layers = cfg.num_layers if cfg.attn_kind != "none" else 0
    if getattr(cfg, "attn_kind", "gqa") == "mla" and cfg.mla is not None:
        per_layer = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    else:
        per_layer = 2 * cfg.num_kv_heads * cfg.head_dim
    return float(attn_layers * per_layer * dtype_bytes)


def kv_flow(
    model,
    prefill_pods: Sequence[int],
    decode_pods: Sequence[int],
    links: int,
    req_rate: float,
    kv_tokens: int,
    link_bw: float = 12.5e9,
    weights: Optional[Dict[int, float]] = None,
) -> Edges:
    """Prefill→decode KV migration demand as bipartite pod-pair edges.

    A disaggregated serving job computes prompt KV on ``prefill_pods`` and
    streams it to ``decode_pods`` — short, latency-critical transfers of
    ``kv_tokens · kv_bytes_per_token(model)`` bytes per request, arriving
    at ``req_rate`` requests/s.  The *offered* load in bytes/s is
    converted to spine-level links (``link_bw`` bytes/s each, the
    100 Gb/s default of :class:`~repro.dist.collectives.AlphaBeta`) and
    spread evenly over the ``|prefill| × |decode|`` pairs, at least one
    link per pair and at most ``links`` (the per-pair port budget) each.
    Deliberately *not* shaved to the pod degree budget here: the edges
    state what the load needs, and when a hot fleet over-subscribes its
    ports the control plane's demand clipping + max-min water-filling
    turn the shortfall into φ < 1 — i.e. proportionally stretched
    transfer latency, the fluid proxy for queueing delay.  Pools sharing
    a pod exchange KV over the in-pod electrical fabric — those pairs
    never reach the OCS and are skipped.

    ``weights`` (router-shaped demand, :mod:`repro.serve.router`) skews
    the spread by decode pod: pod ``d`` draws links in proportion to
    ``weights[d]`` — the share of requests a topology-aware router sends
    it — with at least one link per pair while its weight is positive,
    and *no* circuits at all when it is zero (a cordoned pod).  ``None``
    keeps the legacy even spread bit-for-bit.

    >>> kv_flow("llama2-13b", [0], [1, 2], 16, 60.0, 2048,
    ...         weights={1: 3.0, 2: 1.0})
    {(0, 1): 7, (0, 2): 2}
    """
    pre = [p for p in prefill_pods]
    dec = [p for p in decode_pods]
    edges: Edges = {}
    pairs = [(p, d) for p in pre for d in dec if p != d]
    if not pairs or links <= 0:
        return edges
    bytes_per_s = req_rate * kv_tokens * kv_bytes_per_token(model)
    need = int(np.ceil(bytes_per_s / link_bw)) if bytes_per_s > 0 else 0
    if weights is None:
        per_pair = min(links, max(1, int(round(need / len(pairs)))))
        for p, d in pairs:
            _add(edges, p, d, per_pair)
        return edges
    total_w = sum(max(0.0, weights.get(d, 1.0)) for d in dec)
    if total_w <= 0.0:
        total_w = 1.0
    for d in dec:
        w = max(0.0, weights.get(d, 1.0))
        pre_d = [p for p in pre if p != d]
        if not pre_d or w <= 0.0:
            continue
        per_pair = min(
            links, max(1, int(round(need * (w / total_w) / len(pre_d))))
        )
        for p in pre_d:
            _add(edges, p, d, per_pair)
    return edges


def serving_edges(
    model,
    prefill_pods: Sequence[int],
    decode_pods: Sequence[int],
    links: int,
    req_rate: float,
    kv_tokens: int,
    link_bw: float = 12.5e9,
    weights: Optional[Dict[int, float]] = None,
) -> Edges:
    """Full cross-pod demand of one disaggregated serving fleet.

    The KV migration stream (:func:`kv_flow`), plus — for MoE models
    whose experts spill out of a pod (``ModelProfile.ep_spill``) — the
    decode pool's expert-parallel dispatch/combine all-to-all: every
    decode step scatters tokens to the experts' pods, a clique over the
    decode pool carrying the same per-pair intensity as the KV stream.
    That clique is the serving twin of the training MoE-EP pattern: the
    demand Theorem 4.1 lets Cross Wiring realize exactly and a
    symmetric-matching fabric (Uniform/Helios) cannot.
    """
    edges = kv_flow(
        model, prefill_pods, decode_pods, links, req_rate, kv_tokens,
        link_bw=link_bw, weights=weights,
    )
    prof = MODEL_PROFILES.get(model) if isinstance(model, str) else None
    if (
        prof is not None and prof.moe and prof.ep_spill
        and len(decode_pods) >= 2
    ):
        stripe = max(edges.values(), default=1)
        for a, b in itertools.combinations(sorted(decode_pods), 2):
            _add(edges, a, b, stripe)
    return edges


# ---------------------------------------------------------------------------
# topology-aware ring ordering
# ---------------------------------------------------------------------------

def _ring_uncovered(order: Sequence[int], cap: np.ndarray, links: int) -> float:
    """Links of the ring's demand the capacity matrix cannot carry."""
    want: Edges = {}
    for i, j in ring_pairs(list(order)):
        _add(want, i, j, links)
    return float(
        sum(max(0.0, w - cap[i, j]) for (i, j), w in want.items())
    )


def uncoverable_fraction(edges: Edges, config) -> float:
    """Share of demanded links the realized configuration cannot carry."""
    total = sum(edges.values())
    if not total:
        return 0.0
    cap = config.pair_capacity()
    short = sum(max(0.0, w - cap[i, j]) for (i, j), w in edges.items())
    return float(short) / float(total)


@functools.lru_cache(maxsize=16)
def _cyclic_perm_indices(n: int) -> np.ndarray:
    """Index array of all cyclic orders over ``n`` sorted pods, first pod
    pinned and mirror images dropped — ``((n-1)!/2, n)``, cached per n."""
    perms = np.array(list(itertools.permutations(range(1, n))), dtype=np.int64)
    perms = perms[perms[:, 0] < perms[:, -1]]  # skip mirror-image rings
    return np.concatenate(
        [np.zeros((perms.shape[0], 1), dtype=np.int64), perms], axis=1
    )


def ring_order(
    pods: Sequence[int],
    config=None,
    links: int = 1,
    exhaustive_limit: int = 8,
) -> Tuple[int, ...]:
    """Order a job's pods so its DP ring minimizes uncoverable demand.

    Deterministic, and never worse than the sorted baseline: the sorted
    order is always in the candidate set and ties break toward it.  With no
    configuration yet (cold start) the sorted order is returned unchanged.
    Small rings are solved exactly (cyclic permutations modulo rotation and
    reflection, scored in one vectorized pass over the capacity matrix);
    larger ones greedily chain best-provisioned pairs.
    """
    base = tuple(sorted(pods))
    n = len(base)
    if config is None or n <= 3:
        return base  # n ≤ 3: all cyclic orders are the same ring
    cap = config.pair_capacity()

    if n <= exhaustive_limit:
        # identity is the first permutation, so base is always candidate 0
        cands = np.asarray(base, dtype=np.int64)[_cyclic_perm_indices(n)]
    else:
        # greedy: start at the lowest pod id, repeatedly hop to the
        # remaining pod with the fattest realized pipe
        left = list(base[1:])
        order = [base[0]]
        while left:
            cur = order[-1]
            nxt = max(left, key=lambda q: (cap[cur, q], -q))
            left.remove(nxt)
            order.append(nxt)
        cands = np.stack([np.asarray(base), np.asarray(order)])

    hops_from = cands
    hops_to = np.roll(cands, -1, axis=1)
    unc = np.maximum(0.0, links - cap[hops_from, hops_to]).sum(axis=1)
    # min over (uncovered, is-not-base, lexicographic), base is candidate 0
    sel = np.nonzero(unc == unc.min())[0]
    if sel[0] == 0:
        return base
    return tuple(min(map(tuple, cands[sel])))


# ---------------------------------------------------------------------------
# planner-derived communication fractions (replaces trace.COMM_FRACTION)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def comm_fraction_for(
    model: str,
    n_pods: int,
    ep: int = 1,
    pp: int = 1,
    links: int = 4,
    tp: int = 8,
) -> float:
    """Cross-pod communication fraction of a step on the ideal fabric.

    α = t_cross / (t_compute + t_in_pod + t_cross) from the alpha–beta
    costs of the job's planned schedule — the quantity the flow model
    stretches by 1/φ.  Unknown models fall back to a dense-7B profile.
    """
    prof = MODEL_PROFILES.get(model)
    ab = AlphaBeta()
    colls = plan_collectives(model, n_pods, tp=tp, ep=ep, pp=pp)
    t_cross = sum(
        collective_time(c, ab, links=max(1, links))
        for c in colls
        if c.scope == CROSS_POD
    )
    t_in = sum(
        collective_time(c, ab) for c in colls if c.scope != CROSS_POD
    )
    compute = prof.compute_s if prof is not None else 0.55
    denom = compute + t_in + t_cross
    if denom <= 0:
        return 0.0
    return float(min(0.95, t_cross / denom))
