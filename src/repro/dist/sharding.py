"""Sharding-spec rules for the paper's mesh (§3.1 containment policy).

The mesh axes mirror the cluster: ``model`` is the intra-pod electrical
domain (TP/EP), ``data``/``pod`` carry data parallelism across the OCS core.
Specs are derived *by name and shape*, never by architecture: every init
function in ``repro.models`` uses a small stable vocabulary of leaf names
(``wq``/``wk``/``wv``/``wi``/``wg`` column-parallel, ``wo``/``out_proj``/…
row-parallel, MoE expert stacks), so one rule set covers all 10 registered
architectures.

Divisibility is checked per leaf: a dim that does not divide the axis size
degrades to replicated — a poor layout is acceptable, a compile error is not.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.mesh import dp_axes, mesh_axis_sizes

__all__ = [
    "_path_str",
    "batch_specs",
    "cache_specs",
    "mesh_axis_sizes",
    "param_pspec",
    "param_specs",
    "shard_map_dp",
    "to_shardings",
    "zero1_dim",
    "zero1_specs",
]

# weights whose *input* dim is the sharded matmul dim (Megatron row-parallel:
# output projections, low-rank up-projections back to d_model)
_ROW_PARALLEL = frozenset(
    {"wo", "out_proj", "dt_proj", "ts_b", "w_b", "w2"}
)
# MoE expert-stacked weights: the leading expert dim rides the ``model`` axis
# (EP shares the in-pod electrical fabric with TP, configs/common.py)
_EXPERT_STACKED = frozenset({"wi", "wg", "wo"})


def _path_str(path) -> str:
    """tree_flatten_with_path key → 'units/l0/mix/wq' (test vocabulary)."""
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    )


def param_pspec(
    key: str, shape: Tuple[int, ...], model: int, is_moe: bool
) -> P:
    """PartitionSpec of one parameter leaf for a ``model``-wide TP axis.

    ``key`` is the '/'-joined tree path; ``shape`` the *global* (possibly
    layer-stacked) shape.  Exactly one dim is sharded: the expert dim for
    MoE expert stacks, the input dim for row-parallel weights, the output
    dim otherwise.  Indivisible candidates degrade to replicated.
    """
    nd = len(shape)
    spec = [None] * nd
    if nd < 2 or model <= 0:
        return P(*spec)
    parts = key.split("/")
    leaf = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""

    def ok(dim: int) -> bool:
        return shape[dim] > 0 and shape[dim] % model == 0

    # MoE expert stacks are 4-D when layer-stacked: (units, E, in, out)
    if is_moe and leaf in _EXPERT_STACKED and parent == "ffn" and nd >= 4:
        if ok(nd - 3):
            spec[nd - 3] = "model"
            return P(*spec)

    if leaf in _ROW_PARALLEL or (leaf == "wv" and parent == "ffn"):
        cand = nd - 2  # rwkv channel-mix wv is (d_ff, d): row-parallel
    else:
        cand = nd - 1
    if ok(cand):
        spec[cand] = "model"
    return P(*spec)


def param_specs(params: Any, mesh, cfg, fsdp: bool = False):
    """Spec tree for a parameter (or same-shaped moment) pytree.

    With ``fsdp`` the ZeRO-3 layout additionally shards each leaf over the
    DP axes on a dim the TP rule left replicated.
    """
    sizes = mesh_axis_sizes(mesh)
    model = sizes.get("model", 1)
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= sizes[a]
    is_moe = getattr(cfg, "moe", None) is not None

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        key = _path_str(path)
        shape = tuple(leaf.shape)
        base = list(param_pspec(key, shape, model, is_moe))
        if fsdp and dp:
            d = zero1_dim(key, shape, model, dp_total, is_moe)
            if d is not None:
                base[d] = dp if len(dp) > 1 else dp[0]
        specs.append(P(*base))
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero1_dim(
    key: str,
    shape: Tuple[int, ...],
    model: int,
    data: int,
    is_moe: bool,
) -> Optional[int]:
    """Scatter dim for ZeRO-1: the first dim the TP spec leaves replicated
    that divides the DP width.  ``None`` → the leaf stays replicated (the
    optimizer update is redundantly computed, never wrong)."""
    if data <= 0:
        return None
    base = param_pspec(key, shape, model, is_moe)
    padded = list(base) + [None] * (len(shape) - len(base))
    for d, size in enumerate(shape):
        if padded[d] is None and size > 0 and size % data == 0:
            return d
    return None


def zero1_specs(moments: Any, mesh, cfg, use_pod: bool = False):
    """Spec tree for fp32 optimizer moments sharded over DP (ZeRO-1).

    ``use_pod`` additionally spreads the scatter dim over the ``pod`` axis
    (the ZeRO-3/fsdp layout, where the moments are the HBM bottleneck)."""
    sizes = mesh_axis_sizes(mesh)
    model = sizes.get("model", 1)
    axes: Tuple[str, ...] = ("data",) if "data" in sizes else ()
    if use_pod and "pod" in sizes:
        axes = axes + ("pod",)
    total = 1
    for a in axes:
        total *= sizes[a]
    is_moe = getattr(cfg, "moe", None) is not None

    flat, treedef = jax.tree_util.tree_flatten_with_path(moments)
    specs = []
    for path, leaf in flat:
        key = _path_str(path)
        shape = tuple(leaf.shape)
        base = list(param_pspec(key, shape, model, is_moe))
        if axes:
            d = zero1_dim(key, shape, model, total, is_moe)
            if d is not None:
                base[d] = axes if len(axes) > 1 else axes[0]
        specs.append(P(*base))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch: Dict[str, Any], mesh):
    """Batch leaves shard dim 0 over the DP axes when divisible."""
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    total = 1
    for a in dp:
        total *= sizes[a]

    def spec(leaf) -> P:
        shape = tuple(leaf.shape)
        if (
            dp
            and len(shape) >= 1
            and shape[0] > 0
            and shape[0] % total == 0
        ):
            return P(dp if len(dp) > 1 else dp[0])
        return P()

    return jax.tree_util.tree_map(spec, batch)


def cache_specs(cache: Any, mesh, cfg, seq_shard: bool = False):
    """KV/state cache specs: batch dim over DP, heads (or head_dim) over
    ``model``.  Layer-stacked entries carry a leading units dim, so the
    batch dim is index 1 for rank ≥ 4 leaves and index 0 otherwise.
    ``seq_shard`` (long-context, batch=1 cells) moves the DP sharding to
    the sequence/state dim instead of the batch dim."""
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    model = sizes.get("model", 1)
    total = 1
    for a in dp:
        total *= sizes[a]

    def spec(leaf) -> P:
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd == 0:
            return P()
        out = [None] * nd
        bdim = 1 if nd >= 4 else 0
        if seq_shard and bdim + 1 < nd:
            bdim = bdim + 1
        if dp and shape[bdim] > 1 and shape[bdim] % total == 0:
            out[bdim] = dp if len(dp) > 1 else dp[0]
        if model > 1 and nd >= 2:
            for d in (nd - 2, nd - 1):
                if d != bdim and shape[d] > 0 and shape[d] % model == 0:
                    out[d] = "model"
                    break
        return P(*out)

    return jax.tree_util.tree_map(spec, cache)


def to_shardings(spec_tree: Any, mesh):
    """PartitionSpec tree → NamedSharding tree on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def shard_map_dp(f, mesh, in_specs, out_specs, manual_axes: Sequence[str]):
    """shard_map manual over ``manual_axes`` with the rest auto (GSPMD).

    Bridges the two jax APIs: ``jax.shard_map(..., axis_names=, check_vma=)``
    (jax ≥ 0.6) and ``jax.experimental.shard_map.shard_map(..., auto=,
    check_rep=)`` (jax 0.4.x, the pinned toolchain)."""
    manual = set(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names=manual,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
        auto=auto,
    )
