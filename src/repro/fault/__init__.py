"""Failure, repair & elastic-expansion resilience for the OCS cluster.

``masks``     — :class:`PortMask`: which slots/OCSes/pods are usable now,
plus the fractional per-link health layer gray failures derate.
``model``     — MTBF/MTTR renewal processes → timestamped event streams.
``chaos``     — scripted *correlated* and *gray* failure injection
(top-of-pod bursts, SRLG cuts, flapping/derated links).
``recover``   — degraded-mode demand clipping + recovery-policy cost models.
``remediate`` — the closed-loop :class:`RemediationEngine` mapping health
detections to actions (cordon, drain, pre-emptive checkpoint, solver
escalation) with hysteresis and budgets.

The degraded-mode solvers themselves live with their healthy-path twins in
``repro.core.reconfig`` (``mask=`` parameter); the event-driven scheduler
(``repro.sim.scheduler``) consumes the event streams and exposes the
actuators the remediation engine drives.
"""
from .chaos import (
    ChaosScenario,
    flapping_link,
    gray_derate,
    scenario_events,
    shared_risk_group,
    standard_scenarios,
    top_of_pod_burst,
)
from .masks import PortMask
from .model import (
    DerateEvent,
    ExpandEvent,
    FailureEvent,
    FaultEvent,
    FaultModel,
    RepairEvent,
    apply_event,
    merge_events,
)
from .recover import (
    CHEAPEST,
    CKPT_RESTART,
    POLICIES,
    REWIRE_AROUND,
    SHRINK_COLLECTIVE,
    checkpoint_bytes,
    ckpt_write_s,
    degrade_demand,
    masked_aggregate_demand,
    mdmcf_degraded,
    policy_costs,
    restart_cost_s,
    rollback_loss,
)
from .remediate import RemediationEngine

__all__ = [
    "CHEAPEST",
    "CKPT_RESTART",
    "ChaosScenario",
    "DerateEvent",
    "ExpandEvent",
    "FailureEvent",
    "FaultEvent",
    "FaultModel",
    "POLICIES",
    "PortMask",
    "REWIRE_AROUND",
    "RemediationEngine",
    "RepairEvent",
    "SHRINK_COLLECTIVE",
    "apply_event",
    "checkpoint_bytes",
    "ckpt_write_s",
    "degrade_demand",
    "flapping_link",
    "gray_derate",
    "masked_aggregate_demand",
    "mdmcf_degraded",
    "merge_events",
    "policy_costs",
    "restart_cost_s",
    "rollback_loss",
    "scenario_events",
    "shared_risk_group",
    "standard_scenarios",
    "top_of_pod_burst",
]
