"""Failure, repair & elastic-expansion resilience for the OCS cluster.

``masks``   — :class:`PortMask`: which slots/OCSes/pods are usable now.
``model``   — MTBF/MTTR renewal processes → timestamped event streams.
``recover`` — degraded-mode demand clipping + recovery-policy cost models.

The degraded-mode solvers themselves live with their healthy-path twins in
``repro.core.reconfig`` (``mask=`` parameter); the event-driven scheduler
(``repro.sim.scheduler``) consumes the event streams.
"""
from .masks import PortMask
from .model import (
    ExpandEvent,
    FailureEvent,
    FaultEvent,
    FaultModel,
    RepairEvent,
    apply_event,
    merge_events,
)
from .recover import (
    CHEAPEST,
    CKPT_RESTART,
    POLICIES,
    REWIRE_AROUND,
    SHRINK_COLLECTIVE,
    checkpoint_bytes,
    degrade_demand,
    masked_aggregate_demand,
    mdmcf_degraded,
    policy_costs,
    restart_cost_s,
    rollback_loss,
)

__all__ = [
    "CHEAPEST",
    "CKPT_RESTART",
    "ExpandEvent",
    "FailureEvent",
    "FaultEvent",
    "FaultModel",
    "POLICIES",
    "PortMask",
    "REWIRE_AROUND",
    "RepairEvent",
    "SHRINK_COLLECTIVE",
    "apply_event",
    "checkpoint_bytes",
    "degrade_demand",
    "masked_aggregate_demand",
    "mdmcf_degraded",
    "merge_events",
    "policy_costs",
    "restart_cost_s",
    "rollback_loss",
]
