"""Correlated and gray failure injection: scripted chaos scenarios.

:mod:`repro.fault.model` draws *independent* renewal processes — every
transceiver, OCS, and pod fails on its own clock.  Real optical plants
do not fail that politely (ROADMAP item 5): a top-of-pod OCS power
domain takes several switches of a spine group down *together*, links
sharing a conduit are cut by the same excavator, and transceivers
rarely die cleanly — they *flap* (bounce between up and down on a
timescale of minutes) or run *gray* (alive, but carrying a fraction of
nominal bandwidth).  This module scripts exactly those shapes as
deterministic event streams over the same :class:`FailureEvent` /
:class:`RepairEvent` / :class:`DerateEvent` vocabulary, so they compose
with the independent background model via
:func:`~repro.fault.model.merge_events` and drive the simulator
unchanged.

A :class:`ChaosScenario` is the declarative spec (burst size =
correlation radius inside the spine group, flap period/duty, derate
health, horizon); :func:`scenario_events` compiles it.  Any randomness
(repair staggering) comes from a generator constructed from the
scenario's own explicit seed — same hygiene as
:meth:`~repro.fault.model.FaultModel.sample`.

>>> sc = ChaosScenario(name="demo", horizon_s=100.0, burst_at_s=10.0,
...                    burst_size=2, burst_repair_s=30.0)
>>> evs = scenario_events(sc, k_spine=8)
>>> [(e.time, type(e).__name__, e.k) for e in evs]
[(10.0, 'FailureEvent', 0), (10.0, 'FailureEvent', 1), (40.0, 'RepairEvent', 0), (40.0, 'RepairEvent', 1)]
>>> flap = ChaosScenario(name="f", horizon_s=50.0,
...                      flap_links=((0, 2, 3),), flap_period_s=20.0)
>>> [round(e.time, 1) for e in scenario_events(flap, k_spine=8)]
[0.0, 10.0, 20.0, 30.0, 40.0, 50.0]
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .model import (
    DerateEvent,
    FailureEvent,
    FaultEvent,
    LINK,
    OCS,
    RepairEvent,
    merge_events,
)

__all__ = [
    "ChaosScenario",
    "flapping_link",
    "gray_derate",
    "scenario_events",
    "shared_risk_group",
    "standard_scenarios",
    "top_of_pod_burst",
]

Link = Tuple[int, int, int]  # (spine group h, OCS k, pod p)


# ---- primitive generators ---------------------------------------------------

def top_of_pod_burst(
    t: float,
    group: int,
    first_ocs: int,
    size: int,
    repair_s: float,
    k_spine: int,
    stagger_s: float = 0.0,
    seed: int = 0,
) -> List[FaultEvent]:
    """Correlated top-of-pod OCS loss: ``size`` consecutive OCSes of
    spine group ``group`` (a shared power/cooling domain) fail at the
    same instant ``t``.

    ``size`` is the correlation radius — how far the blast extends along
    the spine.  Repairs land after ``repair_s``, optionally staggered by
    exponential jitter with mean ``stagger_s`` (field replacement is
    serialized, not simultaneous) drawn from a generator seeded by
    ``seed`` only."""
    if not 0 < size <= k_spine:
        raise ValueError("burst size must be in [1, k_spine]")
    rng = np.random.default_rng(seed)
    events: List[FaultEvent] = []
    for n in range(size):
        k = (first_ocs + n) % k_spine
        jitter = float(rng.exponential(stagger_s)) if stagger_s > 0 else 0.0
        events.append(FailureEvent(t, OCS, h=group, k=k))
        events.append(RepairEvent(t + repair_s + jitter, OCS, h=group, k=k))
    return merge_events(events)


def shared_risk_group(
    t: float, links: Tuple[Link, ...], repair_s: float
) -> List[FaultEvent]:
    """A shared-risk link group (SRLG) cut: every link riding the same
    conduit/patch panel fails at ``t`` and is respliced together at
    ``t + repair_s``."""
    events: List[FaultEvent] = []
    for h, k, p in links:
        events.append(FailureEvent(t, LINK, h=h, k=k, pod=p))
        events.append(RepairEvent(t + repair_s, LINK, h=h, k=k, pod=p))
    return merge_events(events)


def flapping_link(
    link: Link,
    t0: float,
    until: float,
    period_s: float,
    duty: float = 0.5,
) -> List[FaultEvent]:
    """A gray *flapping* link: down for ``duty · period_s``, up for the
    rest, repeating over ``[t0, until)``.  Every failure gets its paired
    repair even when the last down-window crosses ``until`` (the
    consumer can always pair them, like ``FaultModel.sample``)."""
    if period_s <= 0:
        raise ValueError("period_s must be > 0")
    if not 0.0 < duty < 1.0:
        raise ValueError("duty must be in (0, 1)")
    h, k, p = link
    events: List[FaultEvent] = []
    t = t0
    while t < until:
        events.append(FailureEvent(t, LINK, h=h, k=k, pod=p))
        events.append(RepairEvent(t + duty * period_s, LINK, h=h, k=k, pod=p))
        t += period_s
    return events


def gray_derate(
    link: Link, t0: float, until: float, health: float
) -> List[FaultEvent]:
    """A bandwidth-derated link: carries ``health`` × nominal bandwidth
    over ``[t0, until)``, then returns to full health."""
    h, k, p = link
    return [
        DerateEvent(t0, h=h, k=k, pod=p, health=health),
        DerateEvent(until, h=h, k=k, pod=p, health=1.0),
    ]


# ---- declarative scenarios --------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaosScenario:
    """One scripted chaos scenario (compile with :func:`scenario_events`).

    Components are optional and compose: a top-of-pod burst
    (``burst_at_s`` set), a shared-risk link-group cut (``srlg_at_s``
    set), gray flapping links (``flap_links`` non-empty), and gray
    bandwidth-derated links (``derate_links`` non-empty).  Every field is
    plain data so scenarios serialize into benchmark artifacts verbatim.
    """

    name: str
    horizon_s: float
    # correlated top-of-pod OCS burst
    burst_at_s: Optional[float] = None
    burst_group: int = 0
    burst_first_ocs: int = 0
    burst_size: int = 2  # correlation radius: OCSes darkened together
    burst_repair_s: float = 3600.0
    burst_stagger_s: float = 0.0  # mean repair-serialization jitter
    # shared-risk link group
    srlg_at_s: Optional[float] = None
    srlg_links: Tuple[Link, ...] = ()
    srlg_repair_s: float = 1800.0
    # gray flapping links
    flap_links: Tuple[Link, ...] = ()
    flap_from_s: float = 0.0
    flap_until_s: Optional[float] = None  # default: horizon_s
    flap_period_s: float = 1200.0
    flap_duty: float = 0.5
    # gray bandwidth-derated links
    derate_links: Tuple[Link, ...] = ()
    derate_health: float = 0.5
    derate_from_s: float = 0.0
    derate_until_s: Optional[float] = None  # default: horizon_s

    seed: int = 0

    def __post_init__(self) -> None:
        if self.horizon_s <= 0:
            raise ValueError("horizon_s must be > 0")


def scenario_events(sc: ChaosScenario, k_spine: int) -> List[FaultEvent]:
    """Compile ``sc`` into one time-sorted fault-event stream.

    Deterministic given the scenario (randomness only through
    ``sc.seed``); merge with a :meth:`FaultModel.sample
    <repro.fault.model.FaultModel.sample>` background stream via
    :func:`~repro.fault.model.merge_events` for correlated-bursts-on-
    top-of-independent-noise runs."""
    streams: List[List[FaultEvent]] = []
    if sc.burst_at_s is not None:
        streams.append(top_of_pod_burst(
            sc.burst_at_s, sc.burst_group, sc.burst_first_ocs,
            sc.burst_size, sc.burst_repair_s, k_spine,
            stagger_s=sc.burst_stagger_s, seed=sc.seed,
        ))
    if sc.srlg_at_s is not None:
        streams.append(shared_risk_group(
            sc.srlg_at_s, sc.srlg_links, sc.srlg_repair_s
        ))
    if sc.flap_links:
        until = sc.flap_until_s if sc.flap_until_s is not None else sc.horizon_s
        for link in sc.flap_links:
            streams.append(flapping_link(
                link, sc.flap_from_s, until, sc.flap_period_s,
                duty=sc.flap_duty,
            ))
    if sc.derate_links:
        until = (
            sc.derate_until_s if sc.derate_until_s is not None
            else sc.horizon_s
        )
        for link in sc.derate_links:
            streams.append(gray_derate(
                link, sc.derate_from_s, until, sc.derate_health
            ))
    return merge_events(*streams)


def standard_scenarios(
    num_pods: int, k_spine: int, horizon_s: float
) -> Tuple[ChaosScenario, ...]:
    """The chaos-suite catalogue ``benchmarks/bench_chaos.py`` sweeps.

    Three escalating regimes sized to the cluster: a correlated
    top-of-pod burst alone, gray flapping links alone, and the
    acceptance scenario — burst *plus* flapping plus derated links, the
    compound failure a passive control plane handles worst (every flap
    cycle forces a cold solve whose dark windows stall live circuits,
    while the gray links silently derate whatever lands on them).

    Scenarios use spine groups 0 and 1, so consumers need ``sim_groups
    ≥ 2`` (the scheduler default)."""
    flap = tuple(
        (h, k % k_spine, p % num_pods)
        for h, k, p in ((0, 1, 1), (0, 3, 2), (1, 2, 5), (0, 5, 7))
    )
    gray = tuple(
        (h, k % k_spine, p % num_pods)
        for h, k, p in ((1, 0, 3), (0, 2, 6))
    )
    return (
        ChaosScenario(
            name="top_of_pod_burst", horizon_s=horizon_s,
            burst_at_s=0.2 * horizon_s, burst_size=max(2, k_spine // 4),
            burst_repair_s=0.25 * horizon_s,
        ),
        ChaosScenario(
            name="gray_flap", horizon_s=horizon_s,
            flap_links=flap, flap_from_s=0.1 * horizon_s,
            flap_period_s=600.0,
        ),
        ChaosScenario(
            name="burst_flap", horizon_s=horizon_s,
            burst_at_s=0.2 * horizon_s, burst_size=2,
            burst_repair_s=0.25 * horizon_s,
            flap_links=flap, flap_from_s=0.1 * horizon_s,
            flap_period_s=600.0,
            derate_links=gray, derate_health=0.4,
            derate_from_s=0.1 * horizon_s,
        ),
    )
