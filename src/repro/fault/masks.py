"""Port-level health masks over the OCS layer.

A :class:`PortMask` records which physical resources of the cluster are
currently unusable — individual transceiver/link slots (a pod's egress or
ingress port on one OCS), whole OCSes, drained (failed) pods — plus which
pods are *active* at all (elastic expansion: the physical wiring for up to
``ClusterSpec.num_pods`` pods exists from day one, but only a prefix may be
populated).  The mask is the single source of truth the degraded-mode
control plane solves against:

* it degrades the feasible-degree budget of a :class:`~repro.core.topology.
  ClusterSpec` (``degree_budget``),
* it validates :class:`~repro.core.topology.OCSConfig` objects
  (``OCSConfig.validate(mask=...)`` delegates to the arrays here),
* reconfiguration strategies exclude masked slots
  (``mdmcf_reconfigure(..., mask=...)``).

Cross Wiring pairs OCSes ``(2t, 2t+1)``; the degraded MDMCF solve uses only
*clean* pairs — pairs with no failure on either OCS among up pods — which
keeps Theorem 4.1's construction intact on the surviving hardware (see
``repro.fault.recover`` for the argument).  ``clean_pairs``/``degree_budget``
encode exactly that.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import numpy as np

__all__ = ["PortMask"]

_DIRECTIONS = ("egress", "ingress", "both")


class PortMask:
    """Mutable health state of the OCS layer for ``num_groups`` spine groups.

    Layered state (each layer fails/repairs independently):

    * ``ocs_down[h, k]``      — whole OCS ``k`` of group ``h`` out of service.
    * ``port_down_eg[h, k, p]`` / ``port_down_in[h, k, p]`` — pod ``p``'s
      egress / ingress transceiver on OCS ``(h, k)`` dead.
    * ``drained[p]``          — pod ``p`` failed / taken out of service.
    * ``active[p]``           — pod ``p`` physically populated (expansion).
    * ``cordoned[h, k, p]``   — pod ``p``'s slot on OCS ``(h, k)``
      administratively removed from the TE demand (remediation of a
      flapping link; see :mod:`repro.fault.remediate`).  Blocks both
      directions, exactly like a dead transceiver, but is an operator
      *decision*, not a hardware state — failures/repairs underneath a
      cordon keep updating ``port_down_*`` independently.
    * ``link_health[h, k, p]`` — fractional health of pod ``p``'s slot on
      OCS ``(h, k)`` in ``(0, 1]``: a *gray* failure running bandwidth-
      derated rather than dead.  Binary views ignore it; the flow engines
      consume it through :meth:`effective_pair_capacity`.

    Mutators (``fail_*`` / ``repair_*`` / ``expand``) keep the layers
    independent; the control plane reads the combined view through
    ``pod_up`` / ``clean_pairs`` / ``degree_budget`` and caches against
    ``fingerprint()``:

    >>> m = PortMask(num_pods=4, k_spine=4, num_groups=1)
    >>> bool(m.is_trivial())
    True
    >>> m.fail_pod(2)
    >>> m.pod_up().tolist()
    [True, True, False, True]
    >>> m.repair_pod(2)
    >>> bool(m.is_trivial())
    True
    """

    def __init__(self, num_pods: int, k_spine: int, num_groups: int):
        if k_spine % 2:
            raise ValueError("k_spine must be even (OCS pairing)")
        self.num_pods = num_pods
        self.k_spine = k_spine
        self.num_groups = num_groups
        H, K, P = num_groups, k_spine, num_pods
        self.ocs_down = np.zeros((H, K), dtype=bool)
        self.port_down_eg = np.zeros((H, K, P), dtype=bool)
        self.port_down_in = np.zeros((H, K, P), dtype=bool)
        self.drained = np.zeros(P, dtype=bool)
        self.active = np.ones(P, dtype=bool)
        self.cordoned = np.zeros((H, K, P), dtype=bool)
        self.link_health = np.ones((H, K, P), dtype=np.float64)

    @classmethod
    def healthy(cls, spec, num_groups: Optional[int] = None) -> "PortMask":
        """All-healthy mask sized for ``spec`` (a ClusterSpec)."""
        H = num_groups if num_groups is not None else spec.num_ocs_groups
        return cls(spec.num_pods, spec.k_spine, H)

    def copy(self) -> "PortMask":
        out = PortMask(self.num_pods, self.k_spine, self.num_groups)
        out.ocs_down = self.ocs_down.copy()
        out.port_down_eg = self.port_down_eg.copy()
        out.port_down_in = self.port_down_in.copy()
        out.drained = self.drained.copy()
        out.active = self.active.copy()
        out.cordoned = self.cordoned.copy()
        out.link_health = self.link_health.copy()
        return out

    # ---- mutators --------------------------------------------------------

    def fail_link(self, h: int, k: int, pod: int, direction: str = "both") -> None:
        """Kill pod ``pod``'s transceiver on OCS ``(h, k)``.

        ``direction='both'`` models a dead transceiver module (Tx and Rx);
        'egress'/'ingress' a single dead fiber/laser."""
        if direction not in _DIRECTIONS:
            raise ValueError(f"direction must be one of {_DIRECTIONS}")
        if direction in ("egress", "both"):
            self.port_down_eg[h, k, pod] = True
        if direction in ("ingress", "both"):
            self.port_down_in[h, k, pod] = True

    def repair_link(self, h: int, k: int, pod: int, direction: str = "both") -> None:
        if direction in ("egress", "both"):
            self.port_down_eg[h, k, pod] = False
        if direction in ("ingress", "both"):
            self.port_down_in[h, k, pod] = False

    def fail_ocs(self, h: int, k: int) -> None:
        self.ocs_down[h, k] = True

    def repair_ocs(self, h: int, k: int) -> None:
        # individually-failed transceivers on this OCS stay failed
        self.ocs_down[h, k] = False

    def fail_pod(self, pod: int) -> None:
        self.drained[pod] = True

    def repair_pod(self, pod: int) -> None:
        self.drained[pod] = False

    def cordon_link(self, h: int, k: int, pod: int) -> None:
        """Administratively remove pod ``pod``'s slot on OCS ``(h, k)``
        from the TE demand (both directions).  Idempotent."""
        self.cordoned[h, k, pod] = True

    def readmit_link(self, h: int, k: int, pod: int) -> None:
        """Lift a cordon (the remediation engine's backoff expired and the
        link stayed healthy)."""
        self.cordoned[h, k, pod] = False

    def derate_link(self, h: int, k: int, pod: int, health: float) -> None:
        """Set the fractional health of pod ``pod``'s slot on OCS
        ``(h, k)`` — a gray failure carrying ``health`` × its nominal
        bandwidth.  ``health=1.0`` restores the slot to full health;
        ``health=0`` is rejected (use :meth:`fail_link` for a dead slot,
        so the *solver* routes around it instead of the flow model
        discovering a zero-capacity circuit)."""
        if not 0.0 < health <= 1.0:
            raise ValueError("health must be in (0, 1]")
        self.link_health[h, k, pod] = health

    def expand(self, pods: Iterable[int]) -> None:
        """Activate newly-populated pods (elastic expansion)."""
        for p in pods:
            self.active[p] = True

    def set_active_count(self, n: int) -> None:
        """Activate exactly the first ``n`` pods (initial partial deployment)."""
        self.active[:] = False
        self.active[:n] = True

    # ---- derived views ---------------------------------------------------

    def pod_up(self) -> np.ndarray:
        """(P,) bool — pods that are populated and not drained."""
        return self.active & ~self.drained

    def egress_blocked(self) -> np.ndarray:
        """(H, K, P) bool — pod p's egress slot on OCS (h, k) unusable
        (dead hardware or an administrative cordon)."""
        return self.ocs_down[:, :, None] | self.port_down_eg | self.cordoned

    def ingress_blocked(self) -> np.ndarray:
        return self.ocs_down[:, :, None] | self.port_down_in | self.cordoned

    def clean_pairs(self, h: int) -> np.ndarray:
        """Pair indices ``t`` whose OCS pair ``(2t, 2t+1)`` in group ``h``
        carries no failure at all among up pods — the slots the degraded
        MDMCF construction uses."""
        up = self.pod_up()
        eg = self.egress_blocked()[h][:, up]
        ing = self.ingress_blocked()[h][:, up]
        bad_ocs = eg.any(axis=1) | ing.any(axis=1)  # (K,)
        bad_pair = bad_ocs[0::2] | bad_ocs[1::2]  # (K/2,)
        return np.nonzero(~bad_pair)[0]

    def degree_budget(self, style: str = "cross_wiring") -> np.ndarray:
        """(H, P) int — per-pod bidirectional-degree budget per spine group
        under the mask; down pods get 0.

        ``style='cross_wiring'``: each clean OCS pair contributes up to 2
        links per pod (one as circuit source on the even OCS, one as sink —
        mirrored on the odd OCS); the budget is uniform over up pods, which
        is what the degraded MDMCF realizes *exactly*.

        ``style='uniform'``: per-pod count of OCSes where both of the pod's
        ports work — finer-grained (a dead transceiver only costs its own
        pod), but only an upper bound: Uniform's symmetric-matching
        constraint already under-realizes heavy demands even fully healthy.
        """
        H, P = self.num_groups, self.num_pods
        budget = np.zeros((H, P), dtype=np.int64)
        up = self.pod_up()
        if style == "uniform":
            ok = ~(self.egress_blocked() | self.ingress_blocked())  # (H,K,P)
            budget[:, up] = ok.sum(axis=1)[:, up]
            return budget
        for h in range(H):
            budget[h, up] = min(self.k_spine, 2 * len(self.clean_pairs(h)))
        return budget

    def allowed(self, h: int, k: int) -> np.ndarray:
        """(P, P) bool — directed circuit i→j permitted on OCS ``(h, k)``."""
        up = self.pod_up()
        eg_ok = ~self.egress_blocked()[h, k] & up
        in_ok = ~self.ingress_blocked()[h, k] & up
        return eg_ok[:, None] & in_ok[None, :]

    def fingerprint(self) -> bytes:
        """Digest of the full health state.  The incremental control plane
        (:mod:`repro.core.incremental`) stamps its :class:`ColoringState`
        with this; any mask change invalidates the state, forcing the
        scheduler back to a cold solve it *can* trust."""
        import hashlib

        d = hashlib.blake2b(digest_size=16)
        for a in (
            self.ocs_down,
            self.port_down_eg,
            self.port_down_in,
            self.drained,
            self.active,
            self.cordoned,
            self.link_health,
        ):
            d.update(a.tobytes())
        return d.digest()

    def is_trivial(self) -> bool:
        """True iff the mask constrains nothing (all healthy, all active)."""
        return bool(
            self.active.all()
            and not self.drained.any()
            and not self.ocs_down.any()
            and not self.port_down_eg.any()
            and not self.port_down_in.any()
            and not self.cordoned.any()
            and not self.has_gray()
        )

    def has_gray(self) -> bool:
        """True iff any slot runs bandwidth-derated (link_health < 1)."""
        return bool((self.link_health < 1.0).any())

    def effective_pair_capacity(self, config) -> np.ndarray:
        """(P, P) per-group-average bidirectional pair capacity of
        ``config`` with gray slots derated.

        A directed circuit i→j on OCS ``(h, k)`` carries
        ``min(link_health[h, k, i], link_health[h, k, j])`` of its nominal
        bandwidth (egress laser of i and ingress receiver of j share the
        slot); the bidirectional pair capacity is the min of the two
        directions, as in :meth:`OCSConfig.pair_capacity
        <repro.core.topology.OCSConfig.pair_capacity>` — with all slots at
        full health the two are identical."""
        x = config.x  # (H', K, P, P) binary
        Hp = x.shape[0]
        w = np.minimum(
            self.link_health[:Hp, :, :, None],
            self.link_health[:Hp, :, None, :],
        )
        directed = (x * w).sum(axis=1)  # (H', P, P)
        bidir = np.minimum(directed, directed.transpose(0, 2, 1))
        return bidir.sum(axis=0) / max(1, Hp)

    def counts(self) -> Dict[str, int]:
        return {
            "failed_ports": int(self.port_down_eg.sum() + self.port_down_in.sum()),
            "failed_ocs": int(self.ocs_down.sum()),
            "drained_pods": int(self.drained.sum()),
            "active_pods": int(self.active.sum()),
            "cordoned_links": int(self.cordoned.sum()),
            "derated_links": int((self.link_health < 1.0).sum()),
        }

    # ---- config validation ----------------------------------------------

    def check_config(self, x: np.ndarray) -> None:
        """Assert no circuit in ``x`` (shape (H', K, P, P), H' ≤ H) touches
        a masked slot or a down pod."""
        H = x.shape[0]
        eg = self.egress_blocked()[:H]
        ing = self.ingress_blocked()[:H]
        if (x.astype(bool) & eg[:, :, :, None]).any():
            raise AssertionError("config assigns a masked egress slot")
        if (x.astype(bool) & ing[:, :, None, :]).any():
            raise AssertionError("config assigns a masked ingress slot")
        down = ~self.pod_up()
        if x[:, :, down, :].any() or x[:, :, :, down].any():
            raise AssertionError("config routes a drained/inactive pod")
