"""Stochastic failure/repair processes and scripted fault scenarios.

Each hardware class — transceiver/link slots, whole OCSes, pods — is an
alternating renewal process: exponential up-times with the class's MTBF,
exponential down-times with its MTTR.  :meth:`FaultModel.sample` draws every
component's timeline over a horizon and merges them into one sorted stream
of :class:`FailureEvent` / :class:`RepairEvent`.  :class:`ExpandEvent`
models elastic expansion (new pods going live on a running cluster); it is
always scripted — capacity growth is an operator action, not a Poisson one.

Deterministic given the seed, so the event-driven simulator stays
reproducible (``tests/test_sim.py::test_sim_determinism`` discipline).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from .masks import PortMask

__all__ = [
    "DerateEvent",
    "ExpandEvent",
    "FailureEvent",
    "FaultEvent",
    "FaultModel",
    "RepairEvent",
    "apply_event",
    "merge_events",
]

LINK, OCS, POD = "link", "ocs", "pod"
_SCOPES = (LINK, OCS, POD)


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """A component going down at ``time``.

    ``scope`` ∈ {'link', 'ocs', 'pod'}; ``h``/``k`` locate the OCS for
    link/ocs scopes, ``pod`` the pod for link/pod scopes."""

    time: float
    scope: str
    h: int = 0
    k: int = 0
    pod: int = 0

    def __post_init__(self) -> None:
        if self.scope not in _SCOPES:
            raise ValueError(f"scope must be one of {_SCOPES}")


@dataclasses.dataclass(frozen=True)
class RepairEvent:
    """The matching component coming back at ``time``."""

    time: float
    scope: str
    h: int = 0
    k: int = 0
    pod: int = 0

    def __post_init__(self) -> None:
        if self.scope not in _SCOPES:
            raise ValueError(f"scope must be one of {_SCOPES}")


@dataclasses.dataclass(frozen=True)
class ExpandEvent:
    """Pods ``pods`` go live at ``time`` (elastic expansion)."""

    time: float
    pods: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class DerateEvent:
    """A *gray* failure: pod ``pod``'s slot on OCS ``(h, k)`` starts
    carrying ``health`` × its nominal bandwidth at ``time``.

    ``health=1.0`` restores the slot (the gray twin of a
    :class:`RepairEvent`); always link-scoped — dead-clean failures use
    :class:`FailureEvent` so the solver routes around them."""

    time: float
    h: int = 0
    k: int = 0
    pod: int = 0
    health: float = 0.5

    def __post_init__(self) -> None:
        if not 0.0 < self.health <= 1.0:
            raise ValueError("health must be in (0, 1]")


FaultEvent = Union[FailureEvent, RepairEvent, ExpandEvent, DerateEvent]


def apply_event(mask: PortMask, ev: FaultEvent) -> None:
    """Mutate ``mask`` to reflect ``ev``."""
    if isinstance(ev, ExpandEvent):
        mask.expand(ev.pods)
    elif isinstance(ev, DerateEvent):
        mask.derate_link(ev.h, ev.k, ev.pod, ev.health)
    elif isinstance(ev, FailureEvent):
        if ev.scope == LINK:
            mask.fail_link(ev.h, ev.k, ev.pod)
        elif ev.scope == OCS:
            mask.fail_ocs(ev.h, ev.k)
        else:
            mask.fail_pod(ev.pod)
    elif isinstance(ev, RepairEvent):
        if ev.scope == LINK:
            mask.repair_link(ev.h, ev.k, ev.pod)
        elif ev.scope == OCS:
            mask.repair_ocs(ev.h, ev.k)
        else:
            mask.repair_pod(ev.pod)
    else:
        raise TypeError(f"unknown fault event {ev!r}")


def merge_events(*streams: Sequence[FaultEvent]) -> List[FaultEvent]:
    """Merge event streams into one time-sorted list (stable)."""
    out: List[FaultEvent] = []
    for s in streams:
        out.extend(s)
    out.sort(key=lambda e: e.time)
    return out


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Per-class MTBF/MTTR failure model for one cluster.

    ``None`` MTBF disables a class.  Times are seconds.  Defaults sit in the
    published ballpark for optical plants scaled to simulation horizons:
    transceivers dominate failure counts, whole-OCS and pod failures are
    order(s) of magnitude rarer.
    """

    num_pods: int
    k_spine: int
    num_groups: int
    link_mtbf_s: Optional[float] = None
    link_mttr_s: float = 1800.0
    ocs_mtbf_s: Optional[float] = None
    ocs_mttr_s: float = 3600.0
    pod_mtbf_s: Optional[float] = None
    pod_mttr_s: float = 7200.0
    seed: int = 0

    def sample(self, horizon_s: float) -> List[FaultEvent]:
        """Draw every component's alternating up/down timeline to
        ``horizon_s`` and merge.  Repairs falling past the horizon are kept
        so a consumer can always pair failures with repairs.

        Each hardware class draws from its *own* ``np.random.Generator``,
        spawned from one explicit :class:`numpy.random.SeedSequence` — no
        shared (or module-level) stream.  Toggling one class's parameters
        therefore cannot perturb another class's event times: the link
        stream with ``pod_mtbf_s=None`` is bit-identical to the link
        stream with pod failures enabled
        (``tests/test_fault.py::test_fault_streams_independent_per_class``).
        """
        g_link, g_ocs, g_pod = np.random.SeedSequence(self.seed).spawn(3)
        events: List[FaultEvent] = []

        def renewal(rng, mtbf: float, mttr: float, make) -> None:
            t = float(rng.exponential(mtbf))
            while t < horizon_s:
                down = float(rng.exponential(mttr))
                fail, rep = make(t, t + down)
                events.append(fail)
                events.append(rep)
                t += down + float(rng.exponential(mtbf))

        H, K, P = self.num_groups, self.k_spine, self.num_pods
        if self.link_mtbf_s is not None:
            rng = np.random.default_rng(g_link)
            for h in range(H):
                for k in range(K):
                    for p in range(P):
                        renewal(
                            rng,
                            self.link_mtbf_s,
                            self.link_mttr_s,
                            lambda a, b, h=h, k=k, p=p: (
                                FailureEvent(a, LINK, h=h, k=k, pod=p),
                                RepairEvent(b, LINK, h=h, k=k, pod=p),
                            ),
                        )
        if self.ocs_mtbf_s is not None:
            rng = np.random.default_rng(g_ocs)
            for h in range(H):
                for k in range(K):
                    renewal(
                        rng,
                        self.ocs_mtbf_s,
                        self.ocs_mttr_s,
                        lambda a, b, h=h, k=k: (
                            FailureEvent(a, OCS, h=h, k=k),
                            RepairEvent(b, OCS, h=h, k=k),
                        ),
                    )
        if self.pod_mtbf_s is not None:
            rng = np.random.default_rng(g_pod)
            for p in range(P):
                renewal(
                    rng,
                    self.pod_mtbf_s,
                    self.pod_mttr_s,
                    lambda a, b, p=p: (
                        FailureEvent(a, POD, pod=p),
                        RepairEvent(b, POD, pod=p),
                    ),
                )
        return merge_events(events)
