"""Degraded-mode topology engineering and recovery policies.

Degraded-mode solve — why Cross Wiring stays polynomial and exact
-----------------------------------------------------------------
Under a :class:`~repro.fault.masks.PortMask`, the degraded MDMCF
(`mdmcf_reconfigure(..., mask=...)`) restricts each spine group ``h`` to
its *clean* OCS pairs — pairs ``(2t, 2t+1)`` with zero failures among up
pods.  The construction of Theorem 4.1 is untouched on those pairs:

1. Feasibility under the mask means the demand ``C[h]`` is symmetric with
   per-pod degree ≤ ``2·|clean(h)|`` (``PortMask.degree_budget``).
2. Theorem 3.1 (`symmetric_split`, Eulerian orientation, O(E)) yields
   ``A`` with ``A + Aᵀ = C[h]`` and row/col sums ≤ ``|clean(h)|``.
3. König edge coloring (`edge_color_bipartite`,
   O(E·(P + |clean|))) decomposes ``A`` into ``|clean(h)|``
   sub-permutations — guaranteed to exist because row/col sums bound the
   bipartite multigraph's maximum degree.
4. Each color class lands on a clean pair (even OCS carries ``M``, odd
   carries ``Mᵀ``), Hungarian-matched to old slots for Min-Rewiring.

Every step is the healthy-case algorithm on a smaller slot set, so the
whole solve is polynomial and realizes any mask-feasible demand *exactly*
(LTRR = 1) while touching no masked slot — the property
``tests/test_fault.py`` checks.  The clean-pair restriction is
conservative: a single dead transceiver retires its whole OCS pair (2 of
``K_spine`` degrees) for that group rather than just one circuit.  That
trade buys the exactness guarantee; Uniform has no analogous move — its
per-OCS symmetric-matching constraint already under-realizes heavy
demands, and port failures only shrink the matchings further (it degrades
non-gracefully, which ``benchmarks/bench_availability.py`` measures).

Recovery policies (consumed by ``sim/scheduler.py``)
----------------------------------------------------
* ``rewire-around``  — OCS-only repair: jobs keep running; the control
  plane re-solves around the masked slots and jobs absorb the (usually
  small) bandwidth loss via the flow model.  Cannot resurrect a dead pod.
* ``shrink-collective`` — a job that loses a pod drops it from its DP ring
  / EP mesh, replans its collectives via ``repro.dist`` over the surviving
  pods, and continues with proportionally less compute.
* ``checkpoint-restart`` — the job rolls back to its last checkpoint and
  restarts; the restart cost is charged from the checkpoint state size
  (the full Adam ``TrainState`` that ``ckpt/manager`` serializes).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

from ..core.logical import shave_to_budget
from ..core.reconfig import linear_sum_assignment
from ..dist.collectives import MODEL_PROFILES
from ..obs.trace import ambient as _trace_ambient
from .masks import PortMask

__all__ = [
    "CKPT_STATE_FACTOR",
    "PER_GPU_RESTORE_BW",
    "POLICIES",
    "POLICY_CAUSE",
    "RESTART_FIXED_S",
    "REWIRE_AROUND",
    "SHRINK_COLLECTIVE",
    "CKPT_RESTART",
    "CHEAPEST",
    "checkpoint_bytes",
    "ckpt_write_s",
    "degrade_demand",
    "masked_aggregate_demand",
    "mdmcf_degraded",
    "policy_costs",
    "restart_cost_s",
    "rollback_loss",
]

REWIRE_AROUND = "rewire_around"
SHRINK_COLLECTIVE = "shrink_collective"
CKPT_RESTART = "ckpt_restart"
CHEAPEST = "cheapest"  # per-victim argmin over the fluid-priced costs
POLICIES = (REWIRE_AROUND, SHRINK_COLLECTIVE, CKPT_RESTART, CHEAPEST)

# which blame bucket (repro.obs.attrib.JOB_CAUSES) each policy's cost
# lands under when it is chosen for a victim — the scheduler stamps
# this on every policy decision (series row + `policy` trace instant),
# so attribution/dashboards can pivot decisions by consequence
POLICY_CAUSE = {
    REWIRE_AROUND: "rollback",  # no checkpoints: the run so far is lost
    SHRINK_COLLECTIVE: "degraded",  # keeps running at a degraded rate
    CKPT_RESTART: "restart",  # restore cost + checkpoint-tail rollback
}

# Checkpoint state vs bf16 gradient bytes: bf16 params (1×) + fp32 master
# params (2×) + two fp32 Adam moments (4×) = 7× — the pytree
# ``ckpt/manager.save_checkpoint`` flattens for an Adam TrainState.
CKPT_STATE_FACTOR = 7.0
PER_GPU_RESTORE_BW = 0.5e9  # bytes/s of restore I/O each GPU contributes
RESTART_FIXED_S = 120.0  # reschedule + process launch + NCCL/mesh re-init


def degrade_demand(C: np.ndarray, mask: PortMask) -> np.ndarray:
    """Clip a logical-topology demand to what the mask leaves feasible.

    Returns a copy of ``C`` (shape (H, P, P)) with down pods zeroed and
    every pod's per-group degree shaved (``shave_to_budget``, fattest-pair
    first) to ``mask.degree_budget()``.  The result satisfies
    ``demand_feasible(C, spec, mask=mask)`` by construction.
    """
    C = np.array(C, dtype=np.int64, copy=True)
    down = ~mask.pod_up()
    C[:, down, :] = 0
    C[:, :, down] = 0
    budget = mask.degree_budget()
    for h in range(C.shape[0]):
        shave_to_budget(C[h], budget[h].copy())
    return C


def masked_aggregate_demand(
    num_pods: int, num_groups: int, edge_dicts, mask: PortMask
) -> np.ndarray:
    """Aggregate per-job edge dicts ((i, j) → links) into an ``(H, P, P)``
    demand clipped job-by-job to the mask's port-granular budget — shared
    by the scheduler and the availability benchmark so their clipping
    policies cannot diverge."""
    C = np.zeros((num_groups, num_pods, num_pods), dtype=np.int64)
    budgets = mask.degree_budget("uniform")
    for edges in edge_dicts:
        base = np.zeros((num_pods, num_pods), dtype=np.int64)
        for (i, j), w in edges.items():
            base[i, j] += w
            base[j, i] += w
        for h in range(num_groups):
            ring = base.copy()
            shave_to_budget(ring, budgets[h])
            budgets[h] -= ring.sum(axis=1)
            C[h] += ring
    return C


def mdmcf_degraded(spec, C: np.ndarray, old=None, mask: Optional[PortMask] = None):
    """Production degraded-mode Cross Wiring solve: exact structure, local
    repair around failures.

    1. Solve the *healthy* Theorem 4.1 construction on all ``K_spine/2``
       OCS pairs (symmetric split + König edge coloring, warm-started from
       ``old`` — unchanged polynomial machinery).
    2. Hungarian-assign color classes to OCS pairs minimizing the number
       of circuits that would land on masked slots (violations dominate;
       rewiring overlap with ``old`` breaks ties, preserving the
       Min-Rewiring objective).  With few scattered failures an assignment
       with zero violations usually exists — the class layout simply
       routes *around* the dead slots.
    3. Drop the violating circuits only, then greedily re-place those
       units first-fit on any pair with a free healthy slot (the odd OCS
       always carries the even transpose, so L2 pairing is preserved).

    Every step is polynomial; no masked slot is ever assigned; with an
    all-healthy mask this *is* ``mdmcf_reconfigure``.  Unlike
    ``mdmcf_reconfigure(mask=...)`` — the provably-exact solver for
    demands within the conservative clean-pair budget — this path accepts
    any demand within the port-granular budget and degrades gracefully
    (LTRR < 1 only for units no healthy slot can carry).
    """
    import time as _time

    from ..core.decomposition import edge_color_bipartite, symmetric_split
    from ..core.reconfig import ReconfigResult, mdmcf_reconfigure
    from ..core.topology import OCSConfig

    if mask is None or mask.is_trivial():
        return mdmcf_reconfigure(spec, C, old=old)
    if linear_sum_assignment is None:
        raise ImportError("scipy is required for degraded-mode slot assignment")
    t0 = _time.perf_counter()
    C = np.asarray(C)
    H, P, _ = C.shape
    K2 = spec.k_spine // 2
    cfg = OCSConfig(spec, num_groups=H)
    for h in range(H):
        A = symmetric_split(C[h])
        warm = old.x[h, 0::2] if old is not None else None
        colors = edge_color_bipartite(A, K2, warm=warm)
        cint = colors.astype(np.int64)
        # ok[t, i, j]: circuit i→j healthy on even OCS 2t AND its mirror
        # j→i healthy on odd OCS 2t+1 (the L2 pairing needs both)
        a_even = np.stack([mask.allowed(h, 2 * t) for t in range(K2)])
        a_odd = np.stack([mask.allowed(h, 2 * t + 1) for t in range(K2)])
        ok = a_even & np.transpose(a_odd, (0, 2, 1))
        viol = np.einsum("cij,tij->ct", cint, (~ok).astype(np.int64))
        # Violation weight is slack-aware.  With spare healthy slots to
        # absorb every circuit the mask could strand, a violation merely
        # becomes a salvage move — the same 4 array entries as relocating
        # any other circuit — so pricing it at ~1.5 circuit-moves makes
        # the assignment the true Min-Rewiring optimum: a scattered link
        # failure drops the one stranded circuit instead of swapping whole
        # color classes (48+ circuit moves) to route around a single dead
        # slot.  When the budget is tight (spare < strandable), a dropped
        # circuit risks staying unrealized, so violations go back to
        # dominating everything (realization-first, the paper's objective
        # hierarchy).
        units = int(cint.sum())
        healthy_cap = int(
            np.minimum(ok.any(axis=2).sum(axis=1), ok.any(axis=1).sum(axis=1))
            .sum()
        )
        masked_cap = K2 * P - healthy_cap
        plentiful = healthy_cap - units >= max(masked_cap, 1)
        # primary costs are scaled ×16 so a sub-integer gray-health
        # tie-break (below) can never reorder violation/overlap decisions
        cost = (viol * 3 if plentiful else viol * (4 * P + 1)) * 16
        if old is not None:
            old_even = old.x[h, 0::2].astype(np.int64)
            old_odd = old.x[h, 1::2].astype(np.int64)
            overlap = (
                np.einsum("cij,tij->ct", cint, old_even)
                + np.einsum("cji,tij->ct", cint, old_odd)
            )
            cost = cost - (overlap * 2 if plentiful else overlap) * 16
        if mask.has_gray():
            # gray tie-break: among assignments with equal violation /
            # overlap cost, steer color classes off bandwidth-derated
            # links.  A circuit i→j on pair t rides 4 links — pods i and
            # j on both the even and odd OCS — so its weight is the min
            # health over those, matching ``effective_pair_capacity``.
            lh = mask.link_health[h]
            pod_min = np.minimum(lh[0::2], lh[1::2])  # (K2, P)
            w = np.minimum(pod_min[:, :, None], pod_min[:, None, :])
            gray = np.einsum("cij,tij->ct", cint, 1.0 - w)
            gmax = float(gray.max())
            if gmax > 0:
                cost = cost + np.rint(gray * (15.0 / gmax)).astype(np.int64)
        classes, pairs = linear_sum_assignment(cost)
        rem = np.zeros((P, P), dtype=np.int64)  # dropped bidirectional units
        row_used = np.zeros((K2, P), dtype=bool)  # even-OCS egress taken
        col_used = np.zeros((K2, P), dtype=bool)  # even-OCS ingress taken
        for c, s in zip(classes, pairs):
            m = colors[c].astype(bool)
            keep = m & ok[s]
            cfg.x[h, 2 * s][keep] = 1
            cfg.x[h, 2 * s + 1][keep.T] = 1
            row_used[s] = keep.any(axis=1)
            col_used[s] = keep.any(axis=0)
            di, dj = np.nonzero(m & ~ok[s])
            for i, j in zip(di.tolist(), dj.tolist()):
                rem[i, j] += 1
                rem[j, i] += 1
        # salvage: first-fit each dropped unit onto any free healthy slot;
        # orientation on the even OCS is free (odd carries the transpose)
        iu, ju = np.nonzero(np.triu(rem, k=1))
        for idx in np.argsort(-rem[iu, ju], kind="stable"):
            i, j = int(iu[idx]), int(ju[idx])
            for _unit in range(int(rem[i, j])):
                placed = False
                for t in range(K2):
                    for a, b in ((i, j), (j, i)):
                        if row_used[t, a] or col_used[t, b] or not ok[t, a, b]:
                            continue
                        cfg.x[h, 2 * t, a, b] = 1
                        cfg.x[h, 2 * t + 1, b, a] = 1
                        row_used[t, a] = col_used[t, b] = True
                        placed = True
                        break
                    if placed:
                        break
                if not placed:
                    break  # no healthy slot anywhere for this link
    cfg.validate(mask)
    res = ReconfigResult(cfg, C, _time.perf_counter() - t0)
    tr = _trace_ambient()
    if tr is not None and tr.enabled:
        tr.instant(
            "solve", "degraded_solve",
            warm=old is not None, groups=int(H), ltrr=round(res.ltrr, 9),
        )
    return res


def checkpoint_bytes(model: str) -> float:
    """Full training-state checkpoint size of ``model`` (see module doc)."""
    prof = MODEL_PROFILES.get(model)
    grad = prof.grad_bytes if prof is not None else 14e9
    return CKPT_STATE_FACTOR * grad


def ckpt_write_s(model: str, num_gpus: int) -> float:
    """Wall seconds a running job pauses to write a full checkpoint:
    sharded dump of the training state at ``PER_GPU_RESTORE_BW`` per
    participating GPU (write and restore ride the same per-GPU storage
    NICs).  No fixed reschedule term — the job stays scheduled.  This is
    what the remediation engine prices a *pre-emptive* checkpoint at."""
    return checkpoint_bytes(model) / (max(1, num_gpus) * PER_GPU_RESTORE_BW)


def restart_cost_s(model: str, num_gpus: int) -> float:
    """Wall seconds to restart a job from its last checkpoint: fixed
    reschedule/re-init overhead plus sharded restore of the checkpoint
    state at ``PER_GPU_RESTORE_BW`` per participating GPU."""
    io = checkpoint_bytes(model) / (max(1, num_gpus) * PER_GPU_RESTORE_BW)
    return RESTART_FIXED_S + io


def rollback_loss(progress_s: float, ckpt_interval_s: float) -> float:
    """Service-seconds of work lost rolling back to the last checkpoint."""
    if ckpt_interval_s <= 0:
        return progress_s
    return progress_s - ckpt_interval_s * (progress_s // ckpt_interval_s)


def _stretch(comm_fraction: float, phi: float, cap: Optional[float]) -> float:
    """Local copy of the flow model's JRT multiplier (``repro.fault`` sits
    below ``repro.sim`` in the layering, so no import): comm stretches by
    1/φ above the residual-electrical floor ``1/cap``; ``cap=None`` with
    φ=0 means no progress at all."""
    floor = 0.0
    if cap is not None and math.isfinite(cap) and cap > 0:
        floor = 1.0 / cap
    phi = min(1.0, max(phi, floor))
    if phi <= 0.0:
        return math.inf if comm_fraction > 0 else 1.0
    return 1.0 + comm_fraction * (1.0 / phi - 1.0)


def policy_costs(
    *,
    service_s: float,
    progress_s: float,
    model: str,
    num_gpus: int,
    lost_gpus: int,
    comm_fraction: float,
    phi_shrunk: float,
    ckpt_interval_s: float,
    slowdown_cap: Optional[float] = 4.0,
    cur_gpus: Optional[int] = None,
) -> Dict[str, float]:
    """Estimated seconds until a pod-failure victim completes, per policy.

    ``phi_shrunk`` must be the *fluid-measured* bandwidth fraction of the
    job's replanned (pod-dropped) collectives on the realized topology —
    the max-min level :func:`repro.sim.fluid.fluid_fractions` reports with
    the dead pod's circuits dark — not the static worst-edge φ snapshot a
    single pre-failure configuration would suggest.  The restart policies
    requeue the job, so their remaining work is priced at full rate on a
    fresh healthy placement (their cost is dominated by the lost progress
    and restore I/O):

    * ``rewire_around`` — no checkpoint infrastructure: the whole run so
      far is lost; fixed reschedule overhead plus the full service time.
    * ``ckpt_restart`` — roll back to the last checkpoint (losing the
      tail), pay the sharded restore, then finish the rest.
    * ``shrink_collective`` — keep running on the surviving GPUs: the
      remaining work stretches by the compute deficit *and* by the
      fluid-measured communication slowdown of the shrunken ring.
      Infinite when no GPU survives.

    ``num_gpus`` is the job's *full* size (its service time is calibrated
    to it, and restarts re-place at full size); ``cur_gpus`` the GPUs it
    currently runs on — smaller after earlier shrinks, so a second shrink
    is priced against the full calibration base, not the already-shrunk
    one.  Defaults to ``num_gpus`` (never shrunk).
    """
    if cur_gpus is None:
        cur_gpus = num_gpus
    remaining = max(0.0, service_s - progress_s)
    out = {
        REWIRE_AROUND: RESTART_FIXED_S + service_s,
        CKPT_RESTART: restart_cost_s(model, num_gpus)
        + remaining
        + rollback_loss(progress_s, ckpt_interval_s),
    }
    survivors = cur_gpus - lost_gpus
    if survivors > 0:
        out[SHRINK_COLLECTIVE] = (
            remaining
            * (num_gpus / survivors)
            * _stretch(comm_fraction, phi_shrunk, slowdown_cap)
        )
    else:
        out[SHRINK_COLLECTIVE] = math.inf
    return out
