"""Closed-loop remediation: health detections → bounded repair actions.

:mod:`repro.obs.health` *detects* trouble while a run is live; this
module *acts* on it.  A :class:`RemediationEngine` subscribes as the
scheduler's ``SimConfig.on_health`` hook; the scheduler recognizes the
``bind`` method and hands the engine its actuator handle, closing the
loop.  Every action is deferred through
:meth:`~repro.sim.scheduler.Simulator.schedule_action` — detectors fire
mid-refresh, so mutations run at top level as ``ACTION`` heap events, in
deterministic order — and lands in the MetricsRegistry
(``remediation.*`` counters), the trace (``remediation`` spans), and the
blame ledger (causes ``remediation`` / ``cordon``).

Action catalogue (each with hysteresis and a budget):

* **cordon** (on ``link_flap``) — take the flapping slot out of TE
  demand.  The slot stays physically up, but with no circuit on it the
  next flap changes nothing the solver sees: re-solves become fixed
  points (rewired = 0) and the flap-induced dark windows stop.
  Readmission is exponential-backoff gated: the slot re-enters demand
  only after staying healthy for ``cordon_base_s · 2^k`` (``k`` =
  cordons/extensions of this slot so far); a failure inside the window
  doubles it instead.  No flap-thrash, property-tested in
  ``tests/test_remediate.py``.
* **drain** (on ``slo_burn`` / ``dark_storm``) — reroute serving load
  off the sickest pod (most active dark pairs + blocked slots + gray
  links): its decode pods drain back to the allocator and the re-solve
  drops its KV circuits.
* **pre-emptive checkpoint** (same triggers) — burn rate predicts an SLO
  breach or restart risk, so running training jobs advance their
  rollback floor now, priced at the ``ckpt/manager`` write cost
  (:func:`~repro.fault.recover.ckpt_write_s`).  Skipped under
  ``rewire_around`` (no checkpoint infrastructure).
* **solver escalation** (on ``solver_fallback``) — the incremental plane
  is thrashing (StaleStateError → cold solve, repeatedly); pin it to the
  degraded-mode solver for a bounded window so each solve pays one
  predictable price.

The engine itself is pure policy: all state mutation goes through the
simulator's actuators, so conservation of blamed time stays exact.

>>> eng = RemediationEngine(cordon_base_s=600.0)
>>> [eng.backoff_s(k) for k in range(4)]
[600.0, 1200.0, 2400.0, 4800.0]
>>> eng.summary()["cordons"]
0
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from .recover import REWIRE_AROUND

__all__ = ["RemediationEngine"]

_Slot = Tuple[int, int, int]  # (spine group h, OCS k, pod p)


@dataclasses.dataclass
class _Cordon:
    """Per-slot cordon hysteresis state."""

    slot: _Slot
    strikes: int = 0  # cordons + in-window extensions so far (backoff k)
    active: bool = False
    since: float = -math.inf  # when the current cordon began
    until: float = -math.inf  # earliest readmission instant


class RemediationEngine:
    """Maps :class:`~repro.obs.health.HealthEvent` firings to bounded
    remediation actions (see module docstring).  Use as the
    ``SimConfig.on_health`` hook; the scheduler calls :meth:`bind`.

    Budgets are per run: at most ``max_cordoned`` slots cordoned at once,
    ``max_drains`` pool drains, ``max_ckpts`` pre-emptive checkpoints,
    ``max_solver_escalations`` degraded-solver windows; cooldowns keep a
    noisy detector from spending a budget in one burst.
    """

    def __init__(
        self,
        cordon_base_s: float = 900.0,
        max_cordoned: int = 8,
        max_backoff_doublings: int = 16,
        drain_cooldown_s: float = 1800.0,
        max_drains: int = 8,
        ckpt_cooldown_s: float = 3600.0,
        max_ckpts: int = 16,
        solver_window_s: float = 1800.0,
        max_solver_escalations: int = 4,
    ):
        if cordon_base_s <= 0:
            raise ValueError("cordon_base_s must be > 0")
        self.cordon_base_s = cordon_base_s
        self.max_cordoned = max_cordoned
        self.max_backoff_doublings = max_backoff_doublings
        self.drain_cooldown_s = drain_cooldown_s
        self.max_drains = max_drains
        self.ckpt_cooldown_s = ckpt_cooldown_s
        self.max_ckpts = max_ckpts
        self.solver_window_s = solver_window_s
        self.max_solver_escalations = max_solver_escalations
        self.sim = None  # set by bind()
        self._cordons: Dict[_Slot, _Cordon] = {}
        self._last_drain = -math.inf
        self._last_ckpt = -math.inf
        self._counts: Dict[str, int] = {
            "cordons": 0, "extensions": 0, "readmits": 0,
            "drains": 0, "ckpts": 0, "solver_escalations": 0,
            "skipped_budget": 0,
        }

    # ---- wiring ----------------------------------------------------------

    def bind(self, sim) -> None:
        """Receive the actuator handle (called by ``Simulator.__init__``
        when it recognizes this hook's ``bind`` attribute)."""
        self.sim = sim

    def __call__(self, ev) -> None:
        """The ``on_health`` hook: dispatch one HealthEvent."""
        if self.sim is None:
            return
        if ev.detector == "link_flap" and ev.detail is not None:
            self._on_flap(ev)
        elif ev.detector == "solver_fallback":
            self._on_fallback(ev)
        elif ev.detector in ("slo_burn", "dark_storm"):
            self._on_burn(ev)

    # ---- cordon with exponential-backoff readmission ---------------------

    def backoff_s(self, strikes: int) -> float:
        """Healthy-residency requirement before readmission: 2^k · base."""
        return self.cordon_base_s * (
            2.0 ** min(strikes, self.max_backoff_doublings)
        )

    def _active_cordons(self) -> int:
        return sum(1 for st in self._cordons.values() if st.active)

    def _on_flap(self, ev) -> None:
        slot: _Slot = tuple(ev.detail)  # type: ignore[assignment]
        st = self._cordons.setdefault(slot, _Cordon(slot))
        if st.active:
            return  # already out of demand; readmission check owns it
        if self._active_cordons() >= self.max_cordoned:
            self._counts["skipped_budget"] += 1
            return
        self.sim.schedule_action(
            ev.t, lambda t, st=st: self._cordon(t, st), trigger="cordon"
        )

    def _cordon(self, t: float, st: _Cordon) -> bool:
        if st.active or not self.sim.cordon_link(t, *st.slot):
            return False
        st.active = True
        st.since = t
        st.until = t + self.backoff_s(st.strikes)
        st.strikes += 1
        self._counts["cordons"] += 1
        self.sim.schedule_action(
            st.until, lambda tt, st=st: self._readmit(tt, st),
            trigger="cordon",
        )
        return True

    def _readmit(self, t: float, st: _Cordon) -> bool:
        """Backoff expired: readmit only if the slot stayed healthy the
        whole window — otherwise extend with a doubled backoff.  Faults
        keep landing on the mask while cordoned, so relapse is visible
        three ways: the slot is down/gray right now, it failed since the
        cordon began, or its trailing flap window is still above the
        detector threshold (the hot latch fires only once, so the window
        must be read directly — a sustained flapper stays cordoned)."""
        sim = self.sim
        h, k, p = st.slot
        mask = sim.mask
        unhealthy = bool(
            mask.port_down_eg[h, k, p] or mask.port_down_in[h, k, p]
            or mask.ocs_down[h, k] or mask.link_health[h, k, p] < 1.0
        )
        still_hot = False
        last = None
        if sim.health is not None:
            last = sim.health.last_link_failure(h, k, p)
            still_hot = (
                sim.health.flap_score(t, h, k, p) >= sim.health.flap_count
            )
        if unhealthy or still_hot or (last is not None and last > st.since):
            st.since = t  # healthy-residency clock restarts now
            st.until = t + self.backoff_s(st.strikes)
            st.strikes += 1
            self._counts["extensions"] += 1
            sim.schedule_action(
                st.until, lambda tt, st=st: self._readmit(tt, st),
                trigger="cordon",
            )
            return False
        st.active = False
        if sim.readmit_link(t, *st.slot):
            self._counts["readmits"] += 1
            return True
        return False

    # ---- drain + pre-emptive checkpoint ----------------------------------

    def _sickest_pod(self, t: float) -> Optional[int]:
        """The pod to route serving load away from: most active dark
        pairs touching it, plus blocked (down/cordoned) slots, plus gray
        bandwidth shortfall."""
        sim = self.sim
        score = np.zeros(sim.cfg.num_pods)
        for i, j in sim._dark.active(t):
            score[i] += 1.0
            score[j] += 1.0
        blocked = sim.mask.egress_blocked() | sim.mask.ingress_blocked()
        score += blocked.sum(axis=(0, 1))
        score += (1.0 - sim.mask.link_health).sum(axis=(0, 1))
        return int(np.argmax(score)) if float(score.max()) > 0 else None

    def _on_burn(self, ev) -> None:
        sim, t = self.sim, ev.t
        if (
            t - self._last_drain >= self.drain_cooldown_s
            and self._counts["drains"] < self.max_drains
        ):
            pod = self._sickest_pod(t)
            jid = self._drain_target(ev, pod)
            if jid is not None:
                self._last_drain = t
                self._counts["drains"] += 1
                sim.schedule_action(
                    t,
                    lambda tt, j=jid, p=pod: sim.remediate_drain(tt, j, p),
                    trigger="remediation",
                )
        elif self._counts["drains"] >= self.max_drains:
            self._counts["skipped_budget"] += 1
        if (
            sim.cfg.recovery_policy != REWIRE_AROUND
            and t - self._last_ckpt >= self.ckpt_cooldown_s
            and self._counts["ckpts"] < self.max_ckpts
        ):
            jids = [
                j for j, r in sorted(sim.running.items())
                if r.job.kind != "serve"
            ]
            if jids:
                self._last_ckpt = t
                for j in jids[: self.max_ckpts - self._counts["ckpts"]]:
                    self._counts["ckpts"] += 1
                    sim.schedule_action(
                        t,
                        lambda tt, jj=j: sim.preempt_checkpoint(tt, jj),
                        trigger="remediation",
                    )

    def _drain_target(self, ev, pod: Optional[int]) -> Optional[int]:
        """The serving fleet to drain off ``pod``: the burning fleet
        itself when it decodes there, else the first (deterministic) one
        that does and can spare a decode pod."""
        if pod is None:
            return None
        sim = self.sim
        if ev.detector == "slo_burn" and ev.key is not None:
            r = sim.running.get(ev.key)
            if (
                r is not None and pod in r.decode_pods
                and len(r.decode_pods) > 1
            ):
                return ev.key
        for j, r in sorted(sim.running.items()):
            if (
                r.job.kind == "serve" and pod in r.decode_pods
                and len(r.decode_pods) > 1
            ):
                return j
        return None

    # ---- solver escalation -----------------------------------------------

    def _on_fallback(self, ev) -> None:
        if self._counts["solver_escalations"] >= self.max_solver_escalations:
            self._counts["skipped_budget"] += 1
            return
        self._counts["solver_escalations"] += 1
        self.sim.schedule_action(
            ev.t,
            lambda t: self.sim.escalate_solver(t, self.solver_window_s),
            trigger="remediation",
        )

    # ---- introspection ---------------------------------------------------

    def summary(self) -> Dict[str, int]:
        """Action counts of the run (benchmark artifact material)."""
        out = dict(self._counts)
        out["active_cordons"] = self._active_cordons()
        return out
