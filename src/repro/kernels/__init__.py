"""Pallas TPU kernels for the compute hot-spots (+ pure-jnp oracles).

The paper's own contribution is the cluster control plane (no custom
kernels), but the data plane it feeds has three hot-spots worth TPU-native
kernels; each is a ``pl.pallas_call`` with explicit BlockSpec VMEM tiling,
validated in interpret mode against ``ref.py``:

* :mod:`.flash_attention` — tiled online-softmax attention (causal, GQA,
  sliding window, softcap) for the 32k prefill cells.
* :mod:`.rmsnorm` — fused single-pass RMSNorm (memory-bound).
* :mod:`.rwkv6_wkv` — chunked WKV6 linear recurrence with the state in VMEM
  (the long_500k SSM cells).

``ops`` is the dispatching entry layer; ``ref`` holds the oracles.
"""
from . import ops, ref
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .rwkv6_wkv import wkv6

__all__ = ["ops", "ref", "flash_attention", "rmsnorm", "wkv6"]
