"""Pallas TPU flash attention (forward) — the compute hot-spot of every
attention arch in the pool at the 32k-prefill cells.

TPU-native adaptation (not a CUDA port): the kernel is organized around the
MXU and VMEM —

* 4-D grid ``(batch, q_head, q_block, kv_block)`` with the *kv* dimension
  innermost and sequential; the online-softmax running state (m, l, acc)
  lives in VMEM scratch that persists across kv iterations of one q block.
* BlockSpecs tile Q/K/V into ``(block_q, head_dim)`` / ``(block_k, head_dim)``
  VMEM windows; ``head_dim`` and block sizes are multiples of 128 so both
  matmuls (q·kᵀ and p·v) land on the MXU with hardware-aligned shapes.
* GQA is handled in the index map: q head ``h`` reads kv head ``h // group``
  — no KV duplication in HBM.
* Causal + sliding-window masking is computed from ``broadcasted_iota`` and
  fully-masked tiles are skipped with ``pl.when`` (a real TPU grid would
  prune them via the index map; the guard keeps the semantics identical).

Supports: causal or full attention, sliding window, attention-logit softcap
(grok/gemma2), GQA/MQA.  fp32 accumulation regardless of input dtype.

Validated against ``ref.mha_reference`` in interpret mode (tests sweep
shapes, dtypes, window sizes, softcaps, group counts).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128  # TPU vector lane width; m/l scratch padded to it


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1, 1, bq, d), (1, 1, bk, d) VMEM windows
    o_ref,  # (1, 1, bq, d)
    m_scr, l_scr, acc_scr,  # VMEM scratch: (bq, LANES), (bq, LANES), (bq, d)
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    kv_len: int,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # A tile is live unless causality/window rules it out entirely.
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window is not None:
        # newest q position in tile must still see the oldest k position
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = k_pos < kv_len  # mask K padding
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window is not None:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[:, :1]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True)

        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled online-softmax attention.  Returns (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    if Hq % Hkv:
        raise ValueError("num q heads must be a multiple of num kv heads")
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    q_pad = (-Sq) % bq
    k_pad = (-Sk) % bk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    Sq_p, Sk_p = Sq + q_pad, Sk + k_pad
    n_q, n_k = Sq_p // bq, Sk_p // bk

    kernel = functools.partial(
        _flash_kernel,
        scale=float(scale),
        causal=causal,
        window=window,
        softcap=softcap,
        kv_len=Sk,
        block_q=bq,
        block_k=bk,
        num_kv_blocks=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if q_pad:
        out = out[:, :, :Sq]
    return out
