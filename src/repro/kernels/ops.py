"""Public kernel entry points: backend dispatch + layout adaptation.

Each op picks the Pallas TPU kernel on TPU backends and an exact XLA
fallback elsewhere (CPU tests can also force the Pallas path in interpret
mode via ``force_pallas=True``, which is how the correctness suite runs the
kernels on this container).

Layouts at this boundary follow the *model* convention (B, S, H, D); the
kernels use (B, H, S, D) internally for contiguous VMEM tiles.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .rmsnorm import rmsnorm as _rmsnorm
from .rwkv6_wkv import wkv6 as _wkv6


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    force_pallas: bool = False,
) -> jnp.ndarray:
    """Flash attention with model-layout inputs; returns (B, Sq, Hq, D)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if _on_tpu() or force_pallas:
        out = _flash(
            qt, kt, vt,
            causal=causal, window=window, softcap=softcap, scale=scale,
            interpret=not _on_tpu(),
        )
    else:
        out = ref.mha_reference(
            qt, kt, vt, causal=causal, window=window, softcap=softcap, scale=scale
        )
    return jnp.swapaxes(out, 1, 2)


def rmsnorm(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    eps: float = 1e-6,
    force_pallas: bool = False,
) -> jnp.ndarray:
    if _on_tpu() or force_pallas:
        return _rmsnorm(x, scale, eps=eps, interpret=not _on_tpu())
    return ref.rmsnorm_reference(x, scale, eps=eps)


def wkv6(
    r: jnp.ndarray,  # (B, S, H, K)
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,
    u: jnp.ndarray,  # (H, K)
    s0: jnp.ndarray,  # (B, H, K, V)
    *,
    chunk: int = 32,
    force_pallas: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """WKV6 with model-layout inputs; returns (y (B,S,H,V), s_final)."""
    rt, kt, vt, lwt = (jnp.swapaxes(a, 1, 2) for a in (r, k, v, log_w))
    if _on_tpu() or force_pallas:
        y, sf = _wkv6(rt, kt, vt, lwt, u, s0, chunk=chunk, interpret=not _on_tpu())
    else:
        y, sf = ref.wkv6_reference(rt, kt, vt, lwt, u, s0)
    return jnp.swapaxes(y, 1, 2), sf
