"""Pure-jnp oracles for every Pallas kernel — the correctness contracts.

These are deliberately naive (materialize full score matrices, sequential
scans) so the tests compare the tiled kernels against the most obviously
correct implementation.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def mha_reference(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_reference(
    x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * r * scale.astype(jnp.float32)).astype(x.dtype)


def wkv6_reference(
    r: jnp.ndarray,  # (B, H, T, K)
    k: jnp.ndarray,  # (B, H, T, K)
    v: jnp.ndarray,  # (B, H, T, V)
    log_w: jnp.ndarray,  # (B, H, T, K)  (log of per-channel decay, < 0)
    u: jnp.ndarray,  # (H, K)  bonus for the current token
    s0: jnp.ndarray,  # (B, H, K, V)  initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential WKV6:  y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ);
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ.  Exact step-by-step oracle."""
    B, H, T, K = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(log_w.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, inputs):
        rt, kt, vt, wt = inputs  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        att = S + uf[None, :, :, None] * kv
        yt = jnp.einsum("bhk,bhkv->bhv", rt, att)
        S2 = wt[..., :, None] * S + kv
        return S2, yt

    seq = (
        jnp.moveaxis(rf, 2, 0),
        jnp.moveaxis(kf, 2, 0),
        jnp.moveaxis(vf, 2, 0),
        jnp.moveaxis(wf, 2, 0),
    )
    S_final, ys = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    y = jnp.moveaxis(ys, 0, 2)  # (B, H, T, V)
    return y.astype(r.dtype), S_final
