"""Pallas TPU fused RMSNorm.

Memory-bound op: the win is a single HBM pass (read x, write y) instead of
XLA's separate reduce + scale kernels.  Rows are tiled into
``(block_rows, d)`` VMEM windows; the reduction runs in fp32 lanes on the
VPU.  ``d`` should be a multiple of 128 (true for every assigned arch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, d)
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * r * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "block_rows", "interpret")
)
def rmsnorm(
    x: jnp.ndarray,  # (..., d)
    scale: jnp.ndarray,  # (d,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale.reshape(1, d))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
