"""Pallas TPU chunked WKV6 recurrence (RWKV-6 "Finch" time mixing).

The recurrence (per head, state S ∈ ℝ^{K×V}):

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

TPU adaptation: instead of a step-by-step scan (serial, VPU-bound), the
sequence is split into chunks of C tokens; within a chunk everything is
expressed as MXU matmuls + one O(C²·K) masked elementwise decay tensor, and
the (K, V) state is carried across chunks in VMEM scratch (grid's last
dimension is sequential on TPU, so scratch persists across chunk steps).

Numerical stability: all decay ratios are computed as ``exp(Σ log w)`` where
the exponent is a *sum of non-positive terms* (w ∈ (0,1)), so nothing can
overflow — no divisions by decayed-away cumulative products.  Inputs carry
``log_w`` directly (the model computes ``log w = -exp(w_lora)``).

Chunk math (cl = cumsum(log_w) within the chunk, cl_prev = cl shifted):

    inter_t  = (r_t ⊙ exp(cl_prev_t)) · S_in                (C,K)·(K,V) MXU
    A[t,j]   = Σ_k r_t[k] k_j[k] exp(cl_prev_t[k]−cl_j[k])  (j<t)
             = r_t·(u ⊙ k_t)                                (j=t)
    y        = inter + A · v                                (C,C)·(C,V) MXU
    S_out    = diag(exp(cl_C)) S_in + (k ⊙ exp(cl_C−cl))ᵀ · v
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref, k_ref, v_ref, lw_ref,  # (1, 1, C, K) VMEM windows
    u_ref,  # (1, K)
    s0_ref,  # (1, 1, K, V)
    y_ref,  # (1, 1, C, V)
    sout_ref,  # (1, 1, K, V)
    state_scr,  # VMEM (K, V) fp32
    *,
    chunk: int,
    num_chunks: int,
):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)  # (C, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)  # (C, V)
    lw = lw_ref[0, 0].astype(jnp.float32)  # (C, K), all ≤ 0
    u = u_ref[0].astype(jnp.float32)  # (K,)
    S = state_scr[...]  # (K, V)

    cl = jnp.cumsum(lw, axis=0)  # (C, K)
    cl_prev = cl - lw  # exclusive cumsum: Σ_{i<t} log w_i

    # inter-chunk contribution: y_t += (r_t ⊙ W_{t-1}) · S_in
    r_decay = r * jnp.exp(cl_prev)  # (C, K)
    inter = jax.lax.dot_general(
        r_decay, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, V)

    # intra-chunk attention matrix A (C, C): exponent ≤ 0 for j < t
    diff = cl_prev[:, None, :] - cl[None, :, :]  # (C, C, K)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (j_idx < t_idx)[:, :, None]
    decay = jnp.where(strict, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    A = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=-1)  # (C, C)
    A = A + jnp.where(
        t_idx == j_idx, jnp.sum(r * u[None, :] * k, axis=-1)[:, None], 0.0
    )
    intra = jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0, 0] = (inter + intra).astype(y_ref.dtype)

    # state update: S_out = diag(exp(cl_C)) S_in + (k ⊙ exp(cl_C − cl))ᵀ · v
    total = cl[-1]  # (K,)
    k_decay = k * jnp.exp(total[None, :] - cl)  # (C, K), exponent ≤ 0
    S_new = jnp.exp(total)[:, None] * S + jax.lax.dot_general(
        k_decay, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_scr[...] = S_new

    @pl.when(it == num_chunks - 1)
    def _emit_state():
        sout_ref[0, 0] = S_new.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jnp.ndarray,  # (B, H, T, K)
    k: jnp.ndarray,  # (B, H, T, K)
    v: jnp.ndarray,  # (B, H, T, V)
    log_w: jnp.ndarray,  # (B, H, T, K), entries < 0
    u: jnp.ndarray,  # (H, K)
    s0: jnp.ndarray,  # (B, H, K, V)
    *,
    chunk: int = 32,
    interpret: bool = False,
):
    """Chunked WKV6.  Returns (y (B,H,T,V), s_final (B,H,K,V))."""
    B, H, T, K = r.shape
    V = v.shape[-1]
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        log_w = jnp.pad(log_w, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    n = Tp // C

    y, s_fin = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=C, num_chunks=n),
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, C, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, C, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, C, V), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, C, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, K), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, C, V), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tp, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u, s0)
    if pad:
        y = y[:, :, :T]
    return y, s_fin
