import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × shape cell × mesh) this lowers + compiles the real
train/prefill/decode step against ShapeDtypeStruct stand-ins (no allocation),
prints ``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes for
the roofline), parses the compiled HLO for collective traffic, and writes one
JSON artifact per cell under ``artifacts/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v3-671b \
      --shape train_4k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""

import argparse  # noqa: E402  (XLA_FLAGS must be set before jax imports)
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..dist.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
    to_shardings,
)
from ..launch import hloparse
from ..launch.inputs import SHAPES, ShapeCell, cells_for, dryrun_model_config, input_specs
from ..launch.mesh import make_production_mesh, mesh_axis_sizes
from ..models import ARCHS, get_api
from ..train.optimizer import OptConfig
from ..train.trainstep import TrainHparams, make_train_state, make_train_step

# ---- TPU v5e-class hardware constants (roofline denominators) -------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link
CHIPS_PER_POD = 256


def _mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


def cell_policy(cfg, cell, mesh) -> Dict[str, Any]:
    """Per-cell production config: grad-accum microbatching sized so the
    remat-saved residual stream fits HBM, and FSDP when parameters cannot
    replicate across DP ranks.  Recorded in the artifact (these are real
    deployment choices, not benchmarks knobs)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    model = sizes.get("model", 1)
    n_total, _ = cfg.param_counts()
    pbytes = 2  # bf16 params
    fsdp = (n_total * pbytes) / model > 4 << 30  # >4 GiB/chip replicated
    accum = 1
    if cell.kind == "train":
        units = cfg.num_layers
        if cfg.block_pattern:
            units = cfg.num_layers // len(cfg.block_pattern)
        act = (cell.batch // dp) * cell.seq * cfg.d_model * 2 * units
        target = 6 << 30  # ≤6 GiB of saved residuals per chip
        while accum < 16 and act / accum > target:
            accum *= 2
    return {"fsdp": fsdp, "grad_accum": accum}


def run_cell(
    arch: str,
    shape_name: str,
    mesh,
    optimized: bool = False,
    out_dir: str = "artifacts/dryrun",
    ga_override: Optional[int] = None,
) -> Dict[str, Any]:
    cell = SHAPES[shape_name]
    cfg = dryrun_model_config(arch)
    api = get_api(cfg)
    num_devices = int(np.prod(mesh.devices.shape))
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": _mesh_tag(mesh),
        "optimized": optimized,
        "ok": False,
    }
    t0 = time.perf_counter()
    try:
        from ..models import shard_hints

        if optimized:
            # beyond-paper data plane: explicit activation sharding hints
            # (+ hierarchical grad collectives for train cells)
            shard_hints.use_hints(mesh)
        specs = input_specs(cfg, cell)
        pol = cell_policy(cfg, cell, mesh)
        if ga_override is not None:
            pol["grad_accum"] = ga_override
        rec["policy"] = pol
        if cell.kind == "train":
            # hierarchical shard_map collectives assume DP-replicated params
            # (ZeRO-1); FSDP cells keep the pjit path (+ hints) instead
            hp = TrainHparams(
                zero1=True,
                hierarchical=optimized and not pol["fsdp"],
                fsdp=pol["fsdp"],
                grad_accum=pol["grad_accum"],
            )
            step_fn, s_shard, b_shard = make_train_step(
                api, cfg, OptConfig(), mesh, hp, specs
            )
            state_sds = jax.eval_shape(
                lambda k: make_train_state(api, k), jax.random.PRNGKey(0)
            )
            lowered = step_fn.lower(state_sds, specs)
        else:
            p_shard = to_shardings(
                param_specs(
                    jax.eval_shape(api.init, jax.random.PRNGKey(0)), mesh, cfg,
                    fsdp=pol["fsdp"],  # 2D weight sharding for ≥300B serving
                ),
                mesh,
            )
            c_shard = to_shardings(
                cache_specs(
                    specs["cache"], mesh, cfg, seq_shard=(shape_name == "long_500k")
                ),
                mesh,
            )
            if cell.kind == "prefill":
                b_shard = to_shardings(batch_specs(specs["batch"], mesh), mesh)

                def prefill_last(p, b, c):
                    return api.prefill(p, b, c, last_only=True)

                fn = jax.jit(prefill_last, in_shardings=(p_shard, b_shard, c_shard))
                lowered = fn.lower(
                    jax.eval_shape(api.init, jax.random.PRNGKey(0)),
                    specs["batch"],
                    specs["cache"],
                )
            else:
                t_shard = to_shardings(batch_specs({"t": specs["tokens"]}, mesh), mesh)["t"]
                fn = jax.jit(api.decode, in_shardings=(p_shard, t_shard, c_shard))
                lowered = fn.lower(
                    jax.eval_shape(api.init, jax.random.PRNGKey(0)),
                    specs["tokens"],
                    specs["cache"],
                )
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)

        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
            cost = cost[0] if cost else {}
        # NOTE: cost_analysis counts while (scan) bodies ONCE and reports
        # post-partition (per-device) numbers — kept for reference only;
        # the roofline uses the loop-corrected hloparse.analyze() numbers.
        rec["xla_cost_flops_uncorrected"] = float(cost.get("flops", 0.0))
        rec["xla_cost_bytes_uncorrected"] = float(cost.get("bytes accessed", 0.0))

        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            ):
                v = getattr(mem, attr, None)
                if v is not None:
                    rec[attr] = int(v)
            args = rec.get("argument_size_in_bytes", 0)
            tmp = rec.get("temp_size_in_bytes", 0)
            out = rec.get("output_size_in_bytes", 0)
            alias = rec.get("alias_size_in_bytes", 0)
            rec["per_device_bytes"] = int(args + tmp + out - alias)

        hlo = compiled.as_text()
        ana = hloparse.analyze(hlo, chips_per_pod=CHIPS_PER_POD)
        rec["collectives"] = ana.collectives
        # all analyzer numbers are PER-DEVICE and trip-count-corrected
        rec["hlo_flops"] = float(ana.flops)  # per-chip
        rec["hlo_bytes"] = float(ana.bytes)  # per-chip HBM traffic
        rec["collective_bytes"] = float(ana.collective_bytes)
        rec["cross_pod_bytes"] = float(ana.cross_pod_bytes)

        # ---- roofline terms (seconds, per chip) -------------------------
        rec["compute_s"] = rec["hlo_flops"] / PEAK_FLOPS
        rec["memory_s"] = rec["hlo_bytes"] / HBM_BW
        rec["collective_s"] = rec["collective_bytes"] / LINK_BW
        terms = {
            "compute": rec["compute_s"],
            "memory": rec["memory_s"],
            "collective": rec["collective_s"],
        }
        rec["bottleneck"] = max(terms, key=terms.get)

        # ---- MODEL_FLOPS (useful-compute ratio) ------------------------
        n_total, n_active = cfg.param_counts()
        tokens = cell.batch * (cell.seq if cell.kind != "decode" else 1)
        model_flops = (6 if cell.kind == "train" else 2) * n_active * tokens
        rec["params_total"] = n_total
        rec["params_active"] = n_active
        rec["model_flops"] = float(model_flops)  # global
        per_chip_model = model_flops / num_devices
        rec["useful_ratio"] = (
            per_chip_model / rec["hlo_flops"] if rec["hlo_flops"] else 0.0
        )
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — dry-run reports failures as data
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    finally:
        from ..models import shard_hints

        shard_hints.use_hints(None)
        rec["total_s"] = round(time.perf_counter() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{rec['mesh']}" + ("__opt" if optimized else "")
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def summarize(rec: Dict[str, Any]) -> str:
    if not rec["ok"]:
        return (
            f"FAIL {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:9s} "
            f"{rec.get('error','')[:90]}"
        )
    return (
        f"ok   {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:9s} "
        f"compile={rec['compile_s']:7.1f}s flops={rec['hlo_flops']:.3e} "
        f"dev_mem={rec.get('per_device_bytes', 0)/2**30:6.2f}GiB "
        f"coll={rec['collective_bytes']:.3e}B bottleneck={rec['bottleneck']}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument("--ga", type=int, default=None, help="grad-accum override")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(make_production_mesh(multi_pod=False))
    if args.mesh in ("multi", "both"):
        meshes.append(make_production_mesh(multi_pod=True))

    if args.all:
        jobs = [
            (arch, shape) for arch in ARCHS for shape in cells_for(arch)
        ]
    else:
        if not args.arch:
            raise SystemExit("--arch required unless --all")
        shapes = [args.shape] if args.shape else list(cells_for(args.arch))
        jobs = [(args.arch, s) for s in shapes]

    failures = 0
    for mesh in meshes:
        for arch, shape in jobs:
            rec = run_cell(
                arch, shape, mesh, optimized=args.optimized, out_dir=args.out,
                ga_override=args.ga,
            )
            print(summarize(rec), flush=True)
            failures += 0 if rec["ok"] else 1
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
