"""Loop-aware post-SPMD HLO analysis: FLOPs, HBM traffic, collectives.

``compiled.cost_analysis()`` counts every ``while`` body ONCE and therefore
under-reports scanned-layer models by ~num_layers×; it also reports
post-partition (per-device) numbers.  This module parses the compiled HLO
text instead, propagating loop **trip counts** (from the
``known_trip_count`` backend config XLA attaches to jax scans) through the
computation call graph, so every roofline term is measured *and*
loop-corrected.  All results are per-device (post-SPMD shapes).

Accounting rules:

* **flops** — ``dot`` ops: 2 × output elements × contracted size (looked up
  from the lhs operand's shape).  Everything else (elementwise, reductions)
  is ignored — matmul-dominated workloads, consistent with MFU convention.
* **bytes** — HBM traffic approximation: for ops at *non-fusion* scope
  (ENTRY, while bodies, conditional branches), output + operand bytes;
  fusion internals are VMEM/register traffic and are skipped (the fusion op
  itself accounts its operands/outputs).  ``dynamic-(update-)slice`` and
  ``gather``/``scatter`` count the *touched region* (slice/update size),
  not the full aliased buffer.  ``bitcast``/``tuple``/``get-tuple-element``
  /``parameter``/``constant`` are views: zero.
* **collectives** — operand bytes per op class, plus in-pod/cross-pod
  split from replica groups; multiplied by the enclosing trip counts.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "u1": 1,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# ops whose operands are aliased views — count only the touched region
_VIEW_OPS = frozenset(
    ("bitcast", "tuple", "get-tuple-element", "parameter", "constant",
     "after-all", "iota", "reshape", "while", "conditional", "call",
     "optimization-barrier", "partition-id", "replica-id")
)
_SLICE_OPS = frozenset(
    ("dynamic-slice", "dynamic-update-slice", "gather", "scatter", "slice",
     "pad", "concatenate")
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)
_DUS_SIZES_RE = re.compile(r"dynamic_slice_sizes=\{([0-9,]*)\}")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _shape_bytes(shape_str: str) -> int:
    return _shape_elems_bytes(shape_str)[1]


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


class Instr:
    __slots__ = ("name", "shape", "op", "rest")

    def __init__(self, name, shape, op, rest):
        self.name = name
        self.shape = shape
        self.op = op
        self.rest = rest


class Computation:
    def __init__(self, name: str, is_entry: bool):
        self.name = name
        self.is_entry = is_entry
        self.instrs: List[Instr] = []
        self.called_as_fusion = False

    def root(self) -> Optional["Instr"]:
        # HLO text lists the ROOT instruction last within its computation
        return self.instrs[-1] if self.instrs else None


def _parse_computations(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1), line.startswith("ENTRY"))
                comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(*m.groups()))
    return comps


def _parse_groups(rest: str):
    m = _GROUPS_RE.search(rest)
    if m:
        return [
            [int(x) for x in g.strip("{}").split(",") if x.strip() != ""]
            for g in re.findall(r"\{[^}]*\}", m.group(1))
        ]
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        import numpy as np

        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else None
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if perm is not None:
            ids = ids.transpose(perm)
        return ids.reshape(ng, gs).tolist()
    return None


def _fusion_traffic(fcomp: Computation, shapes: Dict[str, str]) -> float:
    """HBM traffic of one fusion call, alias-aware.

    Parameters that are only *sliced* inside the fusion count at the slice
    size; parameters that are *updated in place* (dynamic-update-slice with
    the parameter as the destination) count at the update size; the output
    counts at the update size when the root is (a tuple of) in-place
    updates.  This is what keeps per-scan-iteration activation stacking
    from being billed at full-stack size every layer.
    """
    # name -> underlying parameter name through view chains
    src: Dict[str, str] = {}
    param_bytes: Dict[str, int] = {}
    for ins in fcomp.instrs:
        if ins.op == "parameter":
            src[ins.name] = ins.name
            param_bytes[ins.name] = _shape_bytes(ins.shape)
        elif ins.op in ("bitcast", "reshape", "copy", "transpose"):
            ops = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
            if ops and ops[0] in src:
                src[ins.name] = src[ops[0]]

    sliced: Dict[str, int] = {}  # param -> touched bytes
    updated: Dict[str, int] = {}
    for ins in fcomp.instrs:
        ops = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
        if ins.op == "dynamic-slice" and ops and ops[0] in src:
            p = src[ops[0]]
            sliced[p] = sliced.get(p, 0) + _shape_bytes(ins.shape)
        elif ins.op == "dynamic-update-slice" and ops and ops[0] in src:
            p = src[ops[0]]
            upd = _shape_bytes(shapes.get(ops[1], "")) if len(ops) > 1 else 0
            updated[p] = updated.get(p, 0) + upd

    total = 0.0
    for pname, pb in param_bytes.items():
        if pname in updated:
            total += updated[pname]  # read-modify-write of the region
        elif pname in sliced:
            total += min(pb, sliced[pname])
        else:
            total += pb

    root = fcomp.root()
    out_bytes = _shape_bytes(root.shape) if root else 0.0
    if root is not None:
        roots = [root]
        if root.op == "tuple":
            names = _OPERAND_RE.findall(root.rest.split(")", 1)[0])
            by_name = {i.name: i for i in fcomp.instrs}
            roots = [by_name[n] for n in names if n in by_name]
        dus_out = 0
        all_dus = True
        for r in roots:
            if r.op == "dynamic-update-slice":
                ops = _OPERAND_RE.findall(r.rest.split(")", 1)[0])
                if len(ops) > 1 and ops[1] in shapes:
                    dus_out += _shape_bytes(shapes[ops[1]])
                    continue
            all_dus = False
        if all_dus and roots:
            out_bytes = dus_out
    return total + out_bytes


class HloAnalysis:
    """Per-device, trip-count-corrected roofline inputs."""

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.collectives: Dict[str, Dict[str, float]] = {
            op: {"bytes": 0.0, "count": 0.0, "cross_pod_bytes": 0.0}
            for op in COLLECTIVE_OPS
        }

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())

    @property
    def cross_pod_bytes(self) -> float:
        return sum(v["cross_pod_bytes"] for v in self.collectives.values())


def analyze(hlo_text: str, chips_per_pod: Optional[int] = None) -> HloAnalysis:
    comps = _parse_computations(hlo_text)

    # global symbol table: instruction name -> result shape string
    shapes: Dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shapes[ins.name] = ins.shape

    # ---- call-graph multipliers ------------------------------------------
    mult: Dict[str, float] = {}
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: largest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    fusion_scope: Dict[str, bool] = {entry.name: False}
    mult[entry.name] = 1.0
    stack = [entry.name]
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        in_fusion = fusion_scope.get(cname, False)
        for ins in comp.instrs:
            callees: List[Tuple[str, float, bool]] = []
            if ins.op == "while":
                t = _TRIP_RE.search(ins.rest)
                trip = float(t.group(1)) if t else 1.0
                b = _BODY_RE.search(ins.rest)
                c = _COND_RE.search(ins.rest)
                if b:
                    callees.append((b.group(1), trip, in_fusion))
                if c:
                    callees.append((c.group(1), trip, in_fusion))
            elif ins.op == "fusion":
                f = _CALLS_RE.search(ins.rest)
                if f:
                    callees.append((f.group(1), 1.0, True))
            elif ins.op in ("call", "custom-call", "async-start"):
                f = _TO_APPLY_RE.search(ins.rest) or _CALLS_RE.search(ins.rest)
                if f:
                    callees.append((f.group(1), 1.0, in_fusion))
            elif ins.op == "conditional":
                br = _BRANCHES_RE.search(ins.rest)
                if br:
                    for name in _OPERAND_RE.finditer(br.group(1)):
                        callees.append((name.group(1), 1.0, in_fusion))
            elif ins.op in ("reduce", "sort", "scatter", "map", "reduce-window",
                            "select-and-scatter", "all-reduce", "reduce-scatter"):
                # applied computations are scalar lambdas — negligible
                continue
            for callee, k, fus in callees:
                new_m = m * k
                if callee not in mult or mult[callee] < new_m:
                    mult[callee] = max(mult.get(callee, 0.0), new_m)
                    fusion_scope[callee] = fus
                    stack.append(callee)
                elif fusion_scope.get(callee, True) and not fus:
                    fusion_scope[callee] = fus
                    stack.append(callee)

    out = HloAnalysis()
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue  # unreachable (dead) computation
        in_fusion = fusion_scope.get(cname, False)
        for ins in comp.instrs:
            # ---- flops: dots anywhere -----------------------------------
            if ins.op == "dot":
                ops = _OPERAND_RE.findall(ins.rest.split(", lhs_contracting", 1)[0])
                cd = _LHS_CDIMS_RE.search(ins.rest)
                k = 1
                if ops and cd and ops[0] in shapes:
                    lhs_dims = _shape_dims(shapes[ops[0]])
                    for d in (cd.group(1).split(",") if cd.group(1) else []):
                        di = int(d)
                        if di < len(lhs_dims):
                            k *= lhs_dims[di]
                elems, _ = _shape_elems_bytes(ins.shape)
                out.flops += m * 2.0 * elems * k
            elif ins.op == "convolution":
                # rare here (frontends stubbed); approximate 2·out·k via
                # operand-1 size — negligible in our models, counted coarse
                elems, _ = _shape_elems_bytes(ins.shape)
                out.flops += m * 2.0 * elems

            # ---- collectives ----------------------------------------------
            base = None
            for c in COLLECTIVE_OPS:
                if ins.op == c or ins.op == c + "-start":
                    base = c
                    break
            if base is not None:
                args = ins.rest.split(")", 1)[0]
                ob = 0
                for om in _OPERAND_RE.finditer(args):
                    if om.group(1) in shapes:
                        ob += _shape_bytes(shapes[om.group(1)])
                if ob == 0:
                    ob = _shape_bytes(ins.shape)
                rec = out.collectives[base]
                rec["bytes"] += m * ob
                rec["count"] += m
                if chips_per_pod:
                    groups = _parse_groups(ins.rest)
                    if groups and any(
                        len({d // chips_per_pod for d in g}) > 1 for g in groups
                    ):
                        rec["cross_pod_bytes"] += m * ob

            # ---- HBM bytes (non-fusion scope only) -------------------------
            if in_fusion or ins.op in _VIEW_OPS:
                continue
            if ins.op == "fusion":
                f = _CALLS_RE.search(ins.rest)
                if f and f.group(1) in comps:
                    out.bytes += m * _fusion_traffic(comps[f.group(1)], shapes)
                    continue
            if ins.op in _SLICE_OPS:
                # touched region ≈ 2 × smaller of (output, update) size
                sz = _shape_bytes(ins.shape)
                ds = _DUS_SIZES_RE.search(ins.rest)
                if ins.op == "dynamic-update-slice":
                    # update operand is the 2nd arg; use its shape if known
                    ops = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
                    if len(ops) >= 2 and ops[1] in shapes:
                        sz = _shape_bytes(shapes[ops[1]])
                elif ds:
                    dims = [int(x) for x in ds.group(1).split(",") if x]
                    n = 1
                    for d in dims:
                        n *= d
                    sz = min(sz, n * 4)
                out.bytes += m * 2.0 * sz
                continue
            # general op: output + operands
            total = _shape_bytes(ins.shape)
            args = ins.rest.split(")", 1)[0]
            for om in _OPERAND_RE.finditer(args):
                if om.group(1) in shapes:
                    total += _shape_bytes(shapes[om.group(1)])
            out.bytes += m * total
    return out


# ---------------------------------------------------------------------------
# backwards-compatible helpers (dryrun.py API)
# ---------------------------------------------------------------------------

def parse_collectives(
    hlo_text: str, chips_per_pod: Optional[int] = None, num_devices: int = 0
) -> Dict[str, Dict[str, float]]:
    return analyze(hlo_text, chips_per_pod=chips_per_pod).collectives


def total_collective_bytes(parsed: Dict[str, Dict[str, float]]) -> float:
    return sum(v["bytes"] for v in parsed.values())


def total_cross_pod_bytes(parsed: Dict[str, Dict[str, float]]) -> float:
    return sum(v["cross_pod_bytes"] for v in parsed.values())
