"""Shape cells and ShapeDtypeStruct input specs for the dry-run.

Each assigned architecture is paired with the LM shape set:
  train_4k     seq 4096,   global_batch 256   (train_step)
  prefill_32k  seq 32768,  global_batch 32    (serve prefill)
  decode_32k   seq 32768,  global_batch 128   (serve decode, 1 new token)
  long_500k    seq 524288, global_batch 1     (long-context decode)

Skip rules (per assignment + DESIGN.md §4): long_500k only for ssm/hybrid
(rwkv6, jamba); everything else runs all of train/prefill/decode.
Modality frontends are stubs: whisper gets frame embeddings, internvl2 gets
patch embeddings, as precomputed inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ARCHS, ModelConfig, get_api

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

LONG_CONTEXT_ARCHS = ("rwkv6-1.6b", "jamba-1.5-large-398b")


def cells_for(arch: str) -> Tuple[str, ...]:
    base = ("train_4k", "prefill_32k", "decode_32k")
    if arch in LONG_CONTEXT_ARCHS:
        return base + ("long_500k",)
    return base


def dryrun_model_config(arch: str) -> ModelConfig:
    """Full config tuned for lowering: activation checkpointing on the layer
    stacks (production norm at 4k seq — recompute attention probs in bwd)."""
    return ARCHS[arch].replace(remat_policy="full")


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the lowered step's *data* arguments.

    train:   {"tokens","targets"(,"frames"/"patches")}
    prefill: {"batch": ..., "cache": zero-shaped cache}
    decode:  {"tokens": (B,1), "cache": cache at full seq}
    """
    B, L = cell.batch, cell.seq
    api = get_api(cfg)
    i32 = jnp.int32

    def modality(d: Dict[str, Any], batch: int) -> Dict[str, Any]:
        if cfg.family == "audio":
            d["frames"] = S((batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            d["patches"] = S((batch, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
        return d

    if cell.kind == "train":
        return modality({"tokens": S((B, L), i32), "targets": S((B, L), i32)}, B)
    cache = jax.eval_shape(lambda: api.init_cache(B, L))
    if cell.kind == "prefill":
        return {
            "batch": modality({"tokens": S((B, L), i32)}, B),
            "cache": cache,
        }
    return {"tokens": S((B, 1), i32), "cache": cache}
