"""Production mesh construction.

The mesh mirrors the paper's cluster architecture (§3.1): the ``model`` axis
is the intra-pod electrical domain (TP/EP traffic confined in-pod), the
``data`` axis spans a pod's DP groups, and the ``pod`` axis crosses the OCS
optical core — exactly the traffic Cross Wiring engineers.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

PodMesh = Tuple[int, int]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single-pod or (2, 16, 16) two-pod production mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small shapes on 1..8 host devices)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None):
    """Mesh over whatever devices exist (CPU tests): (data, model)."""
    n = len(jax.devices())
    model = model or 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism (pod × data when multi-pod)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
