"""Batched serving driver: prefill + greedy KV-cache decode.

Example (CPU container):
  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .. import configs
from ..models import get_api, smoke_config
from ..serve.engine import ServeEngine
import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    inputs = {
        "tokens": rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len)
        ).astype(np.int32)
    }
    if cfg.family == "audio":
        inputs["frames"] = rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)
        ).astype(np.float32)
    if cfg.family == "vlm":
        inputs["patches"] = rng.normal(
            size=(args.batch, cfg.vision_tokens, cfg.vision_dim)
        ).astype(np.float32)

    s_max = args.prompt_len + args.max_new + (
        cfg.vision_tokens if cfg.family == "vlm" else 0
    ) + 2
    eng = ServeEngine(api, params, batch=args.batch, s_max=s_max)

    t0 = time.perf_counter()
    out = eng.generate(inputs, max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s → {toks/dt:,.1f} tok/s")
    print("first row:", out[0][:12].tolist())


if __name__ == "__main__":
    main()
