"""End-to-end training driver: control plane (Cross Wiring) + data plane.

The launcher mirrors the paper's running-stage workflow (§2.1):

1. **Scheduler / control plane** — the job is placed onto pods of the
   OCS cluster; its parallelism plan (TP/EP in-pod, DP ring across pods)
   becomes a logical-topology demand; MDMCF computes the OCS configuration
   (polynomial time) and reports LTRR + reconfiguration wall time.
2. **Data plane** — the sharded train step runs under the JAX mesh whose
   axes mirror the cluster (model=in-pod electrical, data/pod=across the
   optical core), with checkpointing and auto-resume.

On this CPU container use ``--smoke`` (reduced config, host mesh).  On a
real TPU/Trainium fleet the same script runs the full config on the
production mesh.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..ckpt.manager import latest_step, restore_checkpoint, save_checkpoint
from ..core.logical import ring_demand
from ..core.reconfig import mdmcf_reconfigure
from ..core.topology import ClusterSpec
from ..models import get_api, smoke_config
from ..train.data import DataConfig, SyntheticData
from ..train.optimizer import OptConfig
from ..train.trainstep import TrainHparams, make_train_state, make_train_step
from .mesh import make_host_mesh, make_production_mesh


def control_plane(arch: str, num_pods_used: int, cluster_pods: int = 8):
    """Place the job, derive its OCS demand, run MDMCF.  Returns a report."""
    spec = ClusterSpec(num_pods=cluster_pods, k_spine=16, k_leaf=16)
    plan = configs.get_plan(arch)
    pods = tuple(range(num_pods_used))
    demand = configs.job_demand(plan, spec, pods)
    t0 = time.perf_counter()
    res = mdmcf_reconfigure(spec, demand) if demand.any() else None
    dt = time.perf_counter() - t0
    return {
        "spec": spec,
        "plan": plan,
        "pods": pods,
        "demand_links": int(demand.sum() // 2),
        "ltrr": (res.ltrr if res is not None else 1.0),
        "reconfig_s": dt,
        "config": (res.config if res is not None else None),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", help="reduced config on host mesh")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--pods", type=int, default=2, help="pods the job occupies")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    # ---- control plane ----------------------------------------------------
    cp = control_plane(args.arch, args.pods)
    print(
        f"[control-plane] arch={args.arch} pods={cp['pods']} "
        f"plan(tp={cp['plan'].tp}, ep={cp['plan'].ep}) "
        f"demand={cp['demand_links']} links  LTRR={cp['ltrr']:.3f} "
        f"mdmcf={cp['reconfig_s']*1e3:.1f} ms"
    )

    # ---- data plane ---------------------------------------------------------
    cfg = smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    api = get_api(cfg)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    data = SyntheticData(
        DataConfig(vocab_size=cfg.vocab_size, batch=args.batch, seq=args.seq),
        model_cfg=cfg,
    )
    opt = OptConfig(lr=args.lr, warmup_steps=5, total_steps=max(args.steps, 10))
    hp = TrainHparams(
        grad_accum=args.grad_accum,
        hierarchical=args.hierarchical,
        compress=args.compress,
        zero1=args.zero1,
    )
    b0 = data.batch_at(0)
    sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in b0.items()}
    step_fn, s_shard, _ = make_train_step(api, cfg, opt, mesh, hp, sds)

    state = make_train_state(api, jax.random.PRNGKey(0))
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start = latest_step(args.ckpt_dir) + 1
        state = restore_checkpoint(
            args.ckpt_dir,
            jax.eval_shape(lambda: make_train_state(api, jax.random.PRNGKey(0))),
        )
        print(f"[resume] from step {start - 1}")

    pending = None
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            toks = args.batch * args.seq * (i - start + 1)
            dt = time.perf_counter() - t0
            print(
                f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                f"lr {float(metrics['lr']):.2e}  {toks/dt:,.0f} tok/s"
            )
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = save_checkpoint(args.ckpt_dir, i, state, background=True)
    if pending is not None:
        pending.join()
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps - 1, state)
        print(f"[ckpt] final at step {args.steps - 1}")


if __name__ == "__main__":
    main()
