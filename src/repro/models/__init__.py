"""Model substrate: 10 assigned architectures behind one functional API."""
from .config import MLAConfig, MambaConfig, ModelConfig, MoEConfig, RWKVConfig
from .registry import ARCHS, ModelAPI, get_api, make_smoke_batch, smoke_config
