"""Attention variants: GQA/MQA (+softcap, sliding window, bias), MLA
(DeepSeek-V3 latent attention with compressed-cache absorbed decode),
and cross-attention (whisper).

All functions are cache-polymorphic:

* ``cache=None``            — training / scoring over a full sequence
* ``cache=(…), pos=None``   — prefill: full sequence, cache slices written
* ``cache=(…), pos=scalar`` — decode: single-token step, cache updated

Shapes: x (B, S, d); GQA cache k/v (B, S_max, Hkv, Dh); MLA cache
(c_kv (B, S_max, R), k_rope (B, S_max, Dr)).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import shard_hints
from .layers import apply_rope, dense_init, norm, softcap

BIG_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, kv_pos, window, valid_len=None):
    """Additive fp32 mask: causal + sliding window + cache validity."""
    q = q_pos[:, None]
    k = kv_pos[None, :]
    ok = k <= q
    ok &= k > q - window
    if valid_len is not None:
        ok &= k < valid_len
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


ATTN_Q_CHUNK = 1024  # flash-pattern query blocking for the XLA path


def sdpa_chunked(
    qg, kv_k, kv_v, q_pos, kv_pos, *, scale, window, cap, valid, causal=True,
    chunk=ATTN_Q_CHUNK,
):
    """Exact attention, scanned over query blocks.

    qg: (B, Sq, Hkv, G, Dq); kv_k: (B, Sk, Hkv, Dq); kv_v: (B, Sk, Hkv, Dv).
    Never materializes the full (…, Sq, Sk) score tensor — peak extra memory
    is O(chunk × Sk).  This is the flash-attention access pattern expressed
    in XLA; the Pallas kernel (repro.kernels.flash_attention) is the
    TPU-native version of the same contract.
    """
    B, Sq, hkv, g, dq = qg.shape
    dv = kv_v.shape[-1]
    if Sq <= 2 * chunk or Sq % chunk:
        sc = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, kv_k, preferred_element_type=jnp.float32
        ) * scale
        sc = softcap(sc, cap)
        if causal:
            sc = sc + _mask_bias(q_pos, kv_pos, window, valid)
        pr = jax.nn.softmax(sc, axis=-1).astype(qg.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", pr, kv_v)

    n = Sq // chunk
    qc = jnp.moveaxis(qg.reshape(B, n, chunk, hkv, g, dq), 1, 0)
    pc = jnp.moveaxis(q_pos.reshape(n, chunk), 0, 0)

    @jax.checkpoint
    def block(q_blk, pos_blk):
        sc = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q_blk, kv_k, preferred_element_type=jnp.float32
        ) * scale
        sc = softcap(sc, cap)
        if causal:
            sc = sc + _mask_bias(pos_blk, kv_pos, window, valid)
        pr = jax.nn.softmax(sc, axis=-1).astype(q_blk.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", pr, kv_v)

    def body(_, xs):
        q_blk, pos_blk = xs
        return None, block(q_blk, pos_blk)

    _, out = jax.lax.scan(body, None, (qc, pc))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, hkv, g, dv)


# ---------------------------------------------------------------------------
# head padding for mesh-divisible sharding (optimized data plane, §Perf)
# ---------------------------------------------------------------------------

import numpy as _np


def _head_pad_plan(hq: int, hkv: int, max_waste: float = 1.26):
    """Pad (hq, hkv) to mesh-divisible counts by replicating kv heads r×
    and permuting q heads into the padded group structure.

    Returns (r, hkv_p, g_p, hq_p, perm, inv) or None when heads already
    divide the model axis / padding would waste > ``max_waste`` compute.
    ``perm[slot] = original q head or -1 (zero pad)``; ``inv`` maps
    original head -> padded slot.  Exactness: padded slots are sliced away
    before the output projection (tested against the unpadded path).
    """
    m = shard_hints.model_size()
    if m <= 1 or (hq % m == 0 and hkv % m == 0):
        return None
    r = m // math.gcd(hkv, m)
    hkv_p = hkv * r
    if hkv_p % m:
        return None
    g = hq // hkv
    g_p = -(-hq // hkv_p)
    hq_p = g_p * hkv_p
    if hq_p > hq * max_waste or g > r * g_p:
        return None
    perm = _np.full(hq_p, -1, dtype=_np.int64)
    for j in range(hkv):
        for t in range(g):
            c, p = divmod(t, g_p)
            perm[(j * r + c) * g_p + p] = j * g + t
    inv = _np.zeros(hq, dtype=_np.int64)
    for s, o in enumerate(perm):
        if o >= 0:
            inv[o] = s
    return r, hkv_p, g_p, hq_p, jnp.asarray(perm), jnp.asarray(inv)


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, cfg.pdtype),
        "wk": dense_init(ks[1], d, hkv * hd, cfg.pdtype),
        "wv": dense_init(ks[2], d, hkv * hd, cfg.pdtype),
        "wo": dense_init(ks[3], hq * hd, d, cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.pdtype)
    return p


def gqa_attention(
    params: dict,
    x: jnp.ndarray,
    cfg,
    *,
    window=None,
    causal: bool = True,
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    pos: Optional[jnp.ndarray] = None,
):
    """Returns (y, new_cache).  ``window``: None→cfg/sliding default handling
    is done by the caller (pass an int or traced scalar)."""
    B, S, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = hq // hkv
    w = BIG_WINDOW if window is None else window

    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = shard_hints.hint_bshd(q.reshape(B, S, hq, hd))
    k = shard_hints.hint_bshd(k.reshape(B, S, hkv, hd))
    v = shard_hints.hint_bshd(v.reshape(B, S, hkv, hd))

    if cache is None or pos is None:  # train / prefill: positions 0..S-1
        q_pos = jnp.arange(S)
    else:  # decode
        q_pos = jnp.asarray(pos)[None]
    if cfg.use_rope:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        ck, cv = cache
        if pos is None:  # prefill: write [0:S]
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, 0, 0))
            kv_k, kv_v = k, v
            kv_pos = jnp.arange(S)
            valid = None
        else:  # decode: write at pos, attend over cache
            ck = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, jnp.asarray(pos), 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, jnp.asarray(pos), 0, 0)
            )
            kv_k, kv_v = ck.astype(x.dtype), cv.astype(x.dtype)
            kv_pos = jnp.arange(ck.shape[1])
            valid = jnp.asarray(pos) + 1
        new_cache = (ck, cv)
    else:
        kv_k, kv_v = k, v
        kv_pos = jnp.arange(S)
        valid = None

    scale = 1.0 / math.sqrt(hd)
    pad = (
        _head_pad_plan(hq, hkv)
        if (shard_hints.active() and pos is None)
        else None
    )
    if pad is not None:
        # optimized path: pad heads to mesh-divisible counts (§Perf iter 2)
        r, hkv_p, g_p, hq_p, perm, inv = pad
        qp = jnp.take(q, jnp.maximum(perm, 0), axis=2)
        qp = qp * (perm >= 0).astype(qp.dtype)[None, None, :, None]
        kp = shard_hints.hint_bshd(jnp.repeat(kv_k, r, axis=2))
        vp = shard_hints.hint_bshd(jnp.repeat(kv_v, r, axis=2))
        qp = shard_hints.hint_bshd(qp)
        out = sdpa_chunked(
            qp.reshape(B, S, hkv_p, g_p, hd), kp, vp, q_pos, kv_pos,
            scale=scale, window=w, cap=cfg.attn_softcap, valid=valid,
            causal=causal,
        )
        out = shard_hints.hint_bshd(out.reshape(B, S, hq_p, hd))
        out = jnp.take(out, inv, axis=2)  # drop pad slots, restore order
    else:
        qg = q.reshape(B, S, hkv, g, hd)
        out = sdpa_chunked(
            qg, kv_k, kv_v, q_pos, kv_pos,
            scale=scale, window=w, cap=cfg.attn_softcap, valid=valid,
            causal=causal,
        )
        out = shard_hints.hint_bshd(out.reshape(B, S, hq, hd))
    out = out.reshape(B, S, hq * hd)
    return out @ params["wo"].astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wdq": dense_init(ks[0], d, m.q_lora_rank, cfg.pdtype),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), cfg.pdtype)},
        "wuq": dense_init(ks[1], m.q_lora_rank, h * qk, cfg.pdtype),
        "wdkv": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, cfg.pdtype),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), cfg.pdtype)},
        "wuk": dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim, cfg.pdtype),
        "wuv": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, cfg.pdtype),
        "wo": dense_init(ks[5], h * m.v_head_dim, d, cfg.pdtype),
    }


def mla_attention(
    params: dict,
    x: jnp.ndarray,
    cfg,
    *,
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    pos: Optional[jnp.ndarray] = None,
):
    """MLA.  Train/prefill uses the expanded form; decode uses the absorbed
    form over the compressed cache (c_kv, k_rope) — the MLA memory win."""
    m = cfg.mla
    B, S, d = x.shape
    h = cfg.num_heads
    nope, rdim, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = 1.0 / math.sqrt(nope + rdim)

    cq = x @ params["wdq"].astype(x.dtype)
    cq = norm(params["q_norm"], cq, "rmsnorm")
    qfull = (cq @ params["wuq"].astype(x.dtype)).reshape(B, S, h, nope + rdim)
    q_nope, q_rope = qfull[..., :nope], qfull[..., nope:]

    dkv = x @ params["wdkv"].astype(x.dtype)
    c_kv, k_rope = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank :]
    c_kv = norm(params["kv_norm"], c_kv, "rmsnorm")

    if cache is None or pos is None:
        q_pos = jnp.arange(S)
    else:
        q_pos = jnp.asarray(pos)[None]
    q_rope = apply_rope(q_rope, q_pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], q_pos, cfg.rope_theta)[..., 0, :]

    new_cache = None
    if cache is not None:
        cc, cr = cache
        if pos is None:  # prefill
            cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, 0, 0))
            cr = jax.lax.dynamic_update_slice(cr, k_rope.astype(cr.dtype), (0, 0, 0))
            new_cache = (cc, cr)
        else:  # decode over compressed cache (absorbed)
            cc = jax.lax.dynamic_update_slice(
                cc, c_kv.astype(cc.dtype), (0, jnp.asarray(pos), 0)
            )
            cr = jax.lax.dynamic_update_slice(
                cr, k_rope.astype(cr.dtype), (0, jnp.asarray(pos), 0)
            )
            new_cache = (cc, cr)
            S_max = cc.shape[1]
            wuk = params["wuk"].astype(x.dtype).reshape(m.kv_lora_rank, h, nope)
            # absorb W_uk into q:  (B,1,h,nope)·(r,h,nope) -> (B,1,h,r)
            q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, wuk)
            sc = jnp.einsum(
                "bqhr,bkr->bhqk", q_abs, cc.astype(x.dtype),
                preferred_element_type=jnp.float32,
            )
            sc = sc + jnp.einsum(
                "bqhr,bkr->bhqk", q_rope, cr.astype(x.dtype),
                preferred_element_type=jnp.float32,
            )
            sc = sc * scale
            kv_pos = jnp.arange(S_max)
            sc = sc + _mask_bias(q_pos, kv_pos, BIG_WINDOW, jnp.asarray(pos) + 1)
            pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
            out_c = jnp.einsum("bhqk,bkr->bqhr", pr, cc.astype(x.dtype))
            wuv = params["wuv"].astype(x.dtype).reshape(m.kv_lora_rank, h, vdim)
            out = jnp.einsum("bqhr,rhv->bqhv", out_c, wuv)
            out = out.reshape(B, S, h * vdim)
            return out @ params["wo"].astype(x.dtype), new_cache

    # expanded path (train / prefill), chunked over query blocks
    k_nope = (c_kv @ params["wuk"].astype(x.dtype)).reshape(B, S, h, nope)
    v = shard_hints.hint_bshd(
        (c_kv @ params["wuv"].astype(x.dtype)).reshape(B, S, h, vdim)
    )
    kq = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,h,nope+rdim)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, rdim))], axis=-1
    )
    kq = shard_hints.hint_bshd(kq)
    kk = shard_hints.hint_bshd(kk)
    kv_pos = jnp.arange(S)
    out = sdpa_chunked(
        kq[:, :, :, None, :], kk, v, q_pos, kv_pos,
        scale=scale, window=BIG_WINDOW, cap=None, valid=None, causal=True,
    )
    out = shard_hints.hint_bshd(out.reshape(B, S, h, vdim))
    out = out.reshape(B, S, h * vdim)
    return out @ params["wo"].astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder -> encoder output)
# ---------------------------------------------------------------------------

def init_cross(key, cfg) -> dict:
    d, hq, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, hq * hd, cfg.pdtype),
        "wk": dense_init(ks[1], d, hq * hd, cfg.pdtype),
        "wv": dense_init(ks[2], d, hq * hd, cfg.pdtype),
        "wo": dense_init(ks[3], hq * hd, d, cfg.pdtype),
    }


def cross_attention(params: dict, x: jnp.ndarray, enc: jnp.ndarray, cfg):
    B, S, d = x.shape
    Se = enc.shape[1]
    hq, hd = cfg.num_heads, cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, hq, hd)
    k = (enc @ params["wk"].astype(x.dtype)).reshape(B, Se, hq, hd)
    v = (enc @ params["wv"].astype(x.dtype)).reshape(B, Se, hq, hd)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    sc = sc / math.sqrt(hd)
    pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, v).reshape(B, S, hq * hd)
    return out @ params["wo"].astype(x.dtype)
