"""Model configuration schema covering all assigned architecture families.

One frozen dataclass drives a single flexible implementation set
(transformer.py / ssm.py / rwkv.py / whisper.py / vlm.py) — the MaxText-style
"one config, many architectures" approach.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    num_shared: int = 0  # shared (always-on) experts, deepseek-style
    first_dense: int = 0  # leading layers that stay dense
    every: int = 1  # MoE every N layers (jamba: 2), else dense MLP
    capacity_factor: float = 1.25
    router: str = "softmax"  # "softmax" | "sigmoid" (deepseek-v3)
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64  # low-rank size of the data-dependent decay (Finch)
    tokenshift_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # ---- attention flavor -------------------------------------------------
    attn_kind: str = "gqa"  # gqa | mla | none (ssm)
    qkv_bias: bool = False
    use_rope: bool = True  # False: absolute position embeddings (whisper)
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    logit_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None  # gemma2: 4096 on local layers
    local_global: bool = False  # alternate local(sliding)/global layers
    mla: Optional[MLAConfig] = None

    # ---- FFN / MoE ---------------------------------------------------------
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    moe: Optional[MoEConfig] = None

    # ---- norm / embeddings --------------------------------------------------
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma multiplies embeds by sqrt(d_model)

    # ---- hybrid / ssm --------------------------------------------------------
    # pattern of a repeating block, e.g. jamba: ("attn",)+("mamba",)*7
    block_pattern: Optional[Tuple[str, ...]] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # ---- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: frames after conv stub
    max_target_positions: int = 448  # learned decoder position table size

    # ---- multimodal stub (vlm) -------------------------------------------------
    vision_tokens: int = 0  # prefix patch embeddings per sample
    vision_dim: int = 0  # raw patch embedding dim (projected into d_model)

    # ---- multi-token prediction (deepseek-v3) -----------------------------------
    mtp_depth: int = 0

    # ---- numerics ---------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ---- remat ----------------------------------------------------------------
    remat_policy: str = "nothing"  # nothing | full | dots

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.attn_kind == "mla" and self.mla is None:
            object.__setattr__(self, "mla", MLAConfig())

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (roofline MODEL_FLOPS) ------------------------------
    def param_counts(self) -> Tuple[int, int]:
        """(total_params, active_params) — active excludes non-routed experts."""
        d, v = self.d_model, self.vocab_size
        embed = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attn_kind == "mla":
                m = self.mla
                qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_dim
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * self.num_heads * (
                    m.qk_nope_head_dim + m.v_head_dim
                )
                p += self.num_heads * m.v_head_dim * d
                return p
            if self.attn_kind == "none":
                return 0
            hd = self.head_dim
            return d * hd * (self.num_heads + 2 * self.num_kv_heads) + (
                self.num_heads * hd * d
            )

        def mlp_params(dff: int) -> int:
            mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            return mult * d * dff

        def mamba_params() -> int:
            mc = self.mamba or MambaConfig()
            d_in = mc.expand * d
            dt_rank = mc.dt_rank or -(-d // 16)
            p = d * 2 * d_in  # in_proj (x and z)
            p += d_in * mc.d_conv  # conv
            p += d_in * (dt_rank + 2 * mc.d_state)  # x -> dt,B,C
            p += dt_rank * d_in  # dt proj
            p += d_in * mc.d_state + d_in  # A, D
            p += d_in * d  # out proj
            return p

        def rwkv_params() -> int:
            rc = self.rwkv or RWKVConfig()
            p = 4 * d * d + d * d  # r,k,v,g + output
            p += 2 * d * rc.decay_lora + 6 * d * rc.tokenshift_lora * 2
            p += d  # u (bonus)
            p += d * self.d_ff + self.d_ff * d + d * d  # channel mix
            return p

        total = embed
        active = embed
        pattern = self.block_pattern or ("attn",) * 1
        for layer in range(self.num_layers):
            kind = pattern[layer % len(pattern)] if self.block_pattern else "attn"
            if kind == "attn":
                total += attn_params()
                active += attn_params()
            elif kind == "mamba":
                total += mamba_params()
                active += mamba_params()
            elif kind == "rwkv":
                total += rwkv_params()
                active += rwkv_params()
            if kind == "rwkv":
                continue  # rwkv_params already includes channel mix
            if self.moe is not None and layer >= self.moe.first_dense and (
                layer % self.moe.every == 0
            ):
                e = self.moe
                total += e.num_experts * mlp_params(e.d_expert) + d * e.num_experts
                total += e.num_shared * mlp_params(e.d_expert)
                active += (e.top_k + e.num_shared) * mlp_params(e.d_expert)
                active += d * e.num_experts
            else:
                total += mlp_params(self.d_ff)
                active += mlp_params(self.d_ff)
        if self.is_encoder_decoder:
            # decoder cross-attention blocks
            total += self.num_layers * attn_params()
            active += self.num_layers * attn_params()
            # encoder stack
            enc = self.encoder_layers * (attn_params() + mlp_params(self.d_ff))
            total += enc
            active += enc
        return int(total), int(active)
