"""Shared neural-net building blocks (pure JAX, functional params-as-pytrees).

Conventions
-----------
* every module is `init_foo(key, cfg, ...) -> params` + `foo(params, x, ...)`
* params are nested dicts of jnp arrays; layer stacks carry a leading
  ``num_layers`` axis and are consumed by ``jax.lax.scan``
* weights are stored in ``cfg.param_dtype`` and matmuls run in
  ``cfg.compute_dtype`` with fp32 softmax/norm accumulations
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * s).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg) -> dict:
    d = cfg.d_model
    if cfg.norm_kind == "rmsnorm":
        return {"scale": jnp.ones((d,), cfg.pdtype)}
    if cfg.norm_kind == "layernorm":
        return {"scale": jnp.ones((d,), cfg.pdtype), "bias": jnp.zeros((d,), cfg.pdtype)}
    if cfg.norm_kind == "nonparametric":  # olmo
        return {}
    raise ValueError(cfg.norm_kind)


def norm(params: dict, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "wi": dense_init(k1, d, f, cfg.pdtype),
            "wg": dense_init(k2, d, f, cfg.pdtype),
            "wo": dense_init(k3, f, d, cfg.pdtype),
        }
    return {"wi": dense_init(k1, d, f, cfg.pdtype), "wo": dense_init(k3, f, d, cfg.pdtype)}


def mlp(params: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    h = x @ params["wi"].astype(x.dtype)
    if kind == "swiglu":
        g = x @ params["wg"].astype(x.dtype)
        h = jax.nn.silu(g) * h
    elif kind == "geglu":
        g = x @ params["wg"].astype(x.dtype)
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg) -> dict:
    p = {"tok": embed_init(key, cfg.vocab_size, cfg.d_model, cfg.pdtype)}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(
            jax.random.fold_in(key, 1), cfg.d_model, cfg.vocab_size, cfg.pdtype
        )
    return p


def embed(params: dict, tokens: jnp.ndarray, cfg) -> jnp.ndarray:
    x = params["tok"].astype(cfg.cdtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)
    return x


def unembed(params: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = x @ params["tok"].astype(x.dtype).T
    else:
        logits = x @ params["out"].astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Mean next-token NLL.  logits (..., V) fp32, targets int (...)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def cross_entropy_fused(
    h: jnp.ndarray,
    embed_params: dict,
    targets: jnp.ndarray,
    cfg,
    mask=None,
    chunk: int = 512,
) -> jnp.ndarray:
    """Fused unembed + NLL, chunked over the sequence.

    Never materializes the full (B, S, V) logits — at 1M-token global
    batches with 100k+ vocabs that tensor alone is hundreds of GB/device.
    Each chunk's logits are produced, reduced to (lse, gold) and discarded;
    the backward pass recomputes them chunk-wise (jax.checkpoint).
    """
    B, S, d = h.shape
    if S % chunk:
        chunk = S if S < chunk else math.gcd(S, chunk)
    n = S // chunk
    hc = h.reshape(B, n, chunk, d).swapaxes(0, 1)  # (n, B, chunk, d)
    tc = targets.reshape(B, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n, chunk).swapaxes(0, 1) if mask is not None else None

    @jax.checkpoint
    def chunk_nll(hx, tx):
        logits = unembed(embed_params, hx, cfg)  # (B, chunk, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tx[..., None], axis=-1)[..., 0]
        return logz - gold  # (B, chunk)

    def body(carry, xs):
        if mc is not None:
            hx, tx, mx = xs
            nll = chunk_nll(hx, tx) * mx
            return (carry[0] + nll.sum(), carry[1] + mx.sum()), None
        hx, tx = xs
        nll = chunk_nll(hx, tx)
        return (carry[0] + nll.sum(), carry[1] + nll.size), None

    xs = (hc, tc, mc) if mc is not None else (hc, tc)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), xs)
    return tot / jnp.maximum(cnt, 1)
