"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Expert-parallel by construction: the expert axis is sharded over the mesh's
``model`` axis (in-pod, per the paper's §3.1 remark that pods are sized to
contain EP traffic), so the gather/scatter turns into an in-pod all-to-all
under GSPMD.

Dispatch is index-based (gather + scatter-add), NOT the O(T·E·C) one-hot
einsum — at DeepSeek-V3 scale the einsum dispatch tensor alone would be
hundreds of GB.  Router runs in fp32.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_moe(key, cfg) -> dict:
    e = cfg.moe
    d = cfg.d_model
    f = e.d_expert
    ks = jax.random.split(key, 5)
    glu = cfg.mlp_kind in ("swiglu", "geglu")
    scale = 1.0 / math.sqrt(d)

    def stack(k, a, b, s):
        return (jax.random.normal(k, (e.num_experts, a, b)) * s).astype(cfg.pdtype)

    p = {
        "router": dense_init(ks[0], d, e.num_experts, jnp.float32),
        "wi": stack(ks[1], d, f, scale),
        "wo": stack(ks[3], f, d, 1.0 / math.sqrt(f)),
    }
    if glu:
        p["wg"] = stack(ks[2], d, f, scale)
    if e.num_shared:
        sf = f * e.num_shared
        p["shared"] = {
            "wi": dense_init(ks[4], d, sf, cfg.pdtype),
            "wo": dense_init(jax.random.fold_in(ks[4], 1), sf, d, cfg.pdtype),
        }
        if glu:
            p["shared"]["wg"] = dense_init(jax.random.fold_in(ks[4], 2), d, sf, cfg.pdtype)
    return p


def _expert_ffn(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """x: (E, C, d) -> (E, C, d), batched over experts."""
    h = jnp.einsum("ecd,edf->ecf", x, p["wi"].astype(x.dtype))
    if cfg.mlp_kind in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", x, p["wg"].astype(x.dtype))
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True)
        )
        h = act(g) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))


def moe_mlp(
    params: dict, x: jnp.ndarray, cfg, capacity: Optional[int] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss).  x: (B, S, d)."""
    e = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32)) @ params["router"]
    if e.router == "sigmoid":  # deepseek-v3 style scores
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(scores, e.top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style) -----------------------
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)  # (E,)
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], e.num_experts, dtype=jnp.float32)
    ce = onehot_top1.mean(axis=0)
    aux = e.num_experts * jnp.sum(me * ce)

    # ---- capacity-based slotting ------------------------------------------
    C = capacity if capacity is not None else int(
        math.ceil(T * e.top_k / e.num_experts * e.capacity_factor)
    )
    C = max(C, 1)
    # membership (T, k) -> position of token t among tokens routed to expert
    flat_e = expert_idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e.num_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position per (slot, expert)
    pos_te = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos_te < C
    slot = jnp.where(keep, pos_te, C)  # overflow -> dropped (mode="drop")

    tok_of = jnp.arange(T).repeat(e.top_k)  # (T*k,)
    # dispatch index table (E, C): token feeding each expert slot (T = empty)
    dispatch = jnp.full((e.num_experts, C), T, dtype=jnp.int32)
    dispatch = dispatch.at[flat_e, slot].set(tok_of, mode="drop")
    gates_ec = jnp.zeros((e.num_experts, C), dtype=jnp.float32)
    gates_ec = gates_ec.at[flat_e, slot].set(gate_vals.reshape(-1), mode="drop")

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xin = xpad[dispatch]  # (E, C, d) gather  -> all-to-all under EP sharding
    out = _expert_ffn(params, xin, cfg)  # (E, C, d)
    out = out * gates_ec[..., None].astype(out.dtype)

    y = jnp.zeros((T + 1, d), out.dtype)
    y = y.at[dispatch.reshape(-1)].add(out.reshape(-1, d))
    y = y[:T]

    if e.num_shared:
        sp = params["shared"]
        h = xt @ sp["wi"].astype(xt.dtype)
        if "wg" in sp:
            g = xt @ sp["wg"].astype(xt.dtype)
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h, approximate=True)
        y = y + h @ sp["wo"].astype(xt.dtype)

    return y.reshape(B, S, d), aux
