"""Architecture registry: the 10 assigned architectures as ModelConfigs,
reduced smoke variants, and a uniform ModelAPI (init/loss/prefill/decode)
so the trainer, server, dry-run, and tests are architecture-agnostic.

Sources for the full configs are the assignment table (public literature);
structural details (MLA dims, mamba dims, first-dense layers) follow the
cited papers/HF configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import MLAConfig, MambaConfig, ModelConfig, MoEConfig, RWKVConfig
from . import transformer, vlm, whisper


# Canonical per-arch definitions live in repro/configs/<arch>.py; this dict
# is the runtime registry assembled from them (``--arch`` lookups).
from .. import configs as _configs

ARCHS: Dict[str, ModelConfig] = {
    arch_id: _configs.get_config(arch_id) for arch_id in _configs.ARCH_IDS
}


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    full = ARCHS[name]
    kw: Dict[str, Any] = dict(
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if full.attn_kind == "mla":
        kw["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
    if full.num_kv_heads == 1:
        kw["num_kv_heads"] = 1
    if full.moe is not None:
        # capacity_factor = E/top_k makes capacity == T (dropless): smoke
        # tests check prefill/decode consistency, and capacity drops are
        # batch-global (non-causal) by design.
        kw["moe"] = dataclasses.replace(
            full.moe,
            num_experts=4,
            top_k=2,
            d_expert=64,
            first_dense=min(full.moe.first_dense, 1),
            capacity_factor=2.0,
        )
        if full.moe.first_dense:
            kw["num_layers"] = 5  # 1 dense + 4 moe
    if full.block_pattern is not None:
        kw["num_layers"] = len(full.block_pattern)
        if full.moe is not None:
            kw["num_layers"] = max(
                kw["num_layers"],
                len(full.block_pattern),
            )
    if full.mamba is not None:
        kw["mamba"] = MambaConfig(d_state=4, d_conv=4, expand=2)
    if full.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8, tokenshift_lora=8)
        kw["num_heads"] = 4
        kw["num_kv_heads"] = 4
    if full.local_global:
        kw["num_layers"] = 4
        kw["sliding_window"] = 8
    if full.is_encoder_decoder:
        kw["num_layers"] = 2
        return full.replace(
            encoder_layers=2, encoder_seq=16, max_target_positions=64, **kw
        )
    if full.family == "vlm":
        kw["vision_tokens"] = 8
        kw["vision_dim"] = 32
    return full.replace(**kw)


# ---------------------------------------------------------------------------
# uniform model API
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    init: Callable  # (key) -> params
    loss: Callable  # (params, batch) -> scalar
    prefill: Callable  # (params, batch, cache) -> (logits, cache)
    decode: Callable  # (params, tokens, cache) -> (logits, cache)
    init_cache: Callable  # (batch, s_max) -> cache pytree


def get_api(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam == "audio":
        def init(key):
            return whisper.init_whisper(
                key, cfg, max_target_positions=cfg.max_target_positions
            )

        def loss(params, batch):
            return whisper.whisper_loss(params, batch, cfg)

        def prefill(params, batch, cache, last_only=False):
            enc = whisper.encode(params, batch["frames"], cfg)
            logits, nc = whisper.decode(
                params, batch["tokens"], enc, cfg, cache=cache, mode="prefill",
                last_only=last_only,
            )
            nc["enc"] = enc
            return logits, nc

        def decode_step(params, tokens, cache):
            logits, nc = whisper.decode(
                params, tokens, cache["enc"], cfg, cache=cache, mode="decode"
            )
            nc["enc"] = cache["enc"]
            return logits, nc

        def make_cache(batch, s_max):
            c = whisper.init_whisper_cache(cfg, batch, s_max)
            c["enc"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), cfg.cdtype)
            return c

        return ModelAPI(cfg, init, loss, prefill, decode_step, make_cache)

    if fam == "vlm":
        def init(key):
            return vlm.init_vlm(key, cfg)

        def loss(params, batch):
            return vlm.vlm_loss(params, batch, cfg)

        def prefill(params, batch, cache, last_only=False):
            logits, _, nc = vlm.apply_vlm(
                params, batch["tokens"], batch["patches"], cfg, cache=cache,
                mode="prefill", last_only=last_only,
            )
            return logits, nc

        def decode_step(params, tokens, cache):
            logits, _, nc = vlm.apply_vlm(params, tokens, None, cfg, cache=cache, mode="decode")
            return logits, nc

        def make_cache(batch, s_max):
            # the vision prefix occupies the first vision_tokens cache slots
            return transformer.init_cache(cfg, batch, s_max + cfg.vision_tokens)

        return ModelAPI(cfg, init, loss, prefill, decode_step, make_cache)

    # decoder-only LM families: dense | moe | hybrid | ssm
    def init(key):
        return transformer.init_lm(key, cfg)

    def loss(params, batch):
        return transformer.lm_loss(params, batch, cfg)

    def prefill(params, batch, cache, last_only=False):
        logits, _, nc = transformer.apply_lm(
            params, batch["tokens"], cfg, cache=cache, mode="prefill",
            last_only=last_only,
        )
        return logits, nc

    def decode_step(params, tokens, cache):
        logits, _, nc = transformer.apply_lm(
            params, tokens, cfg, cache=cache, mode="decode"
        )
        return logits, nc

    def make_cache(batch, s_max):
        return transformer.init_cache(cfg, batch, s_max)

    return ModelAPI(cfg, init, loss, prefill, decode_step, make_cache)


def make_smoke_batch(cfg: ModelConfig, rng=None, batch: int = 2, seq: int = 16):
    rng = rng or np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(batch, seq)).astype(np.int32)
    tgts = np.roll(toks, -1, axis=1).astype(np.int32)
    b = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        )
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vision_tokens, cfg.vision_dim)).astype(np.float32)
        )
    return b
