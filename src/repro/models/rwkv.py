"""RWKV-6 "Finch" block: attention-free time mixing with data-dependent
per-channel decay (arXiv:2404.05892), plus squared-ReLU channel mixing.

The WKV recurrence (state S_t ∈ ℝ^{K×V} per head):

    y_t = r_t · (S_{t-1} + diag(u) k_t vᵀ_t)
    S_t = diag(w_t) S_{t-1} + k_t vᵀ_t

is computed with the shared :func:`~repro.models.ssm.diag_ssm_scan` engine
(exact chunked scan — numerically stable; no decay-ratio divisions).  The
Pallas kernel in ``repro.kernels.rwkv6_wkv`` implements the same contract for
TPU with the state held in VMEM.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from .ssm import chunked_scan, scan_chunk


def _dims(cfg):
    rc = cfg.rwkv
    H = cfg.d_model // rc.head_dim
    return rc, H, rc.head_dim


def init_rwkv_time(key, cfg) -> dict:
    rc, H, K = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    p = {
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(cfg.pdtype),
        # shared token-shift lora: x -> 5 per-channel lerp adjustments
        "ts_a": dense_init(ks[1], d, 5 * rc.tokenshift_lora, cfg.pdtype),
        "ts_b": dense_init(ks[2], rc.tokenshift_lora, 5 * d, cfg.pdtype, scale=0.01),
        "wr": dense_init(ks[3], d, d, cfg.pdtype),
        "wk": dense_init(ks[4], d, d, cfg.pdtype),
        "wv": dense_init(ks[5], d, d, cfg.pdtype),
        "wg": dense_init(ks[6], d, d, cfg.pdtype),
        "wo": dense_init(ks[7], d, d, cfg.pdtype),
        "w0": (jax.random.normal(ks[8], (d,)) * 0.5 - 0.5).astype(jnp.float32),
        "w_a": dense_init(ks[9], d, rc.decay_lora, cfg.pdtype),
        "w_b": dense_init(ks[10], rc.decay_lora, d, cfg.pdtype, scale=0.01),
        "u": (jax.random.normal(ks[11], (d,)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((d,), cfg.pdtype),  # per-head group norm scale
    }
    return p


def rwkv_time_mix(
    params: dict,
    x: jnp.ndarray,
    cfg,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    chunk: int = 64,
):
    """x (B,S,d) -> (y, new_state).  state = (x_prev (B,1,d), wkv (B,H,K,V))."""
    rc, H, K = _dims(cfg)
    B, S, d = x.shape
    if state is not None:
        x_prev_in, wkv0 = state
        xs = jnp.concatenate([x_prev_in.astype(x.dtype), x[:, :-1]], axis=1)
    else:
        xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        wkv0 = jnp.zeros((B, H, K, K), jnp.float32)

    # Finch ddlerp token shift: per-channel static mu + low-rank dynamic term
    delta = xs - x
    base = x + delta * params["mu"][0][None, None]
    dyn = jnp.tanh(base @ params["ts_a"].astype(x.dtype)).reshape(
        B, S, 5, rc.tokenshift_lora
    )
    dyn = jnp.einsum(
        "bsfr,rfd->bsfd",
        dyn,
        params["ts_b"].astype(x.dtype).reshape(rc.tokenshift_lora, 5, d),
    )
    mixed = x[:, :, None] + delta[:, :, None] * (
        params["mu"].astype(x.dtype)[None, None] + dyn
    )  # (B,S,5,d)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = (xr @ params["wr"].astype(x.dtype)).reshape(B, S, H, K)
    k = (xk @ params["wk"].astype(x.dtype)).reshape(B, S, H, K)
    v = (xv @ params["wv"].astype(x.dtype)).reshape(B, S, H, K)
    g = jax.nn.silu(xg @ params["wg"].astype(x.dtype))
    wlog = params["w0"][None, None] + jnp.tanh(
        xw @ params["w_a"].astype(x.dtype)
    ).astype(jnp.float32) @ params["w_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, S, H, K)  # (0,1) decay
    u = params["u"].astype(jnp.float32).reshape(H, K)

    def chunk_fn(h, ac):
        r_c, k_c, v_c, w_c = ac  # (B,Q,H,K) each
        kv = k_c.astype(jnp.float32)[..., :, None] * v_c.astype(jnp.float32)[
            ..., None, :
        ]  # (B,Q,H,K,V)
        decay = jnp.broadcast_to(w_c.astype(jnp.float32)[..., :, None], kv.shape)
        states, h2 = scan_chunk(decay, kv, h)
        # y_t = r_t · (S_{t-1} + diag(u) k_t vᵀ_t); S_{t-1} = shifted states
        prev = jnp.concatenate([h[:, None], states[:, :-1]], axis=1)
        att = prev + u[None, None, :, :, None] * kv
        y_c = jnp.einsum("bqhk,bqhkv->bqhv", r_c.astype(jnp.float32), att)
        return h2, y_c

    y, final = chunked_scan((r, k, v, w), wkv0, chunk_fn, chunk)

    # per-head group norm
    mu_ = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu_) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, S, d).astype(x.dtype) * params["ln_scale"].astype(x.dtype)
    y = y * g
    out = y @ params["wo"].astype(x.dtype)
    new_state = (x[:, -1:, :], final)
    return out, new_state


def init_rwkv_channel(key, cfg) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": (jax.random.uniform(ks[0], (d,)) * 0.5 + 0.25).astype(cfg.pdtype),
        "mu_r": (jax.random.uniform(ks[0], (d,)) * 0.5 + 0.25).astype(cfg.pdtype),
        "wk": dense_init(ks[1], d, cfg.d_ff, cfg.pdtype),
        "wv": dense_init(ks[2], cfg.d_ff, d, cfg.pdtype),
        "wr": dense_init(jax.random.fold_in(ks[2], 1), d, d, cfg.pdtype),
    }


def rwkv_channel_mix(
    params: dict,
    x: jnp.ndarray,
    cfg,
    state: Optional[jnp.ndarray] = None,
):
    """Squared-relu channel mix with token shift.  state: x_prev (B,1,d)."""
    B, S, d = x.shape
    if state is not None:
        xs = jnp.concatenate([state.astype(x.dtype), x[:, :-1]], axis=1)
    else:
        xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    delta = xs - x
    xk = x + delta * params["mu_k"][None, None].astype(x.dtype)
    xr = x + delta * params["mu_r"][None, None].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ params["wk"].astype(x.dtype)))
    r = jax.nn.sigmoid(xr @ params["wr"].astype(x.dtype))
    return r * (k @ params["wv"].astype(x.dtype)), x[:, -1:, :]


def rwkv_state_shapes(cfg, batch: int):
    rc, H, K = _dims(cfg)
    return (
        (batch, 1, cfg.d_model),  # time-mix x_prev
        (batch, H, K, K),  # wkv state
        (batch, 1, cfg.d_model),  # channel-mix x_prev
    )
