"""Optional activation-sharding hints (the 'optimized' data plane).

GSPMD left alone makes poor choices inside scanned attention blocks — the
dry-run baseline shows fp32 score tensors being all-reduced over the model
axis thousands of times (EXPERIMENTS.md §Perf).  The standard fix (MaxText
et al.) is explicit ``with_sharding_constraint`` on the attention
activations.  This module keeps the models mesh-agnostic: hints are
no-ops until a launcher registers a mesh via :func:`use_hints`.

Baseline (paper-faithful) lowering keeps hints OFF; the optimized
configuration turns them on — the delta is the measured §Perf iteration.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_SIZES: dict = {}
_DP: Tuple[str, ...] = ()


def use_hints(mesh: Optional[Mesh]) -> None:
    """Register (or clear, with None) the mesh for activation hints."""
    global _MESH, _SIZES, _DP
    _MESH = mesh
    if mesh is None:
        _SIZES, _DP = {}, ()
    else:
        _SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))
        _DP = tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def active() -> bool:
    return _MESH is not None


def model_size() -> int:
    return _SIZES.get("model", 1)


def _set_sizes_for_test(sizes: dict) -> None:
    """Test hook: drive the head-padding planner without a real mesh
    (``_MESH`` stays None so constraints remain no-ops)."""
    global _SIZES
    _SIZES = dict(sizes)


def _dp_total() -> int:
    n = 1
    for a in _DP:
        n *= _SIZES[a]
    return n


def _apply(x, spec_list):
    """Apply a constraint, dropping axes that are Manual in the current
    tracing context (inside shard_map over the DP axes only the model
    axis remains Auto)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        manual = {
            name
            for name, ty in zip(am.axis_names, am.axis_types)
            if "Manual" in str(ty)
        } if am is not None and am.axis_names else set()
    except Exception:  # noqa: BLE001 — hints must never break tracing
        manual = set()

    def keep(a):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            kept = tuple(x_ for x_ in a if x_ not in manual)
            return kept if kept else None
        return None if a in manual else a

    spec = P(*[keep(a) for a in spec_list])
    if all(a is None for a in spec):
        return x
    try:
        if manual:
            return jax.lax.with_sharding_constraint(x, spec)
        return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
    except Exception:  # noqa: BLE001
        return x


def hint_bshd(x):
    """(B, S, H, D) attention activations: batch over DP, heads over model
    when divisible (else head_dim), sequence replicated."""
    if _MESH is None or x.ndim != 4:
        return x
    B, S, H, D = x.shape
    model = _SIZES.get("model", 1)
    spec = [None, None, None, None]
    if B % _dp_total() == 0 and B > 1:
        spec[0] = _DP
    if H % model == 0:
        spec[2] = "model"
    elif D % model == 0:
        spec[3] = "model"
    return _apply(x, spec)


def hint_bsd(x):
    """(B, S, d) residual-stream activations: batch over DP only."""
    if _MESH is None or x.ndim != 3:
        return x
    B = x.shape[0]
    spec = [None, None, None]
    if B % _dp_total() == 0 and B > 1:
        spec[0] = _DP
    return _apply(x, spec)


def hint_expert(x):
    """(E, C, d) MoE dispatch buffers: experts over model when divisible."""
    if _MESH is None or x.ndim != 3:
        return x
    E = x.shape[0]
    model = _SIZES.get("model", 1)
    spec = [None, None, None]
    if E % model == 0:
        spec[0] = "model"
    elif x.shape[2] % model == 0:
        spec[2] = "model"
    return _apply(x, spec)
