"""Selective state-space (Mamba-1 / S6) block, used by jamba's hybrid stack.

The recurrence  h_t = a_t ⊙ h_{t-1} + b_t  (diagonal, data-dependent) is
shared with RWKV6, so this module provides the common engine:

* :func:`scan_chunk` — exact parallel scan *within* a chunk
  (``associative_scan``; no decay-ratio divisions → numerically stable).
* :func:`chunked_scan` — sequential ``lax.scan`` *over* chunks, with a
  caller-supplied ``chunk_fn`` that expands per-chunk decays/inputs and reads
  out per-chunk outputs, so the O(B·S·state) full-state tensor is never
  materialized — peak extra memory is O(B·chunk·state).
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init


def scan_chunk(decay: jnp.ndarray, inp: jnp.ndarray, h0: jnp.ndarray):
    """h_t = decay_t * h_{t-1} + inp_t within a chunk (axis 1).

    decay/inp: (B, Q, ...); h0: (B, ...).  Returns (states (B,Q,...), h_Q).
    """

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    pa, pb = jax.lax.associative_scan(combine, (decay, inp), axis=1)
    states = pa * h0[:, None] + pb
    return states, states[:, -1]


def chunked_scan(
    aux,  # pytree of (B, S, ...) arrays, chunked along axis 1
    h0: jnp.ndarray,
    chunk_fn: Callable,  # (h, aux_chunk) -> (h_next, y_chunk (B, Q, ...))
    chunk: int,
):
    """Run ``chunk_fn`` over S//chunk chunks sequentially, threading state."""
    S = jax.tree_util.tree_leaves(aux)[0].shape[1]
    if S % chunk:
        chunk = S if S < chunk else math.gcd(S, chunk)
    n_chunks = S // chunk

    def reshape(x):
        return jnp.moveaxis(
            x.reshape((x.shape[0], n_chunks, chunk) + x.shape[2:]), 1, 0
        )

    aux_c = jax.tree_util.tree_map(reshape, aux)

    def step(h, ac):
        h2, y = chunk_fn(h, ac)
        return h2, y

    final, ys = jax.lax.scan(step, h0, aux_c)
    ys = jnp.moveaxis(ys, 0, 1)
    ys = ys.reshape((ys.shape[0], S) + ys.shape[3:])
    return ys, final


# ---------------------------------------------------------------------------
# Mamba block
# ---------------------------------------------------------------------------

def _mamba_dims(cfg):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return mc, d_in, dt_rank


def init_mamba(key, cfg) -> dict:
    mc, d_in, dt_rank = _mamba_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in, cfg.pdtype),
        "conv_w": (
            jax.random.normal(ks[1], (mc.d_conv, d_in)) / math.sqrt(mc.d_conv)
        ).astype(cfg.pdtype),
        "conv_b": jnp.zeros((d_in,), cfg.pdtype),
        "x_proj": dense_init(ks[2], d_in, dt_rank + 2 * mc.d_state, cfg.pdtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_in, cfg.pdtype),
        "dt_bias": jnp.zeros((d_in,), cfg.pdtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], d_in, d, cfg.pdtype),
    }


def mamba_block(
    params: dict,
    x: jnp.ndarray,
    cfg,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    chunk: int = 64,
):
    """x (B,S,d) -> (y, new_state).  state = (conv_buf (B,d_conv-1,d_in),
    ssm_state (B,d_in,N)); pass for decode (S may be 1), None for training."""
    mc, d_in, dt_rank = _mamba_dims(cfg)
    B, S, d = x.shape
    xz = x @ params["in_proj"].astype(x.dtype)
    xpart, z = jnp.split(xz, 2, axis=-1)  # (B,S,d_in) each

    # causal depthwise conv along S
    if state is not None:
        conv_buf, ssm_state = state
        xcat = jnp.concatenate([conv_buf.astype(x.dtype), xpart], axis=1)
    else:
        ssm_state = jnp.zeros((B, d_in, mc.d_state), jnp.float32)
        xcat = jnp.pad(xpart, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
    w = params["conv_w"].astype(x.dtype)  # (d_conv, d_in)
    xc = sum(
        xcat[:, i : i + S, :] * w[i][None, None, :] for i in range(mc.d_conv)
    ) + params["conv_b"].astype(x.dtype)
    new_conv_buf = xcat[:, -(mc.d_conv - 1) :, :] if mc.d_conv > 1 else xcat[:, :0, :]
    xc = jax.nn.silu(xc)

    dbc = xc @ params["x_proj"].astype(x.dtype)
    dt = dbc[..., :dt_rank] @ params["dt_proj"].astype(x.dtype)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,S,d_in)
    Bm = dbc[..., dt_rank : dt_rank + mc.d_state].astype(jnp.float32)
    Cm = dbc[..., dt_rank + mc.d_state :].astype(jnp.float32)

    A = -jnp.exp(params["A_log"])  # (d_in, N)
    dtx = dt * xc.astype(jnp.float32)  # (B,S,d_in)

    def chunk_fn(h, ac):
        dt_c, dtx_c, b_c, c_c = ac  # (B,Q,d_in),(B,Q,d_in),(B,Q,N),(B,Q,N)
        decay = jnp.exp(dt_c[..., None] * A[None, None])  # (B,Q,d_in,N)
        binp = dtx_c[..., None] * b_c[:, :, None, :]
        states, h2 = scan_chunk(decay, binp, h)
        y_c = jnp.einsum("bqdn,bqn->bqd", states, c_c)
        return h2, y_c

    y, final = chunked_scan((dt, dtx, Bm, Cm), ssm_state, chunk_fn, chunk)
    y = y + params["D"][None, None] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, (new_conv_buf, final)


def mamba_state_shape(cfg, batch: int):
    mc, d_in, _ = _mamba_dims(cfg)
    return (
        (batch, mc.d_conv - 1, d_in),
        (batch, d_in, mc.d_state),
    )
