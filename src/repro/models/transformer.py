"""Decoder-only LM assembly: layer plans, scan-over-layers, KV caches.

A model is a *prologue* stack (e.g. DeepSeek's leading dense layers) plus a
scan over homogeneous *repeat units* (1 layer for dense models; 8 for jamba's
attn:mamba 1:7 interleave; 2 for gemma2's local/global alternation).  Scanning
the unit keeps the compiled HLO to one unit body regardless of depth — this
is what makes the 61-layer DeepSeek dry-run compile in seconds.

Caches mirror the layer plan: each unit element owns a cache entry stacked
over units; ``init_cache`` builds the pytree, prefill writes it, decode
updates it in place (functionally).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import BIG_WINDOW, gqa_attention, init_gqa, init_mla, mla_attention
from .layers import (
    cross_entropy,
    cross_entropy_fused,
    embed,
    init_embed,
    init_mlp,
    init_norm,
    mlp,
    norm,
    unembed,
)
from .moe import init_moe, moe_mlp
from .rwkv import (
    init_rwkv_channel,
    init_rwkv_time,
    rwkv_channel_mix,
    rwkv_state_shapes,
    rwkv_time_mix,
)
from .ssm import init_mamba, mamba_block, mamba_state_shape


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str  # attn | mamba | rwkv
    moe: bool = False
    window: Optional[int] = None  # sliding window (gemma2 local layers)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    prologue: Tuple[LayerSpec, ...]
    unit: Tuple[LayerSpec, ...]
    n_units: int


def layer_plan(cfg) -> LayerPlan:
    moe = cfg.moe
    first_dense = moe.first_dense if moe else 0

    def ffn_is_moe(global_idx: int) -> bool:
        if moe is None or global_idx < first_dense:
            return False
        return (global_idx % moe.every) == (moe.every - 1) if moe.every > 1 else True

    if cfg.block_pattern:
        pattern = cfg.block_pattern
        if cfg.num_layers % len(pattern):
            raise ValueError("num_layers must be a multiple of the block pattern")
        if moe and len(pattern) % moe.every:
            raise ValueError("pattern length must be a multiple of moe.every")
        unit = tuple(
            LayerSpec(kind=k, moe=ffn_is_moe(i)) for i, k in enumerate(pattern)
        )
        return LayerPlan((), unit, cfg.num_layers // len(pattern))
    if cfg.local_global:
        if cfg.num_layers % 2:
            raise ValueError("local_global needs even num_layers")
        unit = (
            LayerSpec("attn", window=cfg.sliding_window),
            LayerSpec("attn", window=None),
        )
        return LayerPlan((), unit, cfg.num_layers // 2)
    prologue = tuple(LayerSpec("attn", moe=False) for _ in range(first_dense))
    unit = (LayerSpec("attn", moe=moe is not None),)
    return LayerPlan(prologue, unit, cfg.num_layers - first_dense)


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(key, spec: LayerSpec, cfg) -> dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": init_norm(cfg), "ln2": init_norm(cfg)}
    if spec.kind == "attn":
        p["mix"] = init_mla(ks[0], cfg) if cfg.attn_kind == "mla" else init_gqa(ks[0], cfg)
    elif spec.kind == "mamba":
        p["mix"] = init_mamba(ks[0], cfg)
    elif spec.kind == "rwkv":
        p["mix"] = init_rwkv_time(ks[0], cfg)
    else:
        raise ValueError(spec.kind)
    if spec.kind == "rwkv":
        p["ffn"] = init_rwkv_channel(ks[1], cfg)
    elif spec.moe:
        p["ffn"] = init_moe(ks[1], cfg)
    else:
        # prologue dense layers in MoE models use the dense d_ff
        p["ffn"] = init_mlp(ks[1], cfg)
    return p


def _cache_shapes(spec: LayerSpec, cfg, batch: int, s_max: int):
    """Shape/dtype tree of one layer's cache entry."""
    dt = cfg.cdtype
    if spec.kind == "attn":
        if cfg.attn_kind == "mla":
            m = cfg.mla
            return (
                ((batch, s_max, m.kv_lora_rank), dt),
                ((batch, s_max, m.qk_rope_head_dim), dt),
            )
        return (
            ((batch, s_max, cfg.num_kv_heads, cfg.head_dim), dt),
            ((batch, s_max, cfg.num_kv_heads, cfg.head_dim), dt),
        )
    if spec.kind == "mamba":
        s1, s2 = mamba_state_shape(cfg, batch)
        return ((s1, dt), (s2, jnp.float32))
    if spec.kind == "rwkv":
        s1, s2, s3 = rwkv_state_shapes(cfg, batch)
        return ((s1, dt), (s2, jnp.float32), (s3, dt))
    raise ValueError(spec.kind)


def _apply_layer(spec: LayerSpec, p, x, cfg, cache_entry, pos, scan_chunk_size):
    aux = jnp.zeros((), jnp.float32)
    h = norm(p["ln1"], x, cfg.norm_kind)
    if spec.kind == "attn":
        window = spec.window if spec.window else BIG_WINDOW
        if cfg.attn_kind == "mla":
            y, new_mix_cache = mla_attention(p["mix"], h, cfg, cache=cache_entry, pos=pos)
        else:
            y, new_mix_cache = gqa_attention(
                p["mix"], h, cfg, window=window, cache=cache_entry, pos=pos
            )
        x = x + y
        h = norm(p["ln2"], x, cfg.norm_kind)
        if spec.moe:
            y, aux = moe_mlp(p["ffn"], h, cfg)
        else:
            y = mlp(p["ffn"], h, cfg.mlp_kind)
        x = x + y
        return x, new_mix_cache, aux
    if spec.kind == "mamba":
        mix_cache = cache_entry[:2] if cache_entry is not None else None
        y, new_mix = mamba_block(p["mix"], h, cfg, state=mix_cache, chunk=scan_chunk_size)
        x = x + y
        h = norm(p["ln2"], x, cfg.norm_kind)
        if spec.moe:
            y, aux = moe_mlp(p["ffn"], h, cfg)
        else:
            y = mlp(p["ffn"], h, cfg.mlp_kind)
        x = x + y
        return x, new_mix, aux
    if spec.kind == "rwkv":
        tcache = cache_entry[:2] if cache_entry is not None else None
        y, new_t = rwkv_time_mix(p["mix"], h, cfg, state=tcache, chunk=scan_chunk_size)
        x = x + y
        h = norm(p["ln2"], x, cfg.norm_kind)
        ccache = cache_entry[2] if cache_entry is not None else None
        y, new_c = rwkv_channel_mix(p["ffn"], h, cfg, state=ccache)
        x = x + y
        return x, new_t + (new_c,), aux
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# whole-model init / apply
# ---------------------------------------------------------------------------

def init_lm(key, cfg) -> dict:
    plan = layer_plan(cfg)
    k_embed, k_pro, k_units = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": init_embed(k_embed, cfg),
        "final_norm": init_norm(cfg),
    }
    if plan.prologue:
        keys = jax.random.split(k_pro, len(plan.prologue))
        params["pro"] = jax.vmap(lambda k: _init_layer(k, plan.prologue[0], cfg))(keys)
    if plan.n_units:
        keys = jax.random.split(k_units, plan.n_units)

        def init_unit(k):
            uks = jax.random.split(k, len(plan.unit))
            return {
                f"l{i}": _init_layer(uks[i], s, cfg) for i, s in enumerate(plan.unit)
            }

        params["units"] = jax.vmap(init_unit)(keys)
    return params


def init_cache(cfg, batch: int, s_max: int):
    """Zero-filled cache pytree matching the layer plan."""
    plan = layer_plan(cfg)

    def entry(spec):
        return tuple(
            jnp.zeros(shape, dtype) for shape, dtype in _cache_shapes(spec, cfg, batch, s_max)
        )

    def stacked_entry(spec, n):
        return tuple(
            jnp.zeros((n,) + shape, dtype)
            for shape, dtype in _cache_shapes(spec, cfg, batch, s_max)
        )

    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if plan.prologue:
        cache["pro"] = stacked_entry(plan.prologue[0], len(plan.prologue))
    if plan.n_units:
        cache["units"] = {
            f"l{i}": stacked_entry(s, plan.n_units) for i, s in enumerate(plan.unit)
        }
    return cache


def _remat_wrap(fn, cfg):
    if cfg.remat_policy == "nothing":
        return fn
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(cfg.remat_policy)


def apply_lm(
    params: dict,
    tokens: Optional[jnp.ndarray],
    cfg,
    cache: Optional[dict] = None,
    mode: str = "train",  # train | prefill | decode
    inputs_embeds: Optional[jnp.ndarray] = None,
    scan_chunk_size: int = 64,
    return_hidden: bool = False,
    last_only: bool = False,
):
    """Returns (logits fp32 (B,S,V), aux_loss, new_cache).

    * mode="train":   cache ignored (None)
    * mode="prefill": cache required; writes positions [0:S], pos := S
    * mode="decode":  cache required; tokens (B,1), updates at cache["pos"]
    """
    if mode == "train":
        cache = None
    elif cache is None:
        raise ValueError(f"mode={mode!r} requires a cache")
    plan = layer_plan(cfg)
    x = inputs_embeds if inputs_embeds is not None else embed(params["embed"], tokens, cfg)
    decode = mode == "decode"
    pos = cache["pos"] if decode else None

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    if plan.prologue:
        spec = plan.prologue[0]

        def pro_step(carry, xs):
            x, aux = carry
            p, c = xs
            x, nc, a = _apply_layer(spec, p, x, cfg, c, pos, scan_chunk_size)
            return (x, aux + a), nc

        pro_step = _remat_wrap(pro_step, cfg)
        if cache is not None:
            (x, aux_total), npc = jax.lax.scan(
                pro_step, (x, aux_total), (params["pro"], cache["pro"])
            )
            new_cache["pro"] = npc
        else:
            def pro_step_nc(carry, p):
                x, aux = carry
                x, _, a = _apply_layer(spec, p, x, cfg, None, pos, scan_chunk_size)
                return (x, aux + a), None

            pro_step_nc = _remat_wrap(pro_step_nc, cfg)
            (x, aux_total), _ = jax.lax.scan(pro_step_nc, (x, aux_total), params["pro"])

    if plan.n_units:
        def unit_step(carry, xs):
            x, aux = carry
            p, c = xs
            ncs = {}
            for i, s in enumerate(plan.unit):
                x, nc, a = _apply_layer(
                    s, p[f"l{i}"], x, cfg, c[f"l{i}"] if c is not None else None,
                    pos, scan_chunk_size,
                )
                ncs[f"l{i}"] = nc
                aux = aux + a
            return (x, aux), ncs

        if cache is not None:
            step = _remat_wrap(unit_step, cfg)
            (x, aux_total), nuc = jax.lax.scan(
                step, (x, aux_total), (params["units"], cache["units"])
            )
            new_cache["units"] = nuc
        else:
            def unit_step_nc(carry, p):
                (x2, aux2), _ = unit_step((carry[0], carry[1]), (p, None))
                return (x2, aux2), None

            unit_step_nc = _remat_wrap(unit_step_nc, cfg)
            (x, aux_total), _ = jax.lax.scan(unit_step_nc, (x, aux_total), params["units"])

    x = norm(params["final_norm"], x, cfg.norm_kind)
    if cache is not None:
        new_cache["pos"] = cache["pos"] + (1 if decode else x.shape[1])
    if return_hidden:
        return x, aux_total, (new_cache if cache is not None else None)
    if last_only:
        x = x[:, -1:, :]
    logits = unembed(params["embed"], x, cfg)
    return logits, aux_total, (new_cache if cache is not None else None)


def lm_loss(params, batch, cfg, scan_chunk_size: int = 64):
    """batch: {"tokens": (B,S), "targets": (B,S), optional "mask"}."""
    h, aux, _ = apply_lm(
        params, batch["tokens"], cfg, scan_chunk_size=scan_chunk_size,
        return_hidden=True,
    )
    loss = cross_entropy_fused(
        h, params["embed"], batch["targets"], cfg, batch.get("mask")
    )
    if cfg.moe is not None:
        loss = loss + 0.01 * aux
    return loss
