"""InternVL2-style VLM (ViT frontend STUBBED per the assignment).

Inputs are precomputed patch embeddings (B, N_patch, vision_dim) — what
InternViT would emit after pixel-shuffle.  The mlp1 projector and the
InternLM2/Qwen2-family LM backbone are implemented fully; patch embeddings
are projected and prepended to the token embeddings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import cross_entropy, cross_entropy_fused, dense_init, embed
from .transformer import apply_lm, init_lm


def init_vlm(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    vd = cfg.vision_dim
    return {
        "proj": {
            "w1": dense_init(k1, vd, cfg.d_model, cfg.pdtype),
            "w2": dense_init(k2, cfg.d_model, cfg.d_model, cfg.pdtype),
        },
        "lm": init_lm(k3, cfg),
    }


def _project(params: dict, patches: jnp.ndarray, cfg) -> jnp.ndarray:
    h = patches.astype(cfg.cdtype) @ params["w1"].astype(cfg.cdtype)
    h = jax.nn.gelu(h, approximate=True)
    return h @ params["w2"].astype(cfg.cdtype)


def apply_vlm(
    params: dict,
    tokens: jnp.ndarray,
    patches: jnp.ndarray,
    cfg,
    cache: Optional[dict] = None,
    mode: str = "train",
    return_hidden: bool = False,
    last_only: bool = False,
):
    """tokens (B, S_text); patches (B, N_patch, vision_dim).

    Sequence = [vision tokens][text tokens].  For decode mode the vision
    prefix is assumed already prefilled; tokens are decoded one at a time.
    """
    if mode == "decode":
        return apply_lm(params["lm"], tokens, cfg, cache=cache, mode=mode)
    vis = _project(params["proj"], patches, cfg)  # (B, Nv, d)
    tok = embed(params["lm"]["embed"], tokens, cfg)
    x = jnp.concatenate([vis, tok], axis=1)
    return apply_lm(
        params["lm"], None, cfg, cache=cache, mode=mode, inputs_embeds=x,
        return_hidden=return_hidden, last_only=last_only,
    )


def vlm_loss(params, batch, cfg):
    """batch: {"tokens", "targets", "patches"}; vision positions unsupervised."""
    h, aux, _ = apply_vlm(
        params, batch["tokens"], batch["patches"], cfg, return_hidden=True
    )
    nv = batch["patches"].shape[1]
    return cross_entropy_fused(
        h[:, nv:, :], params["lm"]["embed"], batch["targets"], cfg, batch.get("mask")
    )
