"""Whisper-style encoder-decoder (audio backbone, conv frontend STUBBED).

Per the assignment, the modality frontend is a stub: inputs are precomputed
frame embeddings (B, S_enc, d_model) — what whisper's two conv layers would
emit.  The transformer backbone (12L enc + 12L dec, layernorm, absolute
positions, cross-attention) is implemented fully.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import cross_attention, gqa_attention, init_cross, init_gqa
from .layers import (
    cross_entropy,
    cross_entropy_fused,
    dense_init,
    init_mlp,
    init_norm,
    mlp,
    norm,
)


def _sinusoid(seq: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(key, cfg) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(cfg),
        "attn": init_gqa(ks[0], cfg),
        "ln2": init_norm(cfg),
        "ffn": init_mlp(ks[1], cfg),
    }


def _init_dec_layer(key, cfg) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg),
        "attn": init_gqa(ks[0], cfg),
        "lnx": init_norm(cfg),
        "xattn": init_cross(ks[1], cfg),
        "ln2": init_norm(cfg),
        "ffn": init_mlp(ks[2], cfg),
    }


def init_whisper(key, cfg, max_target_positions: int = 448) -> dict:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_ln": init_norm(cfg),
        "tok": (jax.random.normal(ks[2], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(
            cfg.pdtype
        ),
        "pos": (
            jax.random.normal(ks[3], (max_target_positions, cfg.d_model)) * 0.02
        ).astype(cfg.pdtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "dec_ln": init_norm(cfg),
    }


def encode(params: dict, frames: jnp.ndarray, cfg) -> jnp.ndarray:
    """frames: (B, S_enc, d) precomputed conv-frontend output (stub)."""
    from .transformer import _remat_wrap

    x = frames.astype(cfg.cdtype) + _sinusoid(frames.shape[1], cfg.d_model).astype(
        cfg.cdtype
    )

    def step(x, p):
        h = norm(p["ln1"], x, cfg.norm_kind)
        y, _ = gqa_attention(p["attn"], h, cfg, causal=False)
        x = x + y
        h = norm(p["ln2"], x, cfg.norm_kind)
        return x + mlp(p["ffn"], h, cfg.mlp_kind), None

    x, _ = jax.lax.scan(_remat_wrap(step, cfg), x, params["enc_layers"])
    return norm(params["enc_ln"], x, cfg.norm_kind)


def decode(
    params: dict,
    tokens: jnp.ndarray,
    enc_out: jnp.ndarray,
    cfg,
    cache: Optional[dict] = None,
    mode: str = "train",
    return_hidden: bool = False,
    last_only: bool = False,
):
    """Returns (logits, new_cache).  cache: {"pos", "kv": stacked (k, v)}."""
    B, S = tokens.shape
    decode_mode = mode == "decode"
    pos = cache["pos"] if decode_mode else None
    x = params["tok"].astype(cfg.cdtype)[tokens]
    if decode_mode:
        pe = jax.lax.dynamic_slice_in_dim(params["pos"], cache["pos"], 1, axis=0)
    else:
        pe = params["pos"][:S]
    x = x + pe.astype(cfg.cdtype)[None]

    def step(carry, xs):
        x = carry
        p, c = xs
        h = norm(p["ln1"], x, cfg.norm_kind)
        y, nc = gqa_attention(p["attn"], h, cfg, cache=c, pos=pos)
        x = x + y
        h = norm(p["lnx"], x, cfg.norm_kind)
        x = x + cross_attention(p["xattn"], h, enc_out, cfg)
        h = norm(p["ln2"], x, cfg.norm_kind)
        x = x + mlp(p["ffn"], h, cfg.mlp_kind)
        return x, nc

    from .transformer import _remat_wrap

    if cache is not None:
        x, nkv = jax.lax.scan(
            _remat_wrap(step, cfg), x, (params["dec_layers"], cache["kv"])
        )
        new_cache = {"pos": cache["pos"] + (1 if decode_mode else S), "kv": nkv}
    else:
        def step_nc(x, p):
            h = norm(p["ln1"], x, cfg.norm_kind)
            y, _ = gqa_attention(p["attn"], h, cfg)
            x = x + y
            h = norm(p["lnx"], x, cfg.norm_kind)
            x = x + cross_attention(p["xattn"], h, enc_out, cfg)
            h = norm(p["ln2"], x, cfg.norm_kind)
            return x + mlp(p["ffn"], h, cfg.mlp_kind), None

        x, _ = jax.lax.scan(_remat_wrap(step_nc, cfg), x, params["dec_layers"])
        new_cache = None
    x = norm(params["dec_ln"], x, cfg.norm_kind)
    if return_hidden:
        return x, new_cache
    if last_only:
        x = x[:, -1:, :]
    logits = (x @ params["tok"].astype(x.dtype).T).astype(jnp.float32)
    return logits, new_cache


def init_whisper_cache(cfg, batch: int, s_max: int):
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (cfg.num_layers, batch, s_max, hkv, hd)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "kv": (jnp.zeros(shape, cfg.cdtype), jnp.zeros(shape, cfg.cdtype)),
    }


def whisper_loss(params, batch, cfg):
    """batch: {"frames": (B,Se,d), "tokens": (B,S), "targets": (B,S)}."""
    enc = encode(params, batch["frames"], cfg)
    h, _ = decode(params, batch["tokens"], enc, cfg, return_hidden=True)
    return cross_entropy_fused(
        h, {"tok": params["tok"]}, batch["targets"], cfg, batch.get("mask")
    )
