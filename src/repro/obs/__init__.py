"""Cluster flight recorder: unified tracing + metrics substrate.

Every headline claim in this repo is a *time-series* claim — logical
topology compatibility over a trace, dark-window cost per
reconfiguration, tail latency under shifting demand.  ``repro.obs``
makes those series first-class instead of scattered ad-hoc state:

* :mod:`.trace` — a span/event tracer keyed on **simulated** time with
  deterministic Chrome-trace-event export (open in Perfetto), plus an
  *ambient* handle deep layers (``core``, ``fault``) emit through;
* :mod:`.metrics` — counters / gauges / quantile sketches / keyed
  timelines behind one registry (the φ bookkeeping both engines share);
* :mod:`.recorder` — a bounded flight buffer dumped as JSON when a run
  dies, so postmortems start with the last N events instead of nothing;
* :mod:`.report` — timeline/summary rendering and the uniform
  ``BENCH_*`` metrics block every benchmark exports.

Everything is disabled-by-default and zero-dependency: a simulation
without a tracer pays one attribute read per would-be event, and golden
traces are byte-identical with tracing on or off
(``tests/test_obs.py``).

On top of the substrate sit the *explanation* layers:

* :mod:`.attrib` — blame attribution: replays the recorded data and
  splits every request's and job's measured slowdown into named causes
  (queue, dark windows, solver, degraded capacity, φ-shortfall …) with
  an exact conservation invariant;
* :mod:`.health` — streaming detectors running inside the event loop
  (SLO burn rate, φ-drop, dark-window storms, reconfig churn) emitting
  ``HealthEvent`` instants plus the ``SimConfig.on_health`` hook.
"""
from .attrib import (
    AttribLog,
    Blame,
    CAUSES,
    DARK_CAUSES,
    JOB_CAUSES,
    Segmentation,
    attribute_jobs,
    attribute_requests,
)
from .health import BurnWindow, HealthEvent, HealthMonitor
from .metrics import (
    Counter,
    Gauge,
    MetricsRegistry,
    QuantileSketch,
    Series,
    Timeline,
)
from .recorder import dump_flight, flight_guard
from .report import (
    BENCH_SCHEMA,
    bench_block,
    flatten_scalars,
    render_blame,
    render_summary,
    render_timeline,
    write_bench_block,
)
from .trace import NULL, NullTracer, Tracer, ambient, set_ambient, validate_trace

__all__ = [
    "AttribLog",
    "BENCH_SCHEMA",
    "Blame",
    "BurnWindow",
    "CAUSES",
    "Counter",
    "DARK_CAUSES",
    "Gauge",
    "HealthEvent",
    "HealthMonitor",
    "JOB_CAUSES",
    "MetricsRegistry",
    "NULL",
    "NullTracer",
    "QuantileSketch",
    "Segmentation",
    "Series",
    "Timeline",
    "Tracer",
    "ambient",
    "attribute_jobs",
    "attribute_requests",
    "bench_block",
    "dump_flight",
    "flatten_scalars",
    "flight_guard",
    "render_blame",
    "render_summary",
    "render_timeline",
    "set_ambient",
    "validate_trace",
    "write_bench_block",
]
