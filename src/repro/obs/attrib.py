"""Blame attribution: decompose measured slowdown into named causes.

PR 6's flight recorder *records* everything — solve spans, dark windows,
φ timelines, request phases — but explains nothing: when a serving
request blows its SLO or a job's JCT regresses, the cause ("a
dark-window storm from reconfig churn", "φ oversubscription", "a
cold-solve fallback") is implicit in the trace and must be dug out by
hand.  This module replays the recorded data and splits the measured
slowdown of every request and every job into a fixed cause taxonomy
(:data:`CAUSES`), with a hard **conservation invariant**: the per-cause
seconds sum to the measured slowdown on every run, within 1e-6
(``tests/test_attrib.py`` property-tests this over mixed train+serve
fluid runs with faults, like the fluid differential suite).

How conservation is *exact by construction*
-------------------------------------------
A serving request arriving at ``a`` with ideal (φ = 1) latency
``work + α`` finishes its transfer at ``f`` with ``∫ₐᶠ φ dt = work``, so
its slowdown is ``(f − a) − work = ∫ₐᶠ (1 − φ) dt``.  The attribution
partitions ``[a, f]`` at every φ breakpoint and every recorded
cause-interval boundary (dark windows, solve spans, degraded-mask
intervals) and assigns each sub-segment's ``(1 − φ)·dt`` weight to
exactly **one** cause by a fixed priority — the sub-segments are
disjoint and exhaustive, so the per-cause sums reconstruct the integral
identically.  Training jobs use the same scheme on their recorded
progress-rate timeline: ``JCT − service = Σ gaps + Σ ∫(1 − rate) dt +
Σ lost work``, each term cause-tagged (see :func:`attribute_jobs`).

Cause priority (first match wins per sub-segment):

1. ``queue`` — before the fleet's first φ breakpoint / a job's
   not-running gaps (minus the portions below);
2. ``autoscale_lag`` — inside a dark window whose reconfiguration was
   triggered by an autoscale event (capacity arrived, fabric still
   retuning);
3. ``remediation`` — inside a dark window opened by a remediation
   action (drain-and-reroute, pre-emptive checkpoint re-solve: the
   self-healing loop's own footprint, charged to itself, never hidden
   in the generic dark buckets);
4. ``dark_incremental`` / ``dark_cold`` — inside a dark window opened
   by an incremental (``mdmcf_delta``) vs cold re-solve;
5. ``solver`` — inside a control-plane solve span (computation time);
6. ``cordon`` — a cordon-triggered dark window, or any interval during
   which ≥ 1 link sat administratively cordoned (capacity voluntarily
   withheld by the remediation engine);
7. ``degraded`` — the fault mask was non-trivial (failure-degraded
   capacity);
8. ``phi_shortfall`` — residual φ < 1 from plain oversubscription.

Plus the job-only causes ``restart`` (kill → ready recovery cost) and
``rollback`` (work re-done after checkpoint rollback, from-scratch
restarts, and the analytic engine's OCS switching pauses).
``remediation`` also carries job work paused for pre-emptive
checkpoints (:meth:`AttribLog.lose` with cause ``remediation``).

The recording side is :class:`AttribLog`, populated by
``sim/scheduler.py`` during the run (solve/dark/degraded intervals,
per-job rate breakpoints, stints, restarts, lost work); the replay side
is :func:`attribute_requests` / :func:`attribute_jobs`.

>>> log = AttribLog()
>>> log.dark_window(2.0, 4.0, "cold", "fault")
>>> seg = Segmentation.for_timeline([(0.0, 1.0), (1.0, 0.5)], log, hi=6.0)
>>> b = seg.blame_window(1.0, 5.0)          # ∫(1−φ) over [1, 5] = 2.0
>>> round(b["dark_cold"], 9), round(b["phi_shortfall"], 9)
(1.0, 1.0)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import metrics as obs_metrics

__all__ = [
    "AttribLog",
    "Blame",
    "CAUSES",
    "JOB_CAUSES",
    "Segmentation",
    "attribute_jobs",
    "attribute_requests",
]

# request-level causes, in classification priority order (queue is
# special-cased first; phi_shortfall is the residual)
CAUSES = (
    "queue",
    "autoscale_lag",
    "remediation",
    "dark_incremental",
    "dark_cold",
    "solver",
    "cordon",
    "degraded",
    "phi_shortfall",
)
# jobs additionally lose time to recovery itself
JOB_CAUSES = CAUSES + ("restart", "rollback")

DARK_CAUSES = ("autoscale_lag", "dark_incremental", "dark_cold")


class AttribLog:
    """The attribution record one simulated run leaves behind.

    Populated by the scheduler as it runs (never read on the hot path);
    replayed afterwards by :func:`attribute_requests` /
    :func:`attribute_jobs`.  All times are simulated seconds.
    """

    __slots__ = (
        "solves", "dark", "degraded", "restarts", "lost", "stints", "rate",
        "cordons", "_degraded_open", "_cordon_open", "_cordon_depth",
    )

    def __init__(self) -> None:
        self.solves: List[Tuple[float, float, str, str]] = []  # t0,t1,kind,trigger
        self.dark: List[Tuple[float, float, str, str]] = []  # t0,t1,kind,trigger
        self.degraded: List[Tuple[float, float]] = []  # mask non-trivial
        self.restarts: Dict[int, List[Tuple[float, float]]] = {}  # kill→ready
        self.lost: Dict[int, List[Tuple[float, float, str]]] = {}  # t,work,cause
        self.stints: Dict[int, List[List[float]]] = {}  # [t0, t1] (t1 nan=open)
        self.rate = obs_metrics.Timeline("attrib.rate")  # jid → (t, 1/slowdown)
        self.cordons: List[Tuple[float, float]] = []  # ≥ 1 link cordoned
        self._degraded_open: Optional[float] = None
        self._cordon_open: Optional[float] = None
        self._cordon_depth = 0

    # ---- recording (scheduler-facing) -----------------------------------

    def solve(self, t0: float, t1: float, kind: str, trigger: str) -> None:
        self.solves.append((t0, t1, kind, trigger))

    def dark_window(self, t0: float, t1: float, kind: str, trigger: str) -> None:
        self.dark.append((t0, t1, kind, trigger))

    def degraded_begin(self, t: float) -> None:
        if self._degraded_open is None:
            self._degraded_open = t

    def degraded_end(self, t: float) -> None:
        if self._degraded_open is not None:
            self.degraded.append((self._degraded_open, t))
            self._degraded_open = None

    def cordon_begin(self, t: float) -> None:
        """A link was cordoned (ref-counted: the interval stays open
        while *any* link is cordoned)."""
        self._cordon_depth += 1
        if self._cordon_depth == 1:
            self._cordon_open = t

    def cordon_end(self, t: float) -> None:
        self._cordon_depth = max(0, self._cordon_depth - 1)
        if self._cordon_depth == 0 and self._cordon_open is not None:
            self.cordons.append((self._cordon_open, t))
            self._cordon_open = None

    def stint_begin(self, jid: int, t: float) -> None:
        self.stints.setdefault(jid, []).append([t, math.nan])

    def stint_end(self, jid: int, t: float) -> None:
        spans = self.stints.get(jid)
        if spans and math.isnan(spans[-1][1]):
            spans[-1][1] = t

    def restart(self, jid: int, kill_t: float, ready_t: float) -> None:
        self.restarts.setdefault(jid, []).append((kill_t, ready_t))

    def lose(self, jid: int, t: float, work_s: float, cause: str) -> None:
        if work_s > 0.0:
            self.lost.setdefault(jid, []).append((t, work_s, cause))

    def close(self, t: float) -> None:
        """End-of-run: close the open degraded/cordon intervals and
        stints."""
        self.degraded_end(t)
        if self._cordon_open is not None:
            self.cordons.append((self._cordon_open, t))
            self._cordon_open = None
            self._cordon_depth = 0
        for spans in self.stints.values():
            if spans and math.isnan(spans[-1][1]):
                spans[-1][1] = t

    # ---- cause intervals --------------------------------------------------

    def cause_intervals(self) -> Dict[str, List[Tuple[float, float]]]:
        """The recorded intervals grouped by the cause they attribute to
        (dark windows split by trigger/kind per the priority rules)."""
        out: Dict[str, List[Tuple[float, float]]] = {
            "autoscale_lag": [], "remediation": [],
            "dark_incremental": [], "dark_cold": [],
            "solver": [(a, b) for a, b, _, _ in self.solves],
            "cordon": list(self.cordons),
            "degraded": list(self.degraded),
        }
        for t0, t1, kind, trigger in self.dark:
            if trigger == "autoscale":
                out["autoscale_lag"].append((t0, t1))
            elif trigger == "remediation":
                out["remediation"].append((t0, t1))
            elif trigger == "cordon":
                out["cordon"].append((t0, t1))
            elif kind == "incremental":
                out["dark_incremental"].append((t0, t1))
            else:
                out["dark_cold"].append((t0, t1))
        return out


@dataclasses.dataclass
class Blame:
    """One attributed entity: measured slowdown + its per-cause split.

    ``residual`` is the conservation gap — |residual| stays below the
    1e-6 invariant on every run (property-tested).
    """

    key: Any
    slowdown_s: float
    causes: Dict[str, float]

    @property
    def residual(self) -> float:
        return self.slowdown_s - math.fsum(self.causes.values())

    def conserved(self, tol: float = 1e-6) -> bool:
        return math.isfinite(self.slowdown_s) and abs(self.residual) <= tol


def _coverage(edges_mid: np.ndarray, intervals: Sequence[Tuple[float, float]]):
    """True where a midpoint falls inside ≥ 1 (possibly overlapping)
    interval — interval stabbing via sorted start/end counts."""
    if not intervals:
        return np.zeros(edges_mid.shape, dtype=bool)
    starts = np.sort(np.array([a for a, _ in intervals]))
    ends = np.sort(np.array([b for _, b in intervals]))
    return (
        np.searchsorted(starts, edges_mid, side="right")
        - np.searchsorted(ends, edges_mid, side="right")
    ) > 0


class Segmentation:
    """A φ (or rate) timeline partitioned at every cause boundary.

    Precomputes per-cause cumulative ``∫(1 − φ)·[cause]`` arrays over the
    partition so :meth:`blame_window` answers any ``[a, b]`` window in
    O(log S) — the per-request attribution over thousands of requests is
    vectorized interpolation, not a Python loop per request.
    """

    def __init__(
        self,
        edges: np.ndarray,
        phi: np.ndarray,
        cause_idx: np.ndarray,
        causes: Tuple[str, ...],
    ):
        self.edges = edges  # (S+1,) segment boundaries
        self.phi = phi  # (S,) φ per segment
        self.cause_idx = cause_idx  # (S,) index into causes
        self.causes = causes
        w = (1.0 - phi) * np.diff(edges)  # (S,) slowdown weight
        self._cum = np.zeros((len(causes), len(edges)))
        for c in range(len(causes)):
            self._cum[c, 1:] = np.cumsum(np.where(cause_idx == c, w, 0.0))

    @classmethod
    def for_timeline(
        cls,
        timeline: Sequence[Tuple[float, float]],
        log: AttribLog,
        hi: float,
        lo: float = 0.0,
    ) -> "Segmentation":
        """Partition ``[lo, hi]`` for one piecewise-constant ``(t, φ)``
        timeline against ``log``'s cause intervals.  Before the first
        breakpoint φ = 0 and the cause is ``queue`` (the fleet/job is not
        up yet); afterwards the priority rules of the module docstring
        classify each segment."""
        ivals = log.cause_intervals()
        ts = np.array([t for t, _ in timeline], dtype=np.float64)
        vs = np.array([v for _, v in timeline], dtype=np.float64)
        cuts = [np.array([lo, hi]), ts]
        for spans in ivals.values():
            for a, b in spans:
                cuts.append(np.array([a, b]))
        edges = np.unique(np.concatenate(cuts))
        edges = edges[(edges >= lo) & (edges <= hi)]
        if edges.size == 0 or edges[0] > lo:
            edges = np.concatenate([[lo], edges])
        if edges[-1] < hi:
            edges = np.concatenate([edges, [hi]])
        mid = 0.5 * (edges[:-1] + edges[1:])
        # φ per segment: piecewise constant from the timeline, 0 before
        # its first breakpoint
        if ts.size:
            idx = np.searchsorted(ts, mid, side="right") - 1
            phi = np.where(idx >= 0, vs[np.clip(idx, 0, None)], 0.0)
            queued = mid < ts[0]
        else:
            phi = np.zeros(mid.shape)
            queued = np.ones(mid.shape, dtype=bool)
        causes = CAUSES
        n_short = causes.index("phi_shortfall")
        cause_idx = np.full(mid.shape, n_short, dtype=np.int64)
        # reverse priority order so higher-priority assignments overwrite
        for name in ("degraded", "cordon", "solver", "dark_cold",
                     "dark_incremental", "remediation", "autoscale_lag"):
            cov = _coverage(mid, ivals[name])
            cause_idx[cov] = causes.index(name)
        cause_idx[queued] = causes.index("queue")
        return cls(edges, phi, cause_idx, causes)

    def _eval(self, x: np.ndarray) -> np.ndarray:
        """Per-cause cumulative weight at each ``x`` — exact within a
        segment because φ and cause are constant there."""
        x = np.clip(x, self.edges[0], self.edges[-1])
        k = np.clip(
            np.searchsorted(self.edges, x, side="right") - 1,
            0, len(self.phi) - 1,
        )
        frac = (x - self.edges[k]) * (1.0 - self.phi[k])
        out = self._cum[:, k]
        out[self.cause_idx[k], np.arange(len(x))] += frac
        return out  # (C, len(x))

    def blame_windows(
        self, a: np.ndarray, b: np.ndarray
    ) -> Dict[str, np.ndarray]:
        """Per-cause ``∫(1 − φ) dt`` over each window ``[a_i, b_i]``."""
        lo, hi = self._eval(np.asarray(a, dtype=np.float64)), self._eval(
            np.asarray(b, dtype=np.float64)
        )
        d = hi - lo
        return {name: d[c] for c, name in enumerate(self.causes)}

    def blame_window(self, a: float, b: float) -> Dict[str, float]:
        per = self.blame_windows(np.array([a]), np.array([b]))
        return {name: float(v[0]) for name, v in per.items()}


# ---- serving requests -------------------------------------------------------

def attribute_requests(sim, tol: float = 1e-6) -> Dict[str, Any]:
    """Per-request blame decomposition of every serving fleet in ``sim``
    (a finished :class:`~repro.sim.scheduler.Simulator`).

    Regenerates each fleet's deterministic request stream exactly as
    ``serving_summary`` does, prices each request against the recorded φ
    timeline, and splits its slowdown (latency − ideal) across
    :data:`CAUSES`.  Returns per-fleet rows (total per-cause seconds,
    mean per request, the p99-tail breakdown — the mean split of the
    slowest 1 % of requests) plus pooled totals and the conservation
    check (``max_residual`` over every finite request must stay ≤
    ``tol``).  Requests that never finish (φ stuck at 0) are excluded
    and counted in ``stalled``.
    """
    from ..sim import serving as serving_mod  # lazy: obs sits below sim

    log: AttribLog = sim.attrib
    horizon = sim._end_time
    rows: Dict[int, Dict[str, Any]] = {}
    totals = {c: 0.0 for c in CAUSES}
    pooled_blame: List[np.ndarray] = []  # (C, N) per fleet
    pooled_lat: List[np.ndarray] = []
    requests = finite = 0
    max_residual = 0.0
    for j in sim.jobs:
        if j.kind != "serve":
            continue
        span = horizon - j.arrival
        arrivals = (
            serving_mod.serving_trace(
                span, j.req_rate, seed=(sim.seed, j.job_id),
                diurnal=j.diurnal, period_s=sim.cfg.serving_period_s,
                t0=j.arrival,
            )
            if span > 0 and j.req_rate > 0 else np.empty(0)
        )
        work, alpha_s = sim._serving_work.get(j.job_id, (0.0, 0.0))
        tl = sim.phi_timeline.get(j.job_id, ())
        lat = serving_mod.request_latencies(arrivals, work, tl, alpha_s=alpha_s)
        ok = np.isfinite(lat)
        slow = serving_mod.request_slowdowns(lat[ok], work, alpha_s=alpha_s)
        finish = arrivals[ok] + lat[ok] - alpha_s
        hi = max(horizon, float(finish.max()) + 1.0 if finish.size else horizon)
        seg = Segmentation.for_timeline(tl, log, hi=hi, lo=min(j.arrival, hi))
        per = seg.blame_windows(arrivals[ok], finish)
        mat = np.stack([per[c] for c in CAUSES]) if ok.any() else np.zeros(
            (len(CAUSES), 0)
        )
        resid = (
            float(np.abs(mat.sum(axis=0) - slow).max()) if slow.size else 0.0
        )
        max_residual = max(max_residual, resid)
        blame = {c: float(per[c].sum()) for c in CAUSES}
        row: Dict[str, Any] = {
            "requests": int(lat.size),
            "stalled": int(lat.size - ok.sum()),
            "slowdown_s": float(slow.sum()),
            "blame": blame,
            "max_residual": resid,
            "p99_blame": _tail_blame(lat[ok], mat),
        }
        rows[j.job_id] = row
        for c in CAUSES:
            totals[c] += blame[c]
        pooled_blame.append(mat)
        pooled_lat.append(lat[ok])
        requests += int(lat.size)
        finite += int(ok.sum())
    all_mat = (
        np.concatenate(pooled_blame, axis=1)
        if pooled_blame else np.zeros((len(CAUSES), 0))
    )
    all_lat = np.concatenate(pooled_lat) if pooled_lat else np.empty(0)
    return {
        "jobs": rows,
        "totals": totals,
        "slowdown_s": float(math.fsum(totals.values())),
        "requests": requests,
        "finite": finite,
        "stalled": requests - finite,
        "max_residual": max_residual,
        "conserved": max_residual <= tol,
        "p99_blame": _tail_blame(all_lat, all_mat),
    }


def _tail_blame(lat: np.ndarray, mat: np.ndarray) -> Dict[str, float]:
    """Mean per-cause seconds over the slowest 1 % of requests — "of the
    p99 request's latency, X s is dark-window, Y s is φ-shortfall"."""
    if lat.size == 0:
        return {c: 0.0 for c in CAUSES}
    cut = np.quantile(lat, 0.99)
    tail = lat >= cut
    n = max(1, int(tail.sum()))
    return {
        c: float(mat[k, tail].sum() / n) for k, c in enumerate(CAUSES)
    }


# ---- training jobs ----------------------------------------------------------

def attribute_jobs(sim, tol: float = 1e-6) -> Dict[int, Blame]:
    """Blame decomposition of every *finished* training job's slowdown
    (``JCT − service_time``) in a finished simulator.

    The identity replayed from the :class:`AttribLog`::

        JCT − service = Σ gaps  +  Σ_stints ∫(1 − rate) dt  +  Σ lost

    — gaps (not running) split into ``restart`` (kill → recovery-ready),
    ``solver`` (overlapping control-plane solve spans) and ``queue``; stint
    deficits are cause-partitioned exactly like request slowdown (the
    recorded rate timeline plays the role of φ); lost work carries the
    cause it was recorded with (``rollback`` for checkpoint rollbacks and
    from-scratch restarts, ``dark_*`` for the analytic engine's OCS
    switching pauses).  Conservation is exact because the recorded rate
    breakpoints are the very values the scheduler integrated progress
    with.
    """
    log: AttribLog = sim.attrib
    out: Dict[int, Blame] = {}
    solve_ivals = [(a, b) for a, b, _, _ in log.solves]
    for jid, rec in sim.records.items():
        if rec.job.kind == "serve" or not math.isfinite(rec.finish):
            continue
        causes = {c: 0.0 for c in JOB_CAUSES}
        stints = [s for s in log.stints.get(jid, []) if not math.isnan(s[1])]
        tl = log.rate.get(jid, ())
        hi = max([rec.finish] + [s[1] for s in stints]) + 1.0
        seg = Segmentation.for_timeline(tl, log, hi=hi, lo=rec.job.arrival)
        # running stints: ∫(1 − rate) dt, cause-partitioned
        for t0, t1 in stints:
            for c, v in seg.blame_window(t0, t1).items():
                if c == "queue":
                    # rate breakpoints exist from the stint start, so the
                    # pre-timeline "queue" bucket can only catch the
                    # first stint's opening instant — fold it into queue
                    causes["queue"] += v
                else:
                    causes[c] += v
        # gaps: [arrival → stint0], [stint_k end → stint_{k+1} start]
        recovery = log.restarts.get(jid, [])
        bounds = [rec.job.arrival] + [
            b for s in stints for b in s
        ]
        gaps = [
            (bounds[i], bounds[i + 1]) for i in range(0, len(bounds) - 1, 2)
        ]
        for g0, g1 in gaps:
            if g1 <= g0:
                continue
            rest = _overlap(g0, g1, recovery)
            causes["restart"] += rest
            solv = _overlap(g0, g1, solve_ivals)
            causes["solver"] += min(solv, (g1 - g0) - rest)
            causes["queue"] += max(0.0, (g1 - g0) - rest - min(
                solv, (g1 - g0) - rest
            ))
        for _, work_s, cause in log.lost.get(jid, []):
            causes[cause] = causes.get(cause, 0.0) + work_s
        out[jid] = Blame(jid, rec.jct - rec.job.service_time, causes)
    return out


def _overlap(
    a: float, b: float, intervals: Sequence[Tuple[float, float]]
) -> float:
    """Total length of ``[a, b]`` covered by (possibly overlapping)
    intervals — swept via sorted boundary events."""
    pts = sorted(
        {a, b}
        | {t for i0, i1 in intervals for t in (i0, i1) if a < t < b}
    )
    mids = [(0.5 * (pts[i] + pts[i + 1]), pts[i + 1] - pts[i])
            for i in range(len(pts) - 1)]
    return math.fsum(
        w for m, w in mids
        if any(i0 <= m < i1 for i0, i1 in intervals)
    )
