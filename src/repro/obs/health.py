"""Streaming cluster health detectors (run *inside* the event loop).

The attribution engine (:mod:`.attrib`) explains a run after the fact;
this module detects trouble *while the run is live*, so decision layers
— the ROADMAP's topology-aware router and reconfig-hysteresis policy —
can subscribe to signals instead of re-deriving them from raw traces.
The scheduler feeds a :class:`HealthMonitor` from its existing emit
sites (φ breakpoints, dark-window creation, control-plane solves); the
monitor is **passive** — it never touches simulation state, so goldens
are byte-identical with or without it — and deterministic, keyed on
simulated time only.

Detectors (all thresholds are constructor parameters):

* ``slo_burn`` — multi-window SLO burn rate per serving fleet.  φ below
  ``1/serving_slo`` is *burning error budget* (a request needs mean φ ≥
  1/slo across its transfer to meet the SLO), so the monitor tracks the
  time-weighted bad fraction over a short and a long trailing window
  and fires when **both** exceed the rule's burn threshold — the classic
  fast-burn/slow-burn pair: the short window gives fast detection, the
  long window keeps one transient spike from paging.
* ``phi_drop`` — a serving fleet's realized φ collapses in one step
  (ratio below ``phi_drop_ratio``): the signature of a failure or a
  reconfiguration landing on its circuits.
* ``dark_storm`` — circuit-seconds of reconfiguration darkness in a
  sliding window exceed ``storm_circuit_s``: many circuits retuning at
  once, the failure mode FastReChain warns shifting demand induces.
* ``reconfig_churn`` — ≥ ``churn_solves`` control-plane solves in the
  churn window with a cold-solve share ≥ ``churn_cold_frac``: the
  incremental path is thrashing and dark windows are about to pile up.
* ``link_flap`` — one link (OCS slot) failed or went gray ≥
  ``flap_count`` times inside ``flap_window_s``: the signature of a
  flapping transceiver, the input the remediation engine's cordon
  action keys on (``event.detail`` carries the ``(h, k, pod)`` slot).
* ``solver_fallback`` — ≥ ``fallback_count`` delta-path fallbacks
  (``StaleStateError`` / ``DeltaInfeasible`` cold solves) inside
  ``fallback_window_s``: the incremental control plane has effectively
  stopped serving events and every solve pays the cold price.

Every firing appends a :class:`HealthEvent`, emits a ``health``-category
instant into the tracer (rendered as its own Perfetto track), and calls
the ``on_event`` subscription hook (``SimConfig.on_health``).  Detectors
re-arm only after their condition clears, so a sustained breach fires
once, not per sample.

>>> fired = []
>>> mon = HealthMonitor(slo=4.0, on_event=fired.append)
>>> for t in range(10):                     # healthy: φ = 1
...     mon.observe_phi(float(t), 7, 1.0)
>>> mon.observe_phi(10.0, 7, 0.05)          # collapse → phi_drop fires
>>> [e.detector for e in fired]
['phi_drop']
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Deque, Dict, List, Optional, Tuple

import collections

from . import trace as obs_trace

__all__ = [
    "BurnWindow",
    "HealthEvent",
    "HealthMonitor",
]


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One detector firing, on simulated time.

    ``key`` scopes the signal (serving job id for per-fleet detectors,
    ``None`` for cluster-wide ones); ``value`` / ``threshold`` record
    what was measured against what, so subscribers can act proportionally
    (e.g. a hysteresis policy backing off harder at 2× threshold).
    ``detail`` carries detector-specific structure — the ``(h, k, pod)``
    slot for ``link_flap`` — so a subscriber can act on the exact
    component without re-deriving it.
    """

    t: float
    detector: str  # slo_burn | phi_drop | dark_storm | reconfig_churn
    # | link_flap | solver_fallback
    severity: str  # warn | page
    key: Optional[int] = None
    value: float = 0.0
    threshold: float = 0.0
    window_s: float = 0.0
    detail: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """One multi-window burn-rate rule: fire ``severity`` when the
    bad-time fraction over *both* trailing windows reaches ``frac``."""

    short_s: float
    long_s: float
    frac: float
    severity: str


_DEFAULT_BURN = (
    BurnWindow(60.0, 600.0, 0.5, "page"),  # half the last minute AND half
    # of the last 10 minutes below SLO-φ: burning budget 50× too fast
    BurnWindow(300.0, 3600.0, 0.1, "warn"),  # slow burn: 10 % of the last
    # 5 min and hour — sustained degradation worth a look, not a page
)


class _BadClock:
    """Per-key piecewise record of "φ below threshold" time, pruned to
    the longest window any rule needs; O(log n) trailing integrals."""

    __slots__ = ("seg", "keep_s")

    def __init__(self, keep_s: float):
        self.seg: Deque[Tuple[float, float, bool]] = collections.deque()
        self.keep_s = keep_s

    def push(self, t0: float, t1: float, bad: bool) -> None:
        if t1 > t0:
            self.seg.append((t0, t1, bad))
        while self.seg and self.seg[0][1] < t1 - self.keep_s:
            self.seg.popleft()

    def bad_fraction(self, now: float, window_s: float) -> float:
        lo = now - window_s
        bad = total = 0.0
        for t0, t1, b in self.seg:
            a, c = max(t0, lo), min(t1, now)
            if c > a:
                total += c - a
                if b:
                    bad += c - a
        # unobserved time in the window (fleet not up yet) is not counted
        # against the budget
        return bad / total if total > 0 else 0.0


class HealthMonitor:
    """Streaming detectors over the scheduler's emit sites (see module
    docstring).  ``slo`` is the serving SLO multiplier the φ threshold
    derives from (``phi_slo = 1/slo``); pass ``on_event`` to subscribe
    (the ``SimConfig.on_health`` hook routes here)."""

    def __init__(
        self,
        slo: float = 4.0,
        burn_rules: Tuple[BurnWindow, ...] = _DEFAULT_BURN,
        phi_drop_ratio: float = 0.5,
        storm_window_s: float = 60.0,
        storm_circuit_s: float = 10.0,
        churn_window_s: float = 600.0,
        churn_solves: int = 8,
        churn_cold_frac: float = 0.5,
        flap_count: int = 3,
        flap_window_s: float = 3600.0,
        fallback_count: int = 5,
        fallback_window_s: float = 600.0,
        on_event: Optional[Callable[[HealthEvent], None]] = None,
        tracer: Optional[obs_trace.NullTracer] = None,
    ):
        self.phi_slo = 1.0 / slo if slo > 0 else 1.0
        self.burn_rules = tuple(burn_rules)
        self.phi_drop_ratio = phi_drop_ratio
        self.storm_window_s = storm_window_s
        self.storm_circuit_s = storm_circuit_s
        self.churn_window_s = churn_window_s
        self.churn_solves = churn_solves
        self.churn_cold_frac = churn_cold_frac
        self.flap_count = flap_count
        self.flap_window_s = flap_window_s
        self.fallback_count = fallback_count
        self.fallback_window_s = fallback_window_s
        self.on_event = on_event
        self.trace = tracer if tracer is not None else obs_trace.NULL
        self.events: List[HealthEvent] = []
        keep = max((r.long_s for r in self.burn_rules), default=3600.0)
        self._keep_s = keep
        self._clock: Dict[int, _BadClock] = {}
        self._last_phi: Dict[int, Tuple[float, float]] = {}  # key → (t, φ)
        self._burn_hot: Dict[Tuple[int, int], bool] = {}  # (key, rule) armed?
        self._dark: Deque[Tuple[float, float]] = collections.deque()
        self._solves: Deque[Tuple[float, str]] = collections.deque()
        self._storm_hot = False
        self._churn_hot = False
        # (h, k, pod) → failure/derate times inside the flap window
        self._flaps: Dict[Tuple[int, int, int], Deque[float]] = {}
        self._flap_hot: Dict[Tuple[int, int, int], bool] = {}
        self._last_fail: Dict[Tuple[int, int, int], float] = {}
        self._fallbacks: Deque[float] = collections.deque()
        self._fallback_hot = False

    # ---- emission --------------------------------------------------------

    def _fire(self, ev: HealthEvent) -> None:
        self.events.append(ev)
        tr = self.trace
        if tr.enabled:
            extra = {} if ev.detail is None else {"detail": list(ev.detail)}
            tr.instant(
                "health", ev.detector, ts=ev.t,
                severity=ev.severity, key=ev.key,
                value=round(ev.value, 9), threshold=ev.threshold,
                window_s=ev.window_s, **extra,
            )
        if self.on_event is not None:
            self.on_event(ev)

    # ---- detectors -------------------------------------------------------

    def observe_phi(self, t: float, key: int, phi: float) -> None:
        """A serving fleet's realized φ changed (a timeline breakpoint)."""
        prev = self._last_phi.get(key)
        self._last_phi[key] = (t, phi)
        if prev is None:
            return
        t0, phi0 = prev
        clock = self._clock.get(key)
        if clock is None:
            clock = self._clock[key] = _BadClock(self._keep_s)
        clock.push(t0, t, phi0 < self.phi_slo)
        # phi_drop: single-step collapse
        if phi0 > 0 and phi <= self.phi_drop_ratio * phi0:
            self._fire(HealthEvent(
                t, "phi_drop", "page" if phi <= 0.0 else "warn", key=key,
                value=phi / phi0 if phi0 > 0 else 0.0,
                threshold=self.phi_drop_ratio,
            ))
        # slo_burn: both windows of a rule above its burn fraction
        for n, rule in enumerate(self.burn_rules):
            fs = clock.bad_fraction(t, rule.short_s)
            fl = clock.bad_fraction(t, rule.long_s)
            hot = min(fs, fl) >= rule.frac
            was = self._burn_hot.get((key, n), False)
            if hot and not was:
                self._fire(HealthEvent(
                    t, "slo_burn", rule.severity, key=key,
                    value=min(fs, fl), threshold=rule.frac,
                    window_s=rule.long_s,
                ))
            self._burn_hot[(key, n)] = hot

    def observe_dark(
        self, t: float, delay_s: float, pairs: int, kind: str
    ) -> None:
        """A reconfiguration opened dark windows: ``pairs`` pod pairs go
        dark for ``delay_s`` starting at ``t``."""
        self._dark.append((t, delay_s * pairs))
        lo = t - self.storm_window_s
        while self._dark and self._dark[0][0] < lo:
            self._dark.popleft()
        total = math.fsum(v for _, v in self._dark)
        hot = total >= self.storm_circuit_s
        if hot and not self._storm_hot:
            self._fire(HealthEvent(
                t, "dark_storm", "page", value=total,
                threshold=self.storm_circuit_s,
                window_s=self.storm_window_s,
            ))
        self._storm_hot = hot

    def observe_solve(self, t: float, kind: str) -> None:
        """The control plane solved (``kind`` = incremental | cold)."""
        self._solves.append((t, kind))
        lo = t - self.churn_window_s
        while self._solves and self._solves[0][0] < lo:
            self._solves.popleft()
        n = len(self._solves)
        cold = sum(1 for _, k in self._solves if k != "incremental")
        hot = n >= self.churn_solves and cold / n >= self.churn_cold_frac
        if hot and not self._churn_hot:
            self._fire(HealthEvent(
                t, "reconfig_churn", "warn", value=cold / n,
                threshold=self.churn_cold_frac,
                window_s=self.churn_window_s,
            ))
        self._churn_hot = hot

    def observe_fault(
        self, t: float, h: int, k: int, pod: int, down: bool
    ) -> None:
        """A link-scoped fault event landed: ``down=True`` for a failure
        (or a derate below full health), ``False`` for the repair/restore.
        Repairs re-evaluate the window (the latch cools once the flap
        count drains) but never fire."""
        slot = (h, k, pod)
        times = self._flaps.get(slot)
        if times is None:
            times = self._flaps[slot] = collections.deque()
        if down:
            self._last_fail[slot] = t
            times.append(t)
        lo = t - self.flap_window_s
        while times and times[0] < lo:
            times.popleft()
        hot = len(times) >= self.flap_count
        if down and hot and not self._flap_hot.get(slot, False):
            self._fire(HealthEvent(
                t, "link_flap", "warn", value=float(len(times)),
                threshold=float(self.flap_count),
                window_s=self.flap_window_s, detail=slot,
            ))
        self._flap_hot[slot] = hot

    def last_link_failure(self, h: int, k: int, pod: int) -> Optional[float]:
        """Most recent failure/derate time seen for one slot (the
        remediation engine's readmission check reads this)."""
        return self._last_fail.get((h, k, pod))

    def flap_score(self, t: float, h: int, k: int, pod: int) -> int:
        """Failures of one slot inside the trailing flap window at ``t``.

        The ``link_flap`` detector latches hot while a sustained flapper
        keeps its count above threshold, so it fires only once — a
        subscriber deciding whether a cordoned slot is safe to readmit
        must read the window directly, not wait for a re-fire."""
        times = self._flaps.get((h, k, pod))
        if not times:
            return 0
        lo = t - self.flap_window_s
        return sum(1 for x in times if x >= lo)

    def observe_fallback(self, t: float, reason: str) -> None:
        """The incremental control plane fell back to a cold solve
        (``reason`` = exception class name, e.g. ``StaleStateError``)."""
        self._fallbacks.append(t)
        lo = t - self.fallback_window_s
        while self._fallbacks and self._fallbacks[0] < lo:
            self._fallbacks.popleft()
        n = len(self._fallbacks)
        hot = n >= self.fallback_count
        if hot and not self._fallback_hot:
            self._fire(HealthEvent(
                t, "solver_fallback", "warn", value=float(n),
                threshold=float(self.fallback_count),
                window_s=self.fallback_window_s,
            ))
        self._fallback_hot = hot

    def finalize(self, t: float) -> None:
        """End of run: flush each fleet's trailing φ segment so burn
        fractions cover the full horizon (no event fires here — there is
        no one left to page)."""
        for key, (t0, phi) in self._last_phi.items():
            clock = self._clock.get(key)
            if clock is None:
                clock = self._clock[key] = _BadClock(self._keep_s)
            clock.push(t0, t, phi < self.phi_slo)
            self._last_phi[key] = (t, phi)

    # ---- introspection ---------------------------------------------------

    def bad_fraction(self, key: int, now: float, window_s: float) -> float:
        """Trailing bad-time fraction for one fleet (test/debug hook)."""
        clock = self._clock.get(key)
        return clock.bad_fraction(now, window_s) if clock else 0.0
