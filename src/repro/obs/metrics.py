"""Lightweight metrics registry: counters, gauges, quantile sketches,
timelines.

One registry replaces the simulator's parallel ad-hoc stores (bare
``self.restarts += 1`` ints, ``phi_timeline`` dicts,
``policy_decisions`` lists): every number a summary reports is an
instrument with a name, so it can be snapshotted, exported into the
uniform ``BENCH_*`` block (:mod:`repro.obs.report`), and cross-checked —
while the public accessors (``fault_summary()``, ``serving_summary()``,
``Simulator.restarts``, …) keep their exact shapes as thin views.

Instruments
-----------
* :class:`Counter` — monotonically accumulating value (``inc``); stays an
  ``int`` while fed ints, so golden JSON comparisons keep exact types.
* :class:`Gauge` — last-write-wins value.
* :class:`Series` — append-only sample log (e.g. per-solve LTRR); list
  view via ``.data``.
* :class:`QuantileSketch` — fixed-bin streaming quantiles with bounded
  *relative* error (geometric bins), for p50/p99 over unbounded streams
  without keeping samples.
* :class:`Timeline` — keyed piecewise-constant ``(t, value)`` breakpoint
  series with a Mapping read API.  This is the *one* φ-per-flow
  bookkeeping implementation: ``Simulator.phi_timeline`` and
  ``FluidSim.phi_history`` are both instances (previously two hand-rolled
  dict-of-lists twins).

Everything is plain Python; the hot-path cost of an instrument update is
one attribute add.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "MetricsRegistry",
    "QuantileSketch",
    "Series",
    "Timeline",
]


class Counter:
    """Accumulating value.  Integer-fed counters stay integers.

    >>> c = Counter("restarts")
    >>> c.inc(); c.inc(2); c.value
    3
    """

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {self.name: self.value}


class Gauge:
    """Last-write-wins value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = math.nan

    def set(self, v) -> None:
        self.value = v

    def snapshot(self) -> Dict[str, Any]:
        return {self.name: self.value}


class Series:
    """Append-only sample log (list view: ``.data``)."""

    __slots__ = ("name", "data")

    def __init__(self, name: str):
        self.name = name
        self.data: List[Any] = []

    def append(self, v) -> None:
        self.data.append(v)

    def __len__(self) -> int:
        return len(self.data)

    def __iter__(self):
        return iter(self.data)

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {f"{self.name}.count": len(self.data)}
        nums = [v for v in self.data if isinstance(v, (int, float))]
        if nums:
            out[f"{self.name}.min"] = float(min(nums))
            out[f"{self.name}.max"] = float(max(nums))
            out[f"{self.name}.mean"] = float(sum(nums) / len(nums))
        return out


class QuantileSketch:
    """Fixed-bin streaming quantile sketch with bounded relative error.

    Values are counted into geometric bins spanning ``[lo, hi]``; a
    quantile query returns the geometric midpoint of the bin holding the
    target rank, so the relative error of any quantile of values inside
    ``[lo, hi]`` is at most ``rel_error()`` (half the bin growth factor).
    Values below ``lo`` (including 0) land in an underflow bin reported
    as ``lo``; values above ``hi`` clamp to ``hi`` — pick generous bounds
    (default covers 1 µs … 10⁵ s, plenty for latencies) rather than tight
    ones.  ``tests/test_obs.py`` checks the bound against numpy
    percentiles on random streams.

    >>> s = QuantileSketch("lat_s", lo=1e-3, hi=1e3, bins=512)
    >>> for v in [0.01, 0.02, 0.03, 0.04, 100.0]: s.observe(v)
    >>> abs(s.quantile(0.5) / 0.03 - 1.0) <= s.rel_error()
    True
    """

    __slots__ = ("name", "lo", "hi", "bins", "_counts", "_ratio", "count", "total")

    def __init__(
        self, name: str, lo: float = 1e-6, hi: float = 1e5, bins: int = 512
    ):
        if not (0 < lo < hi) or bins < 2:
            raise ValueError("need 0 < lo < hi and bins >= 2")
        self.name = name
        self.lo = float(lo)
        self.hi = float(hi)
        self.bins = int(bins)
        self._counts = np.zeros(bins + 2, dtype=np.int64)  # [under, bins…, over]
        self._ratio = (self.hi / self.lo) ** (1.0 / bins)
        self.count = 0
        self.total = 0.0

    def rel_error(self) -> float:
        """Max relative quantile error for in-range values: the bin
        midpoint is within a half-bin of the true value."""
        return math.sqrt(self._ratio) - 1.0

    def compatible(self, other: "QuantileSketch") -> bool:
        """True when ``other`` shares this sketch's bin layout (a
        prerequisite for exact :meth:`merge`)."""
        return (
            isinstance(other, QuantileSketch)
            and other.lo == self.lo
            and other.hi == self.hi
            and other.bins == self.bins
        )

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other``'s observations into this sketch, in place.

        Bin counts add exactly, so per-fleet sketches aggregate into
        cluster-level percentiles without re-streaming the samples — the
        merged quantile is identical to observing both streams into one
        sketch.  Requires an identical bin layout (``lo``/``hi``/``bins``).

        >>> a, b = QuantileSketch("a"), QuantileSketch("b")
        >>> for v in (0.1, 0.2): a.observe(v)
        >>> for v in (0.3, 0.4): b.observe(v)
        >>> c = QuantileSketch("c")
        >>> for v in (0.1, 0.2, 0.3, 0.4): c.observe(v)
        >>> a.merge(b).quantile(0.5) == c.quantile(0.5)
        True
        """
        if not self.compatible(other):
            raise ValueError(
                f"cannot merge sketch {other.name!r} into {self.name!r}: "
                "bin layouts (lo/hi/bins) differ"
            )
        self._counts += other._counts
        self.count += other.count
        self.total += other.total
        return self

    def _bin(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self.bins + 1
        return 1 + int(math.log(v / self.lo) / math.log(self._ratio))

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self._counts[min(self._bin(v), self.bins + 1)] += 1

    def quantile(self, q: float) -> float:
        """Streaming quantile estimate (nan while empty)."""
        if self.count == 0:
            return math.nan
        rank = q * (self.count - 1)
        cum = np.cumsum(self._counts)
        b = int(np.searchsorted(cum, rank + 1))
        if b == 0:
            return self.lo
        if b >= self.bins + 1:
            return self.hi
        lo_edge = self.lo * self._ratio ** (b - 1)
        return lo_edge * math.sqrt(self._ratio)  # geometric bin midpoint

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def snapshot(self) -> Dict[str, Any]:
        return {
            f"{self.name}.count": self.count,
            f"{self.name}.mean": self.mean,
            f"{self.name}.p50": self.quantile(0.5),
            f"{self.name}.p99": self.quantile(0.99),
        }


class Timeline:
    """Keyed piecewise-constant breakpoint series: key → [(t, value), …].

    The single φ-bookkeeping implementation shared by the scheduler
    (``Simulator.phi_timeline``) and the fluid engine
    (``FluidSim.phi_history``).  Reads look like the dict-of-lists they
    replaced (``tl[key]``, ``tl.get(key, ())``, iteration); writes go
    through :meth:`point`, which monotonizes timestamps — a start refresh
    can run slightly ahead of the event clock (reconfiguration
    computation time), so a point earlier than the key's last breakpoint
    is clamped to it.

    >>> tl = Timeline("phi")
    >>> tl.point(7, 0.0, 1.0); tl.point(7, 5.0, 0.25); tl.point(7, 4.0, 0.5)
    >>> tl[7]
    [(0.0, 1.0), (5.0, 0.25), (5.0, 0.5)]
    """

    __slots__ = ("name", "series")

    def __init__(self, name: str = "timeline"):
        self.name = name
        self.series: Dict[Any, List[Tuple[float, float]]] = {}

    def point(self, key, t: float, value: float) -> None:
        tl = self.series.setdefault(key, [])
        if tl and t < tl[-1][0]:
            t = tl[-1][0]
        tl.append((t, value))

    # ---- Mapping-style read API -----------------------------------------
    def __getitem__(self, key) -> List[Tuple[float, float]]:
        return self.series[key]

    def get(self, key, default=None):
        return self.series.get(key, default)

    def __contains__(self, key) -> bool:
        return key in self.series

    def __iter__(self) -> Iterator:
        return iter(self.series)

    def __len__(self) -> int:
        return len(self.series)

    def __bool__(self) -> bool:
        return bool(self.series)

    def keys(self):
        return self.series.keys()

    def items(self):
        return self.series.items()

    def values(self):
        return self.series.values()

    def integrate(self, key, t0: float, t1: float) -> float:
        """∫ value dt over ``[t0, t1]`` for ``key`` (piecewise constant,
        last value extends to ``t1``; 0 before the first breakpoint).

        Exact on the edge cases the blame-attribution replay depends on
        (:mod:`repro.obs.attrib`): a zero-width window (``t1 == t0``) is
        exactly 0, zero-width segments (monotonized same-``t``
        breakpoints) contribute exactly 0, and an *open-ended* final
        segment integrates against ``t1 = inf`` without producing
        ``inf · 0 = nan`` when the tail value is 0.

        >>> tl = Timeline("phi"); tl.point("a", 0.0, 1.0)
        >>> tl.point("a", 2.0, 0.0)  # tail goes dark
        >>> tl.integrate("a", 0.0, math.inf)  # open-ended, not nan
        2.0
        >>> tl.integrate("a", 1.5, 1.5)  # zero-width window
        0.0
        """
        tl = self.series.get(key)
        if not tl or t1 <= t0:
            return 0.0
        total = 0.0
        for n, (t, v) in enumerate(tl):
            if v == 0.0:
                continue  # exact 0 even over an infinite tail segment
            seg_end = tl[n + 1][0] if n + 1 < len(tl) else t1
            a, b = max(t, t0), min(seg_end, t1)
            if b > a:
                total += (b - a) * v
        return total

    def snapshot(self) -> Dict[str, Any]:
        return {
            f"{self.name}.keys": len(self.series),
            f"{self.name}.points": sum(len(v) for v in self.series.values()),
        }


class MetricsRegistry:
    """Name → instrument registry with get-or-create accessors.

    >>> reg = MetricsRegistry()
    >>> reg.counter("restarts").inc()
    >>> reg.counter("restarts").value
    1
    >>> sorted(reg.snapshot())
    ['restarts']
    """

    def __init__(self):
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls, *args, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, *args, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(inst).__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def series(self, name: str) -> Series:
        return self._get(name, Series)

    def histogram(
        self, name: str, lo: float = 1e-6, hi: float = 1e5, bins: int = 512
    ) -> QuantileSketch:
        return self._get(name, QuantileSketch, lo, hi, bins)

    def timeline(self, name: str) -> Timeline:
        return self._get(name, Timeline)

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def __iter__(self) -> Iterator[str]:
        return iter(self._instruments)

    def get(self, name: str) -> Optional[Any]:
        return self._instruments.get(name)

    def snapshot(self) -> Dict[str, Any]:
        """Flat scalar view of every instrument (stable key order)."""
        out: Dict[str, Any] = {}
        for name in sorted(self._instruments):
            out.update(self._instruments[name].snapshot())
        return out
