"""Bounded flight recorder: dump the last N trace events on failure.

A long simulation that dies on an assertion loses exactly the context a
postmortem needs — what the control plane and fault stream were doing in
the seconds before.  The :class:`~repro.obs.trace.Tracer` already keeps a
bounded ring buffer of the most recent events (``flight_size``); this
module turns that tail into an artifact:

* :func:`dump_flight` writes the ring buffer (plus the exception, when
  one is in flight) as a small JSON document;
* :func:`flight_guard` wraps a block of simulation code — on *any*
  exception it writes the dump and re-raises, untouched, so behaviour is
  identical except that a ``*.flightrec.json`` file now exists.

``Simulator.run`` guards its event loop automatically whenever its
tracer carries a ``flight_dump`` path (opt-in: library code never writes
files unless asked to).
"""
from __future__ import annotations

import contextlib
import json
import traceback
from typing import Any, Dict, Iterator, Optional

from .trace import NullTracer

__all__ = ["dump_flight", "flight_guard"]

SCHEMA = "repro-flightrec/1"


def dump_flight(
    tracer: NullTracer,
    path: str,
    error: Optional[BaseException] = None,
) -> str:
    """Write the tracer's bounded event tail to ``path``; returns the
    path.  ``error`` (when given) is recorded as type/message/traceback
    strings so the dump is self-contained."""
    doc: Dict[str, Any] = {
        "schema": SCHEMA,
        "events": tracer.flight_events(),
    }
    if error is not None:
        doc["error"] = {
            "type": type(error).__name__,
            "message": str(error),
            "traceback": traceback.format_exception(
                type(error), error, error.__traceback__
            ),
        }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True, default=str)
        fh.write("\n")
    return path


@contextlib.contextmanager
def flight_guard(tracer: NullTracer, path: Optional[str] = None) -> Iterator[None]:
    """Dump the flight buffer to ``path`` if the guarded block raises.

    ``path=None`` reads the tracer's ``flight_dump`` attribute; when both
    are unset (or the tracer is disabled) the guard is a no-op
    passthrough.  The exception always propagates unchanged.
    """
    target = path if path is not None else getattr(tracer, "flight_dump", None)
    if not tracer.enabled or target is None:
        yield
        return
    try:
        yield
    except BaseException as err:
        try:
            dump_flight(tracer, target, error=err)
        except OSError:
            pass  # a failing dump must never mask the original error
        raise
