"""Rendering and export: trace timelines, metric summaries, BENCH blocks.

Three consumers share this module:

* humans — :func:`render_timeline` (per-category event density over the
  simulated horizon, ASCII) and :func:`render_summary` (a metrics
  snapshot as aligned ``key = value`` lines) for quick terminal reads of
  a traced run;
* the benchmark driver — :func:`bench_block` /
  :func:`write_bench_block` wrap any benchmark payload in the uniform
  ``BENCH_*`` schema (``repro-bench/1``): flattened scalar ``metrics``,
  the ``checks`` dict, and the raw rows.  ``benchmarks/common.save``
  emits one next to every legacy artifact, so *all* registered
  benchmarks — not just the hand-rolled ones — export the same shape;
* CI — ``benchmarks/check_regression.py`` reads the shared schema for
  both its control-plane gate and the tracing-overhead gate.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, List, Optional

import numpy as np

from .metrics import MetricsRegistry
from .trace import NullTracer

__all__ = [
    "BENCH_SCHEMA",
    "bench_block",
    "flatten_scalars",
    "render_blame",
    "render_summary",
    "render_timeline",
    "write_bench_block",
]

BENCH_SCHEMA = "repro-bench/1"


# ---- metric flattening ------------------------------------------------------

def flatten_scalars(payload: Any, prefix: str = "") -> Dict[str, Any]:
    """Flatten nested dicts/lists of a benchmark payload into dotted
    scalar keys (non-scalar leaves are dropped).

    >>> flatten_scalars({"throughput": {"events_per_sec": 2500.0},
    ...                  "rows": [{"phi": 1.0}]})
    {'throughput.events_per_sec': 2500.0, 'rows.0.phi': 1.0}
    """
    out: Dict[str, Any] = {}
    if isinstance(payload, dict):
        for k in payload:
            out.update(flatten_scalars(payload[k], f"{prefix}{k}."))
    elif isinstance(payload, (list, tuple)):
        for n, v in enumerate(payload):
            out.update(flatten_scalars(v, f"{prefix}{n}."))
    elif isinstance(payload, np.generic):  # numpy ints/bools aren't int/bool
        out[prefix[:-1]] = payload.item()
    elif isinstance(payload, (int, float, str, bool)) or payload is None:
        out[prefix[:-1]] = payload
    return out


# ---- uniform benchmark block ------------------------------------------------

def bench_block(name: str, payload: Dict[str, Any]) -> Dict[str, Any]:
    """Wrap one benchmark's payload in the uniform ``repro-bench/1``
    schema: every bench exports the same top-level shape regardless of
    its internal row structure, so gates and dashboards need one parser.
    """
    return {
        "schema": BENCH_SCHEMA,
        "bench": name,
        "metrics": flatten_scalars(payload),
        "checks": payload.get("checks", {}),
        "rows": payload.get("rows", []),
    }


def write_bench_block(
    name: str, payload: Dict[str, Any], art_dir: str
) -> str:
    """Write ``BENCH_<name>.json`` under ``art_dir``; returns the path."""
    os.makedirs(art_dir, exist_ok=True)
    path = os.path.join(art_dir, f"BENCH_{name}.json")
    with open(path, "w") as fh:
        json.dump(bench_block(name, payload), fh, indent=1, default=float)
        fh.write("\n")
    return path


def load_bench_metrics(path: str) -> Dict[str, Any]:
    """Read a benchmark artifact in either format: a ``repro-bench/1``
    block (returns its ``metrics``) or a legacy raw payload (flattened on
    the fly)."""
    with open(path) as fh:
        doc = json.load(fh)
    if isinstance(doc, dict) and doc.get("schema") == BENCH_SCHEMA:
        return doc["metrics"]
    return flatten_scalars(doc)


def load_bench_rows(path: str) -> List[Dict[str, Any]]:
    """Read the row list from either artifact format."""
    with open(path) as fh:
        doc = json.load(fh)
    if isinstance(doc, dict):
        return doc.get("rows", [])
    return doc if isinstance(doc, list) else []


# ---- human rendering --------------------------------------------------------

def render_blame(
    causes: Dict[str, float],
    slowdown_s: Optional[float] = None,
    title: str = "blame",
    width: int = 32,
) -> str:
    """A blame decomposition (``repro.obs.attrib``) as an ASCII table:
    one bar per cause, seconds and share of the total, largest first.

    ``slowdown_s`` (the measured total) adds a conservation footer — the
    residual versus the attributed sum, which the attribution engine
    guarantees stays within 1e-6.

    >>> print(render_blame({"queue": 3.0, "dark_cold": 1.0},
    ...                    slowdown_s=4.0, width=8))
    == blame ==
    queue           3.000000 s  75.0% ######
    dark_cold       1.000000 s  25.0% ##
    total           4.000000 s  (residual +0.000e+00)
    """
    total = math.fsum(causes.values())
    lines = [f"== {title} =="]
    if not causes:
        return lines[0] + "\n(no causes)"
    cwidth = max(len(c) for c in causes)
    order = sorted(causes, key=lambda c: (-causes[c], c))
    denom = total if total > 0 else 1.0
    for c in order:
        v = causes[c]
        share = v / denom
        bar = "#" * max(0, int(round(share * width)))
        if v > 0 and not bar:
            bar = "#"  # a nonzero cause always shows at least one tick
        lines.append(
            f"{c:<{cwidth}} {v:>14.6f} s  {share:>5.1%} {bar}"
        )
    if slowdown_s is not None:
        resid = slowdown_s - total
        lines.append(
            f"{'total':<{cwidth}} {slowdown_s:>14.6f} s  "
            f"(residual {resid:+.3e})"
        )
    return "\n".join(lines)


def render_summary(metrics: MetricsRegistry, title: str = "metrics") -> str:
    """A metrics snapshot as aligned ``key = value`` lines."""
    snap = metrics.snapshot()
    if not snap:
        return f"{title}: (empty)"
    width = max(len(k) for k in snap)
    lines = [f"== {title} =="]
    for k, v in snap.items():
        if isinstance(v, float):
            lines.append(f"{k:<{width}} = {v:.6g}")
        else:
            lines.append(f"{k:<{width}} = {v}")
    return "\n".join(lines)


def render_timeline(
    tracer: NullTracer, width: int = 64, title: str = "trace"
) -> str:
    """Per-category event density over the traced horizon, one ASCII row
    per category (darker glyph = more events in that time bucket)."""
    events = [e for e in tracer.flight_events() or [] if "ts" in e]
    # prefer the full event list when the tracer exposes it
    full = getattr(tracer, "events", None)
    if callable(full):
        events = [e for e in full() if "ts" in e and e.get("ph") != "M"]
    if not events:
        return f"{title}: (no events)"
    t0 = min(e["ts"] for e in events)
    t1 = max(e["ts"] + e.get("dur", 0.0) for e in events)
    span = max(t1 - t0, 1e-9)
    cats: Dict[str, List[int]] = {}
    for e in events:
        row = cats.setdefault(e.get("cat", "?"), [0] * width)
        b = min(width - 1, int((e["ts"] - t0) / span * width))
        row[b] += 1
    glyphs = " .:-=+*#%@"
    peak = max(max(r) for r in cats.values()) or 1
    lines = [
        f"== {title} ==  [{t0 / 1e6:.1f}s .. {t1 / 1e6:.1f}s simulated]"
    ]
    cwidth = max(len(c) for c in cats)
    for cat in sorted(cats):
        row = "".join(
            glyphs[min(len(glyphs) - 1, (n * (len(glyphs) - 1) + peak - 1) // peak)]
            for n in cats[cat]
        )
        lines.append(f"{cat:<{cwidth}} |{row}| {sum(cats[cat])} events")
    return "\n".join(lines)
