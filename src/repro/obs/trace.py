"""Simulation-time span/event tracer with Chrome trace-event export.

The cluster's headline claims — logical-topology compatibility, dark-window
cost, polynomial-solvable TE — are all *time-series* claims, so evidence
has to be an inspectable timeline, not a scatter of ad-hoc dicts.  This
module is the recording half of the flight recorder
(:mod:`repro.obs.recorder` is the postmortem half):

* :class:`Tracer` collects **complete spans** (``ph="X"``: TE solves,
  dark windows, serving requests, job lifetimes) and **instant events**
  (``ph="i"``: faults, repairs, autoscale, policy decisions) keyed on
  *simulated* time — never wall-clock — so a seeded run exports a
  byte-identical trace every time (``tests/test_obs.py`` pins this).
* :func:`Tracer.export_json` emits Chrome trace-event JSON (the format
  Perfetto / ``chrome://tracing`` load directly): ``ts``/``dur`` in
  microseconds, one synthetic thread per event category, thread-name
  metadata records so the Perfetto track labels read ``solve``,
  ``dark_window``, ``fault``, ``policy``, ``request``, …
* :func:`validate_trace` checks an exported object against the trace-event
  schema Perfetto requires (used by the test suite and the CI obs smoke
  job, so exported artifacts are loadable by construction).
* :func:`ambient` / :func:`set_ambient` give deep library layers
  (``core/incremental.py``, ``core/reconfig.py``, ``fault/recover.py``)
  a zero-setup handle: the scheduler installs its tracer around each
  solve; un-instrumented callers see :data:`NULL` and pay one attribute
  read.

Disabled cost: every emit site guards on ``tracer.enabled`` before
building the args dict, so the hot path with tracing off pays a single
attribute load per event (``benchmarks/check_regression.py
--tracing-overhead`` gates the enabled-mode cost too).
"""
from __future__ import annotations

import collections
import json
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = [
    "NULL",
    "NullTracer",
    "Tracer",
    "ambient",
    "set_ambient",
    "validate_trace",
]

# stable synthetic-thread ids per category: the exported trace groups one
# Perfetto track per category, in this order
CATEGORY_TIDS = {
    "solve": 1,
    "dark_window": 2,
    "fault": 3,
    "policy": 4,
    "request": 5,
    "job": 6,
    "flow": 7,
    "serving": 8,
    "health": 9,
    "router": 10,  # request-routing decisions (pool/demand restatements)
}
_PID = 1  # one synthetic process: "cluster"
# export-time lane tids: category c's overflow lanes start here so they
# never collide with another category's base tid
_LANE_STRIDE = 100
_OVERLAP_EPS = 1e-6  # µs slack absorbing the 3-decimal ts/dur rounding


class NullTracer:
    """Disabled tracer: every emit is a no-op.

    ``enabled`` is False so instrumentation sites can skip building args
    dicts entirely — the pattern is::

        tr = self.trace
        if tr.enabled:
            tr.instant("fault", "pod_failure", ts=now, pod=3)
    """

    enabled = False
    sim_now = 0.0

    def span(self, cat: str, name: str, ts: float, dur: float, **args) -> None:
        pass

    def instant(self, cat: str, name: str, ts: Optional[float] = None, **args) -> None:
        pass

    def export_json(self, path: Optional[str] = None) -> str:
        return json.dumps({"traceEvents": []})

    def flight_events(self) -> List[Dict[str, Any]]:
        return []


NULL = NullTracer()


class Tracer(NullTracer):
    """Deterministic simulation-time tracer (see module docstring).

    ``flight_size`` bounds the postmortem ring buffer (the last N events
    kept for :mod:`repro.obs.recorder` dumps); ``max_events`` optionally
    caps the full event list on very long runs (drops are counted in
    ``dropped``, never silent); ``request_cap`` bounds how many serving
    *request* spans are traced per job (request volume dwarfs every other
    category; the cap is reported via ``dropped`` too).

    >>> tr = Tracer()
    >>> tr.span("solve", "mdmcf_delta", ts=1.5, dur=0.01, rewired=4)
    >>> tr.instant("fault", "pod_failure", ts=2.0, pod=3)
    >>> sorted(tr.categories())
    ['fault', 'solve']
    >>> validate_trace(json.loads(tr.export_json()))
    []
    """

    enabled = True

    def __init__(
        self,
        flight_size: int = 256,
        max_events: Optional[int] = None,
        request_cap: int = 512,
        flight_dump: Optional[str] = None,
    ):
        self._events: List[Dict[str, Any]] = []
        self._flight: Deque[Dict[str, Any]] = collections.deque(maxlen=flight_size)
        self.max_events = max_events
        self.request_cap = request_cap
        self.flight_dump = flight_dump  # recorder.flight_guard dump target
        self.dropped = 0
        self.sim_now = 0.0  # ambient clock, set by the host before solves
        self._tids = dict(CATEGORY_TIDS)

    # ---- emit --------------------------------------------------------------

    def _tid(self, cat: str) -> int:
        tid = self._tids.get(cat)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[cat] = tid
        return tid

    def _push(self, ev: Dict[str, Any]) -> None:
        self._flight.append(ev)
        if self.max_events is not None and len(self._events) >= self.max_events:
            self.dropped += 1
            return
        self._events.append(ev)

    def span(self, cat: str, name: str, ts: float, dur: float, **args) -> None:
        """A complete span (``ph="X"``) of ``dur`` simulated seconds."""
        ev: Dict[str, Any] = {
            "name": name,
            "cat": cat,
            "ph": "X",
            "ts": round(ts * 1e6, 3),
            "dur": round(max(0.0, dur) * 1e6, 3),
            "pid": _PID,
            "tid": self._tid(cat),
        }
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, cat: str, name: str, ts: Optional[float] = None, **args) -> None:
        """An instant event (``ph="i"``); ``ts=None`` reads the ambient
        simulated clock (``sim_now``), which hosts update before handing
        the tracer to deeper layers."""
        ev: Dict[str, Any] = {
            "name": name,
            "cat": cat,
            "ph": "i",
            "s": "p",  # process-scoped instant
            "ts": round((self.sim_now if ts is None else ts) * 1e6, 3),
            "pid": _PID,
            "tid": self._tid(cat),
        }
        if args:
            ev["args"] = args
        self._push(ev)

    # ---- introspection -----------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    def events(self, cat: Optional[str] = None) -> List[Dict[str, Any]]:
        if cat is None:
            return list(self._events)
        return [e for e in self._events if e.get("cat") == cat]

    def categories(self) -> set:
        return {e["cat"] for e in self._events if "cat" in e}

    def flight_events(self) -> List[Dict[str, Any]]:
        """The bounded tail kept for postmortem dumps (oldest first)."""
        return list(self._flight)

    # ---- export ------------------------------------------------------------

    def _assign_lanes(
        self, body: List[Dict[str, Any]]
    ) -> Tuple[List[Dict[str, Any]], Dict[int, str]]:
        """Spread each category's spans over overlap-free sub-tracks.

        Concurrent spans (overlapping requests, per-pair dark windows,
        parallel jobs) cannot share a Chrome trace tid unless properly
        nested — Perfetto renders partial overlap as garbage.  Walking
        the ts-sorted body, each span goes to the first lane of its
        category where it is either disjoint from every open span or
        fully nested inside the innermost one; otherwise a new lane
        opens.  Lane 0 keeps the category's base tid and bare name;
        overflow lanes get ``base·100 + k`` and ``cat/k+1``.  The walk is
        deterministic, so exports stay byte-identical across runs — and
        :func:`validate_trace(..., strict=True)` passes by construction.
        """
        lanes: Dict[str, List[List[float]]] = {}  # cat → per-lane open-end stacks
        names: Dict[int, str] = {}
        out: List[Dict[str, Any]] = []
        for ev in body:
            cat = ev.get("cat", "?")
            base = self._tid(cat)
            if ev.get("ph") != "X":
                names.setdefault(base, cat)
                out.append(ev)
                continue
            ts, end = ev["ts"], ev["ts"] + ev.get("dur", 0.0)
            stacks = lanes.setdefault(cat, [])
            lane = None
            for k, stack in enumerate(stacks):
                while stack and stack[-1] <= ts + _OVERLAP_EPS:
                    stack.pop()
                if not stack or end <= stack[-1] + _OVERLAP_EPS:
                    lane = k
                    break
            if lane is None:
                lane = len(stacks)
                stacks.append([])
            stacks[lane].append(end)
            tid = base if lane == 0 else base * _LANE_STRIDE + lane
            names.setdefault(tid, cat if lane == 0 else f"{cat}/{lane + 1}")
            if tid != ev["tid"]:
                ev = {**ev, "tid": tid}
            out.append(ev)
        return out, names

    def chrome_trace(self) -> Dict[str, Any]:
        """The trace as a Chrome trace-event object (Perfetto-loadable)."""
        # stable sort by timestamp keeps emission order within a tick —
        # deterministic given a seeded simulation
        body = sorted(self._events, key=lambda e: e["ts"])
        body, lane_names = self._assign_lanes(body)
        names = {
            tid: cat for cat, tid in self._tids.items()
        }  # categories seen only as instants still get their track named
        names.update(lane_names)
        meta: List[Dict[str, Any]] = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": _PID,
                "tid": 0,
                "args": {"name": "cluster"},
            }
        ]
        for tid in sorted(names):
            meta.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": _PID,
                    "tid": tid,
                    "args": {"name": names[tid]},
                }
            )
        return {
            "traceEvents": meta + body,
            "displayTimeUnit": "ms",
            "otherData": {"dropped": self.dropped},
        }

    def export_json(self, path: Optional[str] = None) -> str:
        """Serialize deterministically (sorted keys, fixed separators);
        write to ``path`` when given.  Same seed ⇒ byte-identical JSON."""
        text = json.dumps(
            self.chrome_trace(), sort_keys=True, separators=(",", ":")
        )
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text)
                fh.write("\n")
        return text


# ---- ambient tracer (deep-layer hook) --------------------------------------

_ambient: NullTracer = NULL


def ambient() -> NullTracer:
    """The tracer installed by the current host (``NULL`` when none)."""
    return _ambient


def set_ambient(tracer: Optional[NullTracer]) -> NullTracer:
    """Install ``tracer`` as the ambient handle; returns the previous one
    so hosts can restore it (``prev = set_ambient(tr); ...;
    set_ambient(prev)``)."""
    global _ambient
    prev = _ambient
    _ambient = NULL if tracer is None else tracer
    return prev


# ---- schema validation -----------------------------------------------------

_PHASES = {"X", "i", "M", "C"}


def validate_trace(obj: Any, strict: bool = False) -> List[str]:
    """Validate ``obj`` against the Chrome trace-event schema Perfetto's
    JSON importer requires.  Returns a list of problems (empty = valid);
    the test suite and the CI obs smoke job assert it is empty.

    ``strict=True`` additionally enforces what Perfetto needs to *render
    sanely* rather than merely load: timestamps within each ``(pid,
    tid)`` track must be non-decreasing, and ``X`` spans on one track may
    nest (containment) but never partially overlap — partial overlap
    draws as garbage.  :meth:`Tracer.chrome_trace` passes strict
    validation by construction (it lane-splits concurrent spans), so a
    strict failure means an emission bug, e.g. a ``HealthEvent`` stamped
    with a stale or wall-clock timestamp.
    """
    problems: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    last_ts: Dict[Tuple[Any, Any], float] = {}
    open_spans: Dict[Tuple[Any, Any], List[Tuple[float, int]]] = {}
    for n, ev in enumerate(events):
        where = f"traceEvents[{n}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            problems.append(f"{where}: ph {ph!r} not in {sorted(_PHASES)}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                problems.append(f"{where}: missing {key!r}")
        if not isinstance(ev.get("name", ""), str):
            problems.append(f"{where}: name must be a string")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                problems.append(f"{where}: ts must be a number")
            if "cat" in ev and not isinstance(ev["cat"], str):
                problems.append(f"{where}: cat must be a string")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event needs dur >= 0")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: args must be an object")
        if not strict or ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            continue
        track = (ev.get("pid"), ev.get("tid"))
        prev = last_ts.get(track)
        if prev is not None and ts < prev - _OVERLAP_EPS:
            problems.append(
                f"{where}: ts {ts} out of order on track pid={track[0]} "
                f"tid={track[1]} (previous ts {prev})"
            )
        last_ts[track] = max(ts, prev) if prev is not None else ts
        if ph == "X" and isinstance(ev.get("dur"), (int, float)):
            end = ts + ev["dur"]
            stack = open_spans.setdefault(track, [])
            while stack and stack[-1][0] <= ts + _OVERLAP_EPS:
                stack.pop()
            if stack and end > stack[-1][0] + _OVERLAP_EPS:
                problems.append(
                    f"{where}: X span [{ts}, {end}] partially overlaps "
                    f"open span ending at {stack[-1][0]} "
                    f"(traceEvents[{stack[-1][1]}]) on track "
                    f"pid={track[0]} tid={track[1]}"
                )
                continue
            stack.append((end, n))
    return problems
