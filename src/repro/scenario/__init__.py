"""End-to-end scenario suite: multi-day cluster life as data.

The composition layer over everything below it — declarative
:class:`ScenarioSpec`\\ s (:mod:`repro.scenario.spec`) compiled to one
deterministic event stream and run to a canonical, golden-checked
:class:`ScenarioSummary` (:mod:`repro.scenario.runner`), a catalogue of
named scenarios (:mod:`repro.scenario.catalog`), and the closed
calibration loop tying simulated compute seconds to measured
``bench_step.py`` constants (:mod:`repro.scenario.calibrate`).
"""
from .calibrate import (
    Uncalibrated,
    calibrated_profile,
    calibration_report,
    measured_archs,
    measured_step_s,
    register_calibrated,
)
from .catalog import CATALOG, SCENARIO_NAMES, get_scenario, quick_spec
from .runner import (
    CompiledScenario,
    ScenarioSummary,
    canonical_json,
    compile_scenario,
    run_scenario,
)
from .spec import FleetSpec, ScenarioSpec, load_spec, spec_from_dict

__all__ = [
    "CATALOG",
    "CompiledScenario",
    "FleetSpec",
    "SCENARIO_NAMES",
    "ScenarioSpec",
    "ScenarioSummary",
    "Uncalibrated",
    "calibrated_profile",
    "calibration_report",
    "canonical_json",
    "compile_scenario",
    "get_scenario",
    "load_spec",
    "measured_archs",
    "measured_step_s",
    "quick_spec",
    "register_calibrated",
    "run_scenario",
    "spec_from_dict",
]
