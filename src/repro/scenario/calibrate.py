"""Closed calibration loop: measured trainstep constants → sim profiles.

Everything the simulator charges for *compute* ultimately flows through
:class:`repro.dist.collectives.ModelProfile.compute_s`.  For the paper's
trace models that number is an analytic placeholder (a hand-set
"seconds per step on the reference accelerator").  This module replaces
the placeholder for every architecture the repo actually *measures*: it
derives per-architecture step times from the committed
``bench_step.py`` constants (``BENCH_step.json``, schema repro-bench/1)
and registers calibrated :class:`ModelProfile`\\ s under the registry
arch ids, so scenario jobs priced as ``model="olmo-1b"`` stretch a
*measured* compute time by the flow model's 1/φ — simulated goodput now
maps to hardware seconds.

Derivation (deterministic, pinned byte-for-byte by
``tests/test_scenario.py``):

* ``compute_s = train_ms/1e3 × active(full)/active(smoke)`` — the
  measured smoke-config step (:data:`REF_TOKENS` tokens), scaled to the
  full architecture by the active-parameter ratio (FLOPs/token ≈
  6·active params, token count held fixed).
* ``grad_bytes = 2 × total params`` (bf16 gradient).
* ``kv_bytes_per_token`` — the analytic GQA/MLA/hybrid formula
  (:func:`repro.dist.demand.kv_bytes_per_token`) on the *full* config;
  the same formula is pinned against a live
  :meth:`repro.serve.engine.ServeEngine.comm_profile` measurement on the
  smoke config for every registered architecture (satellite sweep in
  ``tests/test_serving.py``).
* MoE / PP byte fields from the config structure (dispatch payload of
  :data:`REF_TOKENS` tokens; one activation tensor per stage boundary).

Only architectures with a measured ``BENCH_step.json`` row calibrate —
:func:`measured_step_s` raises :class:`Uncalibrated` for the rest, and
the test sweep *skips visibly* rather than passing silently.

>>> round(measured_step_s("olmo-1b"), 4)  # committed BENCH_step.json
0.0144
>>> "olmo-1b" in register_calibrated()
True
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, Optional, Sequence

from ..dist import collectives as _coll
from ..dist import demand as _demand

__all__ = [
    "REF_TOKENS",
    "Uncalibrated",
    "calibrated_profile",
    "calibration_report",
    "load_measured",
    "measured_archs",
    "measured_step_s",
    "register_calibrated",
]

# bench_step.py measures B=4 × S=64 token steps on the smoke configs
REF_TOKENS = 256

# repo root (src/repro/scenario/calibrate.py → three levels up from src)
_REPO = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
)
_BENCH_PATHS = (
    os.path.join(_REPO, "BENCH_step.json"),
    os.path.join(_REPO, "artifacts", "bench", "step.json"),
)


class Uncalibrated(KeyError):
    """Architecture has no measured ``bench_step`` row — the caller must
    skip it *visibly* (``pytest.skip``), never default silently."""


def load_measured(path: Optional[str] = None) -> Dict[str, Dict[str, float]]:
    """Measured per-arch constants from a repro-bench/1 ``step`` block.

    Returns ``{arch: {"train_ms": ..., "decode_ms": ...}}`` from the
    committed ``BENCH_step.json`` (or ``path``).  Raises
    ``FileNotFoundError`` when no block exists — calibration never
    invents constants.
    """
    paths = (path,) if path is not None else _BENCH_PATHS
    for p in paths:
        if p and os.path.exists(p):
            with open(p) as fh:
                block = json.load(fh)
            rows = block.get("rows", [])
            out = {
                str(r["arch"]): {
                    "train_ms": float(r["train_ms"]),
                    "decode_ms": float(r["decode_ms"]),
                }
                for r in rows
            }
            if out:
                return out
    raise FileNotFoundError(
        f"no measured step constants found (looked in {paths}); run "
        "`python -m benchmarks.bench_step` and commit BENCH_step.json"
    )


def measured_archs(path: Optional[str] = None) -> tuple:
    """Arch ids with a measured row, sorted (the calibratable set)."""
    return tuple(sorted(load_measured(path)))


def measured_step_s(arch: str, path: Optional[str] = None) -> float:
    """Measured smoke-config train-step seconds (:data:`REF_TOKENS`
    tokens) for ``arch``; raises :class:`Uncalibrated` if unmeasured."""
    rows = load_measured(path)
    if arch not in rows:
        raise Uncalibrated(
            f"{arch!r} has no bench_step row — measured archs: "
            f"{sorted(rows)}"
        )
    return rows[arch]["train_ms"] / 1e3


def _param_scale(arch: str) -> float:
    """active(full) / active(smoke) — the FLOPs ratio at fixed tokens."""
    from ..models.registry import ARCHS, smoke_config  # lazy: pulls jax

    _, full_active = ARCHS[arch].param_counts()
    _, smoke_active = smoke_config(arch).param_counts()
    return full_active / max(1, smoke_active)


def calibrated_profile(
    arch: str, path: Optional[str] = None
) -> _coll.ModelProfile:
    """Measured-constant :class:`ModelProfile` for a registered arch."""
    from ..models.registry import ARCHS  # lazy: pulls jax

    step_s = measured_step_s(arch, path)
    cfg = ARCHS[arch]
    n_total, _ = cfg.param_counts()
    moe = cfg.moe
    moe_layers = 0
    if moe is not None:
        span = cfg.num_layers - moe.first_dense
        moe_layers = max(0, -(-span // max(1, moe.every)))
    return _coll.ModelProfile(
        grad_bytes=2.0 * n_total,
        compute_s=step_s * _param_scale(arch),
        layers=cfg.num_layers,
        moe=moe is not None,
        moe_layers=moe_layers,
        moe_tokens_bytes=(
            REF_TOKENS * cfg.d_model * 2.0 * moe.capacity_factor
            if moe is not None else 0.0
        ),
        # experts past the ~100B total-parameter mark cannot share a pod's
        # HBM: the EP all-to-all spills onto the optical core (§3.1)
        ep_spill=moe is not None and n_total > 100e9,
        pp_act_bytes=REF_TOKENS * cfg.d_model * 2.0,
        kv_bytes_per_token=_demand.kv_bytes_per_token(cfg),
    )


def register_calibrated(
    archs: Optional[Sequence[str]] = None, path: Optional[str] = None
) -> Dict[str, _coll.ModelProfile]:
    """Install calibrated profiles into ``MODEL_PROFILES`` (idempotent).

    ``archs`` defaults to every measured architecture.  Registration
    makes the arch ids valid ``Job.model`` names for both the training
    path (planner-derived comm fractions off measured ``compute_s``) and
    the serving path (``kv_bytes_per_token > 0``).  The
    ``comm_fraction_for`` cache is cleared so earlier fallback lookups
    cannot go stale.
    """
    names = tuple(archs) if archs is not None else measured_archs(path)
    out: Dict[str, _coll.ModelProfile] = {}
    changed = False
    for arch in names:
        prof = calibrated_profile(arch, path)
        if _coll.MODEL_PROFILES.get(arch) != prof:
            _coll.MODEL_PROFILES[arch] = prof
            changed = True
        out[arch] = prof
    if changed:
        _demand.comm_fraction_for.cache_clear()
    return out


def calibration_report(path: Optional[str] = None) -> Dict[str, Dict[str, float]]:
    """Flat per-arch calibration table (benchmark-artifact material).

    The ``check_regression.py --scenarios`` gate re-derives this from the
    current ``BENCH_step.json`` and asserts the recorded
    ``BENCH_scenarios.json`` copy drifted by at most the documented
    tolerance — a re-bench on different hardware that moves step times
    must ship regenerated scenario goldens with it.
    """
    out: Dict[str, Dict[str, float]] = {}
    for arch, prof in register_calibrated(path=path).items():
        step = measured_step_s(arch, path)
        out[arch] = {
            "measured_step_ms": step * 1e3,
            "compute_s": prof.compute_s,
            "grad_bytes": prof.grad_bytes,
            "kv_bytes_per_token": prof.kv_bytes_per_token,
            "scale": prof.compute_s / step if step > 0 else math.nan,
        }
    return out
