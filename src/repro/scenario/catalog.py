"""The catalogued scenarios: named multi-day cluster-life compositions.

Each entry is a frozen :class:`~repro.scenario.spec.ScenarioSpec` with a
committed golden summary under ``tests/golden/scenarios/<name>.json``
(regenerate with ``PYTHONPATH=src python -m tests.golden.regen``) and a
YAML twin under ``examples/scenarios/`` (pinned equal in tests — the
YAML front door can never drift from the catalogue).

* ``steady_week`` — seven quiet days: Poisson training arrivals over two
  diurnal serving regions, no faults.  The baseline every other scenario
  is read against.
* ``diurnal_burst`` — three regions whose load peaks sweep around the
  clock (phases 0/8/16 h) with scripted autoscaling, hit by a correlated
  top-of-pod OCS burst at the second day's peak.
* ``expansion_under_load`` — the cluster starts at P−3 pods under a
  heavy training load; the missing pods go live mid-run (the paper's
  incremental-expansion regime) while one flat fleet keeps serving.
* ``burst_flap_remediated`` — the compound chaos regime (burst + gray
  flapping links) with the closed loop on: remediation engine,
  topology-aware routing, checkpoint-restart recovery under a tight
  checkpoint interval, and a 5 s reconfiguration delay so dark windows
  are visible in every metric.
* ``static_calib`` — serialized (contention-free) training jobs priced
  by the *calibrated* measured-constant profiles, no faults, no serving:
  the scenario where ``engine="analytic"`` and ``engine="fluid"`` must
  agree to 1e-6, and where simulated seconds tie directly back to
  ``bench_step.py`` wall-clock.

>>> sorted(CATALOG) == sorted(SCENARIO_NAMES)
True
>>> get_scenario("steady_week").days
7.0
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from ..fault.chaos import ChaosScenario
from .spec import FleetSpec, ScenarioSpec

__all__ = ["CATALOG", "SCENARIO_NAMES", "get_scenario", "quick_spec"]

# calibrated archetypes: the architectures bench_step.py measures (dense,
# MoE/MLA, linear-attention RNN, encoder–decoder audio)
_CALIBRATED = ("olmo-1b", "deepseek-v3-671b", "rwkv6-1.6b", "whisper-small")

_DAY = 86400.0


def _build() -> Dict[str, ScenarioSpec]:
    steady_week = ScenarioSpec(
        name="steady_week", days=7.0, seed=11,
        num_train_jobs=16, workload_level=0.6,
        fleets=(
            FleetSpec(model="llama2-13b", req_rate=0.02, diurnal=0.4,
                      phase_offset_s=0.0),
            FleetSpec(model="mixtral-8x7b", req_rate=0.02, diurnal=0.4,
                      kv_tokens=4096, phase_offset_s=0.5 * _DAY),
        ),
    )
    diurnal_burst = ScenarioSpec(
        name="diurnal_burst", days=2.0, seed=5,
        num_train_jobs=12, workload_level=0.5,
        fleets=tuple(
            FleetSpec(model="llama2-13b", req_rate=0.04, diurnal=0.6,
                      phase_offset_s=n * _DAY / 3.0, autoscale_pods=1)
            for n in range(3)
        ),
        chaos=ChaosScenario(
            name="peak_burst", horizon_s=2.0 * _DAY,
            burst_at_s=1.25 * _DAY, burst_size=2,
            burst_repair_s=7200.0,
        ),
        reconfig_delay_s=1.0,
    )
    expansion_under_load = ScenarioSpec(
        name="expansion_under_load", days=2.0, seed=3,
        num_train_jobs=18, workload_level=0.85,
        expand_pods=3, expand_at_s=1.0 * _DAY,
        fleets=(FleetSpec(model="llama2-13b", req_rate=0.03),),
    )
    flap = ((0, 1, 1), (0, 3, 2), (1, 2, 5))
    burst_flap_remediated = ScenarioSpec(
        name="burst_flap_remediated", days=1.0, seed=7,
        num_train_jobs=12, workload_level=0.9,
        fleets=(
            FleetSpec(model="llama2-13b", req_rate=0.05, diurnal=0.3),
        ),
        chaos=ChaosScenario(
            name="burst_flap", horizon_s=_DAY,
            burst_at_s=0.25 * _DAY, burst_size=2,
            burst_repair_s=0.15 * _DAY,
            flap_links=flap, flap_from_s=(1.0 / 3.0) * _DAY,
            flap_until_s=0.75 * _DAY, flap_period_s=3600.0,
        ),
        remediation=True, router="topology_aware",
        recovery_policy="ckpt_restart", ckpt_interval_s=900.0,
        reconfig_delay_s=5.0, serving_slo=2.0,
    )
    static_calib = ScenarioSpec(
        name="static_calib", days=4.0, seed=2, engine="analytic",
        num_train_jobs=6, workload_level=0.3,
        train_models=_CALIBRATED, spacing="serial",
        reconfig_delay_s=0.0,
    )
    out = (steady_week, diurnal_burst, expansion_under_load,
           burst_flap_remediated, static_calib)
    return {s.name: s for s in out}


CATALOG: Dict[str, ScenarioSpec] = _build()
SCENARIO_NAMES: Tuple[str, ...] = tuple(CATALOG)


def get_scenario(name: str) -> ScenarioSpec:
    """Catalogue lookup with the valid names in the error message."""
    try:
        return CATALOG[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; catalogued: {SCENARIO_NAMES}"
        ) from None


def quick_spec(spec: ScenarioSpec) -> ScenarioSpec:
    """Reduced-scale twin for CI smoke runs: same composition (chaos,
    expansion, routing, remediation all preserved), shorter horizon and
    lighter request load — minutes of simulated cluster life, not days.
    Chaos timing scales with the horizon so every burst/flap still
    lands inside the run."""
    scale = min(1.0, 0.25 / spec.days)
    chaos = spec.chaos
    if chaos is not None and scale < 1.0:
        chaos = dataclasses.replace(
            chaos,
            horizon_s=chaos.horizon_s * scale,
            burst_at_s=(
                None if chaos.burst_at_s is None
                else chaos.burst_at_s * scale
            ),
            burst_repair_s=chaos.burst_repair_s * scale,
            srlg_at_s=(
                None if chaos.srlg_at_s is None else chaos.srlg_at_s * scale
            ),
            flap_from_s=chaos.flap_from_s * scale,
            flap_until_s=(
                None if chaos.flap_until_s is None
                else chaos.flap_until_s * scale
            ),
        )
    return dataclasses.replace(
        spec,
        days=spec.days * scale,
        num_train_jobs=min(spec.num_train_jobs, 8),
        chaos=chaos,
        expand_at_s=(
            None if spec.expand_at_s is None else spec.expand_at_s * scale
        ),
        fleets=tuple(
            dataclasses.replace(
                f, req_rate=min(f.req_rate, 0.05),
                phase_offset_s=f.phase_offset_s * scale,
            )
            for f in spec.fleets
        ),
    )
