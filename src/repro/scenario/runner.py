"""Scenario compiler + harness: spec → event stream → canonical summary.

:func:`compile_scenario` lowers a :class:`~repro.scenario.spec.
ScenarioSpec` into the three things the simulator consumes — a
:class:`~repro.sim.scheduler.SimConfig`, a job list (training trace +
serving fleets, ids positional), and ONE time-sorted fault-event stream
(chaos + expansion + autoscale, merged) — deterministically: same spec ⇒
identical jobs and events, byte for byte.

:func:`run_scenario` runs the compiled scenario and folds the run into a
:class:`ScenarioSummary`: per-job JCTs, training JCT statistics, goodput
/ availability (:meth:`~repro.sim.scheduler.Simulator.fault_summary`),
serving SLO availability and p50/p99 TTFT, dark circuit-seconds, the
full per-cause blame split with its conservation residual
(:mod:`repro.obs.attrib`), and the action ledger (remediation counts,
autoscale applied/skipped, control-plane call counts).

:func:`canonical_json` renders a summary to the byte-stable form the
golden files under ``tests/golden/scenarios/`` freeze: keys sorted,
floats at 10 significant digits, non-finite values spelled ``"inf"`` /
``"nan"`` (JSON has neither).

>>> canonical_json({"b": 1 / 3, "a": float("inf")})
'{\\n "a": "inf",\\n "b": 0.3333333333\\n}'
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, List, Optional, Tuple

from ..fault.model import ExpandEvent, merge_events
from ..fault.chaos import scenario_events
from ..fault.remediate import RemediationEngine
from ..obs.attrib import CAUSES, attribute_jobs, attribute_requests
from ..sim.scheduler import SimConfig, Simulator, summarize
from ..sim.serving import autoscale_events, serving_job
from ..sim.trace import generate_trace
from . import calibrate
from .spec import ScenarioSpec

__all__ = [
    "CompiledScenario",
    "ScenarioSummary",
    "canonical_json",
    "compile_scenario",
    "run_scenario",
]


@dataclasses.dataclass
class CompiledScenario:
    """The simulator-ready lowering of one spec."""

    spec: ScenarioSpec
    cfg: SimConfig
    jobs: List[Any]
    events: List[Any]
    remediation: Optional[RemediationEngine]


def _train_jobs(spec: ScenarioSpec) -> List[Any]:
    gpus = spec.num_pods * spec.k_spine * spec.k_leaf
    jobs = generate_trace(
        spec.num_train_jobs, num_gpus=gpus,
        workload_level=spec.workload_level, seed=spec.seed,
        max_job_gpus=max(spec.k_spine * spec.k_leaf,
                         int(gpus * spec.max_gpu_frac)),
    )
    if spec.train_models:
        # price trace jobs with calibrated measured-constant profiles:
        # round-robin over the requested archs, parallelism reset to what
        # the calibrated profile implies (EP only for MoE archs)
        profs = calibrate.register_calibrated(spec.train_models)
        jobs = [
            dataclasses.replace(
                j, model=arch, ep=2 if profs[arch].moe else 1, pp=1
            )
            for j, arch in zip(
                jobs,
                (spec.train_models[n % len(spec.train_models)]
                 for n in range(len(jobs))),
            )
        ]
    if spec.spacing == "serial":
        # contention-free respacing: slowdown is capped at 4×, so gaps of
        # 4·service + 60 s guarantee one job in flight at a time — the
        # static regime where both progress engines agree to 1e-6
        t, out = 0.0, []
        for j in jobs:
            out.append(dataclasses.replace(j, arrival=t))
            t += 4.0 * j.service_time + 60.0
        jobs = out
    return jobs


def compile_scenario(spec: ScenarioSpec) -> CompiledScenario:
    """Deterministically lower ``spec`` (same spec ⇒ identical output)."""
    jobs = _train_jobs(spec)
    horizon = spec.horizon_s

    streams: List[List[Any]] = []
    for fs in spec.fleets:
        if fs.model in calibrate.measured_archs():
            calibrate.register_calibrated((fs.model,))
        fleet = serving_job(
            len(jobs), fs.num_gpus, arrival=fs.phase_offset_s,
            model=fs.model, req_rate=fs.req_rate, kv_tokens=fs.kv_tokens,
            diurnal=fs.diurnal,
        )
        jobs.append(fleet)
        if fs.autoscale_pods > 0:
            streams.append(autoscale_events(
                fleet, horizon - fs.phase_offset_s,
                period_s=spec.serving_period_s, pods=fs.autoscale_pods,
                cycles=fs.autoscale_cycles,
            ))
    if spec.chaos is not None:
        streams.append(scenario_events(spec.chaos, spec.k_spine))
    active = None
    if spec.expand_pods:
        active = spec.num_pods - spec.expand_pods
        t_exp = (
            spec.expand_at_s if spec.expand_at_s is not None
            else 0.5 * horizon
        )
        streams.append([ExpandEvent(
            t_exp, tuple(range(active, spec.num_pods))
        )])

    eng = RemediationEngine() if spec.remediation else None
    cfg = SimConfig(
        architecture=spec.architecture, strategy=spec.strategy,
        num_pods=spec.num_pods, k_spine=spec.k_spine, k_leaf=spec.k_leaf,
        sim_groups=spec.sim_groups, engine=spec.engine,
        incremental=spec.incremental,
        reconfig_delay_s=spec.reconfig_delay_s,
        recovery_policy=spec.recovery_policy,
        ckpt_interval_s=spec.ckpt_interval_s,
        active_pods=active, router=spec.router,
        serving_slo=spec.serving_slo,
        serving_period_s=spec.serving_period_s,
        on_health=eng,
    )
    return CompiledScenario(spec, cfg, jobs, merge_events(*streams), eng)


@dataclasses.dataclass
class ScenarioSummary:
    """Canonical outcome of one scenario run (the golden payload)."""

    name: str
    table: Dict[str, Any]

    def to_json(self) -> str:
        return canonical_json({"name": self.name, **self.table})


def run_scenario(
    spec: ScenarioSpec, tracer: Optional[Any] = None, seed: int = 0
) -> Tuple[ScenarioSummary, Simulator]:
    """Compile and run ``spec``; return (summary, finished simulator).

    ``tracer`` (a :class:`repro.obs.Tracer`) attaches the flight
    recorder; tracing is passive, so the summary must be byte-identical
    with it on or off (property-tested per catalogued scenario).
    """
    comp = compile_scenario(spec)
    cfg = (
        dataclasses.replace(comp.cfg, tracer=tracer)
        if tracer is not None else comp.cfg
    )
    sim = Simulator(cfg, comp.jobs, seed=seed, fault_events=comp.events)
    records = sim.run(until=spec.horizon_s)

    train = [r for r in records if r.job.kind != "serve"]
    done = [r for r in train if math.isfinite(r.finish)]
    jct = {
        str(r.job.job_id): (r.jct if math.isfinite(r.finish) else None)
        for r in train
    }
    fault = sim.fault_summary()
    serving = sim.serving_summary() if spec.fleets else None

    req = attribute_requests(sim)
    blames = attribute_jobs(sim)
    job_totals = {c: 0.0 for c in CAUSES}
    job_residual = 0.0
    for b in blames.values():
        job_residual = max(job_residual, abs(b.residual))
        for c, v in b.causes.items():
            if c in job_totals:
                job_totals[c] += v

    ledger: Dict[str, float] = {
        "reconfig_calls": float(sim.reconfig_calls),
        "delta_calls": float(sim.delta_calls),
        "solver_fallbacks": float(sim.solver_fallbacks),
        "autoscale_applied": float(sim.autoscale_applied),
        "autoscale_skipped": float(sim.autoscale_skipped),
        "restarts": fault["restarts"],
        "shrinks": fault["shrinks"],
    }
    if comp.remediation is not None:
        for k, v in comp.remediation.summary().items():
            ledger[f"remedy_{k}"] = float(v)

    table: Dict[str, Any] = {
        "spec": spec.to_dict(),
        "train": {**summarize(train), "jct": jct, "submitted": len(train),
                  "finished": len(done)},
        "goodput": fault["goodput"],
        "availability": fault["availability"],
        "lost_gpu_s": fault["lost_gpu_s"],
        "dark": {
            "events": float(sim.downtime_events),
            "window_s": sim.downtime_s,
            "circuit_s": sim.downtime_circuit_s,
        },
        "blame": {
            "requests": req["totals"],
            "jobs": job_totals,
            "max_residual": max(req["max_residual"], job_residual),
            "conserved": bool(req["conserved"]) and job_residual <= 1e-6,
        },
        "actions": ledger,
    }
    if serving is not None:
        table["serving"] = {
            "requests": serving["requests"],
            "p50_ttft_s": serving["p50_s"],
            "p99_ttft_s": serving["p99_s"],
            "goodput": serving["goodput"],
            "slo_availability": serving["availability"],
        }
    return ScenarioSummary(spec.name, table), sim


# ---------------------------------------------------------------------------
# canonical JSON (golden byte-stability)
# ---------------------------------------------------------------------------

def _canon(v: Any) -> Any:
    if isinstance(v, dict):
        return {str(k): _canon(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_canon(x) for x in v]
    if isinstance(v, bool) or v is None or isinstance(v, (int, str)):
        return v
    if isinstance(v, float):
        if math.isnan(v):
            return "nan"
        if math.isinf(v):
            return "inf" if v > 0 else "-inf"
        if v == int(v) and abs(v) < 1e15:
            return int(v)
        # 10 significant digits: stable across runs, diffs stay readable
        return float(f"{v:.10g}")
    raise TypeError(f"non-canonical value {v!r} in scenario summary")


def canonical_json(table: Dict[str, Any]) -> str:
    """Byte-stable JSON for golden summaries (sorted keys, 10-sig-digit
    floats, ``"inf"``/``"nan"`` strings for non-finite values)."""
    return json.dumps(_canon(table), indent=1, sort_keys=True)
