"""Declarative scenario specification (frozen, YAML-loadable).

A :class:`ScenarioSpec` names everything a multi-day cluster-life run
composes — cluster shape, fabric/strategy/engine, a mixed train+serve
trace with regional diurnal phases, correlated chaos from the
:mod:`repro.fault.chaos` catalogue, live P−k→P expansion, fleet
autoscaling, request-router policy, remediation on/off, and
checkpoint-restart pressure — as *data*.  The compiler
(:func:`repro.scenario.runner.compile_scenario`) turns a spec into one
deterministic event stream; same spec + seed ⇒ byte-identical
:class:`~repro.scenario.runner.ScenarioSummary` (property-tested).

Specs are frozen dataclasses so they can live in the catalogue and in
YAML files under ``examples/scenarios/`` interchangeably:
:func:`load_spec` reads the YAML form, :meth:`ScenarioSpec.to_dict` /
:func:`spec_from_dict` round-trip it.

>>> s = ScenarioSpec(name="tiny", days=0.5)
>>> spec_from_dict(s.to_dict()) == s
True
>>> s.horizon_s
43200.0
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from ..fault.chaos import ChaosScenario

__all__ = ["FleetSpec", "ScenarioSpec", "load_spec", "spec_from_dict"]


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """One serving fleet: a region's diurnal request population.

    ``phase_offset_s`` is both the fleet's arrival time and its diurnal
    phase origin (the load sine starts at the arrival), so fleets with
    offsets 0 / 8h / 16h model three regions whose peaks sweep around the
    clock.  ``autoscale_pods > 0`` scripts the diurnal scale-up/down
    schedule of :func:`repro.sim.serving.autoscale_events`.
    """

    model: str = "llama2-13b"
    num_gpus: int = 128
    req_rate: float = 0.05
    kv_tokens: int = 2048
    diurnal: float = 0.0
    phase_offset_s: float = 0.0
    autoscale_pods: int = 0
    autoscale_cycles: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Everything one multi-day cluster-life scenario composes."""

    name: str
    days: float = 2.0
    seed: int = 0

    # ---- cluster + control plane ----------------------------------------
    num_pods: int = 12
    k_spine: int = 8
    k_leaf: int = 8
    sim_groups: int = 2
    architecture: str = "cross_wiring"
    strategy: str = "mdmcf"
    engine: str = "fluid"
    incremental: bool = True
    reconfig_delay_s: float = 0.01

    # ---- training trace -------------------------------------------------
    num_train_jobs: int = 16
    workload_level: float = 0.6
    max_gpu_frac: float = 0.25  # per-job cap as a share of the cluster
    # round-robin remap of trace-job models onto calibrated registry archs
    # (() = keep the paper's trace models); "serial" spacing respaces
    # arrivals so no two training jobs ever overlap (the contention-free
    # regime where analytic and fluid engines agree to 1e-6)
    train_models: Tuple[str, ...] = ()
    spacing: str = "poisson"  # poisson | serial

    # ---- serving fleets -------------------------------------------------
    fleets: Tuple[FleetSpec, ...] = ()
    serving_slo: float = 4.0
    serving_period_s: float = 86400.0
    router: Optional[str] = None

    # ---- faults / expansion / remediation -------------------------------
    chaos: Optional[ChaosScenario] = None
    expand_pods: int = 0  # start at P − expand_pods, grow back at…
    expand_at_s: Optional[float] = None  # …this time (default: mid-run)
    remediation: bool = False
    recovery_policy: str = "rewire_around"
    ckpt_interval_s: float = 1800.0

    @property
    def horizon_s(self) -> float:
        return self.days * 86400.0

    def __post_init__(self) -> None:
        if self.days <= 0:
            raise ValueError("days must be positive")
        if self.spacing not in ("poisson", "serial"):
            raise ValueError("spacing must be 'poisson' or 'serial'")
        if not 0 <= self.expand_pods < self.num_pods:
            raise ValueError("expand_pods must be in [0, num_pods)")

    # ---- dict / YAML round-trip -----------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """Plain-dict form (nested dataclasses → dicts), YAML-safe."""
        d = dataclasses.asdict(self)
        d["fleets"] = [dataclasses.asdict(f) for f in self.fleets]
        d["train_models"] = list(self.train_models)
        if self.chaos is not None:
            d["chaos"] = dataclasses.asdict(self.chaos)
        return d


def spec_from_dict(d: Dict[str, Any]) -> ScenarioSpec:
    """Inverse of :meth:`ScenarioSpec.to_dict` (YAML loader backend)."""
    kw = dict(d)
    kw["fleets"] = tuple(
        f if isinstance(f, FleetSpec) else FleetSpec(**f)
        for f in kw.get("fleets", ())
    )
    kw["train_models"] = tuple(kw.get("train_models", ()))
    chaos = kw.get("chaos")
    if chaos is not None and not isinstance(chaos, ChaosScenario):
        links = ("srlg_links", "flap_links", "derate_links")
        chaos = ChaosScenario(**{
            k: tuple(tuple(x) for x in (v or ())) if k in links else v
            for k, v in chaos.items()
        })
    kw["chaos"] = chaos
    return ScenarioSpec(**kw)


def load_spec(path: str) -> ScenarioSpec:
    """Load a :class:`ScenarioSpec` from a YAML file.

    Requires PyYAML (available in the dev environment); the catalogue in
    :mod:`repro.scenario.catalog` never goes through YAML, so the core
    path has no third-party dependency.
    """
    import yaml  # local: optional dependency, only the YAML front door

    with open(path) as fh:
        return spec_from_dict(yaml.safe_load(fh))
