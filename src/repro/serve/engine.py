"""Batched serving engine: prefill + greedy KV-cache decode.

Mirrors a production continuous-batching server in miniature: fixed batch
slots, one jitted prefill and one jitted decode step (both shardable with the
same specs the dry-run uses).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ServeEngine:
    def __init__(self, api, params, batch: int, s_max: int, mesh=None):
        self.api = api
        self.params = params
        self.batch = batch
        self.s_max = s_max
        self.mesh = mesh
        self._prefill = jax.jit(api.prefill)
        self._decode = jax.jit(api.decode)

    def generate(
        self, batch_inputs: Dict[str, np.ndarray], max_new_tokens: int
    ) -> np.ndarray:
        """Greedy generation.  batch_inputs must contain "tokens" (B, S0) and
        any modality extras the arch needs (frames/patches)."""
        B, S0 = batch_inputs["tokens"].shape
        cache = self.api.init_cache(B, self.s_max)
        batch_inputs = {k: jnp.asarray(v) for k, v in batch_inputs.items()}
        logits, cache = self._prefill(self.params, batch_inputs, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out = [tok]
        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, tok[:, None], cache)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)
