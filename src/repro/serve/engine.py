"""Batched serving engine: prefill + greedy KV-cache decode.

Mirrors a production continuous-batching server in miniature: fixed batch
slots, one jitted prefill and one jitted decode step (both shardable with the
same specs the dry-run uses).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ServeEngine:
    """Continuous-batching inference engine over one ModelAPI.

    ``generate`` runs greedy decoding against the jitted prefill/decode
    steps; ``comm_profile`` exports the engine's measured communication
    footprint, which calibrates the cluster simulator's serving archetype
    (:mod:`repro.sim.serving` — per-request KV bytes moved from prefill
    to decode pods in a disaggregated deployment).
    """

    def __init__(self, api, params, batch: int, s_max: int, mesh=None):
        self.api = api
        self.params = params
        self.batch = batch
        self.s_max = s_max
        self.mesh = mesh
        self._prefill = jax.jit(api.prefill)
        self._decode = jax.jit(api.decode)

    def comm_profile(self) -> Dict[str, float]:
        """Measured per-request communication profile of this engine.

        ``kv_bytes_per_token`` is derived from the *real* cache pytree —
        the byte growth of ``api.init_cache`` per context slot — so it is
        exact for every architecture family (GQA, MLA latents, hybrid
        patterns whose mamba/rwkv state does not grow with context), not
        a formula restated.  The analytic twin is
        :func:`repro.dist.demand.kv_bytes_per_token`;
        ``tests/test_serving.py`` pins the two against each other.  The
        simulator sizes prefill→decode KV migration flows
        (:func:`repro.dist.demand.kv_flow`) from this number.
        """
        def nbytes(s_max: int) -> int:
            cache = self.api.init_cache(1, s_max)
            return int(
                sum(x.nbytes for x in jax.tree_util.tree_leaves(cache))
            )
        s0, s1 = 8, 16
        per_token = (nbytes(s1) - nbytes(s0)) / (s1 - s0)
        cfg = self.api.cfg
        return {
            "kv_bytes_per_token": float(per_token),
            "fixed_state_bytes": float(nbytes(s0) - per_token * s0),
            "dtype_bytes": float(jnp.dtype(cfg.compute_dtype).itemsize),
            "num_layers": float(cfg.num_layers),
            "batch_slots": float(self.batch),
        }

    def generate(
        self, batch_inputs: Dict[str, np.ndarray], max_new_tokens: int
    ) -> np.ndarray:
        """Greedy generation.  batch_inputs must contain "tokens" (B, S0) and
        any modality extras the arch needs (frames/patches)."""
        B, S0 = batch_inputs["tokens"].shape
        cache = self.api.init_cache(B, self.s_max)
        batch_inputs = {k: jnp.asarray(v) for k, v in batch_inputs.items()}
        logits, cache = self._prefill(self.params, batch_inputs, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out = [tok]
        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, tok[:, None], cache)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)
