"""Request routing between ``serving_trace`` arrivals and decode pools.

The scheduler's disaggregated serving fleets (``Job.kind == "serve"``,
PR 5) place requests by a *static* pool split: one pooled arrival stream,
one fleet-level φ timeline, no per-request decisions.  This module adds
the control plane the ROADMAP's "millions of users" half calls for — a
router in the style of vLLM production-stack's ``routing_logic.py``
(round-robin / session / prefix-aware / overload detection), extended
with a policy that *sees the optical fabric*:

``random``
    uniform choice over the live decode pool — the baseline every other
    policy must beat.
``round_robin``
    cycle through the live pool in pod-id order.
``session_affinity``
    sessions pin to a decode pod by rendezvous (highest-random-weight)
    hashing: a repeat request finds its KV prefix resident (*hit*) and
    skips the prefill→decode KV stream entirely; pool membership changes
    move only the sessions of departed pods.
``kv_aware``
    session affinity plus overload detection: within each
    ``overload_window_s`` window, pods drawing more than
    ``overload_factor ×`` their fair share of requests spill the excess
    to their rendezvous runner-up — trading prefix-cache hits (the
    spilled requests re-stream their KV) for tail latency.
``topology_aware``
    session affinity scored by φ headroom: the rendezvous weight of pod
    ``p`` for a request at ``t`` is ``φ_p(t)^headroom_gamma``, where
    ``φ_p`` is the per-pod realized-bandwidth timeline the scheduler
    records for the fleet's prefill→p KV circuits.  Pods behind dark
    windows (φ = 0) or :class:`~repro.fault.masks.PortMask` cordons are
    *hard-excluded* while any healthy alternative exists, so load sheds
    away from retuning or quarantined circuits (the remediation engine's
    drain signals remove pods from the pool outright, via the
    scheduler's pool log).

Cache-hit-rate vs transfer-bytes is an explicit tradeoff: a *hit* costs
only the circuit latency ``alpha_s``; a *miss* pays the full ``kv_flow``
transfer under the pod's φ timeline.  ``random`` / ``round_robin`` never
pin sessions, so they never hit — exactly the legacy pooled behaviour,
which keeps the scheduler-level differential (`random` on a one-pod
fleet reproduces the unrouted numbers bit-for-bit).

Routing is *replayed* after the run, like the request streams
themselves: the scheduler records what the router needs (decode-pool
membership history, per-pod φ timelines, per-pod cordon counts) and
:meth:`Router.replay` deterministically re-derives every per-request
decision — requests never enter the event heap, so the simulator stays
O(events), not O(requests).

>>> import numpy as np
>>> r = Router("round_robin", seed=1)
>>> res = r.replay(np.array([0.5, 1.0, 1.5, 2.0]), [(0.0, (3, 4))], {})
>>> res.pods.tolist()
[3, 4, 3, 4]
>>> r = Router("topology_aware", seed=1)
>>> tls = {3: [(0.0, 0.0)], 4: [(0.0, 1.0)]}   # pod 3 dark throughout
>>> res = r.replay(np.array([0.5, 1.0, 1.5, 2.0]), [(0.0, (3, 4))], tls)
>>> res.pods.tolist(), int(res.stats["sheds"]) > 0
([4, 4, 4, 4], True)
>>> int(res.stats["hits"]) + int(res.stats["misses"]) == 4
True
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "AFFINITY_POLICIES",
    "POLICIES",
    "RouteResult",
    "Router",
    "partition_edges",
]

POLICIES = (
    "random", "round_robin", "session_affinity", "kv_aware",
    "topology_aware",
)
# policies that pin sessions to pods (and therefore can *hit* the
# decode-side prefix cache); random / round_robin stay stateless
AFFINITY_POLICIES = frozenset(
    {"session_affinity", "kv_aware", "topology_aware"}
)

_MIX1 = np.uint64(0x9E3779B97F4A7C15)
_MIX2 = np.uint64(0xBF58476D1CE4E5B9)
_MIX3 = np.uint64(0xFF51AFD7ED558CCD)
_MIX4 = np.uint64(0xC4CEB9FE1A85EC53)
_S33 = np.uint64(33)


def _hash01(sid: np.ndarray, pod: int, salt: int) -> np.ndarray:
    """Per-(session, pod) uniforms in (0, 1] — splitmix64-style mixing,
    so rendezvous choices are deterministic, stable across runs, and
    independent of pool iteration order."""
    with np.errstate(over="ignore"):
        x = sid.astype(np.uint64) * _MIX1
        x ^= np.uint64((pod + 1) * 0x9E3779B9) * _MIX2
        x ^= np.uint64(salt & 0xFFFFFFFF) * _MIX4
        x ^= x >> _S33
        x *= _MIX3
        x ^= x >> _S33
        x *= _MIX4
        x ^= x >> _S33
    # top 53 bits → (0, 1]: never exactly 0, so -log(u) stays finite
    return ((x >> np.uint64(11)).astype(np.float64) + 1.0) * (2.0 ** -53)


def _step_at(
    timeline: Sequence[Tuple[float, float]],
    query: np.ndarray,
    default: float,
) -> np.ndarray:
    """Piecewise-constant lookup: value holding at each query time
    (``default`` before the first breakpoint / for an empty timeline)."""
    if not len(timeline):
        return np.full(query.shape, default, dtype=np.float64)
    ts = np.asarray([t for t, _ in timeline], dtype=np.float64)
    vs = np.asarray([v for _, v in timeline], dtype=np.float64)
    idx = np.searchsorted(ts, query, side="right") - 1
    out = np.full(query.shape, default, dtype=np.float64)
    ok = idx >= 0
    out[ok] = vs[idx[ok]]
    return out


def partition_edges(
    edges: Dict[Tuple[int, int], int], decode_pods: Iterable[int]
) -> Dict[int, Dict[Tuple[int, int], int]]:
    """Split a serving fleet's KV edge demand by owning decode pod.

    Each prefill→decode edge belongs to its decode endpoint; a
    decode↔decode edge (the MoE EP-spill clique) is charged to the lower
    pod id, and an edge touching no decode pod falls to the lowest pod so
    no demand is ever dropped from the flow model.  The scheduler turns
    each part into its own :class:`~repro.sim.flowsim.JobFlows`, giving
    every decode pod a φ timeline of its own — the signal
    ``topology_aware`` routing scores by.

    >>> parts = partition_edges({(0, 2): 4, (0, 3): 4, (2, 3): 1}, [2, 3])
    >>> sorted((p, sorted(e)) for p, e in parts.items())
    [(2, [(0, 2), (2, 3)]), (3, [(0, 3)])]
    """
    dec = sorted(set(decode_pods))
    dset = set(dec)
    parts: Dict[int, Dict[Tuple[int, int], int]] = {}
    for e, w in edges.items():
        a, b = e
        if a in dset and b in dset:
            pod = min(a, b)
        elif a in dset:
            pod = a
        elif b in dset:
            pod = b
        else:
            pod = dec[0]
        parts.setdefault(pod, {})[e] = w
    return parts


@dataclasses.dataclass
class RouteResult:
    """Outcome of one :meth:`Router.replay` pass.

    ``pods[i]`` is request *i*'s decode pod (−1 = no decode pool at that
    time: single-pod fleet or a fleet that died — the caller prices such
    requests against the fleet-level φ timeline), ``hits[i]`` whether its
    KV prefix was already resident (the request skips the KV stream).
    ``stats`` carries the ``routing.*`` counter values."""

    pods: np.ndarray
    hits: np.ndarray
    stats: Dict[str, float]


class Router:
    """Deterministic request router for one serving fleet (see module
    docstring for the policy axis).  ``seed`` may be anything
    ``np.random.default_rng`` accepts — the scheduler passes
    ``(sim_seed, job_id)`` so fleets draw independent session streams.
    """

    def __init__(
        self,
        policy: str,
        seed=0,
        session_mean: float = 8.0,
        working_set: int = 64,
        overload_window_s: float = 60.0,
        overload_factor: float = 2.0,
        phi_floor: float = 0.25,
        headroom_gamma: float = 2.0,
    ):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")
        if session_mean < 1.0:
            raise ValueError("session_mean must be >= 1 request/session")
        self.policy = policy
        self.seed = seed
        self.session_mean = float(session_mean)
        self.working_set = int(working_set)
        self.overload_window_s = float(overload_window_s)
        self.overload_factor = float(overload_factor)
        self.phi_floor = float(phi_floor)
        self.headroom_gamma = float(headroom_gamma)
        # stable per-router salt for the rendezvous hash (NOT drawn from
        # the replay rng: replay must be pure / repeatable per call)
        self._salt = int(
            np.random.default_rng(seed).integers(0, 2**31 - 1)
        )

    # ---- event-time hook (scheduler demand shaping) ----------------------

    def demand_weights(
        self,
        decode_pods: Sequence[int],
        phi_by_pod: Dict[int, float],
        cordoned_by_pod: Dict[int, int],
    ) -> Optional[Dict[int, float]]:
        """Per-decode-pod KV-circuit weights for the next demand
        restatement — the router-shaped ``kv_flow``.

        Only ``topology_aware`` shapes demand (its replay *sends* load
        where φ has headroom, so TE should provision circuits there);
        every other policy returns None and the legacy even spread is
        byte-identical.  Weights are floored at 0.1 for non-cordoned
        pods — φ dips are transient, and a starved pair could never
        recover (demand restatements happen at event cadence, not per
        request).

        >>> r = Router("topology_aware")
        >>> w = r.demand_weights([2, 3], {2: 1.0, 3: 0.25}, {3: 1})
        >>> w[2] > w[3] == 0.0
        True
        >>> Router("round_robin").demand_weights([2], {2: 1.0}, {}) is None
        True
        """
        if self.policy != "topology_aware":
            return None
        out: Dict[int, float] = {}
        for p in decode_pods:
            if cordoned_by_pod.get(p, 0):
                out[p] = 0.0
            else:
                phi = float(phi_by_pod.get(p, 1.0))
                out[p] = max(0.1, phi ** self.headroom_gamma)
        if all(v == 0.0 for v in out.values()):
            out = {p: 1.0 for p in decode_pods}  # everything cordoned
        return out

    # ---- session stream --------------------------------------------------

    def _sessions(self, n: int, rng: np.random.Generator) -> np.ndarray:
        """Session id per request: a new session opens with probability
        1/session_mean (geometric session lengths), otherwise a recent
        session from the working set re-issues."""
        new = rng.random(n) < 1.0 / self.session_mean
        off = rng.integers(0, max(1, self.working_set), size=n)
        if n:
            new[0] = True
        latest = np.cumsum(new) - 1
        return np.where(new, latest, np.maximum(0, latest - off))

    # ---- replay ----------------------------------------------------------

    def replay(
        self,
        arrivals: np.ndarray,
        pool_log: Sequence[Tuple[float, Tuple[int, ...]]],
        phi_timelines: Dict[int, Sequence[Tuple[float, float]]],
        cordon_log: Optional[Dict[int, Sequence[Tuple[float, float]]]] = None,
    ) -> RouteResult:
        """Route every request post-hoc from the scheduler's records.

        ``pool_log`` is the decode-pool membership history ``[(t,
        (pods...)), ...]`` (drains/autoscales/failures appear as new
        entries), ``phi_timelines`` the per-pod φ breakpoints recorded
        under ``(job_id, pod)`` keys, ``cordon_log`` per-pod cordoned
        OCS-slot counts over time.  Pure: a fresh rng is derived from
        ``seed`` on every call, so two replays of the same run agree
        bit-for-bit (``serving_summary`` is recomputed freely).
        """
        arrivals = np.asarray(arrivals, dtype=np.float64)
        n = arrivals.size
        pods = np.full(n, -1, dtype=np.int64)
        hits = np.zeros(n, dtype=bool)
        stats = {
            "policy": self.policy, "requests": float(n), "hits": 0.0,
            "misses": float(n), "sheds": 0.0, "overloads": 0.0,
            "hit_rate": 0.0, "pods_used": 0.0,
        }
        if n == 0 or not pool_log:
            return RouteResult(pods, hits, stats)
        rng = np.random.default_rng(self.seed)
        # draw order is fixed and policy-independent: same seed, same
        # request stream → same sessions under every policy
        r_pod = rng.integers(0, np.iinfo(np.int64).max, size=n)
        sid = self._sessions(n, rng)
        cordon_log = cordon_log or {}

        # segments of constant router state: pool membership + cordons
        # (φ varies *within* a segment and is looked up per request)
        bounds = sorted(
            {t for t, _ in pool_log}
            | {t for tl in cordon_log.values() for t, _ in tl}
        )
        seg_of = np.clip(
            np.searchsorted(bounds, arrivals, side="right") - 1,
            0, len(bounds) - 1,
        )
        pool_ts = [t for t, _ in pool_log]
        rr_base = 0  # round-robin cursor carries across segments
        sheds = overloads = 0
        for s in np.unique(seg_of):
            mask = seg_of == s
            t_seg = bounds[s]
            k = min(
                len(pool_log) - 1,
                max(0, np.searchsorted(pool_ts, t_seg, side="right") - 1),
            )
            pool = list(pool_log[k][1])
            cnt = int(mask.sum())
            if not pool:
                continue  # no decode pool: fleet-level fallback (-1)
            m = len(pool)
            if self.policy == "random":
                pods[mask] = np.asarray(pool)[r_pod[mask] % m]
                continue
            if self.policy == "round_robin":
                pods[mask] = np.asarray(pool)[
                    (rr_base + np.arange(cnt)) % m
                ]
                rr_base += cnt
                continue
            # ---- affinity policies: rendezvous hashing -------------------
            sid_seg = sid[mask]
            u = np.stack(
                [_hash01(sid_seg, p, self._salt) for p in pool], axis=1
            )
            plain = np.argmax(u, axis=1)  # health-blind sticky choice
            if self.policy == "topology_aware":
                t_req = arrivals[mask]
                phi = np.stack(
                    [
                        _step_at(phi_timelines.get(p, ()), t_req, 1.0)
                        for p in pool
                    ],
                    axis=1,
                )
                cord = np.asarray(
                    [
                        _step_at(
                            cordon_log.get(p, ()), np.asarray([t_seg]), 0.0
                        )[0] > 0
                        for p in pool
                    ]
                )
                eligible = (phi > 0.0) & ~cord[None, :]
                # soft floor: shed from pods far below the pool's best φ
                best = np.max(np.where(eligible, phi, 0.0), axis=1)
                strong = eligible & (
                    phi >= self.phi_floor * best[:, None]
                )
                use = np.where(
                    strong.any(axis=1)[:, None], strong,
                    np.where(eligible.any(axis=1)[:, None], eligible, True),
                )
                w = np.maximum(phi, 1e-9) ** self.headroom_gamma
                score = -np.log(u) / w  # weighted rendezvous: argmin
                score[~use] = np.inf
                choice = np.argmin(score, axis=1)
                # a shed is load *forced off* an unhealthy sticky pod —
                # φ-headroom re-weighting alone is not a shed
                sheds += int(
                    (~use[np.arange(plain.size), plain]).sum()
                )
            else:
                choice = plain
                if self.policy == "kv_aware":
                    choice, spilled = self._spill_overloads(
                        arrivals[mask], choice, u, m
                    )
                    overloads += spilled
                    sheds += spilled
            pods[mask] = np.asarray(pool)[choice]

        # hits: an affinity-pinned request whose session's previous
        # request landed on the same (valid) pod — its KV prefix is
        # still resident, so the prefill→decode stream is skipped
        if self.policy in AFFINITY_POLICIES:
            order = np.argsort(sid, kind="stable")
            ps, ss = pods[order], sid[order]
            h = np.zeros(n, dtype=bool)
            h[1:] = (ss[1:] == ss[:-1]) & (ps[1:] == ps[:-1]) & (ps[1:] >= 0)
            hits[order] = h
        nhits = int(hits.sum())
        stats.update(
            hits=float(nhits), misses=float(n - nhits),
            sheds=float(sheds), overloads=float(overloads),
            hit_rate=nhits / n, pods_used=float(len(set(pods[pods >= 0]))),
        )
        return RouteResult(pods, hits, stats)

    def _spill_overloads(
        self,
        t_req: np.ndarray,
        choice: np.ndarray,
        u: np.ndarray,
        m: int,
    ) -> Tuple[np.ndarray, int]:
        """kv_aware overload detection: inside each window, pods above
        ``overload_factor ×`` fair share spill their latest-arriving
        excess to the rendezvous runner-up among non-overloaded pods."""
        choice = choice.copy()
        spilled = 0
        if m < 2 or t_req.size == 0:
            return choice, spilled
        t0, t1 = float(t_req[0]), float(t_req[-1])
        edges = np.arange(t0, t1 + self.overload_window_s,
                          self.overload_window_s)
        win = np.clip(
            np.searchsorted(edges, t_req, side="right") - 1,
            0, max(0, len(edges) - 1),
        )
        for wdx in np.unique(win):
            sel = np.nonzero(win == wdx)[0]
            counts = np.bincount(choice[sel], minlength=m)
            cap = max(1, int(math.ceil(
                self.overload_factor * sel.size / m
            )))
            ok = counts <= cap
            if ok.all() or not ok.any():
                continue
            runner = np.argsort(-u[sel], axis=1)  # per-request preference
            for p in np.nonzero(~ok)[0]:
                mine = sel[choice[sel] == p]
                excess = mine[cap:]  # earliest keep their pin
                if excess.size == 0:
                    continue
                # best-ranked non-overloaded pod per spilled request
                alt = runner[np.searchsorted(sel, excess)]
                pick = np.argmax(ok[alt], axis=1)
                choice[excess] = alt[np.arange(excess.size), pick]
                spilled += int(excess.size)
        return choice, spilled
