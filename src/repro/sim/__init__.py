"""RapidAISim-analog: flow-level multi-tenant cluster simulation (paper §6).

Two progress engines share one scheduler interface
(``SimConfig.engine``): the closed-form snapshot model (:mod:`.flowsim`)
and the event-driven max-min fluid simulator (:mod:`.fluid`) that prices
OCS reconfiguration downtime and time-varying contention.
"""
from .flowsim import (
    JobFlows,
    job_slowdown,
    realized_fractions,
    ring_edges,
    waterfill_fractions,
    waterfill_levels,
)
from .fluid import CapacityEvent, Flow, FlowRecord, FluidSim, fluid_fractions
from .scheduler import (
    ENGINES,
    JobRecord,
    SimConfig,
    Simulator,
    ilp_time_model,
    summarize,
)
from .serving import (
    ScaleEvent,
    autoscale_events,
    request_latencies,
    serving_job,
    serving_trace,
)
from ..serve.router import POLICIES as ROUTER_POLICIES
from ..serve.router import RouteResult, Router
from .trace import arrival_rate_for, generate_trace

__all__ = [
    "CapacityEvent",
    "ENGINES",
    "Flow",
    "FlowRecord",
    "FluidSim",
    "JobFlows",
    "JobRecord",
    "ROUTER_POLICIES",
    "RouteResult",
    "Router",
    "ScaleEvent",
    "SimConfig",
    "Simulator",
    "arrival_rate_for",
    "autoscale_events",
    "fluid_fractions",
    "generate_trace",
    "ilp_time_model",
    "job_slowdown",
    "realized_fractions",
    "request_latencies",
    "ring_edges",
    "serving_job",
    "serving_trace",
    "summarize",
    "waterfill_fractions",
    "waterfill_levels",
]
