"""RapidAISim-analog: flow-level multi-tenant cluster simulation (paper §6)."""
from .flowsim import (
    JobFlows,
    job_slowdown,
    realized_fractions,
    ring_edges,
    waterfill_fractions,
)
from .scheduler import JobRecord, SimConfig, Simulator, ilp_time_model, summarize
from .trace import arrival_rate_for, generate_trace

__all__ = [
    "JobFlows",
    "JobRecord",
    "SimConfig",
    "Simulator",
    "arrival_rate_for",
    "generate_trace",
    "ilp_time_model",
    "job_slowdown",
    "realized_fractions",
    "ring_edges",
    "summarize",
    "waterfill_fractions",
]
