"""RapidAISim-analog flow-level network model (paper §6.1).

Coarse-grained on purpose: instead of packet simulation, each running job's
step time is stretched by the *uncoverable communication* fraction ζ — the
share of its cross-pod demand the current OCS configuration (or electrical
fabric) cannot carry at full rate:

    JRT = T_best · (1 + α · (1/φ − 1))

where α is the job's cross-pod communication fraction on the ideal fabric
and φ ∈ (0, 1] is the realized bandwidth fraction of its worst ring edge
(flows on a shortfall edge share the remaining capacity max-min fairly).

Architectures:

* ``best``  — infinite crossbar: φ = 1 always (paper's Best upper bound).
* ``cross_wiring`` / ``uniform`` — φ read off the realized OCS config:
  per edge, realized/requested, attributed to jobs proportionally.
* ``clos``  — 3-tier electrical Clos: demand is always routable, but ECMP
  hash polarization [28] concentrates flows: φ = 1/(1+β·ρ) with ρ the
  pod-pair oversubscription ratio and β the polarization severity.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.logical import Placement, ring_pairs
from ..core.topology import ClusterSpec, OCSConfig

SLOWDOWN_CAP = 4.0  # a starved flow still gets residual electrical paths
CLOS_BETA = 0.013  # hash-polarization severity (calibrated to ~1.3% avg JRT gap)


@dataclasses.dataclass
class JobFlows:
    """A job's cross-pod ring demand: edges ((i, j) with i<j) → links/group."""

    job_id: int
    edges: Dict[Tuple[int, int], int]
    comm_fraction: float


def ring_edges(pods: Sequence[int], links: int) -> Dict[Tuple[int, int], int]:
    edges: Dict[Tuple[int, int], int] = {}
    if links <= 0:
        return edges
    for i, j in ring_pairs(list(pods)):
        if i == j:
            continue
        e = (min(i, j), max(i, j))
        edges[e] = edges.get(e, 0) + links
    return edges


def realized_fractions(
    spec: ClusterSpec,
    flows: Sequence[JobFlows],
    config: Optional[OCSConfig],
    architecture: str,
) -> Dict[int, float]:
    """φ per job: min over its edges of its realized/requested share."""
    if architecture == "best":
        return {f.job_id: 1.0 for f in flows}

    # total requested links per pod pair (per spine group it is uniform; we
    # work in per-group units: request r, realization summed over groups / H)
    total_req: Dict[Tuple[int, int], int] = {}
    for f in flows:
        for e, r in f.edges.items():
            total_req[e] = total_req.get(e, 0) + r

    phi: Dict[int, float] = {}
    if architecture == "clos":
        # electrical: link exists, but polarization penalizes hot pairs
        for f in flows:
            worst = 1.0
            for e, r in f.edges.items():
                rho = total_req[e] / max(1, spec.k_spine)
                worst = min(worst, 1.0 / (1.0 + CLOS_BETA * rho * spec.num_pods / 8))
            phi[f.job_id] = worst
        return phi

    assert config is not None, "OCS architectures need a realized config"
    realized_pair = config.pair_capacity()

    for f in flows:
        worst = 1.0
        for e, r in f.edges.items():
            got = realized_pair[e[0], e[1]]
            share = got * (r / max(1, total_req[e]))
            worst = min(worst, share / r if r else 1.0)
        phi[f.job_id] = float(np.clip(worst, 1.0 / SLOWDOWN_CAP, 1.0))
    return phi


def job_slowdown(comm_fraction: float, phi: float) -> float:
    """JRT multiplier: comm stretches by 1/φ, compute unaffected."""
    return 1.0 + comm_fraction * (1.0 / max(phi, 1.0 / SLOWDOWN_CAP) - 1.0)


def waterfill_fractions(
    spec: ClusterSpec,
    flows: Sequence[JobFlows],
    config: Optional[OCSConfig],
    architecture: str,
) -> Dict[int, float]:
    """φ per job from vectorized max-min water-filling over edges.

    Progressive filling: every unfrozen flow's satisfied fraction x rises
    uniformly until some edge saturates (Σ demand·x = capacity); flows on
    saturated edges freeze at that level and release no further demand,
    and the remaining flows keep filling with the leftover capacity.  A
    collective runs at its slowest edge, so x is per-flow, not per-edge —
    each job's φ is the level at which it froze.

    Compared to the proportional heuristic (:func:`realized_fractions`),
    capacity a frozen flow cannot use is redistributed, so φ is a true
    max-min allocation.  ``best``/``clos`` delegate (no OCS edges there).
    """
    if architecture in ("best", "clos"):
        return realized_fractions(spec, flows, config, architecture)
    assert config is not None, "OCS architectures need a realized config"
    flows = list(flows)
    if not flows:
        return {}

    cap_pair = config.pair_capacity()

    edge_ix: Dict[Tuple[int, int], int] = {}
    for f in flows:
        for e in f.edges:
            edge_ix.setdefault(e, len(edge_ix))
    if not edge_ix:
        return {f.job_id: 1.0 for f in flows}

    F, E = len(flows), len(edge_ix)
    D = np.zeros((F, E), dtype=np.float64)  # requested links per (flow, edge)
    for fi, f in enumerate(flows):
        for e, r in f.edges.items():
            D[fi, edge_ix[e]] = float(r)
    cap = np.array(
        [cap_pair[i, j] for (i, j) in edge_ix], dtype=np.float64
    )

    x = np.ones(F, dtype=np.float64)
    active = D.any(axis=1)
    frozen_use = np.zeros(E, dtype=np.float64)
    for _ in range(E):
        if not active.any():
            break
        load = active @ D  # unfrozen demand per edge
        live = load > 1e-12
        if not live.any():
            break
        level = np.full(E, np.inf)
        level[live] = np.maximum(0.0, cap[live] - frozen_use[live]) / load[live]
        lvl = level.min()
        if lvl >= 1.0:
            break  # everyone fits at full rate
        sat = level <= lvl + 1e-12
        hit = active & (D[:, sat].sum(axis=1) > 0)
        x[hit] = lvl
        frozen_use += lvl * (hit @ D)
        active &= ~hit

    return {
        f.job_id: float(np.clip(x[fi], 1.0 / SLOWDOWN_CAP, 1.0))
        for fi, f in enumerate(flows)
    }
