"""RapidAISim-analog flow-level network model (paper §6.1).

Coarse-grained on purpose: instead of packet simulation, each running job's
step time is stretched by the *uncoverable communication* fraction ζ — the
share of its cross-pod demand the current OCS configuration (or electrical
fabric) cannot carry at full rate:

    JRT = T_best · (1 + α · (1/φ − 1))

where α is the job's cross-pod communication fraction on the ideal fabric
and φ ∈ (0, 1] is the realized bandwidth fraction of its worst ring edge
(flows on a shortfall edge share the remaining capacity max-min fairly).

Architectures:

* ``best``  — infinite crossbar: φ = 1 always (paper's Best upper bound).
* ``cross_wiring`` / ``uniform`` — φ read off the realized OCS config:
  per edge, realized/requested, attributed to jobs proportionally.
* ``clos``  — 3-tier electrical Clos: demand is always routable, but ECMP
  hash polarization [28] concentrates flows: φ = 1/(1+β·ρ) with ρ the
  pod-pair oversubscription ratio and β the polarization severity.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.logical import Placement
from ..core.topology import ClusterSpec, OCSConfig

SLOWDOWN_CAP = 4.0  # a starved flow still gets residual electrical paths
CLOS_BETA = 0.013  # hash-polarization severity (calibrated to ~1.3% avg JRT gap)


@dataclasses.dataclass
class JobFlows:
    """A job's cross-pod ring demand: edges ((i, j) with i<j) → links/group."""

    job_id: int
    edges: Dict[Tuple[int, int], int]
    comm_fraction: float


def ring_edges(pods: Sequence[int], links: int) -> Dict[Tuple[int, int], int]:
    edges: Dict[Tuple[int, int], int] = {}
    n = len(pods)
    if n < 2 or links <= 0:
        return edges
    for t in range(n):
        i, j = pods[t], pods[(t + 1) % n]
        if i == j:
            continue
        e = (min(i, j), max(i, j))
        edges[e] = edges.get(e, 0) + links
        if n == 2:
            break  # both ring directions collapse onto one pair
    return edges


def realized_fractions(
    spec: ClusterSpec,
    flows: Sequence[JobFlows],
    config: Optional[OCSConfig],
    architecture: str,
) -> Dict[int, float]:
    """φ per job: min over its edges of its realized/requested share."""
    if architecture == "best":
        return {f.job_id: 1.0 for f in flows}

    # total requested links per pod pair (per spine group it is uniform; we
    # work in per-group units: request r, realization summed over groups / H)
    total_req: Dict[Tuple[int, int], int] = {}
    for f in flows:
        for e, r in f.edges.items():
            total_req[e] = total_req.get(e, 0) + r

    phi: Dict[int, float] = {}
    if architecture == "clos":
        # electrical: link exists, but polarization penalizes hot pairs
        for f in flows:
            worst = 1.0
            for e, r in f.edges.items():
                rho = total_req[e] / max(1, spec.k_spine)
                worst = min(worst, 1.0 / (1.0 + CLOS_BETA * rho * spec.num_pods / 8))
            phi[f.job_id] = worst
        return phi

    assert config is not None, "OCS architectures need a realized config"
    realized = config.realized_bidirectional().astype(np.float64)  # (H, P, P)
    realized_pair = realized.sum(axis=0) / max(1, config.num_groups)

    for f in flows:
        worst = 1.0
        for e, r in f.edges.items():
            got = realized_pair[e[0], e[1]]
            share = got * (r / max(1, total_req[e]))
            worst = min(worst, share / r if r else 1.0)
        phi[f.job_id] = float(np.clip(worst, 1.0 / SLOWDOWN_CAP, 1.0))
    return phi


def job_slowdown(comm_fraction: float, phi: float) -> float:
    """JRT multiplier: comm stretches by 1/φ, compute unaffected."""
    return 1.0 + comm_fraction * (1.0 / max(phi, 1.0 / SLOWDOWN_CAP) - 1.0)
