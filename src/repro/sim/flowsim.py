"""RapidAISim-analog flow-level network model (paper §6.1).

Coarse-grained on purpose: instead of packet simulation, each running job's
step time is stretched by the *uncoverable communication* fraction ζ — the
share of its cross-pod demand the current OCS configuration (or electrical
fabric) cannot carry at full rate:

    JRT = T_best · (1 + α · (1/φ − 1))

where α is the job's cross-pod communication fraction on the ideal fabric
and φ ∈ (0, 1] is the realized bandwidth fraction of its worst ring edge
(flows on a shortfall edge share the remaining capacity max-min fairly).

Architectures:

* ``best``  — infinite crossbar: φ = 1 always (paper's Best upper bound).
* ``cross_wiring`` / ``uniform`` — φ read off the realized OCS config:
  per edge, realized/requested, attributed to jobs proportionally.
* ``clos``  — 3-tier electrical Clos: demand is always routable, but ECMP
  hash polarization [28] concentrates flows: φ = 1/(1+β·ρ) with ρ the
  pod-pair oversubscription ratio and β the polarization severity.

The residual-electrical slowdown ceiling is a deployment parameter
(:attr:`~repro.core.topology.ClusterSpec.slowdown_cap`): a starved flow
bottoms out at ``1/slowdown_cap`` of full rate over leftover electrical
paths, and ``slowdown_cap=None`` models a cluster with *no* residual
fabric — a fully-dark circuit then stalls its flows (infinite slowdown)
instead of silently progressing at the cap.  ``SLOWDOWN_CAP`` is only the
spec's default value.

The vectorized progressive-filling core (:func:`waterfill_levels`) is
shared with the event-driven fluid engine (:mod:`.fluid`), which replays
the same allocation through time instead of from one snapshot.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..core.logical import ring_pairs
from ..core.topology import ClusterSpec, OCSConfig

SLOWDOWN_CAP = 4.0  # default ClusterSpec.slowdown_cap (residual electrical)
CLOS_BETA = 0.013  # hash-polarization severity (calibrated to ~1.3% avg JRT gap)


def phi_floor(cap: Optional[float]) -> float:
    """The φ floor implied by a slowdown cap (0 when no residual fabric)."""
    if cap is None or not math.isfinite(cap) or cap <= 0:
        return 0.0
    return 1.0 / cap


def _spec_cap(spec: ClusterSpec) -> Optional[float]:
    return getattr(spec, "slowdown_cap", SLOWDOWN_CAP)


@dataclasses.dataclass
class JobFlows:
    """A job's cross-pod ring demand: edges ((i, j) with i<j) → links/group."""

    job_id: int
    edges: Dict[Tuple[int, int], int]
    comm_fraction: float


def ring_edges(pods: Sequence[int], links: int) -> Dict[Tuple[int, int], int]:
    edges: Dict[Tuple[int, int], int] = {}
    if links <= 0:
        return edges
    for i, j in ring_pairs(list(pods)):
        if i == j:
            continue
        e = (min(i, j), max(i, j))
        edges[e] = edges.get(e, 0) + links
    return edges


def realized_fractions(
    spec: ClusterSpec,
    flows: Sequence[JobFlows],
    config: Optional[OCSConfig],
    architecture: str,
) -> Dict[int, float]:
    """φ per job: min over its edges of its realized/requested share."""
    if architecture == "best":
        return {f.job_id: 1.0 for f in flows}

    # total requested links per pod pair (per spine group it is uniform; we
    # work in per-group units: request r, realization summed over groups / H)
    total_req: Dict[Tuple[int, int], int] = {}
    for f in flows:
        for e, r in f.edges.items():
            total_req[e] = total_req.get(e, 0) + r

    phi: Dict[int, float] = {}
    if architecture == "clos":
        # electrical: link exists, but polarization penalizes hot pairs
        for f in flows:
            worst = 1.0
            for e, r in f.edges.items():
                rho = total_req[e] / max(1, spec.k_spine)
                worst = min(worst, 1.0 / (1.0 + CLOS_BETA * rho * spec.num_pods / 8))
            phi[f.job_id] = worst
        return phi

    assert config is not None, "OCS architectures need a realized config"
    realized_pair = config.pair_capacity()
    floor = phi_floor(_spec_cap(spec))

    for f in flows:
        worst = 1.0
        for e, r in f.edges.items():
            got = realized_pair[e[0], e[1]]
            share = got * (r / max(1, total_req[e]))
            worst = min(worst, share / r if r else 1.0)
        phi[f.job_id] = float(np.clip(worst, floor, 1.0))
    return phi


def job_slowdown(
    comm_fraction: float, phi: float, cap: Optional[float] = SLOWDOWN_CAP
) -> float:
    """JRT multiplier: comm stretches by 1/φ, compute unaffected.

    ``cap`` is the residual-electrical slowdown ceiling (see module doc);
    with ``cap=None`` a φ of zero means the flow makes no progress at all
    (``inf`` — the fluid engine turns this into a stall, not a finite JRT).
    """
    phi = min(1.0, max(phi, phi_floor(cap)))
    if phi <= 0.0:
        return math.inf if comm_fraction > 0 else 1.0
    return 1.0 + comm_fraction * (1.0 / phi - 1.0)


def waterfill_levels(D: np.ndarray, cap: np.ndarray) -> np.ndarray:
    """Vectorized max-min progressive filling: per-flow fill levels.

    ``D`` is the ``(F, E)`` per-flow edge demand, ``cap`` the ``(E,)`` edge
    capacities.  Every unfrozen flow's satisfied fraction x rises uniformly
    until some edge saturates (Σ demand·x = capacity); flows on saturated
    edges freeze at that level and release no further demand, and the rest
    keep filling with the leftover capacity.  A collective runs at its
    slowest edge, so x is per-flow, not per-edge.  Returns x ∈ [0, 1]^F,
    *unclipped* — a flow whose every path is dark gets exactly 0.
    """
    D = np.asarray(D, dtype=np.float64)
    cap = np.asarray(cap, dtype=np.float64)
    F, E = D.shape
    x = np.ones(F, dtype=np.float64)
    if F == 0 or E == 0:
        return x
    active = D.any(axis=1)
    frozen_use = np.zeros(E, dtype=np.float64)
    for _ in range(E):
        if not active.any():
            break
        load = active @ D  # unfrozen demand per edge
        live = load > 1e-12
        if not live.any():
            break
        level = np.full(E, np.inf)
        level[live] = np.maximum(0.0, cap[live] - frozen_use[live]) / load[live]
        lvl = level.min()
        if lvl >= 1.0:
            break  # everyone fits at full rate
        sat = level <= lvl + 1e-12
        hit = active & (D[:, sat].sum(axis=1) > 0)
        x[hit] = lvl
        frozen_use += lvl * (hit @ D)
        active &= ~hit
    return x


def demand_matrix(
    flows: Sequence[JobFlows], cap_pair: np.ndarray
) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Assemble the ``(F, E)`` demand matrix and ``(E,)`` capacity vector
    over the union edge set of ``flows`` (None when no flow has edges).

    Small-scale snapshot path; the fluid engine's per-event hot loop uses
    cached encoded edge arrays instead (``fluid.FluidSim._rates``).
    """
    edge_ix: Dict[Tuple[int, int], int] = {}
    for f in flows:
        for e in f.edges:
            edge_ix.setdefault(e, len(edge_ix))
    if not edge_ix:
        return None
    F, E = len(flows), len(edge_ix)
    D = np.zeros((F, E), dtype=np.float64)  # requested links per (flow, edge)
    for fi, f in enumerate(flows):
        for e, r in f.edges.items():
            D[fi, edge_ix[e]] = float(r)
    cap = np.array([cap_pair[i, j] for (i, j) in edge_ix], dtype=np.float64)
    return D, cap


def waterfill_fractions(
    spec: ClusterSpec,
    flows: Sequence[JobFlows],
    config: Optional[OCSConfig],
    architecture: str,
    pair_cap: Optional[np.ndarray] = None,
) -> Dict[int, float]:
    """φ per job from vectorized max-min water-filling over edges.

    Compared to the proportional heuristic (:func:`realized_fractions`),
    capacity a frozen flow cannot use is redistributed, so φ is a true
    max-min allocation (see :func:`waterfill_levels`).  ``best``/``clos``
    delegate (no OCS edges there).  φ is clipped to the spec's residual-
    electrical floor — zero when ``slowdown_cap`` is None.

    ``pair_cap`` overrides ``config.pair_capacity()`` — the gray-failure
    path hands in :meth:`PortMask.effective_pair_capacity
    <repro.fault.masks.PortMask.effective_pair_capacity>` so derated
    links surface as φ < 1 here too, not only in the fluid engine.
    """
    if architecture in ("best", "clos"):
        return realized_fractions(spec, flows, config, architecture)
    assert config is not None, "OCS architectures need a realized config"
    flows = list(flows)
    if not flows:
        return {}

    mat = demand_matrix(
        flows, config.pair_capacity() if pair_cap is None else pair_cap
    )
    if mat is None:
        return {f.job_id: 1.0 for f in flows}
    x = waterfill_levels(*mat)
    floor = phi_floor(_spec_cap(spec))
    return {
        f.job_id: float(np.clip(x[fi], floor, 1.0))
        for fi, f in enumerate(flows)
    }
