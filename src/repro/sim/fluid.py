"""Event-driven max-min fluid flow simulator (paper §6, fidelity upgrade).

The closed-form model (:mod:`.flowsim`) prices a job's whole run from one
topology snapshot: ``JRT = T_best · (1 + α(1/φ − 1))``.  This module
simulates the same max-min bandwidth sharing *through time*: flows carry
remaining work, and on every event — flow arrival, flow completion,
capacity change, reconfiguration downtime window, fault/repair re-solve —
the vectorized progressive-filling allocation
(:func:`~repro.sim.flowsim.waterfill_levels`) is recomputed on the
realized topology and virtual time advances to the next completion.  On
static scenarios the two models agree to float precision
(``tests/test_fluid_differential.py``); the fluid engine additionally
expresses what the closed form cannot:

* **OCS reconfiguration delay** — circuits being retuned carry zero
  bandwidth for ``downtime_s`` (rotorsim-style dark windows).  Incremental
  deltas from :mod:`~repro.core.incremental` touch fewer circuits, so
  their dark set — and the time-priced downtime Σ delay·|Δx| — is
  strictly smaller than a cold re-solve's.
* **Time-varying contention** — a flow's φ changes as neighbours arrive
  and finish; progress integrates the realized rate instead of scaling
  once from a static snapshot.
* **Mid-run bandwidth changes** — fault/repair transitions arrive as
  :class:`CapacityEvent` re-solves; with
  ``ClusterSpec.slowdown_cap=None`` a fully-dark flow *stalls* (its
  stalled seconds are accounted) rather than bottoming out at a cap.

Everything is plain numpy; a 10k-event trace runs in seconds
(``benchmarks/bench_fluid.py`` reports events/sec and the fidelity gap).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.topology import ClusterSpec, OCSConfig
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from . import flowsim

__all__ = [
    "CLEAR_PAIR_CAP",
    "CapacityEvent",
    "DarkWindows",
    "Flow",
    "FlowRecord",
    "FluidSim",
    "effective_capacity",
    "fluid_fractions",
]

Pair = Tuple[int, int]

_SPEC_CAP = "spec"  # sentinel: read the slowdown cap off the ClusterSpec
CLEAR_PAIR_CAP = "clear"  # CapacityEvent.pair_cap sentinel: back to nominal


@dataclasses.dataclass
class Flow:
    """One job's cross-pod collective demand, carrying remaining work.

    ``edges`` are per-group link demands over pod pairs (``i < j``), the
    same objects :func:`repro.dist.demand.job_edges` emits; ``work`` is
    the job's ideal-fabric service time (T_best seconds).  The collective
    runs at its slowest edge, so the whole flow progresses at the max-min
    fill level of its worst edge — per-flow, not per-edge.
    """

    flow_id: int
    edges: Dict[Pair, float]
    comm_fraction: float
    work: float
    arrival: float = 0.0
    # latency-sensitive flows (inference serving KV streams): the engine
    # records their (t, φ) timeline in ``FluidSim.phi_history`` so
    # per-request transfer completions — the TTFT proxy, not a JCT — can
    # be integrated afterwards by ``repro.sim.serving.request_latencies``.
    # Standalone-engine twin of ``Simulator.phi_timeline`` (the scheduler
    # drives ``fluid_fractions`` directly and records its own timeline);
    # both feed the same integrator, so the semantics cannot diverge.
    latency_sensitive: bool = False


@dataclasses.dataclass(frozen=True)
class CapacityEvent:
    """The realized topology changes at ``time``.

    ``config`` (if given) becomes the live configuration; ``dark_pairs``
    are the pod pairs whose circuits are retuning and carry *zero*
    bandwidth during ``[time, time + downtime_s]``.  ``rewired`` (Σ|Δx|
    circuit endpoints touched, from
    :attr:`~repro.core.incremental.ColoringState.rewired` or
    :meth:`~repro.core.topology.OCSConfig.rewiring_distance`) prices the
    downtime; it defaults to the dark-pair count.
    """

    time: float
    config: Optional[OCSConfig] = None
    dark_pairs: FrozenSet[Pair] = frozenset()
    downtime_s: float = 0.0
    rewired: Optional[int] = None
    # gray failures: replace the live pair-capacity matrix (None = keep;
    # use ``CLEAR_PAIR_CAP`` to drop an earlier override back to nominal)
    pair_cap: Optional[object] = None


@dataclasses.dataclass
class FlowRecord:
    """Per-flow outcome of a fluid run."""

    flow_id: int
    arrival: float
    work: float
    finish: float = math.nan
    min_phi: float = 1.0
    stalled_s: float = 0.0  # wall seconds spent at zero rate (dark/starved)

    @property
    def jct(self) -> float:
        return self.finish - self.arrival


class DarkWindows:
    """Per-pair reconfiguration dark windows: pair → ``[start, until)``.

    Shared by :class:`FluidSim` and the scheduler so the window semantics
    cannot diverge.  Windows are tracked per pod pair — an unrelated
    later reconfiguration never extends an earlier pair's outage (and
    vice versa); re-darkening a pair merges to ``min(start), max(until)``.
    """

    __slots__ = ("_win",)

    def __init__(self):
        self._win: Dict[Pair, Tuple[float, float]] = {}

    def __bool__(self) -> bool:
        return bool(self._win)

    def add(self, pairs: Iterable[Pair], start: float, until: float) -> None:
        for p in pairs:
            s0, u0 = self._win.get(p, (start, until))
            self._win[p] = (min(s0, start), max(u0, until))

    def active(self, now: float) -> List[Pair]:
        """Pairs dark at ``now``."""
        return [p for p, (s, u) in self._win.items() if s <= now < u]

    def prune(self, now: float) -> bool:
        """Drop windows that have ended by ``now``; True if any did."""
        dead = [p for p, (_, u) in self._win.items() if u <= now]
        for p in dead:
            del self._win[p]
        return bool(dead)


def effective_capacity(
    config: OCSConfig,
    dark_pairs: Iterable[Pair] = (),
    pair_cap: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Pair capacity of ``config`` with retuning circuits zeroed out.

    ``pair_cap`` substitutes the nominal capacity matrix — the gray-
    failure path passes :meth:`PortMask.effective_pair_capacity
    <repro.fault.masks.PortMask.effective_pair_capacity>` so derated
    links carry their fractional bandwidth through the water-filling."""
    base = config.pair_capacity() if pair_cap is None else pair_cap
    cap = np.array(base, dtype=np.float64)
    for i, j in dark_pairs:
        cap[i, j] = 0.0
        cap[j, i] = 0.0
    return cap


def fluid_fractions(
    spec: ClusterSpec,
    flows: Sequence[flowsim.JobFlows],
    config: Optional[OCSConfig],
    architecture: str,
    dark_pairs: Iterable[Pair] = (),
    cap: object = _SPEC_CAP,
    pair_cap: Optional[np.ndarray] = None,
) -> Dict[int, float]:
    """φ per flow via max-min water-filling on the *effective* capacity.

    The fluid twin of :func:`~repro.sim.flowsim.waterfill_fractions`:
    identical on a healthy snapshot (the differential guarantee), but
    circuits in ``dark_pairs`` carry zero bandwidth, and the clip floor
    comes from ``cap`` (default: the spec's ``slowdown_cap``) — with no
    residual electrical fabric (``None``) a fully-dark flow gets φ = 0.
    ``pair_cap`` substitutes the nominal capacity matrix (gray-derated
    links; see :func:`effective_capacity`).  ``best``/``clos`` have no
    OCS circuits to darken and delegate to the closed-form fractions.
    """
    if architecture in ("best", "clos"):
        return flowsim.realized_fractions(spec, flows, config, architecture)
    assert config is not None, "OCS architectures need a realized config"
    flows = list(flows)
    if not flows:
        return {}
    mat = flowsim.demand_matrix(
        flows, effective_capacity(config, dark_pairs, pair_cap=pair_cap)
    )
    if mat is None:
        return {f.job_id: 1.0 for f in flows}
    x = flowsim.waterfill_levels(*mat)
    if cap is _SPEC_CAP:
        cap = getattr(spec, "slowdown_cap", flowsim.SLOWDOWN_CAP)
    floor = flowsim.phi_floor(cap)  # type: ignore[arg-type]
    x = np.clip(x, floor, 1.0)
    return {f.job_id: float(x[fi]) for fi, f in enumerate(flows)}


class _Active:
    __slots__ = (
        "flow", "remaining", "rate", "last_t", "record", "ekeys", "ew",
    )

    def __init__(self, flow: Flow, record: FlowRecord, num_pods: int):
        self.flow = flow
        self.remaining = flow.work
        self.rate = 0.0  # work-seconds per wall second (1/slowdown)
        self.last_t = flow.arrival
        self.record = record
        # encoded edge arrays, cached for the flow's lifetime (the per-event
        # hot path re-assembles the demand matrix from these)
        n = len(flow.edges)
        self.ekeys = np.fromiter(
            (i * num_pods + j for i, j in flow.edges), dtype=np.int64, count=n
        )
        self.ew = np.fromiter(flow.edges.values(), dtype=np.float64, count=n)

    def advance(self, now: float) -> None:
        dt = now - self.last_t
        if dt <= 0:
            return
        if self.rate > 0:
            self.remaining = max(0.0, self.remaining - dt * self.rate)
        else:
            self.record.stalled_s += dt
        self.last_t = now


class FluidSim:
    """Event-driven fluid simulation of a flow set on one cluster.

    Flows start at their arrival time (admission/queueing is the
    scheduler's job — :class:`~repro.sim.scheduler.Simulator` with
    ``SimConfig.engine='fluid'`` drives this machinery behind placement
    and the control plane); capacity events re-solve the allocation and
    open dark windows.  ``run()`` drains the heap and returns per-flow
    records; ``events`` counts processed (non-stale) events and
    ``downtime_circuit_s`` accumulates the time-priced reconfiguration
    downtime Σ downtime · rewired.
    """

    _ARRIVE, _CAPACITY, _DARK_END, _FINISH = 0, 1, 2, 3

    def __init__(
        self,
        spec: ClusterSpec,
        architecture: str = "cross_wiring",
        config: Optional[OCSConfig] = None,
        flows: Sequence[Flow] = (),
        capacity_events: Sequence[CapacityEvent] = (),
        slowdown_cap: object = _SPEC_CAP,
        tracer: Optional[obs_trace.NullTracer] = None,
        health: Optional[object] = None,
        pair_cap: Optional[np.ndarray] = None,
    ):
        self.spec = spec
        self.architecture = architecture
        self.config = config
        # gray-failure capacity override (None = config.pair_capacity());
        # CapacityEvents can swap it mid-run as links derate/restore
        self.pair_cap = pair_cap
        self.cap = (
            getattr(spec, "slowdown_cap", flowsim.SLOWDOWN_CAP)
            if slowdown_cap is _SPEC_CAP
            else slowdown_cap
        )
        self.flows = list(flows)
        self.capacity_events = sorted(capacity_events, key=lambda e: e.time)
        self.records: Dict[int, FlowRecord] = {}
        self.events = 0  # processed (non-stale) events
        self.downtime_events = 0
        self.downtime_s = 0.0
        self.downtime_circuit_s = 0.0  # Σ downtime · rewired (time-priced)
        self._active: Dict[int, _Active] = {}
        self._dark = DarkWindows()
        self.trace = tracer if tracer is not None else obs_trace.NULL
        # (t, φ) breakpoints per latency-sensitive flow, piecewise
        # constant — the serving latency integration consumes these.
        # Same Timeline instrument as ``Simulator.phi_timeline``: the two
        # engines share one φ-bookkeeping implementation.
        self.phi_history = obs_metrics.Timeline("fluid.phi")
        # optional repro.obs.health.HealthMonitor: streamed the same φ
        # breakpoints and dark windows the scheduler path feeds it, so
        # detectors behave identically when this engine runs standalone
        self.health = health

    def add_flow(self, flow: Flow) -> None:
        self.flows.append(flow)

    # ---- allocation ------------------------------------------------------

    def _rates(self, acts: List[_Active], now: float) -> np.ndarray:
        """Vectorized (φ, slowdown⁻¹) evaluation for the active flows:
        demand matrix scattered from the cached per-flow edge arrays, one
        water-filling, one clip, one stretch — no per-flow Python math on
        the event hot path.  Returns the (F,) rate vector and stores min_phi
        on the records."""
        F = len(acts)
        if F == 0:
            return np.zeros(0)
        alphas = np.array([a.flow.comm_fraction for a in acts])
        if self.architecture in ("best", "clos"):
            jf = [
                flowsim.JobFlows(a.flow.flow_id, a.flow.edges, a.flow.comm_fraction)
                for a in acts
            ]
            pd = flowsim.realized_fractions(
                self.spec, jf, self.config, self.architecture
            )
            phi = np.array([pd[a.flow.flow_id] for a in acts])
        else:
            assert self.config is not None, "OCS architectures need a config"
            P = self.spec.num_pods
            counts = np.array([a.ekeys.size for a in acts], dtype=np.int64)
            total = int(counts.sum())
            if total == 0:
                phi = np.ones(F)
            else:
                cap_pair = effective_capacity(
                    self.config, self._dark.active(now),
                    pair_cap=self.pair_cap,
                )
                keys = np.concatenate([a.ekeys for a in acts])
                w = np.concatenate([a.ew for a in acts])
                uniq, inv = np.unique(keys, return_inverse=True)
                D = np.zeros((F, uniq.size))
                rows = np.repeat(np.arange(F, dtype=np.int64), counts)
                np.add.at(D, (rows, inv), w)
                cap_vec = cap_pair[uniq // P, uniq % P]
                phi = flowsim.waterfill_levels(D, cap_vec)
        floor = flowsim.phi_floor(self.cap)  # type: ignore[arg-type]
        phi = np.clip(phi, floor, 1.0)
        for a, p in zip(acts, phi.tolist()):
            if p < a.record.min_phi:
                a.record.min_phi = p
            if a.flow.latency_sensitive:
                self.phi_history.point(a.flow.flow_id, now, p)
                if self.health is not None:
                    self.health.observe_phi(now, a.flow.flow_id, p)
        # rate = 1/(1 + α(1/φ − 1)); φ = 0 → stall (rate 0) unless α = 0
        rate = np.empty(F)
        live = phi > 0.0
        rate[live] = 1.0 / (1.0 + alphas[live] * (1.0 / phi[live] - 1.0))
        rate[~live] = np.where(alphas[~live] > 0, 0.0, 1.0)
        return rate

    # ---- main loop -------------------------------------------------------

    def run(self, until: Optional[float] = None) -> List[FlowRecord]:
        ARRIVE, CAPACITY, DARK_END, FINISH = (
            self._ARRIVE, self._CAPACITY, self._DARK_END, self._FINISH
        )
        heap: List[Tuple[float, int, int, int]] = []
        seq = 0
        order = sorted(
            range(len(self.flows)), key=lambda i: (self.flows[i].arrival, i)
        )
        for i in order:
            heapq.heappush(heap, (self.flows[i].arrival, ARRIVE, seq, i))
            seq += 1
        for ci, ev in enumerate(self.capacity_events):
            heapq.heappush(heap, (ev.time, CAPACITY, seq, ci))
            seq += 1
        finish_version: Dict[int, int] = {}

        def advance_all(now: float) -> None:
            for a in self._active.values():
                a.advance(now)

        def refresh(now: float) -> None:
            """Re-run the water-filling and reschedule completions."""
            nonlocal seq
            acts = list(self._active.values())
            rates = self._rates(acts, now)
            for a, r in zip(acts, rates.tolist()):
                fid = a.flow.flow_id
                a.rate = r
                if r > 0 and math.isfinite(a.remaining):
                    finish_version[fid] = seq
                    heapq.heappush(heap, (now + a.remaining / r, FINISH, seq, fid))
                    seq += 1
                else:
                    # stalled, or an open-ended (infinite-work) serving
                    # flow: no finish to schedule
                    finish_version[fid] = -1

        last_t = 0.0
        while heap:
            t, kind, sq, payload = heapq.heappop(heap)
            if until is not None and t > until:
                last_t = until
                break
            last_t = t
            if kind == FINISH:
                if finish_version.get(payload) != sq:
                    continue  # stale: rates changed since scheduling
                self.events += 1
                advance_all(t)
                a = self._active.pop(payload)
                finish_version.pop(payload, None)
                a.record.finish = t
                a.remaining = 0.0
                if self.trace.enabled:
                    self.trace.span(
                        "flow", f"flow{payload}",
                        ts=a.record.arrival, dur=t - a.record.arrival,
                        flow_id=payload,
                        min_phi=round(a.record.min_phi, 9),
                        stalled_s=round(a.record.stalled_s, 9),
                    )
                refresh(t)
            elif kind == ARRIVE:
                self.events += 1
                advance_all(t)
                flow = self.flows[payload]
                rec = FlowRecord(flow.flow_id, flow.arrival, flow.work)
                self.records[flow.flow_id] = rec
                self._active[flow.flow_id] = _Active(
                    flow, rec, self.spec.num_pods
                )
                refresh(t)
            elif kind == CAPACITY:
                self.events += 1
                advance_all(t)
                ev = self.capacity_events[payload]
                if ev.config is not None:
                    self.config = ev.config
                if ev.pair_cap is not None:
                    self.pair_cap = (
                        None if isinstance(ev.pair_cap, str)
                        and ev.pair_cap == CLEAR_PAIR_CAP
                        else ev.pair_cap
                    )
                if self.trace.enabled:
                    self.trace.instant(
                        "fault", "capacity", ts=t,
                        reconfig=ev.config is not None,
                        dark=len(ev.dark_pairs),
                        rewired=ev.rewired,
                    )
                if ev.downtime_s > 0 and ev.dark_pairs:
                    self._dark.add(ev.dark_pairs, t, t + ev.downtime_s)
                    if self.health is not None:
                        self.health.observe_dark(
                            t, ev.downtime_s, len(ev.dark_pairs),
                            "incremental" if ev.rewired is not None
                            else "cold",
                        )
                    rewired = (
                        ev.rewired if ev.rewired is not None
                        else len(ev.dark_pairs)
                    )
                    self.downtime_events += 1
                    self.downtime_s += ev.downtime_s
                    self.downtime_circuit_s += ev.downtime_s * rewired
                    if self.trace.enabled:
                        for i, j in sorted(ev.dark_pairs):
                            self.trace.span(
                                "dark_window", f"{i}-{j}",
                                ts=t, dur=ev.downtime_s, pair=[i, j],
                            )
                    heapq.heappush(heap, (t + ev.downtime_s, DARK_END, seq, 0))
                    seq += 1
                refresh(t)
            else:  # DARK_END
                if not self._dark.prune(t):
                    continue  # stale: this pair's window was merged/extended
                self.events += 1
                advance_all(t)
                refresh(t)
        if until is not None:
            last_t = until
        advance_all(last_t)
        if self.health is not None:
            self.health.finalize(last_t)
        return [self.records[f.flow_id] for f in self.flows
                if f.flow_id in self.records]
