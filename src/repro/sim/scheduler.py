"""Event-driven multi-tenant cluster simulator (paper §6.3).

Simulates a P-pod OCS cluster running a job trace under a chosen
(architecture × reconfiguration strategy) pair:

* placement: fewest-pods best-fit (TP in-server, EP in-pod per §3.1 — both
  invisible to the OCS core; only the DP ring crosses pods),
* on each job start the control plane recomputes the OCS configuration for
  the aggregate demand of all running jobs; the *computation time* of the
  strategy delays the job start (JWT includes it, as in the paper),
* running jobs progress under processor-sharing with per-job slowdown from
  the flow model (``flowsim.waterfill_fractions`` — max-min water-filling
  over OCS edges); slowdowns are re-evaluated whenever the running set or
  the OCS configuration changes.  Per-job communication fractions and edge
  demand come from the collective planner (``repro.dist``): dense jobs
  contribute a DP ring, MoE-EP jobs an all-to-all mesh, PP jobs a stage
  chain, each ring-ordered against the current configuration.

Strategy runtimes: polynomial algorithms (MDMCF, greedy, Helios) are
*measured* (this container's wall clock, scaled to all OCS groups); exact
ILP is *modeled* by a curve calibrated to the paper's Gurobi measurements
(435.07 s at 32k nodes, manageable below 4k — Fig. 2c/6), since no ILP
solver ships in this container.  The model is documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.logical import Job, Placement, shave_to_budget
from ..core.reconfig import (
    helios_matching,
    ltrr,
    mdmcf_cold,
    mdmcf_reconfigure,
    uniform_best_effort,
    uniform_greedy,
)
from ..core.topology import ClusterSpec, OCSConfig
from ..dist import collectives as dist_collectives
from ..dist import demand as dist_demand
from . import flowsim
from .trace import COMM_FRACTION

OCS_SWITCH_S = 0.1  # optical switching pause applied to impacted jobs


def ilp_time_model(num_gpus: int) -> float:
    """Calibrated Gurobi-ILP runtime (paper Fig. 2c: 435.07 s at 32k nodes,
    ~exponential growth, manageable below 4k)."""
    return 0.5 * math.exp(num_gpus / 4800.0)


def poly_time_model(num_gpus: int) -> float:
    """Deterministic stand-in for the polynomial strategies' computation
    time (used by ``timing='modeled'``).  Calibrated to this container's
    measured MDMCF wall times (see benchmarks/bench_reconfig_time.py);
    linear in cluster size, ~60 ms at 32k nodes."""
    return 2e-6 * num_gpus


@dataclasses.dataclass(frozen=True)
class SimConfig:
    architecture: str  # cross_wiring | uniform | clos | best
    strategy: str  # mdmcf | mcf | itv_ilp | greedy | uniform_ilp | helios | none
    num_pods: int = 32
    k_spine: int = 16
    k_leaf: int = 16
    tau: int = 1
    sim_groups: int = 2  # OCS groups actually solved (demand is identical
    # across groups; measured runtime is scaled to all groups)
    timing: str = "modeled"  # modeled (deterministic) | measured (wall clock)

    @property
    def spec(self) -> ClusterSpec:
        return ClusterSpec(
            num_pods=self.num_pods,
            k_spine=self.k_spine,
            k_leaf=self.k_leaf,
            tau=self.tau,
        )

    @property
    def num_gpus(self) -> int:
        return self.spec.num_gpus


@dataclasses.dataclass
class JobRecord:
    job: Job
    start: float = math.nan
    finish: float = math.nan
    reconfig_s: float = 0.0
    min_phi: float = 1.0

    @property
    def jrt(self) -> float:
        return self.finish - self.start

    @property
    def jwt(self) -> float:
        return self.start - self.job.arrival

    @property
    def jct(self) -> float:
        return self.finish - self.job.arrival


class _Running:
    __slots__ = (
        "job", "placement", "edges", "comm_frac", "progress", "slowdown",
        "last_t", "record",
    )

    def __init__(
        self,
        job: Job,
        placement: Placement,
        edges,
        comm_frac: float,
        record: JobRecord,
    ):
        self.job = job
        self.placement = placement
        self.edges = edges
        self.comm_frac = comm_frac
        self.progress = 0.0
        self.slowdown = 1.0
        self.last_t = record.start
        self.record = record

    @property
    def pods(self) -> Dict[int, int]:
        return self.placement.pods

    def advance(self, now: float) -> None:
        if now > self.last_t:
            self.progress += (now - self.last_t) / self.slowdown
            self.last_t = now

    def remaining(self) -> float:
        return max(0.0, (self.job.service_time - self.progress)) * self.slowdown


def _place(
    free: np.ndarray, gpus_per_pod: int, need: int
) -> Optional[Dict[int, int]]:
    """Fewest-pods best-fit: single pod if possible, else pack descending."""
    if need <= 0:
        return {}
    fits = np.nonzero(free >= need)[0]
    if fits.size:
        p = int(fits[np.argmin(free[fits])])  # tightest fit
        return {p: need}
    order = np.argsort(-free)
    got: Dict[int, int] = {}
    left = need
    for p in order:
        take = int(min(free[p], left))
        if take <= 0:
            break
        got[int(p)] = take
        left -= take
        if left == 0:
            return got
    return None


class Simulator:
    def __init__(self, cfg: SimConfig, jobs: Sequence[Job], seed: int = 0):
        self.cfg = cfg
        self.spec = cfg.spec
        self.jobs = list(jobs)
        self.rng = np.random.default_rng(seed)
        self.free = np.full(cfg.num_pods, self.spec.gpus_per_pod, dtype=np.int64)
        self.running: Dict[int, _Running] = {}
        self.queue: List[Job] = []
        self.records: Dict[int, JobRecord] = {j.job_id: JobRecord(j) for j in jobs}
        self.old_config: Optional[OCSConfig] = None
        self.reconfig_calls = 0
        self.reconfig_wall = 0.0
        self.ltrr_samples: List[float] = []

    # ---- control plane -----------------------------------------------------

    def _ring_links(self, job: Job, pods: Dict[int, int]) -> int:
        """Links per ring hop so the job's DP traffic uses its port share.

        A pod in an n≥3 ring has two neighbours (degree 2·links); a 2-pod
        ring collapses to one pair (degree = links).  The job owns a
        ``frac`` share of each pod, so it may claim ``frac·K_spine`` of the
        pod's OCS ports — the paper's heavy-workload regime where logical
        topologies fully utilize pod ports (§6.2)."""
        frac = min(1.0, max(pods.values()) / self.spec.gpus_per_pod)
        degree_budget = self.cfg.k_spine * frac
        links = degree_budget if len(pods) == 2 else degree_budget / 2
        return max(1, int(round(links)))

    def _aggregate_demand(self) -> np.ndarray:
        """Clipped symmetric demand over sim_groups (identical per group)."""
        P, K, H = self.cfg.num_pods, self.cfg.k_spine, self.cfg.sim_groups
        C = np.zeros((H, P, P), dtype=np.int64)
        budget = np.full(P, K, dtype=np.int64)
        for r in self.running.values():
            ring = np.zeros((P, P), dtype=np.int64)
            for (i, j), links in r.edges.items():
                ring[i, j] += links
                ring[j, i] += links
            shave_to_budget(ring, budget)
            budget -= ring.sum(axis=1)
            C[:] += ring[None]
        return C

    def _reconfigure(self) -> Tuple[Optional[OCSConfig], float]:
        """Run the strategy; returns (config, computation seconds)."""
        st = self.cfg.strategy
        if st == "none":
            return None, 0.0
        C = self._aggregate_demand()
        spec, H_full = self.spec, self.spec.num_ocs_groups
        scale = H_full / self.cfg.sim_groups
        t0 = time.perf_counter()
        if st in ("mdmcf", "itv_ilp"):
            res = mdmcf_reconfigure(spec, C, old=self.old_config)
        elif st == "mcf":
            res = mdmcf_cold(spec, C)
        elif st == "greedy":
            res = uniform_greedy(spec, C)
        elif st == "uniform_ilp":
            res = uniform_best_effort(spec, C)
        elif st == "helios":
            res = helios_matching(spec, C)
        else:
            raise ValueError(f"unknown strategy {st!r}")
        measured = (time.perf_counter() - t0) * scale
        self.reconfig_calls += 1
        self.reconfig_wall += measured
        self.ltrr_samples.append(ltrr(res.config, C))
        if st in ("itv_ilp", "uniform_ilp"):
            comp = ilp_time_model(self.cfg.num_gpus)
        elif self.cfg.timing == "measured":
            comp = measured
        else:
            comp = poly_time_model(self.cfg.num_gpus)
        return res.config, comp

    # ---- flow model ----------------------------------------------------------

    def _comm_fraction(self, job: Job, n_pods: int, links: int) -> float:
        """Planner-derived α; legacy COMM_FRACTION only for unprofiled
        models (so external traces with custom names keep working)."""
        if job.model in dist_collectives.MODEL_PROFILES:
            return dist_demand.comm_fraction_for(
                job.model, n_pods, ep=job.ep, pp=job.pp, links=links,
                tp=job.tp,
            )
        return COMM_FRACTION.get(job.model, 0.2)

    def _refresh_slowdowns(self, now: float, config: Optional[OCSConfig]) -> None:
        flows = [
            flowsim.JobFlows(jid, r.edges, r.comm_frac)
            for jid, r in self.running.items()
        ]
        phi = flowsim.waterfill_fractions(
            self.spec, flows, config, self.cfg.architecture
        )
        for jid, r in self.running.items():
            r.advance(now)
            p = phi.get(jid, 1.0)
            r.slowdown = flowsim.job_slowdown(r.comm_frac, p)
            r.record.min_phi = min(r.record.min_phi, p)

    # ---- main loop -------------------------------------------------------------

    def run(self) -> List[JobRecord]:
        ARRIVE, FINISH = 0, 1
        ev: List[Tuple[float, int, int, int]] = []  # (t, kind, seq, job_id)
        seq = 0
        for j in self.jobs:
            heapq.heappush(ev, (j.arrival, ARRIVE, seq, j.job_id))
            seq += 1
        finish_version: Dict[int, int] = {}

        def schedule_finish(now: float, r: _Running):
            nonlocal seq
            finish_version[r.job.job_id] = seq
            heapq.heappush(ev, (now + r.remaining(), FINISH, seq, r.job.job_id))
            seq += 1

        def reschedule_all(now: float):
            for r in self.running.values():
                schedule_finish(now, r)

        def try_start(now: float) -> bool:
            """FCFS head-of-queue; returns True if a job started."""
            if not self.queue:
                return False
            job = self.queue[0]
            pods = _place(self.free, self.spec.gpus_per_pod, job.num_gpus)
            if pods is None:
                return False
            self.queue.pop(0)
            for p, n in pods.items():
                self.free[p] -= n
            links = self._ring_links(job, pods)
            # topology-aware ring ordering against the *current* OCS config
            # (minimizes uncoverable demand even before reconfiguration)
            order = dist_demand.ring_order(
                sorted(pods), self.old_config, links=links
            )
            placement = Placement(job.job_id, pods, ring_order=order)
            edges = dist_demand.job_edges(
                job.model, order, links, ep=job.ep, pp=job.pp, tp=job.tp
            )
            rec = self.records[job.job_id]
            alpha = self._comm_fraction(job, len(pods), links)
            run = _Running(job, placement, edges, alpha, rec)
            self.running[job.job_id] = run
            config, comp_s = self._reconfigure()
            rec.reconfig_s = comp_s
            rec.start = now + comp_s
            run.last_t = rec.start
            # OCS switching pause hits impacted running jobs (min-rewiring
            # keeps this set small; Table 1 shows the effect is tiny)
            if self.old_config is not None and config is not None:
                changed = config.rewiring_distance(self.old_config)
                if changed:
                    for other in self.running.values():
                        if other.job.job_id != job.job_id:
                            other.progress = max(
                                0.0, other.progress - OCS_SWITCH_S
                            )
            self.old_config = config
            self._refresh_slowdowns(max(now, rec.start), config)
            reschedule_all(max(now, rec.start))
            return True

        while ev:
            t, kind, sq, jid = heapq.heappop(ev)
            if kind == FINISH:
                if finish_version.get(jid) != sq or jid not in self.running:
                    continue  # stale event
                r = self.running.pop(jid)
                r.advance(t)
                r.record.finish = t
                for p, n in r.pods.items():
                    self.free[p] += n
                self._refresh_slowdowns(t, self.old_config)
                reschedule_all(t)
                while try_start(t):
                    pass
            else:
                self.queue.append(self.jobs[jid])
                while try_start(t):
                    pass
        return [self.records[j.job_id] for j in self.jobs]


def summarize(records: Sequence[JobRecord]) -> Dict[str, float]:
    done = [r for r in records if math.isfinite(r.finish)]
    jrt = np.array([r.jrt for r in done])
    jwt = np.array([r.jwt for r in done])
    jct = np.array([r.jct for r in done])
    service = np.array([r.job.service_time for r in done])
    return {
        "completed": len(done),
        "avg_jrt": float(jrt.mean()),
        "avg_jwt": float(jwt.mean()),
        "avg_jct": float(jct.mean()),
        "p99_jrt_slowdown": float(np.quantile(jrt / service - 1.0, 0.99)),
        "avg_jrt_slowdown": float((jrt / service - 1.0).mean()),
        "max_jwt": float(jwt.max()) if len(jwt) else 0.0,
    }
