"""Event-driven multi-tenant cluster simulator (paper §6.3).

Simulates a P-pod OCS cluster running a job trace under a chosen
(architecture × reconfiguration strategy) pair:

* placement: fewest-pods best-fit (TP in-server, EP in-pod per §3.1 — both
  invisible to the OCS core; only the DP ring crosses pods),
* on each job start the control plane recomputes the OCS configuration for
  the aggregate demand of all running jobs; the *computation time* of the
  strategy delays the job start (JWT includes it, as in the paper),
* running jobs progress under processor-sharing with per-job slowdown from
  the selected progress engine (``SimConfig.engine``): the closed-form
  max-min water-filling (``flowsim.waterfill_fractions``) or the fluid
  engine (``fluid.fluid_fractions``, which additionally zeroes circuits
  inside reconfiguration dark windows); slowdowns are re-evaluated
  whenever the running set or the OCS configuration changes.  Per-job
  communication fractions and edge demand come from the collective
  planner (``repro.dist``): dense jobs contribute a DP ring, MoE-EP jobs
  an all-to-all mesh, PP jobs a stage chain, each ring-ordered against
  the current configuration.  Inference-serving fleets
  (``Job.kind == "serve"``, see ``repro.sim.serving``) contribute
  prefill→decode KV streams instead and are priced per *request* via
  ``serving_summary``, not per job.

Strategy runtimes: polynomial algorithms (MDMCF, greedy, Helios) are
*measured* (this container's wall clock, scaled to all OCS groups); exact
ILP is *modeled* by a curve calibrated to the paper's Gurobi measurements
(435.07 s at 32k nodes, manageable below 4k — Fig. 2c/6), since no ILP
solver ships in this container.  The model is documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.incremental import (
    ColoringState,
    DeltaInfeasible,
    StaleStateError,
    mdmcf_delta,
)
from ..core.logical import Job, Placement, shave_to_budget
from ..core.reconfig import (
    ReconfigResult,
    helios_matching,
    mdmcf_cold,
    mdmcf_reconfigure,
    uniform_best_effort,
    uniform_greedy,
)
from ..core.topology import ClusterSpec, OCSConfig, demand_feasible
from ..dist import collectives as dist_collectives
from ..dist import demand as dist_demand
from ..fault import (
    CHEAPEST,
    DerateEvent,
    ExpandEvent,
    FailureEvent,
    FaultEvent,
    POLICIES,
    PortMask,
    REWIRE_AROUND,
    RepairEvent,
    SHRINK_COLLECTIVE,
    apply_event,
    masked_aggregate_demand,
    mdmcf_degraded,
    policy_costs,
    restart_cost_s,
    rollback_loss,
)
from ..fault.recover import POLICY_CAUSE, RESTART_FIXED_S, ckpt_write_s
from ..obs import attrib as obs_attrib
from ..obs import health as obs_health
from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..obs import trace as obs_trace
from ..serve import router as serve_router
from . import flowsim
from . import fluid as fluid_engine
from . import serving as serving_mod
from .trace import COMM_FRACTION

OCS_SWITCH_S = 0.1  # analytic engine's optical switching pause stand-in;
# the fluid engine prices switching as real dark windows instead
ENGINES = ("analytic", "fluid")


def ilp_time_model(num_gpus: int) -> float:
    """Calibrated Gurobi-ILP runtime (paper Fig. 2c: 435.07 s at 32k nodes,
    ~exponential growth, manageable below 4k)."""
    return 0.5 * math.exp(num_gpus / 4800.0)


def poly_time_model(num_gpus: int, incremental: bool = False) -> float:
    """Deterministic stand-in for the polynomial strategies' computation
    time (used by ``timing='modeled'``).  Calibrated to this container's
    measured MDMCF wall times (benchmarks/bench_reconfig_time.py; see
    EXPERIMENTS.md §Control-plane performance): the vectorized warm cold
    solve runs ~2e-6 s/GPU (~64 ms at 32k nodes, P=128, H=16), and the
    incremental delta path (``mdmcf_delta`` on a single-job change)
    ~1.6e-7 s/GPU (~5 ms at 32k) — the rate charged when the scheduler's
    ColoringState served the event."""
    if incremental:
        return 1.6e-7 * num_gpus
    return 2e-6 * num_gpus


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Immutable description of one simulated (cluster × policy) run.

    The first two fields pick the paper's comparison axes: the physical
    ``architecture`` (``cross_wiring`` | ``uniform`` | ``clos`` | ``best``)
    and the reconfiguration ``strategy`` computing logical→physical
    mappings (``mdmcf`` | ``mcf`` | ``itv_ilp`` | ``greedy`` |
    ``uniform_ilp`` | ``helios`` | ``none``).  ``num_pods`` / ``k_spine``
    / ``k_leaf`` / ``tau`` size the :class:`~repro.core.topology.
    ClusterSpec`; the remaining fields select control-plane behaviour
    (``incremental`` delta solving, ``timing`` model), the progress
    ``engine`` (analytic closed form vs event-driven fluid with
    ``reconfig_delay_s`` dark windows), the resilience policy
    (``recovery_policy`` / ``ckpt_interval_s`` / ``active_pods``), and the
    serving SLO (``serving_slo`` × the ideal KV transfer time counts as
    served; ``serving_period_s`` is the diurnal period shared by the
    arrival process and autoscale schedules).

    >>> cfg = SimConfig("cross_wiring", "mdmcf", num_pods=4, k_spine=4,
    ...                 k_leaf=4)
    >>> (cfg.num_gpus, cfg.spec.gpus_per_pod)
    (64, 16)
    """

    architecture: str  # cross_wiring | uniform | clos | best
    strategy: str  # mdmcf | mcf | itv_ilp | greedy | uniform_ilp | helios | none
    num_pods: int = 32
    k_spine: int = 16
    k_leaf: int = 16
    tau: int = 1
    sim_groups: int = 2  # OCS groups actually solved (demand is identical
    # across groups; measured runtime is scaled to all groups)
    timing: str = "modeled"  # modeled (deterministic) | measured (wall clock)
    incremental: bool = True  # carry ColoringState between events and patch
    # the decomposition with mdmcf_delta (cold-solving only on mask changes
    # or budget-exceeding demand); False = cold-solve every event
    # ---- progress engine (repro.sim.fluid) -------------------------------
    engine: str = "analytic"  # analytic (closed-form snapshot stretch) |
    # fluid (event-driven max-min fluid flows with reconfiguration dark
    # windows; see sim/fluid.py)
    reconfig_delay_s: float = 0.0  # OCS retune time: circuits changed by a
    # reconfiguration carry zero bandwidth this long (fluid engine only;
    # the analytic engine keeps the legacy OCS_SWITCH_S progress pause)
    # ---- resilience (repro.fault) ---------------------------------------
    recovery_policy: str = REWIRE_AROUND  # | shrink_collective |
    # ckpt_restart | cheapest (per-victim argmin of the fluid-priced costs)
    ckpt_interval_s: float = 1800.0  # checkpoint cadence for ckpt_restart
    active_pods: Optional[int] = None  # initially populated pods (expansion
    # scenarios; None → all num_pods live from t=0)
    # ---- inference serving (repro.sim.serving) ---------------------------
    serving_slo: float = 4.0  # a request is "served" when its KV-transfer
    # latency stays within serving_slo × the ideal (φ=1) transfer time
    serving_period_s: float = 86400.0  # diurnal period of serving load
    # (shared by request arrivals and scripted autoscale schedules)
    router: Optional[str] = None  # request-routing policy for serving
    # fleets (repro.serve.router.POLICIES).  None = legacy pooled
    # placement, byte-identical to the pre-router simulator; a policy
    # name gives every fleet per-decode-pod φ accounting, per-request
    # placement in serving_summary, and (topology_aware) router-shaped
    # KV demand
    # ---- observability (repro.obs) ---------------------------------------
    tracer: Optional[obs_trace.NullTracer] = dataclasses.field(
        default=None, compare=False, repr=False
    )  # span/event tracer on simulated time (None = tracing off; the
    # tracer is passive, so traces/goldens are byte-identical either way)
    on_health: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False
    )  # HealthEvent subscription hook: a callable(HealthEvent) invoked
    # on every streaming-detector firing (repro.obs.health).  Setting it
    # (or attaching a tracer) activates the in-loop HealthMonitor.  The
    # hook itself is passive; a subscriber that additionally exposes
    # ``bind(sim)`` (repro.fault.remediate.RemediationEngine) is given
    # the simulator handle and may close the loop by scheduling
    # remediation actions (``Simulator.schedule_action``)

    def __post_init__(self) -> None:
        if self.recovery_policy not in POLICIES:
            raise ValueError(f"recovery_policy must be one of {POLICIES}")
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}")
        if self.reconfig_delay_s < 0:
            raise ValueError("reconfig_delay_s must be >= 0")
        if self.router is not None and self.router not in serve_router.POLICIES:
            raise ValueError(
                f"router must be None or one of {serve_router.POLICIES}"
            )

    @property
    def spec(self) -> ClusterSpec:
        return ClusterSpec(
            num_pods=self.num_pods,
            k_spine=self.k_spine,
            k_leaf=self.k_leaf,
            tau=self.tau,
        )

    @property
    def num_gpus(self) -> int:
        return self.spec.num_gpus


@dataclasses.dataclass
class JobRecord:
    """Per-job outcome of a simulated run: start/finish timestamps (JRT =
    finish − start, JWT = start − arrival, JCT = finish − arrival),
    control-plane time charged to the job (``reconfig_s``), the worst
    realized bandwidth fraction it saw (``min_phi``), and its resilience
    history (restarts / shrinks / rolled-back seconds).  ``finish`` stays
    NaN for jobs still running at the horizon — serving fleets always,
    training jobs when ``run(until=...)`` cut them off."""

    job: Job
    start: float = math.nan
    finish: float = math.nan
    reconfig_s: float = 0.0
    min_phi: float = 1.0
    restarts: int = 0  # times the job was killed and requeued (pod failure)
    shrinks: int = 0  # times the job dropped a failed pod and continued
    lost_s: float = 0.0  # service-seconds of progress lost to rollbacks

    @property
    def jrt(self) -> float:
        return self.finish - self.start

    @property
    def jwt(self) -> float:
        return self.start - self.job.arrival

    @property
    def jct(self) -> float:
        return self.finish - self.job.arrival


class _Running:
    __slots__ = (
        "job", "placement", "edges", "comm_frac", "progress", "slowdown",
        "last_t", "record", "compute_scale", "cur_gpus", "ckpt_progress",
        "prefill_pods", "decode_pods", "kv_links", "replica_gpus",
        "router",
    )

    def __init__(
        self,
        job: Job,
        placement: Placement,
        edges,
        comm_frac: float,
        record: JobRecord,
        start_t: Optional[float] = None,
    ):
        self.job = job
        self.placement = placement
        self.edges = edges
        self.comm_frac = comm_frac
        self.progress = 0.0
        self.slowdown = 1.0
        self.last_t = record.start if start_t is None else start_t
        self.record = record
        # shrink-collective state: GPUs still alive and the resulting
        # compute stretch (service_time is calibrated to num_gpus)
        self.cur_gpus = job.num_gpus
        self.compute_scale = 1.0
        # progress floor guaranteed by an explicit (pre-emptive)
        # checkpoint — a restart never rolls back below this point
        self.ckpt_progress = 0.0
        # serving-fleet state (kind == "serve"): disaggregated pools and
        # the per-pod link budget its KV flows were sized with
        self.prefill_pods: List[int] = []
        self.decode_pods: List[int] = []
        self.kv_links = 0
        self.replica_gpus = 0
        # per-fleet request router (None = legacy pooled placement)
        self.router: Optional[serve_router.Router] = None

    @property
    def pods(self) -> Dict[int, int]:
        return self.placement.pods

    def advance(self, now: float) -> None:
        if now > self.last_t:
            self.progress += (now - self.last_t) / self.slowdown
            self.last_t = now

    def remaining(self) -> float:
        return max(0.0, (self.job.service_time - self.progress)) * self.slowdown


def _place(
    free: np.ndarray, gpus_per_pod: int, need: int
) -> Optional[Dict[int, int]]:
    """Fewest-pods best-fit: single pod if possible, else pack descending."""
    if need <= 0:
        return {}
    fits = np.nonzero(free >= need)[0]
    if fits.size:
        p = int(fits[np.argmin(free[fits])])  # tightest fit
        return {p: need}
    order = np.argsort(-free)
    got: Dict[int, int] = {}
    left = need
    for p in order:
        take = int(min(free[p], left))
        if take <= 0:
            break
        got[int(p)] = take
        left -= take
        if left == 0:
            return got
    return None


def _split_pools(
    pods: Dict[int, int], prefill_frac: float
) -> Tuple[List[int], List[int]]:
    """Partition a serving fleet's pods into (prefill, decode) pools.

    Walks pods in id order accumulating GPUs until the prefill share is
    covered; both pools are non-empty whenever the fleet spans ≥ 2 pods
    (a single-pod fleet keeps its KV traffic on the electrical fabric)."""
    order = sorted(pods)
    if len(order) < 2:
        return order, []
    want = prefill_frac * sum(pods.values())
    prefill: List[int] = []
    got = 0
    for p in order[:-1]:  # always leave ≥ 1 pod for decode
        prefill.append(p)
        got += pods[p]
        if got >= want:
            break
    taken = set(prefill)  # O(1) membership: fleets can span many pods
    return prefill, [p for p in order if p not in taken]


class Simulator:
    """Event-driven multi-tenant cluster simulator (see module docstring).

    Drives the trace in ``jobs`` (training jobs and serving fleets; list
    position must equal ``job_id``) under ``cfg``'s architecture ×
    strategy × engine, applying the optional ``fault_events`` stream
    (failures/repairs/expansion from :mod:`repro.fault`, plus serving
    :class:`~repro.sim.serving.ScaleEvent` autoscaling).  ``run()``
    returns per-job :class:`JobRecord`\\ s; ``fault_summary()`` and
    ``serving_summary()`` aggregate goodput/availability and
    request-latency metrics.  Deterministic given ``seed``.
    """

    def __init__(
        self,
        cfg: SimConfig,
        jobs: Sequence[Job],
        seed: int = 0,
        fault_events: Optional[Sequence[FaultEvent]] = None,
    ):
        self.cfg = cfg
        self.spec = cfg.spec
        self.jobs = list(jobs)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.free = np.full(cfg.num_pods, self.spec.gpus_per_pod, dtype=np.int64)
        self.running: Dict[int, _Running] = {}
        self.queue: List[Job] = []
        self.records: Dict[int, JobRecord] = {j.job_id: JobRecord(j) for j in jobs}
        self.old_config: Optional[OCSConfig] = None
        self.events = 0  # heap events processed (bench_control_plane metric)
        # ---- observability (repro.obs): the tracer handle is a no-op
        # NullTracer when disabled (one attribute read per would-be event);
        # every counter/series the summaries report lives on one metrics
        # registry instead of parallel ad-hoc stores, with thin property
        # views (reconfig_calls, policy_decisions, …) keeping the public
        # shapes unchanged
        self.trace = cfg.tracer if cfg.tracer is not None else obs_trace.NULL
        m = self.metrics = obs_metrics.MetricsRegistry()
        self._c_reconfigs = m.counter("control.reconfigs")
        self._c_delta = m.counter("control.delta_calls")
        self._c_wall = m.counter("control.solver_wall_s")
        self._h_wall = m.histogram("control.solver_wall", lo=1e-7, hi=1e3)
        self._s_ltrr = m.series("control.ltrr")
        self._c_fail = m.counter("faults.failures")
        self._c_repair = m.counter("faults.repairs")
        self._c_expand = m.counter("faults.expands")
        self._c_restarts = m.counter("faults.restarts")
        self._c_shrinks = m.counter("faults.shrinks")
        self._c_lost = m.counter("faults.lost_gpu_s")
        self._s_policy = m.series("policy.decisions")
        self._c_scale_ok = m.counter("serving.autoscale_applied")
        self._c_scale_skip = m.counter("serving.autoscale_skipped")
        self._c_dt_events = m.counter("downtime.events")
        self._c_dt_s = m.counter("downtime.s")
        self._c_dt_circ = m.counter("downtime.circuit_s")
        self._c_fallbacks = m.counter("control.solver_fallbacks")
        self._c_derate = m.counter("faults.derates")
        self._phi = m.timeline("serving.phi")
        self._requests_traced: set = set()  # job ids with request spans out
        # ---- attribution + health (repro.obs.attrib / .health) -----------
        # the AttribLog records what blame replay needs (solve/dark/
        # degraded intervals, per-job rate breakpoints, stints, lost
        # work); the HealthMonitor runs streaming detectors inside the
        # event loop — both passive, results never change
        self.attrib = obs_attrib.AttribLog()
        self.health: Optional[obs_health.HealthMonitor] = None
        if cfg.on_health is not None or self.trace.enabled:
            self.health = obs_health.HealthMonitor(
                slo=cfg.serving_slo,
                on_event=cfg.on_health,  # type: ignore[arg-type]
                tracer=self.trace,
            )
        if self.health is not None and hasattr(cfg.on_health, "bind"):
            # closed-loop subscriber (repro.fault.remediate): hand the
            # engine its actuator handle before any detector can fire
            cfg.on_health.bind(self)  # type: ignore[union-attr]
        # ---- incremental control plane (repro.core.incremental) ----------
        self._coloring_state: Optional[ColoringState] = None
        self._last_incremental = False
        self._last_fallback: Optional[str] = None  # delta-path exception name
        self._last_rewired: Optional[int] = None  # Σ|Δx| of the last solve
        self._solver_degraded_until = -math.inf  # remediation escalation:
        # while now ≤ this, solves skip the delta path and state rebuilds
        # ---- resilience state (repro.fault) ------------------------------
        self.mask = PortMask(cfg.num_pods, cfg.k_spine, cfg.sim_groups)
        if cfg.active_pods is not None:
            self.mask.set_active_count(cfg.active_pods)
            self.free[cfg.active_pods:] = 0
        if not self.mask.is_trivial():
            # expansion scenario: capacity-limited from t = 0 — blame
            # replay treats the whole pre-expansion era as degraded
            self.attrib.degraded_begin(0.0)
        self.fault_events: List[FaultEvent] = sorted(
            fault_events or [], key=lambda e: e.time
        )
        self.carry_progress: Dict[int, float] = {}  # jid → progress kept
        self._actions: List[Tuple[float, object, str]] = []  # deferred
        # remediation actions, drained into the event heap as ACTION
        # events (health hooks fire mid-refresh; mutating there would
        # corrupt the in-flight refresh — see schedule_action)
        # ---- serving state (repro.sim.serving) ---------------------------
        self._serving_work: Dict[int, Tuple[float, float]] = {}  # jid →
        # (work_s at φ=1, alpha_s), frozen at first start for the latency
        # integration (pool reshapes show up through φ, not the stripe)
        # ---- request routing (repro.serve.router) ------------------------
        # replay inputs the router needs after the run: decode-pool
        # membership history, and per-pod cordoned-slot counts; per-pod
        # φ lands in the shared timeline under (jid, pod) keys.  All
        # three stay empty when cfg.router is None, so pooled runs keep
        # their exact pre-router metric surface
        self._routers: Dict[int, serve_router.Router] = {}
        self._pool_log: Dict[int, List[Tuple[float, Tuple[int, ...]]]] = {}
        self._cordon_log: Dict[int, List[Tuple[float, float]]] = {}
        self._routing_counted: set = set()  # jids with routing.* counted
        # ---- fluid engine state (repro.sim.fluid) ------------------------
        self._dark = fluid_engine.DarkWindows()  # circuits retuning now
        self._pod_down_since: Dict[int, float] = {}
        self._gpu_down_s = 0.0  # GPU-seconds pods spent failed
        self._cap_t = 0.0  # capacity integral (expansion-aware)
        self._cap_gpus = int(self.mask.active.sum()) * self.spec.gpus_per_pod
        self._cap_gpu_s = 0.0
        self._end_time = 0.0

    # ---- registry views (public shapes preserved; storage = repro.obs) ----

    @property
    def reconfig_calls(self) -> int:
        return self._c_reconfigs.value

    @property
    def reconfig_wall(self) -> float:
        return self._c_wall.value

    @property
    def delta_calls(self) -> int:
        return self._c_delta.value

    @property
    def ltrr_samples(self) -> List[float]:
        return self._s_ltrr.data

    @property
    def fault_counts(self) -> Dict[str, int]:
        return {
            "failures": self._c_fail.value,
            "repairs": self._c_repair.value,
            "expands": self._c_expand.value,
        }

    @property
    def restarts(self) -> int:
        return self._c_restarts.value

    @property
    def shrinks(self) -> int:
        return self._c_shrinks.value

    @property
    def lost_gpu_s(self) -> float:
        return self._c_lost.value

    @property
    def policy_decisions(self) -> List[Dict[str, object]]:
        return self._s_policy.data

    @property
    def autoscale_applied(self) -> int:
        return self._c_scale_ok.value

    @property
    def autoscale_skipped(self) -> int:
        return self._c_scale_skip.value

    @property
    def solver_fallbacks(self) -> int:
        """Delta-path fallbacks silently absorbed as cold solves (every
        StaleStateError / DeltaInfeasible the incremental plane ate)."""
        return self._c_fallbacks.value

    @property
    def downtime_events(self) -> int:
        return self._c_dt_events.value

    @property
    def downtime_s(self) -> float:
        return self._c_dt_s.value

    @property
    def downtime_circuit_s(self) -> float:
        return self._c_dt_circ.value

    @property
    def phi_timeline(self) -> obs_metrics.Timeline:
        """Per-serving-job realized-φ breakpoints — a
        :class:`repro.obs.metrics.Timeline` (dict-of-lists read API)."""
        return self._phi

    def _mask_arg(self) -> Optional[PortMask]:
        """The mask handed to strategies: None while fully healthy, so the
        healthy path stays byte-for-byte identical to the fault-free sim."""
        return None if self.mask.is_trivial() else self.mask

    # ---- control plane -----------------------------------------------------

    def _ring_links(self, job: Job, pods: Dict[int, int]) -> int:
        """Links per ring hop so the job's DP traffic uses its port share.

        A pod in an n≥3 ring has two neighbours (degree 2·links); a 2-pod
        ring collapses to one pair (degree = links).  The job owns a
        ``frac`` share of each pod, so it may claim ``frac·K_spine`` of the
        pod's OCS ports — the paper's heavy-workload regime where logical
        topologies fully utilize pod ports (§6.2)."""
        frac = min(1.0, max(pods.values()) / self.spec.gpus_per_pod)
        degree_budget = self.cfg.k_spine * frac
        links = degree_budget if len(pods) == 2 else degree_budget / 2
        return max(1, int(round(links)))

    def _aggregate_demand(self) -> np.ndarray:
        """Clipped symmetric demand over sim_groups (identical per group
        while healthy; per-group once the mask degrades budgets)."""
        P, K, H = self.cfg.num_pods, self.cfg.k_spine, self.cfg.sim_groups
        mask = self._mask_arg()
        if mask is None:
            # healthy demand is identical across groups: accumulate one
            # (P, P) plane and materialize the (H, P, P) tensor once
            acc = np.zeros((P, P), dtype=np.int64)
            budget = np.full(P, K, dtype=np.int64)
            ring = np.empty((P, P), dtype=np.int64)
            for r in self.running.values():
                if not r.edges:
                    continue
                ring[:] = 0
                ei = np.fromiter(
                    (v for e in r.edges for v in e), dtype=np.int64
                ).reshape(-1, 2)
                w = np.fromiter(r.edges.values(), dtype=np.int64)
                np.add.at(ring, (ei[:, 0], ei[:, 1]), w)
                np.add.at(ring, (ei[:, 1], ei[:, 0]), w)
                shave_to_budget(ring, budget)
                budget -= ring.sum(axis=1)
                acc += ring
            return np.repeat(acc[None], H, axis=0)
        # port-granular upper bound for every architecture: strategies do
        # their own structural degradation (clean-pair core + salvage for
        # Cross Wiring, shrunken matchings for Uniform); what they cannot
        # realize surfaces as phi < 1 in the flow model
        return masked_aggregate_demand(
            P, H, [r.edges for r in self.running.values()], mask
        )

    def _solve_mdmcf(
        self, now: float, C: np.ndarray, mask: Optional[PortMask]
    ) -> ReconfigResult:
        """ITV-MDMCF with a persistent :class:`ColoringState`.

        While the mask is unchanged and the demand fits the state's budget,
        each event is served by :func:`mdmcf_delta` — O(|demand delta|).
        Mask changes (stale state) or budget-exceeding demand fall back to
        a cold solve; the state is rebuilt from it when the cold solve is
        the exact clean-pair construction (``mdmcf_degraded``'s salvage
        output has no adoptable coloring, so degraded events stay cold).

        Every swallowed fallback is counted (``control.solver_fallbacks``)
        and fed to the HealthMonitor — repeated fallbacks mean the delta
        path has stopped serving events, and the remediation engine may
        escalate (:meth:`escalate_solver`): inside the escalation window
        solves go straight to the degraded-mode path, paying one
        predictable price instead of retry-then-cold thrash.
        """
        self._last_incremental = False
        if now <= self._solver_degraded_until:
            self._coloring_state = None
            if mask is None:
                return mdmcf_reconfigure(self.spec, C, old=self.old_config)
            return mdmcf_degraded(self.spec, C, old=self.old_config, mask=mask)
        if not self.cfg.incremental:
            self._coloring_state = None
            if mask is None:
                return mdmcf_reconfigure(self.spec, C, old=self.old_config)
            return mdmcf_degraded(self.spec, C, old=self.old_config, mask=mask)
        state = self._coloring_state
        if state is not None:
            try:
                # healthy aggregate demand is shaved + symmetric by
                # construction, and the emitted config's sub-permutation
                # property holds by the state invariants — skip both
                # O(H·K·P²) re-checks on the hot path
                res = mdmcf_delta(
                    self.spec,
                    state,
                    C,
                    mask=mask,
                    validate=False,
                    check_feasible=mask is not None,
                )
                self._last_incremental = True
                self._c_delta.inc()
                return res
            except (StaleStateError, DeltaInfeasible) as err:
                # delta path lost its state: record the reason — the
                # incremental-fallback rate is a first-class health metric
                self._last_fallback = type(err).__name__
                self.metrics.counter(
                    f"control.fallback.{self._last_fallback}"
                ).inc()
                self._c_fallbacks.inc()
                if self.trace.enabled:
                    self.trace.instant(
                        "health", "fallback", ts=now,
                        reason=self._last_fallback,
                    )
                if self.health is not None:
                    self.health.observe_fallback(now, self._last_fallback)
                self._coloring_state = None
        if mask is not None and not demand_feasible(C, self.spec, mask=mask):
            # beyond the clean-pair budget: graceful degradation, no state
            return mdmcf_degraded(self.spec, C, old=self.old_config, mask=mask)
        res = mdmcf_reconfigure(self.spec, C, old=self.old_config, mask=mask)
        self._coloring_state = ColoringState.from_config(
            self.spec, res.demand, res.config, mask=mask
        )
        return res

    def _reconfigure(self, now: float = 0.0) -> Tuple[Optional[OCSConfig], float]:
        """Run the strategy; returns (config, computation seconds)."""
        st = self.cfg.strategy
        if st == "none":
            return None, 0.0
        C = self._aggregate_demand()
        spec, H_full = self.spec, self.spec.num_ocs_groups
        scale = H_full / self.cfg.sim_groups
        mask = self._mask_arg()
        tr = self.trace
        self._last_fallback = None
        ambient_set = False
        if tr.enabled:
            # deep layers (core/incremental, core/reconfig, fault/recover)
            # emit through the ambient handle during this solve
            tr.sim_now = now
            obs_trace.set_ambient(tr)
            ambient_set = True
        t0 = time.perf_counter()
        try:
            if st in ("mdmcf", "itv_ilp"):
                res = self._solve_mdmcf(now, C, mask)
            elif st == "mcf":
                if mask is None:
                    res = mdmcf_cold(spec, C)
                else:
                    res = mdmcf_degraded(spec, C, old=None, mask=mask)
            elif st == "greedy":
                res = uniform_greedy(spec, C, mask=mask)
            elif st == "uniform_ilp":
                res = uniform_best_effort(spec, C, mask=mask)
            elif st == "helios":
                res = helios_matching(spec, C, mask=mask)
            else:
                raise ValueError(f"unknown strategy {st!r}")
        finally:
            if ambient_set:
                obs_trace.set_ambient(None)
        measured = (time.perf_counter() - t0) * scale
        self._c_reconfigs.inc()
        self._c_wall.inc(measured)
        self._h_wall.observe(measured)
        # mdmcf_delta already knows its Σ|Δx|; saves an O(H·K·P²) compare
        self._last_rewired = getattr(res, "rewired", None)
        lt = res.ltrr
        self._s_ltrr.append(lt)
        if st in ("itv_ilp", "uniform_ilp"):
            comp = ilp_time_model(self.cfg.num_gpus)
        elif self.cfg.timing == "measured":
            comp = measured
        else:
            comp = poly_time_model(
                self.cfg.num_gpus, incremental=self._last_incremental
            )
        if tr.enabled:
            # span dur is the *modeled* computation time — simulated, so
            # the trace stays deterministic under timing='modeled'
            tr.span(
                "solve",
                "mdmcf_delta" if self._last_incremental else st,
                ts=now,
                dur=comp,
                strategy=st,
                incremental=self._last_incremental,
                rewired=self._last_rewired,
                ltrr=round(lt, 9),
                fallback=self._last_fallback,
                degraded=mask is not None,
                jobs=len(self.running),
            )
        return res.config, comp

    # ---- flow model ----------------------------------------------------------

    def _comm_fraction(self, job: Job, n_pods: int, links: int) -> float:
        """Planner-derived α; legacy COMM_FRACTION only for unprofiled
        models (so external traces with custom names keep working)."""
        if job.model in dist_collectives.MODEL_PROFILES:
            return dist_demand.comm_fraction_for(
                job.model, n_pods, ep=job.ep, pp=job.pp, links=links,
                tp=job.tp,
            )
        return COMM_FRACTION.get(job.model, 0.2)

    def _pair_cap_arg(self, config: Optional[OCSConfig]):
        """Gray-failure capacity override for the flow engines: the
        mask's health-weighted per-pair capacity when any link runs
        derated, None otherwise — so the all-healthy path stays
        byte-identical to the pre-gray model."""
        if config is None or not self.mask.has_gray():
            return None
        return self.mask.effective_pair_capacity(config)

    def _refresh_slowdowns(self, now: float, config: Optional[OCSConfig]) -> None:
        # routed serving fleets are decomposed into one sub-flow per
        # decode pod (repro.serve.router.partition_edges), so every pod
        # gets its own φ timeline — the signal topology-aware routing
        # scores by.  Unrouted jobs keep the exact legacy single-flow
        # path (pooled runs stay byte-identical)
        flows = []
        routed: List[Tuple[int, _Running, Dict]] = []
        for jid, r in self.running.items():
            if r.router is not None and r.decode_pods and r.edges:
                parts = serve_router.partition_edges(r.edges, r.decode_pods)
                for pod, pe in sorted(parts.items()):
                    flows.append(
                        flowsim.JobFlows((jid, pod), pe, r.comm_frac)
                    )
                routed.append((jid, r, parts))
            else:
                flows.append(flowsim.JobFlows(jid, r.edges, r.comm_frac))
        cap = self.spec.slowdown_cap
        pcap = self._pair_cap_arg(config)
        if self.cfg.engine == "fluid":
            phi = fluid_engine.fluid_fractions(
                self.spec, flows, config, self.cfg.architecture,
                dark_pairs=self._dark.active(now), cap=cap, pair_cap=pcap,
            )
        else:
            phi = flowsim.waterfill_fractions(
                self.spec, flows, config, self.cfg.architecture,
                pair_cap=pcap,
            )
        routed_ids = {jid for jid, _, _ in routed}
        for jid, r in self.running.items():
            r.advance(now)
            if jid in routed_ids:
                continue  # per-pod accounting below
            p = phi.get(jid, 1.0)
            # compute_scale > 1 after shrink-collective: fewer GPUs do the
            # same work, on top of any communication stretch
            r.slowdown = r.compute_scale * flowsim.job_slowdown(
                r.comm_frac, p, cap=cap
            )
            r.record.min_phi = min(r.record.min_phi, p)
            if r.job.kind == "serve":
                self._phi_point(now, jid, p)
            else:
                # blame replay integrates exactly these breakpoints —
                # the progress-rate twin of the serving φ timeline
                self.attrib.rate.point(jid, now, 1.0 / r.slowdown)
        for jid, r, parts in routed:
            pod_phi = []
            for p in r.decode_pods:
                # a pod the router-shaped demand starved of circuits
                # (weight 0: cordoned) has no sub-flow and no bandwidth
                pp = float(phi.get((jid, p), 1.0)) if p in parts else 0.0
                pod_phi.append(pp)
                self._phi.point((jid, p), now, pp)
            # fleet-level φ = worst pod: the timeline blame replay and
            # the health monitor integrate (conservative aggregate — a
            # single struggling pod is exactly what they should see)
            pf = min(pod_phi) if pod_phi else 1.0
            r.slowdown = r.compute_scale * flowsim.job_slowdown(
                r.comm_frac, pf, cap=cap
            )
            r.record.min_phi = min(r.record.min_phi, pf)
            self._phi_point(now, jid, pf)

    def _phi_point(self, t: float, jid: int, phi: float) -> None:
        """Append a (t, φ) breakpoint to a serving job's realized-bandwidth
        timeline (``serving.request_latencies`` integrates it).  Storage is
        one :class:`repro.obs.metrics.Timeline` — the same class backing
        the standalone engine's ``FluidSim.phi_history``, so the two views
        cannot diverge; monotonization (a start refresh can run slightly
        ahead of the event clock) lives in :meth:`Timeline.point`."""
        self._phi.point(jid, t, phi)
        if self.health is not None:
            self.health.observe_phi(t, jid, phi)

    # ---- serving fleets (repro.sim.serving) ------------------------------

    def _serving_links(self, job: Job, pods: Dict[int, int]) -> int:
        """Per-pod spine-port budget of a serving fleet's KV flows.  Unlike
        a ring (two neighbours share the degree), the prefill→decode
        bipartite pattern uses the full degree budget of the job's port
        share."""
        frac = min(1.0, max(pods.values()) / self.spec.gpus_per_pod)
        return max(1, int(round(self.cfg.k_spine * frac)))

    def _rate_at(self, job: Job, now: float) -> float:
        """Instantaneous offered request rate of a serving fleet — the
        diurnal swell of :func:`~repro.sim.serving.serving_trace` applied
        to the mean rate, so demand re-statements at event time (start,
        autoscale, shrink) carry crest-hour load at the crest rather than
        the flat mean."""
        if job.diurnal <= 0.0:
            return job.req_rate
        phase = 2 * math.pi * (now - job.arrival) / self.cfg.serving_period_s
        return job.req_rate * (1.0 + job.diurnal * math.sin(phase))

    def _phi_last(self, key, default: float = 1.0) -> float:
        """Last recorded value of one φ timeline (1.0 before any point)."""
        tl = self._phi.get(key, ())
        return float(tl[-1][1]) if len(tl) else default

    def _kv_edges(self, r: _Running, now: float):
        weights = None
        if r.router is not None and r.decode_pods:
            jid = r.job.job_id
            weights = r.router.demand_weights(
                r.decode_pods,
                {p: self._phi_last((jid, p)) for p in r.decode_pods},
                {
                    p: int(self.mask.cordoned[:, :, p].sum())
                    for p in r.decode_pods
                },
            )
            if weights is not None and self.trace.enabled:
                self.trace.instant(
                    "router", "demand_weights", ts=now, job_id=jid,
                    weights={
                        str(p): round(w, 4)
                        for p, w in sorted(weights.items())
                    },
                )
        return dist_demand.serving_edges(
            r.job.model, r.prefill_pods, r.decode_pods, r.kv_links,
            self._rate_at(r.job, now), r.job.kv_tokens,
            weights=weights,
        )

    def _log_pool(self, t: float, r: _Running) -> None:
        """Record a decode-pool membership breakpoint — the router's
        replay input.  Every pool mutation (start, autoscale, failure
        shrink, remediation drain) appends one entry."""
        if r.router is not None:
            self._pool_log.setdefault(r.job.job_id, []).append(
                (t, tuple(r.decode_pods))
            )
            if self.trace.enabled:
                self.trace.instant(
                    "router", "pool", ts=t, job_id=r.job.job_id,
                    decode_pods=list(r.decode_pods),
                )

    def _start_serving(
        self, job: Job, pods: Dict[int, int], rec: JobRecord, start_t: float
    ) -> _Running:
        """Bring a serving fleet up on ``pods``: split prefill/decode
        pools, size the KV migration flows, and freeze the per-request
        transfer work the latency integration uses.  α = 1 — a serving
        flow *is* its communication, so its progress integrates delivered
        bandwidth (∫φ dt)."""
        placement = Placement(job.job_id, pods, ring_order=tuple(sorted(pods)))
        run = _Running(job, placement, {}, 0.0, rec, start_t=start_t)
        run.prefill_pods, run.decode_pods = _split_pools(
            pods, job.prefill_frac
        )
        run.kv_links = self._serving_links(job, pods)
        run.replica_gpus = (
            max(1, sum(pods[p] for p in run.decode_pods)
                // max(1, len(run.decode_pods)))
            if run.decode_pods else self.spec.gpus_per_pod
        )
        if self.cfg.router is not None:
            run.router = self._routers.get(job.job_id)
            if run.router is None:
                run.router = serve_router.Router(
                    self.cfg.router, seed=(self.seed, job.job_id)
                )
                self._routers[job.job_id] = run.router
            self._log_pool(start_t, run)
        run.edges = self._kv_edges(run, start_t)
        ab = dist_collectives.AlphaBeta()
        if run.edges:
            run.comm_frac = 1.0
            stripe = max(run.edges.values())
            work = serving_mod.request_work_s(
                job.model, job.kv_tokens, links=stripe, ab=ab
            )
            alpha_s = ab.alpha_cross_pod
        else:  # single-pod fleet: KV moves on the electrical fabric
            work = (
                job.kv_tokens * dist_demand.kv_bytes_per_token(job.model)
                * ab.beta_in_pod
            )
            alpha_s = ab.alpha_in_pod
        if work <= 0:
            # zero-byte KV stream (no model profile / kv_tokens=0): every
            # latency metric would be silently meaningless
            raise ValueError(
                f"serving job {job.job_id} ({job.model!r}) has no KV "
                "payload — use serving.serving_job / a profiled model"
            )
        self._serving_work.setdefault(job.job_id, (work, alpha_s))
        return run

    def _apply_scale(self, now: float, ev: "serving_mod.ScaleEvent") -> None:
        """Autoscale a running serving fleet's decode pool.  The PortMask
        is untouched, so the reconfiguration that follows is a pure demand
        delta — served by ``mdmcf_delta``, not a cold solve."""
        r = self.running.get(ev.job_id)
        if r is None or r.job.kind != "serve":
            self._c_scale_skip.inc()
            return
        changed = 0
        if ev.pods > 0:
            up = self.mask.pod_up()
            need = r.replica_gpus
            for _ in range(ev.pods):
                cand = [
                    p for p in range(self.cfg.num_pods)
                    if up[p] and p not in r.pods and self.free[p] >= need
                ]
                if not cand:
                    break
                p = min(cand, key=lambda q: (self.free[q], q))  # tightest
                self.free[p] -= need
                r.pods[p] = need
                r.decode_pods.append(p)
                r.cur_gpus += need
                changed += 1
        else:
            for _ in range(-ev.pods):
                if len(r.decode_pods) <= 1:
                    break  # never drain the last decode replica
                p = r.decode_pods.pop()
                n = r.pods.pop(p)
                self.free[p] += n
                r.cur_gpus -= n
                changed += 1
        want = abs(ev.pods)
        self._c_scale_ok.inc(changed)
        self._c_scale_skip.inc(want - changed)
        if self.trace.enabled:
            self.trace.instant(
                "fault", "autoscale", ts=now,
                job_id=ev.job_id, pods=ev.pods, applied=changed,
            )
        if changed == 0:
            return
        self._log_pool(now, r)
        r.edges = self._kv_edges(r, now)

    def _shrink_serving(self, now: float, r: _Running, pod: int) -> None:
        """A pod failure hit a serving fleet: drop the pod from its pool
        and keep serving on the survivors.  A wiped pool is re-seeded
        from the other one (a decode pod promotes to prefill and vice
        versa) so a multi-pod fleet always keeps both stages — losing a
        whole pool must surface as rebuilt/degraded KV flows, never as a
        silently-perfect φ = 1.  A fleet reduced to nothing goes dark —
        its timeline ends at φ = 0 and every later request waits forever
        (counted against goodput)."""
        lost = r.pods.pop(pod)
        self.free[pod] += lost
        r.cur_gpus = max(0, r.cur_gpus - lost)
        if pod in r.decode_pods:
            r.decode_pods.remove(pod)
        if pod in r.prefill_pods:
            r.prefill_pods.remove(pod)
        if not r.prefill_pods and r.decode_pods:
            r.prefill_pods.append(r.decode_pods.pop(0))
        elif not r.decode_pods and len(r.prefill_pods) > 1:
            r.decode_pods.append(r.prefill_pods.pop())
        if not r.pods:
            del self.running[r.job.job_id]
            self._phi_point(now, r.job.job_id, 0.0)
            self._log_pool(now, r)  # fleet died: empty decode pool
            return
        self._log_pool(now, r)
        r.edges = self._kv_edges(r, now)
        r.record.shrinks += 1
        self._c_shrinks.inc()

    # ---- fault handling --------------------------------------------------

    def _restart_job(self, now: float, r: _Running, from_scratch: bool) -> float:
        """Kill ``r`` (pod failure), release its GPUs, requeue it.

        ``from_scratch`` (rewire-around: no checkpoint infrastructure)
        loses all progress; otherwise roll back to the last checkpoint and
        charge the checkpoint-restore cost.  Returns when the job is ready
        to be queued again."""
        jid = r.job.job_id
        del self.running[jid]
        for p, n in r.pods.items():
            self.free[p] += n
        if from_scratch:
            # nothing to restore: fixed reschedule/re-init overhead only
            lost, cost = r.progress, RESTART_FIXED_S
        else:
            lost = rollback_loss(r.progress, self.cfg.ckpt_interval_s)
            # a pre-emptive checkpoint (remediation) may be fresher than
            # the last periodic one: never roll back below its floor
            lost = min(lost, max(0.0, r.progress - r.ckpt_progress))
            cost = restart_cost_s(r.job.model, r.job.num_gpus)
        self.carry_progress[jid] = r.progress - lost
        r.record.restarts += 1
        r.record.lost_s += lost
        self._c_restarts.inc()
        self._c_lost.inc(lost * r.job.num_gpus)
        # the job's progress is integrated through r.last_t, which can sit
        # one solve-comp_s ahead of the fault's event time when a job start
        # at the same timestamp already advanced the runners — the stint
        # must cover exactly what was integrated or conservation breaks
        self.attrib.stint_end(jid, max(now, r.last_t))
        self.attrib.restart(jid, now, now + cost)
        self.attrib.lose(jid, now, lost, "rollback")
        return now + cost

    def _replan_without_pod(self, job: Job, pods: Dict[int, int]):
        """Re-plan a job's collectives over ``pods`` (a surviving pod →
        GPU-count map): returns ``(order, edges, comm_frac)``."""
        pods_left = sorted(pods)
        if len(pods_left) >= 2:
            links = self._ring_links(job, pods)
            order = dist_demand.ring_order(pods_left, self.old_config, links=links)
            edges = dist_demand.job_edges(
                job.model, order, links, ep=job.ep, pp=job.pp, tp=job.tp
            )
            comm_frac = self._comm_fraction(job, len(pods_left), links)
            return order, edges, comm_frac
        return tuple(pods_left), {}, 0.0

    def _shrink_job(self, now: float, r: _Running, pod: int) -> None:
        """Drop ``pod`` from a running job's collectives and continue on
        the surviving GPUs (shrink-collective policy)."""
        lost_gpus = r.placement.pods.pop(pod)
        self.free[pod] += lost_gpus
        r.cur_gpus -= lost_gpus
        r.compute_scale = r.job.num_gpus / r.cur_gpus
        order, r.edges, r.comm_frac = self._replan_without_pod(
            r.job, r.placement.pods
        )
        r.placement = Placement(r.job.job_id, r.placement.pods, ring_order=order)
        r.record.shrinks += 1
        self._c_shrinks.inc()

    # ---- remediation actuators (driven by repro.fault.remediate) ---------

    def schedule_action(self, t: float, fn, trigger: str = "remediation") -> None:
        """Defer a remediation action onto the event heap.

        Health detectors fire mid-refresh, deep inside event processing;
        mutating topology/demand state there would corrupt the in-flight
        refresh.  Actions enqueue here instead and run at top level as
        ``ACTION`` events, in deterministic heap order.  ``fn(t)`` returns
        True when it changed demand or the mask — the loop then re-solves
        with ``trigger`` as the blame bucket its dark windows land under
        (``remediation`` or ``cordon``)."""
        self._actions.append((t, fn, trigger))

    def cordon_link(self, now: float, h: int, k: int, pod: int) -> bool:
        """Cordon one OCS slot out of TE demand (both directions).

        The slot stays physically up — faults keep landing on the mask
        and the flap window keeps counting — but no circuit is placed on
        it, so once the re-solve settles, subsequent flaps of this slot
        change nothing the solver sees (rewired = 0, no dark windows).
        Cordon time is a first-class blame cause (``cordon``)."""
        if self.mask.cordoned[h, k, pod]:
            return False
        was_trivial = self.mask.is_trivial()
        self.mask.cordon_link(h, k, pod)
        if was_trivial:
            self.attrib.degraded_begin(now)
        self.attrib.cordon_begin(now)
        if self._routers:
            # routers shed load off cordoned pods: record the per-pod
            # cordon-count breakpoint their replay reads
            self._cordon_log.setdefault(pod, []).append(
                (now, float(self.mask.cordoned[:, :, pod].sum()))
            )
        self.metrics.counter("remediation.cordons").inc()
        if self.trace.enabled:
            self.trace.instant(
                "remediation", "cordon", ts=now, h=h, k=k, pod=pod
            )
        return True

    def readmit_link(self, now: float, h: int, k: int, pod: int) -> bool:
        """Readmit a cordoned slot into TE demand (backoff expired and
        the slot stayed healthy — the remediation engine's hysteresis
        decides when; this just flips the mask and the blame interval)."""
        if not self.mask.cordoned[h, k, pod]:
            return False
        self.mask.readmit_link(h, k, pod)
        self.attrib.cordon_end(now)
        if self.mask.is_trivial():
            self.attrib.degraded_end(now)
        if self._routers:
            self._cordon_log.setdefault(pod, []).append(
                (now, float(self.mask.cordoned[:, :, pod].sum()))
            )
        self.metrics.counter("remediation.readmits").inc()
        if self.trace.enabled:
            self.trace.instant(
                "remediation", "readmit", ts=now, h=h, k=k, pod=pod
            )
        return True

    def preempt_checkpoint(self, now: float, jid: int) -> bool:
        """Pre-emptively checkpoint one running training job.

        The job stalls for the sharded state dump (priced like the
        ``ckpt/manager`` TrainState write — :func:`~repro.fault.recover.
        ckpt_write_s`) and its rollback floor advances to the paused
        progress: a later restart loses only work since this instant.
        The stall is blamed on ``remediation``.  No-op under
        ``rewire_around``, which has no checkpoint infrastructure."""
        r = self.running.get(jid)
        if (
            r is None or r.job.kind == "serve"
            or self.cfg.recovery_policy == REWIRE_AROUND
        ):
            return False
        r.advance(now)
        pause = min(ckpt_write_s(r.job.model, max(1, r.cur_gpus)), r.progress)
        if pause > 0:
            # the write stalls training: the analytic twin of a dark
            # window, rolled back and blamed exactly like the OCS pause
            r.progress -= pause
            self.attrib.lose(jid, now, pause, "remediation")
        r.ckpt_progress = r.progress
        self.metrics.counter("remediation.ckpts").inc()
        if self.trace.enabled:
            self.trace.span(
                "remediation", f"ckpt:job{jid}", ts=now, dur=pause,
                job_id=jid,
            )
        return False

    def remediate_drain(self, now: float, jid: int, pod: int) -> bool:
        """Drain a serving fleet's decode pool off ``pod`` — reroute load
        away from a pod behind persistently dark/degraded circuits.  Same
        mechanics as a scale-down autoscale: the freed GPUs return to the
        allocator and the fleet keeps serving on the survivors.  Returns
        True when the pool changed, so the caller re-solves and TE drops
        the pod's KV circuits."""
        r = self.running.get(jid)
        if r is None or r.job.kind != "serve":
            return False
        if pod not in r.decode_pods or len(r.decode_pods) <= 1:
            return False
        r.decode_pods.remove(pod)
        n = r.pods.pop(pod)
        self.free[pod] += n
        r.cur_gpus = max(0, r.cur_gpus - n)
        self._log_pool(now, r)
        r.edges = self._kv_edges(r, now)
        self.metrics.counter("remediation.drains").inc()
        if self.trace.enabled:
            self.trace.instant(
                "remediation", "drain", ts=now, job_id=jid, pod=pod
            )
        return True

    def escalate_solver(self, now: float, window_s: float) -> bool:
        """Pin the control plane to the degraded-mode solver for
        ``window_s`` (bounded escalation after repeated delta-path
        fallbacks): no delta attempts, no state rebuilds — every solve
        inside the window pays one predictable degraded price instead of
        the StaleStateError retry-then-cold thrash."""
        self._solver_degraded_until = max(
            self._solver_degraded_until, now + window_s
        )
        self._coloring_state = None
        self.metrics.counter("remediation.solver_escalations").inc()
        if self.trace.enabled:
            self.trace.span(
                "remediation", "solver_degraded", ts=now, dur=window_s
            )
        return False

    def _choose_policy(self, now: float, r: _Running, pod: int) -> str:
        """Pick the cheapest recovery policy for one victim of a pod
        failure, pricing the shrink path with the *fluid-measured*
        degradation: the max-min φ its replanned collectives would get on
        the realized topology with the dead pod's circuits dark (not the
        static worst-edge snapshot — see ``repro.fault.recover``)."""
        survivors = {p: n for p, n in r.pods.items() if p != pod}
        lost_gpus = r.pods.get(pod, 0)
        _, edges, alpha = self._replan_without_pod(r.job, survivors)
        phi_shrunk = 1.0
        if edges and self.old_config is not None:
            dark = frozenset(
                (min(pod, q), max(pod, q)) for q in range(self.cfg.num_pods)
            )
            flows = [
                flowsim.JobFlows(jid, o.edges, o.comm_frac)
                for jid, o in self.running.items()
                if jid != r.job.job_id
            ]
            flows.append(flowsim.JobFlows(r.job.job_id, edges, alpha))
            phi_shrunk = fluid_engine.fluid_fractions(
                self.spec, flows, self.old_config, self.cfg.architecture,
                dark_pairs=dark, cap=self.spec.slowdown_cap,
                pair_cap=self._pair_cap_arg(self.old_config),
            ).get(r.job.job_id, 1.0)
        costs = policy_costs(
            service_s=r.job.service_time,
            progress_s=r.progress,
            model=r.job.model,
            num_gpus=r.job.num_gpus,
            cur_gpus=r.cur_gpus,
            lost_gpus=lost_gpus,
            comm_fraction=alpha,
            phi_shrunk=phi_shrunk,
            ckpt_interval_s=self.cfg.ckpt_interval_s,
            slowdown_cap=self.spec.slowdown_cap,
        )
        chosen = min(sorted(costs), key=lambda p: costs[p])
        cause = POLICY_CAUSE[chosen]  # blame bucket the cost lands under
        self._s_policy.append(
            {"t": now, "job_id": float(r.job.job_id),
             "phi_shrunk": phi_shrunk, "policy": chosen, "cause": cause,
             **costs}
        )
        if self.trace.enabled:
            self.trace.instant(
                "policy", chosen, ts=now,
                job_id=r.job.job_id, phi_shrunk=round(phi_shrunk, 9),
                cause=cause,
                **{k: round(costs[k], 6) for k in sorted(costs)},
            )
        return chosen

    def _apply_fault(self, now: float, ev: FaultEvent) -> List[Tuple[float, int]]:
        """Update mask/capacity/victims for one event.  Returns requeue
        (ready_time, job_id) pairs for jobs killed by the event."""
        requeue: List[Tuple[float, int]] = []
        pod_was_up = self.mask.pod_up()
        was_active = self.mask.active.copy()
        was_trivial = self.mask.is_trivial()
        apply_event(self.mask, ev)
        # degraded-capacity bookkeeping for blame replay: the interval
        # during which the fault mask is non-trivial
        if was_trivial and not self.mask.is_trivial():
            self.attrib.degraded_begin(now)
        elif not was_trivial and self.mask.is_trivial():
            self.attrib.degraded_end(now)
        if isinstance(ev, ExpandEvent):
            self._c_expand.inc()
            if self.trace.enabled:
                self.trace.instant(
                    "fault", "expand", ts=now, pods=sorted(ev.pods)
                )
            self._cap_gpu_s += self._cap_gpus * (now - self._cap_t)
            self._cap_t = now
            self._cap_gpus = int(self.mask.active.sum()) * self.spec.gpus_per_pod
            for p in ev.pods:
                if not was_active[p]:  # re-announcing a live pod is a no-op
                    self.free[p] = self.spec.gpus_per_pod
            return requeue
        if isinstance(ev, DerateEvent):
            self._c_derate.inc()
            if self.trace.enabled:
                self.trace.instant(
                    "fault", "derate_link", ts=now,
                    h=ev.h, k=ev.k, pod=ev.pod, health=ev.health,
                )
            if self.health is not None:
                # a derate below full health counts toward the flap window
                self.health.observe_fault(
                    now, ev.h, ev.k, ev.pod, down=ev.health < 1.0
                )
            return requeue
        if isinstance(ev, FailureEvent):
            self._c_fail.inc()
            if self.trace.enabled:
                self.trace.instant(
                    "fault", f"fail_{ev.scope}", ts=now,
                    scope=ev.scope, h=ev.h, k=ev.k, pod=ev.pod,
                )
            if ev.scope == "link" and self.health is not None:
                self.health.observe_fault(now, ev.h, ev.k, ev.pod, down=True)
            if ev.scope == "pod" and pod_was_up[ev.pod]:
                self._pod_down_since[ev.pod] = now
                policy = self.cfg.recovery_policy
                victims = [
                    r for r in list(self.running.values()) if ev.pod in r.pods
                ]
                for r in victims:
                    if r.job.kind == "serve":
                        # serving fleets never restart: they degrade by
                        # dropping the dead pod from their pools
                        self._shrink_serving(now, r, ev.pod)
                        continue
                    pol = policy
                    if pol == CHEAPEST:
                        pol = self._choose_policy(now, r, ev.pod)
                    if pol == SHRINK_COLLECTIVE and len(r.pods) > 1:
                        self._shrink_job(now, r, ev.pod)
                    else:
                        # rewire-around has no checkpoints to fall back on —
                        # a dead pod means losing the whole run so far
                        scratch = pol == REWIRE_AROUND
                        ready = self._restart_job(now, r, from_scratch=scratch)
                        requeue.append((ready, r.job.job_id))
        elif isinstance(ev, RepairEvent):
            self._c_repair.inc()
            if self.trace.enabled:
                self.trace.instant(
                    "fault", f"repair_{ev.scope}", ts=now,
                    scope=ev.scope, h=ev.h, k=ev.k, pod=ev.pod,
                )
            if ev.scope == "link" and self.health is not None:
                # repairs cool the flap latch but never fire it
                self.health.observe_fault(now, ev.h, ev.k, ev.pod, down=False)
            if ev.scope == "pod":
                t0 = self._pod_down_since.pop(ev.pod, None)
                if t0 is not None:
                    self._gpu_down_s += (now - t0) * self.spec.gpus_per_pod
        return requeue

    # ---- main loop -------------------------------------------------------------

    def run(self, until: Optional[float] = None) -> List[JobRecord]:
        """Drain the event heap (arrivals, finishes, faults, requeues).

        ``until`` caps simulated time (goodput/availability accounting over
        a fixed horizon); running jobs are advanced to the cap and left
        unfinished (``finish`` stays NaN)."""
        ARRIVE, FINISH, FAULT, REQUEUE, DARK_END, REFRESH, ACTION = range(7)
        ev: List[Tuple[float, int, int, int]] = []  # (t, kind, seq, payload)
        seq = 0
        actions: List[Tuple[object, str]] = []  # ACTION payloads (fn, trigger)
        for j in self.jobs:
            heapq.heappush(ev, (j.arrival, ARRIVE, seq, j.job_id))
            seq += 1
        for idx, fe in enumerate(self.fault_events):
            if until is None or fe.time <= until:
                heapq.heappush(ev, (fe.time, FAULT, seq, idx))
                seq += 1
        finish_version: Dict[int, int] = {}

        def schedule_finish(now: float, r: _Running):
            nonlocal seq
            rem = r.remaining()
            if not math.isfinite(rem):
                # stalled flow (dark circuits, no residual fabric): the
                # DARK_END / next fault event will reschedule it
                finish_version[r.job.job_id] = -1
                return
            finish_version[r.job.job_id] = seq
            # progress is valued at r.last_t, so under piecewise-constant
            # slowdown the finish is last_t + rem regardless of `now`.
            # Anchoring at `now` is wrong when a zero-comp_s start at the
            # same event time reschedules runners already advanced to
            # now + comp_s by an earlier start — the finish would land one
            # comp_s early and break the blame-conservation identity.
            heapq.heappush(
                ev, (max(now, r.last_t + rem), FINISH, seq, r.job.job_id)
            )
            seq += 1

        def reschedule_all(now: float):
            for r in self.running.values():
                schedule_finish(now, r)

        def reconfigure_now(
            now: float,
            skip_pause_for: Optional[int] = None,
            trigger: str = "start",
        ):
            """Re-solve the control plane and price the switching.

            Analytic engine: the legacy OCS switching pause rolls back a
            slice of progress on impacted jobs (min-rewiring keeps the set
            small; Table 1 shows the effect is tiny).  Fluid engine: the
            changed circuits go *dark* for ``reconfig_delay_s`` instead — a
            real bandwidth hole the water-filling sees — and the downtime
            is time-priced as delay · Σ|Δx|, so incremental deltas (fewer
            circuits moved) are strictly cheaper than cold re-solves.  The
            retune can only begin once the solver has emitted the new
            configuration, so the window is anchored at ``now + comp_s``
            (the same instant the starting job's slowdown refresh runs)."""
            nonlocal seq
            config, comp_s = self._reconfigure(now)
            kind = "incremental" if self._last_incremental else "cold"
            if comp_s > 0:
                self.attrib.solve(now, now + comp_s, kind, trigger)
            if self.health is not None and config is not None:
                self.health.observe_solve(now, kind)
            if self.old_config is not None and config is not None:
                changed = (
                    self._last_rewired
                    if self._last_rewired is not None
                    else config.rewiring_distance(self.old_config)
                )
                if changed and self.cfg.engine == "fluid":
                    delay = self.cfg.reconfig_delay_s
                    if delay > 0:
                        pairs = config.dark_pairs(self.old_config)
                        start = now + comp_s
                        self._dark.add(pairs, start, start + delay)
                        self.attrib.dark_window(
                            start, start + delay, kind, trigger
                        )
                        if self.health is not None:
                            self.health.observe_dark(
                                start, delay, len(pairs), kind
                            )
                        self._c_dt_events.inc()
                        self._c_dt_s.inc(delay)
                        self._c_dt_circ.inc(delay * changed)
                        if self.trace.enabled:
                            for i, j in sorted(pairs):
                                self.trace.span(
                                    "dark_window", f"{i}-{j}",
                                    ts=start, dur=delay, pair=[i, j],
                                )
                        heapq.heappush(
                            ev, (start + delay, DARK_END, seq, 0)
                        )
                        seq += 1
                        # rates must be re-evaluated the instant the window
                        # opens (the job-start path refreshes then anyway;
                        # the fault path refreshes at `now` only)
                        heapq.heappush(ev, (start, REFRESH, seq, 0))
                        seq += 1
                elif changed:
                    dark_cause = (
                        "dark_incremental" if kind == "incremental"
                        else "dark_cold"
                    )
                    for other in self.running.values():
                        if other.job.job_id != skip_pause_for:
                            pause = min(OCS_SWITCH_S, other.progress)
                            other.progress -= pause
                            # the analytic twin of a dark window: work
                            # rolled back by the switching pause
                            self.attrib.lose(
                                other.job.job_id, now, pause, dark_cause
                            )
            self.old_config = config
            return comp_s

        def try_start(now: float) -> bool:
            """FCFS head-of-queue; returns True if a job started."""
            if not self.queue:
                return False
            job = self.queue[0]
            up = self.mask.pod_up()
            free_now = np.where(up, self.free, 0)
            pods = _place(free_now, self.spec.gpus_per_pod, job.num_gpus)
            if pods is None:
                return False
            self.queue.pop(0)
            for p, n in pods.items():
                self.free[p] -= n
            rec = self.records[job.job_id]
            start_t = now  # refined below once reconfig time is known
            if job.kind == "serve":
                run = self._start_serving(job, pods, rec, start_t)
            else:
                links = self._ring_links(job, pods)
                # topology-aware ring ordering against the *current* OCS
                # config (minimizes uncoverable demand even before
                # reconfiguration)
                order = dist_demand.ring_order(
                    sorted(pods), self.old_config, links=links
                )
                placement = Placement(job.job_id, pods, ring_order=order)
                edges = dist_demand.job_edges(
                    job.model, order, links, ep=job.ep, pp=job.pp, tp=job.tp
                )
                alpha = self._comm_fraction(job, len(pods), links)
                run = _Running(
                    job, placement, edges, alpha, rec, start_t=start_t
                )
            run.progress = self.carry_progress.pop(job.job_id, 0.0)
            self.running[job.job_id] = run
            comp_s = reconfigure_now(now, skip_pause_for=job.job_id)
            rec.reconfig_s += comp_s
            start_t = now + comp_s
            if math.isnan(rec.start):
                rec.start = start_t  # first start only: JWT is queue wait
            run.last_t = start_t
            if job.kind != "serve":
                self.attrib.stint_begin(job.job_id, start_t)
            self._refresh_slowdowns(max(now, start_t), self.old_config)
            reschedule_all(max(now, start_t))
            return True

        last_t = 0.0
        with obs_recorder.flight_guard(self.trace):
            while ev:
                t, kind, sq, jid = heapq.heappop(ev)
                if until is not None and t > until:
                    last_t = until
                    break
                last_t = t
                self.events += 1
                if kind == FINISH:
                    if finish_version.get(jid) != sq or jid not in self.running:
                        continue  # stale event
                    r = self.running.pop(jid)
                    r.advance(t)
                    r.record.finish = t
                    if r.job.kind != "serve":
                        self.attrib.stint_end(jid, t)
                    if self.trace.enabled and math.isfinite(r.record.start):
                        self.trace.span(
                            "job", f"job{jid}:{r.job.kind}",
                            ts=r.record.start, dur=t - r.record.start,
                            job_id=jid, kind=r.job.kind,
                            gpus=r.job.num_gpus,
                            restarts=r.record.restarts,
                        )
                    for p, n in r.pods.items():
                        self.free[p] += n
                    self._refresh_slowdowns(t, self.old_config)
                    reschedule_all(t)
                    while try_start(t):
                        pass
                elif kind == FAULT:
                    for r in self.running.values():
                        r.advance(t)
                    fe = self.fault_events[jid]
                    if isinstance(fe, serving_mod.ScaleEvent):
                        # autoscale rides the fault stream but never touches
                        # the PortMask: the re-solve below is a pure demand
                        # delta (incremental path, no cold solve)
                        self._apply_scale(t, fe)
                    else:
                        requeue = self._apply_fault(t, fe)
                        for ready, rq_jid in requeue:
                            heapq.heappush(ev, (ready, REQUEUE, seq, rq_jid))
                            seq += 1
                    # re-solve around the new mask; surviving jobs absorb the
                    # capacity change through the flow model
                    reconfigure_now(
                        t,
                        trigger=(
                            "autoscale"
                            if isinstance(fe, serving_mod.ScaleEvent)
                            else "fault"
                        ),
                    )
                    self._refresh_slowdowns(t, self.old_config)
                    reschedule_all(t)
                    while try_start(t):
                        pass
                elif kind == DARK_END:
                    if not self._dark.prune(t):
                        continue  # stale: window was merged/extended
                    self._refresh_slowdowns(t, self.old_config)
                    reschedule_all(t)
                elif kind == REFRESH:  # a dark window just opened
                    self._refresh_slowdowns(t, self.old_config)
                    reschedule_all(t)
                elif kind == ACTION:  # deferred remediation action
                    fn, trigger = actions[jid]
                    for r in self.running.values():
                        r.advance(t)
                    if fn(t):  # mask/demand changed: re-solve around it
                        reconfigure_now(t, trigger=trigger)
                    self._refresh_slowdowns(t, self.old_config)
                    reschedule_all(t)
                    while try_start(t):
                        pass
                else:  # ARRIVE / REQUEUE
                    self.queue.append(self.jobs[jid])
                    while try_start(t):
                        pass
                # drain actions the remediation engine scheduled while
                # this event was processed (top-level dispatch keeps the
                # actions re-entrancy safe and deterministically ordered)
                while self._actions:
                    at, fn, trigger = self._actions.pop(0)
                    heapq.heappush(
                        ev, (max(at, t), ACTION, seq, len(actions))
                    )
                    actions.append((fn, trigger))
                    seq += 1
        if until is not None:
            # the heap may drain before the requested horizon; accounting
            # (capacity integral, downtime) still covers the full window
            last_t = until
        self._end_time = last_t
        for r in self.running.values():
            r.advance(last_t)
        self.attrib.close(last_t)
        if self.health is not None:
            self.health.finalize(last_t)
        self._cap_gpu_s += self._cap_gpus * (last_t - self._cap_t)
        self._cap_t = last_t
        for p, t0 in self._pod_down_since.items():
            self._gpu_down_s += (last_t - t0) * self.spec.gpus_per_pod
        self._pod_down_since = {}
        return [self.records[j.job_id] for j in self.jobs]

    # ---- resilience metrics ----------------------------------------------

    def fault_summary(self) -> Dict[str, float]:
        """Goodput / availability / disruption metrics of the finished run.

        *Goodput* is useful delivered work (progress that survived, in
        GPU-seconds at each job's full size) over the capacity integral
        (expansion-aware).  *Availability* is the share of capacity-time
        not lost to failed pods.  See EXPERIMENTS.md §Resilience."""
        useful = 0.0
        for rec in self.records.values():
            r = self.running.get(rec.job.job_id)
            if r is not None:
                useful += r.progress * rec.job.num_gpus
            elif math.isfinite(rec.finish):
                useful += rec.job.service_time * rec.job.num_gpus
            else:
                useful += (
                    self.carry_progress.get(rec.job.job_id, 0.0)
                    * rec.job.num_gpus
                )
        cap = max(self._cap_gpu_s, 1e-9)
        return {
            "horizon_s": self._end_time,
            "capacity_gpu_s": self._cap_gpu_s,
            "useful_gpu_s": useful,
            "goodput": useful / cap,
            "availability": 1.0 - self._gpu_down_s / cap,
            "lost_gpu_s": self.lost_gpu_s,
            "restarts": float(self.restarts),
            "shrinks": float(self.shrinks),
            "failures": float(self.fault_counts["failures"]),
            "repairs": float(self.fault_counts["repairs"]),
            "expands": float(self.fault_counts["expands"]),
        }

    # ---- serving metrics -------------------------------------------------

    def serving_summary(self) -> Dict[str, object]:
        """Request-level outcome of the run's serving fleets.

        For every ``kind="serve"`` job, regenerate its deterministic
        request stream (:func:`~repro.sim.serving.serving_trace`, seeded
        from the simulator seed and the job id) over the simulated
        horizon and price each request's KV-transfer completion against
        the φ timeline the run recorded — queue wait, contention, and
        reconfiguration dark windows all surface as latency.  Returns
        per-job rows (p50/p99/goodput vs the ``serving_slo``) plus the
        pooled tail across all fleets; call after :meth:`run`.
        """
        rows: Dict[int, Dict[str, float]] = {}
        pooled: List[np.ndarray] = []
        served = requests = 0.0
        avail_s = avail_span = 0.0
        for j in self.jobs:
            if j.kind != "serve":
                continue
            span = self._end_time - j.arrival
            arrivals = (
                serving_mod.serving_trace(
                    span, j.req_rate, seed=(self.seed, j.job_id),
                    diurnal=j.diurnal, period_s=self.cfg.serving_period_s,
                    t0=j.arrival,
                )
                if span > 0 and j.req_rate > 0 else _EMPTY
            )
            work, alpha_s = self._serving_work.get(j.job_id, (0.0, 0.0))
            fleet_tl = self.phi_timeline.get(j.job_id, ())
            router = self._routers.get(j.job_id)
            route = None
            phi_tls: Dict[int, object] = {}
            if router is not None:
                # per-request placement, replayed deterministically from
                # the run's records (pool membership, per-pod φ, cordon
                # counts) — requests never entered the event heap
                pool_log = self._pool_log.get(j.job_id, [])
                phi_tls = {
                    p: self.phi_timeline.get((j.job_id, p), ())
                    for p in sorted(
                        {q for _, pool in pool_log for q in pool}
                    )
                }
                route = router.replay(
                    arrivals, pool_log, phi_tls, self._cordon_log
                )
                lat = np.empty(arrivals.shape, dtype=np.float64)
                miss = ~route.hits
                for pod in np.unique(route.pods):
                    sel = miss & (route.pods == pod)
                    if not sel.any():
                        continue
                    # pod −1 = no decode pool at that time (single-pod
                    # fleet / dead fleet): fleet-level timeline
                    tl = fleet_tl if pod < 0 else phi_tls.get(int(pod), ())
                    lat[sel] = serving_mod.request_latencies(
                        arrivals[sel], work, tl, alpha_s=alpha_s
                    )
                # a hit finds its KV prefix resident on the decode pod:
                # the prefill→decode stream is skipped entirely and the
                # request pays only the circuit latency
                lat[route.hits] = alpha_s
            else:
                lat = serving_mod.request_latencies(
                    arrivals, work, fleet_tl, alpha_s=alpha_s
                )
            slo = self.cfg.serving_slo * (work + alpha_s)
            row = serving_mod.summarize_requests(lat, slo)
            if route is not None:
                kvb = (
                    j.kv_tokens * dist_demand.kv_bytes_per_token(j.model)
                )
                row["routing"] = dict(
                    route.stats,
                    kv_bytes_streamed=route.stats["misses"] * kvb,
                    kv_bytes_saved=route.stats["hits"] * kvb,
                )
                if j.job_id not in self._routing_counted:
                    # summaries may be recomputed; count each fleet once
                    self._routing_counted.add(j.job_id)
                    for key in ("hits", "misses", "sheds", "overloads"):
                        self.metrics.counter(f"routing.{key}").inc(
                            route.stats[key]
                        )
            row["ideal_s"] = work + alpha_s
            row["slo_s"] = slo
            if span > 0:
                # φ ≥ 1/slo keeps a steady-state request inside the SLO
                row["availability"] = serving_mod.slo_availability(
                    self.phi_timeline.get(j.job_id, ()),
                    1.0 / self.cfg.serving_slo, j.arrival, self._end_time,
                )
                avail_s += row["availability"] * span
                avail_span += span
            rows[j.job_id] = row
            if j.job_id not in self._requests_traced:
                # summaries may be recomputed; record each fleet once
                self._requests_traced.add(j.job_id)
                hist = self.metrics.histogram("serving.latency_s")
                for v in lat:
                    if math.isfinite(v):
                        hist.observe(float(v))
                tr = self.trace
                if tr.enabled:
                    cap = min(len(arrivals), tr.request_cap)
                    tr.dropped += len(arrivals) - cap
                    for n in range(cap):
                        a, l = float(arrivals[n]), float(lat[n])
                        if not math.isfinite(l):
                            tr.instant(
                                "request", "stalled", ts=a,
                                job_id=j.job_id, req=n,
                            )
                            continue
                        tl = fleet_tl
                        if route is not None and route.pods[n] >= 0:
                            # routed miss: phases against *its* pod's
                            # timeline (hits have zero transfer anyway)
                            tl = phi_tls.get(int(route.pods[n]), fleet_tl)
                        q, x, d = serving_mod.request_phases(
                            a, l, tl, alpha_s=alpha_s
                        )
                        tr.span(
                            "request", f"req{n}", ts=a, dur=l,
                            job_id=j.job_id, req=n,
                            queue_s=round(q, 9),
                            transfer_s=round(x, 9),
                            decode_s=round(d, 9),
                        )
            pooled.append(lat)
            requests += row["requests"]
            served += row["goodput"] * row["requests"] if row["requests"] else 0
        lat = np.concatenate(pooled) if pooled else _EMPTY
        return {
            "jobs": rows,
            "requests": float(requests),
            "p50_s": serving_mod.pool_quantile(lat, 0.5),
            "p99_s": serving_mod.pool_quantile(lat, 0.99, strict=True),
            "goodput": served / requests if requests else math.nan,
            "availability": avail_s / avail_span if avail_span else math.nan,
            "autoscale_applied": float(self.autoscale_applied),
            "autoscale_skipped": float(self.autoscale_skipped),
        }


_EMPTY = np.empty(0)


def summarize(records: Sequence[JobRecord]) -> Dict[str, float]:
    done = [r for r in records if math.isfinite(r.finish)]
    jrt = np.array([r.jrt for r in done])
    jwt = np.array([r.jwt for r in done])
    jct = np.array([r.jct for r in done])
    service = np.array([r.job.service_time for r in done])
    return {
        "completed": len(done),
        "avg_jrt": float(jrt.mean()),
        "avg_jwt": float(jwt.mean()),
        "avg_jct": float(jct.mean()),
        "p99_jrt_slowdown": float(np.quantile(jrt / service - 1.0, 0.99)),
        "avg_jrt_slowdown": float((jrt / service - 1.0).mean()),
        "max_jwt": float(jwt.max()) if len(jwt) else 0.0,
    }
