"""Inference-serving workload archetype over the optical fabric.

Training jobs are long-lived rings; production *serving* traffic — the
ROADMAP's "millions of users" half — looks nothing like them: request-level
Poisson arrivals with diurnal swell, disaggregated prefill/decode pools
exchanging short latency-critical KV-cache transfers, and autoscaling that
reshapes demand while the cluster is live (the shifting-demand regime
FastReChain argues TE must be judged under — see PAPERS.md).  This module
gives both progress engines that workload:

* **Arrival process** — :func:`serving_trace`: an inhomogeneous Poisson
  stream (thinning) whose rate swells by a diurnal factor, deterministic
  given the seed (the simulator's reproducibility discipline).
* **KV migration flows** — a serving job's cross-pod demand is the
  prefill→decode KV-cache stream, sized by
  :func:`repro.dist.demand.kv_flow` from the model's
  ``kv_bytes_per_token`` (calibrated against the real serving engine via
  :meth:`repro.serve.engine.ServeEngine.comm_profile`).
* **Latency accounting** — a request arriving at ``t`` completes its KV
  transfer when the *time-varying* realized bandwidth fraction φ has
  delivered its bytes: :func:`request_latencies` integrates the φ
  timeline the scheduler records per serving job, so reconfiguration dark
  windows and contention surface as p99 tail latency (TTFT proxy), not as
  JCT.
* **Autoscaling** — :class:`ScaleEvent` adds/drains decode-pool pods of a
  *running* serving job.  It rides the scheduler's fault-event stream
  (the :class:`~repro.fault.model.ExpandEvent` machinery) but, unlike
  expansion, never touches the :class:`~repro.fault.masks.PortMask` — so
  the control plane absorbs it as a pure demand delta via
  :func:`~repro.core.incremental.mdmcf_delta`, no cold solve
  (``tests/test_serving.py`` pins this).

The scheduler-facing entry points are :func:`serving_job` (build a
``kind="serve"`` :class:`~repro.core.logical.Job`) and
:func:`repro.sim.scheduler.Simulator.serving_summary`.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import operator
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.logical import Job
from ..dist.collectives import AlphaBeta
from ..dist.demand import kv_bytes_per_token

__all__ = [
    "KV_ALPHA_S",
    "ScaleEvent",
    "autoscale_events",
    "ideal_latency_s",
    "pool_quantile",
    "request_latencies",
    "request_phases",
    "request_slowdowns",
    "request_work_s",
    "serving_job",
    "serving_trace",
    "slo_availability",
    "summarize_requests",
]

# per-transfer circuit latency: one cross-pod hop of the alpha-beta model
KV_ALPHA_S = AlphaBeta().alpha_cross_pod

_T0 = operator.itemgetter(0)  # breakpoint time, for bisect key=


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """Autoscale a running serving job's decode pool at ``time``.

    ``pods > 0`` adds that many decode-pod replicas (allocated from free,
    healthy pods); ``pods < 0`` drains them (last added first).  Processed
    on the scheduler's fault-event stream, but the cluster's
    :class:`~repro.fault.masks.PortMask` is untouched: the reconfiguration
    that follows is a demand-only delta, served by the incremental control
    plane (:func:`~repro.core.incremental.mdmcf_delta`) instead of a cold
    solve.
    """

    time: float
    job_id: int
    pods: int

    def __post_init__(self) -> None:
        if self.pods == 0:
            raise ValueError("ScaleEvent must add or drain at least one pod")


def serving_job(
    job_id: int,
    num_gpus: int,
    arrival: float = 0.0,
    model: str = "llama2-13b",
    req_rate: float = 10.0,
    kv_tokens: int = 2048,
    prefill_frac: float = 0.25,
    diurnal: float = 0.0,
    tp: int = 8,
) -> Job:
    """Build a ``kind="serve"`` :class:`~repro.core.logical.Job`.

    A serving job is a replica fleet, not a batch job: it has no service
    time (it runs until the simulation horizon) and its cross-pod demand
    is the prefill→decode KV stream rather than a DP ring.  ``req_rate``
    is the mean offered load in requests/s, ``kv_tokens`` the prompt
    length whose KV migrates per request, ``prefill_frac`` the share of
    the fleet's GPUs dedicated to the prefill pool, and ``diurnal`` the
    relative amplitude of the daily load swing (0 = flat).

    Raises ``ValueError`` for models without a KV profile — a zero-byte
    KV stream would make every latency metric silently meaningless (the
    training path has a legacy fallback for unprofiled models; the
    serving path refuses instead).

    >>> j = serving_job(7, 256, req_rate=20.0)
    >>> (j.kind, j.service_time, j.dp_pp_ways > 1)
    ('serve', inf, True)
    """
    if kv_bytes_per_token(model) <= 0:
        raise ValueError(
            f"model {model!r} has no kv_bytes_per_token profile — add it to "
            "repro.dist.collectives.MODEL_PROFILES before serving it"
        )
    return Job(
        job_id=job_id,
        num_gpus=num_gpus,
        arrival=arrival,
        service_time=math.inf,
        model=model,
        tp=tp,
        kind="serve",
        req_rate=req_rate,
        kv_tokens=kv_tokens,
        prefill_frac=prefill_frac,
        diurnal=diurnal,
    )


def serving_trace(
    horizon_s: float,
    req_rate: float,
    seed: int = 0,
    diurnal: float = 0.0,
    period_s: float = 86400.0,
    t0: float = 0.0,
) -> np.ndarray:
    """Request arrival times on ``[t0, t0 + horizon_s)``.

    Inhomogeneous Poisson process with rate ``req_rate · (1 + diurnal ·
    sin(2π(t − t0)/period_s))`` generated by Lewis–Shedler thinning
    against the peak rate, so the stream is exact and deterministic given
    the seed.  ``diurnal = 0`` reduces to a plain Poisson process.

    >>> a = serving_trace(100.0, 5.0, seed=1)
    >>> bool((np.diff(a) > 0).all() and a[0] >= 0.0 and a[-1] < 100.0)
    True
    """
    if not 0.0 <= diurnal < 1.0:
        raise ValueError("diurnal amplitude must be in [0, 1)")
    if req_rate <= 0 or horizon_s <= 0:
        return np.empty(0)
    rng = np.random.default_rng(seed)
    peak = req_rate * (1.0 + diurnal)
    # homogeneous candidates at the peak rate, thinned in vectorized
    # chunks; the cap bounds transient memory on day-long horizons
    chunk = max(64, min(1_000_000, int(peak * horizon_s) + 1))
    out: List[np.ndarray] = []
    t = 0.0
    while t < horizon_s:
        cand = t + np.cumsum(rng.exponential(1.0 / peak, size=chunk))
        t = float(cand[-1])
        u = rng.random(cand.size)
        lam = req_rate * (1.0 + diurnal * np.sin(2 * np.pi * cand / period_s))
        out.append(cand[(u * peak < lam) & (cand < horizon_s)])
    arrivals = np.concatenate(out)
    return arrivals + t0


def request_work_s(
    model,
    kv_tokens: int,
    links: int = 1,
    ab: Optional[AlphaBeta] = None,
) -> float:
    """Bandwidth-seconds to stream one request's KV at φ = 1.

    ``kv_tokens · kv_bytes_per_token(model) · β_cross / links`` — the
    bandwidth term of the alpha–beta p2p transfer, striped over the
    ``links`` spine circuits provisioned on the prefill→decode pair.  The
    circuit latency term (:data:`KV_ALPHA_S`) is added by
    :func:`request_latencies`, because latency does not stretch with φ
    (the circuit exists, it is just thinner than requested).
    """
    ab = ab if ab is not None else AlphaBeta()
    return (
        kv_tokens * kv_bytes_per_token(model) * ab.beta_cross_pod
        / max(1, links)
    )


def request_latencies(
    arrivals: np.ndarray,
    work_s: float,
    timeline: Sequence[Tuple[float, float]],
    alpha_s: float = KV_ALPHA_S,
) -> np.ndarray:
    """KV-transfer completion latency of each request (TTFT proxy).

    ``timeline`` is the piecewise-constant realized-bandwidth-fraction
    record the scheduler keeps per serving job: ``(t, φ)`` breakpoints,
    each φ holding until the next breakpoint and the last extending to
    the horizon.  A request arriving at ``a`` finishes at the first ``f``
    with ``∫_a^f φ(t) dt = work_s``; its latency is ``f − a + alpha_s``.
    Before the first breakpoint (job still queued) and inside dark
    windows φ = 0, so those requests *wait* — queueing and
    reconfiguration downtime surface here as tail latency.  Requests the
    timeline can never finish (φ stuck at 0) get ``inf``.

    >>> lat = request_latencies(
    ...     np.array([0.0, 1.0]), 1.0, [(0.0, 1.0), (2.0, 0.5)], alpha_s=0.0)
    >>> [round(float(x), 3) for x in lat]
    [1.0, 1.0]
    """
    arrivals = np.asarray(arrivals, dtype=np.float64)
    if arrivals.size == 0:
        return np.empty(0)
    if not timeline:
        return np.full(arrivals.shape, math.inf)
    ts = np.array([t for t, _ in timeline], dtype=np.float64)
    phis = np.array([p for _, p in timeline], dtype=np.float64)
    # cumulative ∫φ at each breakpoint (piecewise constant segments)
    seg = np.diff(ts) * phis[:-1]
    I = np.concatenate([[0.0], np.cumsum(seg)])  # I[i] = ∫ up to ts[i]
    # integral at each arrival (arrivals before ts[0] accrue nothing)
    idx = np.searchsorted(ts, arrivals, side="right") - 1
    inside = idx >= 0
    I_a = np.zeros_like(arrivals)
    I_a[inside] = I[idx[inside]] + (
        arrivals[inside] - ts[idx[inside]]
    ) * phis[idx[inside]]
    target = I_a + work_s
    # first breakpoint whose cumulative integral reaches the target; the
    # finish segment can never precede the arrival's segment.  When the
    # target lands *exactly* on a zero-φ plateau's cumulative value
    # (work_s → 0, or an arrival inside a dark window), side="left"
    # picks the plateau's first breakpoint — possibly before the arrival
    # itself, which used to yield a negative latency.  Clamp to the
    # arrival's segment; for work_s > 0 the searchsorted result already
    # satisfies ``j >= idx + 1`` (target > I_a >= I[idx]).
    j = np.searchsorted(I, target, side="left")
    j[inside] = np.maximum(j[inside], idx[inside] + 1)
    finish = np.empty_like(arrivals)
    open_end = j >= len(ts)  # target lands beyond the last breakpoint
    inner = ~open_end
    ji = j[inner]
    # interpolate inside segment [ts[j-1], ts[j]] (φ > 0 there, else the
    # cumulative integral could not have increased past the target)
    prev = np.maximum(ji - 1, 0)
    phi_seg = phis[prev]
    finish[inner] = np.where(
        phi_seg > 0,
        ts[prev] + (target[inner] - I[prev]) / np.where(phi_seg > 0, phi_seg, 1.0),
        ts[ji],
    )
    if open_end.any():
        tail_phi = phis[-1]
        if tail_phi > 0:
            finish[open_end] = ts[-1] + (target[open_end] - I[-1]) / tail_phi
        else:
            finish[open_end] = math.inf
    return finish - arrivals + alpha_s


def ideal_latency_s(work_s: float, alpha_s: float = KV_ALPHA_S) -> float:
    """A request's latency on an uncontended φ = 1 fabric — the baseline
    the attribution engine measures slowdown against (``work + α``, the
    same quantity ``serving_summary`` scales the SLO from).

    >>> ideal_latency_s(2.0, alpha_s=0.5)
    2.5
    """
    return work_s + alpha_s


def request_slowdowns(
    latencies: np.ndarray, work_s: float, alpha_s: float = KV_ALPHA_S
) -> np.ndarray:
    """Per-request slowdown: actual − ideal latency.

    This is the quantity the blame decomposition conserves —
    ``latency − (work + α) = ∫ₐᶠ (1 − φ) dt`` over the request's
    transfer window, which :mod:`repro.obs.attrib` partitions by cause.

    >>> request_slowdowns(np.array([3.0, 2.5]), 2.0, alpha_s=0.5).tolist()
    [0.5, 0.0]
    """
    lat = np.asarray(latencies, dtype=np.float64)
    return lat - ideal_latency_s(work_s, alpha_s)


def request_phases(
    arrival: float,
    latency: float,
    timeline: Sequence[Tuple[float, float]],
    alpha_s: float = KV_ALPHA_S,
) -> Tuple[float, float, float]:
    """Decompose one request's latency into ``(queue_s, transfer_s,
    decode_s)`` phases for tracing.

    ``queue_s`` is the portion of the KV-transfer window spent with
    φ = 0 (job still queued, or a reconfiguration dark window),
    ``transfer_s`` the portion with bandwidth actually flowing, and
    ``decode_s`` the fixed ``alpha_s`` term.  The three always sum to
    ``latency`` (``queue_s`` is ``inf`` for requests that never finish).

    >>> request_phases(0.5, 1.5, [(1.0, 1.0)], alpha_s=0.0)
    (0.5, 1.0, 0.0)
    """
    if not math.isfinite(latency):
        return math.inf, 0.0, alpha_s
    finish = arrival + latency - alpha_s
    busy = 0.0  # time with φ > 0 inside [arrival, finish]
    if timeline and finish > arrival:
        # only segments overlapping [arrival, finish] can contribute —
        # binary-search the window bounds instead of scanning the whole
        # timeline (chaos runs accumulate thousands of breakpoints, and
        # this runs once per traced request)
        n_seg = len(timeline)
        lo = max(0, bisect.bisect_right(timeline, arrival, key=_T0) - 1)
        hi = bisect.bisect_left(timeline, finish, lo, n_seg, key=_T0)
        for n in range(lo, hi):
            t, phi = timeline[n]
            seg_end = timeline[n + 1][0] if n + 1 < n_seg else finish
            a, b = max(t, arrival), min(seg_end, finish)
            if b > a and phi > 0:
                busy += b - a
    transfer = min(busy, finish - arrival)
    return (finish - arrival) - transfer, transfer, alpha_s


def autoscale_events(
    job: Job,
    horizon_s: float,
    period_s: float = 86400.0,
    pods: int = 1,
    cycles: Optional[int] = None,
) -> List[ScaleEvent]:
    """Scripted diurnal autoscale schedule for one serving job.

    Capacity follows load: ``pods`` decode replicas join at each daily
    peak (quarter period after the job starts, where the diurnal sine
    crests) and drain at each trough (three quarters).  Scripted rather
    than reactive — like :class:`~repro.fault.model.ExpandEvent`,
    capacity change is an operator policy, and a deterministic schedule
    keeps simulations reproducible.  Returns an empty list for flat
    (``diurnal = 0``) jobs.
    """
    if job.kind != "serve" or job.diurnal <= 0.0:
        return []
    out: List[ScaleEvent] = []
    n = 0
    t_up = job.arrival + 0.25 * period_s
    while t_up < job.arrival + horizon_s and (cycles is None or n < cycles):
        out.append(ScaleEvent(t_up, job.job_id, pods))
        t_down = t_up + 0.5 * period_s
        if t_down < job.arrival + horizon_s:
            out.append(ScaleEvent(t_down, job.job_id, -pods))
        t_up += period_s
        n += 1
    return out


def pool_quantile(
    latencies: np.ndarray, q: float, strict: bool = False
) -> float:
    """Quantile over request latencies, inf-aware.  ``strict`` (tail
    quantiles): any never-finishing request (φ stuck at zero) poisons the
    estimate to inf; otherwise unfinished requests are dropped (median of
    what finished).  The single implementation behind both the per-job
    rows (:func:`summarize_requests`) and the pooled summary
    (:meth:`~repro.sim.scheduler.Simulator.serving_summary`)."""
    lat = np.asarray(latencies, dtype=np.float64)
    if lat.size == 0:
        return math.nan
    finite = lat[np.isfinite(lat)]
    if finite.size == 0 or (strict and finite.size < lat.size):
        return math.inf
    return float(np.quantile(finite, q))


def slo_availability(
    timeline: Sequence[Tuple[float, float]],
    phi_floor: float,
    t0: float,
    t1: float,
) -> float:
    """Share of ``[t0, t1]`` during which the fleet's realized bandwidth
    fraction φ is at least ``phi_floor`` — the *time-based* availability
    behind the chaos benchmarks (request-based goodput weights by
    arrivals; this weights by wall clock, so a quiet-hour outage still
    counts).

    ``timeline`` is the piecewise-constant φ record the scheduler keeps
    per serving job (same input as :func:`request_latencies`).  Time
    before the first sample counts as *unavailable* (the fleet was not
    serving yet); the last sample holds to ``t1``.

    >>> tl = [(0.0, 1.0), (40.0, 0.2), (80.0, 1.0)]
    >>> slo_availability(tl, 0.5, 0.0, 100.0)
    0.6
    >>> slo_availability([], 0.5, 0.0, 100.0)
    0.0
    """
    if t1 <= t0:
        return math.nan
    if not timeline:
        return 0.0
    ts = [max(t0, min(t1, t)) for t, _ in timeline] + [t1]
    ok = 0.0
    for n, (_, phi) in enumerate(timeline):
        if phi >= phi_floor:
            ok += max(0.0, ts[n + 1] - ts[n])
    return ok / (t1 - t0)


def summarize_requests(
    latencies: np.ndarray, slo_s: float
) -> Dict[str, float]:
    """p50/p99/goodput summary of one serving job's request latencies.

    *Goodput* is the share of requests whose KV transfer completed within
    ``slo_s`` (requests that never finish — φ stuck at zero — count
    against it).
    """
    lat = np.asarray(latencies, dtype=np.float64)
    if lat.size == 0:
        return {
            "requests": 0.0, "p50_s": math.nan, "p99_s": math.nan,
            "max_s": math.nan, "goodput": math.nan,
        }
    finite = lat[np.isfinite(lat)]
    served = finite[finite <= slo_s]
    return {
        "requests": float(lat.size),
        "p50_s": pool_quantile(lat, 0.5),
        "p99_s": pool_quantile(lat, 0.99, strict=True),
        "max_s": pool_quantile(lat, 1.0, strict=True),
        "goodput": float(served.size / lat.size),
    }
