"""Multi-tenant job trace generation (paper §6.3 workload model).

Jobs follow the Sense-dataset-style [12] profile the paper uses: Poisson
arrivals, GPU counts drawn from powers-of-two buckets with a heavy tail,
log-normal service times.  The arrival rate is calibrated to a target
*workload level* (paper eq. 17):

    workload = Σ_k  k · λ_k · T_k / GPU_num
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.logical import Job

# (num_gpus, probability, mean service seconds) — testbed §5 mixes models on
# {16, 32, 64, 96, 128} GPUs; large-scale sim extends the tail as in [12].
JOB_MIX: Tuple[Tuple[int, float, float], ...] = (
    (8, 0.28, 1800.0),
    (16, 0.22, 2400.0),
    (32, 0.18, 3600.0),
    (64, 0.12, 5400.0),
    (96, 0.06, 5400.0),
    (128, 0.06, 7200.0),
    (256, 0.04, 9000.0),
    (512, 0.02, 10800.0),
    (1024, 0.015, 14400.0),
    (2048, 0.005, 21600.0),
)

MODELS = ("llama-7b", "llama2-7b", "llama2-13b", "pangu-alpha-6b", "gpt2-13b")
# larger-scale archetypes drawn only for big jobs: a mixtral-class MoE whose
# EP all-to-all spills across pods, and a 70B-class job that pipelines
# stages across pods (PP chain traffic)
BIG_MODELS = ("mixtral-8x7b", "llama2-70b")
BIG_MODEL_MIN_GPUS = 256

# LEGACY calibration fallback: fraction of a step that is cross-pod
# communication on the Best fabric.  The scheduler now derives per-job
# fractions from the collective planner (``dist.demand.comm_fraction_for``);
# this table only covers models without a planner profile.
COMM_FRACTION = {
    "llama-7b": 0.18,
    "llama2-7b": 0.18,
    "llama2-13b": 0.22,
    "pangu-alpha-6b": 0.30,
    "gpt2-13b": 0.28,
}

# parallelism plan per archetype: (ep_ways, pp_stages)
_MODEL_PLAN = {
    "pangu-alpha-6b": (2, 1),
    "gpt2-13b": (2, 1),
    "mixtral-8x7b": (8, 1),
    "llama2-70b": (1, 4),
}


def expected_gpu_seconds() -> float:
    return sum(k * p * t for k, p, t in JOB_MIX)


def arrival_rate_for(workload_level: float, num_gpus: int) -> float:
    """λ (jobs/s) so that eq. (17) hits ``workload_level``."""
    return workload_level * num_gpus / expected_gpu_seconds()


# serving-fleet archetypes mixed into a trace (model, kv prompt tokens,
# requests/s per 64 fleet GPUs): a dense 13B chat tier and a GQA MoE tier
SERVING_MIX: Tuple[Tuple[str, int, float], ...] = (
    ("llama2-13b", 2048, 16.0),
    ("mixtral-8x7b", 4096, 48.0),
)


def generate_trace(
    num_jobs: int,
    num_gpus: int,
    workload_level: float = 0.801,
    seed: int = 0,
    max_job_gpus: Optional[int] = None,
    serving_jobs: int = 0,
    serving_gpus: int = 128,
    serving_diurnal: float = 0.0,
    serving_load: float = 1.0,
) -> List[Job]:
    """Poisson arrivals, mixed sizes, log-normal service times.

    ``serving_jobs > 0`` appends that many long-lived inference-serving
    fleets (:func:`repro.sim.serving.serving_job`) of ``serving_gpus``
    GPUs each, cycling through :data:`SERVING_MIX` with request rates
    scaled by ``serving_load`` and fleet size.  Serving fleets arrive
    jittered inside the first training inter-arrival so they are placed
    before the queue builds up.  The training stream is drawn first from
    its own generator state, so a mixed trace's training jobs are
    *byte-identical* to the ``serving_jobs=0`` trace with the same seed
    (determinism pinned in ``tests/test_serving.py``).
    """
    from .serving import serving_job  # local: avoid import cycle at load

    rng = np.random.default_rng(seed)
    lam = arrival_rate_for(workload_level, num_gpus)
    sizes = np.array([k for k, _, _ in JOB_MIX])
    probs = np.array([p for _, p, _ in JOB_MIX])
    means = np.array([t for _, _, t in JOB_MIX])
    if max_job_gpus is not None:
        keep = sizes <= max_job_gpus
        sizes, probs, means = sizes[keep], probs[keep], means[keep]
    probs = probs / probs.sum()

    t = 0.0
    jobs: List[Job] = []
    for jid in range(num_jobs):
        t += rng.exponential(1.0 / lam)
        b = rng.choice(len(sizes), p=probs)
        # log-normal around the bucket mean, sigma=0.5
        service = float(means[b] * rng.lognormal(mean=-0.125, sigma=0.5))
        gpus = int(sizes[b])
        if gpus >= BIG_MODEL_MIN_GPUS and rng.random() < 0.5:
            model = BIG_MODELS[int(rng.integers(len(BIG_MODELS)))]
        else:
            model = MODELS[int(rng.integers(len(MODELS)))]
        ep, pp = _MODEL_PLAN.get(model, (1, 1))
        jobs.append(
            Job(
                job_id=jid,
                num_gpus=gpus,
                arrival=t,
                service_time=service,
                model=model,
                tp=8,
                ep=ep,
                pp=pp,
            )
        )
    if serving_jobs > 0:
        # separate generator: the training stream above stays identical
        srng = np.random.default_rng([seed, 0x5E27E])
        first_t = jobs[0].arrival if jobs else 0.0
        for k in range(serving_jobs):
            model, kv_tokens, rate64 = SERVING_MIX[k % len(SERVING_MIX)]
            jobs.append(
                serving_job(
                    job_id=num_jobs + k,
                    num_gpus=serving_gpus,
                    arrival=float(srng.uniform(0.0, max(first_t, 1e-3))),
                    model=model,
                    req_rate=rate64 * serving_load * serving_gpus / 64.0,
                    kv_tokens=kv_tokens,
                    diurnal=serving_diurnal,
                )
            )
        # keep list position == job_id (the scheduler indexes jobs by id);
        # the event heap orders arrivals regardless of list order
    return jobs
