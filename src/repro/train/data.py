"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step, arch), so:

* any host can generate exactly its shard (multi-host determinism),
* restart-from-checkpoint resumes the stream with no state to save,
* straggler mitigation / elastic rescale just re-partitions index ranges.

Two token distributions:

* ``mode="affine"``: next = (a·tok + b) mod V — a *learnable* structure, so
  tiny smoke-training runs show decreasing loss (used by integration tests).
* ``mode="uniform"``: i.i.d. tokens (throughput benchmarking).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int  # global batch
    seq: int
    seed: int = 0
    mode: str = "affine"  # affine | uniform
    a: int = 31
    b: int = 7


class SyntheticData:
    def __init__(self, cfg: DataConfig, model_cfg=None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def _tokens(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the global batch at `step` (deterministic)."""
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        if c.mode == "uniform":
            all_rows = rng.integers(0, c.vocab_size, size=(c.batch, c.seq))
            return all_rows[lo:hi].astype(np.int32)
        starts = rng.integers(0, c.vocab_size, size=(c.batch,))[lo:hi]
        toks = np.empty((hi - lo, c.seq), dtype=np.int64)
        toks[:, 0] = starts
        for t in range(1, c.seq):
            toks[:, t] = (c.a * toks[:, t - 1] + c.b) % c.vocab_size
        return toks.astype(np.int32)

    def batch_at(self, step: int, lo: int = 0, hi: Optional[int] = None) -> Dict[str, np.ndarray]:
        c = self.cfg
        hi = hi if hi is not None else c.batch
        toks = self._tokens(step, lo, hi)
        nxt = (c.a * toks.astype(np.int64) + c.b) % c.vocab_size if c.mode == "affine" else np.roll(toks, -1, 1)
        out = {"tokens": toks, "targets": nxt.astype(np.int32)}
        m = self.model_cfg
        if m is not None and m.family == "audio":
            rng = np.random.default_rng((c.seed, step, 1))
            out["frames"] = rng.normal(size=(hi - lo, m.encoder_seq, m.d_model)).astype(
                np.float32
            )
        if m is not None and m.family == "vlm":
            rng = np.random.default_rng((c.seed, step, 2))
            out["patches"] = rng.normal(
                size=(hi - lo, m.vision_tokens, m.vision_dim)
            ).astype(np.float32)
        return out
