"""AdamW + cosine schedule + global-norm clipping, from scratch (no optax).

State is a plain dict pytree {"m", "v", "step"} in fp32 regardless of param
dtype; ZeRO-1 sharding of m/v is applied by the caller via
``dist.sharding.zero1_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def schedule(opt: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(opt.warmup_steps, 1))
    prog = jnp.clip(
        (step - opt.warmup_steps) / max(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = opt.min_lr_frac + (1 - opt.min_lr_frac) * cos
    return opt.lr * warm * frac


def adamw_init(params: Any) -> dict:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(
    grads: Any, state: dict, params: Any, opt: OptConfig
) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    lr = schedule(opt, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = opt.beta1, opt.beta2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + opt.eps) + opt.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step + 1,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
