"""Distributed train steps.

Two interchangeable step builders:

* :func:`make_pjit_step` — the *paper-faithful baseline* data plane: plain
  pjit/GSPMD; the DP gradient reduction lowers to one flat all-reduce over
  (pod × data).  Cross-pod bytes = full gradient size.

* :func:`make_hierarchical_step` — the beyond-paper optimized data plane:
  `jax.shard_map` manual over the DP axes (model axis stays auto/GSPMD).
  Per-leaf reduce-scatter in-pod → (optionally int8-compressed) cross-pod
  all-reduce → ZeRO-1 optimizer update on the gradient *shard* → in-pod
  all-gather of the updated parameters.  Cross-pod bytes shrink by the
  in-pod DP width (16×) and optimizer memory by the same factor.

Both support gradient-accumulation microbatching via ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..dist.sharding import (
    batch_specs,
    mesh_axis_sizes,
    param_specs,
    shard_map_dp,
    to_shardings,
    zero1_dim,
    zero1_specs,
    _path_str,
)
from ..launch.mesh import dp_axes
from .optimizer import OptConfig, adamw_init, adamw_update, global_norm, schedule


@dataclasses.dataclass(frozen=True)
class TrainHparams:
    grad_accum: int = 1
    hierarchical: bool = False  # shard_map hierarchical collectives
    compress: bool = False  # int8 cross-pod gradient compression
    zero1: bool = False  # shard optimizer state over data axis
    fsdp: bool = False  # ZeRO-3: shard params over data; gather per layer


def make_train_state(api, key) -> dict:
    params = api.init(key)
    return {"params": params, "opt": adamw_init(params)}


def train_state_specs(state_shape: dict, mesh, cfg, hp: TrainHparams):
    pspecs = param_specs(state_shape["params"], mesh, cfg, fsdp=hp.fsdp)
    if hp.zero1 or hp.hierarchical or hp.fsdp:
        # fsdp runs shard the fp32 moments over (data, pod) — with params
        # already data-sharded, the moments are the HBM bottleneck
        mspecs = zero1_specs(state_shape["opt"]["m"], mesh, cfg, use_pod=hp.fsdp)
        vspecs = zero1_specs(state_shape["opt"]["v"], mesh, cfg, use_pod=hp.fsdp)
    else:
        mspecs = param_specs(state_shape["opt"]["m"], mesh, cfg)
        vspecs = param_specs(state_shape["opt"]["v"], mesh, cfg)
    return {
        "params": pspecs,
        "opt": {"m": mspecs, "v": vspecs, "step": P()},
    }


def _accum_grads(loss_fn, params, batch, n_micro: int):
    """Gradient accumulation over microbatches with lax.scan."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def micro(b):
        return jax.tree_util.tree_map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]), b
        )

    mb = micro(batch)

    def step(carry, b):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(loss_fn)(params, b)
        return (
            loss_acc + loss / n_micro,
            jax.tree_util.tree_map(lambda a, x: a + x / n_micro, g_acc, g),
        ), None

    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(step, (jnp.zeros(()), zeros), mb)
    return loss, grads


# ---------------------------------------------------------------------------
# baseline: plain pjit
# ---------------------------------------------------------------------------

def make_pjit_step(api, cfg, opt: OptConfig, mesh, hp: TrainHparams, batch_shape):
    """Returns (jitted step, state_shardings, batch_shardings)."""
    state_shape = jax.eval_shape(lambda k: make_train_state(api, k), jax.random.PRNGKey(0))
    sspecs = train_state_specs(state_shape, mesh, cfg, hp)
    s_shard = to_shardings(sspecs, mesh)
    b_shard = to_shardings(batch_specs(batch_shape, mesh), mesh)

    def step(state, batch):
        loss, grads = _accum_grads(
            lambda p, b: api.loss(p, b), state["params"], batch, hp.grad_accum
        )
        new_params, new_opt, metrics = adamw_update(
            grads, state["opt"], state["params"], opt
        )
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    jitted = jax.jit(
        step,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, NamedSharding(mesh, P())),
        donate_argnums=(0,),
    )
    return jitted, s_shard, b_shard


# ---------------------------------------------------------------------------
# optimized: hierarchical shard_map + ZeRO-1 (+ int8 cross-pod compression)
# ---------------------------------------------------------------------------

def make_hierarchical_step(api, cfg, opt: OptConfig, mesh, hp: TrainHparams, batch_shape):
    """shard_map over DP axes; model axis remains auto (GSPMD)."""
    sizes = mesh_axis_sizes(mesh)
    dp = dp_axes(mesh)
    data_size = sizes.get("data", 1)
    has_pod = "pod" in sizes
    pod_size = sizes.get("pod", 1)
    n_dp = data_size * pod_size
    model_size = sizes.get("model", 1)
    in_moe = cfg.moe is not None

    state_shape = jax.eval_shape(lambda k: make_train_state(api, k), jax.random.PRNGKey(0))
    sspecs = train_state_specs(state_shape, mesh, cfg, hp)
    s_shard = to_shardings(sspecs, mesh)
    bspecs = batch_specs(batch_shape, mesh)
    b_shard = to_shardings(bspecs, mesh)

    # manual (DP-axes-only) views of the same specs
    dp_set = set(dp)

    def _dp_only_spec(s: P) -> P:
        out = []
        for a in s:
            if a is None:
                out.append(None)
            elif isinstance(a, (tuple, list)):
                kept = tuple(x for x in a if x in dp_set)
                out.append(kept if kept else None)
            else:
                out.append(a if a in dp_set else None)
        return P(*out)

    def dp_only(spec_tree):
        return jax.tree_util.tree_map(
            _dp_only_spec, spec_tree, is_leaf=lambda x: isinstance(x, P)
        )

    params_dp = jax.tree_util.tree_map(
        lambda s: P(*[None] * len(s)), sspecs["params"],
        is_leaf=lambda x: isinstance(x, P),
    )
    opt_dp = dp_only(sspecs["opt"])
    batch_dp = dp_only(bspecs)

    # per-leaf scatter dims (must match zero1_specs)
    leaf_paths = [
        _path_str(p)
        for p, _ in jax.tree_util.tree_flatten_with_path(state_shape["params"])[0]
    ]
    leaf_shapes = [
        tuple(l.shape)
        for l in jax.tree_util.tree_leaves(state_shape["params"])
    ]
    scatter_dims = [
        zero1_dim(p, s, model_size, data_size, in_moe)
        for p, s in zip(leaf_paths, leaf_shapes)
    ]
    treedef = jax.tree_util.tree_structure(state_shape["params"])

    def body(state, batch):
        params = state["params"]
        loss, grads = _accum_grads(
            lambda p, b: api.loss(p, b), params, batch, hp.grad_accum
        )
        loss = jax.lax.pmean(loss, dp)

        flat_g = treedef.flatten_up_to(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_m = treedef.flatten_up_to(state["opt"]["m"])
        flat_v = treedef.flatten_up_to(state["opt"]["v"])
        step_ = state["opt"]["step"]

        # ---- global grad norm from shards (no extra gather) -------------
        sq = jnp.zeros(())
        shards = []
        for g, dim in zip(flat_g, scatter_dims):
            g = g.astype(jnp.float32)
            if dim is not None:
                gs = jax.lax.psum_scatter(g, "data", scatter_dimension=dim, tiled=True)
            else:
                gs = jax.lax.psum(g, "data")
            if has_pod:
                if hp.compress:
                    scale = jnp.maximum(
                        jax.lax.pmax(jnp.max(jnp.abs(gs)), "pod"), 1e-12
                    )
                    q = jnp.clip(jnp.round(gs / scale * 127.0), -127, 127)
                    gs = jax.lax.psum(q.astype(jnp.int32), "pod").astype(
                        jnp.float32
                    ) * (scale / 127.0)
                else:
                    gs = jax.lax.psum(gs, "pod")
            gs = gs / n_dp
            shards.append(gs)
            part = jnp.sum(gs * gs)
            if dim is not None:
                part = jax.lax.psum(part, "data")
            sq = sq + part
        gnorm = jnp.sqrt(sq)
        clip = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))

        lr = schedule(opt, step_)
        b1, b2 = opt.beta1, opt.beta2
        t = (step_ + 1).astype(jnp.float32)
        bc1, bc2 = 1 - b1**t, 1 - b2**t

        new_p, new_m, new_v = [], [], []
        for g, p, m, v, dim in zip(shards, flat_p, flat_m, flat_v, scatter_dims):
            g = g * clip
            if dim is not None:
                idx = jax.lax.axis_index("data")
                size = p.shape[dim] // data_size
                p_shard = jax.lax.dynamic_slice_in_dim(p, idx * size, size, axis=dim)
            else:
                p_shard = p
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + opt.eps)
            upd = upd + opt.weight_decay * p_shard.astype(jnp.float32)
            p2 = (p_shard.astype(jnp.float32) - lr * upd).astype(p.dtype)
            if dim is not None:
                p2 = jax.lax.all_gather(p2, "data", axis=dim, tiled=True)
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)

        new_state = {
            "params": treedef.unflatten(new_p),
            "opt": {
                "m": treedef.unflatten(new_m),
                "v": treedef.unflatten(new_v),
                "step": step_ + 1,
            },
        }
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    state_in_specs = {"params": params_dp, "opt": opt_dp}
    sm = shard_map_dp(
        body,
        mesh,
        in_specs=(state_in_specs, batch_dp),
        out_specs=(state_in_specs, P()),
        manual_axes=dp,
    )
    jitted = jax.jit(
        sm,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, NamedSharding(mesh, P())),
        donate_argnums=(0,),
    )
    return jitted, s_shard, b_shard


def make_train_step(api, cfg, opt: OptConfig, mesh, hp: TrainHparams, batch_shape):
    if hp.hierarchical:
        return make_hierarchical_step(api, cfg, opt, mesh, hp, batch_shape)
    return make_pjit_step(api, cfg, opt, mesh, hp, batch_shape)
