# Package marker so `python -m tests.golden.regen` works from the repo root.
