# Golden regression fixtures for the fluid engine (see regen.py).
