"""Golden regression tables: every family, one regeneration entrypoint.

Two golden families live under ``tests/golden/``:

* ``fluid_trace.json`` — per-job JCTs of one seeded fluid-engine run
  (Cross Wiring, incremental MDMCF, a link failure/repair mid-trace and
  a nonzero reconfiguration delay), so *any* behavioral drift in the
  engine — water-filling, dark windows, mask handling, scheduler event
  ordering — shows up as a reviewed diff instead of a silent change.
* ``scenarios/<name>.json`` — the canonical
  :class:`~repro.scenario.runner.ScenarioSummary` of every catalogued
  multi-day scenario (:data:`repro.scenario.CATALOG`), byte-identical
  across reruns and across tracer on/off.

Regenerate *all* families after an intentional behavioral change with:

    PYTHONPATH=src python -m tests.golden.regen

and commit the updated files together with the change.  The entrypoint
prints a per-file ``wrote``/``unchanged`` line so the diff surface is
explicit — no per-suite knowledge needed.
"""
from __future__ import annotations

import json
import math
import os
from typing import Callable, Dict

GOLDEN_DIR = os.path.dirname(__file__)
GOLDEN_PATH = os.path.join(GOLDEN_DIR, "fluid_trace.json")
SCENARIO_DIR = os.path.join(GOLDEN_DIR, "scenarios")

# ---------------------------------------------------------------------------
# family 1: the pinned fluid-engine trace
# ---------------------------------------------------------------------------

SCENARIO = {
    "num_pods": 12,
    "k_spine": 8,
    "k_leaf": 8,
    "n_jobs": 18,
    "seed": 7,
    "workload_level": 0.9,
    "architecture": "cross_wiring",
    "strategy": "mdmcf",
    "engine": "fluid",
    "reconfig_delay_s": 0.01,
    "fault": {"scope": "link", "h": 0, "k": 2, "pod": 3},
}


def run_scenario(tracer=None):
    """Run the pinned scenario; returns (records, simulator).

    ``tracer`` (a :class:`repro.obs.Tracer`) attaches the flight
    recorder; it must never change the table (tracing is passive —
    ``tests/test_obs.py`` pins byte-identity with it on or off)."""
    from repro.fault import FailureEvent, RepairEvent
    from repro.sim import SimConfig, Simulator, generate_trace

    s = SCENARIO
    num_gpus = s["num_pods"] * s["k_spine"] * s["k_leaf"]
    jobs = generate_trace(
        s["n_jobs"], num_gpus=num_gpus, workload_level=s["workload_level"],
        seed=s["seed"], max_job_gpus=num_gpus // 4,
    )
    t_fail = jobs[s["n_jobs"] // 3].arrival
    f = s["fault"]
    events = [
        FailureEvent(t_fail, f["scope"], h=f["h"], k=f["k"], pod=f["pod"]),
        RepairEvent(t_fail + 1800.0, f["scope"], h=f["h"], k=f["k"], pod=f["pod"]),
    ]
    sim = Simulator(
        SimConfig(
            architecture=s["architecture"], strategy=s["strategy"],
            num_pods=s["num_pods"], k_spine=s["k_spine"], k_leaf=s["k_leaf"],
            engine=s["engine"], reconfig_delay_s=s["reconfig_delay_s"],
            tracer=tracer,
        ),
        jobs,
        fault_events=events,
    )
    records = sim.run()
    return records, sim


def build_table(tracer=None):
    records, sim = run_scenario(tracer)
    jct = {
        str(r.job.job_id): (r.jct if math.isfinite(r.finish) else None)
        for r in records
    }
    return {
        "scenario": SCENARIO,
        "jct": jct,
        "downtime_events": sim.downtime_events,
        "reconfig_calls": sim.reconfig_calls,
    }


def _fluid_trace_bytes() -> str:
    return json.dumps(build_table(), indent=1, sort_keys=True) + "\n"


# ---------------------------------------------------------------------------
# family 2: scenario-suite summaries (repro.scenario catalogue)
# ---------------------------------------------------------------------------

def scenario_summary_bytes(name: str) -> str:
    """Canonical golden bytes for one catalogued scenario."""
    from repro.scenario import get_scenario, run_scenario as run_spec

    summary, _ = run_spec(get_scenario(name))
    return summary.to_json() + "\n"


def families() -> Dict[str, Callable[[], str]]:
    """Every golden file → a thunk producing its canonical bytes."""
    from repro.scenario import SCENARIO_NAMES

    fams: Dict[str, Callable[[], str]] = {GOLDEN_PATH: _fluid_trace_bytes}
    for name in SCENARIO_NAMES:
        fams[os.path.join(SCENARIO_DIR, f"{name}.json")] = (
            lambda n=name: scenario_summary_bytes(n)
        )
    return fams


def main() -> None:
    os.makedirs(SCENARIO_DIR, exist_ok=True)
    for path, build in sorted(families().items()):
        new = build()
        old = None
        if os.path.exists(path):
            with open(path) as fh:
                old = fh.read()
        rel = os.path.relpath(path, GOLDEN_DIR)
        if old == new:
            print(f"unchanged {rel}")
            continue
        with open(path, "w") as fh:
            fh.write(new)
        print(f"wrote     {rel} "
              f"({'new file' if old is None else 'contents changed'})")


if __name__ == "__main__":
    main()
