"""Golden fluid-engine trace: scenario definition + regeneration.

The golden table freezes the per-job JCTs of one seeded fluid-engine run
(Cross Wiring, incremental MDMCF, a link failure/repair mid-trace and a
nonzero reconfiguration delay) so that *any* behavioral drift in the
engine — water-filling, dark windows, mask handling, scheduler event
ordering — shows up as a reviewed diff instead of a silent change.

Regenerate after an intentional change with:

    PYTHONPATH=src python -m tests.golden.regen

and commit the updated ``fluid_trace.json`` together with the change.
"""
from __future__ import annotations

import json
import math
import os

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "fluid_trace.json")

SCENARIO = {
    "num_pods": 12,
    "k_spine": 8,
    "k_leaf": 8,
    "n_jobs": 18,
    "seed": 7,
    "workload_level": 0.9,
    "architecture": "cross_wiring",
    "strategy": "mdmcf",
    "engine": "fluid",
    "reconfig_delay_s": 0.01,
    "fault": {"scope": "link", "h": 0, "k": 2, "pod": 3},
}


def run_scenario(tracer=None):
    """Run the pinned scenario; returns (records, simulator).

    ``tracer`` (a :class:`repro.obs.Tracer`) attaches the flight
    recorder; it must never change the table (tracing is passive —
    ``tests/test_obs.py`` pins byte-identity with it on or off)."""
    from repro.fault import FailureEvent, RepairEvent
    from repro.sim import SimConfig, Simulator, generate_trace

    s = SCENARIO
    num_gpus = s["num_pods"] * s["k_spine"] * s["k_leaf"]
    jobs = generate_trace(
        s["n_jobs"], num_gpus=num_gpus, workload_level=s["workload_level"],
        seed=s["seed"], max_job_gpus=num_gpus // 4,
    )
    t_fail = jobs[s["n_jobs"] // 3].arrival
    f = s["fault"]
    events = [
        FailureEvent(t_fail, f["scope"], h=f["h"], k=f["k"], pod=f["pod"]),
        RepairEvent(t_fail + 1800.0, f["scope"], h=f["h"], k=f["k"], pod=f["pod"]),
    ]
    sim = Simulator(
        SimConfig(
            architecture=s["architecture"], strategy=s["strategy"],
            num_pods=s["num_pods"], k_spine=s["k_spine"], k_leaf=s["k_leaf"],
            engine=s["engine"], reconfig_delay_s=s["reconfig_delay_s"],
            tracer=tracer,
        ),
        jobs,
        fault_events=events,
    )
    records = sim.run()
    return records, sim


def build_table(tracer=None):
    records, sim = run_scenario(tracer)
    jct = {
        str(r.job.job_id): (r.jct if math.isfinite(r.finish) else None)
        for r in records
    }
    return {
        "scenario": SCENARIO,
        "jct": jct,
        "downtime_events": sim.downtime_events,
        "reconfig_calls": sim.reconfig_calls,
    }


def main() -> None:
    table = build_table()
    with open(GOLDEN_PATH, "w") as fh:
        json.dump(table, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {GOLDEN_PATH}: {len(table['jct'])} jobs, "
          f"{table['downtime_events']} downtime windows")


if __name__ == "__main__":
    main()
