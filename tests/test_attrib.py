"""Tests for the blame-attribution engine (repro.obs.attrib).

The load-bearing property is **conservation**: every decomposition must
reconstruct the measured slowdown exactly (residual ≤ 1e-6, in practice
~1e-12), because each sub-interval of a request's transfer window — and
each second of a training job's JCT — is assigned to exactly one cause.
A residual means the replay no longer matches what the scheduler
integrated, which is how the engine caught two real scheduler bugs
(finish events anchored at stale times; stints ending before the clock
the progress was valued at — see the same-timestamp regression tests).
"""
import math

import numpy as np
import pytest

from repro.fault import FailureEvent, FaultModel, RepairEvent, merge_events
from repro.obs import attribute_jobs, attribute_requests
from repro.obs.attrib import (
    CAUSES,
    JOB_CAUSES,
    AttribLog,
    Blame,
    Segmentation,
)
from repro.sim import SimConfig, Simulator, autoscale_events, generate_trace

P, K = 12, 8
GPUS = P * K * K


def _jobs(serving=2):
    return generate_trace(
        14, num_gpus=GPUS, workload_level=0.9, seed=3,
        max_job_gpus=GPUS // 4, serving_jobs=serving, serving_gpus=128,
    )


def _pods_at(t, jobs):
    """(training pod, serving pod) hosting work at time ``t`` (probe)."""
    probe = Simulator(
        SimConfig(architecture="cross_wiring", strategy="mdmcf",
                  num_pods=P, k_spine=K, k_leaf=K, engine="fluid"),
        _jobs(),
    )
    probe.run(until=t)
    by_kind = {"train": set(), "serve": set()}
    for r in probe.running.values():
        by_kind[r.job.kind].update(r.pods)
    train = sorted(by_kind["train"] - by_kind["serve"])
    serve = sorted(by_kind["serve"])
    assert train and serve, "scenario drifted: need both kinds running"
    return train[0], serve[0]


@pytest.fixture(scope="module")
def faulted_run():
    """Mixed train+serve fluid run with pod failures hitting *both* a
    training pod (restarts) and a serving pod (degraded φ, dark windows
    on serving pairs) — every cause class live."""
    jobs = _jobs()
    t_fail = jobs[7].arrival + 5.0
    train_pod, serve_pod = _pods_at(t_fail, jobs)
    cfg = SimConfig(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=P, k_spine=K, k_leaf=K, engine="fluid",
        reconfig_delay_s=0.01, recovery_policy="ckpt_restart",
    )
    sim = Simulator(cfg, jobs, fault_events=[
        FailureEvent(t_fail, "pod", pod=train_pod),
        FailureEvent(t_fail + 40.0, "pod", pod=serve_pod),
        RepairEvent(t_fail + 3600.0, "pod", pod=train_pod),
        RepairEvent(t_fail + 3600.0, "pod", pod=serve_pod),
    ])
    sim.run()
    return sim


# ---- request attribution ---------------------------------------------------

def test_request_blame_conserves(faulted_run):
    attr = attribute_requests(faulted_run)
    assert attr["requests"] > 0 and attr["finite"] > 0
    assert attr["conserved"], f"max_residual={attr['max_residual']:.3e}"
    assert attr["max_residual"] <= 1e-9  # in practice float-noise exact
    # pooled totals are the fsum of the per-fleet rows
    for c in CAUSES:
        assert attr["totals"][c] == pytest.approx(
            math.fsum(r["blame"][c] for r in attr["jobs"].values())
        )
    # pooled blame reconstructs the pooled measured slowdown; per-request
    # residuals are ~1e-12 but millions of requests accumulate, so the
    # aggregate tolerance scales with the request count
    assert attr["slowdown_s"] == pytest.approx(
        math.fsum(r["slowdown_s"] for r in attr["jobs"].values()),
        abs=attr["requests"] * 1e-9,
    )


def test_request_blame_rows_have_full_shape(faulted_run):
    attr = attribute_requests(faulted_run)
    for row in attr["jobs"].values():
        assert set(row["blame"]) == set(CAUSES)
        assert set(row["p99_blame"]) == set(CAUSES)
        assert all(v >= 0.0 for v in row["blame"].values())
        # tail split is per-request mean: bounded by total / requests
        assert row["requests"] >= row["stalled"] >= 0


@pytest.fixture(scope="module")
def loaded_serving_run():
    """The serving-benchmark scenario at high load: link failures keep
    the fabric in degraded mode and autoscale events churn the control
    plane, so serving φ genuinely dips (a pure pod failure is absorbed
    instantly by the re-solve and prices to zero — correctly)."""
    horizon = 2500.0
    jobs = generate_trace(
        24, num_gpus=GPUS, workload_level=0.801, seed=0,
        max_job_gpus=GPUS // 4, serving_jobs=2, serving_gpus=4 * K * K,
        serving_diurnal=0.3, serving_load=2.0,
    )
    evs = list(FaultModel(
        num_pods=P, k_spine=K, num_groups=2,
        link_mtbf_s=600.0 * 0.995 / 0.005, link_mttr_s=600.0, seed=7,
    ).sample(horizon))
    for j in jobs:
        if j.kind == "serve":
            evs += autoscale_events(j, horizon, period_s=1200.0)
    cfg = SimConfig(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=P, k_spine=K, k_leaf=K, engine="fluid",
        reconfig_delay_s=0.1, serving_period_s=1200.0,
    )
    sim = Simulator(cfg, jobs, seed=0, fault_events=merge_events(evs))
    sim.run(until=horizon)
    return sim


def test_loaded_run_produces_named_serving_blame(loaded_serving_run):
    """Under link faults + autoscale churn the slowdown arrives
    *explained*: degraded/φ-shortfall blame from the failed transceivers
    and dark-window blame from reconfigurations — and still conserves."""
    attr = attribute_requests(loaded_serving_run)
    t = attr["totals"]
    assert attr["conserved"], f"max_residual={attr['max_residual']:.3e}"
    assert attr["slowdown_s"] > 0.0
    assert t["degraded"] + t["phi_shortfall"] > 0.0
    dark = t["autoscale_lag"] + t["dark_incremental"] + t["dark_cold"]
    assert dark > 0.0
    # the p99 tail split is populated and bounded by the tail latency
    tail = attr["p99_blame"]
    assert any(v > 0 for v in tail.values())


# ---- job attribution -------------------------------------------------------

@pytest.mark.parametrize("policy", [
    "rewire_around", "ckpt_restart", "shrink_collective", "cheapest",
])
@pytest.mark.parametrize("engine", ["analytic", "fluid"])
def test_job_blame_conserves_across_policies(policy, engine):
    jobs = generate_trace(
        16, num_gpus=GPUS, workload_level=0.801, seed=0,
        max_job_gpus=GPUS // 4,
    )
    t_fail = jobs[7].arrival  # exactly on an arrival: the hard case
    cfg = SimConfig(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=P, k_spine=K, k_leaf=K, engine=engine,
        recovery_policy=policy,
        reconfig_delay_s=0.1 if engine == "fluid" else 0.0,
    )
    sim = Simulator(cfg, jobs, seed=0, fault_events=[
        FailureEvent(t_fail, "pod", pod=1),
        RepairEvent(t_fail + 7200.0, "pod", pod=1),
    ])
    sim.run()
    blames = attribute_jobs(sim)
    assert blames, "no finished training jobs"
    worst = max(abs(b.residual) for b in blames.values())
    assert worst <= 1e-6, f"{policy}@{engine}: residual {worst:.3e}"
    for b in blames.values():
        assert b.conserved()
        assert set(b.causes) <= set(JOB_CAUSES)
        assert all(v >= -1e-12 for v in b.causes.values())


def test_restart_blame_names_rollback_and_restart(faulted_run):
    blames = attribute_jobs(faulted_run)
    restarted = [
        jid for jid, rec in faulted_run.records.items()
        if rec.restarts > 0 and math.isfinite(rec.finish)
    ]
    assert restarted, "fault must restart at least one finished job"
    for jid in restarted:
        b = blames[jid]
        assert b.causes["restart"] > 0.0
        assert b.causes["rollback"] > 0.0
        assert abs(b.residual) <= 1e-6


def test_same_timestamp_arrival_and_fault_regression():
    """A fault at *exactly* a job-arrival timestamp: the arrival's start
    advances runners to ``now + comp_s`` before the fault handler runs,
    so both the kill bookkeeping and rescheduled finishes must anchor on
    ``r.last_t``, not the event time.  Each bug showed up as a residual
    of exactly one solver comp_s (1.6e-4 s at 1024 GPUs)."""
    num_pods, k = 16, 8
    jobs = generate_trace(
        40, num_gpus=num_pods * k * k, workload_level=0.801, seed=0,
        max_job_gpus=num_pods * k * k // 4,
    )
    t_fail = jobs[13].arrival
    cfg = SimConfig(
        architecture="cross_wiring", strategy="mdmcf", num_pods=num_pods,
        k_spine=k, k_leaf=k, engine="analytic",
        recovery_policy="ckpt_restart",
    )
    sim = Simulator(cfg, jobs, seed=0, fault_events=[
        FailureEvent(t_fail, "pod", pod=1),
        RepairEvent(t_fail + 7200.0, "pod", pod=1),
    ])
    sim.run()
    worst = max(abs(b.residual) for b in attribute_jobs(sim).values())
    assert worst <= 1e-6, f"comp_s-sized leak is back: {worst:.3e}"


# ---- Segmentation / AttribLog units ----------------------------------------

def test_segmentation_partitions_by_cause_priority():
    """(1 − φ) time lands on the highest-priority cause covering it:
    dark beats degraded beats phi_shortfall; φ = 1 time blames nothing."""
    log = AttribLog()
    log.dark_window(2.0, 3.0, "cold", "fault")
    log.degraded_begin(0.0)
    log.degraded_end(10.0)
    tl = [(0.0, 1.0), (1.0, 0.5), (4.0, 1.0)]  # φ drops on [1, 4]
    seg = Segmentation.for_timeline(tl, log, hi=10.0, lo=0.0)
    blame = seg.blame_window(0.0, 10.0)
    # slowdown price of the window: ∫(1−φ) dt = 3 s · 0.5
    assert math.fsum(blame.values()) == pytest.approx(1.5)
    assert blame["dark_cold"] == pytest.approx(0.5)   # [2, 3] · 0.5
    assert blame["degraded"] == pytest.approx(1.0)    # rest of [1, 4]
    assert blame["phi_shortfall"] == 0.0
    assert blame["queue"] == 0.0


def test_segmentation_pre_timeline_window_is_queue():
    log = AttribLog()
    tl = [(5.0, 1.0)]
    seg = Segmentation.for_timeline(tl, log, hi=10.0, lo=0.0)
    blame = seg.blame_window(0.0, 6.0)
    # before the first breakpoint φ is unknown (fleet not up): queue
    assert blame["queue"] == pytest.approx(5.0)
    assert math.fsum(blame.values()) == pytest.approx(5.0)


def test_blame_residual_and_conserved():
    b = Blame(1, 10.0, {"queue": 6.0, "restart": 4.0})
    assert b.residual == pytest.approx(0.0)
    assert b.conserved()
    b2 = Blame(2, 10.0, {"queue": 6.0})
    assert b2.residual == pytest.approx(4.0)
    assert not b2.conserved()
    assert b2.conserved(tol=5.0)
