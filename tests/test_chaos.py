"""repro.fault.chaos: correlated/gray failure injection.

Primitive generators (top-of-pod bursts, SRLG cuts, flapping and derated
links), the declarative ChaosScenario compiler, the standard catalogue,
and the PortMask layers gray failures ride on (cordoned + link_health).
Everything here must be deterministic given the scenario — the chaos
benchmark's passive/remediate comparison depends on both runs seeing the
identical fault stream."""
import numpy as np
import pytest

from repro.core.topology import ClusterSpec
from repro.fault import (
    ChaosScenario,
    DerateEvent,
    FailureEvent,
    PortMask,
    RepairEvent,
    apply_event,
    flapping_link,
    gray_derate,
    scenario_events,
    shared_risk_group,
    standard_scenarios,
    top_of_pod_burst,
)


# ---------------------------------------------------------------------------
# primitive generators
# ---------------------------------------------------------------------------

def test_top_of_pod_burst_is_correlated_and_paired():
    evs = top_of_pod_burst(100.0, group=1, first_ocs=6, size=3,
                           repair_s=50.0, k_spine=8)
    fails = [e for e in evs if isinstance(e, FailureEvent)]
    reps = [e for e in evs if isinstance(e, RepairEvent)]
    # all failures at the same instant (one power domain), consecutive
    # OCSes wrapping around the spine, all in the blast group
    assert [e.time for e in fails] == [100.0] * 3
    assert sorted(e.k for e in fails) == [0, 6, 7]
    assert all(e.h == 1 and e.scope == "ocs" for e in fails)
    assert all(e.time == 150.0 for e in reps)


def test_top_of_pod_burst_stagger_is_seeded():
    kw = dict(group=0, first_ocs=0, size=4, repair_s=100.0, k_spine=8,
              stagger_s=30.0)
    a = top_of_pod_burst(0.0, seed=1, **kw)
    b = top_of_pod_burst(0.0, seed=1, **kw)
    c = top_of_pod_burst(0.0, seed=2, **kw)
    assert a == b
    rep = lambda evs: [e.time for e in evs if isinstance(e, RepairEvent)]
    assert rep(a) != rep(c)
    assert all(t >= 100.0 for t in rep(a))  # jitter only delays


def test_top_of_pod_burst_size_validated():
    for size in (0, 9):
        with pytest.raises(ValueError):
            top_of_pod_burst(0.0, 0, 0, size, 10.0, k_spine=8)


def test_shared_risk_group_cuts_together():
    links = ((0, 1, 2), (1, 3, 4), (0, 5, 2))
    evs = shared_risk_group(500.0, links, repair_s=250.0)
    fails = [e for e in evs if isinstance(e, FailureEvent)]
    assert {(e.h, e.k, e.pod) for e in fails} == set(links)
    assert all(e.time == 500.0 and e.scope == "link" for e in fails)
    assert all(
        e.time == 750.0 for e in evs if isinstance(e, RepairEvent)
    )


def test_flapping_link_alternates_with_duty():
    evs = flapping_link((0, 2, 3), t0=10.0, until=70.0, period_s=30.0,
                        duty=0.2)
    # cycles start at 10 and 40 (60 < until, 70 ends it)
    assert [(type(e).__name__, e.time) for e in evs] == [
        ("FailureEvent", 10.0), ("RepairEvent", 16.0),
        ("FailureEvent", 40.0), ("RepairEvent", 46.0),
    ]
    with pytest.raises(ValueError):
        flapping_link((0, 0, 0), 0.0, 10.0, period_s=0.0)
    with pytest.raises(ValueError):
        flapping_link((0, 0, 0), 0.0, 10.0, period_s=5.0, duty=1.0)


def test_gray_derate_pairs_with_restore():
    lo, hi = gray_derate((1, 0, 5), 100.0, 400.0, health=0.3)
    assert isinstance(lo, DerateEvent) and lo.health == 0.3
    assert hi.time == 400.0 and hi.health == 1.0
    with pytest.raises(ValueError):
        DerateEvent(0.0, health=0.0)
    with pytest.raises(ValueError):
        DerateEvent(0.0, health=1.5)


# ---------------------------------------------------------------------------
# scenario compiler
# ---------------------------------------------------------------------------

def test_scenario_events_compose_sorted_deterministic():
    sc = ChaosScenario(
        name="compound", horizon_s=7200.0,
        burst_at_s=1000.0, burst_size=2, burst_repair_s=2000.0,
        srlg_at_s=1500.0, srlg_links=((0, 0, 1), (0, 0, 2)),
        flap_links=((1, 2, 3),), flap_period_s=600.0,
        derate_links=((0, 4, 5),), derate_health=0.5,
    )
    a, b = scenario_events(sc, k_spine=8), scenario_events(sc, k_spine=8)
    assert a == b
    times = [e.time for e in a]
    assert times == sorted(times)
    # every component family is represented
    assert any(isinstance(e, DerateEvent) for e in a)
    assert any(e.scope == "ocs" for e in a if isinstance(e, FailureEvent))
    assert any(e.scope == "link" for e in a if isinstance(e, FailureEvent))


def test_scenario_defaults_span_horizon():
    sc = ChaosScenario(name="f", horizon_s=3000.0,
                       flap_links=((0, 0, 0),), flap_period_s=1000.0)
    evs = scenario_events(sc, k_spine=8)
    fails = [e.time for e in evs if isinstance(e, FailureEvent)]
    assert fails == [0.0, 1000.0, 2000.0]  # flap_until defaults to horizon
    with pytest.raises(ValueError):
        ChaosScenario(name="bad", horizon_s=0.0)


def test_standard_scenarios_catalogue_in_bounds():
    P, K, H = 12, 8, 8 * 3600.0
    cat = standard_scenarios(P, K, H)
    assert [sc.name for sc in cat] == [
        "top_of_pod_burst", "gray_flap", "burst_flap",
    ]
    for sc in cat:
        evs = scenario_events(sc, K)
        assert evs, sc.name
        for e in evs:
            if isinstance(e, (FailureEvent, RepairEvent, DerateEvent)):
                assert 0 <= e.h < 2          # sim_groups default
                assert 0 <= e.k < K
                assert 0 <= e.pod < P
        assert min(e.time for e in evs) >= 0.0
        # failures start inside the horizon (repairs may trail past)
        fails = [e.time for e in evs if not isinstance(e, RepairEvent)]
        assert max(fails) <= H


# ---------------------------------------------------------------------------
# the mask layers gray failures ride on
# ---------------------------------------------------------------------------

def _mask(p=8, k=8, groups=2):
    return PortMask.healthy(ClusterSpec(num_pods=p, k_spine=k, k_leaf=k),
                            num_groups=groups)


def test_cordon_blocks_te_but_is_not_a_failure():
    m = _mask()
    m.cordon_link(0, 2, 3)
    assert not m.is_trivial()
    assert m.egress_blocked()[0, 2, 3] and m.ingress_blocked()[0, 2, 3]
    # underlying port layers untouched: the slot is administratively
    # out, not broken
    assert not m.port_down_eg[0, 2, 3] and not m.port_down_in[0, 2, 3]
    m.readmit_link(0, 2, 3)
    assert m.is_trivial()


def test_derate_layer_scales_effective_capacity():
    spec = ClusterSpec(num_pods=4, k_spine=4, k_leaf=4)
    m = PortMask.healthy(spec, num_groups=1)
    from repro.core.reconfig import mdmcf_reconfigure
    from repro.core.logical import random_feasible_demand
    C = random_feasible_demand(
        spec, np.random.default_rng(0), num_groups=1
    )
    cfg = mdmcf_reconfigure(spec, C).config
    full = cfg.pair_capacity()
    assert np.array_equal(m.effective_pair_capacity(cfg), full)
    apply_event(m, DerateEvent(0.0, h=0, k=1, pod=2, health=0.5))
    assert m.has_gray() and not m.is_trivial()
    eff = m.effective_pair_capacity(cfg)
    assert (eff <= full + 1e-12).all()
    assert eff.sum() < full.sum()  # the gray slot's circuits derated
    apply_event(m, DerateEvent(1.0, h=0, k=1, pod=2, health=1.0))
    assert not m.has_gray() and m.is_trivial()


def test_gray_and_cordon_change_fingerprint():
    m = _mask()
    f0 = m.fingerprint()
    m.derate_link(0, 0, 0, 0.7)
    f1 = m.fingerprint()
    assert f1 != f0
    m.cordon_link(1, 1, 1)
    assert m.fingerprint() not in (f0, f1)
    counts = m.counts()
    assert counts["derated_links"] == 1 and counts["cordoned_links"] == 1
