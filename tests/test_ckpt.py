"""Checkpoint/restore: bitwise roundtrip, async write, latest-step pick,
elastic re-mesh restore (fault-tolerance contract)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import latest_step, restore_checkpoint, save_checkpoint


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {
            "w": jax.random.normal(k, (8, 16), jnp.float32),
            "b": jnp.arange(16, dtype=jnp.bfloat16),
        },
        "opt": {"m": jnp.zeros((8, 16)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_bitwise(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state)
    restored = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: state))
    for a, b in zip(
        jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_write(tmp_path):
    t = save_checkpoint(str(tmp_path), 3, _state(), background=True)
    assert t is not None
    t.join(timeout=30)
    assert latest_step(str(tmp_path)) == 3


def test_latest_step_picks_max(tmp_path):
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, _state(s))
    assert latest_step(str(tmp_path)) == 5
    restored = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: _state()))
    expect = _state(5)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(expect["params"]["w"])
    )


def test_restore_with_shardings(tmp_path):
    """Elastic restore: device_put with explicit (trivial 1-device) shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    state = _state()
    save_checkpoint(str(tmp_path), 1, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: state)
    )
    restored = restore_checkpoint(
        str(tmp_path), jax.eval_shape(lambda: state), shardings=shardings
    )
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), {})
