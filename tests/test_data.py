"""Synthetic data pipeline: determinism and shard consistency (the
multi-host / elastic-restart contract)."""
import numpy as np

from repro.train.data import DataConfig, SyntheticData


def test_determinism():
    d1 = SyntheticData(DataConfig(vocab_size=97, batch=8, seq=16, seed=3))
    d2 = SyntheticData(DataConfig(vocab_size=97, batch=8, seq=16, seed=3))
    for step in (0, 1, 100):
        a, b = d1.batch_at(step), d2.batch_at(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["targets"], b["targets"])


def test_shard_consistency():
    """Any host generating rows [lo:hi) must match the global batch slice —
    elastic rescale / straggler skip-ahead correctness."""
    d = SyntheticData(DataConfig(vocab_size=101, batch=16, seq=8, seed=0))
    full = d.batch_at(7)
    for lo, hi in [(0, 4), (4, 12), (12, 16)]:
        part = d.batch_at(7, lo, hi)
        np.testing.assert_array_equal(part["tokens"], full["tokens"][lo:hi])
        np.testing.assert_array_equal(part["targets"], full["targets"][lo:hi])


def test_affine_structure_learnable():
    """targets must be the affine map of tokens (loss-decrease signal)."""
    c = DataConfig(vocab_size=53, batch=4, seq=8, seed=1, mode="affine")
    b = SyntheticData(c).batch_at(0)
    np.testing.assert_array_equal(
        b["targets"], (c.a * b["tokens"].astype(np.int64) + c.b) % c.vocab_size
    )


def test_modality_extras():
    from repro.models import smoke_config

    cfg = smoke_config("whisper-small")
    d = SyntheticData(
        DataConfig(vocab_size=cfg.vocab_size, batch=2, seq=8), model_cfg=cfg
    )
    b = d.batch_at(0)
    assert b["frames"].shape == (2, cfg.encoder_seq, cfg.d_model)

    cfg = smoke_config("internvl2-1b")
    d = SyntheticData(
        DataConfig(vocab_size=cfg.vocab_size, batch=2, seq=8), model_cfg=cfg
    )
    b = d.batch_at(0)
    assert b["patches"].shape == (2, cfg.vision_tokens, cfg.vision_dim)
