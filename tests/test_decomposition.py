"""Property tests for the paper's two matrix-decomposition theorems
(§3.4): the Euler fast paths and the MCF oracles must both satisfy the
theorem bounds on arbitrary inputs, and must agree with each other."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.decomposition import (
    check_edge_coloring,
    check_symmetric_split,
    edge_color_bipartite,
    halve_matrix,
    integer_matrix_decompose,
    symmetric_split_euler,
    symmetric_split_mcf,
)


def _random_symmetric(rng: np.random.Generator, n: int, hi: int) -> np.ndarray:
    A = rng.integers(0, hi + 1, size=(n, n))
    C = A + A.T  # even diagonal by construction
    return C


@st.composite
def symmetric_matrices(draw):
    n = draw(st.integers(2, 8))
    seed = draw(st.integers(0, 2**31 - 1))
    hi = draw(st.integers(0, 6))
    return _random_symmetric(np.random.default_rng(seed), n, hi)


@settings(max_examples=40, deadline=None)
@given(symmetric_matrices())
def test_thm31_euler(C):
    """Thm 3.1 via Eulerian balanced orientation."""
    A = symmetric_split_euler(C)
    check_symmetric_split(C, A)


@settings(max_examples=15, deadline=None)
@given(symmetric_matrices())
def test_thm31_mcf_oracle(C):
    """Thm 3.1 via the paper's MCF proof construction."""
    A = symmetric_split_mcf(C)
    check_symmetric_split(C, A)


def test_thm31_rejects_asymmetric():
    with pytest.raises(ValueError):
        symmetric_split_euler(np.array([[0, 1], [2, 0]]))


def test_thm31_rejects_odd_diagonal():
    with pytest.raises(ValueError):
        symmetric_split_euler(np.array([[1, 1], [1, 0]]))


@st.composite
def colorable_matrices(draw):
    """Non-negative integer matrices with row/col sums ≤ K."""
    p = draw(st.integers(2, 7))
    q = draw(st.integers(2, 7))
    k = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    A = np.zeros((p, q), dtype=np.int64)
    rows = rng.permutation(np.repeat(np.arange(p), k))
    cols = rng.permutation(np.repeat(np.arange(q), k))
    m = draw(st.integers(0, min(len(rows), len(cols))))
    for i, j in zip(rows[:m], cols[:m]):
        A[i, j] += 1
    return A, k


@settings(max_examples=40, deadline=None)
@given(colorable_matrices())
def test_edge_coloring(arg):
    """König: Δ ≤ K bipartite multigraphs decompose into K sub-permutations."""
    A, k = arg
    colors = edge_color_bipartite(A, k)
    check_edge_coloring(A, colors)
    assert colors.shape[0] == k


@settings(max_examples=20, deadline=None)
@given(colorable_matrices(), st.integers(0, 2**31 - 1))
def test_edge_coloring_warm_start_preserves(arg, seed):
    """Warm-started units that are still demanded keep their color class
    (the Min-Rewiring mechanism)."""
    A, k = arg
    base = edge_color_bipartite(A, k)
    # perturb demand: drop some units, keep the old coloring as warm start
    rng = np.random.default_rng(seed)
    drop = (rng.random(A.shape) < 0.3) & (A > 0)
    A2 = A - drop.astype(np.int64)
    colors = edge_color_bipartite(A2, k, warm=base)
    check_edge_coloring(A2, colors)
    # every (i,j,c) unit demanded by A2 that base already colored c stays
    kept = np.minimum(colors, base).sum()
    # lower bound: at least A2's overlap with base, color-wise, is achievable
    # greedily; assert the warm start did *something* (no regression to 0)
    if A2.sum() > 0:
        assert kept >= min(base.sum(), A2.sum()) * 0.5


def test_edge_coloring_rejects_overfull():
    with pytest.raises(ValueError):
        edge_color_bipartite(np.array([[3, 0], [0, 0]]), 2)


@st.composite
def any_matrices(draw):
    p = draw(st.integers(1, 6))
    q = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    hi = draw(st.integers(0, 20))
    return np.random.default_rng(seed).integers(0, hi + 1, size=(p, q))


@settings(max_examples=40, deadline=None)
@given(any_matrices())
def test_halve_matrix(C):
    C1, C2 = halve_matrix(C)
    assert (C1 + C2 == C).all()
    for part in (C1, C2):
        assert (part >= C // 2).all() and (part <= -(-C // 2)).all()
        assert (part.sum(1) >= C.sum(1) // 2).all()
        assert (part.sum(1) <= -(-C.sum(1) // 2)).all()
        assert (part.sum(0) >= C.sum(0) // 2).all()
        assert (part.sum(0) <= -(-C.sum(0) // 2)).all()


@settings(max_examples=25, deadline=None)
@given(any_matrices(), st.sampled_from([2, 3, 4, 5, 8]))
def test_thm32_decompose(C, K):
    """Thm 3.2: K-way split with floor/ceil balance of entries & sums."""
    parts = integer_matrix_decompose(C, K)
    assert len(parts) == K
    assert (sum(parts) == C).all()
    for S in parts:
        assert (S >= C // K).all() and (S <= -(-C // K)).all()
        assert (S.sum(1) >= C.sum(1) // K).all()
        assert (S.sum(1) <= -(-C.sum(1) // K)).all()
        assert (S.sum(0) >= C.sum(0) // K).all()
        assert (S.sum(0) <= -(-C.sum(0) // K)).all()
