"""Collective planner invariants: demand matrices are symmetric and
degree-feasible, ring ordering never increases uncoverable demand, and the
alpha-beta cost model behaves monotonically.

Randomized property tests use seeded numpy generators (always run); the
hypothesis-based suites elsewhere cover the control-plane theorems."""
import itertools

import numpy as np
import pytest

from repro.core.logical import Job, Placement
from repro.core.reconfig import mdmcf_reconfigure, uniform_greedy
from repro.core.topology import ClusterSpec, OCSConfig, demand_feasible
from repro.dist import (
    AlphaBeta,
    Collective,
    MODEL_PROFILES,
    collective_time,
    collectives_to_edges,
    comm_fraction_for,
    edges_to_matrix,
    job_edges,
    plan_collectives,
    ring_order,
    uncoverable_fraction,
)
from repro.dist.demand import _ring_uncovered, clip_feasible
from repro.sim import flowsim

SPEC = ClusterSpec(num_pods=8, k_spine=16, k_leaf=16)


def _random_edges(rng, n_jobs=3):
    """Aggregate planner edges of a few random jobs."""
    models = sorted(MODEL_PROFILES)
    edges_list = []
    for _ in range(n_jobs):
        model = models[int(rng.integers(len(models)))]
        n = int(rng.integers(2, 6))
        pods = sorted(
            rng.choice(SPEC.num_pods, size=n, replace=False).tolist()
        )
        ep = int(rng.choice([1, 2, 8]))
        pp = int(rng.choice([1, 2, 4]))
        links = int(rng.integers(1, 9))
        edges_list.append(job_edges(model, pods, links, ep=ep, pp=pp))
    return edges_list


@pytest.mark.parametrize("seed", range(20))
def test_planner_demand_symmetric_and_feasible(seed):
    """Lowered demand is a valid logical topology after clipping
    (paper eq. 11 symmetry + eq. 12 degree bound)."""
    rng = np.random.default_rng(seed)
    C = sum(
        edges_to_matrix(e, SPEC.num_pods, SPEC.num_ocs_groups)
        for e in _random_edges(rng)
    )
    assert (C == np.transpose(C, (0, 2, 1))).all()
    assert (np.diagonal(C, axis1=1, axis2=2) == 0).all()
    clipped = clip_feasible(C, SPEC.k_spine)
    assert demand_feasible(clipped, SPEC)
    assert (clipped <= C).all()  # clipping only removes links


@pytest.mark.parametrize("seed", range(10))
def test_ring_order_never_increases_uncoverable(seed):
    """The topology-aware ordering is at least as good as sorted order
    under any realized configuration."""
    rng = np.random.default_rng(seed)
    from repro.core.logical import random_feasible_demand

    C = random_feasible_demand(SPEC, rng, fill=0.6)
    config = mdmcf_reconfigure(SPEC, C).config
    cap = config.realized_bidirectional().astype(np.float64).sum(axis=0)
    cap /= max(1, config.num_groups)
    for n in (2, 3, 4, 5, 6):
        pods = sorted(rng.choice(SPEC.num_pods, size=n, replace=False).tolist())
        links = int(rng.integers(1, 6))
        order = ring_order(pods, config, links=links)
        assert sorted(order) == pods  # a permutation, nothing dropped
        assert _ring_uncovered(order, cap, links) <= _ring_uncovered(
            tuple(pods), cap, links
        ) + 1e-9


def test_ring_order_finds_covered_ring():
    """With capacity laid out as a known ring, the pass recovers it."""
    config = OCSConfig(SPEC, num_groups=1)
    ring = [0, 2, 4, 6, 1, 3]
    for t in range(len(ring)):
        i, j = ring[t], ring[(t + 1) % len(ring)]
        config.x[0, 2 * t % SPEC.ocs_per_group, i, j] = 1
        config.x[0, (2 * t + 1) % SPEC.ocs_per_group, j, i] = 1
    order = ring_order(sorted(ring), config, links=1)
    cap = config.realized_bidirectional().astype(np.float64).sum(axis=0)
    assert _ring_uncovered(order, cap, 1) <= _ring_uncovered(
        tuple(sorted(ring)), cap, 1
    )


def test_moe_all_to_all_is_dense():
    """EP spillover produces edges between *every* pod pair."""
    pods = [1, 3, 5, 6]
    edges = job_edges("mixtral-8x7b", pods, links=8, ep=8)
    for pair in itertools.combinations(pods, 2):
        assert edges.get(pair, 0) >= 1, pair


def test_pp_chain_is_open():
    """PP stage traffic is a chain: the wrap-around pair stays empty when
    the DP ring is absent (pp archetype with in-pod DP)."""
    pods = [0, 1, 2, 3]
    colls = plan_collectives("llama2-70b", 4, pp=4, dp_cross=False)
    edges = collectives_to_edges(colls, pods, links=4)
    assert (0, 3) not in edges
    assert edges.get((0, 1), 0) >= 1 and edges.get((2, 3), 0) >= 1


def test_cost_model_monotonicity():
    ab = AlphaBeta()
    small = Collective("all_reduce", "cross_pod", 1e9, 4)
    big = Collective("all_reduce", "cross_pod", 2e9, 4)
    assert collective_time(big, ab) > collective_time(small, ab)
    # more links → faster; lower phi → slower
    assert collective_time(small, ab, links=8) < collective_time(small, ab)
    assert collective_time(small, ab, phi=0.5) > collective_time(small, ab)
    # zero1 reduce-scatter + all-gather == one ring all-reduce (bandwidth)
    rs = Collective("reduce_scatter", "cross_pod", 1e9, 4)
    ag = Collective("all_gather", "cross_pod", 1e9, 4)
    both = collective_time(rs, ab) + collective_time(ag, ab)
    assert both == pytest.approx(collective_time(small, ab), rel=1e-6)


def test_comm_fraction_bounds_and_growth():
    for model in MODEL_PROFILES:
        a2 = comm_fraction_for(model, 2, ep=2, pp=1)
        a8 = comm_fraction_for(model, 8, ep=2, pp=1)
        assert 0.0 <= a2 <= 0.95 and 0.0 <= a8 <= 0.95
        assert a8 >= a2 - 1e-9  # more pods, relatively more cross traffic
    assert comm_fraction_for("unknown-model", 2) > 0.0  # fallback profile


def test_waterfill_matches_capacity():
    """Max-min φ: a fully realized demand gives φ=1; a half-capacity
    fabric gives φ=0.5; frozen flows' leftovers go to others."""
    spec = ClusterSpec(num_pods=4, k_spine=16, k_leaf=16)
    want = {(0, 1): 8, (1, 2): 8}
    C = edges_to_matrix(want, 4, spec.num_ocs_groups)
    config = mdmcf_reconfigure(spec, C).config
    flows = [flowsim.JobFlows(0, want, 0.3)]
    phi = flowsim.waterfill_fractions(spec, flows, config, "cross_wiring")
    assert phi[0] == pytest.approx(1.0)

    # second job congests edge (0,1) only; job 1 freezes at 8/16 while
    # job 0's (1,2) edge is untouched -> job 0 also pinned by (0,1)
    flows = [
        flowsim.JobFlows(0, {(0, 1): 8, (1, 2): 8}, 0.3),
        flowsim.JobFlows(1, {(0, 1): 8}, 0.3),
    ]
    phi = flowsim.waterfill_fractions(spec, flows, config, "cross_wiring")
    assert phi[0] == pytest.approx(0.5)
    assert phi[1] == pytest.approx(0.5)


def test_placement_ring_roundtrip():
    pl = Placement(0, {4: 8, 1: 8, 7: 8}, ring_order=(1, 7, 4))
    assert pl.ring() == [1, 7, 4]
    assert pl.pod_list() == [1, 4, 7]
    assert Placement(0, {4: 8, 1: 8}).ring() == [1, 4]


def test_uncoverable_fraction_zero_when_realized():
    want = {(0, 1): 4, (2, 3): 4}
    C = edges_to_matrix(want, SPEC.num_pods, SPEC.num_ocs_groups)
    config = mdmcf_reconfigure(SPEC, C).config
    assert uncoverable_fraction(want, config) == pytest.approx(0.0)
    assert uncoverable_fraction({(0, 1): 4, (4, 5): 4}, config) > 0.0
