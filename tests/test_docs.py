"""Documentation gate (CI `docs` job).

Three checks keep the documentation tree honest as the code grows:

* every doctest-style example embedded in the public entry points'
  docstrings executes cleanly (``doctest.testmod`` on the modules the
  docstring pass covers — all numpy-only, so this stays cheap),
* every package under ``src/repro`` is mentioned in README.md's package
  map (a new subsystem cannot land undocumented),
* the top-level docs tree exists (README + docs/*.md).
"""
import doctest
import importlib
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# modules whose docstrings carry executable examples (the PR 5 docstring
# pass); extend as examples are added elsewhere
DOCTEST_MODULES = [
    "repro.core.incremental",
    "repro.dist.demand",
    "repro.fault.chaos",
    "repro.fault.masks",
    "repro.fault.remediate",
    "repro.obs.attrib",
    "repro.obs.health",
    "repro.obs.metrics",
    "repro.obs.report",
    "repro.obs.trace",
    "repro.scenario.calibrate",
    "repro.scenario.catalog",
    "repro.scenario.runner",
    "repro.scenario.spec",
    "repro.serve.router",
    "repro.sim.scheduler",
    "repro.sim.serving",
]

REQUIRED_DOCS = [
    "README.md",
    os.path.join("docs", "architecture.md"),
    os.path.join("docs", "simulation.md"),
    os.path.join("docs", "serving.md"),
    os.path.join("docs", "observability.md"),
    os.path.join("docs", "resilience.md"),
    os.path.join("docs", "scenarios.md"),
]


@pytest.mark.parametrize("mod", DOCTEST_MODULES)
def test_docstring_examples_execute(mod):
    results = doctest.testmod(importlib.import_module(mod), verbose=False)
    assert results.attempted > 0, f"{mod}: docstring examples disappeared"
    assert results.failed == 0, f"{mod}: {results.failed} doctest failures"


@pytest.mark.parametrize("path", REQUIRED_DOCS)
def test_docs_exist(path):
    assert os.path.exists(os.path.join(REPO, path)), f"{path} missing"


def test_readme_package_map_complete():
    """Every repro.* package must appear in README's package map."""
    with open(os.path.join(REPO, "README.md")) as fh:
        readme = fh.read()
    pkg_root = os.path.join(REPO, "src", "repro")
    packages = sorted(
        d for d in os.listdir(pkg_root)
        if os.path.isdir(os.path.join(pkg_root, d))
        and not d.startswith("__")
    )
    assert packages, "src/repro packages not found"
    missing = [
        p for p in packages
        if not re.search(rf"`(repro[./])?{re.escape(p)}[/`]", readme)
    ]
    assert not missing, (
        f"README.md package map is missing packages: {missing} — "
        "add a row for each new subsystem"
    )
