"""repro.fault: masks, fault models, degraded-mode topology engineering and
the failure/expansion-aware scheduler.

Centerpiece (ISSUE 2 satellite): a property test that `mdmcf_reconfigure`
under random `PortMask`s still satisfies ILP constraints (1)-(6), realizes
the degraded demand exactly (Thm 4.1 on the surviving clean pairs), and
never assigns a masked slot."""
import math

import numpy as np
import pytest

from repro.core.logical import random_feasible_demand
from repro.core.reconfig import (
    check_ilp_constraints,
    mdmcf_reconfigure,
    uniform_best_effort,
    uniform_greedy,
)
from repro.core.topology import ClusterSpec, demand_feasible
from repro.fault import (
    ExpandEvent,
    FailureEvent,
    FaultModel,
    PortMask,
    RepairEvent,
    apply_event,
    degrade_demand,
    mdmcf_degraded,
    restart_cost_s,
    rollback_loss,
)
from repro.sim import SimConfig, Simulator, generate_trace, summarize


def _spec(p=8, k=8):
    return ClusterSpec(num_pods=p, k_spine=k, k_leaf=8)


# ---------------------------------------------------------------------------
# PortMask
# ---------------------------------------------------------------------------

def test_mask_budgets_and_clean_pairs():
    spec = _spec()
    m = PortMask.healthy(spec, num_groups=2)
    assert m.is_trivial()
    assert (m.degree_budget() == spec.k_spine).all()
    m.fail_link(0, 3, 2)  # kills pair 1 in group 0 (clean-pair granularity)
    assert m.clean_pairs(0).tolist() == [0, 2, 3]
    assert m.clean_pairs(1).tolist() == [0, 1, 2, 3]
    assert (m.degree_budget()[0] == 6).all()
    # port-granular budget only dings the failed pod
    u = m.degree_budget("uniform")
    assert u[0, 2] == 7 and u[0, 0] == 8 and (u[1] == 8).all()
    m.repair_link(0, 3, 2)
    assert m.is_trivial()


def test_mask_layers_are_independent():
    """An OCS repair must not resurrect an individually failed transceiver."""
    spec = _spec()
    m = PortMask.healthy(spec, num_groups=1)
    m.fail_link(0, 2, 1)
    m.fail_ocs(0, 2)
    m.repair_ocs(0, 2)
    assert m.egress_blocked()[0, 2, 1] and m.ingress_blocked()[0, 2, 1]
    assert not m.egress_blocked()[0, 2, 0]


def test_mask_rejects_bad_config():
    spec = _spec(p=4, k=4)
    m = PortMask.healthy(spec, num_groups=1)
    m.fail_link(0, 0, 1, direction="egress")
    x = np.zeros((1, 4, 4, 4), dtype=np.int8)
    x[0, 0, 1, 2] = 1  # uses pod 1's failed egress on OCS (0, 0)
    with pytest.raises(AssertionError):
        m.check_config(x)
    x[:] = 0
    x[0, 1, 1, 2] = 1  # different OCS: fine
    m.check_config(x)


def test_drained_and_inactive_pods_have_zero_budget():
    spec = _spec()
    m = PortMask.healthy(spec, num_groups=2)
    m.fail_pod(3)
    m.set_active_count(6)  # pods 6, 7 not yet populated
    b = m.degree_budget()
    assert (b[:, 3] == 0).all() and (b[:, 6:] == 0).all()
    assert b[0, 0] == spec.k_spine
    m.expand([6, 7])
    assert (m.degree_budget()[:, 6:] == spec.k_spine).all()


# ---------------------------------------------------------------------------
# FaultModel
# ---------------------------------------------------------------------------

def test_fault_model_deterministic_sorted_paired():
    fm = FaultModel(8, 8, 2, link_mtbf_s=5e4, link_mttr_s=3600,
                    ocs_mtbf_s=2e5, pod_mtbf_s=4e5, seed=7)
    a, b = fm.sample(48 * 3600.0), fm.sample(48 * 3600.0)
    assert a == b
    times = [e.time for e in a]
    assert times == sorted(times)
    # every failure has a later repair of the same component
    for ev in a:
        if isinstance(ev, FailureEvent):
            rep = [
                r for r in a
                if isinstance(r, RepairEvent) and r.scope == ev.scope
                and (r.h, r.k, r.pod) == (ev.h, ev.k, ev.pod)
                and r.time > ev.time
            ]
            assert rep, ev


def test_fault_streams_independent_per_class():
    """Per-class RNG isolation (the seed discipline model.py promises):
    toggling or retuning one hardware class's failure process must not
    perturb any other class's event times."""
    H = 48 * 3600.0
    base = dict(link_mtbf_s=5e4, link_mttr_s=3600, ocs_mtbf_s=2e5,
                pod_mtbf_s=4e5, seed=7)

    def stream(evs, scope):
        return [
            (e.time, type(e).__name__, e.h, e.k, e.pod)
            for e in evs if e.scope == scope
        ]

    a = FaultModel(8, 8, 2, **base).sample(H)
    # disabling pod failures entirely: link + OCS streams bit-identical
    b = FaultModel(8, 8, 2, **{**base, "pod_mtbf_s": None}).sample(H)
    assert stream(a, "link") == stream(b, "link")
    assert stream(a, "ocs") == stream(b, "ocs")
    assert stream(a, "pod") and not stream(b, "pod")
    # retuning the OCS process: link + pod streams bit-identical
    c = FaultModel(8, 8, 2, **{**base, "ocs_mtbf_s": 5e4}).sample(H)
    assert stream(a, "link") == stream(c, "link")
    assert stream(a, "pod") == stream(c, "pod")
    assert stream(a, "ocs") != stream(c, "ocs")


# ---------------------------------------------------------------------------
# degraded-mode topology engineering
# ---------------------------------------------------------------------------

def _random_mask(spec, num_groups, rng):
    m = PortMask.healthy(spec, num_groups)
    for _ in range(int(rng.integers(0, 5))):
        m.fail_link(
            int(rng.integers(num_groups)),
            int(rng.integers(spec.k_spine)),
            int(rng.integers(spec.num_pods)),
        )
    if rng.random() < 0.4:
        m.fail_ocs(int(rng.integers(num_groups)), int(rng.integers(spec.k_spine)))
    if rng.random() < 0.3:
        m.fail_pod(int(rng.integers(spec.num_pods)))
    return m


def test_degrade_demand_is_mask_feasible():
    spec = _spec()
    rng = np.random.default_rng(0)
    for seed in range(10):
        rng = np.random.default_rng(seed)
        C = random_feasible_demand(spec, rng, fill=1.0, num_groups=2)
        m = _random_mask(spec, 2, rng)
        Cd = degrade_demand(C, m)
        assert demand_feasible(Cd, spec, mask=m)


def test_mdmcf_masked_property():
    """ISSUE 2 satellite: mdmcf under random PortMasks — ILP (1)-(6) hold,
    the degraded demand is realized exactly, no masked slot is assigned."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def inner(seed):
        rng = np.random.default_rng(seed)
        p = int(rng.integers(3, 8))
        k = int(rng.choice([4, 6, 8]))
        spec = ClusterSpec(num_pods=p, k_spine=k, k_leaf=4)
        C = random_feasible_demand(
            spec, rng, fill=float(rng.uniform(0.4, 1.0)), num_groups=2
        )
        m = _random_mask(spec, 2, rng)
        Cd = degrade_demand(C, m)
        old = mdmcf_reconfigure(spec, C).config if rng.random() < 0.5 else None
        res = mdmcf_reconfigure(spec, Cd, old=old, mask=m)
        check_ilp_constraints(
            spec, Cd, res.config, topology="cross_wiring", mask=m
        )
        if Cd.any():
            assert res.ltrr == pytest.approx(1.0)

    inner()


def test_mdmcf_masked_rejects_undegraded_demand():
    spec = _spec()
    rng = np.random.default_rng(1)
    C = random_feasible_demand(spec, rng, fill=1.0, num_groups=2)
    m = PortMask.healthy(spec, num_groups=2)
    m.fail_ocs(0, 0)  # budget drops below the full-fill demand
    with pytest.raises(ValueError):
        mdmcf_reconfigure(spec, C, mask=m)


def test_mdmcf_degraded_graceful_and_clean():
    """Production path: accepts port-granular demand, never assigns a
    masked slot, stays exact with slack and degrades gracefully."""
    spec = _spec(p=12, k=8)
    rng = np.random.default_rng(2)
    C = random_feasible_demand(spec, rng, fill=0.6, num_groups=2)
    m = _random_mask(spec, 2, np.random.default_rng(3))
    Cd = degrade_demand(C, m)  # within even the conservative budget
    res = mdmcf_degraded(spec, Cd, mask=m)
    check_ilp_constraints(
        spec, Cd, res.config, topology="cross_wiring", require_exact=False,
        mask=m,
    )
    assert res.ltrr >= mdmcf_reconfigure(spec, Cd, mask=m).ltrr - 1e-9


def test_uniform_strategies_respect_mask():
    spec = _spec(p=6, k=6)
    rng = np.random.default_rng(4)
    C = random_feasible_demand(spec, rng, fill=0.8, num_groups=2)
    m = _random_mask(spec, 2, rng)
    Cd = degrade_demand(C, m)
    for fn in (uniform_greedy, uniform_best_effort):
        res = fn(spec, Cd, mask=m)
        check_ilp_constraints(
            spec, Cd, res.config, topology="uniform", require_exact=False,
            mask=m,
        )


def test_recovery_cost_models():
    assert rollback_loss(5000.0, 1800.0) == pytest.approx(5000.0 - 2 * 1800.0)
    assert rollback_loss(100.0, 0.0) == 100.0
    assert restart_cost_s("llama2-70b", 64) > restart_cost_s("llama2-70b", 512)


# ---------------------------------------------------------------------------
# scheduler integration
# ---------------------------------------------------------------------------

def _jobs(n=50, pods=16, k=8, wl=0.9, seed=0):
    return generate_trace(
        n, num_gpus=pods * k * k, workload_level=wl, seed=seed,
        max_job_gpus=pods * k * k // 4,
    )


def _cfg(pods=16, k=8, **kw):
    return SimConfig(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=pods, k_spine=k, k_leaf=k, **kw,
    )


def test_sim_without_faults_matches_legacy():
    """A fault-free Simulator with the new machinery must reproduce the
    exact schedule of the pre-fault code path (mask stays trivial)."""
    jobs = _jobs()
    r1 = Simulator(_cfg(), jobs).run()
    r2 = Simulator(_cfg(), jobs, fault_events=[]).run()
    assert [(r.start, r.finish) for r in r1] == [(r.start, r.finish) for r in r2]
    assert all(math.isfinite(r.finish) for r in r1)


def test_sim_pod_failure_policies():
    jobs = _jobs()
    t_fail = jobs[len(jobs) // 3].arrival
    evs = [
        FailureEvent(t_fail, "pod", pod=1),
        RepairEvent(t_fail + 7200.0, "pod", pod=1),
    ]
    out = {}
    for pol in ("rewire_around", "ckpt_restart", "shrink_collective"):
        sim = Simulator(_cfg(recovery_policy=pol), jobs, fault_events=evs)
        recs = sim.run()
        assert all(math.isfinite(r.finish) for r in recs), pol
        out[pol] = sim
    # someone was on pod 1 under both restart-y policies
    assert out["rewire_around"].restarts >= 1
    assert out["ckpt_restart"].restarts >= 1
    assert (
        out["shrink_collective"].restarts + out["shrink_collective"].shrinks
        >= 1
    )
    # checkpoints strictly bound the work lost vs restart-from-scratch
    assert (
        out["ckpt_restart"].lost_gpu_s <= out["rewire_around"].lost_gpu_s
    )
    assert out["shrink_collective"].lost_gpu_s == 0.0
    fs = out["ckpt_restart"].fault_summary()
    assert 0.0 < fs["availability"] < 1.0
    assert fs["failures"] == 1 and fs["repairs"] == 1


def test_sim_fault_determinism():
    jobs = _jobs(40)
    fm = FaultModel(16, 8, 2, link_mtbf_s=1e5, link_mttr_s=3600, seed=5)
    evs = fm.sample(jobs[-1].arrival)
    a = Simulator(_cfg(), jobs, fault_events=evs).run()
    b = Simulator(_cfg(), jobs, fault_events=evs).run()
    assert [(r.start, r.finish) for r in a] == [(r.start, r.finish) for r in b]


def test_sim_link_failures_rewire_without_restarts():
    jobs = _jobs(40)
    fm = FaultModel(16, 8, 2, link_mtbf_s=1e5, link_mttr_s=3600, seed=6)
    evs = fm.sample(jobs[-1].arrival)
    assert evs, "model produced no events"
    sim = Simulator(_cfg(), jobs, fault_events=evs)
    recs = sim.run()
    assert sim.restarts == 0  # OCS-layer faults never kill a job
    assert all(math.isfinite(r.finish) for r in recs)


def test_sim_live_expansion_no_restarts():
    """Acceptance: grow P-ΔP → P live; nothing restarts, queueing drops."""
    pods, k, d = 16, 8, 4
    jobs = generate_trace(
        60, num_gpus=(pods - d) * k * k, workload_level=4.0, seed=0,
        max_job_gpus=(pods - d) * k * k // 4,
    )
    t_exp = jobs[len(jobs) // 3].arrival
    grow = [ExpandEvent(t_exp, tuple(range(pods - d, pods)))]
    small = Simulator(_cfg(pods, k, active_pods=pods - d), jobs)
    s_small = summarize(small.run())
    sim = Simulator(_cfg(pods, k, active_pods=pods - d), jobs, fault_events=grow)
    s_grown = summarize(sim.run())
    assert sim.restarts == 0
    assert sim.fault_counts["expands"] == 1
    assert s_grown["avg_jct"] <= s_small["avg_jct"]
    assert s_grown["completed"] == len(jobs)
    # capacity integral reflects the grow-out (avg GPU capacity rises)
    fs_g, fs_s = sim.fault_summary(), small.fault_summary()
    assert (
        fs_g["capacity_gpu_s"] / fs_g["horizon_s"]
        > fs_s["capacity_gpu_s"] / fs_s["horizon_s"]
    )


def test_apply_event_roundtrip():
    spec = _spec()
    m = PortMask.healthy(spec, num_groups=2)
    apply_event(m, FailureEvent(0.0, "link", h=1, k=2, pod=3))
    apply_event(m, FailureEvent(1.0, "pod", pod=5))
    assert not m.is_trivial()
    apply_event(m, RepairEvent(2.0, "link", h=1, k=2, pod=3))
    apply_event(m, RepairEvent(3.0, "pod", pod=5))
    assert m.is_trivial()


def test_degraded_solver_salvages_instead_of_relocating():
    """A single failed transceiver with unchanged demand is a *salvage*
    problem: move the one stranded circuit to a spare healthy slot, not
    relocate whole color classes.  The slack-aware assignment keeps the
    rewiring (and the make-before-break dark set) near the physical
    minimum, realizes the demand exactly, and is idempotent — re-solving
    the same degraded state moves nothing (no reconfiguration churn)."""
    spec = _spec(p=12, k=8)
    H, P = 2, spec.num_pods
    C = np.zeros((H, P, P), dtype=np.int64)
    for i in range(P):  # symmetric ring demand: neighbours at ±1, ±3
        for d in (1, 3):
            j = (i + d) % P
            C[:, i, j] += 1
            C[:, j, i] += 1
    healthy = mdmcf_reconfigure(spec, C).config
    m = PortMask.healthy(spec, H)
    m.fail_link(0, 0, 0)
    Cd = degrade_demand(C, m)
    res = mdmcf_degraded(spec, Cd, old=healthy, mask=m)
    check_ilp_constraints(
        spec, Cd, res.config, topology="cross_wiring", require_exact=False,
        mask=m,
    )
    assert res.ltrr >= 1.0 - 1e-9  # plenty of slack: exact realization
    # salvage, not wholesale relocation (one circuit strands; a pre-fix
    # class-relocating assignment moved 48 circuit-ends / 12 dark pairs)
    assert res.config.rewiring_distance(healthy) <= 16
    assert len(res.config.dark_pairs(healthy)) <= 4
    res2 = mdmcf_degraded(spec, Cd, old=res.config, mask=m)
    assert res2.config.rewiring_distance(res.config) == 0
    assert res2.config.dark_pairs(res.config) == frozenset()
