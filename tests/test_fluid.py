"""Fluid engine unit + property tests (ISSUE 4).

Covers: the max-min water-filling core (conservation, bottleneck
saturation, monotonicity — hypothesis when available, seeded always),
the ClusterSpec.slowdown_cap surface (a fully-dark circuit stalls when no
residual electrical capacity is configured), reconfiguration dark
windows, and the fluid-priced recovery-policy cost model.
"""
import math

import numpy as np
import pytest

from repro.core.logical import Job
from repro.core.reconfig import mdmcf_reconfigure
from repro.core.topology import ClusterSpec, OCSConfig
from repro.dist import demand as dist_demand
from repro.fault import (
    CHEAPEST,
    CKPT_RESTART,
    FailureEvent,
    REWIRE_AROUND,
    RepairEvent,
    SHRINK_COLLECTIVE,
    policy_costs,
)
from repro.sim import SimConfig, Simulator, generate_trace, summarize
from repro.sim import flowsim, fluid


def _seeded_cases(n=60, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        F = int(rng.integers(1, 7))
        E = int(rng.integers(1, 7))
        D = rng.integers(0, 4, size=(F, E)).astype(np.float64)
        cap = np.round(rng.uniform(0.0, 10.0, size=E), 3)
        yield D, cap


def _check_waterfill_properties(D, cap):
    x = flowsim.waterfill_levels(D, cap)
    F, E = D.shape
    assert x.shape == (F,)
    assert (x >= -1e-12).all() and (x <= 1.0 + 1e-12).all()
    # conservation: no edge carries more than its capacity
    load = x @ D
    assert (load <= cap + 1e-6).all(), (load, cap)
    # bottleneck saturation: every rate-limited flow sits on a saturated edge
    for f in range(F):
        if x[f] >= 1.0 - 1e-9 or not D[f].any():
            continue
        on = D[f] > 0
        assert (load[on] >= cap[on] - 1e-6).any(), (f, x, load, cap)
    # leximin monotonicity: removing a flow never decreases the *minimum*
    # survivor level.  (Per-flow monotonicity is provably FALSE for
    # multi-edge collective flows: removing a flow can raise one edge's
    # saturation level so other flows no longer freeze early there and
    # press a second edge harder, hurting a flow that only uses the
    # second edge.  Max-min is leximin-optimal, not pointwise-monotone;
    # see test_waterfill_single_edge_monotonicity for the regime where
    # the pointwise property does hold.)
    for drop in range(F):
        keep = [f for f in range(F) if f != drop]
        if not keep:
            continue
        x2 = flowsim.waterfill_levels(D[keep], cap)
        assert x2.min() >= x[keep].min() - 1e-9, (drop, x, x2)


def test_waterfill_properties_seeded():
    for D, cap in _seeded_cases():
        _check_waterfill_properties(D, cap)


def test_waterfill_properties_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def inner(seed):
        for D, cap in _seeded_cases(n=3, seed=seed):
            _check_waterfill_properties(D, cap)

    inner()


def test_waterfill_single_edge_monotonicity():
    """When every flow uses exactly one edge the edges decouple, and
    removing a flow never decreases any survivor's rate."""
    rng = np.random.default_rng(7)
    for _ in range(60):
        F = int(rng.integers(1, 8))
        E = int(rng.integers(1, 8))
        D = np.zeros((F, E))
        for f in range(F):
            D[f, int(rng.integers(E))] = float(rng.integers(1, 4))
        cap = np.round(rng.uniform(0.0, 10.0, size=E), 3)
        x = flowsim.waterfill_levels(D, cap)
        for drop in range(F):
            keep = [f for f in range(F) if f != drop]
            if not keep:
                continue
            x2 = flowsim.waterfill_levels(D[keep], cap)
            assert (x2 >= x[keep] - 1e-9).all()


def test_waterfill_levels_edge_cases():
    # no flows / no edges
    assert flowsim.waterfill_levels(np.zeros((0, 3)), np.ones(3)).shape == (0,)
    x = flowsim.waterfill_levels(np.zeros((2, 0)), np.zeros(0))
    assert (x == 1.0).all()
    # zero capacity: demanding flows get exactly 0, idle flows stay at 1
    D = np.array([[1.0, 0.0], [0.0, 0.0]])
    x = flowsim.waterfill_levels(D, np.zeros(2))
    assert x[0] == 0.0 and x[1] == 1.0
    # everyone fits → all 1
    x = flowsim.waterfill_levels(np.ones((3, 2)), np.full(2, 10.0))
    assert (x == 1.0).all()


# ---------------------------------------------------------------------------
# slowdown cap surface (ISSUE 4 satellite: φ→0 on fully-dark circuits)
# ---------------------------------------------------------------------------

def _dark_config(spec, num_groups=2):
    """A config with zero circuits everywhere: every edge is dark."""
    return OCSConfig(spec, num_groups=num_groups).freeze()


def test_dark_circuit_with_residual_cap_is_floored():
    spec = ClusterSpec(num_pods=4, k_spine=8, k_leaf=8)  # default cap 4.0
    flows = [flowsim.JobFlows(0, {(0, 1): 2}, 0.5)]
    phi = flowsim.waterfill_fractions(spec, flows, _dark_config(spec), "cross_wiring")
    assert phi[0] == pytest.approx(1.0 / 4.0)
    assert flowsim.job_slowdown(0.5, phi[0], cap=spec.slowdown_cap) == pytest.approx(
        1.0 + 0.5 * 3.0
    )


def test_dark_circuit_without_residual_cap_stalls():
    """A fully-dark circuit with slowdown_cap=None must NOT yield a finite
    slowdown: there is no residual electrical path to limp along on."""
    spec = ClusterSpec(num_pods=4, k_spine=8, k_leaf=8, slowdown_cap=None)
    flows = [flowsim.JobFlows(0, {(0, 1): 2}, 0.5)]
    phi = flowsim.waterfill_fractions(spec, flows, _dark_config(spec), "cross_wiring")
    assert phi[0] == 0.0
    assert flowsim.job_slowdown(0.5, 0.0, cap=None) == math.inf
    # compute-only flows are unaffected even at φ=0
    assert flowsim.job_slowdown(0.0, 0.0, cap=None) == 1.0


def test_slowdown_cap_validation():
    with pytest.raises(ValueError):
        ClusterSpec(num_pods=4, k_spine=8, k_leaf=8, slowdown_cap=0.5)


# ---------------------------------------------------------------------------
# FluidSim: dark windows, stalls, capacity events
# ---------------------------------------------------------------------------

def _ring_setup(P=8, k=8, pods=(0, 2, 4, 6), links=2):
    spec = ClusterSpec(num_pods=P, k_spine=k, k_leaf=k)
    edges = flowsim.ring_edges(list(pods), links)
    C = dist_demand.edges_to_matrix(edges, P, 2)
    config = mdmcf_reconfigure(spec, C).config
    return spec, edges, config


def test_fluid_dark_window_delays_completion():
    spec, edges, config = _ring_setup()
    alpha, work = 0.4, 100.0
    base = fluid.FluidSim(
        spec, "cross_wiring", config, flows=[fluid.Flow(0, edges, alpha, work)]
    )
    base_jct = base.run()[0].jct
    # darken one ring edge for 10 s mid-run, no residual electrical fabric:
    # the flow must fully stall for the window
    spec_hard = ClusterSpec(
        num_pods=spec.num_pods, k_spine=spec.k_spine, k_leaf=spec.k_leaf,
        slowdown_cap=None,
    )
    dark = fluid.CapacityEvent(
        time=10.0, dark_pairs=frozenset({(0, 2)}), downtime_s=10.0, rewired=4
    )
    sim = fluid.FluidSim(
        spec_hard, "cross_wiring", config,
        flows=[fluid.Flow(0, edges, alpha, work)], capacity_events=[dark],
    )
    rec = sim.run()[0]
    assert rec.jct == pytest.approx(base_jct + 10.0)
    assert rec.stalled_s == pytest.approx(10.0)
    assert rec.min_phi == 0.0
    assert sim.downtime_circuit_s == pytest.approx(10.0 * 4)


def test_fluid_dark_window_with_residual_cap_limps():
    """With the default residual cap the flow keeps crawling at 1/cap
    through the window instead of stalling outright."""
    spec, edges, config = _ring_setup()
    alpha, work = 0.4, 100.0
    dark = fluid.CapacityEvent(
        time=10.0, dark_pairs=frozenset({(0, 2)}), downtime_s=10.0
    )
    sim = fluid.FluidSim(
        spec, "cross_wiring", config,
        flows=[fluid.Flow(0, edges, alpha, work)], capacity_events=[dark],
    )
    rec = sim.run()[0]
    base = fluid.FluidSim(
        spec, "cross_wiring", config, flows=[fluid.Flow(0, edges, alpha, work)]
    ).run()[0]
    slow = flowsim.job_slowdown(alpha, 1.0 / 4.0, cap=4.0)
    lost = 10.0 * (1.0 - 1.0 / slow)  # work-seconds lost to the window,
    # made up at full rate (φ=1) once the window closes
    assert rec.stalled_s == 0.0
    assert rec.jct == pytest.approx(base.jct + lost, rel=1e-9)


def test_fluid_contention_beats_snapshot():
    """Two staggered flows on one edge: the fluid JCT of the first flow is
    *shorter* than a whole-run snapshot stretch (it ran alone before the
    second arrived) — the time-varying effect the closed form misses."""
    spec = ClusterSpec(num_pods=4, k_spine=8, k_leaf=8)
    edges = {(0, 1): 8}
    C = dist_demand.edges_to_matrix(edges, 4, 2)
    config = mdmcf_reconfigure(spec, C).config  # capacity exactly one flow
    flows = [
        fluid.Flow(0, edges, 0.5, 100.0, arrival=0.0),
        fluid.Flow(1, edges, 0.5, 100.0, arrival=50.0),
    ]
    recs = fluid.FluidSim(spec, "cross_wiring", config, flows=flows).run()
    jf = [flowsim.JobFlows(f.flow_id, f.edges, f.comm_fraction) for f in flows]
    phi_both = flowsim.waterfill_fractions(spec, jf, config, "cross_wiring")
    snap = 100.0 * flowsim.job_slowdown(0.5, phi_both[0])
    alone = 100.0
    assert alone < recs[0].jct < snap
    # conservation at the fluid level: both flows finish, in arrival order
    assert recs[0].finish < recs[1].finish


def test_overlapping_dark_windows_stay_per_pair():
    """A long outage on one pair must not extend an unrelated pair's
    short window (windows are tracked per pair, not collapsed into one
    global interval)."""
    spec = ClusterSpec(
        num_pods=6, k_spine=8, k_leaf=8, slowdown_cap=None
    )
    edges_a, edges_b = {(0, 1): 2}, {(2, 3): 2}
    agg = {**edges_a, **edges_b}
    C = dist_demand.edges_to_matrix(agg, 6, 2)
    config = mdmcf_reconfigure(spec, C).config
    events = [
        fluid.CapacityEvent(0.0, dark_pairs=frozenset({(0, 1)}), downtime_s=50.0),
        fluid.CapacityEvent(10.0, dark_pairs=frozenset({(2, 3)}), downtime_s=1.0),
    ]
    flows = [
        fluid.Flow(0, edges_a, 0.5, 100.0),
        fluid.Flow(1, edges_b, 0.5, 100.0),
    ]
    sim = fluid.FluidSim(
        spec, "cross_wiring", config, flows=flows, capacity_events=events
    )
    recs = {r.flow_id: r for r in sim.run()}
    assert recs[0].stalled_s == pytest.approx(50.0)
    assert recs[1].stalled_s == pytest.approx(1.0)  # not 40 s
    assert recs[1].finish == pytest.approx(101.0)
    # re-darkening the same pair merges instead of double-counting
    w = fluid.DarkWindows()
    w.add([(0, 1)], 0.0, 5.0)
    w.add([(0, 1)], 3.0, 8.0)
    assert w.active(4.0) == [(0, 1)]
    assert not w.prune(5.0) and w.prune(8.0)


def test_fluid_until_caps_time():
    spec, edges, config = _ring_setup()
    sim = fluid.FluidSim(
        spec, "cross_wiring", config, flows=[fluid.Flow(0, edges, 0.3, 1e6)]
    )
    recs = sim.run(until=100.0)
    assert math.isnan(recs[0].finish)


def test_fluid_fractions_match_waterfill_on_healthy_snapshot():
    spec, edges, config = _ring_setup()
    flows = [
        flowsim.JobFlows(0, edges, 0.3),
        flowsim.JobFlows(1, {(0, 2): 3, (2, 4): 1}, 0.5),
    ]
    a = flowsim.waterfill_fractions(spec, flows, config, "cross_wiring")
    b = fluid.fluid_fractions(spec, flows, config, "cross_wiring")
    assert a == b


# ---------------------------------------------------------------------------
# scheduler integration: engine axis, downtime accounting
# ---------------------------------------------------------------------------

def _jobs(n=40, pods=8, k=8, seed=1):
    return generate_trace(
        n, num_gpus=pods * k * k, workload_level=0.85, seed=seed,
        max_job_gpus=pods * k * k // 4,
    )


def test_engine_validation():
    with pytest.raises(ValueError):
        SimConfig(architecture="best", strategy="none", engine="packet")
    with pytest.raises(ValueError):
        SimConfig(architecture="best", strategy="none", reconfig_delay_s=-1.0)


def test_fluid_engine_completes_and_prices_downtime():
    jobs = _jobs()
    sim = Simulator(
        SimConfig(
            architecture="cross_wiring", strategy="mdmcf",
            num_pods=8, k_spine=8, k_leaf=8,
            engine="fluid", reconfig_delay_s=0.1,
        ),
        jobs,
    )
    recs = sim.run()
    assert all(math.isfinite(r.finish) for r in recs)
    if sim.downtime_events:
        assert sim.downtime_circuit_s > 0
        assert sim.downtime_s == pytest.approx(0.1 * sim.downtime_events)


def test_fluid_engine_deterministic():
    jobs = _jobs(30)
    cfg = SimConfig(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=8, k_spine=8, k_leaf=8, engine="fluid", reconfig_delay_s=0.05,
    )
    r1 = Simulator(cfg, jobs).run()
    r2 = Simulator(cfg, jobs).run()
    assert [(r.start, r.finish) for r in r1] == [(r.start, r.finish) for r in r2]


def test_reconfig_delay_never_speeds_jobs_up():
    jobs = _jobs(30)
    base_cfg = dict(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=8, k_spine=8, k_leaf=8, engine="fluid",
    )
    r0 = Simulator(SimConfig(**base_cfg, reconfig_delay_s=0.0), jobs).run()
    r1 = Simulator(SimConfig(**base_cfg, reconfig_delay_s=1.0), jobs).run()
    assert summarize(r1)["avg_jct"] >= summarize(r0)["avg_jct"] - 1e-9


def test_scheduler_extra_strategies_smoke():
    """mcf / helios / uniform_ilp through both engines (coverage of the
    strategy dispatch; correctness of each solver is tested elsewhere)."""
    jobs = _jobs(15)
    for arch, strat in [
        ("cross_wiring", "mcf"),
        ("uniform", "helios"),
        ("uniform", "uniform_ilp"),
    ]:
        for engine in ("analytic", "fluid"):
            sim = Simulator(
                SimConfig(
                    architecture=arch, strategy=strat,
                    num_pods=8, k_spine=8, k_leaf=8, engine=engine,
                ),
                jobs,
            )
            recs = sim.run()
            assert all(math.isfinite(r.finish) for r in recs), (arch, strat, engine)


# ---------------------------------------------------------------------------
# fluid-priced recovery-policy costs
# ---------------------------------------------------------------------------

def test_policy_costs_shape_and_ordering():
    kw = dict(
        service_s=10000.0, progress_s=6000.0, model="llama2-13b",
        num_gpus=128, lost_gpus=64, comm_fraction=0.3,
        ckpt_interval_s=1800.0,
    )
    healthy = policy_costs(phi_shrunk=1.0, **kw)
    degraded = policy_costs(phi_shrunk=0.25, **kw)
    assert set(healthy) == {REWIRE_AROUND, CKPT_RESTART, SHRINK_COLLECTIVE}
    # restart costs don't depend on the measured φ; shrink does
    assert healthy[REWIRE_AROUND] == degraded[REWIRE_AROUND]
    assert healthy[CKPT_RESTART] == degraded[CKPT_RESTART]
    assert degraded[SHRINK_COLLECTIVE] > healthy[SHRINK_COLLECTIVE]
    # losing every GPU makes shrink impossible
    dead = policy_costs(
        phi_shrunk=1.0, **{**kw, "lost_gpus": kw["num_gpus"]}
    )
    assert dead[SHRINK_COLLECTIVE] == math.inf
    # with deep progress and a checkpoint to restore, scratch-restart is
    # strictly worse than rolling back
    assert healthy[REWIRE_AROUND] > healthy[CKPT_RESTART]


def test_policy_costs_second_shrink_uses_full_calibration_base():
    """A job that already shrank once (cur_gpus < num_gpus) must price a
    further shrink against its *full* size: service time is calibrated to
    num_gpus, and _shrink_job will set compute_scale = num_gpus/survivors."""
    kw = dict(
        service_s=10000.0, progress_s=2000.0, model="llama2-13b",
        comm_fraction=0.0, phi_shrunk=1.0, ckpt_interval_s=1800.0,
    )
    second = policy_costs(num_gpus=256, cur_gpus=192, lost_gpus=64, **kw)
    # survivors = 128 → the remaining 8000 s stretch by 256/128 = 2×
    assert second[SHRINK_COLLECTIVE] == pytest.approx(8000.0 * 2.0)
    never_shrunk = policy_costs(num_gpus=256, lost_gpus=64, **kw)
    assert never_shrunk[SHRINK_COLLECTIVE] == pytest.approx(8000.0 * 256 / 192)


def test_policy_costs_stall_pricing():
    """With no residual fabric and a fully-dark shrunken ring, shrink is
    priced as never finishing."""
    c = policy_costs(
        service_s=1000.0, progress_s=100.0, model="llama2-13b",
        num_gpus=16, lost_gpus=8, comm_fraction=0.3, phi_shrunk=0.0,
        ckpt_interval_s=600.0, slowdown_cap=None,
    )
    assert c[SHRINK_COLLECTIVE] == math.inf


def test_cheapest_policy_in_scheduler():
    """`recovery_policy='cheapest'` picks per victim from the fluid-priced
    costs and logs the decision."""
    pods, k = 12, 8
    jobs = _jobs(25, pods=pods, k=k, seed=4)
    t_fail = jobs[8].arrival
    events = [
        FailureEvent(t_fail, "pod", pod=1),
        RepairEvent(t_fail + 3600.0, "pod", pod=1),
    ]
    sim = Simulator(
        SimConfig(
            architecture="cross_wiring", strategy="mdmcf",
            num_pods=pods, k_spine=k, k_leaf=k,
            engine="fluid", recovery_policy=CHEAPEST,
        ),
        jobs,
        fault_events=events,
    )
    recs = sim.run()
    assert all(math.isfinite(r.finish) for r in recs)
    for d in sim.policy_decisions:
        assert d["policy"] in (REWIRE_AROUND, CKPT_RESTART, SHRINK_COLLECTIVE)
        chosen = d["policy"]
        for other in (REWIRE_AROUND, CKPT_RESTART, SHRINK_COLLECTIVE):
            assert d[chosen] <= d[other] + 1e-9
