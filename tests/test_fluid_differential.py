"""Differential harness: fluid engine vs the closed-form flow model.

On *static* scenarios — a fixed realized configuration, no
reconfiguration, no faults — the event-driven fluid simulator must
reproduce the closed-form stretch-factor JRT

    JRT = T_best · (1 + α · (1/φ − 1))

to 1e-6 relative tolerance, for every architecture (best / cross_wiring /
uniform / clos): a single job trivially, and non-overlapping multi-job
sequences job by job (each runs alone, so contention never kicks in and
the snapshot model is exact).  Seeded placements always; hypothesis-
generated placements when available.  A scheduler-level twin checks that
``SimConfig.engine`` produces identical records on a contention-free
trace.
"""
import math

import numpy as np
import pytest

from repro.core.reconfig import mdmcf_reconfigure, uniform_greedy
from repro.core.topology import ClusterSpec
from repro.dist import demand as dist_demand
from repro.sim import SimConfig, Simulator, generate_trace
from repro.sim import flowsim, fluid

ARCHES = ("best", "cross_wiring", "uniform", "clos")
RTOL = 1e-6


def _solve_config(spec, arch, all_edges, num_groups=2):
    """Fixed realized configuration for the union demand of a scenario."""
    if arch in ("best", "clos"):
        return None
    agg = {}
    for edges in all_edges:
        for e, w in edges.items():
            agg[e] = agg.get(e, 0) + w
    C = dist_demand.edges_to_matrix(agg, spec.num_pods, num_groups)
    C = dist_demand.clip_feasible(C, spec.k_spine)
    if arch == "cross_wiring":
        return mdmcf_reconfigure(spec, C).config
    return uniform_greedy(spec, C).config


def _closed_form_jrt(spec, flow, config, arch):
    """Snapshot JRT of ``flow`` running *alone* on ``config``."""
    jf = [flowsim.JobFlows(flow.flow_id, flow.edges, flow.comm_fraction)]
    phi = flowsim.waterfill_fractions(spec, jf, config, arch)
    slow = flowsim.job_slowdown(
        flow.comm_fraction, phi[flow.flow_id], cap=spec.slowdown_cap
    )
    return flow.work * slow


def _random_scenario(rng, n_jobs):
    """Random placements → (spec, flows) with non-overlapping arrivals
    computed later from the closed form."""
    P = int(rng.choice([6, 8, 12]))
    k = int(rng.choice([8, 16]))
    spec = ClusterSpec(num_pods=P, k_spine=k, k_leaf=k)
    flows = []
    for fid in range(n_jobs):
        n = int(rng.integers(2, min(6, P) + 1))
        pods = sorted(rng.choice(P, size=n, replace=False).tolist())
        links = int(rng.integers(1, max(2, k // n)))
        edges = flowsim.ring_edges(pods, links)
        alpha = float(rng.uniform(0.05, 0.9))
        work = float(rng.uniform(50.0, 5000.0))
        flows.append(fluid.Flow(fid, edges, alpha, work))
    return spec, flows


def _check_differential(spec, flows, arch, gap=1.0):
    config = _solve_config(spec, arch, [f.edges for f in flows])
    # stagger arrivals so no two jobs ever overlap: each starts after the
    # previous one's closed-form completion
    t = 0.0
    expected = {}
    for f in flows:
        f.arrival = t
        jrt = _closed_form_jrt(spec, f, config, arch)
        expected[f.flow_id] = jrt
        t += jrt + gap
    sim = fluid.FluidSim(spec, arch, config, flows=flows)
    recs = {r.flow_id: r for r in sim.run()}
    for f in flows:
        got = recs[f.flow_id].jct
        want = expected[f.flow_id]
        assert got == pytest.approx(want, rel=RTOL), (
            arch, f.flow_id, want, got
        )


@pytest.mark.parametrize("arch", ARCHES)
def test_single_job_matches_closed_form(arch):
    rng = np.random.default_rng(11)
    for _ in range(8):
        spec, flows = _random_scenario(rng, 1)
        _check_differential(spec, flows, arch)


@pytest.mark.parametrize("arch", ARCHES)
def test_non_overlapping_multijob_matches_closed_form(arch):
    rng = np.random.default_rng(29)
    for _ in range(4):
        spec, flows = _random_scenario(rng, int(rng.integers(2, 5)))
        _check_differential(spec, flows, arch)


def test_differential_hypothesis_placements():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from(ARCHES))
    def inner(seed, arch):
        rng = np.random.default_rng(seed)
        spec, flows = _random_scenario(rng, int(rng.integers(1, 4)))
        _check_differential(spec, flows, arch)

    inner()


def test_planner_edges_differential():
    """Same guarantee with real planner demand (MoE all-to-all, PP chain)
    instead of synthetic rings."""
    spec = ClusterSpec(num_pods=8, k_spine=16, k_leaf=16)
    cases = [
        ("mixtral-8x7b", [0, 1, 2, 3, 4], 8, 1, 4),
        ("llama2-70b", [1, 3, 5, 7], 1, 4, 4),
        ("llama2-13b", [0, 2, 4], 1, 1, 8),
    ]
    flows = []
    for fid, (model, pods, ep, pp, links) in enumerate(cases):
        edges, alpha = dist_demand.job_flow(model, pods, links, ep=ep, pp=pp)
        flows.append(fluid.Flow(fid, edges, alpha, 1000.0))
    for arch in ARCHES:
        _check_differential(spec, [
            fluid.Flow(f.flow_id, dict(f.edges), f.comm_fraction, f.work)
            for f in flows
        ], arch)


def test_scheduler_engines_agree_without_contention():
    """With one job in flight at a time and no reconfiguration delay, the
    scheduler produces identical records under both engines."""
    import dataclasses

    raw = generate_trace(
        12, num_gpus=8 * 64, workload_level=0.05, seed=13, max_job_gpus=128
    )
    # space arrivals so no two jobs ever overlap (slowdown-capped JRT is at
    # most 4× service time): truly contention-free
    t, jobs = 0.0, []
    for j in raw:
        jobs.append(dataclasses.replace(j, arrival=t))
        t += 4.0 * j.service_time + 60.0
    recs = {}
    for engine in ("analytic", "fluid"):
        sim = Simulator(
            SimConfig(
                architecture="cross_wiring", strategy="mdmcf",
                num_pods=8, k_spine=8, k_leaf=8,
                engine=engine, reconfig_delay_s=0.0,
            ),
            jobs,
        )
        recs[engine] = sim.run()
    for a, b in zip(recs["analytic"], recs["fluid"]):
        assert math.isfinite(a.finish) and math.isfinite(b.finish)
        assert b.jct == pytest.approx(a.jct, rel=RTOL)
