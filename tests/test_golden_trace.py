"""Golden-trace regression: a seeded fluid-engine run must reproduce the
committed per-job JCT table (tests/golden/fluid_trace.json) within 1e-6
relative tolerance.  On mismatch the assertion prints a per-job diff and
the regeneration command — behavioral drift must be a reviewed diff, not
a silent change."""
import json
import math

import pytest

from tests.golden import regen

RTOL = 1e-6


def _load_golden():
    try:
        with open(regen.GOLDEN_PATH) as fh:
            return json.load(fh)
    except FileNotFoundError:  # pragma: no cover - repo always ships it
        pytest.fail(
            "tests/golden/fluid_trace.json missing — generate it with: "
            "PYTHONPATH=src python -m tests.golden.regen"
        )


def test_fluid_golden_trace():
    golden = _load_golden()
    assert golden["scenario"] == regen.SCENARIO, (
        "Golden scenario drifted from regen.SCENARIO — regenerate with: "
        "PYTHONPATH=src python -m tests.golden.regen"
    )
    table = regen.build_table()
    diffs = []
    for jid, want in sorted(golden["jct"].items(), key=lambda kv: int(kv[0])):
        got = table["jct"].get(jid)
        if want is None or got is None:
            if want != got:
                diffs.append(f"  job {jid}: want {want}, got {got}")
            continue
        rel = abs(got - want) / max(abs(want), 1e-12)
        if not math.isfinite(got) or rel > RTOL:
            diffs.append(
                f"  job {jid}: want {want:.6f}, got {got:.6f} (rel {rel:.2e})"
            )
    assert not diffs, (
        "Golden fluid trace diverged ({} of {} jobs):\n{}\n"
        "If this change is intentional, regenerate the table with:\n"
        "    PYTHONPATH=src python -m tests.golden.regen\n"
        "and commit the updated tests/golden/fluid_trace.json.".format(
            len(diffs), len(golden["jct"]), "\n".join(diffs)
        )
    )
    assert table["downtime_events"] == golden["downtime_events"]
    assert table["reconfig_calls"] == golden["reconfig_calls"]
