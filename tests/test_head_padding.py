"""Head-padding (§Perf iteration 2): the padded attention path must be
*exactly* equivalent to the unpadded path — padded q slots are zeros and
their outputs are sliced away before the output projection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import shard_hints
from repro.models.attention import _head_pad_plan, gqa_attention
from repro.models.config import ModelConfig


@pytest.fixture(autouse=True)
def _reset_sizes():
    yield
    shard_hints._set_sizes_for_test({})
    shard_hints.use_hints(None)


@pytest.mark.parametrize(
    "hq,hkv,m,expect",
    [
        (40, 8, 16, (2, 16, 3, 48)),   # qwen2.5
        (48, 8, 16, (2, 16, 3, 48)),   # grok
        (64, 8, 16, (2, 16, 4, 64)),   # jamba
        (16, 8, 16, (2, 16, 1, 16)),   # gemma2
        (14, 2, 16, (8, 16, 1, 16)),   # internvl2
        (16, 16, 16, None),            # already divisible
        (8, 1, 16, None),              # gemma-2b: 2× waste → rejected
        (12, 12, 16, None),            # whisper: 4× waste → rejected
    ],
)
def test_pad_plan(hq, hkv, m, expect):
    shard_hints._set_sizes_for_test({"model": m})
    plan = _head_pad_plan(hq, hkv)
    if expect is None:
        assert plan is None
        return
    r, hkv_p, g_p, hq_p, perm, inv = plan
    assert (r, hkv_p, g_p, hq_p) == expect
    perm = np.asarray(perm)
    inv = np.asarray(inv)
    # every original head appears exactly once, at the slot inv points to
    orig = perm[perm >= 0]
    assert sorted(orig.tolist()) == list(range(hq))
    for h in range(hq):
        assert perm[inv[h]] == h
    # group consistency: padded slot s uses kv_p[s // g_p] = kv[(s//g_p)//r],
    # which must equal the original head's kv group perm[s] // (hq//hkv)
    g = hq // hkv
    for s, o in enumerate(perm):
        if o >= 0:
            assert (s // g_p) // r == o // g


@pytest.mark.parametrize("hq,hkv,m", [(40, 8, 16), (14, 2, 16), (64, 8, 16)])
def test_padded_attention_exact(hq, hkv, m):
    """gqa_attention with the padding plan active equals the plain path."""
    cfg = ModelConfig(
        name="t", family="dense", num_layers=1, d_model=hq * 16,
        num_heads=hq, num_kv_heads=hkv, d_ff=64, vocab_size=64, head_dim=16,
        param_dtype="float32", compute_dtype="float32",
    )
    params = jax.vmap(lambda k: None)  # placeholder
    from repro.models.attention import init_gqa

    p = init_gqa(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(2, 24, cfg.d_model)).astype(np.float32)
    )
    base, _ = gqa_attention(p, x, cfg)

    shard_hints._set_sizes_for_test({"model": m})
    # make active() true without a real mesh: register the host mesh but
    # keep the test sizes (model=m) for the planner
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard_hints.use_hints(mesh)
    shard_hints._set_sizes_for_test({"model": m, "data": 1})
    padded, _ = gqa_attention(p, x, cfg)
    np.testing.assert_allclose(
        np.asarray(base), np.asarray(padded), atol=2e-5, rtol=2e-5
    )
