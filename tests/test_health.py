"""Tests for the streaming cluster health monitor (repro.obs.health).

Each detector is driven with a synthetic signal that crosses its
threshold, and the firing discipline is checked: fire once on the
breach, stay silent while the condition persists (hot latch), re-arm
only after it clears.  Plus the wiring: ``SimConfig.on_health``
activates the monitor inside the scheduler, firings land in the tracer
as ``health``-category instants, and attaching the monitor never
perturbs simulation results (passivity).
"""
import math

import pytest

from repro import obs
from repro.obs.health import BurnWindow, HealthEvent, HealthMonitor
from repro.sim import SimConfig, Simulator, generate_trace

P, K = 12, 8
GPUS = P * K * K


def _monitor(**kw):
    fired = []
    kw.setdefault("on_event", fired.append)
    return HealthMonitor(**kw), fired


# ---- phi_drop --------------------------------------------------------------

def test_phi_drop_fires_on_collapse_not_on_drift():
    mon, fired = _monitor(slo=4.0, phi_drop_ratio=0.5)
    mon.observe_phi(0.0, 7, 1.0)
    mon.observe_phi(1.0, 7, 0.9)   # mild drift: no event
    assert fired == []
    mon.observe_phi(2.0, 7, 0.4)   # 0.4 <= 0.5 · 0.9 → collapse
    assert [e.detector for e in fired] == ["phi_drop"]
    assert fired[0].severity == "warn" and fired[0].key == 7
    assert fired[0].value == pytest.approx(0.4 / 0.9)
    # a further slow decay from the already-low level is not a new drop
    mon.observe_phi(3.0, 7, 0.35)
    assert len(fired) == 1


def test_phi_drop_to_zero_pages():
    mon, fired = _monitor()
    mon.observe_phi(0.0, 1, 1.0)
    mon.observe_phi(1.0, 1, 0.0)
    assert [(e.detector, e.severity) for e in fired] == [("phi_drop", "page")]


# ---- slo_burn --------------------------------------------------------------

FAST = BurnWindow(short_s=60.0, long_s=600.0, frac=0.5, severity="page")


def test_slo_burn_fires_once_and_rearms_after_recovery():
    mon, fired = _monitor(slo=4.0, burn_rules=(FAST,), phi_drop_ratio=0.0)
    # φ = 0.2 < 1/slo = 0.25: burning budget from t=0
    for t in range(0, 130, 10):
        mon.observe_phi(float(t), 3, 0.2)
    burns = [e for e in fired if e.detector == "slo_burn"]
    assert len(burns) == 1, "sustained breach must fire once, not per sample"
    assert burns[0].severity == "page"
    assert burns[0].value >= 0.5 and burns[0].threshold == 0.5
    # recovery: healthy φ long enough to clear both windows
    for t in range(130, 1400, 10):
        mon.observe_phi(float(t), 3, 1.0)
    assert len([e for e in fired if e.detector == "slo_burn"]) == 1
    # second breach after re-arm fires again
    for t in range(1400, 2200, 10):
        mon.observe_phi(float(t), 3, 0.2)
    assert len([e for e in fired if e.detector == "slo_burn"]) == 2


def test_slo_burn_needs_both_windows():
    """A transient spike trips the short window but not the long one —
    the multi-window rule must stay silent."""
    mon, fired = _monitor(slo=4.0, burn_rules=(FAST,), phi_drop_ratio=0.0)
    for t in range(0, 550, 10):            # 550 s healthy history
        mon.observe_phi(float(t), 3, 1.0)
    for t in range(550, 600, 10):          # 50 s bad: short-window frac
        mon.observe_phi(float(t), 3, 0.2)  # ≈ 0.83, long-window ≈ 0.08
    assert [e for e in fired if e.detector == "slo_burn"] == []


def test_bad_fraction_ignores_unobserved_time():
    mon, _ = _monitor(phi_drop_ratio=0.0)
    mon.observe_phi(100.0, 5, 0.0)   # fleet comes up at t=100
    mon.observe_phi(110.0, 5, 1.0)
    # only 10 s observed; a 600 s window must not dilute the fraction
    assert mon.bad_fraction(5, 110.0, 600.0) == pytest.approx(1.0)
    assert mon.bad_fraction(99, 110.0, 600.0) == 0.0  # unknown key


def test_finalize_flushes_trailing_segment():
    mon, _ = _monitor(phi_drop_ratio=0.0)
    mon.observe_phi(0.0, 2, 0.1)
    assert mon.bad_fraction(2, 50.0, 100.0) == 0.0  # nothing pushed yet
    mon.finalize(50.0)
    assert mon.bad_fraction(2, 50.0, 100.0) == pytest.approx(1.0)


# ---- dark_storm ------------------------------------------------------------

def test_dark_storm_latches_and_cools():
    mon, fired = _monitor(storm_window_s=60.0, storm_circuit_s=10.0)
    mon.observe_dark(0.0, 0.1, 50, "incremental")    # 5 circuit-s
    assert fired == []
    mon.observe_dark(1.0, 0.1, 60, "cold")           # total 11 → storm
    assert [e.detector for e in fired] == ["dark_storm"]
    assert fired[0].severity == "page"
    assert fired[0].value == pytest.approx(11.0)
    mon.observe_dark(2.0, 0.1, 10, "cold")           # still hot: no refire
    assert len(fired) == 1
    mon.observe_dark(100.0, 0.1, 10, "cold")         # window slid: cooled
    assert len(fired) == 1
    mon.observe_dark(101.0, 0.1, 95, "cold")         # breach again → refire
    assert len(fired) == 2


# ---- reconfig_churn --------------------------------------------------------

def test_reconfig_churn_needs_count_and_cold_share():
    mon, fired = _monitor(
        churn_window_s=600.0, churn_solves=8, churn_cold_frac=0.5,
    )
    for n in range(8):                       # 8 solves, all incremental
        mon.observe_solve(float(n), "incremental")
    assert fired == []                       # count met, cold share 0
    for n in range(8, 16):                   # now 8 cold in the window
        mon.observe_solve(float(n), "cold")
    churn = [e for e in fired if e.detector == "reconfig_churn"]
    assert len(churn) == 1
    assert churn[0].severity == "warn"
    assert churn[0].value >= 0.5


# ---- emission / wiring -----------------------------------------------------

def test_firings_land_in_tracer_as_health_instants():
    tr = obs.Tracer()
    mon = HealthMonitor(tracer=tr)
    mon.observe_phi(0.0, 9, 1.0)
    mon.observe_phi(1.0, 9, 0.0)
    evs = tr.events("health")
    assert len(evs) == 1 and evs[0]["ph"] == "i"
    assert evs[0]["name"] == "phi_drop"
    assert evs[0]["args"]["severity"] == "page"
    assert evs[0]["args"]["key"] == 9
    # and the event list mirrors it
    assert [e.detector for e in mon.events] == ["phi_drop"]


def test_health_event_fields_are_frozen():
    ev = HealthEvent(1.0, "dark_storm", "page", value=2.0, threshold=1.0)
    with pytest.raises(Exception):
        ev.t = 2.0


def _small_cfg(**kw):
    return SimConfig(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=P, k_spine=K, k_leaf=K, engine="fluid",
        reconfig_delay_s=0.01, **kw,
    )


def _small_jobs():
    return generate_trace(
        10, num_gpus=GPUS, workload_level=0.9, seed=3,
        max_job_gpus=GPUS // 4, serving_jobs=1, serving_gpus=128,
    )


def test_on_health_hook_activates_monitor_and_stays_passive():
    seen = []
    sim = Simulator(_small_cfg(on_health=seen.append), _small_jobs())
    assert sim.health is not None
    assert sim.health.on_event is not None
    recs = sim.run()
    # every observed event also sits in the monitor's own list
    assert seen == sim.health.events
    # passivity: identical run without the monitor, same outcomes
    plain = Simulator(_small_cfg(), _small_jobs())
    assert plain.health is None
    precs = plain.run()
    assert [r.finish for r in recs] == [r.finish for r in precs]
    assert [r.min_phi for r in recs] == [r.min_phi for r in precs]


def test_tracer_alone_activates_monitor():
    sim = Simulator(_small_cfg(tracer=obs.Tracer()), _small_jobs())
    assert sim.health is not None and sim.health.on_event is None
