"""Loop-aware HLO analyzer: trip-count propagation, dot-FLOPs accounting,
alias-aware fusion traffic, collective classification — on crafted HLO
text fixtures (fast, deterministic) plus the end-to-end property that
scan length multiplies measured FLOPs."""
import textwrap

from repro.launch import hloparse


FIXTURE = textwrap.dedent(
    """\
    HloModule test

    %body (p: (s32[], f32[32,64])) -> (s32[], f32[32,64]) {
      %p = (s32[], f32[32,64]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[32,64]{1,0} get-tuple-element(%p), index=1
      %w = f32[64,64]{1,0} constant({...})
      %d = f32[32,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[32,64]{1,0} all-reduce(%d), replica_groups={{0,1},{2,3}}, to_apply=%add_comp
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[32,64]{1,0}) tuple(%i2, %ar)
    }

    %cond (pc: (s32[], f32[32,64])) -> pred[] {
      %pc = (s32[], f32[32,64]{1,0}) parameter(0)
      %ic = s32[] get-tuple-element(%pc), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%ic, %n), direction=LT
    }

    %add_comp (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (arg: f32[32,64]) -> f32[32,64] {
      %arg = f32[32,64]{1,0} parameter(0)
      %z = s32[] constant(0)
      %init = (s32[], f32[32,64]{1,0}) tuple(%z, %arg)
      %loop = (s32[], f32[32,64]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %out = f32[32,64]{1,0} get-tuple-element(%loop), index=1
    }
    """
)


def test_trip_count_multiplies_dots():
    ana = hloparse.analyze(FIXTURE)
    # dot: 2 * 32*64 (out) * 64 (K) per iteration, ×5 iterations
    assert ana.flops == 5 * 2 * 32 * 64 * 64


def test_collectives_multiplied_and_classified():
    ana = hloparse.analyze(FIXTURE, chips_per_pod=2)
    ar = ana.collectives["all-reduce"]
    assert ar["count"] == 5
    assert ar["bytes"] == 5 * 32 * 64 * 4
    # groups {0,1},{2,3} stay inside 2-chip pods → no cross-pod bytes
    assert ar["cross_pod_bytes"] == 0


def test_cross_pod_detection():
    cross = FIXTURE.replace("{{0,1},{2,3}}", "{{0,2},{1,3}}")
    ana = hloparse.analyze(cross, chips_per_pod=2)
    assert ana.collectives["all-reduce"]["cross_pod_bytes"] > 0


def test_views_are_free():
    ana = hloparse.analyze(FIXTURE)
    # bytes: only dot, all-reduce, add (s32 scalars) and the while-free ops
    # contribute; ensure it's within a small multiple of the real traffic
    real = 5 * (32 * 64 * 4 * 3 + 32 * 64 * 4 * 2)  # dot (out+2 ops) + ar
    assert ana.bytes <= real * 1.5


def test_end_to_end_scan_scaling():
    """Measured FLOPs of a jitted scan must scale with its length."""
    import jax
    import jax.numpy as jnp

    def make(n):
        def f(x, w):
            def step(c, _):
                return jnp.tanh(c @ w), None
            c, _ = jax.lax.scan(step, x, None, length=n)
            return c

        return (
            jax.jit(f)
            .lower(
                jax.ShapeDtypeStruct((8, 16), jnp.float32),
                jax.ShapeDtypeStruct((16, 16), jnp.float32),
            )
            .compile()
        )

    f3 = hloparse.analyze(make(3).as_text()).flops
    f12 = hloparse.analyze(make(12).as_text()).flops
    assert abs(f12 / f3 - 4.0) < 0.01
