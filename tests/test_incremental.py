"""Incremental topology engineering (repro.core.incremental): a random
sequence of demand deltas through ``mdmcf_delta`` must always match the
cold solve — exact realization, ILP constraints (1)-(6), masked validity —
with rewiring count no worse than the warm-started cold solve's."""
import numpy as np
import pytest

from repro.core.incremental import (
    ColoringState,
    DeltaInfeasible,
    StaleStateError,
    mdmcf_delta,
)
from repro.core.logical import random_feasible_demand, ring_demand
from repro.core.reconfig import check_ilp_constraints, mdmcf_reconfigure
from repro.core.topology import ClusterSpec, demand_feasible
from repro.fault.masks import PortMask
from repro.fault.recover import degrade_demand


def _job_delta_sequence(spec, rng, H, steps, fill=0.5):
    """Yield a job-arrival/-departure demand sequence starting from a
    random base (the workload shape the scheduler feeds the delta path)."""
    C = random_feasible_demand(spec, rng, fill=fill, num_groups=H)
    yield C
    rings = []
    for _ in range(steps):
        if rings and rng.random() < 0.4:
            C = C - rings.pop(int(rng.integers(len(rings))))
        else:
            n = int(rng.integers(2, min(6, spec.num_pods) + 1))
            pods = sorted(
                rng.choice(spec.num_pods, size=n, replace=False).tolist()
            )
            R = ring_demand(spec, pods, links=1, num_groups=H)
            if not demand_feasible(C + R, spec):
                continue
            rings.append(R)
            C = C + R
        yield C


def _run_sequence(spec, rng, H=2, steps=8, fill=0.5):
    """Drive a delta sequence, asserting per-step exactness and that the
    *cumulative* rewiring stays within the warm-started cold solve's (a
    single step may occasionally churn a few more circuits than a full
    re-color would, but the sequence never does — pinning untouched
    demand to its slots wins over any horizon)."""
    seq = _job_delta_sequence(spec, rng, H, steps, fill=fill)
    C0 = next(seq)
    res0 = mdmcf_reconfigure(spec, C0)
    state = ColoringState.from_config(spec, C0, res0.config)
    prev = res0.config
    total_inc = total_cold_warm = 0
    for C in seq:
        res = mdmcf_delta(spec, state, C)
        # exact realization + ILP (1)-(6) on every step
        check_ilp_constraints(spec, C, res.config, topology="cross_wiring")
        assert res.ltrr == pytest.approx(1.0)
        # the rewired metric is the true Σ|Δx|
        assert res.rewired == res.config.rewiring_distance(prev)
        total_inc += res.rewired
        cold_warm = mdmcf_reconfigure(spec, C, old=prev).config
        total_cold_warm += cold_warm.rewiring_distance(prev)
        prev = res.config
    assert total_inc <= total_cold_warm
    return state


@pytest.mark.parametrize("seed", range(8))
def test_delta_sequence_matches_cold_solve(seed):
    rng = np.random.default_rng(seed)
    P = int(rng.integers(4, 12))
    K = int(rng.choice([4, 8]))
    spec = ClusterSpec(num_pods=P, k_spine=K, k_leaf=4)
    _run_sequence(spec, rng)


def test_delta_from_empty_state():
    spec = ClusterSpec(num_pods=6, k_spine=4, k_leaf=4)
    state = ColoringState.empty(spec, 2)
    C = ring_demand(spec, [0, 2, 4], links=1, num_groups=2)
    res = mdmcf_delta(spec, state, C)
    check_ilp_constraints(spec, C, res.config, topology="cross_wiring")
    # back to zero
    res = mdmcf_delta(spec, state, np.zeros_like(C))
    assert res.config.x.sum() == 0


def test_untouched_groups_never_rewire():
    spec = ClusterSpec(num_pods=8, k_spine=8, k_leaf=4)
    rng = np.random.default_rng(0)
    C = random_feasible_demand(spec, rng, fill=0.5, num_groups=3)
    res0 = mdmcf_reconfigure(spec, C)
    state = ColoringState.from_config(spec, C, res0.config)
    C2 = C.copy()
    C2[1] = random_feasible_demand(spec, rng, fill=0.4, num_groups=1)[0]
    res = mdmcf_delta(spec, state, C2)
    check_ilp_constraints(spec, C2, res.config, topology="cross_wiring")
    assert (res.config.x[0] == res0.config.x[0]).all()
    assert (res.config.x[2] == res0.config.x[2]).all()


def test_masked_delta_exact_and_stale_detection():
    rng = np.random.default_rng(5)
    spec = ClusterSpec(num_pods=8, k_spine=8, k_leaf=4)
    H = 2
    mask = PortMask(8, 8, H)
    mask.fail_link(0, 3, 2)
    mask.fail_ocs(1, 6)
    C = degrade_demand(
        random_feasible_demand(spec, rng, fill=0.6, num_groups=H), mask
    )
    res0 = mdmcf_reconfigure(spec, C, mask=mask)
    state = ColoringState.from_config(spec, C, res0.config, mask=mask)
    for _ in range(4):
        C = degrade_demand(
            random_feasible_demand(spec, rng, fill=0.5, num_groups=H), mask
        )
        res = mdmcf_delta(spec, state, C, mask=mask)
        check_ilp_constraints(
            spec, C, res.config, topology="cross_wiring", mask=mask
        )
    # any mask change invalidates the state
    mask.fail_link(1, 0, 0)
    with pytest.raises(StaleStateError):
        mdmcf_delta(spec, state, C, mask=mask)


def test_infeasible_delta_rejected_state_survives():
    spec = ClusterSpec(num_pods=4, k_spine=4, k_leaf=4)
    state = ColoringState.empty(spec, 1)
    bad = np.zeros((1, 4, 4), dtype=np.int64)
    bad[0, 0, 1] = bad[0, 1, 0] = spec.k_spine + 1  # degree overflow
    with pytest.raises(DeltaInfeasible):
        mdmcf_delta(spec, state, bad)
    ok = np.zeros((1, 4, 4), dtype=np.int64)
    ok[0, 0, 1] = ok[0, 1, 0] = 2
    res = mdmcf_delta(spec, state, ok)  # state not poisoned by the reject
    check_ilp_constraints(spec, ok, res.config, topology="cross_wiring")


def test_scheduler_carries_state_and_stays_exact():
    """End-to-end: the simulator's incremental path must keep the raw x
    (no derived-view caches) exactly realizing the aggregate demand."""
    from repro.sim import SimConfig, Simulator, generate_trace

    jobs = generate_trace(
        60, num_gpus=32 * 64, workload_level=0.9, seed=3, max_job_gpus=512
    )
    cfg = SimConfig(
        architecture="cross_wiring", strategy="mdmcf", num_pods=32,
        k_spine=8, k_leaf=8, sim_groups=4, incremental=True,
    )
    sim = Simulator(cfg, jobs)
    recs = sim.run()
    assert sim.delta_calls > 0, "delta path never used"
    st = sim._coloring_state
    assert st is not None and not st._poisoned
    out = st.emit_config()
    out.validate()  # sub-permutation on raw x
    x = out.x.astype(np.int64)
    assert (x.sum(axis=1) == st.C).all()  # exact realization, no caches
    assert (sim.old_config.x == st._x).all()  # emitted mirror in sync
    even, odd = x[:, 0::2], x[:, 1::2]
    assert (odd == np.transpose(even, (0, 1, 3, 2))).all()  # L2 pairing
    # and the workload completed as under the cold path
    import math

    assert all(math.isfinite(r.finish) for r in recs)


def test_scheduler_incremental_matches_cold_jct_ordering():
    """Incremental vs cold runs of the same trace agree on LTRR == 1 and
    complete the same job set (JCTs may differ slightly: min-rewiring
    deltas move fewer circuits, so fewer OCS switching pauses)."""
    from repro.sim import SimConfig, Simulator, generate_trace

    jobs = generate_trace(
        50, num_gpus=32 * 64, workload_level=0.801, seed=1, max_job_gpus=512
    )
    finishes = {}
    for inc in (False, True):
        cfg = SimConfig(
            architecture="cross_wiring", strategy="mdmcf", num_pods=32,
            k_spine=8, k_leaf=8, incremental=inc,
        )
        sim = Simulator(cfg, jobs)
        recs = sim.run()
        assert np.min(sim.ltrr_samples) == pytest.approx(1.0)
        finishes[inc] = [np.isfinite(r.finish) for r in recs]
    assert finishes[False] == finishes[True]


# ---------------------------------------------------------------------------
# hypothesis property: random delta sequences == cold solve, fewer rewirings
# ---------------------------------------------------------------------------

def test_property_random_delta_sequences():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def sequences(draw):
        p = draw(st.integers(4, 10))
        k = draw(st.sampled_from([4, 8]))
        seed = draw(st.integers(0, 2**31 - 1))
        steps = draw(st.integers(2, 8))
        return p, k, seed, steps

    @settings(max_examples=25, deadline=None)
    @given(sequences())
    def inner(arg):
        p, k, seed, steps = arg
        spec = ClusterSpec(num_pods=p, k_spine=k, k_leaf=4)
        rng = np.random.default_rng(seed)
        _run_sequence(spec, rng, steps=steps)

    inner()
