"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps with assert_allclose per the kernel contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, ref, rmsnorm, wkv6
from repro.kernels import ops


def _randn(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Sk,D",
    [
        (1, 1, 1, 128, 128, 64),
        (2, 4, 2, 256, 256, 64),
        (1, 8, 1, 128, 256, 128),  # MQA, cross lengths
        (1, 2, 2, 100, 100, 32),  # non-divisible seq (padding path)
    ],
)
def test_flash_shapes(rng, dtype, B, Hq, Hkv, Sq, Sk, D):
    q = _randn(rng, (B, Hq, Sq, D), dtype)
    k = _randn(rng, (B, Hkv, Sk, D), dtype)
    v = _randn(rng, (B, Hkv, Sk, D), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True, block_q=64, block_k=64)
    expect = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize(
    "kw",
    [
        dict(causal=False),
        dict(causal=True, window=64),
        dict(causal=True, softcap=30.0),
        dict(causal=True, window=32, softcap=50.0),
    ],
)
def test_flash_variants(rng, kw):
    q = _randn(rng, (1, 4, 256, 64), jnp.float32)
    k = _randn(rng, (1, 2, 256, 64), jnp.float32)
    v = _randn(rng, (1, 2, 256, 64), jnp.float32)
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64, **kw)
    expect = ref.mha_reference(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5)


def test_flash_block_shape_independence(rng):
    """Output must not depend on the chosen VMEM tiling."""
    q = _randn(rng, (1, 2, 384, 64), jnp.float32)
    k = _randn(rng, (1, 2, 384, 64), jnp.float32)
    v = _randn(rng, (1, 2, 384, 64), jnp.float32)
    outs = [
        flash_attention(q, k, v, interpret=True, block_q=bq, block_k=bk)
        for bq, bk in [(64, 64), (128, 128), (128, 64), (384, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 256), (3, 5, 512), (64, 128)])
def test_rmsnorm(rng, dtype, shape):
    x = _randn(rng, shape, dtype)
    s = _randn(rng, shape[-1:], dtype)
    out = rmsnorm(x, s, interpret=True, block_rows=16)
    expect = ref.rmsnorm_reference(x, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("T,chunk", [(64, 16), (96, 32), (50, 32), (16, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_wkv6(rng, T, chunk, dtype):
    B, H, K, V = 2, 3, 16, 16
    r = _randn(rng, (B, H, T, K), dtype)
    k = _randn(rng, (B, H, T, K), dtype)
    v = _randn(rng, (B, H, T, V), dtype)
    lw = jnp.asarray(-np.exp(rng.normal(size=(B, H, T, K))).astype(np.float32))
    u = _randn(rng, (H, K), jnp.float32)
    s0 = _randn(rng, (B, H, K, V), jnp.float32)
    y, sf = wkv6(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    ye, se = ref.wkv6_reference(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(se), atol=2e-4, rtol=2e-4)


def test_wkv6_extreme_decay(rng):
    """Strong decay (log_w very negative) must not overflow/NaN — the
    exponent-of-nonpositive construction."""
    B, H, T, K = 1, 1, 32, 8
    r = _randn(rng, (B, H, T, K), jnp.float32)
    k = _randn(rng, (B, H, T, K), jnp.float32)
    v = _randn(rng, (B, H, T, K), jnp.float32)
    lw = jnp.full((B, H, T, K), -50.0)  # decay ~ e^-50
    u = _randn(rng, (H, K), jnp.float32)
    s0 = jnp.zeros((B, H, K, K), jnp.float32)
    y, sf = wkv6(r, k, v, lw, u, s0, chunk=16, interpret=True)
    ye, se = ref.wkv6_reference(r, k, v, lw, u, s0)
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=1e-4)


def test_ops_layout_roundtrip(rng):
    """ops.* accept model layout (B, S, H, D) and agree with the oracle."""
    q = _randn(rng, (2, 64, 4, 32), jnp.float32)
    kv = _randn(rng, (2, 64, 2, 32), jnp.float32)
    a = ops.attention(q, kv, kv, force_pallas=True)
    b = ops.attention(q, kv, kv, force_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    x = _randn(rng, (4, 16, 128), jnp.float32)
    s = _randn(rng, (128,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, s, force_pallas=True)),
        np.asarray(ops.rmsnorm(x, s, force_pallas=False)),
        atol=1e-5,
    )

    B, S, H, K = 1, 48, 2, 8
    r = _randn(rng, (B, S, H, K), jnp.float32)
    k = _randn(rng, (B, S, H, K), jnp.float32)
    v = _randn(rng, (B, S, H, K), jnp.float32)
    lw = jnp.asarray(-np.exp(rng.normal(size=(B, S, H, K))).astype(np.float32))
    u = _randn(rng, (H, K), jnp.float32)
    s0 = jnp.zeros((B, H, K, K), jnp.float32)
    y1, f1 = ops.wkv6(r, k, v, lw, u, s0, force_pallas=True)
    y2, f2 = ops.wkv6(r, k, v, lw, u, s0, force_pallas=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=2e-4)
