"""Logical-topology demand generation: feasibility invariants (eq. 11/12)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.logical import (
    Job,
    Placement,
    jobs_to_demand,
    random_feasible_demand,
    ring_demand,
)
from repro.core.topology import ClusterSpec, demand_feasible


@settings(max_examples=30, deadline=None)
@given(
    st.integers(2, 10),
    st.sampled_from([2, 4, 8, 16]),
    st.floats(0.0, 1.0),
    st.integers(0, 2**31 - 1),
)
def test_random_demand_feasible(p, k, fill, seed):
    spec = ClusterSpec(num_pods=p, k_spine=k, k_leaf=4)
    C = random_feasible_demand(spec, np.random.default_rng(seed), fill=fill)
    assert demand_feasible(C, spec)


def test_ring_demand_structure():
    spec = ClusterSpec(num_pods=6, k_spine=8, k_leaf=4)
    C = ring_demand(spec, [0, 2, 4], links=2)
    assert demand_feasible(C, spec)
    # each hop appears bidirectionally
    assert C[0, 0, 2] == 2 and C[0, 2, 0] == 2
    assert C[0, 2, 4] == 2 and C[0, 4, 0] == 2
    # per-pod degree = 2 hops × 2 links
    assert C[0].sum(axis=1)[0] == 4


def test_ring_demand_two_pods():
    spec = ClusterSpec(num_pods=4, k_spine=8, k_leaf=4)
    C = ring_demand(spec, [1, 3], links=3)
    assert C[0, 1, 3] == 6  # both ring directions collapse onto the pair
    assert demand_feasible(C, spec)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_jobs_to_demand_respects_budget(seed):
    rng = np.random.default_rng(seed)
    spec = ClusterSpec(num_pods=8, k_spine=8, k_leaf=4)
    placements = []
    for jid in range(rng.integers(1, 8)):
        pods = rng.choice(8, size=rng.integers(2, 5), replace=False)
        placements.append(
            Placement(jid, {int(p): int(rng.integers(8, 33)) for p in pods})
        )
    C = jobs_to_demand(spec, placements)
    assert demand_feasible(C, spec)
