"""Per-architecture smoke tests (reduced same-family configs): one forward
+ one train-grad step on CPU, shape + finiteness checks, and prefill/decode
consistency against the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import ARCHS, get_api, make_smoke_batch, smoke_config

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = smoke_config(arch)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_smoke_batch(cfg)
    loss, grads = jax.value_and_grad(api.loss)(params, batch)
    assert np.isfinite(float(loss))
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in gleaves)
    # vocab-scale sanity: initial loss ≈ ln(V)
    assert float(loss) < np.log(cfg.vocab_size) + 1.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode must reproduce the full-sequence logits."""
    cfg = smoke_config(arch)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    rng = np.random.default_rng(2)
    batch = make_smoke_batch(cfg, rng=rng, batch=B, seq=S)
    s_max = 32
    nv = cfg.vision_tokens if cfg.family == "vlm" else 0  # vision prefix

    # full pass (no cache)
    cache0 = api.init_cache(B, s_max)
    full_logits, _ = api.prefill(params, batch, cache0)

    # prefill on the first half, then decode token by token
    split = S // 2
    half = dict(batch)
    half["tokens"] = batch["tokens"][:, :split]
    cache = api.init_cache(B, s_max)
    logits, cache = api.prefill(params, half, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, nv:], np.float32),
        np.asarray(full_logits[:, nv : nv + split], np.float32),
        atol=2e-3, rtol=2e-3,
    )
    for t in range(split, S):
        tok = batch["tokens"][:, t : t + 1]
        step_logits, cache = api.decode(params, tok, cache)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0], np.float32),
            np.asarray(full_logits[:, nv + t], np.float32),
            atol=2e-3, rtol=2e-3,
            err_msg=f"{arch} decode step {t}",
        )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_counts_match_actual(arch):
    """config.param_counts() total must track the real parameter count of
    the smoke model within 20% (it drives the roofline MODEL_FLOPS)."""
    cfg = smoke_config(arch)
    api = get_api(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    actual = sum(
        int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes)
    )
    declared, _ = cfg.param_counts()
    assert declared == pytest.approx(actual, rel=0.2), (declared, actual)


def test_full_configs_match_assignment():
    """The exact assignment numbers, via the canonical configs package."""
    c = configs.get_config("deepseek-v3-671b")
    assert (c.num_layers, c.d_model, c.num_heads) == (61, 7168, 128)
    assert c.moe.num_experts == 256 and c.moe.top_k == 8
    assert c.moe.d_expert == 2048 and c.vocab_size == 129280
    c = configs.get_config("grok-1-314b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (64, 6144, 48, 8)
    assert c.moe.num_experts == 8 and c.moe.top_k == 2
    c = configs.get_config("gemma-2b")
    assert c.num_kv_heads == 1 and c.head_dim == 256 and c.vocab_size == 256000
    c = configs.get_config("gemma2-9b")
    assert c.local_global and c.sliding_window == 4096 and c.logit_softcap == 30.0
    c = configs.get_config("qwen2.5-14b")
    assert c.qkv_bias and c.d_ff == 13824
    c = configs.get_config("olmo-1b")
    assert c.norm_kind == "nonparametric" and c.vocab_size == 50304
    c = configs.get_config("jamba-1.5-large-398b")
    assert c.block_pattern == ("attn",) + ("mamba",) * 7
    assert c.moe.num_experts == 16 and c.d_model == 8192
    c = configs.get_config("rwkv6-1.6b")
    assert c.attn_kind == "none" and c.d_ff == 7168
    c = configs.get_config("whisper-small")
    assert c.is_encoder_decoder and c.encoder_layers == 12
    c = configs.get_config("internvl2-1b")
    assert c.vision_tokens == 256 and c.num_kv_heads == 2


def test_plans_exist_for_all():
    for a in configs.ARCH_IDS:
        plan = configs.get_plan(a)
        assert plan.tp >= 1 and plan.notes


def test_moe_active_params_less_than_total():
    for a in ("deepseek-v3-671b", "grok-1-314b", "jamba-1.5-large-398b"):
        total, active = configs.get_config(a).param_counts()
        assert active < total / 2


def test_gemma2_local_global_alternation():
    """Local layers must mask beyond the sliding window; global must not."""
    cfg = smoke_config("gemma2-9b")
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 1, 16
    rng = np.random.default_rng(0)
    batch = make_smoke_batch(cfg, rng=rng, batch=B, seq=S)
    # perturb the earliest token; beyond the window the *local-only* layers
    # ignore it, but the model has global layers so logits may change —
    # just assert finiteness + shape here (alternation correctness is
    # covered by decode consistency above).
    loss = api.loss(params, batch)
    assert np.isfinite(float(loss))
