"""Tests for the repro.obs flight-recorder substrate (tracing + metrics).

Covers the four acceptance properties of the observability PR:

* **determinism** — two identical seeded runs export byte-identical
  Chrome trace JSON (spans are keyed on *simulated* time only; measured
  wall-clock never enters the trace);
* **passivity** — the golden fluid trace (tests/golden/fluid_trace.json)
  is byte-identical with the tracer attached or not;
* **schema** — a mixed train+serve fluid run with a pod failure under
  the ``cheapest`` recovery policy produces a Perfetto-loadable trace
  covering all five required categories (solve, dark_window, fault,
  policy, request);
* **postmortem** — the bounded flight recorder dumps the last N events
  as JSON when a guarded block raises, and re-raises unchanged.

Plus accuracy/shape unit tests for the metrics registry (quantile
sketch vs numpy percentiles, int-preserving counters, the shared
φ Timeline).
"""
import json
import math
import os

import numpy as np
import pytest

from repro import obs
from repro.fault import FailureEvent, RepairEvent
from repro.sim import SimConfig, Simulator, generate_trace
from tests.golden import regen

P, K = 12, 8
GPUS = P * K * K


def _mixed_cfg(tracer=None):
    return SimConfig(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=P, k_spine=K, k_leaf=K, engine="fluid",
        reconfig_delay_s=0.01, recovery_policy="cheapest",
        tracer=tracer,
    )


def _mixed_jobs():
    return generate_trace(
        14, num_gpus=GPUS, workload_level=0.9, seed=3,
        max_job_gpus=GPUS // 4, serving_jobs=2, serving_gpus=128,
    )


def _run_mixed(tracer):
    """Mixed train+serve fluid run: pod failure on a pod hosting a
    *training* job (so recovery-policy decisions fire), nonzero
    reconfiguration delay (dark windows), serving fleets (requests)."""
    jobs = _mixed_jobs()
    t_fail = jobs[7].arrival + 5.0
    # probe run: find a pod hosting training work at the fault instant
    probe = Simulator(_mixed_cfg(), _mixed_jobs())
    probe.run(until=t_fail)
    train_pods = sorted({
        p for r in probe.running.values() if r.job.kind == "train"
        for p in r.pods
    })
    assert train_pods, "scenario drifted: no training job running at t_fail"
    pod = train_pods[0]
    evs = [
        FailureEvent(t_fail, "pod", pod=pod),
        RepairEvent(t_fail + 3600.0, "pod", pod=pod),
    ]
    sim = Simulator(_mixed_cfg(tracer), jobs, fault_events=evs)
    sim.run()
    sim.serving_summary()
    return sim


@pytest.fixture(scope="module")
def mixed(tmp_path_factory):
    """One traced mixed run + a second identical run's export bytes."""
    d = tmp_path_factory.mktemp("obs")
    tr1, tr2 = obs.Tracer(), obs.Tracer()
    sim = _run_mixed(tr1)
    _run_mixed(tr2)
    p1, p2 = str(d / "a.json"), str(d / "b.json")
    tr1.export_json(p1)
    tr2.export_json(p2)
    with open(p1, "rb") as fh:
        b1 = fh.read()
    with open(p2, "rb") as fh:
        b2 = fh.read()
    return sim, tr1, b1, b2


# ---- determinism ----------------------------------------------------------

def test_trace_export_deterministic(mixed):
    _, _, b1, b2 = mixed
    assert b1 == b2, "same seed must export byte-identical trace JSON"


def test_golden_table_byte_identical_with_tracer():
    """Tracing is passive: the golden fluid table regenerated with a
    tracer attached serializes byte-for-byte like the committed file."""
    with open(regen.GOLDEN_PATH) as fh:
        committed = fh.read()
    table = regen.build_table(tracer=obs.Tracer())
    regenerated = json.dumps(table, indent=1, sort_keys=True) + "\n"
    assert regenerated == committed


# ---- Perfetto / Chrome trace-event schema ---------------------------------

def test_trace_validates_and_covers_required_categories(mixed):
    sim, tr, b1, _ = mixed
    doc = json.loads(b1)
    assert obs.validate_trace(doc) == []
    cats = {e.get("cat") for e in doc["traceEvents"] if e.get("ph") != "M"}
    required = {"solve", "dark_window", "fault", "policy", "request"}
    assert required <= cats, f"missing categories: {required - cats}"
    # thread-name metadata makes Perfetto group rows by category
    meta = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    named = {
        e["args"]["name"] for e in meta if e["name"] == "thread_name"
    }
    assert required <= named
    # simulated-time µs timestamps, non-decreasing body order
    body = [e for e in doc["traceEvents"] if e.get("ph") != "M"]
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts) and all(t >= 0 for t in ts)
    assert all(e["dur"] >= 0 for e in body if e["ph"] == "X")


def test_solve_spans_carry_control_plane_args(mixed):
    sim, tr, _, _ = mixed
    spans = [e for e in tr.events("solve") if e["ph"] == "X"]
    assert spans and len(spans) == sim.reconfig_calls
    incremental = [e for e in spans if e["args"]["incremental"]]
    assert incremental, "mixed run must hit the mdmcf_delta path"
    assert all("rewired" in e["args"] and "ltrr" in e["args"] for e in spans)
    assert sum(1 for e in incremental) == sim.delta_calls


def test_dark_window_and_downtime_agree(mixed):
    sim, tr, _, _ = mixed
    assert sim.downtime_events > 0
    windows = [e for e in tr.events("dark_window") if e["ph"] == "X"]
    assert windows
    # every window prices the configured delay (10 ms → µs)
    assert all(abs(e["dur"] - 0.01 * 1e6) < 1e-6 for e in windows)


def test_policy_and_request_events(mixed):
    sim, tr, _, _ = mixed
    decisions = [e for e in tr.events("policy")]
    assert len(decisions) == len(sim.policy_decisions) > 0
    reqs = tr.events("request")
    assert reqs
    for e in reqs:
        if e["ph"] != "X":
            continue
        a = e["args"]
        total = a["queue_s"] + a["transfer_s"] + a["decode_s"]
        assert abs(total - e["dur"] / 1e6) < 1e-6


# ---- flight recorder ------------------------------------------------------

def test_flight_recorder_dumps_on_exception(tmp_path):
    dump = str(tmp_path / "crash.flightrec.json")
    tr = obs.Tracer(flight_size=8, flight_dump=dump)
    for n in range(20):
        tr.instant("fault", f"ev{n}", ts=float(n))
    with pytest.raises(ValueError, match="boom"):
        with obs.flight_guard(tr):
            raise ValueError("boom")
    with open(dump) as fh:
        doc = json.load(fh)
    assert doc["schema"] == "repro-flightrec/1"
    assert doc["error"]["type"] == "ValueError"
    assert "boom" in doc["error"]["message"]
    # bounded: only the last flight_size events survive
    assert len(doc["events"]) == 8
    assert doc["events"][-1]["name"] == "ev19"


def test_flight_guard_noop_without_target(tmp_path):
    tr = obs.Tracer()  # enabled, but no flight_dump path
    with pytest.raises(RuntimeError):
        with obs.flight_guard(tr):
            raise RuntimeError("x")
    with pytest.raises(RuntimeError):
        with obs.flight_guard(obs.NULL, str(tmp_path / "never.json")):
            raise RuntimeError("y")
    assert not os.path.exists(str(tmp_path / "never.json"))


def test_simulator_run_dumps_flight_on_crash(tmp_path, monkeypatch):
    dump = str(tmp_path / "sim.flightrec.json")
    tr = obs.Tracer(flight_dump=dump)
    jobs = _mixed_jobs()
    sim = Simulator(_mixed_cfg(tr), jobs)
    monkeypatch.setattr(
        sim, "_refresh_slowdowns",
        lambda *a, **kw: (_ for _ in ()).throw(RuntimeError("mid-run")),
    )
    with pytest.raises(RuntimeError, match="mid-run"):
        sim.run()
    assert os.path.exists(dump)
    with open(dump) as fh:
        assert json.load(fh)["error"]["type"] == "RuntimeError"


# ---- null tracer / disabled cost ------------------------------------------

def test_null_tracer_is_inert():
    assert obs.NULL.enabled is False
    assert obs.NULL.span("solve", "x", ts=0.0, dur=1.0) is None
    assert obs.NULL.instant("fault", "y") is None
    assert obs.NULL.flight_events() == []
    sim = Simulator(_mixed_cfg(), _mixed_jobs())
    assert sim.trace is obs.NULL


# ---- metrics registry -----------------------------------------------------

def test_quantile_sketch_matches_numpy_within_bound():
    rng = np.random.default_rng(11)
    vals = rng.lognormal(mean=-1.0, sigma=1.5, size=20_000)
    s = obs.QuantileSketch("lat", lo=1e-6, hi=1e4, bins=512)
    for v in vals:
        s.observe(float(v))
    tol = s.rel_error()
    for q in (0.5, 0.9, 0.99):
        truth = float(np.percentile(vals, 100 * q))
        est = s.quantile(q)
        assert abs(est / truth - 1.0) <= tol + 1e-12, (q, est, truth, tol)
    assert abs(s.mean - vals.mean()) < 1e-9 * max(1.0, abs(vals.mean()))


def test_quantile_sketch_clamps_out_of_range():
    s = obs.QuantileSketch("x", lo=1e-3, hi=1e3, bins=64)
    for v in (0.0, 1e-9, 1e9):
        s.observe(v)
    assert s.quantile(0.0) == s.lo
    assert s.quantile(1.0) == s.hi
    assert math.isnan(obs.QuantileSketch("empty").quantile(0.5))


def test_counter_stays_int():
    c = obs.Counter("n")
    c.inc()
    c.inc(2)
    assert c.value == 3 and isinstance(c.value, int)
    c.inc(0.5)
    assert isinstance(c.value, float)


def test_timeline_monotonizes_and_integrates():
    tl = obs.Timeline("phi")
    tl.point("a", 0.0, 1.0)
    tl.point("a", 10.0, 0.0)
    tl.point("a", 5.0, 0.5)  # behind the clock → clamped to t=10
    assert tl["a"] == [(0.0, 1.0), (10.0, 0.0), (10.0, 0.5)]
    assert tl.integrate("a", 0.0, 20.0) == pytest.approx(10.0 + 5.0)
    assert tl.integrate("missing", 0.0, 1.0) == 0.0
    assert "a" in tl and len(tl) == 1 and list(tl) == ["a"]


def test_registry_get_or_create_and_type_guard():
    reg = obs.MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    reg.histogram("h").observe(1.0)
    reg.timeline("t").point("k", 0.0, 1.0)
    snap = reg.snapshot()
    assert snap["x"] == 0 and snap["h.count"] == 1 and snap["t.keys"] == 1


def test_simulator_metrics_views_keep_shapes(mixed):
    sim, _, _, _ = mixed
    assert isinstance(sim.fault_counts, dict)
    assert set(sim.fault_counts) == {"failures", "repairs", "expands"}
    assert sim.fault_counts["failures"] == 1
    assert isinstance(sim.reconfig_calls, int)
    assert isinstance(sim.policy_decisions, list)
    assert all(isinstance(d, dict) for d in sim.policy_decisions)
    assert isinstance(sim.phi_timeline, obs.Timeline)
    # serving latencies stream into the registry sketch exactly once
    h = sim.metrics.get("serving.latency_s")
    assert h is not None and h.count > 0
    before = h.count
    sim.serving_summary()  # recompute must not double-observe
    assert h.count == before
    snap = sim.metrics.snapshot()
    assert snap["control.reconfigs"] == sim.reconfig_calls


# ---- report / bench block -------------------------------------------------

def test_bench_block_roundtrip(tmp_path):
    from repro.obs.report import load_bench_metrics, load_bench_rows

    payload = {
        "throughput": {"events_per_sec": np.float64(2500.0),
                       "events": np.int64(10)},
        "rows": [{"pods": 16, "k_spine": 8, "speedup": 3.0}],
        "checks": {"ok": True},
    }
    path = obs.write_bench_block("demo", payload, str(tmp_path))
    assert os.path.basename(path) == "BENCH_demo.json"
    m = load_bench_metrics(path)
    assert m["throughput.events_per_sec"] == 2500.0
    assert m["throughput.events"] == 10  # numpy ints survive flattening
    assert m["checks.ok"] is True
    assert load_bench_rows(path) == payload["rows"]
    # legacy raw payloads read through the same loaders
    legacy = tmp_path / "legacy.json"
    legacy.write_text(json.dumps({"rows": payload["rows"], "a": {"b": 1}}))
    assert load_bench_metrics(str(legacy))["a.b"] == 1
    assert load_bench_rows(str(legacy)) == payload["rows"]


def test_render_smoke(mixed):
    sim, tr, _, _ = mixed
    summary = obs.render_summary(sim.metrics)
    assert "control.reconfigs" in summary
    art = obs.render_timeline(tr)
    assert "solve" in art and "request" in art
    assert obs.render_timeline(obs.NULL) == "trace: (no events)"


# ---- renderer golden text (exact output is the contract) -------------------

def test_render_blame_golden():
    causes = {
        "dark_cold": 1.5,
        "queue": 0.0,
        "phi_shortfall": 0.5,
        "degraded": 2.0,
    }
    expected = "\n".join([
        "== blame ==",
        "degraded            2.000000 s  50.0% ########",
        "dark_cold           1.500000 s  37.5% ######",
        "phi_shortfall       0.500000 s  12.5% ##",
        "queue               0.000000 s   0.0% ",
        "total               4.000000 s  (residual +0.000e+00)",
    ])
    assert obs.render_blame(causes, slowdown_s=4.0, width=16) == expected


def test_render_blame_residual_and_tiny_share():
    # a nonzero cause always gets ≥ one tick; the footer shows the
    # conservation residual with its sign
    out = obs.render_blame(
        {"solver": 0.001, "queue": 99.999}, slowdown_s=101.0, width=8,
    )
    lines = out.splitlines()
    assert lines[1] == "queue       99.999000 s  100.0% ########"
    assert lines[2] == "solver       0.001000 s   0.0% #"
    assert lines[3] == "total      101.000000 s  (residual +1.000e+00)"
    assert obs.render_blame({}) == "== blame ==\n(no causes)"


def test_render_summary_golden():
    reg = obs.MetricsRegistry()
    reg.counter("control.reconfigs").inc(3)
    reg.gauge("fleet.phi").set(0.25)
    expected = "\n".join([
        "== metrics ==",
        "control.reconfigs = 3",
        "fleet.phi         = 0.25",
    ])
    assert obs.render_summary(reg) == expected
    assert obs.render_summary(obs.MetricsRegistry()) == "metrics: (empty)"


def test_render_timeline_golden():
    tr = obs.Tracer()
    for n in range(4):
        tr.instant("fault", f"f{n}", ts=float(n))
    tr.span("solve", "s", ts=0.0, dur=4.0)
    # the tracer stamps µs (simulated seconds × 1e6): ts 0..3 s + a 4 s
    # span give a 4-second horizon bucketed into 9 columns
    expected = "\n".join([
        "== trace ==  [0.0s .. 4.0s simulated]",
        "fault |@ @ @ @  | 4 events",
        "solve |@        | 1 events",
    ])
    assert obs.render_timeline(tr, width=9) == expected


# ---- quantile-sketch merge -------------------------------------------------

def test_sketch_merge_equals_combined_stream():
    rng = np.random.default_rng(5)
    xs = rng.lognormal(sigma=1.2, size=4000)
    ys = rng.lognormal(mean=1.0, sigma=0.8, size=6000)
    a = obs.QuantileSketch("a", lo=1e-4, hi=1e4, bins=256)
    b = obs.QuantileSketch("b", lo=1e-4, hi=1e4, bins=256)
    c = obs.QuantileSketch("c", lo=1e-4, hi=1e4, bins=256)
    for v in xs:
        a.observe(float(v))
        c.observe(float(v))
    for v in ys:
        b.observe(float(v))
        c.observe(float(v))
    out = a.merge(b)
    assert out is a  # in place, chainable
    assert a.count == c.count == 10_000
    assert a.total == pytest.approx(c.total)
    for q in (0.01, 0.5, 0.9, 0.99):
        assert a.quantile(q) == c.quantile(q), q  # bitwise: bins add


def test_sketch_merge_rejects_layout_mismatch():
    a = obs.QuantileSketch("a", lo=1e-3, hi=1e3, bins=64)
    assert a.compatible(obs.QuantileSketch("x", lo=1e-3, hi=1e3, bins=64))
    for bad in (
        obs.QuantileSketch("lo", lo=1e-4, hi=1e3, bins=64),
        obs.QuantileSketch("hi", lo=1e-3, hi=1e4, bins=64),
        obs.QuantileSketch("bins", lo=1e-3, hi=1e3, bins=128),
    ):
        assert not a.compatible(bad)
        with pytest.raises(ValueError, match="bin layouts"):
            a.merge(bad)


# ---- timeline integrate edge cases ----------------------------------------

def test_timeline_integrate_edge_cases():
    tl = obs.Timeline("phi")
    tl.point("a", 1.0, 1.0)
    tl.point("a", 3.0, 0.5)
    tl.point("a", 3.0, 0.25)  # zero-width monotonized segment
    # zero-width window and inverted bounds are exactly 0
    assert tl.integrate("a", 2.0, 2.0) == 0.0
    assert tl.integrate("a", 5.0, 2.0) == 0.0
    # before the first breakpoint the value is 0
    assert tl.integrate("a", 0.0, 1.0) == 0.0
    # the zero-width (3.0, 0.5) segment contributes exactly 0
    assert tl.integrate("a", 1.0, 5.0) == pytest.approx(2.0 + 0.25 * 2.0)
    # open-ended tail: a zero tail value never yields inf · 0 = nan
    tl.point("a", 5.0, 0.0)
    got = tl.integrate("a", 1.0, math.inf)
    assert got == pytest.approx(2.0 + 0.5) and not math.isnan(got)
    # nonzero tail over an infinite window is inf, not nan
    tl.point("b", 0.0, 1.0)
    assert tl.integrate("b", 0.0, math.inf) == math.inf


# ---- strict trace validation ----------------------------------------------

def _ev(ts, dur=None, ph="i", pid=1, tid=1, name="e"):
    ev = {"ph": ph, "ts": ts, "pid": pid, "tid": tid, "name": name,
          "cat": "solve"}
    if dur is not None:
        ev.update(ph="X", dur=dur)
    return ev


def test_validate_trace_strict_rejects_out_of_order_ts():
    doc = {"traceEvents": [_ev(5.0), _ev(1.0)]}
    assert obs.validate_trace(doc) == []  # loadable
    problems = obs.validate_trace(doc, strict=True)
    assert len(problems) == 1 and "out of order" in problems[0]
    # a different track is a different clock: no problem
    ok = {"traceEvents": [_ev(5.0), _ev(1.0, tid=2)]}
    assert obs.validate_trace(ok, strict=True) == []


def test_validate_trace_strict_rejects_partial_overlap():
    # [0, 10] then [5, 15] on one lane draws as garbage in Perfetto
    doc = {"traceEvents": [_ev(0.0, dur=10.0), _ev(5.0, dur=10.0)]}
    assert obs.validate_trace(doc) == []
    problems = obs.validate_trace(doc, strict=True)
    assert len(problems) == 1 and "partially overlaps" in problems[0]
    # containment (nesting) is fine; so are back-to-back spans
    nested = {"traceEvents": [
        _ev(0.0, dur=10.0), _ev(2.0, dur=3.0), _ev(5.0, dur=5.0),
        _ev(10.0, dur=4.0),
    ]}
    assert obs.validate_trace(nested, strict=True) == []


def test_tracer_output_passes_strict_validation(mixed):
    """chrome_trace() lane-splits concurrent spans, so the real tracer's
    output must satisfy the strict renderability rules by construction."""
    _, tr, b1, _ = mixed
    assert obs.validate_trace(json.loads(b1), strict=True) == []
