"""Reconfiguration algorithms vs the paper's ILP model (§3.2) and the
optimality theorem (Thm 4.1): MDMCF must realize *every* feasible demand
exactly under Cross Wiring; Uniform provably cannot (Fig. 1)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.logical import random_feasible_demand
from repro.core.reconfig import (
    check_ilp_constraints,
    config_cosine,
    helios_matching,
    ltrr,
    mdmcf_cold,
    mdmcf_reconfigure,
    uniform_best_effort,
    uniform_exact_small,
    uniform_greedy,
)
from repro.core.topology import ClusterSpec, demand_feasible


@st.composite
def feasible_demands(draw):
    p = draw(st.integers(2, 6))
    k = draw(st.sampled_from([2, 4, 6, 8]))
    fill = draw(st.floats(0.3, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    spec = ClusterSpec(num_pods=p, k_spine=k, k_leaf=4)
    C = random_feasible_demand(spec, np.random.default_rng(seed), fill=fill)
    return spec, C


@settings(max_examples=40, deadline=None)
@given(feasible_demands())
def test_thm41_mdmcf_realizes_everything(arg):
    """Thm 4.1 as a property: any symmetric degree-feasible demand is
    realized *exactly* under Cross Wiring, satisfying ILP (1)-(6)."""
    spec, C = arg
    assert demand_feasible(C, spec)
    res = mdmcf_reconfigure(spec, C)
    check_ilp_constraints(spec, C, res.config, topology="cross_wiring")
    assert res.ltrr == pytest.approx(1.0)


@settings(max_examples=20, deadline=None)
@given(feasible_demands())
def test_thm41_mcf_oracle_path(arg):
    spec, C = arg
    res = mdmcf_reconfigure(spec, C, method="mcf")
    check_ilp_constraints(spec, C, res.config, topology="cross_wiring")
    assert res.ltrr == pytest.approx(1.0)


@settings(max_examples=20, deadline=None)
@given(feasible_demands(), st.integers(0, 2**31 - 1))
def test_min_rewiring_warm_start(arg, seed):
    """Warm-started MDMCF rewires no more than cold MDMCF (eq. 7)."""
    spec, C1 = arg
    C2 = random_feasible_demand(spec, np.random.default_rng(seed), fill=0.8)
    old = mdmcf_reconfigure(spec, C1).config
    warm = mdmcf_reconfigure(spec, C2, old=old).config
    cold = mdmcf_cold(spec, C2).config
    check_ilp_constraints(spec, C2, warm, topology="cross_wiring")
    assert warm.rewiring_distance(old) <= cold.rewiring_distance(old)


def _triangle_demand(spec, links):
    """Fig. 1's counterexample: 3-pod full mesh at full port budget."""
    H = spec.num_ocs_groups
    C = np.zeros((H, 3, 3), dtype=np.int64)
    for i in range(3):
        for j in range(3):
            if i != j:
                C[:, i, j] = links
    return C


def test_fig1_uniform_counterexample():
    """The paper's Fig. 1: a 3-pod full mesh at full degree is certifiably
    unrealizable under Uniform (odd cycle ⇒ chromatic index 3Δ/2 > K_spine)
    but realized exactly by Cross Wiring."""
    spec = ClusterSpec(num_pods=3, k_spine=4, k_leaf=2)
    C = _triangle_demand(spec, 2)  # degree 4 = K_spine (full)
    assert demand_feasible(C, spec)

    exact = uniform_exact_small(spec, C)
    assert exact.ltrr < 1.0  # certified: even the optimum drops demand
    # a triangle with multiplicity m needs 3m matchings; m=2, K=4 < 6
    realized = exact.config.realized_bidirectional().sum()
    assert realized < C.sum()

    res = mdmcf_reconfigure(spec, C)
    check_ilp_constraints(spec, C, res.config, topology="cross_wiring")
    assert res.ltrr == pytest.approx(1.0)


def test_uniform_greedy_valid_configs():
    spec = ClusterSpec(num_pods=5, k_spine=6, k_leaf=4)
    rng = np.random.default_rng(3)
    C = random_feasible_demand(spec, rng, fill=1.0)
    for fn in (uniform_greedy, uniform_best_effort):
        res = fn(spec, C)
        check_ilp_constraints(
            spec, C, res.config, topology="uniform", require_exact=False
        )
        assert 0.0 <= res.ltrr <= 1.0


def test_helios_valid():
    spec = ClusterSpec(num_pods=5, k_spine=6, k_leaf=4)
    C = random_feasible_demand(spec, np.random.default_rng(4), fill=0.8)
    res = helios_matching(spec, C)
    check_ilp_constraints(
        spec, C, res.config, topology="cross_wiring", require_exact=False
    )


def test_ltrr_uniform_degrades_at_full_fill():
    """Paper Fig. 2b/5: Uniform's realization rate < 1 on heavy demands;
    Cross Wiring stays at 1.0."""
    spec = ClusterSpec(num_pods=8, k_spine=8, k_leaf=4)
    rng = np.random.default_rng(0)
    uni, itv = [], []
    for _ in range(10):
        C = random_feasible_demand(spec, rng, fill=1.0)
        uni.append(uniform_greedy(spec, C).ltrr)
        itv.append(mdmcf_reconfigure(spec, C).ltrr)
    assert np.mean(itv) == pytest.approx(1.0)
    assert np.mean(uni) < 1.0


def test_config_cosine_bounds():
    spec = ClusterSpec(num_pods=3, k_spine=4, k_leaf=2)
    C = _triangle_demand(spec, 1)
    a = mdmcf_reconfigure(spec, C).config
    assert config_cosine(a, a) == pytest.approx(1.0)


def test_infeasible_demand_rejected():
    spec = ClusterSpec(num_pods=3, k_spine=4, k_leaf=2)
    C = _triangle_demand(spec, 3)  # degree 6 > K_spine
    with pytest.raises(ValueError):
        mdmcf_reconfigure(spec, C)
