"""repro.fault.remediate: the closed-loop self-healing engine.

Centerpiece: the no-flap-thrash property — a cordoned link re-enters TE
demand only after its exponential backoff expires with the slot healthy
the whole window, and a *sustained* flapper never re-enters at all.
Plus: every remediation actuator lands in the metrics registry and the
blame ledger (conservation stays exact with remediation causes in
play), the solver-fallback counter satellite, and budget enforcement."""
import math

import pytest

from repro import obs
from repro.fault import (
    ChaosScenario,
    RemediationEngine,
    flapping_link,
    scenario_events,
    standard_scenarios,
)
from repro.obs import attribute_jobs, attribute_requests
from repro.sim import SimConfig, Simulator, generate_trace

P, K = 12, 8
GPUS = P * K * K


def _cfg(**kw):
    kw.setdefault("reconfig_delay_s", 0.01)
    return SimConfig(
        architecture="cross_wiring", strategy="mdmcf",
        num_pods=P, k_spine=K, k_leaf=K, engine="fluid",
        recovery_policy="ckpt_restart", **kw,
    )


def _jobs(n=10, serving_gpus=256, **kw):
    return generate_trace(
        n, num_gpus=GPUS, workload_level=0.9, seed=3,
        max_job_gpus=GPUS // 4, serving_jobs=1, serving_gpus=serving_gpus,
        **kw,
    )


# ---------------------------------------------------------------------------
# pure policy: backoff + budgets
# ---------------------------------------------------------------------------

def test_backoff_doubles_and_caps():
    eng = RemediationEngine(cordon_base_s=100.0, max_backoff_doublings=3)
    assert [eng.backoff_s(k) for k in range(5)] == [
        100.0, 200.0, 400.0, 800.0, 800.0,  # capped at 2^3
    ]
    with pytest.raises(ValueError):
        RemediationEngine(cordon_base_s=0.0)


def test_unbound_engine_is_inert():
    eng = RemediationEngine()
    eng(object())  # no sim bound: must swallow anything silently
    assert eng.summary() == {
        "cordons": 0, "extensions": 0, "readmits": 0, "drains": 0,
        "ckpts": 0, "solver_escalations": 0, "skipped_budget": 0,
        "active_cordons": 0,
    }


# ---------------------------------------------------------------------------
# the no-flap-thrash property
# ---------------------------------------------------------------------------

def _flap_run(flap_until, until=None, base=600.0):
    """One sim with a single scripted flapper (period 600 s, duty 0.5)
    active over [600, flap_until) and a cordon-only engine."""
    eng = RemediationEngine(cordon_base_s=base, max_drains=0, max_ckpts=0,
                            max_solver_escalations=0)
    tr = obs.Tracer()
    sim = Simulator(
        _cfg(on_health=eng, tracer=tr),
        _jobs(),
        fault_events=flapping_link((0, 1, 1), 600.0, flap_until, 600.0),
    )
    sim.run(until=until)
    return sim, eng, tr


def test_sustained_flapper_stays_cordoned():
    """A link that flaps for the whole observed window is cordoned once
    and NEVER readmitted inside it: each backoff expiry sees the
    trailing flap window still hot (or a failure since the cordon) and
    doubles the backoff instead."""
    H = 6 * 3600.0
    sim, eng, tr = _flap_run(flap_until=H, until=H)
    s = eng.summary()
    assert s["cordons"] == 1
    assert s["readmits"] == 0 and s["active_cordons"] == 1
    assert s["extensions"] >= 1  # backoff doubled, not readmitted
    assert sim.mask.cordoned[0, 1, 1]
    assert sim.metrics.counter("remediation.readmits").value == 0
    names = [e["name"] for e in tr.events("remediation")]
    assert names.count("cordon") == 1 and "readmit" not in names


def test_readmission_waits_out_the_backoff():
    """A flapper that goes quiet re-enters TE demand — but only after a
    full backoff window of healthy residency, never earlier."""
    base = 600.0
    sim, eng, tr = _flap_run(flap_until=2400.0, base=base)
    s = eng.summary()
    assert s["cordons"] == 1 and s["readmits"] == 1
    assert s["active_cordons"] == 0 and not sim.mask.cordoned[0, 1, 1]
    evs = tr.events("remediation")
    t_cordon = next(e["ts"] for e in evs if e["name"] == "cordon")
    t_readmit = next(e["ts"] for e in evs if e["name"] == "readmit")
    # trace timestamps are microseconds of simulated time
    assert t_readmit - t_cordon >= base * 1e6
    # ... and the slot was healthy for >= base before re-entry: the last
    # scripted failure is at 1800 s, so readmission cannot predate 2400 s
    assert t_readmit >= (1800.0 + base) * 1e6
    # relapse extensions (if any) each restarted the residency clock
    last = sim.health.last_link_failure(0, 1, 1)
    assert last is not None and t_readmit >= (last + base) * 1e6


def test_cordon_budget_is_enforced():
    """With max_cordoned=0 every flap detection is a budget skip — the
    mask is never touched."""
    eng = RemediationEngine(cordon_base_s=600.0, max_cordoned=0,
                            max_drains=0, max_ckpts=0,
                            max_solver_escalations=0)
    sim = Simulator(
        _cfg(on_health=eng),
        _jobs(),
        fault_events=flapping_link((0, 1, 1), 600.0, 6 * 3600.0, 600.0),
    )
    sim.run()
    s = eng.summary()
    assert s["cordons"] == 0 and s["skipped_budget"] >= 1
    assert not sim.mask.cordoned.any()


# ---------------------------------------------------------------------------
# actuators land in metrics + blame, conservation stays exact
# ---------------------------------------------------------------------------

def test_preempt_checkpoint_pauses_and_blames():
    jobs = _jobs()
    train = next(j for j in jobs if j.kind != "serve")
    sim = Simulator(_cfg(), jobs)
    sim.schedule_action(
        train.arrival + 1800.0,
        lambda t: sim.preempt_checkpoint(t, train.job_id),
    )
    sim.run()
    assert sim.metrics.counter("remediation.ckpts").value == 1
    blames = attribute_jobs(sim)
    b = blames[train.job_id]
    assert b.causes.get("remediation", 0.0) > 0
    assert abs(b.residual) <= 1e-6


def test_remediate_drain_frees_pod_and_counts():
    sim = Simulator(_cfg(), _jobs())

    def act(t):
        for j, r in sorted(sim.running.items()):
            if r.job.kind == "serve" and len(r.decode_pods) > 1:
                return sim.remediate_drain(t, j, sorted(r.decode_pods)[-1])
        return False

    sim.schedule_action(1800.0, act, trigger="remediation")
    sim.run()
    assert sim.metrics.counter("remediation.drains").value == 1
    res = attribute_requests(sim)
    assert res["conserved"]


def test_escalate_solver_is_bounded():
    sim = Simulator(_cfg(), _jobs())
    sim.schedule_action(
        1000.0, lambda t: sim.escalate_solver(t, 1800.0)
    )
    sim.run()
    assert sim.metrics.counter("remediation.solver_escalations").value == 1
    assert sim._solver_degraded_until == pytest.approx(1000.0 + 1800.0)


# ---------------------------------------------------------------------------
# satellite: swallowed delta-path fallbacks are first-class signals
# ---------------------------------------------------------------------------

def test_solver_fallbacks_counted_and_detected():
    """Sustained flapping invalidates the incremental solver's state on
    every mask change: the swallowed StaleStateError cold solves must
    land in the counter, the trace, and the fallback-rate detector."""
    tr = obs.Tracer()
    sc = ChaosScenario(
        name="flap", horizon_s=4 * 3600.0,
        # 3 flappers × (fail + repair) per 450 s period ≈ 8 cold solves
        # per 600 s — above the default fallback-rate threshold (5/600 s)
        flap_links=((0, 1, 1), (1, 2, 5), (0, 3, 7)), flap_from_s=600.0,
        flap_period_s=450.0,
    )
    sim = Simulator(
        _cfg(on_health=[].append, tracer=tr),
        _jobs(),
        fault_events=scenario_events(sc, K),
    )
    sim.run()
    assert sim.solver_fallbacks > 0
    assert sim.metrics.counter("control.solver_fallbacks").value == \
        sim.solver_fallbacks
    falls = [e for e in tr.events("health") if e["name"] == "fallback"]
    assert len(falls) == sim.solver_fallbacks
    assert "link_flap" in {e.detector for e in sim.health.events}


def test_fallback_rate_detector_and_escalation_budget():
    """≥ fallback_count cold solves inside the window fire the
    ``solver_fallback`` detector once (hot latch); the engine answers
    each firing with a bounded escalation until its budget is spent."""
    from repro.obs.health import HealthMonitor

    class _StubSim:
        def __init__(self):
            self.scheduled = []
            self.escalated = []
            self.health = None

        def schedule_action(self, t, fn, trigger="remediation"):
            self.scheduled.append((t, fn, trigger))
            fn(t)

        def escalate_solver(self, t, window_s):
            self.escalated.append((t, window_s))
            return False

    stub = _StubSim()
    eng = RemediationEngine(solver_window_s=900.0, max_solver_escalations=2)
    eng.bind(stub)
    mon = HealthMonitor(on_event=eng, fallback_count=3,
                        fallback_window_s=100.0)
    stub.health = mon
    for n in range(3):
        mon.observe_fallback(float(n), "StaleStateError")
    assert [e.detector for e in mon.events] == ["solver_fallback"]
    assert stub.escalated == [(2.0, 900.0)]
    # re-arm by letting the window cool, then refire twice more: the
    # second firing escalates (budget 2), the third is a budget skip
    for t0 in (1000.0, 2000.0):
        for n in range(3):
            mon.observe_fallback(t0 + n, "DeltaInfeasible")
    assert len(stub.escalated) == 2
    assert eng.summary()["solver_escalations"] == 2
    assert eng.summary()["skipped_budget"] == 1


# ---------------------------------------------------------------------------
# the closed loop end to end: engine helps, blame still conserves
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_closed_loop_improves_and_conserves():
    """The acceptance scenario (correlated top-of-pod burst + gray
    flapping + derated links) under an overloaded mixed workload:
    remediation strictly improves serving availability and SLO goodput
    over passive, and the blame ledger still conserves exactly with the
    new causes in play."""
    H = 8 * 3600.0
    sc = standard_scenarios(P, K, H)[2]
    assert sc.name == "burst_flap"

    def one(engine):
        sim = Simulator(
            _cfg(on_health=engine, reconfig_delay_s=30.0, serving_slo=2.0),
            generate_trace(
                12, num_gpus=GPUS, workload_level=1.1, seed=3,
                max_job_gpus=GPUS // 4, serving_jobs=2, serving_gpus=256,
            ),
            fault_events=scenario_events(sc, K),
        )
        sim.run(until=H)
        return sim, sim.serving_summary()

    passive, p_ss = one([].append)
    eng = RemediationEngine(cordon_base_s=600.0)
    healed, h_ss = one(eng)
    # the engine acted, and acting shrank the dark + fallback bill ...
    assert eng.summary()["cordons"] >= 1
    assert healed.downtime_s < passive.downtime_s
    assert healed.solver_fallbacks < passive.solver_fallbacks
    # ... which the users see: strictly better availability and goodput
    assert h_ss["availability"] > p_ss["availability"]
    assert h_ss["goodput"] > p_ss["goodput"]
    # every remediation second is attributed; conservation exact
    res = attribute_requests(healed)
    assert res["conserved"] and res["max_residual"] <= 1e-6
    assert res["totals"].get("cordon", 0.0) > 0
    assert res["totals"].get("remediation", 0.0) > 0
    blames = attribute_jobs(healed)
    assert max(abs(b.residual) for b in blames.values()) <= 1e-6
